"""SoS predicate properties: determinism, consistency, sign-exactness."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sos

ints = st.integers(min_value=-(2**30) + 1, max_value=2**30 - 1)
idxs = st.integers(min_value=0, max_value=2**40)


@given(ints, ints, ints, ints, idxs, idxs)
@settings(max_examples=300, deadline=None)
def test_sign_matches_det_when_nonzero(au, av, bu, bv, ma, mb):
    if ma == mb:
        mb += 1
    d = au * bv - av * bu
    s = sos.sign_det_sos(
        np,
        np.array([au]), np.array([av]), np.array([ma]),
        np.array([bu]), np.array([bv]), np.array([mb]),
    )[0]
    if d != 0:
        assert s == np.sign(d)
    assert s in (-1, 1)  # never zero under SoS


@given(ints, ints, ints, ints, idxs, idxs)
@settings(max_examples=200, deadline=None)
def test_antisymmetry(au, av, bu, bv, ma, mb):
    if ma == mb:
        mb += 1
    args = (np.array([au]), np.array([av]), np.array([ma]),
            np.array([bu]), np.array([bv]), np.array([mb]))
    s1 = sos.sign_det_sos(np, *args)[0]
    s2 = sos.sign_det_sos(
        np, args[3], args[4], args[5], args[0], args[1], args[2]
    )[0]
    assert s1 == -s2


def test_degenerate_resolved_consistently():
    # identical values, different indices: must resolve deterministically
    a = np.array([5]); b = np.array([5])
    s1 = sos.sign_det_sos(np, a, a, np.array([1]), b, b, np.array([2]))
    s2 = sos.sign_det_sos(np, a, a, np.array([1]), b, b, np.array([2]))
    assert s1 == s2 and s1[0] in (-1, 1)


def test_origin_vertex_resolved():
    # one vertex exactly at the origin -- classic degeneracy (case ii)
    u = np.array([[0, 5, -3]])
    v = np.array([[0, -2, 4]])
    idx = np.array([[10, 11, 12]])
    p = sos.face_crossed_vals(np, u, v, idx)
    assert p.dtype == bool  # resolves without error, deterministic
    p2 = sos.face_crossed_vals(np, u, v, idx)
    assert (p == p2).all()


@given(st.lists(st.tuples(ints, ints), min_size=3, max_size=3),
       st.permutations([0, 1, 2]))
@settings(max_examples=200, deadline=None)
def test_face_predicate_order_invariant(vals, perm):
    """Crossing decision must not depend on the vertex order given."""
    u = np.array([[x for x, _ in vals]])
    v = np.array([[y for _, y in vals]])
    idx = np.array([[100, 200, 300]])
    pu = u[:, perm]
    pv = v[:, perm]
    pidx = idx[:, perm]
    p1 = sos.face_crossed_vals(np, u, v, idx)[0]
    p2 = sos.face_crossed_vals(np, pu, pv, pidx)[0]
    assert p1 == p2


def test_strict_interior_and_exterior():
    # origin strictly inside conv{(1,0), (-1,1), (-1,-1)}
    u = np.array([[1, -1, -1]])
    v = np.array([[0, 1, -1]])
    idx = np.array([[0, 1, 2]])
    assert sos.face_crossed_vals(np, u, v, idx)[0]
    # clearly outside (all in right half-plane)
    u = np.array([[1, 2, 3]])
    v = np.array([[1, -1, 2]])
    assert not sos.face_crossed_vals(np, u, v, idx)[0]


def test_jax_numpy_agree():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    u = rng.integers(-(2**20), 2**20, (500, 3))
    v = rng.integers(-(2**20), 2**20, (500, 3))
    # inject degeneracies
    u[::7, 1] = u[::7, 0]
    v[::7, 1] = v[::7, 0]
    u[::11] = 0
    idx = np.arange(1500).reshape(500, 3)
    pn = sos.face_crossed_vals(np, u, v, idx)
    pj = np.asarray(
        sos.face_crossed_vals(jnp, jnp.asarray(u), jnp.asarray(v), jnp.asarray(idx))
    )
    assert (pn == pj).all()
