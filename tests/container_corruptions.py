"""Shared corrupt-container matrix (importable, assert-free checks).

Used twice:

* tests/test_container_errors.py runs it under pytest (both codecs);
* tests/opt_mode_check.py runs it under ``python -O`` in CI, where
  ``assert`` statements are stripped -- the typed ContainerError /
  ValueError raises exercised here are the only thing standing between
  a truncated container and silent garbage output, so every check below
  fails loudly with a real raise, never an assert.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.core import CompressionConfig, TileGrid, compress, compress_tiled
from repro.core import encode


def build_blobs():
    """(monolithic blob, tiled blob, tiled header) on a tiny field."""
    from repro.data import synthetic

    u, v = synthetic.double_gyre(T=5, H=12, W=16)
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                            fused=True, track_index=True,
                            dt=0.1, dx=2.0 / 15, dy=1.0 / 11)
    mono, _ = compress(u, v, cfg)
    tiled, _ = compress_tiled(u, v, cfg, TileGrid(tile_h=6, tile_w=8,
                                                  window_t=3))
    return mono, tiled, encode.tiled_header(tiled)


def expect(exc_types, fn, what: str):
    """Assert-free 'raises' check (works under python -O)."""
    try:
        fn()
    except exc_types:
        return
    except Exception as e:  # wrong type is as bad as no raise
        raise SystemExit(
            f"{what}: expected {exc_types}, got {type(e).__name__}: {e}")
    raise SystemExit(f"{what}: expected {exc_types}, nothing was raised")


def corrupt_footer_length(tiled: bytes) -> bytes:
    """Overwrite the footer's u32 length word with garbage."""
    m = len(encode.MAGIC_TILED)
    return tiled[: -m - 4] + struct.pack("<I", 2**31 - 1) + tiled[-m:]


def run_matrix(mono: bytes, tiled: bytes, hdr: dict):
    """The corrupt-container matrix; raises SystemExit on any miss."""
    CE = encode.ContainerError
    m = len(encode.MAGIC_TILED)

    # unknown codec tag is refused, never silently routed through zlib
    expect(ValueError, lambda: encode.codec_decompress(b"\x00" * 8, "lzma"),
           "unknown codec tag")
    expect(ValueError, lambda: encode.codec_decompress(b"", "huffman0"),
           "forged codec tag")

    # monolithic container: bad magic / corrupted frame / bad length word
    expect(CE, lambda: encode.unpack(b"NOPE!" + mono[5:]), "bad magic")
    expect(CE, lambda: encode.unpack(mono[:5] + b"\x00\x01\x02\x03"),
           "corrupt codec frame")
    payload = encode.codec_decompress(mono[5:],
                                      "zstd" if mono[:5] == encode.MAGIC
                                      else "zlib")
    forged = mono[:5] + encode.codec_compress(
        struct.pack("<I", len(payload) + 999) + payload[4:])
    expect(CE, lambda: encode.unpack(forged), "oversized header length")

    # forged header structure: sections as a list / entries missing keys
    import msgpack

    def forge_header(header):
        hdr = msgpack.packb(header, use_bin_type=True)
        return mono[:5] + encode.codec_compress(
            struct.pack("<I", len(hdr)) + hdr)

    expect(CE, lambda: encode.unpack(forge_header({"sections": [1, 2]})),
           "sections index not a map")
    expect(CE, lambda: encode.unpack(
        forge_header({"sections": {"a": {"off": 0}}})),
        "section entry missing keys")
    expect(CE, lambda: encode.unpack(
        forge_header({"sections": {"a": {"off": "0", "len": 4,
                                         "dtype": "u1", "shape": [4]}}})),
        "section entry non-integer off/len")
    # forged tiled footer: units directory malformed
    def forge_footer(units):
        import zlib as _zlib
        raw = _zlib.compress(msgpack.packb({"units": units},
                                           use_bin_type=True), 6)
        m = encode.MAGIC_TILED
        return m + raw + struct.pack("<I", len(raw)) + m
    expect(CE, lambda: encode.tiled_header(forge_footer("nope")),
           "units directory not a list")
    expect(CE, lambda: encode.tiled_header(forge_footer([{"off": 3}])),
           "unit entry missing keys")
    expect(CE, lambda: encode.tiled_header(forge_footer(
        [{"key": [0, 0, 0], "box": [0, 1, 0, 1, 0, 1],
          "off": -100, "len": 50}])), "negative unit offset")
    expect(CE, lambda: encode.tiled_header(forge_footer(
        [{"key": [0, 0, 0], "box": [0, 1, 0, 1, 0, 1],
          "off": 5, "len": 10**9}])), "unit length beyond container")

    # tiled container: truncated footer / corrupt length word / short unit
    expect(CE, lambda: encode.tiled_header(tiled[:-3]), "truncated footer")
    expect(CE, lambda: encode.tiled_header(tiled[: m + 7]),
           "tiny truncated container")
    expect(CE, lambda: encode.tiled_header(corrupt_footer_length(tiled)),
           "corrupt footer length word")
    entry = hdr["units"][-1]
    cut = tiled[: entry["off"] + entry["len"] // 2]
    expect(CE, lambda: encode.read_tiled_unit(cut, entry),
           "short read mid-unit")
    # unit frame bytes flipped: the inner unpack must raise, not decode
    pos = entry["off"] + entry["len"] // 2
    flipped = (tiled[:pos] + bytes([tiled[pos] ^ 0xFF])
               + tiled[pos + 1:])
    expect(CE, lambda: encode.read_tiled_unit(flipped, entry),
           "bit-flipped unit frame")

    # decode paths surface the same typed errors end to end
    from repro import analysis
    from repro.core import decompress_region, tiling

    expect(CE, lambda: tiling.decompress_tiled(tiled[:-3]),
           "decompress of truncated container")
    expect(CE, lambda: analysis.decode_for_track(corrupt_footer_length(tiled),
                                                 0),
           "track decode on corrupt footer")
    expect(ValueError, lambda: decompress_region(tiled, (0, 99, 0, 4, 0, 4)),
           "out-of-bounds region")
    expect(ValueError,
           lambda: compress(np.zeros((4, 4)), np.zeros((4, 4))),
           "bad field shape")
    expect(ValueError, lambda: TileGrid(halo=0).validate(), "halo=0 grid")
    return True


def check(cond, what: str):
    """Assert-free truth check (works under python -O)."""
    if not cond:
        raise SystemExit(f"recovery matrix: {what}")


def build_adaptive_blob():
    """(tiled adaptive blob, header, (u, v), policy) on a tiny field."""
    from repro.core import ebpolicy
    from repro.data import synthetic

    u, v = synthetic.double_gyre(T=5, H=12, W=16)
    pol = ebpolicy.TilePolicy.make(
        2, 6, 8, default=2e-2, values={(0, 0, 0): 1e-3, (1, 1, 1): 4e-3})
    cfg = CompressionConfig(eb=2e-2, mode="abs", predictor="mop",
                            fused=True, track_index=True,
                            dt=0.1, dx=2.0 / 15, dy=1.0 / 11,
                            eb_policy=pol,
                            n_levels=ebpolicy.levels_for(pol))
    blob, _ = compress_tiled(u, v, cfg, TileGrid(tile_h=6, tile_w=8,
                                                 window_t=3))
    return blob, encode.tiled_header(blob), (u, v), pol


def run_adaptive_matrix(blob: bytes, hdr: dict, field, pol):
    """Adaptive (v6) container validation, assert-free (python -O):
    self-description, round-trip, typed refusals on truncation / forged
    future versions / degenerate relative ranges, and salvage."""
    import struct as _struct
    import zlib as _zlib

    import msgpack

    from repro.core import compressor, ebpolicy, tiling

    CE = encode.ContainerError
    u, v = field
    m = len(encode.MAGIC_TILED)

    # self-describing: version bump + policy spec round-trip
    check(hdr["version"] == tiling.TILED_FORMAT_VERSION_ADAPTIVE,
          f"adaptive tiled container version: {hdr['version']}")
    check(ebpolicy.policy_from_spec(hdr["eb_policy"]) == pol,
          "adaptive header policy spec round-trips")

    # round-trip holds the LOOSEST bound (adaptivity only clamps down)
    ur, vr = tiling.decompress_tiled(blob)
    loose = ebpolicy.max_bound(pol)
    check(float(np.abs(ur.astype(np.float64) - u).max()) <= loose
          and float(np.abs(vr.astype(np.float64) - v).max()) <= loose,
          "adaptive round-trip violates the loosest bound")

    # truncation surfaces the same typed errors as uniform containers
    expect(CE, lambda: encode.tiled_header(blob[:-3]),
           "adaptive truncated footer")
    expect(CE, lambda: tiling.decompress_tiled(blob[:-3]),
           "adaptive decompress of truncated container")

    # a FUTURE version (v7) must be refused, not half-decoded: forge
    # the footer with version+1 and identical everything else
    header, footer_raw = encode.tiled_footer_ranged(
        lambda off, ln: blob[off: off + ln], len(blob))
    forged_hdr = dict(header)
    forged_hdr["version"] = tiling.TILED_FORMAT_VERSION_ADAPTIVE + 1
    raw = _zlib.compress(msgpack.packb(forged_hdr, use_bin_type=True), 6)
    forged = (blob[: len(blob) - len(footer_raw) - 4 - m] + raw
              + _struct.pack("<I", len(raw)) + encode.MAGIC_TILED)
    expect(ValueError, lambda: tiling.decompress_tiled(forged),
           "forged future-version adaptive container")

    # monolithic adaptive (v3) future-version refusal too
    from repro.core import compress as _compress

    cfg_m = CompressionConfig(eb=2e-2, mode="abs", fused=True,
                              eb_policy=pol,
                              n_levels=ebpolicy.levels_for(pol))
    mono, _ = _compress(u, v, cfg_m)
    mh, _ = encode.unpack(mono)
    check(mh["version"] == compressor.FORMAT_VERSION_ADAPTIVE,
          f"adaptive monolithic version: {mh['version']}")
    mh2 = dict(mh)
    mh2["version"] = compressor.FORMAT_VERSION_ADAPTIVE + 1
    packed = msgpack.packb(mh2, use_bin_type=True)
    payload = encode.codec_decompress(
        mono[5:], "zstd" if mono[:5] == encode.MAGIC else "zlib")
    (hlen,) = _struct.unpack("<I", payload[:4])
    forged_m = mono[:5] + encode.codec_compress(
        _struct.pack("<I", len(packed)) + packed + payload[4 + hlen:])
    expect(ValueError, lambda: compressor.decompress(forged_m),
           "forged future-version monolithic container")

    # degenerate relative range: typed raise survives -O (the check is
    # a real ValueError subclass, never an assert)
    flat = np.full((3, 8, 8), 1.5, np.float32)
    expect(ebpolicy.DegenerateRangeError,
           lambda: _compress(flat, flat,
                             CompressionConfig(eb=1e-2, mode="rel")),
           "degenerate relative range (monolithic)")
    expect(ValueError,     # and it IS a ValueError for generic handlers
           lambda: compress_tiled(flat, flat,
                                  CompressionConfig(eb=1e-2, mode="rel"),
                                  TileGrid(tile_h=8, tile_w=8,
                                           window_t=2)),
           "degenerate relative range (tiled)")

    # salvage keeps adaptive unit frames readable (per-unit eb_base is
    # inside the frames, so a rebuilt footer loses nothing needed)
    units = sorted(hdr["units"], key=lambda e: e["off"])
    e = units[-1]
    sblob, rep = encode.salvage_container(blob[: e["off"] + e["len"] // 2])
    check(rep["units_recovered"] == len(units) - 1,
          "adaptive salvage drops exactly the torn unit")
    tiling.decompress_tiled(sblob)
    return True


def _stream_inputs():
    from repro.data import synthetic

    u, v = synthetic.double_gyre(T=12, H=12, W=16)
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                            fused=True, track_index=True,
                            dt=0.1, dx=2.0 / 15, dy=1.0 / 11)
    grid = TileGrid(tile_h=6, tile_w=8, window_t=3)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    pairs = list(zip(u, v))
    return pairs, cfg, grid, vr


def run_recovery_matrix(tiled: bytes, hdr: dict, tmpdir: str):
    """Salvage / degraded-read / kill-and-resume matrix (assert-free).

    * truncation at EVERY unit-frame boundary -> salvage recovers
      exactly the units whose frames are intact, never a partial one;
    * a single-bit flip in EVERY unit payload -> strict reads raise
      ChecksumError, degraded reads report exactly that unit and
      decode the rest;
    * a bit flip inside the footer -> structural ContainerError, and
      salvage still rebuilds a readable container from the frames;
    * an injected crash at each pipeline stage (serial compute; async
      ingest / compute / write) -> ``resume=True`` finishes the run
      and the container is byte-identical to an uninterrupted one.
    """
    import os

    from repro.core import compress_stream, tiling
    from repro.core import faults as faults_mod

    CE = encode.ContainerError
    units = sorted(hdr["units"], key=lambda e: e["off"])
    check(all("crc" in e for e in units), "v4 entries carry a crc")

    # -- truncation at every unit-frame boundary -------------------------
    for i in range(len(units) + 1):
        cut_at = (units[i]["off"] - encode.PREAMBLE_LEN if i < len(units)
                  else units[-1]["off"] + units[-1]["len"])
        blob, rep = encode.salvage_container(tiled[:cut_at])
        check(rep["units_recovered"] == i,
              f"boundary cut before unit {i}: recovered "
              f"{rep['units_recovered']}, wanted {i}")
        if i:
            h2 = encode.tiled_header(blob)
            check(len(h2["units"]) == i and h2.get("salvaged"),
                  f"salvaged footer at boundary {i}")
            tiling.decompress_tiled(blob)   # must be fully readable
    # mid-frame cut: the torn unit is dropped, intact ones survive
    e = units[-1]
    blob, rep = encode.salvage_container(
        tiled[: e["off"] + e["len"] // 2])
    check(rep["units_recovered"] == len(units) - 1,
          "mid-frame cut drops exactly the torn unit")

    # -- single-bit flips in every unit payload --------------------------
    for i, e in enumerate(units):
        pos = e["off"] + (e["len"] // 2 + i) % e["len"]
        bad = bytearray(tiled)
        bad[pos] ^= 1 << (i % 8)
        bad = bytes(bad)
        expect(encode.ChecksumError,
               lambda b=bad, e=e: encode.read_tiled_unit(b, e),
               f"bit flip in unit {i} payload")
        out = tiling.decompress_tiled(bad, degraded=True)
        rep = out[2]
        check(len(rep.missing_units) == 1
              and rep.missing_units[0]["key"] == tuple(e["key"])
              and rep.n_decoded == len(units) - 1,
              f"degraded decode pinpoints flipped unit {i}")

    # -- footer bit flip: structural error; salvage still works ----------
    m = len(encode.MAGIC_TILED)
    foot = bytearray(tiled)
    foot[len(tiled) - m - 4 - 8] ^= 0x10     # inside the zlib footer
    foot = bytes(foot)
    expect(CE, lambda: encode.tiled_header(foot), "bit-flipped footer")
    blob, rep = encode.salvage_container(foot)
    check(rep["units_recovered"] == len(units),
          "salvage of a bad-footer container keeps every unit")
    tiling.decompress_tiled(blob)

    # -- kill-and-resume at each pipeline stage --------------------------
    pairs, cfg, grid, vr = _stream_inputs()

    def feed(t0):
        return iter(pairs[t0:])

    ref_path = os.path.join(tmpdir, "ref.cptt")
    compress_stream(feed, cfg, grid, value_range=vr, sink=ref_path)
    with open(ref_path, "rb") as f:
        ref = f.read()
    stages = [("stream.compute", False), ("stream.ingest", True),
              ("stream.compute", True), ("stream.write", True)]
    for k, (site, use_async) in enumerate(stages):
        p = os.path.join(tmpdir, f"crash_{k}.cptt")
        plan = faults_mod.FaultPlan().io_error(site, nth=7)
        try:
            compress_stream(feed, cfg, grid, value_range=vr, sink=p,
                            async_engine=use_async, faults=plan)
            raise SystemExit(f"stage {site} async={use_async}: "
                             f"injected fault did not surface")
        except faults_mod.InjectedFault:
            pass
        check(os.path.exists(p + ".journal"),
              f"stage {site}: journal survives the crash")
        compress_stream(feed, cfg, grid, value_range=vr, sink=p,
                        resume=True, async_engine=use_async)
        with open(p, "rb") as f:
            got = f.read()
        check(got == ref,
              f"stage {site} async={use_async}: resumed container is "
              f"not byte-identical")
        check(not os.path.exists(p + ".journal"),
              f"stage {site}: journal removed after completion")
    return True
