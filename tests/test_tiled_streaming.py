"""Streaming compression, tile directory and random-access region decode."""
import io

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_stream,
    compress_tiled,
    decompress,
    decompress_region,
    decompress_tiled,
    encode,
    tiling,
)
from repro.data import synthetic


GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)


@pytest.fixture(scope="module")
def field():
    return synthetic.double_gyre(T=7, H=16, W=24)


@pytest.fixture(scope="module")
def cfg():
    return CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                             dt=0.1, dx=2.0 / 23, dy=1.0 / 15, fused=True)


@pytest.fixture(scope="module")
def tiled_blob(field, cfg):
    u, v = field
    blob, stats = compress_tiled(u, v, cfg, GRID)
    return blob, stats


def test_stream_equals_tiled_bytes(field, cfg, tiled_blob):
    """Windowed streaming emission produces the exact same container."""
    u, v = field
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    blob_s, stats = compress_stream(
        ((u[t], v[t]) for t in range(u.shape[0])), cfg, GRID,
        value_range=vr)
    assert blob_s == tiled_blob[0]
    assert stats["n_units"] == tiled_blob[1]["n_units"]


def test_stream_without_range_materializes(field, cfg, tiled_blob):
    u, v = field
    blob_s, _ = compress_stream(
        ((u[t], v[t]) for t in range(u.shape[0])), cfg, GRID)
    assert blob_s == tiled_blob[0]


def test_stream_writes_to_sink(field, cfg, tiled_blob):
    u, v = field
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    sink = io.BytesIO()
    blob, _ = compress_stream(
        ((u[t], v[t]) for t in range(u.shape[0])), cfg, GRID,
        value_range=vr, sink=sink)
    assert blob is None
    assert sink.getvalue() == tiled_blob[0]


def test_decompress_autodetects_tiled(field, tiled_blob):
    u, v = field
    ur, vr = decompress(tiled_blob[0])  # routed by the CPTT magic
    ur2, vr2 = decompress_tiled(tiled_blob[0])
    assert np.array_equal(ur, ur2) and np.array_equal(vr, vr2)
    assert np.abs(ur.astype(np.float64) - u).max() <= tiled_blob[1]["eb_abs"]


def test_config_tiling_routes_to_tiled(field, cfg, tiled_blob):
    import dataclasses

    u, v = field
    cfg_t = dataclasses.replace(cfg, tiling=GRID)
    blob, stats = compress(u, v, cfg_t)
    assert stats["pipeline"] == "tiled"
    assert blob == tiled_blob[0]


def test_region_decode_reads_only_covering_tiles(field, tiled_blob):
    """Acceptance: random access touches exactly the covering units,
    asserted through the tile-directory offsets."""
    u, v = field
    blob, _ = tiled_blob
    hdr = encode.tiled_header(blob)
    # a region strictly inside the owned box of unit (wi=1, ti=0, tj=1)
    region = (4, 6, 2, 7, 13, 22)
    plan = tiling.read_plan(blob, region)
    assert len(plan) == 1
    assert plan[0]["key"] == [1, 0, 1]
    # the directory offsets partition the payload; the planned unit's
    # byte range is a strict subset of the blob
    assert 0 < plan[0]["off"] < plan[0]["off"] + plan[0]["len"] < len(blob)
    total = sum(e["len"] for e in hdr["units"])
    assert plan[0]["len"] < total
    # region decode == full decode restricted, computed from 1 unit
    ur_full, vr_full = decompress_tiled(blob)
    ur, vrg = decompress_region(blob, region)
    t0, t1, i0, i1, j0, j1 = region
    assert np.array_equal(ur, ur_full[t0:t1, i0:i1, j0:j1])
    assert np.array_equal(vrg, vr_full[t0:t1, i0:i1, j0:j1])


def test_region_decode_multi_tile(field, tiled_blob):
    blob, _ = tiled_blob
    region = (0, 3, 6, 10, 10, 14)  # crosses one spatial seam each way
    plan = tiling.read_plan(blob, region)
    assert 1 < len(plan) < len(encode.tiled_header(blob)["units"])
    ur_full, vr_full = decompress_tiled(blob)
    ur, vr = decompress_region(blob, region)
    t0, t1, i0, i1, j0, j1 = region
    assert np.array_equal(ur, ur_full[t0:t1, i0:i1, j0:j1])
    assert np.array_equal(vr, vr_full[t0:t1, i0:i1, j0:j1])


def test_region_rejects_out_of_bounds(tiled_blob):
    # a typed error (not an assert): must hold under python -O
    with pytest.raises(ValueError, match="outside field"):
        decompress_region(tiled_blob[0], (0, 99, 0, 4, 0, 4))


def test_tiled_pointwise_bound_and_determinism(field, cfg, tiled_blob):
    u, v = field
    blob, stats = tiled_blob
    ur, vr = decompress_tiled(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]
    blob2, _ = compress_tiled(u, v, cfg, GRID)
    assert blob2 == blob


def test_organic_forcing_bitwise_identical():
    """Large-magnitude field: f32 output rounding competes with the
    bound, so the verify loop FIRES organically (rounds >= 1) -- the
    seam-agreed per-tile fixpoint must still land on the monolithic
    output bit-for-bit, and streaming on the same bytes."""
    rng = np.random.default_rng(3)
    T = 4
    base = 1.0e8
    u = (base + rng.normal(0, 100.0, (T, 16, 16))).astype(np.float32)
    v = (base + rng.normal(0, 100.0, (T, 16, 16))).astype(np.float32)
    cfg_f = CompressionConfig(eb=6.0, mode="abs", predictor="mop",
                              backend="xla", fused=True)
    blob_m, sm = compress(u, v, cfg_f)
    assert sm["verify_rounds"] >= 1 and sm["verify_bad_counts"][0] > 0
    um, vm = decompress(blob_m)
    grid = TileGrid(tile_h=7, tile_w=9, window_t=2)
    blob_t, st = compress_tiled(u, v, cfg_f, grid)
    assert st["verify_rounds"] >= 1
    ut, vt = decompress_tiled(blob_t)
    assert np.array_equal(um, ut) and np.array_equal(vm, vt)
    vrange = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    blob_s, _ = compress_stream(((u[t], v[t]) for t in range(T)), cfg_f,
                                grid, value_range=vrange)
    assert blob_s == blob_t


def test_single_frame_window_units(field, cfg):
    """window_t that leaves a 1-frame tail window still roundtrips."""
    u, v = field  # T=7 -> windows of 3, 3, 1
    grid = TileGrid(tile_h=16, tile_w=24, window_t=3)
    blob, stats = compress_tiled(u, v, cfg, grid)
    um, _ = decompress(compress(u, v, cfg)[0])
    ut, _ = decompress_tiled(blob)
    assert np.array_equal(um, ut)
