"""Container codec fallback + vectorized Huffman decode."""
import numpy as np
import pytest

from repro.core import encode


def test_backend_codec_reported():
    assert encode.backend_codec() in ("zstd", "zlib")


def test_container_roundtrip_current_codec():
    header = {"x": 42}
    secs = {"a": np.arange(100, dtype=np.int64)}
    blob = encode.pack(header, secs)
    magic = blob[:5]
    assert magic in (encode.MAGIC, encode.MAGIC_ZLIB)
    h, s = encode.unpack(blob)
    assert h["x"] == 42 and h["codec"] == encode.backend_codec()
    assert (s["a"] == secs["a"]).all()


def test_zlib_frame_always_decodable():
    """A zlib container decodes regardless of zstandard availability."""
    import io
    import struct
    import zlib

    import msgpack

    secs = {"a": np.arange(7, dtype=np.int32)}
    body = io.BytesIO()
    idx = {}
    for name, arr in secs.items():
        raw = arr.tobytes()
        idx[name] = {"off": body.tell(), "len": len(raw),
                     "dtype": str(arr.dtype), "shape": list(arr.shape)}
        body.write(raw)
    hdr = msgpack.packb({"sections": idx, "codec": "zlib"}, use_bin_type=True)
    payload = struct.pack("<I", len(hdr)) + hdr + body.getvalue()
    blob = encode.MAGIC_ZLIB + zlib.compress(payload, 6)
    h, s = encode.unpack(blob)
    assert (s["a"] == secs["a"]).all()


@pytest.mark.parametrize("n", [1, 2, 1000, 50_000])
@pytest.mark.parametrize("dist", ["geometric", "uniform", "const", "binary"])
def test_huffman_vectorized_decode(n, dist):
    rng = np.random.default_rng(n)
    if dist == "geometric":
        sym = np.minimum(rng.geometric(0.25, n) - 1, 255).astype(np.uint8)
    elif dist == "uniform":
        sym = rng.integers(0, 256, n).astype(np.uint8)
    elif dist == "binary":
        sym = (rng.random(n) < 0.03).astype(np.uint8)
    else:
        sym = np.zeros(n, dtype=np.uint8)
    lengths, data, count = encode.huffman_encode(sym)
    got = encode.huffman_decode(lengths, data, count)
    assert (got == sym).all()


def test_huffman_vectorized_matches_scalar():
    rng = np.random.default_rng(9)
    sym = np.minimum(rng.geometric(0.4, 4000) - 1, 255).astype(np.uint8)
    lengths, data, n = encode.huffman_encode(sym)
    codes, _ = encode.canonical_codes(lengths)
    maxlen = int(lengths.max())
    peek, plen = encode._peek_tables(lengths, codes, maxlen)
    want = encode._huffman_decode_scalar(peek, plen, maxlen, data, n)
    got = encode.huffman_decode(lengths, data, n)
    assert (got == want).all() and (got == sym).all()


def test_huffman_chunked_paths():
    """Small _chunk forces the multi-block stage-1 path."""
    rng = np.random.default_rng(13)
    sym = rng.integers(0, 17, 5000).astype(np.uint8)
    lengths, data, n = encode.huffman_encode(sym)
    got = encode.huffman_decode(lengths, data, n, _chunk=257)
    assert (got == sym).all()
