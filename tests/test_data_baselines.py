"""Synthetic datasets + baseline compressors behave as specified."""
import numpy as np
import pytest

from repro.baselines import REGISTRY
from repro.core import fixedpoint, trajectory
from repro.data import synthetic
from repro.data.tokens import TokenPipelineConfig, global_batch, host_batch


@pytest.mark.parametrize("name", list(synthetic.DATASETS))
def test_datasets_shape_and_finite(name):
    u, v = synthetic.load(name, T=6, H=16, W=20)
    assert u.shape == (6, 16, 20) and v.shape == (6, 16, 20)
    assert u.dtype == np.float32
    assert np.isfinite(u).all() and np.isfinite(v).all()
    assert u.std() > 0


def test_advected_turbulence_is_sl_predictable():
    """Taylor-frozen field: frame t equals frame t-1 shifted by u0 px."""
    u, v = synthetic.advected_turbulence(T=4, H=24, W=24, u0=3.0)
    # interior columns shifted exactly by 3 (integer carrier speed)
    np.testing.assert_allclose(
        v[1][:, 3:], v[0][:, :-3], rtol=1e-4, atol=1e-5)


def test_advected_turbulence_has_moving_cps():
    u, v = synthetic.advected_turbulence(T=6, H=48, W=48)
    scale, ufp, vfp = fixedpoint.to_fixed(u, v)
    tr = trajectory.extract_tracks(ufp, vfp)
    assert tr["n_tracks"] > 0


def test_token_pipeline_deterministic_and_sharded():
    cfg = TokenPipelineConfig(vocab=1000, batch=8, seq_len=32, seed=3)
    t1, l1 = global_batch(cfg, 5)
    t2, l2 = global_batch(cfg, 5)
    assert (t1 == t2).all()  # pure function of (seed, step)
    t3, _ = global_batch(cfg, 6)
    assert not (t1 == t3).all()
    assert (l1[:, :-1] == t1[:, 1:]).all()  # next-token labels
    h0, _ = host_batch(cfg, 5, 0, 2)
    h1, _ = host_batch(cfg, 5, 1, 2)
    assert (np.concatenate([h0, h1]) == t1).all()


@pytest.mark.parametrize("name", ["gzip", "zstd", "fpzip-like"])
def test_lossless_baselines_roundtrip(name):
    u, v = synthetic.double_gyre(T=4, H=12, W=16)
    res = REGISTRY[name](u, v)
    assert res["lossless"]
    assert (res["u_rec"] == u).all() and (res["v_rec"] == v).all()
    assert res["ratio"] >= 1.0


@pytest.mark.parametrize("name", ["zfp-like", "sz3-like", "cpsz-like"])
def test_lossy_baselines_respect_eb(name):
    u, v = synthetic.double_gyre(T=4, H=12, W=16)
    res = REGISTRY[name](u, v, eb=1e-2, mode="rel")
    err = max(np.abs(res["u_rec"] - u).max(), np.abs(res["v_rec"] - v).max())
    # zfp-like's transform bound is approximate (coefficient-domain);
    # the SZ-family bounds are strict
    slack = 4.0 if name == "zfp-like" else 1.0 + 1e-6
    assert err <= res["eb_abs"] * slack, (name, err, res["eb_abs"])
    assert res["ratio"] > 1.5


def test_cpsz_like_preserves_slices_only():
    """cpsz-like must have FC_t == 0 (its guarantee) on CP-rich data."""
    u, v = synthetic.vortex_street(T=6, H=24, W=32)
    res = REGISTRY["cpsz-like"](u, v, eb=2e-2, mode="rel")
    fc = trajectory.false_cases(u, v, res["u_rec"], res["v_rec"],
                                fixedpoint.to_fixed(u, v)[0])
    assert fc["FC_t"] == 0
