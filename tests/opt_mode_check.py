"""Container-error validation under ``python -O`` (CI leg).

Run as:  PYTHONPATH=src python -O tests/opt_mode_check.py

Under ``-O`` every ``assert`` in the codebase is stripped, so any
integrity check still written as an assert silently vanishes -- which
is exactly how truncated/corrupt containers used to decode to garbage.
This script replays the full corrupt-container matrix with real raises
only (see container_corruptions.py) and exits non-zero on any miss, so
assert-stripped validation can never regress unnoticed.

It intentionally does NOT use pytest: pytest's assertion rewriting is
disabled under -O, which would turn the test bodies themselves into
no-ops.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import container_corruptions as cc  # noqa: E402


def main() -> int:
    if sys.flags.optimize < 1:
        print("opt_mode_check: warning: not running under python -O; "
              "the assert-stripping scenario is not being exercised",
              file=sys.stderr)
    import tempfile

    mono, tiled, hdr = cc.build_blobs()
    cc.run_matrix(mono, tiled, hdr)
    with tempfile.TemporaryDirectory() as td:
        cc.run_recovery_matrix(tiled, hdr, td)

    # adaptive (v6/v3) containers: self-description, typed refusals,
    # degenerate-range raise -- all must survive assert stripping
    ablob, ahdr, afield, apol = cc.build_adaptive_blob()
    cc.run_adaptive_matrix(ablob, ahdr, afield, apol)

    # checkpoint restore validation must be a real raise, not an assert
    from repro.train import checkpoint

    with tempfile.TemporaryDirectory() as td:
        cc.expect(checkpoint.CheckpointError,
                  lambda: checkpoint.restore(td, {}),
                  "restore from an empty checkpoint dir")
    print(f"opt_mode_check: typed container errors + recovery matrix "
          f"hold (optimize={sys.flags.optimize})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
