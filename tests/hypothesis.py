"""Shim: prefer the real `hypothesis` package, else a tiny deterministic
fallback so the tier-1 suite runs on minimal environments (the container
image has no hypothesis wheel).  Because pytest prepends tests/ to
sys.path, this module shadows the real package; it therefore re-exports
the real one when it can be found elsewhere on the path.
"""
from __future__ import annotations

import importlib.machinery
import importlib.util
import itertools
import os
import random
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_real = None
_search = [p for p in sys.path
           if os.path.abspath(p or os.getcwd()) != _HERE]
_spec = importlib.machinery.PathFinder.find_spec("hypothesis", _search)
if _spec is not None and _spec.origin and _HERE not in _spec.origin:
    _self = sys.modules.pop("hypothesis", None)
    try:
        _real = importlib.util.module_from_spec(_spec)
        sys.modules["hypothesis"] = _real
        _spec.loader.exec_module(_real)
    except Exception:  # pragma: no cover - fall back to the stub
        _real = None
        if _self is not None:
            sys.modules["hypothesis"] = _self

if _real is not None:
    given = _real.given
    settings = _real.settings
    strategies = _real.strategies
else:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value=-(2**63), max_value=2**63 - 1):
            def draw(rng):
                # bias towards the boundary values degenerate cases live at
                r = rng.random()
                if r < 0.1:
                    return min_value
                if r < 0.2:
                    return max_value
                if r < 0.35 and min_value <= 0 <= max_value:
                    return rng.randint(-1, 1) if min_value < 0 else 0
                return rng.randint(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elem.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            def draw(rng):
                return tuple(e.example(rng) for e in elems)
            return _Strategy(draw)

        @staticmethod
        def permutations(values):
            def draw(rng):
                vals = list(values)
                rng.shuffle(vals)
                return vals
            return _Strategy(draw)

    strategies = _St()

    def settings(max_examples=50, deadline=None, **kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            n = getattr(fn, "_stub_max_examples", 50)

            def wrapper(*args, **kwargs):
                rng = random.Random(1234)
                for _ in range(min(n, 60)):
                    vals = [s.example(rng) for s in strats]
                    fn(*args, *vals, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
