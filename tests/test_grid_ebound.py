"""Space-time mesh structure + error-bound derivation properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ebound, grid, sos


def test_face_counts():
    H, W, T = 5, 7, 4
    c = grid.face_counts(H, W, T)
    assert c["slice_faces"] == 2 * (H - 1) * (W - 1) * T
    assert c["slab_faces"] == (
        2 * (H * (W - 1) + (H - 1) * W + (H - 1) * (W - 1))
        + 4 * (H - 1) * (W - 1)
    ) * (T - 1)
    assert c["tets"] == 6 * (H - 1) * (W - 1) * (T - 1)


def test_faces_sorted_and_unique():
    H, W = 6, 5
    f = grid.slab_faces(H, W)
    allf = np.concatenate(list(f.values()), axis=0)
    assert (allf[:, 0] < allf[:, 1]).all() and (allf[:, 1] < allf[:, 2]).all()
    keys = set(map(tuple, allf.tolist()))
    assert len(keys) == len(allf)  # enumeration has no duplicates


def test_tet_faces_conform():
    """Every internal tet face appears in exactly 2 tets; boundary in 1.
    Side faces shared between adjacent prisms must match (conformity)."""
    H, W = 4, 4
    tets = grid.slab_tets(H, W)
    from collections import Counter

    cnt = Counter()
    for tet in tets:
        for fidx in grid.TET_FACES:
            cnt[tuple(tet[fidx])] += 1
    assert set(cnt.values()) <= {1, 2}
    # all enumerated slab faces + slices must be exactly the tet faces
    f = grid.slab_faces(H, W)
    enumerated = set(
        map(tuple, np.concatenate(list(f.values()), axis=0).tolist())
    )
    assert enumerated == set(cnt.keys())


def test_vertex_incident_face_budget():
    """Paper: each vertex touches <= 36 faces in its 3x3x3 neighborhood
    (6 in-plane per slice x interactions with two slabs)."""
    H, W = 8, 8
    f = grid.slab_faces(H, W)
    allf = np.concatenate(list(f.values()), axis=0)
    counts = np.bincount(allf.reshape(-1), minlength=2 * H * W)
    # per-slab incidence; a vertex sees two slabs -> twice the plane-0
    # count plus plane-1 count of the previous slab; bounded by 36.
    per_vertex_two_slab = counts[: H * W] + counts[H * W :]
    assert per_vertex_two_slab.max() <= 36 + 6  # +6: slice faces double-listed
    # (slice0 of slab t duplicates slice1 of slab t-1 in this accounting)


def _random_field(T, H, W, seed):
    rng = np.random.default_rng(seed)
    u = rng.integers(-(2**16), 2**16, (T, H, W)).astype(np.int64)
    v = rng.integers(-(2**16), 2**16, (T, H, W)).astype(np.int64)
    return u, v


@pytest.mark.parametrize("seed", [0, 1])
def test_eb_preserves_predicates_single_vertex(seed):
    """Property behind Alg. 2: perturbing ONE vertex by <= its derived
    bound never flips any face predicate."""
    T, H, W = 3, 5, 5
    u, v = _random_field(T, H, W, seed)
    tau = 2**20
    eb, _, _ = ebound.derive_vertex_eb(u, v, tau)
    eb = np.asarray(eb)
    p0_slice, p0_slab = map(np.asarray, ebound.all_face_predicates(u, v))

    rng = np.random.default_rng(seed + 100)
    for trial in range(20):
        t, i, j = rng.integers(0, T), rng.integers(0, H), rng.integers(0, W)
        b = int(eb[t, i, j])
        if b == 0:
            continue
        du = rng.integers(-b, b + 1)
        dv = rng.integers(-b, b + 1)
        u2 = u.copy(); v2 = v.copy()
        u2[t, i, j] += du
        v2[t, i, j] += dv
        p1_slice, p1_slab = map(np.asarray, ebound.all_face_predicates(u2, v2))
        assert (p0_slice == p1_slice).all(), (t, i, j, b, du, dv)
        assert (p0_slab == p1_slab).all(), (t, i, j, b, du, dv)


def test_crossed_faces_force_lossless():
    """Vertices of crossed faces get eb = 0 (stored losslessly)."""
    T, H, W = 2, 3, 3
    u = np.full((T, H, W), 7, dtype=np.int64)
    v = np.full((T, H, W), 7, dtype=np.int64)
    # plant a critical point inside the slice triangle {(0,0),(1,0),(1,1)}
    u[0, 0, 0], v[0, 0, 0] = 10, 1
    u[0, 1, 0], v[0, 1, 0] = -10, 8
    u[0, 1, 1], v[0, 1, 1] = 2, -9
    eb, slice_crossed, _ = ebound.derive_vertex_eb(u, v, 2**20)
    eb = np.asarray(eb)
    assert np.asarray(slice_crossed).any()
    assert eb[0, 0, 0] == 0 and eb[0, 1, 0] == 0 and eb[0, 1, 1] == 0


def test_eb_capped_by_tau():
    T, H, W = 2, 4, 4
    u = np.full((T, H, W), 1000, dtype=np.int64)
    v = np.full((T, H, W), 1000, dtype=np.int64)
    tau = 37
    eb, _, _ = ebound.derive_vertex_eb(u, v, tau)
    assert int(np.asarray(eb).max()) <= tau


def test_rotation_ebs_match_per_rotation_reference():
    """The det-sharing refactor of face_rotation_ebs must be bit-equal
    to the original per-rotation Alg. 2 evaluation (_alg2_eb)."""
    rng = np.random.default_rng(0)
    n = 4096
    fu = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    fv = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    # degeneracies: zeros, shared signs, duplicate vertices
    fu[::7] = np.abs(fu[::7])
    fv[::11] = 0
    fu[5, 1] = fu[5, 0]
    fv[5, 1] = fv[5, 0]
    crossed = rng.random(n) < 0.2
    got = np.asarray(ebound.face_rotation_ebs(np, fu, fv, crossed))
    a_u, b_u, c_u = fu[:, 0], fu[:, 1], fu[:, 2]
    a_v, b_v, c_v = fv[:, 0], fv[:, 1], fv[:, 2]
    eb_c = ebound._alg2_eb(np, a_u, b_u, c_u, a_v, b_v, c_v)
    eb_a = ebound._alg2_eb(np, b_u, c_u, a_u, b_v, c_v, a_v)
    eb_b = ebound._alg2_eb(np, c_u, a_u, b_u, c_v, a_v, b_v)
    want = np.stack([eb_a, eb_b, eb_c], axis=-1)
    want = np.where(crossed[:, None], 0, want)
    assert (got == want).all()


def test_incidence_table_covers_all_faces():
    H, W = 6, 7
    for kind, tab, n_verts in (
        ("slice", grid.slab_faces(H, W)["slice0"], H * W),
        ("slab", ebound.slab_face_table(H, W), 2 * H * W),
    ):
        inc = ebound._incidence_table(H, W, kind)
        F = len(tab)
        got = sorted(int(i) for row in inc for i in row if i < F * 3)
        assert got == list(range(F * 3))  # every (face, slot) exactly once
        # every listed entry belongs to the right vertex
        for vtx in range(n_verts):
            for i in inc[vtx]:
                if i < F * 3:
                    assert tab[i // 3, i % 3] == vtx
