"""Per-kernel validation: interpret-mode pallas vs pure-jnp oracle.

Integer kernels assert exact equality; the f32 SL kernel asserts
allclose at f32 tolerances.  Shapes sweep non-aligned sizes to exercise
the padding paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core  # noqa: F401 (x64 on; kernels must be dtype-explicit)
from repro.core import predictors, quantize
from repro.kernels.cptest import ops as cp_ops
from repro.kernels.cptest import ref as cp_ref
from repro.kernels.lorenzo import ops as lz_ops
from repro.kernels.semilagrange import ops as sl_ops
from repro.kernels.semilagrange import ref as sl_ref


# ------------------------------------------------------------- lorenzo

@pytest.mark.parametrize("shape", [(2, 128, 128), (3, 128, 256), (2, 130, 140)])
@pytest.mark.parametrize("tau", [100, 10_000, 2**24])
def test_lorenzo_kernel_matches_core(shape, tau):
    rng = np.random.default_rng(0)
    T, H, W = shape
    dfp = rng.integers(-(2**29), 2**29, shape).astype(np.int64)
    xi_unit, n_levels = quantize.ladder(tau)
    eb = jnp.asarray(
        rng.integers(0, tau + 1, shape).astype(np.int64))
    k, lossless = quantize.quantize_eb(eb, xi_unit, n_levels)

    # core pipeline result
    x = quantize.dual_quantize(jnp.asarray(dfp), k, lossless, xi_unit)
    want = predictors.lorenzo_encode(x, 16).astype(jnp.int32)

    got = lz_ops.dualquant_lorenzo_residual(
        jnp.asarray(dfp), k, lossless, xi_unit)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_lorenzo_kernel_interpret_path_runs():
    """Aligned shape goes through pallas interpret, not the ref loop."""
    T, H, W = 2, 128, 128
    dfp = jnp.asarray(np.arange(T * H * W).reshape(T, H, W) % 1000,
                      dtype=jnp.int64)
    k = jnp.zeros((T, H, W), jnp.int32)
    ll = jnp.zeros((T, H, W), bool)
    out = lz_ops.dualquant_lorenzo_residual(dfp, k, ll, 8)
    assert out.shape == (T, H, W) and out.dtype == jnp.int32


# ------------------------------------------------------------- cptest

ints30 = st.integers(min_value=-(2**30) + 1, max_value=2**30 - 1)


@given(st.lists(st.tuples(ints30, ints30), min_size=3, max_size=3))
@settings(max_examples=200, deadline=None)
def test_cptest_limb_sign_exact(vals):
    """int32-limb det sign == int64 ground truth (random + boundary)."""
    from repro.kernels.cptest.kernel import _sign_det_exact

    (au, av), (bu, bv), _ = vals
    want = int(np.sign(np.int64(au) * np.int64(bv)
                       - np.int64(av) * np.int64(bu)))
    got = int(_sign_det_exact(jnp.int32(au), jnp.int32(av),
                              jnp.int32(bu), jnp.int32(bv)))
    assert got == want


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 1025])
def test_cptest_kernel_matches_sos(n):
    rng = np.random.default_rng(n)
    u = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    v = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    # plant degeneracies: zeros and duplicated vertices
    u[:: max(n // 7, 1)] = 0
    if n > 3:
        v[3, 1] = v[3, 0]
        u[3, 1] = u[3, 0]
    idx = np.arange(3 * n).reshape(n, 3)
    want = np.asarray(cp_ref.face_crossed(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(idx)))
    got = np.asarray(cp_ops.face_crossed_batch(u, v, idx))
    assert (got == want).all()


def test_cptest_small_values_near_zero():
    """Dense sweep of tiny configurations around the origin."""
    vals = np.array(
        [[a, b, c] for a in (-1, 0, 1) for b in (-1, 0, 1)
         for c in (-1, 0, 1)], dtype=np.int64)
    n = len(vals)
    u = vals
    v = np.roll(vals, 1, axis=0)
    idx = np.arange(3 * n).reshape(n, 3)
    want = np.asarray(cp_ref.face_crossed(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(idx)))
    got = np.asarray(cp_ops.face_crossed_batch(u, v, idx))
    assert (got == want).all()


# ------------------------------------------------------------- semilagrange

@pytest.mark.parametrize("shape", [(16, 128), (32, 64), (8, 200)])
@pytest.mark.parametrize("speed", [0.3, 5.0])
def test_sl_kernel_matches_ref(shape, speed):
    rng = np.random.default_rng(1)
    H, W = shape
    u = (rng.normal(0, speed, (H, W))).astype(np.float32)
    v = (rng.normal(0, speed, (H, W))).astype(np.float32)
    pu_ref, pv_ref = sl_ref.sl_predict(jnp.asarray(u), jnp.asarray(v),
                                       1.0, 1.0)
    pu, pv = sl_ops.sl_predict(u, v, 1.0, 1.0)
    # f32 rounding differs between compilation contexts (fusion changes
    # op roundings) and the iterative backtrace amplifies it by the
    # velocity gradient; the substepping regime (speed > d_max) needs
    # the looser bound.  Exact end-to-end consistency is structural
    # (shared stepper executable, core/backend.py), not numerical.
    tol = 1e-5 if speed <= 2.0 else 1e-3
    np.testing.assert_allclose(np.asarray(pu), np.asarray(pu_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(pv_ref),
                               rtol=tol, atol=tol)


def test_sl_kernel_uniform_translation_exact():
    H, W = 16, 128
    u = np.full((H, W), 2.0, np.float32)   # exactly 2 px in j
    v = np.zeros((H, W), np.float32)
    pu, pv = sl_ops.sl_predict(u, v, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(pu), 2.0, atol=1e-6)


def test_sl_batched_kernel_matches_per_frame():
    """The (B, rows)-grid encoder batch kernel computes the same tiles
    as B per-frame launches (same math, frame-parallel grid)."""
    from repro.kernels.semilagrange import kernel as sl_kernel

    rng = np.random.default_rng(4)
    B, H, W = 3, 16, 64
    u = rng.normal(0, 1.5, (B, H, W)).astype(np.float32)
    v = rng.normal(0, 1.5, (B, H, W)).astype(np.float32)
    pu_b, pv_b = sl_kernel.sl_predict_batched_pallas(
        jnp.asarray(u), jnp.asarray(v), 1.0, 1.0, 2.0, 8)
    for b in range(B):
        pu, pv = sl_kernel.sl_predict_pallas(
            jnp.asarray(u[b]), jnp.asarray(v[b]), 1.0, 1.0, 2.0, 8)
        np.testing.assert_allclose(np.asarray(pu_b[b]), np.asarray(pu),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(pv_b[b]), np.asarray(pv),
                                   rtol=1e-5, atol=1e-5)
