"""Crash-recoverable streaming + self-healing reads (DESIGN.md #12).

The contract under test:

* ``compress_stream(..., sink=path)`` journals its progress; a run
  killed at ANY point restarts with ``resume=True`` and finishes a
  container byte-identical to an uninterrupted run (the tentpole
  guarantee -- resume is invisible in the output bytes);
* ``encode.salvage_container`` rebuilds a directory for a truncated /
  footerless v4 archive, recovering every unit whose frame is intact;
* degraded reads skip checksum-failed units and REPORT the holes
  instead of raising, and every surviving value is bit-identical to an
  undamaged decode (the FC=0 preservation argument extends to partial
  reads).
"""
import io
import os

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress_stream,
    compress_tiled,
    decompress_region,
    decompress_tiled,
)
from repro.core import encode
from repro.core import faults as faults_mod
from repro.core import stream_engine
from repro.analysis import query
from repro.data import synthetic


GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)
CFG = CompressionConfig(track_index=True)


@pytest.fixture(scope="module")
def field():
    u, v = synthetic.double_gyre(T=18, H=16, W=24)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    return u, v, list(zip(u, v)), vr


@pytest.fixture(scope="module")
def container(field):
    u, v, _, _ = field
    blob, _ = compress_tiled(u, v, CFG, GRID)
    return blob


@pytest.fixture(scope="module")
def reference(field, tmp_path_factory):
    _, _, pairs, vr = field
    p = tmp_path_factory.mktemp("ref") / "ref.cptt"
    compress_stream(lambda t0: iter(pairs[t0:]), CFG, GRID,
                    value_range=vr, sink=str(p))
    return p.read_bytes()


# ------------------------------------------------------ journal/resume

def test_stream_to_path_equals_bytesio_and_tiled(field, reference):
    u, v, pairs, vr = field
    sink = io.BytesIO()
    compress_stream(iter(pairs), CFG, GRID, value_range=vr, sink=sink)
    assert reference == sink.getvalue()
    blob, _ = compress_tiled(u, v, CFG, GRID)
    assert reference == blob


def test_completed_run_leaves_no_journal(field, tmp_path):
    _, _, pairs, vr = field
    p = tmp_path / "c.cptt"
    compress_stream(iter(pairs), CFG, GRID, value_range=vr, sink=str(p))
    assert not os.path.exists(str(p) + ".journal")


@pytest.mark.parametrize("use_async", [False, True],
                         ids=["serial", "async"])
@pytest.mark.parametrize("nth", [2, 9, 14, 17])
def test_kill_and_resume_byte_identical(field, reference, tmp_path,
                                        nth, use_async):
    """Crash at frame `nth` (spanning before-first-checkpoint through
    last-window), resume, byte-diff against the uninterrupted run."""
    _, _, pairs, vr = field
    p = tmp_path / "crash.cptt"
    plan = faults_mod.FaultPlan().io_error("stream.compute", nth=nth)

    def feed(t0):
        return iter(pairs[t0:])

    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                        async_engine=use_async, faults=plan)
    info = stream_engine.resume_info(str(p))
    assert info["resumable"] and not info["complete"]
    blob, stats = compress_stream(feed, CFG, GRID, value_range=vr,
                                  sink=str(p), resume=True,
                                  async_engine=use_async)
    assert stats["resumed_from"] == info["resume_from"]
    assert p.read_bytes() == reference
    assert not os.path.exists(str(p) + ".journal")


def test_double_crash_then_resume(field, reference, tmp_path):
    """Resume runs are themselves resumable: crash, resume-and-crash
    again, resume to completion."""
    _, _, pairs, vr = field
    p = tmp_path / "crash2.cptt"

    def feed(t0):
        return iter(pairs[t0:])

    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                        faults=faults_mod.FaultPlan().io_error(
                            "stream.compute", nth=16))
    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                        resume=True,
                        faults=faults_mod.FaultPlan().io_error(
                            "stream.compute", nth=2))
    compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                    resume=True)
    assert p.read_bytes() == reference


def test_resume_of_complete_container_is_noop(field, reference,
                                              tmp_path):
    _, _, pairs, vr = field
    p = tmp_path / "done.cptt"
    p.write_bytes(reference)
    blob, stats = compress_stream(lambda t0: iter(pairs[t0:]), CFG,
                                  GRID, value_range=vr, sink=str(p),
                                  resume=True)
    assert stats.get("already_complete")
    assert p.read_bytes() == reference


def test_resume_refuses_mismatched_config(field, tmp_path):
    """The journal fingerprints (cfg, grid, value_range, H, W); a
    resume under different settings must fail typed, not splice
    incompatible units into one container."""
    _, _, pairs, vr = field
    p = tmp_path / "fp.cptt"
    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(iter(pairs), CFG, GRID, value_range=vr,
                        sink=str(p),
                        faults=faults_mod.FaultPlan().io_error(
                            "stream.compute", nth=14))
    other = CompressionConfig(eb=3e-3, track_index=True)
    with pytest.raises(stream_engine.ResumeError):
        compress_stream(iter(pairs), other, GRID, value_range=vr,
                        sink=str(p), resume=True)


def test_resume_requires_path_sink(field):
    _, _, pairs, vr = field
    with pytest.raises(ValueError):
        compress_stream(iter(pairs), CFG, GRID, value_range=vr,
                        sink=io.BytesIO(), resume=True)


def test_torn_journal_tail_is_tolerated(field, reference, tmp_path):
    """fsync ordering means a crash can tear the LAST journal record;
    the reader must fall back to the previous checkpoint, and resume
    still finishes byte-identical."""
    _, _, pairs, vr = field
    p = tmp_path / "torn.cptt"

    def feed(t0):
        return iter(pairs[t0:])

    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                        faults=faults_mod.FaultPlan().io_error(
                            "stream.compute", nth=17))
    jp = str(p) + ".journal"
    raw = open(jp, "rb").read()
    open(jp, "wb").write(raw[:-7])         # tear mid-record
    compress_stream(feed, CFG, GRID, value_range=vr, sink=str(p),
                    resume=True)
    assert p.read_bytes() == reference


def test_resume_info_shapes(field, reference, tmp_path):
    _, _, pairs, vr = field
    p = tmp_path / "info.cptt"
    p.write_bytes(reference)
    info = stream_engine.resume_info(str(p))
    assert info["complete"] and not info["resumable"]


# ------------------------------------------------------------ salvage

def test_salvage_footerless_recovers_all_units(container):
    hdr = encode.tiled_header(container)
    last = max(hdr["units"], key=lambda e: e["off"])
    cut = container[: last["off"] + last["len"]]   # footer gone entirely
    blob, rep = encode.salvage_container(cut)
    assert rep["units_recovered"] == len(hdr["units"])
    assert rep["prologue_recovered"]
    h2 = encode.tiled_header(blob)
    assert h2.get("salvaged") is True
    ur_s, vr_s = decompress_tiled(blob)
    ur, vr = decompress_tiled(container)
    assert np.array_equal(ur_s, ur)
    assert np.array_equal(vr_s, vr)


def test_salvage_to_file(container, tmp_path):
    last = max(encode.tiled_header(container)["units"],
               key=lambda e: e["off"])
    out = tmp_path / "salvaged.cptt"
    res, rep = encode.salvage_container(
        container[: last["off"] + last["len"] // 3], out=str(out))
    assert res is None and rep["units_recovered"] > 0
    decompress_tiled(out.read_bytes())


def test_salvage_refuses_non_container():
    with pytest.raises(encode.ContainerError):
        encode.salvage_container(b"not a container at all")


# ----------------------------------------------------- degraded reads

def _flip(blob: bytes, entry: dict) -> bytes:
    ba = bytearray(blob)
    ba[entry["off"] + entry["len"] // 2] ^= 0x20
    return bytes(ba)


def test_degraded_region_reports_holes(container):
    hdr = encode.tiled_header(container)
    entry = hdr["units"][2]
    bad = _flip(container, entry)
    with pytest.raises(encode.ChecksumError):
        decompress_tiled(bad)
    u_ref, v_ref = decompress_tiled(container)
    u_d, v_d, rep = decompress_tiled(bad, degraded=True)
    assert not rep.complete
    assert [m["key"] for m in rep.missing_units] == [tuple(entry["key"])]
    t0, t1, i0, i1, j0, j1 = entry["box"]
    hole = np.zeros(u_ref.shape, bool)
    hole[t0:t1, i0:i1, j0:j1] = True
    assert np.array_equal(u_d[~hole], u_ref[~hole])
    assert not u_d[hole].any() and not v_d[hole].any()
    mask = rep.hole_mask((0, u_ref.shape[0], 0, u_ref.shape[1],
                          0, u_ref.shape[2]))
    assert np.array_equal(mask, hole)


def test_degraded_region_decode(container):
    query.configure_unit_cache(0)
    try:
        hdr = encode.tiled_header(container)
        entry = hdr["units"][0]
        bad = _flip(container, entry)
        region = tuple(entry["box"])
        u_d, v_d, rep = decompress_region(bad, region, degraded=True)
        assert rep.n_decoded < rep.n_units or rep.n_units == 1
        assert not rep.complete
        assert not u_d.any()               # region IS the hole
    finally:
        query.configure_unit_cache(256)


def test_degraded_track_decode_drops_only_affected(container):
    """Kill one covering unit: the surviving piece(s) must be
    node-for-node bit-identical to the full decode (FC=0 on what
    survives), and every dropped segment must actually touch the
    missing box."""
    query.configure_unit_cache(0)
    try:
        s = max(query.track_summaries(container),
                key=lambda s: s["n_nodes"])
        tid = s["track_id"]
        full = query.decode_for_track(container, tid)
        assert full.complete and full.track is not None
        src = query.ContainerSource(container)
        idx = query.parse_track_index(src.header())
        cover = query._cover_entries(src.header(), idx, tid)
        bad = _flip(container, cover[0])
        with pytest.raises(encode.ChecksumError):
            query.decode_for_track(bad, tid)
        d = query.decode_for_track(bad, tid, degraded=True)
        assert not d.complete
        assert [m["key"] for m in d.missing_units] \
            == [tuple(cover[0]["key"])]
        assert d.segments_dropped > 0
        ref = {int(f): tuple(n) for f, n in
               zip(full.track.face_ids, full.track.nodes)}
        pieces = d.pieces or ((d.track,) if d.track is not None else ())
        n_nodes = 0
        for piece in pieces:
            for f, n in zip(piece.face_ids, piece.nodes):
                assert tuple(n) == ref[int(f)]
                n_nodes += 1
        assert 0 < n_nodes < len(full.track.face_ids)
    finally:
        query.configure_unit_cache(256)


def test_degraded_decode_of_salvaged_truncation(container):
    """End-to-end damaged-archive path: truncate mid-frame, salvage,
    then degraded-decode the salvaged container -- values on recovered
    units match the original bit-for-bit."""
    hdr = encode.tiled_header(container)
    units = sorted(hdr["units"], key=lambda e: e["off"])
    e = units[len(units) // 2]
    blob, rep = encode.salvage_container(container[: e["off"] + 5])
    assert rep["units_recovered"] == len(units) // 2
    u_ref, v_ref = decompress_tiled(container)
    u_d, v_d, drep = decompress_tiled(blob, degraded=True)
    assert drep.complete                   # salvaged units all verify
    for ent in encode.tiled_header(blob)["units"]:
        t0, t1, i0, i1, j0, j1 = ent["box"]
        assert np.array_equal(u_d[t0:t1, i0:i1, j0:j1],
                              u_ref[t0:t1, i0:i1, j0:j1])


# --------------------------------------------------- checkpoint errors

def test_checkpoint_restore_raises_typed(tmp_path):
    from repro.train import checkpoint

    with pytest.raises(checkpoint.CheckpointError,
                       match="no checkpoint"):
        checkpoint.restore(str(tmp_path), {})
    assert issubclass(checkpoint.CheckpointError, RuntimeError)
