"""Model-level correctness: decode == prefill consistency, chunked-vs-
reference attention, RWKV chunked-vs-recurrent equivalence, MoE routing,
optimizer behaviour, microbatching equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import rwkv as R
from repro.models import mamba as M
from repro.models.config import ModelConfig
from repro.models.transformer import build_model
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step, init_train_state


def tiny(family="dense", **kw):
    base = dict(name="t", family=family, n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=128, dtype="float32",
                scan_chunk=8, attn_chunk=16)
    base.update(kw)
    return ModelConfig(**base)


def test_decode_matches_prefill_dense():
    """Decoding token-by-token must reproduce prefill's last logits."""
    cfg = tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)

    # reference: prefill over the first 8 tokens
    ref_logits, ref_cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :8]})

    # step-by-step: prefill 4, then decode 4 with a padded cache
    cache = m.init_cache(2, 16, dtype=jnp.float32)
    _, c4 = jax.jit(m.prefill)(params, {"tokens": toks[:, :4]})
    # copy prefill-4 kv into padded cache
    cache["k"] = cache["k"].at[:, :, :4].set(c4["k"])
    cache["v"] = cache["v"].at[:, :, :4].set(c4["v"])
    cache["length"] = c4["length"]
    logits = None
    for t in range(4, 8):
        logits, cache = jax.jit(m.decode_step)(
            params, {"tokens": toks[:, t : t + 1]}, cache
        )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(ref_logits[:, 0]),
        rtol=2e-4, atol=2e-4,
    )


def test_chunked_attention_matches_full():
    cfg = tiny(attn_chunk=8)
    key = jax.random.PRNGKey(0)
    B, S, hkv, g, hd = 2, 32, 2, 2, 16
    q = jax.random.normal(key, (B, S, hkv, g, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, hkv, hd))
    full = L.causal_attention(cfg, q, k, v, chunk=64)   # single chunk path
    chunked = L.causal_attention(cfg, q, k, v, chunk=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_rwkv_chunked_equals_recurrent():
    """The chunk-parallel WKV must equal the token-by-token recurrence."""
    cfg = tiny("ssm", rwkv_head_dim=16, scan_chunk=4)
    p = R.rwkv_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32) * 0.5

    out_chunk, s_chunk, _ = R.time_mix(cfg, p, x, chunk=4)

    state = jnp.zeros((1, R.n_heads(cfg), 16, 16), jnp.float32)
    last = jnp.zeros((1, 1, cfg.d_model), jnp.float32)
    outs = []
    for t in range(8):
        o, state, last = R.time_mix_decode(cfg, p, x[:, t : t + 1], state, last)
        outs.append(o)
    out_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_mamba_forward_equals_decode():
    cfg = tiny("hybrid", attn_every=4, mamba_d_state=4, mamba_d_conv=2,
               scan_chunk=4)
    p = M.mamba_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32) * 0.5
    ref = M.mamba_forward(cfg, p, x, chunk=4)
    state = M.mamba_init_state(cfg, 1)
    outs = []
    for t in range(8):
        o, state = M.mamba_decode_step(cfg, p, x[:, t : t + 1], state)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-4, atol=2e-4)


def test_moe_routes_all_tokens_when_capacity_ample():
    from repro.models import moe as E

    cfg = tiny("moe", n_experts=4, top_k=2, d_ff_expert=32,
               capacity_factor=4.0)
    p = E.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = E.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-5  # >= 1 by Cauchy-Schwarz, = 1 if balanced
    # with ample capacity, output must be a true mixture (nonzero nearly
    # everywhere)
    assert (np.abs(np.asarray(out)) > 0).mean() > 0.99


def test_adamw_reduces_loss():
    cfg = tiny()
    m = build_model(cfg)
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=1)
    params, state = init_train_state(m, jax.random.PRNGKey(0), ocfg)
    step = jax.jit(make_train_step(m, ocfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(10):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
    assert int(state["adam"]["step"]) == 10


def test_microbatching_matches_full_batch():
    cfg = tiny()
    m = build_model(cfg)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1)
    params, state = init_train_state(m, jax.random.PRNGKey(0), ocfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    p1, s1, m1 = jax.jit(make_train_step(m, ocfg, microbatches=1))(
        params, state, batch
    )
    p2, s2, m2 = jax.jit(make_train_step(m, ocfg, microbatches=2))(
        params, state, batch
    )
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_grad_compression_roundtrip_quality():
    from repro.train import grad_compress as gc

    cfg = gc.GradCompressConfig(enabled=True)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 1e-3}
    r = gc.init_residuals(g)
    out, r2, m = gc.compress_grads(g, r, cfg)
    rel = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max() / 1e-3
    assert rel < 0.02  # int8 block quantization: < 2% of scale
    # error feedback carries the quantization error
    assert np.abs(np.asarray(r2["w"])).max() > 0


def test_mrope_text_only_equals_rope():
    """With all three position streams equal, M-RoPE == RoPE."""
    S, hd = 16, 32
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    a1 = L.rope_angles(pos, hd, 1e4)
    pid = jnp.broadcast_to(pos[None], (3, 1, S))
    a2 = L.mrope_angles(pid, hd, 1e4, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)
