"""Per-unit adaptive error bounds (core/ebpolicy.py; DESIGN.md #16).

Covers the three load-bearing guarantees of the EbPolicy refactor:

* **uniform is byte-identical**: a config with no policy, an explicit
  :class:`UniformPolicy` and the string ``"uniform"`` produce the exact
  same containers as before the refactor (same format versions, no new
  header keys) on every engine;
* **adaptive resolution is engine-independent**: the policy resolves to
  the same per-vertex bound field whether compression runs monolithic,
  tiled, streaming (serial or async) or crash-and-resumed -- tiled
  containers are byte-identical across those engines and decode equal
  to the monolithic adaptive container;
* **adaptive containers are self-describing**: version-bumped headers
  carry the policy spec and per-unit ``eb_base``, and the policy spec
  round-trips.
"""
import dataclasses
import io

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_stream,
    compress_tiled,
    compressor,
    decompress,
    decompress_tiled,
    encode,
    pipeline,
    stream_engine,
    tiling,
)
from repro.core import faults as faults_mod
from repro.core.ebpolicy import (
    DegenerateRangeError,
    TilePolicy,
    UniformPolicy,
)
from repro.core import ebpolicy

T, H, W = 7, 16, 20
GRID = TileGrid(tile_h=7, tile_w=9, window_t=3)   # != the policy grid

# policy grid deliberately misaligned with GRID: resolution must never
# read the execution tiling
POL = TilePolicy.make(2, 6, 8, default=5e-2,
                      values={(0, 0, 0): 5e-3, (1, 1, 1): 1e-2,
                              (2, 2, 1): 2e-3})


def _cfg(**kw):
    kw.setdefault("eb", 5e-2)
    kw.setdefault("mode", "abs")
    kw.setdefault("predictor", "mop")
    kw.setdefault("fused", True)
    return CompressionConfig(**kw)


def _adaptive_cfg(**kw):
    return _cfg(eb_policy=POL,
                n_levels=ebpolicy.levels_for(POL), **kw)


@pytest.fixture(scope="module")
def field():
    rng = np.random.default_rng(7)
    u = rng.normal(size=(T, H, W)).astype(np.float32)
    v = rng.normal(size=(T, H, W)).astype(np.float32)
    u[:, :, 9] *= 0.05   # near-zero bands so crossings exist
    v[:, 6, :] *= 0.05
    return u, v


# ------------------------------------------------- uniform byte-identity

def test_uniform_policy_byte_identical_monolithic(field):
    u, v = field
    blob_none, _ = compress(u, v, _cfg())
    blob_obj, _ = compress(u, v, _cfg(eb_policy=UniformPolicy()))
    blob_str, _ = compress(u, v, _cfg(eb_policy="uniform"))
    assert blob_none == blob_obj == blob_str
    header, _ = encode.unpack(blob_none)
    assert header["version"] == pipeline.FORMAT_VERSION
    assert "eb_policy" not in header


def test_uniform_policy_byte_identical_tiled(field):
    u, v = field
    blob_none, _ = compress_tiled(u, v, _cfg(), GRID)
    blob_obj, _ = compress_tiled(u, v, _cfg(eb_policy=UniformPolicy()),
                                 GRID)
    assert blob_none == blob_obj
    header = encode.tiled_header(blob_none)
    assert header["version"] == tiling.TILED_FORMAT_VERSION
    assert "eb_policy" not in header


# ------------------------------------------- engine-independent adaptive

def test_adaptive_monolithic_decodes_equal_to_tiled(field):
    u, v = field
    blob_m, st_m = compress(u, v, _adaptive_cfg())
    blob_t, st_t = compress_tiled(u, v, _adaptive_cfg(), GRID)
    um, vm = decompress(blob_m)
    ut, vt = decompress_tiled(blob_t)
    assert np.array_equal(um, ut) and np.array_equal(vm, vt)
    # adaptivity only clamps DOWN: the loosest policy bound still holds
    loose = ebpolicy.max_bound(POL)
    assert np.abs(um.astype(np.float64) - u).max() <= loose
    assert np.abs(vm.astype(np.float64) - v).max() <= loose


def _vr(u, v):
    return (float(min(u.min(), v.min())), float(max(u.max(), v.max())))


def test_adaptive_streaming_serial_async_byte_identical(field):
    u, v = field
    pairs = list(zip(u, v))
    blob_t, _ = compress_tiled(u, v, _adaptive_cfg(), GRID)
    for use_async in (False, True):
        blob_s, _ = compress_stream(iter(pairs), _adaptive_cfg(), GRID,
                                    value_range=_vr(u, v),
                                    async_engine=use_async)
        assert blob_s == blob_t, f"async={use_async}"


def test_adaptive_kill_and_resume_byte_identical(field, tmp_path):
    u, v = field
    pairs = list(zip(u, v))
    cfg = _adaptive_cfg()
    blob_ref, _ = compress_tiled(u, v, cfg, GRID)
    p = tmp_path / "crash.cptt"

    def feed(t0):
        return iter(pairs[t0:])

    plan = faults_mod.FaultPlan().io_error("stream.compute", nth=4)
    with pytest.raises(faults_mod.InjectedFault):
        compress_stream(feed, cfg, GRID, value_range=_vr(u, v),
                        sink=str(p), faults=plan)
    info = stream_engine.resume_info(str(p))
    assert info["resumable"] and not info["complete"]
    compress_stream(feed, cfg, GRID, value_range=_vr(u, v),
                    sink=str(p), resume=True)
    assert p.read_bytes() == blob_ref


def test_resume_fingerprint_includes_policy():
    """The journal's run fingerprint carries the policy spec (the
    dataclasses.asdict scalar filter would silently drop it), so a
    resume under a different policy trips the existing ResumeError
    mismatch check instead of splicing mixed-bound bytes."""
    fp_a = stream_engine._fingerprint(_adaptive_cfg(), GRID,
                                      (0.0, 1.0), H, W)
    fp_u = stream_engine._fingerprint(_cfg(), GRID, (0.0, 1.0), H, W)
    assert fp_a["eb_policy"] == POL.spec()
    assert fp_u["eb_policy"] is None
    assert not stream_engine._fp_equal(fp_a, fp_u)
    other = TilePolicy.make(2, 6, 8, default=5e-2,
                            values={(0, 0, 0): 1e-3})
    fp_o = stream_engine._fingerprint(
        _cfg(eb_policy=other, n_levels=_adaptive_cfg().n_levels),
        GRID, (0.0, 1.0), H, W)
    assert not stream_engine._fp_equal(fp_a, fp_o)
    # same policy from a round-tripped spec still matches
    fp_rt = stream_engine._fingerprint(
        _cfg(eb_policy=POL.spec(), n_levels=_adaptive_cfg().n_levels),
        GRID, (0.0, 1.0), H, W)
    assert stream_engine._fp_equal(fp_a, fp_rt)


# ------------------------------------------------ self-describing format

def test_adaptive_container_versions_and_policy_header(field):
    u, v = field
    blob_m, _ = compress(u, v, _adaptive_cfg())
    hm, _ = encode.unpack(blob_m)
    assert hm["version"] == pipeline.FORMAT_VERSION_ADAPTIVE
    assert ebpolicy.policy_from_spec(hm["eb_policy"]) == POL

    blob_t, _ = compress_tiled(u, v, _adaptive_cfg(), GRID)
    ht = encode.tiled_header(blob_t)
    assert ht["version"] == tiling.TILED_FORMAT_VERSION_ADAPTIVE
    assert ebpolicy.policy_from_spec(ht["eb_policy"]) == POL


def test_adaptive_unit_frames_record_eb_base(field):
    u, v = field
    blob_t, _ = compress_tiled(u, v, _adaptive_cfg(), GRID)
    frames, _, _ = encode._scan_frames(blob_t)
    seen = 0
    for fr in frames:
        if fr["mark"] == encode.PROLOGUE_MARK:
            continue
        frame = blob_t[fr["off"]: fr["off"] + fr["len"]]
        fh, _ = encode.unpack(frame)
        assert isinstance(fh["eb_base"], float) and fh["eb_base"] > 0
        seen += 1
    assert seen > 1


def test_run_report_eb_base_column(field):
    from repro import obs

    u, v = field
    blob_u, st_u = compress_tiled(u, v, _cfg(), GRID)
    for row in obs.run_report(blob_u)["units"]:
        assert row["eb_base"] == pytest.approx(st_u["eb_abs"])
    blob_a, _ = compress_tiled(u, v, _adaptive_cfg(), GRID)
    bases = {row["eb_base"]
             for row in obs.run_report(blob_a)["units"]}
    assert len(bases) > 1       # per-unit bounds actually vary


def test_policy_spec_roundtrip_and_validation():
    spec = POL.spec()
    assert ebpolicy.policy_from_spec(spec) == POL
    # msgpack round-trips tuples as lists; from_spec must accept both
    import msgpack

    listy = msgpack.unpackb(msgpack.packb(spec, use_bin_type=True),
                            raw=False)
    assert ebpolicy.policy_from_spec(listy) == POL
    with pytest.raises(ValueError):
        TilePolicy.make(0, 6, 8, default=1e-2)
    with pytest.raises(ValueError):
        TilePolicy.make(2, 6, 8, default=-1.0)
    with pytest.raises(ValueError):
        TilePolicy.make(2, 6, 8, default=1e-2,
                        values={(0, 0): 1e-3})
    with pytest.raises(TypeError):
        ebpolicy.normalize(object())


def test_levels_for_covers_policy_span():
    pol = TilePolicy.make(1, 8, 8, default=0.64,
                          values={(0, 0, 0): 0.01})
    # span 64 -> ladder needs ceil(log2(64)) + 1 = 7 rungs
    assert ebpolicy.levels_for(pol) == 7
    assert ebpolicy.levels_for(pol, n_levels=9) == 9
    assert ebpolicy.min_bound(pol) == 0.01
    assert ebpolicy.max_bound(pol) == 0.64


# --------------------------------------------------- degenerate range

def test_degenerate_range_typed_error():
    """mode='rel' on a constant field: a typed DegenerateRangeError (a
    ValueError, raised not asserted), never a silent eb collapse."""
    u = np.full((3, 8, 8), 2.5, np.float32)
    v = np.full((3, 8, 8), 2.5, np.float32)
    with pytest.raises(DegenerateRangeError):
        compress(u, v, CompressionConfig(eb=1e-2, mode="rel"))
    with pytest.raises(ValueError):        # it IS a ValueError
        compressor._abs_eb(u, v, CompressionConfig(eb=1e-2, mode="rel"))
    with pytest.raises(DegenerateRangeError):
        compress_tiled(u, v, CompressionConfig(eb=1e-2, mode="rel"),
                       TileGrid(tile_h=8, tile_w=8, window_t=3))
    # abs mode on the same field stays fine
    blob, _ = compress(u, v, CompressionConfig(eb=1e-2, mode="abs"))
    ur, vr = decompress(blob)
    assert np.abs(ur - u).max() <= 1e-2


# --------------------------------------------------- target-ratio API

def test_compress_target_ratio_uniform_sufficient(field):
    u, v = field
    cfg = _cfg(backend="numpy")
    _, st0 = compress(u, v, cfg)
    blob, st = compress(u, v, cfg, target_ratio=st0["ratio"] * 0.5)
    rt = st["rate_target"]
    assert rt["met"] and rt["uniform_sufficient"]
    ur, vr = decompress(blob)
    assert ur.shape == u.shape


def test_compress_target_ratio_rejects_explicit_policy(field):
    u, v = field
    with pytest.raises(ValueError):
        compress(u, v, _adaptive_cfg(), target_ratio=2.0)
    with pytest.raises(ValueError):
        compress(u, v, _cfg(), target_ratio=-1.0)
