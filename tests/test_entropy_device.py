"""Device entropy stage guarantees (core/entropy.py, DESIGN.md).

The batched symbolize/table/bit-pack stage is an alternate *encoding*
of the exact same streams the host coder ships, so every property here
is bit-level: device containers must decode identically to host
containers, batched fragments must equal sequential fragments byte for
byte, the numpy mirrors must match the jax path, and the vectorized
batch table construction must emit the same canonical code space as
``encode.canonical_codes``.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import CompressionConfig, compress, decompress, encode
from repro.core import entropy, tiling
from repro.data import synthetic


def _cfg(**kw):
    base = dict(eb=1e-3, mode="rel", predictor="mop", backend="xla",
                verify=True, fused=True)
    base.update(kw)
    return CompressionConfig(**base)


def _residual_stacks(n_units=4, shape=(2, 12, 16), seed=0, spikes=False):
    rng = np.random.default_rng(seed)
    ru = np.round(rng.standard_normal((n_units,) + shape) * 7)
    rv = np.round(rng.standard_normal((n_units,) + shape) * 7)
    if spikes:
        # force the escape path (|residual| beyond the symbol range)
        ru.reshape(n_units, -1)[:, ::61] = 10 ** 7
    return ru.astype(np.int64), rv.astype(np.int64)


# ------------------------------------------------- end-to-end codec A/B

@pytest.mark.parametrize("predictor", ["mop", "lorenzo"])
def test_device_codec_decode_parity(small_field, predictor):
    """codec='device' ships a CPTH1 container whose decode is
    bit-identical to the host-codec decode of the same field."""
    u, v = small_field
    host_blob, host_stats = compress(u, v, _cfg(predictor=predictor))
    dev_blob, dev_stats = compress(
        u, v, _cfg(predictor=predictor, codec="device"))
    assert dev_blob[:5] == encode.MAGIC_HUF
    assert host_blob[:5] != encode.MAGIC_HUF
    uh, vh = decompress(host_blob)
    ud, vd = decompress(dev_blob)
    assert np.array_equal(uh, ud) and np.array_equal(vh, vd)
    assert host_stats["eb_abs"] == dev_stats["eb_abs"]


def test_device_codec_container_self_describing(small_field):
    """The reader dispatches on the container, not the config: a CPTH1
    blob decodes without being told which codec wrote it."""
    u, v = small_field
    blob, _ = compress(u, v, _cfg(codec="device"))
    header, _ = encode.unpack(blob)
    assert header["codec"] == "huffman"
    ur, _ = decompress(blob)          # no codec hint anywhere
    assert ur.shape == u.shape


@pytest.mark.parametrize("batch_units", [True, False])
def test_tiled_device_codec_bytes(small_field, batch_units):
    """Tiled archives under codec='device': the batched and per-unit
    paths produce byte-identical containers, and both decode to the
    host-codec tiled decode."""
    u, v = small_field
    grid = tiling.TileGrid(2, 10, 14)
    cfg = _cfg(codec="device", tiling=grid, batch_units=batch_units)
    blob, _ = tiling.compress_tiled(u, v, cfg, grid)
    ref_blob, _ = tiling.compress_tiled(
        u, v, dataclasses.replace(cfg, batch_units=not batch_units), grid)
    assert blob == ref_blob
    ut, vt = tiling.decompress_tiled(blob)
    uh, vh = tiling.decompress_tiled(
        tiling.compress_tiled(
            u, v, dataclasses.replace(cfg, codec="host"), grid)[0])
    assert np.array_equal(ut, uh) and np.array_equal(vt, vh)


# ------------------------------------------- stage-level bit identities

def test_batched_equals_sequential_fragments():
    """Per-row tables make fragments independent of batch size: the
    B-unit call and B single-unit calls emit identical bytes, lengths
    and escapes."""
    ru, rv = _residual_stacks(n_units=5, spikes=True)
    batched = entropy.encode_streams(ru, rv)
    for i, frag in enumerate(batched):
        solo = entropy.encode_streams(ru[i:i + 1], rv[i:i + 1])[0]
        for key in ("sym_u", "sym_v"):
            assert frag[key].data == solo[key].data
            assert np.array_equal(frag[key].lengths, solo[key].lengths)
            assert frag[key].n == solo[key].n
        for key in ("esc_u", "esc_v"):
            assert np.array_equal(np.asarray(frag[key]),
                                  np.asarray(solo[key]))


def test_numpy_backend_matches_xla():
    """The numpy mirrors are a backend, not an approximation: both
    bindings emit the same bitstreams on the same residuals."""
    ru, rv = _residual_stacks(n_units=3, spikes=True)
    fx = entropy.encode_streams(ru, rv, "xla")
    fn = entropy.encode_streams(ru, rv, "numpy")
    for a, b in zip(fx, fn):
        for key in ("sym_u", "sym_v"):
            assert a[key].data == b[key].data
            assert np.array_equal(a[key].lengths, b[key].lengths)
        for key in ("esc_u", "esc_v"):
            assert np.array_equal(np.asarray(a[key]), np.asarray(b[key]))


def test_decode_matches_host_symbolize():
    """Device bitstreams decode to the exact symbol arrays the host
    coder produces, and the escape values round-trip."""
    ru, rv = _residual_stacks(n_units=3, spikes=True)
    frags = entropy.encode_streams(ru, rv)
    for i, frag in enumerate(frags):
        for key, ekey, res in (("sym_u", "esc_u", ru[i]),
                               ("sym_v", "esc_v", rv[i])):
            sym, esc = encode.to_symbols(res)
            sec = frag[key]
            assert np.array_equal(
                entropy.decode_symbols(sec.lengths, sec.data, sec.n), sym)
            assert np.array_equal(np.asarray(frag[ekey]), esc)


def test_pallas_histogram_parity():
    """The pallas histogram kernel (interpret mode off-TPU) is
    bit-identical to the jnp ref and the numpy mirror, including on a
    non-CHUNK-aligned row length (exercises the pad-correction)."""
    from repro.kernels.entropy import ops

    rng = np.random.default_rng(3)
    for n in (128, 1000):             # aligned and ragged
        sym = rng.integers(0, 256, (4, n)).astype(np.uint8)
        ref = np.asarray(ops.symbol_histogram(sym, force_ref=True))
        pal = np.asarray(ops.symbol_histogram(sym, force_pallas=True))
        npy = np.stack([np.bincount(row, minlength=256) for row in sym])
        assert np.array_equal(ref, pal)
        assert np.array_equal(ref, npy)


# ------------------------------------------------ batch table validity

def test_build_tables_batch_canonical_and_kraft():
    """Fuzzed histograms: batch-built lengths are always decodable
    (1..L_MAX, Kraft holds) and the code words are exactly
    ``encode.canonical_codes`` of those lengths, row by row."""
    rng = np.random.default_rng(7)
    hists = []
    for _ in range(40):
        hist = np.zeros(256, np.int64)
        k = int(rng.integers(1, 200))
        idx = rng.choice(256, k, replace=False)
        hist[idx] = rng.zipf(1.6, k).clip(1, 10 ** 6)
        hists.append(hist)
    hists.append(np.eye(256, dtype=np.int64)[17] * 999)   # single symbol
    hist = np.stack(hists)
    lengths, codes = entropy.build_tables_batch(hist)
    for r in range(hist.shape[0]):
        ln = lengths[r]
        present = hist[r] > 0
        assert (ln[present] >= 1).all() and (ln[present] <= entropy.L_MAX).all()
        assert (ln[~present] == 0).all()
        kraft = (np.int64(1) << (entropy.L_MAX - ln[present])).sum()
        assert kraft <= (np.int64(1) << entropy.L_MAX)
        ref_codes, _ = encode.canonical_codes(ln.astype(np.uint8))
        assert np.array_equal(codes[r][present],
                              ref_codes[present].astype(np.uint32))


def test_build_tables_batch_rows_independent():
    """A row's table depends only on that row's counts -- the property
    that makes batched == sequential bytes."""
    rng = np.random.default_rng(11)
    hist = rng.integers(0, 50, (6, 256)).astype(np.int64)
    full_l, full_c = entropy.build_tables_batch(hist)
    solo_l, solo_c = entropy.build_tables_batch(hist[2:3])
    assert np.array_equal(full_l[2], solo_l[0])
    assert np.array_equal(full_c[2], solo_c[0])


# ------------------------------------------------------- failure paths

def test_cpth1_corruption_raises(small_field):
    """Corrupt CPTH1 containers fail with ContainerError, never decode
    garbage: truncation, a mangled header, and a Kraft-breaking huffman
    table are all typed failures."""
    u, v = small_field
    blob, _ = compress(u, v, _cfg(codec="device"))

    with pytest.raises(encode.ContainerError):
        encode.unpack(blob[:7])
    corrupt_hdr = bytearray(blob)
    corrupt_hdr[12] ^= 0xFF           # inside the msgpack header
    with pytest.raises(encode.ContainerError):
        encode.unpack(bytes(corrupt_hdr))

    ru, rv = _residual_stacks(n_units=1)
    sec = entropy.encode_streams(ru, rv)[0]["sym_u"]
    bad = np.zeros(256, np.uint8)
    bad[:4] = 1                       # four 1-bit codes: Kraft sum 2 > 1
    with pytest.raises(encode.ContainerError, match="Kraft"):
        entropy.decode_symbols(bad, sec.data, sec.n)
    with pytest.raises(encode.ContainerError, match="max code length"):
        entropy.decode_symbols(np.full(256, 31, np.uint8), sec.data, sec.n)


def test_magics_disjoint():
    """No container tag is a prefix of another (the reader dispatches
    on a fixed-length magic read)."""
    magics = (encode.MAGIC, encode.MAGIC_ZLIB, encode.MAGIC_TILED,
              encode.MAGIC_HUF)
    assert len(set(magics)) == len(magics)
    for a in magics:
        for b in magics:
            assert a == b or not b.startswith(a[:4])
