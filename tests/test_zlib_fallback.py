"""CPTL1 zlib-fallback container coverage (no ``zstandard`` installed).

The CI minimal-env job exercises import + one roundtrip without the
zstandard wheel; these tests monkeypatch the module away so the degraded
codec path is exercised in the full suite too: monolithic roundtrip on
the CPTL1 magic, tiled-container behavior (unit frames degrade codec,
the CPTT1 layout is codec-agnostic), and the error path for decoding a
zstd blob without the module.
"""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_tiled,
    decompress,
    decompress_region,
    decompress_tiled,
    encode,
)
from repro.data import synthetic


@pytest.fixture(scope="module")
def field():
    return synthetic.double_gyre(T=5, H=12, W=16)


def _cfg(**kw):
    kw.setdefault("eb", 1e-2)
    kw.setdefault("mode", "rel")
    kw.setdefault("track_index", False)
    return CompressionConfig(**kw)


@pytest.fixture()
def no_zstd(monkeypatch):
    monkeypatch.setattr(encode, "zstandard", None)
    yield


def test_monolithic_roundtrip_on_zlib(field, no_zstd):
    u, v = field
    assert encode.backend_codec() == "zlib"
    blob, stats = compress(u, v, _cfg())
    assert blob[: len(encode.MAGIC_ZLIB)] == encode.MAGIC_ZLIB
    header, _ = encode.unpack(blob)
    assert header["codec"] == "zlib"
    ur, vr = decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]


def test_tiled_container_on_zlib(field, no_zstd):
    """Unit frames degrade to CPTL1 inside the CPTT1 directory layout;
    full, region and batched==sequential behavior survive the fallback."""
    u, v = field
    grid = TileGrid(tile_h=6, tile_w=8, window_t=3)
    blob, stats = compress_tiled(u, v, _cfg(), grid)
    assert encode.is_tiled(blob)
    hdr = encode.tiled_header(blob)
    uh, _ = encode.read_tiled_unit(blob, hdr["units"][0])
    assert uh["codec"] == "zlib"
    ur, vr = decompress_tiled(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    region = (0, 2, 0, 6, 0, 8)
    urr, vrr = decompress_region(blob, region)
    assert np.array_equal(urr, ur[0:2, 0:6, 0:8])
    assert np.array_equal(vrr, vr[0:2, 0:6, 0:8])
    blob_s, _ = compress_tiled(
        u, v, _cfg(batch_units=False), grid)
    assert blob_s == blob


def test_zlib_blob_decodes_with_zstd_available(field, monkeypatch):
    """A CPTL1 blob written by a minimal env must decode when zstandard
    IS installed (mixed-environment archive reads)."""
    u, v = field
    monkeypatch.setattr(encode, "zstandard", None)
    blob, stats = compress(u, v, _cfg())
    assert blob[: len(encode.MAGIC_ZLIB)] == encode.MAGIC_ZLIB
    monkeypatch.undo()
    ur, vr = decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]


def test_zstd_blob_without_zstandard_raises(field, monkeypatch):
    if not encode.have_zstd():
        pytest.skip("zstandard not installed in this env")
    u, v = field
    blob, _ = compress(u, v, _cfg())
    assert blob[: len(encode.MAGIC)] == encode.MAGIC
    monkeypatch.setattr(encode, "zstandard", None)
    with pytest.raises(RuntimeError, match="zstandard"):
        decompress(blob)
