"""End-to-end trajectory equivalence (paper Sec. VII-G evaluation).

The headline claim: every critical-point trajectory of the space-time
mesh survives compression -- zero false positives, zero false negatives,
zero type changes.  These tests compress, decompress, EXTRACT the
trajectories from both fields (core/trajectory.py union-find over the
crossed-face graph) and compare -- for both paper predictors and for the
MoP mixture, on the monolithic and the tiled pipeline, asserting tiled
output is bit-for-bit the monolithic output.
"""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_tiled,
    decompress,
    decompress_tiled,
    fixedpoint,
    trajectory,
)
from repro.data import synthetic


def _fields():
    u1, v1 = synthetic.double_gyre(T=6, H=20, W=28)
    u2, v2 = synthetic.vortex_street(T=6, H=24, W=36)
    return {
        "double_gyre": (u1, v1, dict(dt=0.1, dx=2.0 / 27, dy=1.0 / 19)),
        "vortex_street": (u2, v2, dict(dt=0.05, dx=2.0 / 35, dy=1.0 / 23)),
    }


def _assert_trajectory_equivalent(u, v, ur, vr, scale):
    # (a) per-face false cases: FC_t = FC_s = 0, counts preserved
    fc = trajectory.false_cases(u, v, ur, vr, scale)
    assert fc["FC_t"] == 0, fc
    assert fc["FC_s"] == 0, fc
    assert fc["CP_t_orig"] == fc["CP_t_rec"]
    assert fc["CP_slab_orig"] == fc["CP_slab_rec"]
    # (b) the extracted track graph is identical: same crossings glued
    # into the same number of trajectories (no split/merge/type change)
    uo, vo = fixedpoint.refix(u, v, scale)
    ud, vd = fixedpoint.refix(ur, vr, scale)
    t_orig = trajectory.extract_tracks(uo, vo)
    t_rec = trajectory.extract_tracks(ud, vd)
    assert t_orig == t_rec, (t_orig, t_rec)
    assert t_orig["n_tracks"] > 0, "field has no trajectories to preserve"


@pytest.mark.parametrize("predictor", ["lorenzo", "sl"])
@pytest.mark.parametrize("name", ["double_gyre", "vortex_street"])
def test_monolithic_trajectory_equivalence(name, predictor):
    u, v, meta = _fields()[name]
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor=predictor,
                            fused=True, **meta)
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    _assert_trajectory_equivalent(u, v, ur, vr, stats["scale"])


@pytest.mark.parametrize("predictor", ["lorenzo", "sl", "mop"])
def test_tiled_equals_monolithic_bitwise(predictor):
    """>= 4 spatial tiles x 2 windows must decode to the exact bytes the
    monolithic fused pipeline produces, trajectories included."""
    u, v, meta = _fields()["double_gyre"]
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor=predictor,
                            fused=True, **meta)
    blob_m, stats_m = compress(u, v, cfg)
    um, vm = decompress(blob_m)
    grid = TileGrid(tile_h=10, tile_w=14, window_t=3)
    blob_t, stats_t = compress_tiled(u, v, cfg, grid)
    assert stats_t["n_units"] >= 8
    ut, vt = decompress_tiled(blob_t)
    assert um.dtype == ut.dtype == np.float32
    assert np.array_equal(um, ut) and np.array_equal(vm, vt)
    _assert_trajectory_equivalent(u, v, ut, vt, stats_t["scale"])


@pytest.mark.parametrize("predictor", ["lorenzo", "sl"])
def test_tiled_trajectory_equivalence(predictor):
    u, v, meta = _fields()["vortex_street"]
    cfg = CompressionConfig(eb=5e-3, mode="rel", predictor=predictor,
                            fused=True, **meta)
    grid = TileGrid(tile_h=12, tile_w=12, window_t=4)
    blob, stats = compress_tiled(u, v, cfg, grid)
    ur, vr = decompress_tiled(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]
    _assert_trajectory_equivalent(u, v, ur, vr, stats["scale"])
