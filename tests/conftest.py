import numpy as np
import pytest

# Core compression requires exact int64 predicates; model code is
# dtype-explicit and unaffected by x64.
import repro.core  # noqa: F401  (enables jax x64)


@pytest.fixture(scope="session")
def small_field():
    from repro.data import synthetic

    return synthetic.double_gyre(T=6, H=20, W=28)


@pytest.fixture(scope="session")
def advective_field():
    from repro.data import synthetic

    return synthetic.vortex_street(T=8, H=32, W=48)
