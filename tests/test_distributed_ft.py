"""Distribution + fault-tolerance: sharded train step on a real (test)
mesh, checkpoint atomicity, mesh-reshape restore, elastic restart.

Multi-device cases run in subprocesses with
xla_force_host_platform_device_count (the parent process has 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.train import checkpoint as ckpt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code, n_devices=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.models.transformer import build_model
        from repro.parallel import sharding as shd
        from repro.train import optimizer as opt
        from repro.train.train_step import make_train_step, init_train_state
        from repro.launch.mesh import make_test_mesh

        cfg = C.get('stablelm_1_6b').SMOKE
        model = build_model(cfg)
        ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1)
        params, state = init_train_state(model, jax.random.PRNGKey(0), ocfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {'tokens': toks, 'labels': jnp.roll(toks, -1, 1)}
        step = make_train_step(model, ocfg)

        # single device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_test_mesh((4, 2), ('data', 'model'))
        rules = shd.rules_for_mesh(mesh)
        with mesh, shd.use_rules(rules):
            pshard = shd.param_shardings(params, mesh)
            params_s = jax.device_put(params, pshard)
            state_s = jax.device_put(
                state, {'adam': {'m': pshard, 'v': pshard,
                        'step': NamedSharding(mesh, P())}})
            bshard = {k: NamedSharding(mesh, P('data', None)) for k in batch}
            batch_s = jax.device_put(batch, bshard)
            p2, s2, m2 = jax.jit(step)(params_s, state_s, batch_s)
        # bf16 forward: reduction-order noise ~2e-4 relative on the loss
        assert abs(float(m1['loss']) - float(m2['loss'])) < 3e-3, (
            float(m1['loss']), float(m2['loss']))
        # AdamW normalizes ulp-level grad noise (reduction order) up to
        # +-lr per step, so compare with an update-bounded atol.
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
            assert d.max() <= 3.0e-3, d.max()
        print('SHARDED_OK', float(m2['loss']))
    """)
    assert "SHARDED_OK" in out


def test_checkpoint_atomic_and_restore(tmp_path):
    params = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
              "b": np.zeros(4, np.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, {"params": params}, meta={"arch": "x"})
    assert ckpt.latest_step(d) == 10
    restored, manifest = ckpt.restore(d, {"params": params})
    np.testing.assert_array_equal(restored["params"]["w"], params["w"])
    assert manifest["meta"]["arch"] == "x"
    # second save supersedes, gc keeps both (keep=3)
    params2 = {"w": params["w"] + 1, "b": params["b"]}
    ckpt.save(d, 20, {"params": params2})
    assert ckpt.latest_step(d) == 20
    r2, _ = ckpt.restore(d, {"params": params})
    np.testing.assert_array_equal(r2["params"]["w"], params["w"] + 1)
    # explicit step restore still works (rollback path)
    r1, _ = ckpt.restore(d, {"params": params}, step=10)
    np.testing.assert_array_equal(r1["params"]["w"], params["w"])


def test_checkpoint_crash_safety(tmp_path):
    """A failed save must not corrupt LATEST."""
    d = str(tmp_path / "ck")
    params = {"w": np.ones((2, 2), np.float32)}
    ckpt.save(d, 1, {"params": params})
    bad = {"params": {"w": object()}}  # unsavable -> raises
    with pytest.raises(Exception):
        ckpt.save(d, 2, bad)
    assert ckpt.latest_step(d) == 1
    restored, _ = ckpt.restore(d, {"params": params})
    np.testing.assert_array_equal(restored["params"]["w"], params["w"])


def test_mesh_reshape_restore(tmp_path):
    """Checkpoint saved on a (4,2) mesh restores onto (2,2,2) -- the
    elastic-scaling / failure-recovery path."""
    d = str(tmp_path / "ck")
    out = run_py(f"""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.train import checkpoint as ckpt

        w = np.arange(64, dtype=np.float32).reshape(8, 8)
        mesh1 = make_test_mesh((4, 2), ('data', 'model'))
        ws = jax.device_put(w, NamedSharding(mesh1, P('data', 'model')))
        ckpt.save({d!r}, 5, {{'params': {{'w': ws}}}})

        mesh2 = make_test_mesh((2, 2, 2), ('pod', 'data', 'model'))
        tgt = NamedSharding(mesh2, P(('pod', 'data'), 'model'))
        restored, _ = ckpt.restore(
            {d!r}, {{'params': {{'w': w}}}},
            shardings={{'params': {{'w': tgt}}}})
        got = restored['params']['w']
        assert got.sharding == tgt, got.sharding
        np.testing.assert_array_equal(np.asarray(got), w)
        print('RESHAPE_OK')
    """)
    assert "RESHAPE_OK" in out


def test_train_driver_restart_continuity(tmp_path):
    """Kill-and-resume produces the same batch sequence (stateless data
    pipeline keyed on step)."""
    d = str(tmp_path / "ck")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1_5_0_5b", "--smoke", "--batch", "2", "--seq", "32",
           "--ckpt-dir", d, "--ckpt-every", "5", "--log-every", "1"]
    r1 = subprocess.run(cmd + ["--steps", "10"], capture_output=True,
                        text=True, env=env, timeout=600)
    assert r1.returncode == 0, r1.stderr
    r2 = subprocess.run(cmd + ["--steps", "14", "--resume"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 10" in r2.stdout
    # loss continues from the checkpointed trajectory (no reset spike)
    import re

    losses1 = [float(m) for m in re.findall(r"loss (\d+\.\d+)", r1.stdout)]
    losses2 = [float(m) for m in re.findall(r"loss (\d+\.\d+)", r2.stdout)]
    assert losses2[0] < losses1[0]  # still below the cold-start loss


def test_grad_compression_in_train_step():
    import jax.numpy as jnp
    from repro.models.transformer import build_model
    import repro.configs as C
    from repro.train import optimizer as opt
    from repro.train.grad_compress import GradCompressConfig
    from repro.train.train_step import make_train_step, init_train_state

    cfg = C.get("qwen1_5_0_5b").SMOKE
    model = build_model(cfg)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1)
    gc_cfg = GradCompressConfig(enabled=True)
    params, state = init_train_state(model, jax.random.PRNGKey(0), ocfg, gc_cfg)
    assert "gc_residuals" in state
    step = jax.jit(make_train_step(model, ocfg, 1, gc_cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # converges despite int8 gradients


def test_lossy_checkpoint_roundtrip(tmp_path):
    """Opt-in eb-quantized checkpoints: bounded error, smaller files,
    transparent restore (the paper's quantizer applied to params)."""
    rng = np.random.default_rng(0)
    params = {"w": rng.normal(0, 0.02, (64, 64)).astype(np.float32),
              "tiny": np.ones(4, np.float32)}
    d1, d2 = str(tmp_path / "exact"), str(tmp_path / "lossy")
    ckpt.save(d1, 1, {"params": params})
    ckpt.save(d2, 1, {"params": params}, lossy_rel_eb=1e-3)
    r, m = ckpt.restore(d2, {"params": params})
    eb = 1e-3 * np.abs(params["w"]).max()
    assert np.abs(r["params"]["w"] - params["w"]).max() <= eb + 1e-9
    # tiny leaves stay exact
    np.testing.assert_array_equal(r["params"]["tiny"], params["tiny"])

    def sz(d):
        import glob
        return sum(os.path.getsize(f) for f in
                   glob.glob(os.path.join(d, "step_*", "arrays.npz")))

    assert sz(d2) < sz(d1) * 0.6  # int32 codes + compression win
