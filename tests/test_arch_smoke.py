"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.transformer import build_model


def _smoke_batch(cfg, B=2, S=32):
    i32 = jnp.int32
    if cfg.is_encoder_decoder:
        return {
            "frames": jnp.zeros((B, S, cfg.d_model), jnp.float32),
            "tokens": jnp.zeros((B, 16), i32),
            "labels": jnp.ones((B, 16), i32),
        }
    if cfg.embedding_inputs:
        return {
            "embeds": jax.random.normal(
                jax.random.PRNGKey(1), (B, S, cfg.d_model)
            ).astype(jnp.bfloat16),
            "position_ids": jnp.zeros((3, B, S), i32),
            "labels": jnp.ones((B, S), i32),
        }
    return {
        "tokens": jnp.zeros((B, S), i32),
        "labels": jnp.ones((B, S), i32),
    }


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_smoke_train_step(arch):
    mod = C.get(arch)
    cfg = mod.SMOKE
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_smoke_decode(arch):
    mod = C.get(arch)
    cfg = mod.SMOKE
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    pref = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(model.prefill)(params, pref)
    assert np.isfinite(np.asarray(logits)).all(), arch
    if cfg.embedding_inputs:
        step_batch = {"embeds": jnp.zeros((2, 1, cfg.d_model), jnp.bfloat16)}
    else:
        step_batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    logits2, cache2 = jax.jit(model.decode_step)(params, step_batch, cache)
    assert logits2.shape[:2] == (2, 1)
    assert logits2.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits2)).all(), arch
    assert int(cache2["length"]) == int(cache["length"]) + 1


@pytest.mark.parametrize("arch", C.ARCHS)
def test_full_config_sanity(arch):
    """Exact published dims + cell table coverage (no allocation)."""
    mod = C.get(arch)
    cfg = mod.CONFIG
    assert cfg.d_model % 16 == 0 or arch == "whisper_small"
    assert set(mod.CELLS) == {"train_4k", "prefill_32k", "decode_32k",
                              "long_500k"}
    runnable = [s for s, c in mod.CELLS.items() if not c.skip]
    assert "train_4k" in runnable and "decode_32k" in runnable
    if cfg.supports_long_context:
        assert not mod.CELLS["long_500k"].skip
    else:
        assert mod.CELLS["long_500k"].skip
    # param count within 40% of the advertised size where the name says it
    n = cfg.param_count()
    expected = {
        "stablelm_1_6b": 1.6e9, "qwen1_5_0_5b": 0.5e9, "yi_6b": 6e9,
        "qwen1_5_32b": 32e9, "jamba_1_5_large": 398e9,
        "llama4_scout_17b_16e": 109e9, "olmoe_1b_7b": 7e9,
        "rwkv6_3b": 3e9, "whisper_small": 0.24e9, "qwen2_vl_7b": 7.6e9,
    }[arch]
    assert 0.6 * expected < n < 1.5 * expected, (arch, n, expected)


def test_input_specs_shapes():
    mod = C.get("yi_6b")
    cell = mod.CELLS["train_4k"]
    specs = C.input_specs(mod.CONFIG, cell)
    assert specs["tokens"].shape == (256, 4096)
    cell = mod.CELLS["prefill_32k"]
    specs = C.input_specs(mod.CONFIG, cell)
    assert specs["tokens"].shape == (32, 32768)
    wm = C.get("whisper_small")
    specs = C.input_specs(wm.CONFIG, wm.CELLS["train_4k"])
    assert specs["frames"].shape == (256, 4096, 768)
    vm = C.get("qwen2_vl_7b")
    specs = C.input_specs(vm.CONFIG, vm.CELLS["train_4k"])
    assert specs["embeds"].shape == (256, 4096, 3584)
    assert specs["position_ids"].shape == (3, 256, 4096)
