"""Corrupt-container matrix: typed errors on every read path.

Truncated footers, forged length words, unknown codec tags, short reads
mid-unit and bit flips must all raise ContainerError (a ValueError
subclass) from ``unpack``, ``tiled_header_ranged`` and
``decode_for_track`` -- on both the zstd and the zlib-fallback
container.  The same matrix runs under ``python -O`` in CI via
tests/opt_mode_check.py (see container_corruptions.py).
"""
import pytest

from repro.core import encode

import container_corruptions as cc


@pytest.fixture(scope="module")
def blobs():
    return cc.build_blobs()


def test_matrix_default_codec(blobs):
    assert cc.run_matrix(*blobs)


def test_matrix_zlib_codec(monkeypatch):
    """Same matrix with zstandard hidden: the CPTL1 fallback container
    must fail just as loudly."""
    monkeypatch.setattr(encode, "zstandard", None)
    encode_state = encode.backend_codec()
    assert encode_state == "zlib"
    mono, tiled, hdr = cc.build_blobs()
    assert mono[:5] == encode.MAGIC_ZLIB
    assert cc.run_matrix(mono, tiled, hdr)


def test_recovery_matrix_default_codec(blobs, tmp_path):
    _, tiled, hdr = blobs
    assert cc.run_recovery_matrix(tiled, hdr, str(tmp_path))


def test_recovery_matrix_zlib_codec(monkeypatch, tmp_path):
    """Salvage and resume must work on the CPTL1 fallback container."""
    monkeypatch.setattr(encode, "zstandard", None)
    assert encode.backend_codec() == "zlib"
    _, tiled, hdr = cc.build_blobs()
    assert cc.run_recovery_matrix(tiled, hdr, str(tmp_path))


def test_adaptive_matrix_default_codec():
    blob, hdr, field, pol = cc.build_adaptive_blob()
    assert cc.run_adaptive_matrix(blob, hdr, field, pol)


def test_unknown_codec_regression():
    """encode.codec_decompress used to route ANY unknown codec string
    through zlib, decoding forged headers to garbage."""
    with pytest.raises(ValueError, match="unknown container codec"):
        encode.codec_decompress(b"\x78\x9c\x03\x00\x00\x00\x00\x01",
                                "lzma")
    # the valid names still work / still raise their own typed errors
    with pytest.raises(encode.ContainerError, match="corrupt zlib frame"):
        encode.codec_decompress(b"not-a-zlib-frame", "zlib")


def test_container_error_is_value_error():
    assert issubclass(encode.ContainerError, ValueError)


def test_short_read_raises_typed_error(tmp_path, blobs):
    """Path sources: a file truncated mid-unit raises ContainerError
    from the persistent-handle source (length-checked pread)."""
    from repro.analysis.query import ContainerSource

    _, tiled, hdr = blobs
    entry = hdr["units"][-1]
    p = tmp_path / "trunc.cptt1"
    p.write_bytes(tiled[: entry["off"] + entry["len"] // 2])
    src = ContainerSource(str(p))
    with pytest.raises(encode.ContainerError, match="short read"):
        src.read(entry["off"], entry["len"])
    src.close()
    with pytest.raises(ValueError, match="closed"):
        src.read(0, 1)
