"""Pipeline-plan executor: one stage graph for every compress path.

Pins the tentpole properties of the refactor (DESIGN.md #10):

* plans: fused / legacy / tiled are bindings of one stage graph, and
  decode plans are recovered from container headers;
* batched unit execution is BYTE-equal to the sequential per-unit loop
  on a >= 8-unit field for both predictor families (the acceptance
  criterion -- integer stages are exact, SL and MoP selection run
  through shared executables);
* the compiled-stage registry is explicitly keyed and never evicts
  (the old 64-entry LRU silently recompiled on shape churn);
* ``compress()`` no longer shares a mutable default config across calls.
"""
import dataclasses
import inspect

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_tiled,
    decompress,
    decompress_tiled,
    encode,
    pipeline,
)
from repro.data import synthetic


@pytest.fixture(scope="module")
def field():
    return synthetic.double_gyre(T=7, H=16, W=24)


def _cfg(**kw):
    kw.setdefault("eb", 1e-2)
    kw.setdefault("mode", "rel")
    kw.setdefault("dt", 0.1)
    kw.setdefault("dx", 2.0 / 23)
    kw.setdefault("dy", 1.0 / 15)
    kw.setdefault("track_index", False)
    return CompressionConfig(**kw)


GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)   # 2x2 tiles x 3 windows


@pytest.mark.parametrize("predictor", ["lorenzo", "mop"])
def test_batched_equals_sequential_bytes(field, predictor):
    """>= 8 units, batched stages vs per-unit loop: identical container
    bytes (residual streams, blockmaps, lossless masks and directory)."""
    u, v = field
    cfg = _cfg(predictor=predictor, batch_units=True)
    blob_b, stats_b = compress_tiled(u, v, cfg, GRID)
    assert stats_b["n_units"] >= 8
    assert stats_b["batch_units"] is True
    blob_s, stats_s = compress_tiled(
        u, v, dataclasses.replace(cfg, batch_units=False), GRID)
    assert stats_s["batch_units"] is False
    assert blob_b == blob_s
    ur, vr = decompress_tiled(blob_b)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats_b["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats_b["eb_abs"]


def test_batched_tiled_still_equals_monolithic(field):
    u, v = field
    cfg = _cfg(predictor="mop", batch_units=True)
    blob_t, _ = compress_tiled(u, v, cfg, GRID)
    blob_m, _ = compress(u, v, cfg)
    um, vm = decompress(blob_m)
    ut, vt = decompress_tiled(blob_t)
    assert np.array_equal(um, ut) and np.array_equal(vm, vt)


def test_plan_bindings_select_pipeline(field):
    u, v = field
    blob_f, stats_f = compress(u, v, _cfg(fused=True))
    blob_l, stats_l = compress(u, v, _cfg(fused=False))
    hdr_f, _ = encode.unpack(blob_f)
    hdr_l, _ = encode.unpack(blob_l)
    assert hdr_f["pipeline"] == "fused" and stats_f["pipeline"] == "fused"
    assert hdr_l["pipeline"] == "legacy" and stats_l["pipeline"] == "legacy"
    assert "sl_backend" in hdr_f and "sl_backend" not in hdr_l
    # both bindings decode through the executor and honor the bound
    for blob, stats in ((blob_f, stats_f), (blob_l, stats_l)):
        ur, vr = decompress(blob)
        assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    # decode plans are recovered from the header
    plan_f = pipeline.plan_from_header(hdr_f)
    plan_l = pipeline.plan_from_header(hdr_l)
    assert dict(plan_f.bindings)["decode"] == "parallel"
    assert dict(plan_l.bindings)["decode"] == "scan"


def test_tiled_header_recovers_fused_bindings(field):
    u, v = field
    blob, _ = compress_tiled(u, v, _cfg(), GRID)
    hdr = encode.tiled_header(blob)
    plan = pipeline.plan_from_header(hdr)
    assert plan.name == "tiled"
    # a host-codec header recovers the fused bindings plus the host
    # symbolize/pack pair (the codec is part of the plan since PR 7)
    assert plan.bindings == pipeline._codec_bindings(
        pipeline.FUSED_BINDINGS, "host")
    assert dict(plan.bindings)["symbolize"] == "host"


def test_registry_is_keyed_and_never_evicts():
    """Shape churn far beyond the old LRU capacity must not evict the
    first entry (eviction = silent recompiles every verify round)."""
    first = pipeline.unit_fns((2, 4, 4), 4, 1, "mop", "xla")
    for w in range(5, 80):
        pipeline.unit_fns((2, 4, w), 4, 1, "mop", "xla")
    assert pipeline.unit_fns((2, 4, 4), 4, 1, "mop", "xla") is first
    key_count = sum(1 for k in pipeline._UNIT_FNS
                    if k[0][:2] == (2, 4) and k[1] == 4)
    assert key_count >= 76


def test_compress_default_config_not_shared():
    """Satellite: cfg defaults to None and is constructed per call --
    the old ``cfg=CompressionConfig()`` default was one module-level
    instance shared (mutably) by every caller."""
    assert inspect.signature(compress).parameters["cfg"].default is None


def test_golden_blob_decodes_through_executor():
    """The checked-in PR-1 blob must decode bitwise through the new
    executor path (redundant with test_container_golden, pinned here so
    executor regressions name the subsystem)."""
    import os
    data = os.path.join(os.path.dirname(__file__), "data")
    with open(os.path.join(data, "golden_v2_mop.cptz"), "rb") as f:
        blob = f.read()
    exp = np.load(os.path.join(data, "golden_v2_expected.npz"))
    hdr, _ = encode.unpack(blob)
    ex = pipeline.executor_from_header(hdr)
    assert ex.plan.name == "fused"
    ur, vr = decompress(blob)
    assert np.array_equal(ur, exp["ur"]) and np.array_equal(vr, exp["vr"])
