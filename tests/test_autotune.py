"""Autotune subsystem: cost model vs measured spans, search determinism,
byte-identity of autotuned containers, calibration-table versioning."""
import dataclasses
import importlib
import json

import numpy as np
import pytest

from repro import autotune, obs
from repro.autotune import costmodel

# the package re-exports the calibrate() *function*; the module needs
# an explicit import
calibrate_mod = importlib.import_module("repro.autotune.calibrate")
from repro.core import CompressionConfig, compress, tiling

SHAPES = ((4, 24, 24), (6, 32, 32))


def _field(shape, seed=3):
    rng = np.random.default_rng(seed)
    base = np.cumsum(rng.normal(size=shape).astype(np.float32), axis=0)
    return base, base[::-1].copy()


@pytest.fixture(scope="module")
def table(tmp_path_factory):
    """One real calibration per module (numpy backend: no jit compile
    noise, runs on any host)."""
    path = str(tmp_path_factory.mktemp("calib") / "table.json")
    return autotune.calibrate(shapes=SHAPES, backends=("numpy",),
                              path=path, jit_cache=False)


def _synthetic_table():
    """A fixed hand-written table: determinism tests must not depend on
    what a live calibration happened to measure."""
    coeffs = {}
    for be in ("xla", "numpy"):
        for i, stage in enumerate(costmodel.STAGES):
            coeffs[(be, stage)] = (1e-4 * (i + 1), 1e-8 * (i + 2))
    return autotune.CalibrationTable(device_kind="cpu", coeffs=coeffs)


# ----------------------------------------------------------------------
# cost model vs obs-measured stage times
# ----------------------------------------------------------------------

class TestPrediction:
    # calibrated on these very shapes, the affine model must land well
    # within an order of magnitude of the measured per-stage time
    FACTOR = 10.0
    NOISE_FLOOR_S = 1e-3

    @pytest.mark.parametrize("shape", SHAPES)
    def test_monolithic_stage_times_within_factor(self, table, shape):
        u, v = _field(shape)
        cfg = CompressionConfig(eb=1e-2, backend="numpy",
                                track_index=False)
        was = obs.enabled()
        try:
            obs.enable()
            compress(u, v, cfg)             # warm any lazy state
            before = obs.stage_durations("pipeline.")
            compress(u, v, cfg)
            after = obs.stage_durations("pipeline.")
        finally:
            obs.enable() if was else obs.disable()

        model = autotune.CostModel(coeffs=table.coeffs,
                                   kind=table.device_kind)
        cand = autotune.PlanCandidate(grid=None, backend="numpy")
        wl = costmodel.Workload(T=shape[0], H=shape[1], W=shape[2])
        pred = model.predict(cand, wl)["stages"]

        checked = 0
        for span, stage in calibrate_mod.SPAN_STAGES.items():
            if not span.startswith("pipeline."):
                continue
            b = before.get(span, {"sum_s": 0.0})
            meas = after.get(span, {"sum_s": 0.0})["sum_s"] - b["sum_s"]
            if meas < self.NOISE_FLOOR_S:
                continue                    # below timer noise: skip
            ratio = pred[stage] / meas
            assert 1.0 / self.FACTOR <= ratio <= self.FACTOR, \
                f"{stage}: predicted {pred[stage]:.5f}s vs measured " \
                f"{meas:.5f}s (x{ratio:.2f}) out of the {self.FACTOR}x " \
                "gate"
            checked += 1
        assert checked >= 1, "no stage rose above the noise floor"

    def test_seeds_exist_for_every_stage_and_backend(self):
        for kind in ("tpu", "gpu", "cpu"):
            for be in ("pallas", "xla", "numpy"):
                seeds = costmodel.seed_coeffs(kind, be)
                assert set(seeds) == set(costmodel.STAGES)
                assert all(c0 > 0 and c1 > 0
                           for c0, c1 in seeds.values())


# ----------------------------------------------------------------------
# search determinism
# ----------------------------------------------------------------------

class TestSearchDeterminism:
    def test_same_table_same_ranking(self):
        t = _synthetic_table()
        runs = []
        for _ in range(3):
            model = autotune.CostModel(coeffs=dict(t.coeffs),
                                       kind=t.device_kind)
            ranked = autotune.search((8, 40, 40), model=model)
            runs.append([r.cand.key for r in ranked])
        assert runs[0] == runs[1] == runs[2]

    def test_candidate_order_does_not_matter(self):
        t = _synthetic_table()
        model = autotune.CostModel(coeffs=t.coeffs, kind=t.device_kind)
        cands = autotune.enumerate_candidates((8, 40, 40))
        fwd = autotune.search((8, 40, 40), model=model, candidates=cands)
        rev = autotune.search((8, 40, 40), model=model,
                              candidates=list(reversed(cands)))
        assert [r.cand for r in fwd] == [r.cand for r in rev]

    def test_stream_ranking_deterministic_and_tiled(self):
        t = _synthetic_table()
        model = autotune.CostModel(coeffs=t.coeffs, kind=t.device_kind)
        a = autotune.search((16, 48, 48), model=model, stream=True)
        b = autotune.search((16, 48, 48), model=model, stream=True)
        assert [r.cand for r in a] == [r.cand for r in b]
        assert all(r.cand.grid is not None for r in a), \
            "a stream must never rank a monolithic candidate"

    def test_enumeration_covers_the_issue_space(self):
        cands = autotune.enumerate_candidates((16, 64, 64), stream=True)
        assert any(c.async_engine for c in cands)
        assert any(not c.async_engine for c in cands)
        assert {c.codec for c in cands} == {"host", "device"}
        assert len({c.batch_cap for c in cands}) > 1
        assert len({c.grid for c in cands}) > 3
        assert any(c.q_out_units for c in cands if c.async_engine)


# ----------------------------------------------------------------------
# byte identity
# ----------------------------------------------------------------------

class TestByteIdentity:
    def test_autotuned_equals_hand_configured_plan(self):
        u, v = _field((6, 32, 32))
        cfg = CompressionConfig(eb=1e-2, track_index=False)
        tuned = autotune.tune_config(u, v, cfg,
                                     table=_synthetic_table(),
                                     measure=False)
        blob_auto, _ = compress(u, v, tuned)
        # the same plan, configured by hand from the report
        hand = dataclasses.replace(tuned)
        if hand.tiling is None:
            blob_hand, _ = compress(u, v, hand)
        else:
            blob_hand, _ = tiling.compress_tiled(u, v, hand, hand.tiling)
        assert blob_auto == blob_hand

    def test_compress_autotune_entry_point(self, monkeypatch):
        u, v = _field((4, 24, 24))
        monkeypatch.setattr(autotune, "load_or_calibrate",
                            lambda path=None: _synthetic_table())
        blob, stats = compress(u, v,
                               CompressionConfig(eb=1e-2,
                                                 track_index=False),
                               autotune=True)
        assert blob and stats["ratio"] > 0
        assert autotune.last_report() is not None
        assert "chosen" in autotune.explain()

    def test_scheduling_knobs_never_change_bytes(self):
        # batch_cap / queue bounds are pure scheduling: same plan,
        # different caps, identical container (DESIGN.md #15)
        u, v = _field((6, 32, 32))
        grid = tiling.TileGrid(tile_h=16, tile_w=16, window_t=3)
        base = CompressionConfig(eb=1e-2, backend="numpy",
                                 track_index=False)
        blobs = set()
        for cap in (1, 3, 8):
            cfg = dataclasses.replace(base, batch_cap=cap)
            blob, _ = tiling.compress_tiled(u, v, cfg, grid)
            blobs.add(blob)
        assert len(blobs) == 1

    def test_autotune_refused_on_resume(self):
        with pytest.raises(ValueError, match="resume"):
            tiling.compress_stream(iter(()), autotune=True, resume=True,
                                   value_range=(0.0, 1.0))


# ----------------------------------------------------------------------
# calibration-table versioning
# ----------------------------------------------------------------------

class TestTableVersioning:
    def _write(self, path, **overrides):
        payload = {
            "format": calibrate_mod.TABLE_FORMAT,
            "version": calibrate_mod.TABLE_VERSION,
            "device_kind": costmodel.device_kind(),
            "meta": {},
            "entries": [{"backend": "numpy", "stage": "derive_eb",
                         "c0": 1e-4, "c1": 1e-8}],
        }
        payload.update(overrides)
        path.write_text(json.dumps(payload))
        return str(path)

    def test_good_table_roundtrips(self, tmp_path):
        p = self._write(tmp_path / "ok.json")
        t = autotune.load_table(p)
        assert t.coeffs[("numpy", "derive_eb")] == (1e-4, 1e-8)

    def test_stale_version_refused_typed(self, tmp_path):
        p = self._write(tmp_path / "stale.json",
                        version=calibrate_mod.TABLE_VERSION + 1)
        with pytest.raises(autotune.CalibrationTableError) as ei:
            autotune.load_table(p)
        assert ei.value.reason == "stale"
        assert isinstance(ei.value, ValueError)

    def test_foreign_device_refused_typed(self, tmp_path):
        p = self._write(tmp_path / "foreign.json",
                        device_kind="not-this-hardware")
        with pytest.raises(autotune.CalibrationTableError) as ei:
            autotune.load_table(p)
        assert ei.value.reason == "foreign"

    def test_corrupt_table_refused_typed(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("{not json")
        with pytest.raises(autotune.CalibrationTableError) as ei:
            autotune.load_table(str(p))
        assert ei.value.reason == "corrupt"
        p2 = self._write(tmp_path / "badfmt.json", format="something")
        with pytest.raises(autotune.CalibrationTableError) as ei:
            autotune.load_table(p2)
        assert ei.value.reason == "corrupt"

    def test_refused_table_triggers_recalibration(self, tmp_path,
                                                  monkeypatch):
        p = self._write(tmp_path / "stale.json",
                        version=calibrate_mod.TABLE_VERSION + 1)
        fresh = _synthetic_table()
        called = {}

        def fake_calibrate(path=None, **kw):
            called["path"] = path
            return fresh

        monkeypatch.setattr(calibrate_mod, "calibrate", fake_calibrate)
        out = calibrate_mod.load_or_calibrate(p)
        assert out is fresh and called["path"] == p

    def test_saved_table_reloads_identically(self, table, tmp_path):
        # the module-scope real calibration: save/load is lossless
        assert table.version == calibrate_mod.TABLE_VERSION
        assert table.coeffs, "calibration fitted no coefficients"
        assert all(c1 >= 0 for _, c1 in table.coeffs.values())
        p = str(tmp_path / "roundtrip.json")
        autotune.save_table(table, p)
        reloaded = autotune.load_table(p, expect_kind=table.device_kind)
        assert reloaded.coeffs == table.coeffs
        assert reloaded.device_kind == table.device_kind
