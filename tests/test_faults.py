"""Deterministic fault-injection harness + the paths it hardens.

core/faults.py is the instrument the recovery tests are built on, so
its own semantics are pinned first (determinism, transient windows,
retry/backoff accounting).  Then the consumers: ContainerSource's
bounded retry, host_map's strict exception surfacing, and the async
engine's shutdown paths (worker failure with bounded queues at
capacity, watchdog stalls, thread death).
"""
import io
import time

import numpy as np
import pytest

from repro.core import CompressionConfig, TileGrid, compress_stream
from repro.core import encode
from repro.core import faults as faults_mod
from repro.core import stream_engine
from repro.core.faults import (
    FaultPlan,
    FaultPoint,
    InjectedFault,
    InjectedThreadDeath,
    retry_transient,
)
from repro.data import synthetic


GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)


def _field(T=10):
    u, v = synthetic.double_gyre(T=T, H=16, W=24)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    return list(zip(u, v)), vr


# ---------------------------------------------------------------- plan

def test_plan_fires_on_exact_call_number():
    plan = FaultPlan().io_error("x", nth=3)
    plan.check("x")
    plan.check("x")
    with pytest.raises(InjectedFault):
        plan.check("x")
    plan.check("x")                        # one-shot: later calls pass
    assert plan.calls("x") == 4
    assert plan.fired("x") == 1
    assert plan.log == [("x", "io_error", 3)]


def test_plan_sites_are_independent():
    plan = FaultPlan().io_error("a", nth=1)
    plan.check("b")                        # different site: no fire
    with pytest.raises(InjectedFault):
        plan.check("a")


def test_transient_window_then_success():
    plan = FaultPlan().io_error("x", nth=2, transient=2)
    plan.check("x")
    for _ in range(3):                     # calls 2, 3, 4 raise
        with pytest.raises(InjectedFault):
            plan.check("x")
    plan.check("x")                        # call 5 succeeds
    assert plan.fired("x") == 3


def test_spread_is_seed_deterministic():
    a = [FaultPlan(seed=7).spread(1, 100) for _ in range(5)]
    b = [FaultPlan(seed=7).spread(1, 100) for _ in range(5)]
    assert a == b
    assert all(1 <= x <= 100 for x in a)


def test_thread_death_is_not_an_exception():
    assert not issubclass(InjectedThreadDeath, Exception)
    plan = FaultPlan().thread_death("x")
    with pytest.raises(InjectedThreadDeath):
        plan.check("x")


def test_stall_sleeps():
    plan = FaultPlan().stall("x", seconds=0.05)
    t0 = time.monotonic()
    plan.check("x")
    assert time.monotonic() - t0 >= 0.05


def test_fault_point_nullable():
    fpt = FaultPoint(None)
    fpt.check("anything")                  # no-op, no raise
    assert not fpt
    assert FaultPoint(FaultPlan())


# ------------------------------------------------------------- retry

def test_retry_transient_recovers_and_counts():
    plan = FaultPlan().io_error("x", nth=1, transient=1)
    notes = []
    out = retry_transient(lambda: (plan.check("x"), "ok")[1],
                          retries=3, backoff=0,
                          on_retry=lambda n, e: notes.append(n))
    assert out == "ok"
    assert notes == [1, 2]


def test_retry_transient_bounded_reraises_original():
    plan = FaultPlan().io_error("x", nth=1, transient=99)
    with pytest.raises(InjectedFault):
        retry_transient(lambda: plan.check("x"), retries=2, backoff=0)
    assert plan.calls("x") == 3            # 1 try + 2 retries, no more


def test_retry_never_swallows_thread_death():
    plan = FaultPlan().thread_death("x")
    with pytest.raises(InjectedThreadDeath):
        retry_transient(lambda: plan.check("x"), retries=5, backoff=0)
    assert plan.calls("x") == 1            # not retried


# ------------------------------------------- ContainerSource consumers

@pytest.fixture(scope="module")
def container():
    u, v = synthetic.double_gyre(T=6, H=16, W=24)
    from repro.core import compress_tiled

    blob, _ = compress_tiled(u, v, CompressionConfig(track_index=True),
                             GRID)
    return blob


def test_source_retries_transient_reads(container, tmp_path):
    from repro.analysis.query import ContainerSource

    p = tmp_path / "c.cptt"
    p.write_bytes(container)
    plan = FaultPlan().io_error("source.read", nth=1, transient=1)
    src = ContainerSource(str(p), faults=plan, retries=2, backoff=0)
    hdr = src.header()                     # survives the fault window
    assert hdr["units"]
    assert src.retried == 2
    src.close()


def test_source_exhausted_retries_raise_typed(container):
    from repro.analysis.query import ContainerSource

    plan = FaultPlan().io_error("source.read", nth=1, transient=99)
    src = ContainerSource(container, faults=plan, retries=1, backoff=0)
    with pytest.raises(InjectedFault):
        src.read(0, 5)


def test_host_pool_worker_fault_reaches_caller(container, tmp_path):
    """A read fault on a pool worker thread must propagate to the
    caller as the original typed error -- pools that swallow worker
    exceptions turn damaged containers into silent short output."""
    from repro.analysis.query import ContainerSource

    p = tmp_path / "c.cptt"
    p.write_bytes(container)
    hdr = ContainerSource(container).header()
    assert len(hdr["units"]) > 1           # so read_many takes the pool
    plan = FaultPlan().io_error("source.read", nth=3)
    src = ContainerSource(str(p), faults=plan)
    with pytest.raises(OSError):
        src.read_many(hdr["units"])
    src.close()


def test_host_map_waits_everyone_raises_first():
    from repro.parallel.sharding import host_map, host_pool

    done = []

    def work(i):
        if i == 2:
            raise KeyError("boom")
        time.sleep(0.01)
        done.append(i)
        return i

    with pytest.raises(KeyError):
        host_map(host_pool("test-host-map", 4), work, range(6))
    assert sorted(done) == [0, 1, 3, 4, 5]  # later items still ran


# ------------------------------------------------- async engine paths

def test_compute_failure_with_full_writer_queue_no_deadlock():
    """Regression: an exception on the caller/compute side while the
    writer queue sits at capacity used to deadlock shutdown.  The
    engine must drain/poison its bounded queues and re-raise within
    the watchdog budget."""
    pairs, vr = _field(T=10)
    plan = FaultPlan().io_error("stream.compute", nth=8)
    t0 = time.monotonic()
    with pytest.raises(InjectedFault):
        compress_stream(iter(pairs), CompressionConfig(), GRID,
                        value_range=vr, sink=io.BytesIO(),
                        async_engine=True, faults=plan,
                        stage_timeout=30.0)
    assert time.monotonic() - t0 < 30.0


def test_writer_thread_fault_propagates(tmp_path):
    pairs, vr = _field(T=10)
    plan = FaultPlan().io_error("stream.write", nth=2)
    with pytest.raises(InjectedFault):
        compress_stream(iter(pairs), CompressionConfig(), GRID,
                        value_range=vr,
                        sink=str(tmp_path / "w.cptt"),
                        async_engine=True, faults=plan)


def test_ingest_thread_death_propagates():
    pairs, vr = _field(T=10)
    plan = FaultPlan().thread_death("stream.ingest", nth=3)
    with pytest.raises(InjectedThreadDeath):
        compress_stream(iter(pairs), CompressionConfig(), GRID,
                        value_range=vr, sink=io.BytesIO(),
                        async_engine=True, faults=plan)


def test_writer_stall_trips_watchdog():
    pairs, vr = _field(T=10)
    # the stall must outlive compute even on a loaded machine, or the
    # writer wakes before the watchdog looks and the run succeeds; the
    # stalled writer is a daemon thread, so the test itself returns as
    # soon as the watchdog trips (~stage_timeout), not after 60s
    plan = FaultPlan().stall("stream.write", seconds=60.0, nth=1)
    with pytest.raises(stream_engine.EngineStallError):
        compress_stream(iter(pairs), CompressionConfig(), GRID,
                        value_range=vr, sink=io.BytesIO(),
                        async_engine=True, faults=plan,
                        stage_timeout=0.2)
