"""CPTT1 track index: query roundtrip + footer forward-compat.

The acceptance bar: ``decode_for_track`` on a >= 8-unit tiled blob must
decode STRICTLY FEWER units than the full field and return a polyline
bit-identical (node coordinates, connectivity, types) to extraction
from a monolithic full decode; and blobs written with the index must
keep decoding identically on readers that ignore the new footer
section (old-reader simulation).
"""
import copy

import numpy as np
import pytest

from repro import analysis
from repro.core import (
    CompressionConfig,
    TileGrid,
    compress_stream,
    compress_tiled,
    decompress_tiled,
    encode,
    fixedpoint,
)
from repro.data import synthetic


def _make_blob(track_index=True, predictor="mop"):
    u, v = synthetic.double_gyre(T=8, H=20, W=28)
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor=predictor,
                            fused=True, track_index=track_index,
                            dt=0.1, dx=2.0 / 27, dy=1.0 / 19)
    grid = TileGrid(tile_h=10, tile_w=14, window_t=4)
    blob, stats = compress_tiled(u, v, cfg, grid)
    return u, v, blob, stats


@pytest.fixture(scope="module")
def indexed():
    return _make_blob(track_index=True)


def test_query_roundtrip_bit_identical(indexed):
    """decode_for_track == full-decode extraction, track by track."""
    u, v, blob, stats = indexed
    assert stats["n_units"] >= 8
    ur, vr = decompress_tiled(blob)
    ufp, vfp = fixedpoint.refix(ur, vr, stats["scale"])
    full = analysis.extract(ufp, vfp)
    assert full.n_tracks == len(analysis.track_summaries(blob))
    for k in range(full.n_tracks):
        res = analysis.decode_for_track(blob, k)
        ref = full.track(k)
        assert res.units_read < res.units_total, \
            "feature decode read the whole field"
        assert np.array_equal(res.track.face_ids, ref.face_ids)
        assert np.array_equal(res.track.nodes, ref.nodes)  # bitwise
        assert np.array_equal(res.track.types, ref.types)
        assert res.track.is_loop == ref.is_loop


def test_read_plan_matches_decode(indexed):
    _, _, blob, _ = indexed
    hdr = encode.tiled_header(blob)
    for s in analysis.track_summaries(blob):
        k = s["track_id"]
        plan = analysis.track_read_plan(blob, k)
        res = analysis.decode_for_track(blob, k)
        assert plan == res.entries
        assert 0 < len(plan) < len(hdr["units"])
        assert res.bytes_read == sum(e["len"] for e in plan)
        assert res.bytes_read < len(blob)


def test_query_filters(indexed):
    _, _, blob, _ = indexed
    T, H, W = 8, 20, 28
    allt = analysis.track_summaries(blob)
    centers = analysis.query_tracks(blob, cp_type="center")
    saddles = analysis.query_tracks(blob, cp_type="saddle")
    assert {s["track_id"] for s in centers} \
        | {s["track_id"] for s in saddles} \
        == {s["track_id"] for s in allt}
    assert len(centers) == 2 and len(saddles) == 2
    # spatial filter: the left gyre core only
    left = analysis.query_tracks(blob, bbox=(5, H - 6, 0, W / 2 - 3),
                                 cp_type="center")
    assert len(left) == 1
    # time filter: everything lives through the whole window
    assert len(analysis.query_tracks(blob, trange=(0, 1))) == len(allt)
    assert analysis.query_tracks(blob, trange=(T + 5, T + 9)) == []
    with pytest.raises(ValueError, match="unknown cp_type"):
        analysis.query_tracks(blob, cp_type="vortexx")


def test_streaming_blob_carries_same_index(indexed):
    u, v, blob, _ = indexed
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                            fused=True, dt=0.1, dx=2.0 / 27, dy=1.0 / 19)
    grid = TileGrid(tile_h=10, tile_w=14, window_t=4)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    blob_s, _ = compress_stream(
        ((u[t], v[t]) for t in range(u.shape[0])), cfg, grid,
        value_range=vr)
    assert blob_s == blob  # bytes, index included


def test_no_index_is_a_clear_error():
    _, _, blob, _ = _make_blob(track_index=False)
    with pytest.raises(ValueError, match="no track index"):
        analysis.track_summaries(blob)
    with pytest.raises(ValueError, match="no track index"):
        analysis.decode_for_track(blob, 0)


def test_index_does_not_perturb_units_or_decode():
    """The sidecar index must be purely additive: same unit bytes, same
    directory offsets, same decoded field as an index-less blob."""
    _, _, blob_on, _ = _make_blob(track_index=True)
    _, _, blob_off, _ = _make_blob(track_index=False)
    h_on = encode.tiled_header(blob_on)
    h_off = encode.tiled_header(blob_off)
    assert h_on["units"] == h_off["units"]       # offsets + lengths
    last = max(e["off"] + e["len"] for e in h_on["units"])
    assert blob_on[:last] == blob_off[:last]     # unit bytes identical
    a = decompress_tiled(blob_on)
    b = decompress_tiled(blob_off)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def test_old_reader_skips_footer_section(indexed):
    """Simulate a pre-index reader: strip the unknown footer key and
    re-pack the footer -- the decode must be unchanged, proving no
    decode path depends on the new section."""
    _, _, blob, _ = indexed
    hdr = encode.tiled_header(blob)
    assert encode.TRACK_INDEX_KEY in hdr
    stripped = copy.deepcopy(hdr)
    units = stripped.pop("units")
    stripped.pop(encode.TRACK_INDEX_KEY)
    # rebuild a footer without the index on top of the same unit bytes
    import msgpack
    import struct
    import zlib
    stripped["units"] = units
    last = max(e["off"] + e["len"] for e in units)
    raw = zlib.compress(msgpack.packb(stripped, use_bin_type=True), 6)
    doctored = blob[:last] + raw + struct.pack("<I", len(raw)) \
        + encode.MAGIC_TILED
    a = decompress_tiled(blob)
    b = decompress_tiled(doctored)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def test_future_index_version_refused(indexed):
    _, _, blob, _ = indexed
    hdr = encode.tiled_header(blob)
    section = copy.deepcopy(hdr[encode.TRACK_INDEX_KEY])
    section["version"] = 99
    with pytest.raises(ValueError, match="track index version 99"):
        analysis.TrackIndex(section)


def test_path_source_uses_range_reads(tmp_path, indexed):
    """A path source must answer queries with seek-based range reads
    (footer + covering units), matching the bytes-source results."""
    _, _, blob, _ = indexed
    p = tmp_path / "field.cptt1"
    p.write_bytes(blob)
    assert analysis.track_summaries(str(p)) == analysis.track_summaries(blob)
    k = analysis.track_summaries(blob)[0]["track_id"]
    assert analysis.track_read_plan(str(p), k) == \
        analysis.track_read_plan(blob, k)
    a = analysis.decode_for_track(str(p), k)
    b = analysis.decode_for_track(blob, k)
    assert np.array_equal(a.track.nodes, b.track.nodes)
    assert a.bytes_read == b.bytes_read < len(blob)


def test_repeated_query_hits_unit_cache(tmp_path, indexed):
    """Acceptance: the second identical query is served from the
    decoded-unit cache -- STRICTLY fewer range reads (only the three
    footer reads), every covering unit a cache hit, same polyline."""
    from repro.analysis import query as query_mod

    _, _, blob, _ = indexed
    p = tmp_path / "field.cptt1"
    p.write_bytes(blob)
    query_mod.unit_cache.clear()
    cold = analysis.decode_for_track(str(p), 0)
    warm = analysis.decode_for_track(str(p), 0)
    assert warm.range_reads < cold.range_reads
    assert warm.bytes_fetched < cold.bytes_fetched
    assert cold.cache_hits == 0
    assert warm.cache_hits == warm.units_read > 0
    # the logical plan accounting is unchanged by caching
    assert warm.bytes_read == cold.bytes_read
    assert warm.entries == cold.entries
    assert np.array_equal(warm.track.nodes, cold.track.nodes)
    # the cache is content-addressed: the same container as BYTES hits
    # the entries populated through the path source
    from_bytes = analysis.decode_for_track(blob, 0)
    assert from_bytes.cache_hits == from_bytes.units_read


def test_overlapping_queries_share_units(indexed):
    """Tracks with overlapping covering sets re-decode nothing for the
    shared units."""
    from repro.analysis import query as query_mod

    _, _, blob, _ = indexed
    query_mod.unit_cache.clear()
    plans = {s["track_id"]: analysis.track_read_plan(blob, s["track_id"])
             for s in analysis.track_summaries(blob)}
    ids = sorted(plans)
    offs = [{e["off"] for e in plans[k]} for k in ids]
    shared = offs[0].intersection(*offs[1:]) if len(offs) > 1 else set()
    seen = set()
    for k in ids:
        res = analysis.decode_for_track(blob, k)
        expected_hits = len({e["off"] for e in plans[k]} & seen)
        assert res.cache_hits == expected_hits
        seen |= {e["off"] for e in plans[k]}
    if shared:  # double-gyre tracks do share covering units
        assert any(res.cache_hits for k in ids[1:]
                   for res in [analysis.decode_for_track(blob, k)])


def test_unit_cache_bounded_and_disablable(indexed):
    from repro.analysis import query as query_mod

    _, _, blob, _ = indexed
    cache = query_mod.configure_unit_cache(0)     # disabled
    try:
        a = analysis.decode_for_track(blob, 0)
        b = analysis.decode_for_track(blob, 0)
        assert a.cache_hits == 0 and b.cache_hits == 0
        assert cache.stats()["entries"] == 0
        # tiny budget: the cache must stay within max_bytes
        query_mod.configure_unit_cache(0.02)      # ~20 KB
        analysis.decode_for_track(blob, 0)
        st = cache.stats()
        assert st["bytes"] <= st["max_bytes"]
    finally:
        query_mod.configure_unit_cache(256)


def test_region_decode_uses_cache(indexed):
    """decompress_region stops re-reading/re-decoding covering units on
    repeated queries (served through the same unit cache)."""
    from repro.core import decompress_region

    from repro.analysis import query as query_mod

    _, _, blob, _ = indexed
    query_mod.unit_cache.clear()
    region = (0, 2, 0, 8, 0, 8)
    r1 = decompress_region(blob, region)
    s1 = query_mod.unit_cache.stats()
    r2 = decompress_region(blob, region)
    s2 = query_mod.unit_cache.stats()
    assert s2["misses"] == s1["misses"]       # nothing re-decoded
    assert s2["hits"] > s1["hits"]
    assert np.array_equal(r1[0], r2[0]) and np.array_equal(r1[1], r2[1])


def test_lorenzo_predictor_roundtrip():
    """Same guarantee under the pure-Lorenzo predictor."""
    u, v, blob, stats = _make_blob(predictor="lorenzo")
    ur, vr = decompress_tiled(blob)
    ufp, vfp = fixedpoint.refix(ur, vr, stats["scale"])
    full = analysis.extract(ufp, vfp)
    for k in range(full.n_tracks):
        res = analysis.decode_for_track(blob, k)
        assert np.array_equal(res.track.nodes, full.track(k).nodes)
        assert res.units_read < res.units_total
