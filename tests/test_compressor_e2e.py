"""End-to-end compressor guarantees (paper Sec. IV constraints)."""
import numpy as np
import pytest

from repro.core import CompressionConfig, compress, decompress, metrics
from repro.core import fixedpoint, trajectory
from repro.data import synthetic


@pytest.mark.parametrize("predictor", ["lorenzo", "sl", "mop"])
def test_roundtrip_guarantees(small_field, predictor):
    u, v = small_field
    cfg = CompressionConfig(eb=5e-3, mode="rel", predictor=predictor,
                            dt=0.1, dx=2.0 / 27, dy=1.0 / 19)
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    assert ur.shape == u.shape and ur.dtype == np.float32
    # (a) pointwise error constraint
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]
    # (b) every face predicate preserved -> FC_t = FC_s = 0
    fc = trajectory.false_cases(u, v, ur, vr, stats["scale"])
    assert fc["FC_t"] == 0 and fc["FC_s"] == 0
    assert fc["CP_t_orig"] == fc["CP_t_rec"]
    assert fc["CP_slab_orig"] == fc["CP_slab_rec"]


@pytest.mark.parametrize("eb", [1e-4, 1e-3, 1e-2, 1e-1])
def test_eb_sweep_preserves_trajectories(advective_field, eb):
    u, v = advective_field
    cfg = CompressionConfig(eb=eb, mode="rel", predictor="mop",
                            dt=0.05, dx=2.0 / 47, dy=1.0 / 31)
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    uo, vo = fixedpoint.refix(u, v, stats["scale"])
    ud, vd = fixedpoint.refix(ur, vr, stats["scale"])
    t0 = trajectory.extract_tracks(uo, vo)
    t1 = trajectory.extract_tracks(ud, vd)
    assert t0 == t1  # identical track graph statistics


def test_deterministic_bytes(small_field):
    u, v = small_field
    cfg = CompressionConfig(eb=1e-3, mode="rel")
    b1, _ = compress(u, v, cfg)
    b2, _ = compress(u, v, cfg)
    assert b1 == b2


def test_higher_eb_higher_ratio(advective_field):
    u, v = advective_field
    ratios = []
    for eb in [1e-4, 1e-2]:
        cfg = CompressionConfig(eb=eb, mode="rel")
        _, stats = compress(u, v, cfg)
        ratios.append(stats["ratio"])
    assert ratios[1] > ratios[0]


def test_abs_mode(small_field):
    u, v = small_field
    cfg = CompressionConfig(eb=1e-4, mode="abs")
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= 1e-4


def test_metrics_suite(small_field):
    u, v = small_field
    cfg = CompressionConfig(eb=1e-3, mode="rel")
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    m = metrics.evaluate(u, v, ur, vr, stats["scale"],
                         stats["orig_bytes"], stats["comp_bytes"])
    assert m["FC_t"] == 0 and m["FC_s"] == 0
    assert m["n_traj_orig"] == m["n_traj_rec"]
    assert m["CR"] > 1.0 and np.isfinite(m["PSNR"])


def test_rejects_bad_shapes():
    # typed errors (not asserts): must hold under python -O
    with pytest.raises(ValueError, match=r"\(T, H, W\)"):
        compress(np.zeros((4, 4)), np.zeros((4, 4)))
    with pytest.raises(ValueError, match="2x2x2"):
        compress(np.zeros((1, 4, 4)), np.zeros((1, 4, 4)))


def test_pathological_fields_still_exact():
    """Fields full of zeros / ties exercise the SoS degeneracy paths."""
    rng = np.random.default_rng(7)
    T, H, W = 4, 10, 10
    u = rng.integers(-2, 3, (T, H, W)).astype(np.float32) * 0.25
    v = rng.integers(-2, 3, (T, H, W)).astype(np.float32) * 0.25
    u[1] = 0.0           # a whole zero frame
    v[:, :, 3] = 0.0
    cfg = CompressionConfig(eb=0.05, mode="abs")
    blob, stats = compress(u, v, cfg)
    ur, vr = decompress(blob)
    fc = trajectory.false_cases(u, v, ur, vr, stats["scale"])
    assert fc["FC_t"] == 0 and fc["FC_s"] == 0
    assert np.abs(ur - u).max() <= stats["eb_abs"]
