"""Observability smoke: the ISSUE acceptance run, end to end.

Run as:  REPRO_OBS=1 PYTHONPATH=src python tests/obs_trace_smoke.py

With observability enabled, one streamed compression (async engine,
filesystem sink so the journal is live) plus one track query must
produce:

  * a valid Chrome-trace JSON (loads as ``{"traceEvents": [...]}``,
    Perfetto-compatible) containing spans for all three engine stages
    on distinct threads, with queue-depth counter events for both
    handoff queues;
  * a registry snapshot covering pipeline, engine, journal, cache and
    retry metrics;
  * a container byte-identical to an obs-off run of the same input.

The in-suite tests (tests/test_obs.py) cover each piece in isolation;
this leg proves they compose in one process the way the README's
Perfetto walkthrough describes.
"""
import json
import os
import sys
import tempfile

import numpy as np

FAILURES = []


def need(cond, msg):
    if not cond:
        FAILURES.append(msg)
        print(f"obs_trace_smoke: FAIL: {msg}", file=sys.stderr)


def main() -> int:
    from repro import analysis, obs
    from repro.core import CompressionConfig, TileGrid, compress_tiled
    from repro.core import faults as faults_mod
    from repro.core.tiling import compress_stream
    from repro.data import synthetic
    from repro.obs import trace

    T, H, W = 10, 24, 32
    u, v = synthetic.double_gyre(T=T, H=H, W=W)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    cfg = CompressionConfig(track_index=True)
    grid = TileGrid(tile_h=8, tile_w=12, window_t=3)

    # reference container with observability hard-off
    obs.disable()
    ref, _ = compress_tiled(u, v, cfg, grid)

    obs.enable()
    trace.reset()
    with tempfile.TemporaryDirectory() as td:
        sink = os.path.join(td, "smoke.cptt")

        # one streamed compression on the async engine, journal live
        _, stats = compress_stream(list(zip(u, v)), cfg, grid,
                                   value_range=vr, sink=sink,
                                   async_engine=True)
        with open(sink, "rb") as f:
            got = f.read()
        need(got == ref,
             f"streamed obs-on container differs from obs-off run "
             f"({len(got)} vs {len(ref)} bytes)")

        # one track query, cold then warm (cache miss then hit)
        snap0 = obs.snapshot()
        res_cold = analysis.decode_for_track(sink, 0)
        res_warm = analysis.decode_for_track(sink, 0)
        need(res_cold.units_read >= 1, "track query decoded no units")
        need(res_warm.cache_hits > 0,
             "warm repeat of the track query missed the unit cache")

        # a recovered transient failure at a real retry site
        plan = faults_mod.FaultPlan().io_error("source.read", nth=1,
                                               transient=1)
        with analysis.ContainerSource(sink, faults=plan,
                                      retries=2) as src:
            src.read(0, 8)
            need(src.retried >= 1,
                 "transient fault was not retried/recovered")

        # ---- trace export: Chrome trace-event JSON ----
        trace_path = os.path.join(td, "trace.json")
        n = obs.export_trace(trace_path)
        need(n > 0, "export_trace wrote no events")
        with open(trace_path) as f:
            payload = json.load(f)
        need(set(payload) == {"traceEvents", "displayTimeUnit"},
             f"trace top-level keys wrong: {sorted(payload)}")
        evs = payload["traceEvents"]
        by_name = {}
        for e in evs:
            by_name.setdefault(e["name"], []).append(e)

        stage_tids = {}
        for stage in ("engine.ingest", "engine.compute", "engine.write"):
            spans = [e for e in by_name.get(stage, ())
                     if e["ph"] == "X"]
            need(spans, f"no {stage} spans in trace")
            stage_tids[stage] = {e["tid"] for e in spans}
        if all(stage_tids.get(s) for s in stage_tids):
            need(stage_tids["engine.ingest"].isdisjoint(
                     stage_tids["engine.compute"]),
                 "ingest and compute spans share a thread")
            need(stage_tids["engine.write"].isdisjoint(
                     stage_tids["engine.compute"]),
                 "write and compute spans share a thread")
        for qname in ("engine.q_in", "engine.q_out"):
            counters = [e for e in by_name.get(qname, ())
                        if e["ph"] == "C"]
            need(counters, f"no {qname} queue-depth counter events")
            need(all(e["args"]["depth"] >= 0 for e in counters),
                 f"{qname} counter event missing depth arg")
        need(len([e for e in by_name.get("engine.ingest", ())
                  if e["ph"] == "X"]) == T,
             "ingest span count != frame count")
        need(len([e for e in by_name.get("engine.write", ())
                  if e["ph"] == "X"]) == stats["n_units"],
             "write span count != unit count")
        bad = [e for e in evs
               if e["ph"] == "X" and "stack_corrupt" in e.get("args", {})]
        need(not bad, f"corrupt span stacks in trace: {bad[:3]}")
        need({"engine.ingest", "engine.writer", "engine.compute"} <=
             {e["args"]["name"] for e in evs if e["ph"] == "M"},
             "engine threads did not self-label")
        need(by_name.get("query.decode_for_track"),
             "no query.decode_for_track span")

        # ---- registry snapshot: all five metric families ----
        snap = obs.snapshot()
        for name in ("engine.units_emitted", "engine.frames_ingested",
                     "engine.units_written", "journal.fsync",
                     "journal.checkpoints", "cache.hits", "cache.misses",
                     "query.range_reads", "query.bytes_fetched",
                     "faults.retry.source.read.attempts",
                     "faults.retry.source.read.retries"):
            need(name in snap, f"snapshot missing {name}")
        need(any(k.startswith("pipeline.") for k in snap),
             "snapshot has no pipeline.* metrics")
        need(snap.get("journal.fsync", {}).get("value", 0) > 0,
             "journal fsyncs not counted on a sink-path run")
        need(snap.get("cache.misses", {}).get("value", 0)
             > snap0.get("cache.misses", {}).get("value", 0),
             "cold track query did not miss the unit cache")
        need(snap.get("faults.retry.source.read.retries", {})
             .get("value", 0) >= 1,
             "recovered retry invisible in the registry")
        st = faults_mod.retry_stats("source.read")
        need(st.get("last_outcome") == "ok",
             f"retry site outcome not ok: {st}")

    if not FAILURES:
        print(f"obs_trace_smoke: trace ok ({n} events), snapshot "
              f"covers pipeline/engine/journal/cache/retry, container "
              f"byte-identical ({len(ref)} bytes, "
              f"{stats['n_units']} units)")
    return 1 if FAILURES else 0


if __name__ == "__main__":
    sys.exit(main())
