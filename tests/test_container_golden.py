"""Container backward compatibility against a checked-in PR-1 blob.

``tests/data/golden_v2_mop.cptz`` was produced by the PR-1 (version-2,
monolithic fused) encoder; today's decoder must keep reading it bitwise
and the new tiled (version-3) directory format must not disturb legacy
detection.  The version byte is honored in both directions: containers
claiming a future version are refused instead of mis-parsed.
"""
import os

import numpy as np
import pytest

from repro.core import compress, decompress, encode
from repro.core.compressor import FORMAT_VERSION

_DATA = os.path.join(os.path.dirname(__file__), "data")


def _golden():
    with open(os.path.join(_DATA, "golden_v2_mop.cptz"), "rb") as f:
        blob = f.read()
    exp = np.load(os.path.join(_DATA, "golden_v2_expected.npz"))
    return blob, exp


def test_golden_v2_blob_decodes_bitwise():
    blob, exp = _golden()
    assert not encode.is_tiled(blob)          # legacy magic, legacy path
    header, _ = encode.unpack(blob)
    assert header["version"] == 2
    ur, vr = decompress(blob)
    assert np.array_equal(ur, exp["ur"])
    assert np.array_equal(vr, exp["vr"])
    assert np.abs(ur.astype(np.float64) - exp["u"]).max() <= exp["eb_abs"]
    assert np.abs(vr.astype(np.float64) - exp["v"]).max() <= exp["eb_abs"]


def test_current_encoder_still_writes_v2_monolithic():
    _, exp = _golden()
    blob, stats = compress(exp["u"], exp["v"])
    header, _ = encode.unpack(blob)
    assert header["version"] == FORMAT_VERSION == 2


def test_future_version_refused_legacy():
    blob, _ = _golden()
    header, sections = encode.unpack(blob)
    header = dict(header)
    header["version"] = 99
    doctored = encode.pack(header, {k: np.asarray(v)
                                    for k, v in sections.items()})
    with pytest.raises(ValueError, match="version 99"):
        decompress(doctored)


def test_future_version_refused_tiled():
    w = encode.TiledWriter()
    w.add_unit((0, 0, 0), (0, 1, 0, 1, 0, 1), {"box": [0, 1, 0, 1, 0, 1]},
               {"sym_u": np.zeros(1, np.uint8)})
    blob = w.finish({"version": 99, "shape": [2, 2, 2]})
    with pytest.raises(ValueError, match="version 99"):
        decompress(blob)


def test_track_index_rides_without_version_bump():
    """The PR-3 sidecar track index must not bump the tiled container
    version: old (PR-2) readers check ``version`` and would refuse a
    bump, but unknown footer KEYS are skipped cleanly.  This pins the
    index to the key-based extension path."""
    from repro.core import CompressionConfig, TileGrid, compress_tiled
    from repro.core.tiling import TILED_FORMAT_VERSION
    from repro.data import synthetic

    u, v = synthetic.double_gyre(T=4, H=10, W=14)
    blob, stats = compress_tiled(
        u, v, CompressionConfig(eb=1e-2, track_index=True),
        TileGrid(tile_h=5, tile_w=7, window_t=2))
    hdr = encode.tiled_header(blob)
    assert hdr["version"] == TILED_FORMAT_VERSION == 4
    assert encode.TRACK_INDEX_KEY in hdr
    # the index section is self-versioned instead
    assert hdr[encode.TRACK_INDEX_KEY]["version"] >= 1
    # and a reader that only knows the PR-2 keys still decodes it
    ur, vr = decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]


def test_golden_v3_tiled_blob_decodes_bitwise():
    """A checked-in pre-CRC (version-3) tiled container: the v4 reader
    must keep reading it bitwise.  v3 directory entries carry no
    ``crc`` and v3 frames have no per-unit preamble; the reader only
    verifies checksums when the entry advertises one, so old blobs
    decode exactly as before the bump."""
    from repro.core import decompress_tiled
    from repro.analysis import query

    with open(os.path.join(_DATA, "golden_v3_tiled.cptt"), "rb") as f:
        blob = f.read()
    exp = np.load(os.path.join(_DATA, "golden_v3_expected.npz"))
    hdr = encode.tiled_header(blob)
    assert hdr["version"] == 3
    assert all("crc" not in e for e in hdr["units"])
    ur, vr = decompress_tiled(blob)
    assert np.array_equal(ur, exp["ur"])
    assert np.array_equal(vr, exp["vr"])
    assert np.abs(ur.astype(np.float64) - exp["u"]).max() <= exp["eb_abs"]
    assert np.abs(vr.astype(np.float64) - exp["v"]).max() <= exp["eb_abs"]
    # track queries work across the version boundary too
    assert query.track_summaries(blob)


def test_golden_v3_salvage_refused_not_misparsed():
    """Pre-v4 containers have no self-describing unit preambles, so
    salvage must REFUSE them (typed error) rather than resync on
    accidental byte matches and fabricate units."""
    with open(os.path.join(_DATA, "golden_v3_tiled.cptt"), "rb") as f:
        blob = f.read()
    with pytest.raises(encode.ContainerError, match="pre-v4|version"):
        encode.salvage_container(blob[: len(blob) - 40])


def test_magics_disjoint():
    assert len({encode.MAGIC, encode.MAGIC_ZLIB, encode.MAGIC_TILED}) == 3
    blob, _ = _golden()
    assert blob[:5] in (encode.MAGIC, encode.MAGIC_ZLIB)
