"""No-accelerator autotune smoke (CI numpy leg).

End to end with REPRO_BACKEND=numpy and no calibration table on disk:
calibrate from obs spans, search the plan space, compress with the
chosen plan via ``compress(..., autotune=True)``, byte-diff the result
against the SAME plan configured by hand, and render the explain()
report.  Real raises, not asserts: the smoke must fail under -O too.

    PYTHONPATH=src python tests/autotune_smoke.py
"""
import os
import tempfile

import numpy as np

from repro import autotune
from repro.core import CompressionConfig, compress, decompress
from repro.core import tiling


def main():
    rng = np.random.default_rng(11)
    base = np.cumsum(rng.normal(size=(6, 32, 32)).astype(np.float32),
                     axis=0)
    u, v = base, base[::-1].copy()

    with tempfile.TemporaryDirectory() as td:
        # calibrate on the numpy backend only (the leg has no
        # accelerator; xla would only add compile time to the smoke)
        path = os.path.join(td, "calib.json")
        table = autotune.calibrate(backends=("numpy",), path=path,
                                   jit_cache=False)
        if not table.coeffs:
            raise SystemExit("calibration fitted no coefficients")
        reloaded = autotune.load_table(path)
        if reloaded.coeffs != table.coeffs:
            raise SystemExit("calibration table did not roundtrip")

        cfg = CompressionConfig(eb=1e-2, track_index=False,
                                backend="numpy")
        tuned = autotune.tune_config(u, v, cfg, table=reloaded)
        blob_auto, stats = compress(u, v, tuned)

        # byte-identity: the autotuned container must equal the same
        # plan run by hand (autotuning changes speed, never bytes)
        if tuned.tiling is None:
            blob_hand, _ = compress(u, v, tuned)
        else:
            blob_hand, _ = tiling.compress_tiled(u, v, tuned, tuned.tiling)
        if blob_auto != blob_hand:
            raise SystemExit("autotuned container diverged from the "
                             "hand-configured plan")
        ur, vr = decompress(blob_auto)
        if abs(ur.astype("float64") - u).max() > stats["eb_abs"]:
            raise SystemExit("autotuned container violated the bound")

        report = autotune.explain()
        if "chosen" not in report:
            raise SystemExit("explain() produced no chosen plan")
        print(report)

        # streaming entry point: autotune=True picks grid + engine.
        # Pre-seed the default table location so the stream tune loads
        # it instead of recalibrating from scratch mid-smoke.
        autotune.save_table(table)
        frames = [(u[t], v[t]) for t in range(u.shape[0])]
        blob_s, _ = tiling.compress_stream(frames, cfg, autotune=True)
        if not blob_s:
            raise SystemExit("autotuned stream produced no container")

    print("autotune smoke ok: chose", autotune.last_report()["chosen"])


if __name__ == "__main__":
    main()
