"""No-accelerator adaptive eb-policy smoke (CI numpy leg).

End to end with REPRO_BACKEND=numpy: build a track-aware policy
(tight bounds on trajectory-covering units, relaxed elsewhere),
compress, decode, and require the headline guarantees of DESIGN.md #16
-- FC_t = FC_s = 0, the extracted track set preserved exactly, a
strictly higher ratio than the uniform-tight baseline, byte-identical
containers for the uniform policy spellings, and a met
``compress(..., target_ratio=...)`` search.  Real raises, not asserts:
the smoke must fail under -O too.

    REPRO_BACKEND=numpy PYTHONPATH=src python tests/adaptive_smoke.py
"""
import dataclasses

import numpy as np

from repro import analysis
from repro.core import (CompressionConfig, compress, compressor,
                        decompress, ebpolicy, fixedpoint, trajectory)
from repro.core.ebpolicy import UniformPolicy
from repro.data import synthetic


def need(cond, what):
    if not cond:
        raise SystemExit(f"adaptive_smoke: {what}")


def _tracks(u, v):
    _, ufp, vfp = fixedpoint.to_fixed(u, v)
    traj = analysis.extract(ufp, vfp, backend="numpy", classify=False)
    return len(traj.tracks), sum(len(t.nodes) for t in traj.tracks)


def main():
    T, H, W = 8, 64, 64
    u, v = synthetic.double_gyre(T=T, H=H, W=W)
    tight, relaxed = 1e-3, 2e-1
    uni = CompressionConfig(eb=tight, mode="abs", backend="numpy",
                            fused=True)

    blob_u, st_u = compress(u, v, uni)
    blob_u2, _ = compress(u, v, dataclasses.replace(
        uni, eb_policy=UniformPolicy()))
    blob_u3, _ = compress(u, v, dataclasses.replace(
        uni, eb_policy="uniform"))
    need(blob_u == blob_u2 == blob_u3,
         "uniform policy spellings are not byte-identical")

    pol = analysis.track_aware_policy(u, v, tight=tight, relaxed=relaxed,
                                      window_t=4, tile_h=8, tile_w=8,
                                      backend="numpy")
    ad = dataclasses.replace(uni, eb_policy=pol,
                             n_levels=ebpolicy.levels_for(pol))
    blob_a, st_a = compress(u, v, ad)
    need(st_a["ratio"] > st_u["ratio"],
         f"adaptive ratio {st_a['ratio']:.3f} does not beat "
         f"uniform-tight {st_u['ratio']:.3f}")

    ur, vr = decompress(blob_a)
    fc = trajectory.false_cases(u, v, ur, vr, st_a["scale"])
    need(fc["FC_t"] == 0 and fc["FC_s"] == 0,
         f"false cases under the adaptive policy: {fc}")
    need(_tracks(u, v) == _tracks(ur, vr),
         "adaptive decode changed the extracted track set")

    target = round(st_u["ratio"] * 1.1, 3)
    blob_t, st_t = compressor.compress(u, v, uni, target_ratio=target)
    rt = st_t["rate_target"]
    need(rt["met"] and rt["achieved_ratio"] >= target,
         f"target-ratio search missed {target}: {rt}")
    ur2, vr2 = decompress(blob_t)
    need(_tracks(u, v) == _tracks(ur2, vr2),
         "target-ratio container changed the extracted track set")

    print(f"adaptive_smoke: ok (uniform {st_u['ratio']:.2f} -> adaptive "
          f"{st_a['ratio']:.2f}; target {target} met at relax "
          f"{rt['relax']}; FC_t=FC_s=0, tracks preserved)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
