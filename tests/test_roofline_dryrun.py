"""Roofline machinery: trip-count-aware HLO cost walker + dry-run
plumbing (tiny-mesh subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hlocost, roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_walker_counts_scan_trip_counts():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    c = hlocost.analyze_text(txt)
    want = 2 * 128**3 * 10
    assert abs(c.flops - want) / want < 0.01, (c.flops, want)
    assert any(t == 10 for _, t in c.loop_info)


def test_walker_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(g).lower(x, w).compile().as_text()
    c = hlocost.analyze_text(txt)
    want = 2 * 64**3 * 15
    assert abs(c.flops - want) / want < 0.02


def test_walker_does_not_overcharge_scan_slices():
    """A scan reading tiny slices of a big stacked xs must not be billed
    the full buffer per iteration (the H6 accounting bug)."""
    def f(xs):
        def body(c, x_t):
            return c + x_t, None
        out, _ = jax.lax.scan(body, jnp.zeros((128,), jnp.float32), xs)
        return out

    xs = jax.ShapeDtypeStruct((1000, 128), jnp.float32)
    txt = jax.jit(f).lower(xs).compile().as_text()
    c = hlocost.analyze_text(txt)
    # true traffic ~ read xs once + carry updates: << 10 x buffer size
    assert c.bytes < 10 * 1000 * 128 * 4, c.bytes


def test_shape_bytes_parsing():
    assert hlocost.shape_bytes("f32[2,3]{1,0}") == 24
    assert hlocost.shape_bytes("bf16[8]") == 16
    assert hlocost.shape_bytes("(f32[2], s8[4,4])") == 24
    assert hlocost.shape_bytes("pred[10]") == 10


def test_collective_parsing():
    txt = """
ENTRY %main.1 (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%add
}
"""
    c = hlocost.analyze_text(txt)
    assert c.collective_bytes == 256
    assert c.coll_breakdown.get("all-reduce") == 256


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(
        arch="a", shape="s", mesh="m", n_chips=4,
        flops_per_device=197e12, bytes_per_device=819e9 * 2,
        coll_bytes_per_device=50e9 * 0.5, coll_breakdown={},
        model_flops=197e12 * 4 * 0.5, memory_report={},
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.roofline_fraction - 0.5) < 1e-9
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9


def test_dryrun_cell_on_tiny_mesh():
    """Lower+compile one real cell end-to-end in a 512-device subprocess
    (the actual deliverable path, smallest arch, single shape)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1_5_0_5b", "--shape", "decode_32k", "--mesh", "single"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1 ok, 0 skip, 0 fail" in r.stdout


def test_model_flops_formulas():
    import repro.configs as C

    mod = C.get("yi_6b")
    cell = mod.CELLS["train_4k"]
    mf = roofline.model_flops(mod.CONFIG, cell)
    want = 6.0 * mod.CONFIG.param_count() * 256 * 4096
    assert abs(mf - want) / want < 1e-6
    cell = mod.CELLS["decode_32k"]
    mf = roofline.model_flops(mod.CONFIG, cell)
    assert mf == 2.0 * mod.CONFIG.param_count() * 128
