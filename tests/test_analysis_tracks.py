"""Trajectory analytics: geometry, classification, device CCL parity.

Oracles come from the analytic structure of the synthetic fields:

* double_gyre: two gyre cores (divergence-free rotation -> ``center``)
  at domain (x, y) ~ (0.5, 0.5) and (1.5, 0.5), plus two boundary-row
  saddles near x ~ 1.0 -- four tracks alive for the whole window.
* vortex_street: Oseen vortex cores advecting downstream (+x) typed as
  centers/spirals, with saddles between them.

Device-vs-host parity: the pointer-jumping connected-component
labeling (backend.connected_labels, xla + numpy) must produce the same
partition as the reference host union-find on every synthetic field.
"""
import numpy as np
import pytest

from repro import analysis
from repro.analysis import classify, extraction, model
from repro.core import backend as backend_mod
from repro.core import fixedpoint, trajectory
from repro.data import synthetic


def _field(name):
    return {
        "double_gyre": lambda: synthetic.double_gyre(T=6, H=20, W=28),
        "vortex_street": lambda: synthetic.vortex_street(T=6, H=24, W=36),
        "heated_plume": lambda: synthetic.heated_plume(T=5, H=32, W=16),
        "turbulence": lambda: synthetic.turbulence(T=5, H=24, W=24),
    }[name]()


def _fixed(name):
    u, v = _field(name)
    scale, ufp, vfp = fixedpoint.to_fixed(u, v)
    return ufp, vfp


# ----------------------------------------------------------------------
# classification oracles
# ----------------------------------------------------------------------

def test_double_gyre_classification_oracle():
    ufp, vfp = _fixed("double_gyre")
    T, H, W = ufp.shape
    ts = analysis.extract(ufp, vfp)
    assert ts.n_tracks == 4
    centers = [t for t in ts.tracks if t.dominant_type == "center"]
    saddles = [t for t in ts.tracks if t.dominant_type == "saddle"]
    assert len(centers) == 2 and len(saddles) == 2
    # gyre cores sit at mid-height, near domain x = 0.5 and 1.5
    # (grid x = j / (W-1) * 2), and live for the whole window
    xs = sorted(t.nodes[:, 2].mean() / (W - 1) * 2.0 for t in centers)
    assert abs(xs[0] - 0.5) < 0.2 and abs(xs[1] - 1.5) < 0.2
    for t in centers:
        assert abs(t.nodes[:, 1].mean() / (H - 1) - 0.5) < 0.05
        assert t.t_min == 0.0 and t.t_max == T - 1
        assert t.events(T) == {"birth": "domain_start",
                               "death": "domain_end"}
    # boundary saddles on the y = 0 / y = H-1 rows near domain x = 1
    rows = sorted(t.nodes[:, 1].mean() for t in saddles)
    assert rows[0] == 0.0 and rows[1] == H - 1
    for t in saddles:
        assert abs(t.nodes[:, 2].mean() / (W - 1) * 2.0 - 1.0) < 0.15
        # every node of a saddle track is typed saddle (det < 0 is
        # robust -- no tolerance involved)
        assert (t.types == model.CP_CODE["saddle"]).all()


def test_vortex_street_classification_oracle():
    ufp, vfp = _fixed("vortex_street")
    ts = analysis.extract(ufp, vfp)
    rotating = {model.CP_CODE[n] for n in
                ("center", "spiral_in", "spiral_out")}
    cores = [t for t in ts.tracks
             if len(t.face_ids) >= 10
             and model.CP_CODE[t.dominant_type] in rotating]
    saddles = [t for t in ts.tracks if t.dominant_type == "saddle"
               and len(t.face_ids) >= 10]
    assert len(cores) >= 4, ts.summary()
    assert len(saddles) >= 2, ts.summary()
    for t in cores:
        # vortices advect downstream with the carrier flow
        assert t.nodes[-1, 2] > t.nodes[0, 2]
        # and the polyline is time-monotone (one CP tracked through time)
        assert (np.diff(t.nodes[:, 0]) >= 0).all()


def test_node_geometry_inside_faces():
    ufp, vfp = _fixed("double_gyre")
    T, H, W = ufp.shape
    ts = analysis.extract(ufp, vfp)
    assert len(ts.nodes)
    from repro.core import grid as mesh
    verts = mesh.face_vertices(ts.face_ids, H, W)
    HW = H * W
    tv, iv, jv = verts // HW, (verts % HW) // W, verts % W
    # barycentric weights of a crossed face are a convex combination
    for col, lo, hi in ((0, tv.min(1), tv.max(1)),
                        (1, iv.min(1), iv.max(1)),
                        (2, jv.min(1), jv.max(1))):
        assert (ts.nodes[:, col] >= lo - 1e-9).all()
        assert (ts.nodes[:, col] <= hi + 1e-9).all()


def test_classify_analytic_jacobians():
    # synthetic single-cell fields with known Jacobians at the center
    base_u = np.zeros((2, 2, 2))
    base_v = np.zeros((2, 2, 2))
    yy, xx = np.meshgrid([-0.5, 0.5], [-0.5, 0.5], indexing="ij")
    cases = {
        "saddle": (xx, -yy),
        "source": (xx, yy),
        "sink": (-xx, -yy),
        "center": (-yy, xx),
        "spiral_out": (0.2 * xx - yy, xx + 0.2 * yy),
        "spiral_in": (-0.2 * xx - yy, xx - 0.2 * yy),
    }
    for name, (uu, vv) in cases.items():
        u = base_u + uu[None]
        v = base_v + vv[None]
        code = classify.classify_nodes(
            u, v, np.array([[0.5, 0.5, 0.5]]))[0]
        assert model.CP_TYPES[code] == name, (name, model.CP_TYPES[code])


# ----------------------------------------------------------------------
# device CCL vs host union-find partition parity
# ----------------------------------------------------------------------

def _host_partition(ufp, vfp):
    """Reference union-find partition: node fid -> canonical group."""
    shape = ufp.shape
    T = shape[0]
    tables = trajectory.face_predicate_tables(ufp, vfp)
    uf = trajectory._UnionFind()
    edges = []
    for lo in range(0, T - 1):
        crossed = trajectory.tet_crossings(tables, shape, lo, lo + 1)
        e = trajectory.segment_edges(crossed, lo, shape)
        edges.append(e)
        for a, b in e:
            uf.union(int(a), int(b))
    fids = np.unique(np.concatenate(edges).reshape(-1))
    groups = {}
    for f in fids:
        groups.setdefault(uf.find(int(f)), []).append(int(f))
    # canonical: each node -> min fid of its group
    out = {}
    for members in groups.values():
        m = min(members)
        for f in members:
            out[f] = m
    return out


@pytest.mark.parametrize("name", ["double_gyre", "vortex_street",
                                  "heated_plume", "turbulence"])
@pytest.mark.parametrize("be", ["numpy", "xla"])
def test_device_partition_matches_host_union_find(name, be):
    ufp, vfp = _fixed(name)
    host = _host_partition(ufp, vfp)
    ts = extraction.extract(ufp, vfp, backend=be)
    assert ts.n_nodes == len(host)
    # same grouping AND same canonical representative per group
    for i, fid in enumerate(ts.face_ids):
        rep_idx = np.nonzero(ts.track_of == ts.track_of[i])[0].min()
        assert int(ts.face_ids[rep_idx]) == host[int(fid)]


def test_connected_labels_backends_agree():
    rng = np.random.default_rng(0)
    for n, e in ((1, 0), (50, 30), (400, 380), (1000, 1500)):
        edges = rng.integers(0, n, size=(e, 2))
        l_np = np.asarray(backend_mod.connected_labels(n, edges, "numpy"))
        l_x = np.asarray(backend_mod.connected_labels(n, edges, "xla"))
        assert np.array_equal(l_np, l_x)
        # label == min of component: idempotent under one more hook
        for a, b in edges:
            assert l_np[a] == l_np[b]
        assert (l_np <= np.arange(n)).all()


def test_connected_labels_long_path_converges():
    # a single 10k-node path exercises the pointer-jumping doubling
    n = 10_000
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    labels = np.asarray(backend_mod.connected_labels(n, edges, "numpy"))
    assert (labels == 0).all()


# ----------------------------------------------------------------------
# Lemma-1 degeneracy is an error, not a silent drop
# ----------------------------------------------------------------------

def test_lemma1_violation_raises():
    crossed = np.zeros((1, 8, 4), dtype=bool)
    crossed[0, 3, 0] = True          # one crossed face: count == 1
    with pytest.raises(trajectory.Lemma1ViolationError, match="tet 3"):
        trajectory.check_lemma1(crossed, t_lo=5)


def test_extract_tracks_raises_on_inconsistent_tables():
    ufp, vfp = _fixed("double_gyre")
    tables = trajectory.face_predicate_tables(ufp, vfp)
    assert tables["slab"].any()
    bad = {"slice": tables["slice"].copy(), "slab": tables["slab"].copy()}
    t, f = np.argwhere(bad["slab"])[0]
    bad["slab"][t, f] = False        # drop one crossing -> odd count
    with pytest.raises(trajectory.Lemma1ViolationError):
        trajectory.extract_tracks(ufp, vfp, tables=bad)


# ----------------------------------------------------------------------
# determinism of the canonical polyline order
# ----------------------------------------------------------------------

def test_polyline_order_edge_order_invariant():
    ufp, vfp = _fixed("vortex_street")
    ts = analysis.extract(ufp, vfp)
    rng = np.random.default_rng(1)
    perm = rng.permutation(len(ts.edges))
    flip = rng.integers(0, 2, len(ts.edges)).astype(bool)
    edges = ts.edges[perm]
    edges[flip[perm]] = edges[flip[perm]][:, ::-1]
    tracks2 = model.build_tracks(ts.nodes, ts.face_ids, ts.types,
                                 ts.track_of, edges)
    for a, b in zip(ts.tracks, tracks2):
        assert np.array_equal(a.face_ids, b.face_ids)
        assert np.array_equal(a.nodes, b.nodes)


def test_metrics_evaluate_shares_tables():
    from repro.core import metrics
    u, v = _field("double_gyre")
    scale, _, _ = fixedpoint.to_fixed(u, v)
    out = metrics.evaluate(u, v, u, v, scale, 100, 10)
    assert out["FC_t"] == 0 and out["FC_s"] == 0
    assert out["n_traj_orig"] == out["n_traj_rec"] == 4
