"""Crash-recovery smoke: SIGKILL a real compress_stream process
mid-run, resume, byte-diff against an uninterrupted container.

Run as:  PYTHONPATH=src python tests/crash_recovery_smoke.py

The in-suite recovery tests inject faults as exceptions, which still
unwind Python frames; SIGKILL does not -- no ``finally`` blocks, no
buffered-file flush, nothing.  This leg proves the journal's fsync
ordering alone is enough: whatever instant the process dies, a
``resume=True`` rerun finishes a container byte-identical to a run
that was never interrupted.

The child process kills itself (``os.kill(getpid(), SIGKILL)``) just
before feeding a chosen frame -- deterministic placement with true
SIGKILL semantics.  Exercised at an early frame (before the first
durable checkpoint), mid-stream, and at the last frame, on both the
serial and the async engine.
"""
import os
import signal
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

T, H, W = 18, 16, 24

_CHILD = r"""
import os, signal, sys
import numpy as np
from repro.core import CompressionConfig, TileGrid, compress_stream
from repro.data import synthetic

sink, kill_at, use_async, resume = (sys.argv[1], int(sys.argv[2]),
                                    int(sys.argv[3]), int(sys.argv[4]))
u, v = synthetic.double_gyre(T=%d, H=%d, W=%d)
vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
pairs = list(zip(u, v))

def feed(t0):
    for t in range(t0, len(pairs)):
        if kill_at >= 0 and t == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)
        yield pairs[t]

compress_stream(feed, CompressionConfig(track_index=True),
                TileGrid(tile_h=8, tile_w=12, window_t=3),
                value_range=vr, sink=sink,
                async_engine=bool(use_async), resume=bool(resume))
""" % (T, H, W)


def run_child(sink: str, kill_at: int, use_async: bool,
              resume: bool = False) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "src"),
            env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, sink, str(kill_at),
         str(int(use_async)), str(int(resume))], env=env, timeout=600)
    return proc.returncode


def main() -> int:
    from repro.core import compress_stream  # noqa: F401 (import check)
    from repro.core import stream_engine

    failures = []
    with tempfile.TemporaryDirectory() as td:
        ref_path = os.path.join(td, "ref.cptt")
        rc = run_child(ref_path, -1, False)
        if rc != 0:
            print(f"uninterrupted child exited {rc}", file=sys.stderr)
            return 1
        with open(ref_path, "rb") as f:
            ref = f.read()

        cases = [(2, False), (9, False), (T - 1, False),
                 (9, True), (T - 1, True)]
        for kill_at, use_async in cases:
            tag = f"kill_at={kill_at} async={use_async}"
            sink = os.path.join(
                td, f"crash_{kill_at}_{int(use_async)}.cptt")
            rc = run_child(sink, kill_at, use_async)
            if rc != -signal.SIGKILL:
                failures.append(f"{tag}: child exited {rc}, "
                                f"expected SIGKILL")
                continue
            info = stream_engine.resume_info(sink)
            if info["complete"]:
                failures.append(f"{tag}: container claims completion "
                                f"after SIGKILL")
                continue
            # resume happens in a NEW process: nothing from the killed
            # run survives except the bytes + journal on disk
            rc = run_child(sink, -1, use_async, resume=True)
            if rc != 0:
                failures.append(f"{tag}: resume child exited {rc}")
                continue
            with open(sink, "rb") as f:
                got = f.read()
            if got != ref:
                failures.append(f"{tag}: resumed container differs "
                                f"({len(got)} vs {len(ref)} bytes)")
            elif os.path.exists(sink + ".journal"):
                failures.append(f"{tag}: journal left after completion")
            else:
                print(f"crash_recovery_smoke: {tag}: resumed from "
                      f"{info['resume_from']}, byte-identical")
    for f in failures:
        print(f"crash_recovery_smoke: FAIL: {f}", file=sys.stderr)
    if not failures:
        print("crash_recovery_smoke: all SIGKILL points resumed "
              "byte-identically")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
