"""Dual-quantization, Lorenzo, SL predictor and coding-layer round trips."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encode, mop, predictors, quantize


# ---------------------------------------------------------------- quantize

@given(
    st.integers(min_value=1, max_value=2**30),
    st.integers(min_value=-(2**30), max_value=2**30),
)
@settings(max_examples=300, deadline=None)
def test_dual_quantize_error_bound(tau, d):
    xi_unit, n_levels = quantize.ladder(tau)
    if n_levels < 1:
        return
    eb = jnp.full((1,), tau, dtype=jnp.int64)
    k, lossless = quantize.quantize_eb(eb, xi_unit, n_levels)
    x = quantize.dual_quantize(jnp.full((1,), d, dtype=jnp.int64), k, lossless, xi_unit)
    if bool(lossless[0]):
        return
    recon = int(x[0]) * 2 * xi_unit
    xi_k = xi_unit * (2 ** int(k[0]))
    assert abs(recon - d) <= xi_k <= tau


def test_quantize_eb_ladder_monotone():
    tau = 10_000
    xi_unit, n_levels = quantize.ladder(tau)
    ebs = jnp.asarray(np.arange(0, tau * 2, 97), dtype=jnp.int64)
    k, lossless = quantize.quantize_eb(ebs, xi_unit, n_levels)
    k = np.asarray(k); lossless = np.asarray(lossless)
    ebs = np.asarray(ebs)
    # quantized bound never exceeds requested bound, and never exceeds tau
    coded = ~lossless
    assert (xi_unit * (2.0 ** k[coded]) <= np.maximum(ebs[coded], xi_unit)).all()
    assert (xi_unit * (2 ** k[coded].max()) <= 2 * tau)


# ---------------------------------------------------------------- lorenzo

@pytest.mark.parametrize("shape", [(3, 8, 8), (2, 17, 13), (4, 16, 33), (2, 5, 50)])
@pytest.mark.parametrize("block", [4, 16])
def test_lorenzo_roundtrip(shape, block):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-(2**20), 2**20, shape).astype(np.int64))
    res = predictors.lorenzo_encode(x, block)
    # decode frame by frame
    prev = jnp.zeros(shape[1:], dtype=jnp.int64)
    out = []
    for t in range(shape[0]):
        prev = predictors.lorenzo_decode_frame(prev, res[t], block)
        out.append(prev)
    got = jnp.stack(out)
    assert (np.asarray(got) == np.asarray(x)).all()


@given(st.integers(min_value=2, max_value=6), st.integers(min_value=0, max_value=2**31))
@settings(max_examples=30, deadline=None)
def test_d2_c2_inverse(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-1000, 1000, (n, n + 3)).astype(np.int64))
    block = 4
    assert (np.asarray(predictors.c2_block(predictors.d2_block(x, block), block)) ==
            np.asarray(x)).all()


# ---------------------------------------------------------------- SL

def test_bilinear_matches_manual():
    f = jnp.asarray(np.arange(12, dtype=np.float64).reshape(3, 4))
    got = predictors.bilinear(f, jnp.asarray([1.5]), jnp.asarray([2.25]))
    # manual: rows 1,2 cols 2,3
    v = (1 - 0.5) * (1 - 0.25) * 6 + (1 - 0.5) * 0.25 * 7 + 0.5 * (1 - 0.25) * 10 + 0.5 * 0.25 * 11
    assert np.allclose(np.asarray(got)[0], v)


def test_bilinear_clamps_at_boundary():
    f = jnp.asarray(np.ones((4, 4)))
    got = predictors.bilinear(f, jnp.asarray([-3.0, 9.0]), jnp.asarray([0.0, 3.9]))
    assert np.allclose(np.asarray(got), 1.0)


def test_sl_encode_decode_consistency():
    """SL residual + same-side prediction reproduces X exactly."""
    rng = np.random.default_rng(1)
    T, H, W = 4, 12, 12
    xu = jnp.asarray(rng.integers(-500, 500, (T, H, W)).astype(np.int64))
    xv = jnp.asarray(rng.integers(-500, 500, (T, H, W)).astype(np.int64))
    g2f, cx, cy = 0.01, 0.5, 0.5
    ru, rv = predictors.sl_encode(xu, xv, g2f, cx, cy)
    for t in range(1, T):
        pu, pv = predictors.sl_predict_frame(xu[t - 1], xv[t - 1], g2f, cx, cy)
        assert (np.asarray(ru[t] + pu) == np.asarray(xu[t])).all()
        assert (np.asarray(rv[t] + pv) == np.asarray(xv[t])).all()


def test_sl_predicts_pure_translation():
    """A pattern advected by a uniform velocity field is predicted almost
    exactly by the SL predictor (the property motivating the paper)."""
    T, H, W = 3, 32, 32
    speed = 2.0  # pixels per frame along j
    ii, jj = np.meshgrid(np.arange(H), np.arange(W), indexing="ij")
    frames_u = []
    for t in range(T):
        pattern = np.sin(2 * np.pi * (jj - speed * t) / 8.0) * 100.0
        frames_u.append(pattern)
    xu = jnp.asarray(np.stack(frames_u)).astype(jnp.int64)
    # u field = constant speed (in data units: grid_to_float=1, cfl_x=1)
    xv = jnp.zeros_like(xu)
    xu_vel = jnp.full((T, H, W), speed, dtype=jnp.int64)
    # build velocity-carrying fields: u carries the advecting velocity
    pu, pv = predictors.sl_predict_frame(xu_vel[0], xv[0], 1.0, 1.0, 1.0)
    # velocity field is uniform => departure point = (i, j - speed)
    # prediction of the *velocity* field itself is exact
    assert (np.asarray(pu) == speed).all()


# ---------------------------------------------------------------- coding

@given(st.lists(st.integers(min_value=-(2**40), max_value=2**40), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_symbols_roundtrip(vals):
    res = np.asarray(vals, dtype=np.int64)
    sym, esc = encode.to_symbols(res)
    back = encode.from_symbols(sym, esc, res.shape)
    assert (back == res).all()


@pytest.mark.parametrize("seed,dist", [(0, "geometric"), (1, "uniform"), (2, "const")])
def test_huffman_roundtrip(seed, dist):
    rng = np.random.default_rng(seed)
    if dist == "geometric":
        sym = np.minimum(rng.geometric(0.3, 5000) - 1, 255).astype(np.uint8)
    elif dist == "uniform":
        sym = rng.integers(0, 256, 5000).astype(np.uint8)
    else:
        sym = np.zeros(5000, dtype=np.uint8)
    lengths, data, n = encode.huffman_encode(sym)
    got = encode.huffman_decode(lengths, data, n)
    assert (got == sym).all()


def test_container_roundtrip():
    header = {"a": 1, "s": "x"}
    secs = {
        "i64": np.arange(10, dtype=np.int64),
        "f32": np.linspace(0, 1, 7, dtype=np.float32).reshape(7, 1),
        "u8": np.frombuffer(b"hello", dtype=np.uint8),
    }
    blob = encode.pack(header, secs)
    h2, s2 = encode.unpack(blob)
    assert h2["a"] == 1 and h2["s"] == "x"
    for k in secs:
        assert (np.asarray(s2[k]) == secs[k]).all()


# ---------------------------------------------------------------- MoP

def test_mop_fold_unfold():
    x = jnp.asarray(np.arange(-20, 20, dtype=np.int64))
    assert (np.asarray(mop.unfold(mop.fold(x))) == np.asarray(x)).all()


def test_mop_selects_sl_for_advected_structure():
    """Spatially-rough content passively advected by a uniform carrier
    flow: SL must beat Lorenzo and MoP must select it (the property
    motivating paper Sec. VI).  u carries the flow (constant 300 data
    units -> exactly 3 px/frame with cfl_x = 0.01); v is a rough texture
    riding on it."""
    rng = np.random.default_rng(5)
    T, H, W = 4, 32, 64
    base = rng.integers(-1000, 1000, (H, W + 3 * T)).astype(np.int64)
    xu = jnp.full((T, H, W), 300, dtype=jnp.int64)
    xv = jnp.asarray(
        np.stack([base[:, 3 * (T - t) : 3 * (T - t) + W] for t in range(T)])
    )  # texture moves +3 px in j per frame, carried by u > 0

    res3_u = predictors.lorenzo_encode(xu, 16)
    res3_v = predictors.lorenzo_encode(xv, 16)
    ressl_u, ressl_v = predictors.sl_encode(xu, xv, 1.0, 0.01, 1e-9)
    # SL residuals on the advected texture beat Lorenzo's by a wide margin
    a3 = np.abs(np.asarray(res3_v[1:])).mean()
    asl = np.abs(np.asarray(ressl_v[1:])).mean()
    assert asl < a3 * 0.2, (asl, a3)

    bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, 16)
    bm = np.asarray(bm)
    assert not bm[0].any()           # frame 0 has no previous frame
    assert bm[1:].mean() > 0.5       # SL selected on most tiles
