"""repro.obs: metrics registry, span tracing, disabled-path no-ops,
trace JSON schema, and engine/threading integration (DESIGN.md #14)."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics, trace
from repro.core import CompressionConfig, TileGrid, compress_tiled
from repro.core.tiling import compress_stream

CFG = dict(eb=1e-2, mode="rel", predictor="mop", backend="xla",
           verify=True, fused=True, track_index=False)
GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)


@pytest.fixture
def obs_state():
    """Restore the enabled flag and clear the trace buffer afterwards
    so tests compose regardless of the REPRO_OBS env the suite runs
    under.  The metrics registry is NOT reset: carrier metrics are
    process-wide by design, so tests assert on deltas or unique
    names."""
    was = obs.enabled()
    yield
    (obs.enable if was else obs.disable)()
    trace.reset()


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------

def test_histogram_log2_bucket_edges():
    h = metrics.Histogram("t")
    h.observe(0)                     # exact zero -> bucket 0
    h.observe(1)                     # [1, 2)     -> bucket 1
    h.observe(2)                     # [2, 4)     -> bucket 2
    h.observe(3)
    h.observe(4)                     # [4, 8)     -> bucket 3
    h.observe(7)
    h.observe(-5)                    # clamped to 0 -> bucket 0
    h.observe(2**62)                 # top bucket absorbs the tail
    h.observe(2**63 + 1)
    snap = h.snapshot()
    assert snap["buckets"] == {0: 2, 1: 1, 2: 2, 3: 2, 63: 2}
    assert snap["count"] == 9
    assert snap["min"] == 0
    assert snap["max"] == 2**63 + 1
    # exact power-of-two edges: 2^k lands in bucket k+1 (lower edge
    # of [2^k, 2^(k+1)))
    for k in range(1, 20):
        hh = metrics.Histogram("e")
        hh.observe(2**k)
        hh.observe(2**k - 1)
        b = hh.snapshot()["buckets"]
        assert b == {k + 1: 1, k: 1}, f"2^{k} bucketed wrong: {b}"


def test_registry_kind_mismatch_raises():
    r = metrics.Registry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")


def test_child_counter_rollup_and_set_local():
    parent = obs.counter("test.obs.rollup")
    base = parent.value
    a = obs.child_counter("test.obs.rollup")
    b = obs.child_counter("test.obs.rollup")
    a.add(3)
    b.add(4)
    assert (a.value, b.value) == (3, 4)
    assert parent.value == base + 7
    # restore/clear path: local view resets, process total survives
    a.set_local(0)
    assert a.value == 0
    assert parent.value == base + 7
    a.add(2)
    assert parent.value == base + 9


def test_snapshot_exact_under_concurrent_writers():
    n_threads, n_adds = 8, 2_000
    c = obs.counter("test.obs.concurrent")
    h = obs.histogram("test.obs.concurrent_h")
    base = c.value
    stop = threading.Event()
    snaps = []

    def writer():
        child = obs.child_counter("test.obs.concurrent")
        for i in range(n_adds):
            child.add(1)
            h.observe(i)

    def snapshotter():
        while not stop.is_set():
            snaps.append(obs.snapshot())

    ts = [threading.Thread(target=writer) for _ in range(n_threads)]
    sn = threading.Thread(target=snapshotter)
    sn.start()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stop.set()
    sn.join()
    # concurrent snapshots observed monotone, never-corrupt values
    seen = [s["test.obs.concurrent"]["value"] for s in snaps
            if "test.obs.concurrent" in s]
    assert all(x <= y for x, y in zip(seen, seen[1:]))
    final = obs.snapshot()
    assert final["test.obs.concurrent"]["value"] == \
        base + n_threads * n_adds
    hs = final["test.obs.concurrent_h"]
    assert hs["count"] >= n_threads * n_adds
    assert sum(hs["buckets"].values()) == hs["count"]


# ----------------------------------------------------------------------
# disabled path
# ----------------------------------------------------------------------

def test_disabled_mode_is_noop(obs_state):
    obs.disable()
    trace.reset()
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is s2 is trace.NOOP          # one shared singleton
    with s1 as sp:
        assert sp.set(y=2) is sp
    assert sp.dur_ns == 0 and sp.dur_s == 0.0
    obs.count("test.obs.gated_counter_never", 5)
    obs.observe("test.obs.gated_hist_never", 5)
    obs.gauge_set("test.obs.gated_gauge_never", 5)
    obs.counter_event("qq", depth=1)
    obs.instant_event("ii")
    obs.name_thread("tt")
    assert obs.trace_events() == []
    snap = obs.snapshot()
    for name in ("test.obs.gated_counter_never",
                 "test.obs.gated_hist_never",
                 "test.obs.gated_gauge_never"):
        assert name not in snap            # gated helpers never registered
    # device_sync is value-neutral in both modes
    x = np.arange(3)
    assert obs.device_sync(x) is x
    obs.enable()
    assert obs.device_sync(x) is x


# ----------------------------------------------------------------------
# span tracing
# ----------------------------------------------------------------------

def test_span_nesting_and_attributes(obs_state):
    obs.enable()
    trace.reset()
    with obs.span("outer", a=1) as so:
        assert trace.current_span() is so
        with obs.span("inner") as si:
            assert trace.current_span() is si
            si.set(found=7)
        assert trace.current_span() is so
    assert trace.current_span() is None
    evs = {e["name"]: e for e in obs.trace_events()}
    outer, inner = evs["outer"], evs["inner"]
    assert outer["args"] == {"a": 1}
    assert inner["args"] == {"found": 7}
    # containment: inner starts no earlier and ends no later
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert "stack_corrupt" not in outer["args"]

    with pytest.raises(RuntimeError):
        with obs.span("failing"):
            raise RuntimeError("boom")
    fail = [e for e in obs.trace_events() if e["name"] == "failing"][0]
    assert fail["args"]["error"] == "RuntimeError"


def test_trace_json_schema_golden(obs_state, tmp_path):
    obs.enable()
    trace.reset()
    obs.name_thread("golden-thread")
    with obs.span("golden.work", unit=3):
        obs.counter_event("golden.queue", depth=2, backlog=0)
        obs.instant_event("golden.marker", why="test")
    path = tmp_path / "trace.json"
    n = obs.export_trace(str(path))
    assert n == 4
    payload = json.loads(path.read_text())
    assert set(payload) == {"traceEvents", "displayTimeUnit"}
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    assert [e["ph"] for e in sorted(evs, key=lambda e: e["ph"])] == \
        ["C", "M", "X", "i"]
    by_ph = {e["ph"]: e for e in evs}
    x = by_ph["X"]
    assert x["name"] == "golden.work" and x["args"] == {"unit": 3}
    assert isinstance(x["ts"], float) and isinstance(x["dur"], float)
    assert x["dur"] >= 0 and x["pid"] > 0 and x["tid"] > 0
    c = by_ph["C"]
    assert c["name"] == "golden.queue"
    assert c["args"] == {"depth": 2, "backlog": 0}
    i = by_ph["i"]
    assert i["s"] == "t" and i["args"] == {"why": "test"}
    m = by_ph["M"]
    assert m["name"] == "thread_name"
    assert m["args"] == {"name": "golden-thread"}
    # ts-sorted on export (metadata events carry no ts and sort first)
    tss = [e.get("ts", 0.0) for e in evs]
    assert tss == sorted(tss)


# ----------------------------------------------------------------------
# engine integration: spans + metrics under the threaded async engine
# ----------------------------------------------------------------------

def test_async_engine_spans_and_metrics(small_field, obs_state):
    u, v = small_field
    cfg = CompressionConfig(**CFG)
    vr = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    obs.enable()
    trace.reset()
    units0 = obs.counter("engine.units_emitted").value
    blob, stats = compress_stream(
        list(zip(u, v)), cfg, GRID, value_range=vr, async_engine=True)
    evs = obs.trace_events()
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)

    # all three engine stages produced spans, on distinct threads
    for stage in ("engine.ingest", "engine.compute", "engine.write"):
        assert by_name.get(stage), f"no {stage} spans"
    tids = {s: {e["tid"] for e in by_name[s]}
            for s in ("engine.ingest", "engine.compute", "engine.write")}
    assert tids["engine.ingest"].isdisjoint(tids["engine.compute"])
    assert tids["engine.write"].isdisjoint(tids["engine.compute"])

    # attribute integrity under threading: every span exited cleanly on
    # its own thread's stack
    for e in evs:
        if e["ph"] == "X":
            assert "stack_corrupt" not in e["args"], e
    assert len(by_name["engine.ingest"]) == u.shape[0]
    assert len(by_name["engine.write"]) == stats["n_units"]

    # queue-depth counter events for both handoff queues
    assert by_name.get("engine.q_in")
    assert by_name.get("engine.q_out")
    assert all(e["args"]["depth"] >= 0 for e in by_name["engine.q_in"])

    # thread self-labelling metadata
    labels = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert {"engine.ingest", "engine.writer",
            "engine.compute"} <= labels

    # tiling-level spans rode along on the compute thread
    assert by_name.get("tiling.derive_window")
    assert by_name.get("tiling.unit_payloads")

    # carrier metrics: the scheduler's public field and the process
    # counter agree
    assert obs.counter("engine.units_emitted").value - units0 \
        == stats["n_units"]
    snap = obs.snapshot()
    assert snap["engine.windows_emitted"]["value"] >= 1

    # and the engine's scheduling left the bytes alone
    blob_t, _ = compress_tiled(u, v, cfg, GRID)
    assert blob == blob_t


def test_byte_identity_and_run_report(small_field, obs_state):
    u, v = small_field
    cfg = CompressionConfig(**CFG)
    obs.disable()
    blob_off, _ = compress_tiled(u, v, cfg, GRID)
    obs.enable()
    blob_on, _ = compress_tiled(u, v, cfg, GRID)
    assert blob_off == blob_on, \
        "observability changed the container bytes"
    rep = obs.run_report(blob_on)
    assert rep["container_bytes"] == len(blob_on)
    assert rep["kind_bytes_total"] == len(blob_on)
    assert sum(rep["bytes_by_kind"].values()) == len(blob_on)
    assert rep["n_units"] == len(rep["units"])
    assert all(r["n_symbols"] > 0 for r in rep["units"])


def test_retry_accounting_visible_on_success():
    from repro.core import faults

    site = "test.obs.retry_site"
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    before = obs.counter(f"faults.retry.{site}.attempts").value
    assert faults.retry_transient(flaky, retries=3, backoff=0,
                                  site=site) == "ok"
    st = faults.retry_stats(site)
    assert st["calls"] >= 1
    assert st["retries"] >= 2
    assert st["last_outcome"] == "ok"
    assert obs.counter(f"faults.retry.{site}.attempts").value \
        == before + 3
