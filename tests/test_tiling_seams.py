"""Property-based seam correctness of the tiled pipeline.

Random tile geometries / halo configurations must reproduce the
monolithic pipeline exactly at every seam:

* the min-reduction of per-tile error bounds equals the monolithic
  per-vertex eb field (and each tile's OWNED region is already exact --
  the halo covers every incident face, so both sides of a seam agree
  without communication);
* the verify-and-correct loop, driven with a synthetic forced seed
  (organic forcing is deliberately rare -- the derived bounds are
  conservative), reaches the exact forced-vertex fixpoint of a full
  re-evaluation reference, i.e. forced sets agree across tile
  boundaries round by round;
* random-geometry tiled compression decodes bit-identically to the
  monolithic fused pipeline.

Geometries are drawn from a palette (ragged edge tiles, uneven windows,
halo 1 and 2) rather than free integers: every distinct tile shape costs
a jit compile, and the palette keeps the property runs within seconds
while still covering seam/corner/degenerate layouts.
"""
import numpy as np
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress,
    compress_tiled,
    compressor,
    decompress,
    decompress_tiled,
    ebound,
    fixedpoint,
    quantize,
    tiling,
)

T, H, W = 4, 10, 12

# (tile_h, tile_w, window_t, halo, thalo): ragged tiles, a full-field
# degenerate tiling, window_t of 1, and halo/thalo of 2
_GEOMS = [
    (3, 4, 2, 1, 1),
    (4, 7, 1, 2, 2),
    (10, 12, 4, 1, 1),
]


def _field():
    rng = np.random.default_rng(42)
    u = rng.normal(size=(T, H, W)).astype(np.float32)
    v = rng.normal(size=(T, H, W)).astype(np.float32)
    u[:, :, 5] *= 0.05  # a near-zero band so crossings exist
    v[:, 4, :] *= 0.05
    return u, v


_U, _V = _field()


def _grid(idx):
    th, tw, wt, halo, thalo = _GEOMS[idx % len(_GEOMS)]
    return TileGrid(tile_h=th, tile_w=tw, window_t=wt,
                    halo=halo, thalo=thalo)


def _monolithic_eb(cfg):
    scale, ufp, vfp = fixedpoint.to_fixed(_U, _V, cfg.fixed_bits)
    eb_abs = compressor._abs_eb(_U, _V, cfg)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    eb, _, _ = ebound.derive_vertex_eb_jit(
        jnp.asarray(ufp), jnp.asarray(vfp), int(max(tau, 1)))
    return np.asarray(eb)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=len(_GEOMS) - 1))
def test_eb_min_reduction_matches_monolithic(gi):
    cfg = CompressionConfig(eb=1e-2, mode="rel")
    grid = _grid(gi)
    st_, windows, _ = tiling._prepare(_U, _V, cfg, grid)
    eb_tiled = st_.eb.box((0, T, 0, H, 0, W))
    eb_mono = _monolithic_eb(cfg)
    assert np.array_equal(eb_tiled, eb_mono)
    # halo-exactness: a tile's OWNED bounds are already the global ones
    # before any reduction -- seam vertices agree from both sides.  One
    # spec per distinct extension shape (each shape = one jit compile).
    tau = int(max(st_.tau, 1))
    by_shape = {}
    for w in windows:
        for spec in w.specs:
            by_shape.setdefault(spec.ext_shape, spec)
    for spec in by_shape.values():
        eb_t, _, _ = ebound.derive_vertex_eb_jit(
            jnp.asarray(st_.ufp.box(spec.ext_box)),
            jnp.asarray(st_.vfp.box(spec.ext_box)), tau)
        o = spec.owned_in_ext
        t0, t1, i0, i1, j0, j1 = spec.owned_box
        assert np.array_equal(np.asarray(eb_t)[o],
                              eb_mono[t0:t1, i0:i1, j0:j1]), spec


def _reference_closure(cfg, seed_mask):
    """Monolithic verify fixpoint by FULL re-evaluation every round
    (no screens, no incremental face sets) -- the ground truth the
    screened/incremental tiled loop must land on exactly."""
    scale, ufp, vfp = fixedpoint.to_fixed(_U, _V, cfg.fixed_bits)
    eb_abs = compressor._abs_eb(_U, _V, cfg)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    xi, n_us = quantize.ladder(tau, cfg.n_levels)
    ufp_j = jnp.asarray(ufp)
    vfp_j = jnp.asarray(vfp)
    eb, sp0, sb0 = ebound.derive_vertex_eb_jit(ufp_j, vfp_j,
                                               int(max(tau, 1)))
    extra = seed_mask.copy()
    if tau < 1 or n_us < 1:
        extra |= True
    for _ in range(cfg.max_rounds + 1):
        extra_j = jnp.asarray(extra)
        k, ll = quantize.quantize_eb(eb, xi, cfg.n_levels)
        ll = jnp.logical_or(ll, extra_j)
        k = jnp.where(extra_j, -1, k)
        xu = quantize.dual_quantize(ufp_j, k, ll, xi)
        xv = quantize.dual_quantize(vfp_j, k, ll, xi)
        u_rec, v_rec = compressor._reconstruct(
            xu, xv, scale, xi, ll, jnp.asarray(_U), jnp.asarray(_V))
        ur, vr = fixedpoint.refix(np.asarray(u_rec), np.asarray(v_rec),
                                  scale)
        sp1, sb1 = ebound.all_face_predicates(jnp.asarray(ur),
                                              jnp.asarray(vr))
        bad_slice = np.asarray(sp0 ^ sp1)
        bad_slab = np.asarray(sb0 ^ sb1)
        err = np.maximum(
            np.abs(np.asarray(u_rec, np.float64) - _U.astype(np.float64)),
            np.abs(np.asarray(v_rec, np.float64) - _V.astype(np.float64)))
        forced = extra | (err > eb_abs) | compressor._faces_to_vertex_mask(
            bad_slice, bad_slab, T, H, W)
        if not (forced & ~extra).any():
            return forced
        extra = forced
    return extra


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=len(_GEOMS) - 1),
       st.integers(min_value=0, max_value=10**6))
def test_seeded_forcing_fixpoint_matches_reference(gi, seed):
    """Seam agreement under forcing: seed a random forced set, run the
    per-tile screened/incremental fixpoint, and require the exact
    forced-vertex set a full-re-evaluation monolithic closure reaches.
    n_levels=3 so forcing actually changes X at coarse vertices."""
    cfg = CompressionConfig(eb=5e-2, mode="rel", n_levels=3)
    rng = np.random.default_rng(seed)
    seed_mask = rng.random((T, H, W)) < 0.03
    st_, windows, _ = tiling._prepare(_U, _V, cfg, _grid(gi))
    for t in range(T):
        st_.forced.ensure(t)
        st_.forced.p[t] |= seed_mask[t]
    tiling._fixpoint(st_, windows, frontier=0)
    forced_tiled = st_.forced.box((0, T, 0, H, 0, W))
    forced_ref = _reference_closure(cfg, seed_mask)
    assert np.array_equal(forced_tiled, forced_ref), (
        int(forced_tiled.sum()), int(forced_ref.sum()))


@settings(max_examples=2, deadline=None)
@given(st.integers(min_value=0, max_value=len(_GEOMS) - 1))
def test_random_geometry_bitwise_roundtrip(gi):
    cfg = CompressionConfig(eb=1e-2, mode="rel", predictor="lorenzo",
                            fused=True)
    blob_m, _ = compress(_U, _V, cfg)
    um, vm = decompress(blob_m)
    blob_t, _ = compress_tiled(_U, _V, cfg, _grid(gi))
    ut, vt = decompress_tiled(blob_t)
    assert np.array_equal(um, ut) and np.array_equal(vm, vt)


@settings(max_examples=2, deadline=None)
@given(st.integers(min_value=0, max_value=len(_GEOMS) - 1),
       st.integers(min_value=0, max_value=10**6))
def test_nonuniform_bounds_tiled_matches_monolithic(gi, seed):
    """Random non-uniform per-tile base bounds (core/ebpolicy.py): the
    policy resolves over its OWN grid, so a random execution tiling and
    the monolithic pipeline must decode identically under it -- the
    seam min-reduction and the policy's one-cell/one-frame inflation
    rule compose engine-independently.  Both predictors."""
    from repro.core import ebpolicy

    rng = np.random.default_rng(seed)
    wt = int(rng.integers(1, T + 1))
    th = int(rng.integers(2, H + 1))
    tw = int(rng.integers(2, W + 1))
    values = {}
    for wi in range(-(-T // wt)):
        for ti in range(-(-H // th)):
            for tj in range(-(-W // tw)):
                if rng.random() < 0.5:
                    values[(wi, ti, tj)] = float(10.0
                                                 ** rng.uniform(-3, -1.3))
    pol = ebpolicy.TilePolicy.make(wt, th, tw, default=5e-2,
                                   values=values)
    for pred in ("mop", "lorenzo"):
        cfg = CompressionConfig(eb=5e-2, mode="abs", predictor=pred,
                                fused=True, eb_policy=pol,
                                n_levels=ebpolicy.levels_for(pol))
        blob_m, _ = compress(_U, _V, cfg)
        um, vm = decompress(blob_m)
        blob_t, _ = compress_tiled(_U, _V, cfg, _grid(gi))
        ut, vt = decompress_tiled(blob_t)
        assert np.array_equal(um, ut) and np.array_equal(vm, vt), \
            (pred, gi, wt, th, tw)


def test_box_vertex_ids_order_isomorphic():
    """The invariant the tiled path rests on: a sub-box's row-major
    local ids preserve the global id order, so SoS tie-breaks (pure <
    comparisons) are bit-equal under tile-local ids."""
    from repro.core import grid as grid_mod

    ids = grid_mod.box_vertex_ids((T, H, W), (1, 3, 2, 7, 4, 11))
    assert ids[0, 0, 0] == 1 * H * W + 2 * W + 4
    flat = ids.reshape(-1)
    assert (np.diff(flat) > 0).all()   # strictly increasing == isomorphic


def test_halo_zero_rejected():
    # ValueError (not an assert): geometry validation must survive -O
    try:
        TileGrid(halo=0).validate()
    except ValueError:
        return
    raise AssertionError("halo=0 must be rejected")
