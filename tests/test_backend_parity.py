"""Backend-dispatch parity (core/backend.py determinism contract).

The two integer hot ops (fused dualquant+Lorenzo residual, SoS face
predicate) must be bit-identical across pallas-interpret / xla / numpy;
full pipeline runs must produce identical residual streams, lossless
masks and blockmaps on synthetic fields; and the verify loop must be
backend-invariant (same round counts, FC_t = FC_s = 0 everywhere).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import backend as backend_mod
from repro.core import compressor, encode, predictors, quantize
from repro.data import synthetic

BACKENDS = ("pallas", "xla", "numpy")


# ------------------------------------------------------------- op level

@pytest.mark.parametrize("shape", [(3, 64, 64), (2, 40, 72)])
@pytest.mark.parametrize("tau", [100, 2**20])
def test_lorenzo_residual_op_parity(shape, tau):
    rng = np.random.default_rng(0)
    dfp = jnp.asarray(rng.integers(-(2**29), 2**29, shape).astype(np.int64))
    xi_unit, n_levels = quantize.ladder(tau)
    eb = jnp.asarray(rng.integers(0, tau + 1, shape).astype(np.int64))
    k, lossless = quantize.quantize_eb(eb, xi_unit, n_levels)
    outs = {
        be: np.asarray(backend_mod.lorenzo_residual(
            dfp, k, lossless, xi_unit, 16, be))
        for be in BACKENDS
    }
    assert (outs["xla"] == outs["numpy"]).all()
    assert (outs["xla"] == outs["pallas"]).all()


@pytest.mark.parametrize("n", [5, 300])
def test_face_crossed_op_parity(n):
    rng = np.random.default_rng(n)
    u = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    v = rng.integers(-(2**29), 2**29, (n, 3)).astype(np.int64)
    u[:: max(n // 5, 1)] = 0   # degeneracies
    idx = np.arange(3 * n, dtype=np.int64).reshape(n, 3)
    outs = {
        be: np.asarray(backend_mod.face_crossed(
            jnp.asarray(u), jnp.asarray(v), jnp.asarray(idx),
            backend=be, n_verts=3 * n))
        for be in BACKENDS
    }
    assert (outs["xla"] == outs["numpy"]).all()
    assert (outs["xla"] == outs["pallas"]).all()


def test_sl_stepper_shared_executable():
    """The same stepper instance is returned for identical params (the
    structural-consistency requirement), and its integer outputs agree
    with the xla reference on aligned frames."""
    s1 = backend_mod.sl_stepper("xla", 0.5, 0.5, 2.0, 8)
    s2 = backend_mod.sl_stepper("xla", 0.5, 0.5, 2.0, 8)
    assert s1 is s2
    rng = np.random.default_rng(2)
    xu = jnp.asarray(rng.integers(-500, 500, (32, 48)).astype(np.int64))
    xv = jnp.asarray(rng.integers(-500, 500, (32, 48)).astype(np.int64))
    pu, pv = s1(xu, xv, 0.01)
    want = predictors.sl_predict_frame(xu, xv, 0.01, 0.5, 0.5, 2.0, 8)
    assert (np.asarray(pu) == np.asarray(want[0])).all()
    assert (np.asarray(pv) == np.asarray(want[1])).all()


# -------------------------------------------------------- stream level

def _sections(u, v, cfg):
    blob, stats = core.compress(u, v, cfg)
    header, sections = encode.unpack(blob)
    return header, sections, stats


@pytest.mark.parametrize("predictor", ["lorenzo", "sl", "mop"])
def test_stream_parity_across_backends(predictor):
    # H = 32 keeps the pallas SL kernel row-tile aligned
    u, v = synthetic.vortex_street(T=6, H=32, W=48)
    meta = dict(dt=0.05, dx=2.0 / 47, dy=1.0 / 31)
    ref = None
    for be in BACKENDS:
        cfg = core.CompressionConfig(eb=1e-3, predictor=predictor,
                                     backend=be, **meta)
        header, sections, stats = _sections(u, v, cfg)
        if ref is None:
            ref = (sections, stats)
            continue
        for name in ref[0]:
            assert np.array_equal(sections[name], ref[0][name]), (
                f"{predictor}/{be}: section {name} differs")
        assert stats["verify_rounds"] == ref[1]["verify_rounds"]


def test_stream_parity_random_field():
    rng = np.random.default_rng(11)
    u = rng.normal(0, 1, (5, 32, 40)).astype(np.float32)
    v = rng.normal(0, 1, (5, 32, 40)).astype(np.float32)
    ref = None
    for be in BACKENDS:
        cfg = core.CompressionConfig(eb=1e-2, predictor="mop", backend=be)
        _, sections, _ = _sections(u, v, cfg)
        if ref is None:
            ref = sections
            continue
        for name in ref:
            assert np.array_equal(sections[name], ref[name]), (
                f"{be}: section {name} differs")


def test_fused_matches_legacy_streams():
    """The fused device-resident pipeline and the seed (legacy) pipeline
    must produce identical residual streams, lossless sets and blockmaps
    -- the restructure is a pure perf transformation.

    For the integer-only lorenzo predictor this equality is guaranteed
    and asserted byte-for-byte.  SL-containing streams additionally rely
    on the legacy in-scan predictor and the fused stepper executable
    rounding f64 identically, which holds on a fixed stack but is not
    contractual (DESIGN.md #4); there we assert the invariant parts
    (lossless set, round counts) plus full end-to-end guarantees.
    """
    from repro.core import trajectory

    u, v = synthetic.double_gyre(T=5, H=24, W=40)
    meta = dict(dt=0.1, dx=2.0 / 39, dy=1.0 / 23)
    for predictor in ("lorenzo", "sl", "mop"):
        cfg_f = core.CompressionConfig(eb=2e-3, predictor=predictor,
                                       backend="xla", fused=True, **meta)
        cfg_l = core.CompressionConfig(eb=2e-3, predictor=predictor,
                                       fused=False, **meta)
        _, sec_f, st_f = _sections(u, v, cfg_f)
        _, sec_l, st_l = _sections(u, v, cfg_l)
        if predictor == "lorenzo":
            for name in sec_f:
                assert np.array_equal(sec_f[name], sec_l[name]), (
                    f"{predictor}: section {name} differs fused vs legacy")
        else:
            assert np.array_equal(sec_f["lossless"], sec_l["lossless"])
            assert np.array_equal(sec_f["bm_shape"], sec_l["bm_shape"])
        assert st_f["verify_rounds"] == st_l["verify_rounds"]
        assert st_f["verify_bad_counts"] == st_l["verify_bad_counts"]
        for cfg in (cfg_f, cfg_l):
            blob, stats = core.compress(u, v, cfg)
            ur, vr = core.decompress(blob)
            assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
            fc = trajectory.false_cases(u, v, ur, vr, stats["scale"])
            assert fc["FC_t"] == 0 and fc["FC_s"] == 0


# ------------------------------------------------- verify-loop behavior

def _large_magnitude_field():
    """f32 output rounding competes with the bound -> pointwise verify
    rounds actually fire (verify_bad_counts[0] > 0)."""
    rng = np.random.default_rng(3)
    T, H, W = 4, 16, 16
    base = 1.0e8
    u = (base + rng.normal(0, 100.0, (T, H, W))).astype(np.float32)
    v = (base + rng.normal(0, 100.0, (T, H, W))).astype(np.float32)
    return u, v


@pytest.mark.parametrize("be", BACKENDS)
def test_verify_convergence_backend_invariant(be):
    u, v = _large_magnitude_field()
    cfg = core.CompressionConfig(eb=6.0, mode="abs", predictor="mop",
                                 backend=be)
    blob, stats = core.compress(u, v, cfg)
    assert stats["verify_rounds"] >= 1          # the loop actually fired
    assert stats["verify_bad_counts"][0] > 0
    assert stats["verify_bad_counts"][-1] == 0  # ... and converged
    ur, vr = core.decompress(blob)
    assert np.abs(ur.astype(np.float64) - u).max() <= stats["eb_abs"]
    assert np.abs(vr.astype(np.float64) - v).max() <= stats["eb_abs"]
    from repro.core import trajectory
    fc = trajectory.false_cases(u, v, ur, vr, stats["scale"])
    assert fc["FC_t"] == 0 and fc["FC_s"] == 0


def test_verify_round_counts_equal_across_backends():
    u, v = _large_magnitude_field()
    counts = {}
    for be in BACKENDS:
        cfg = core.CompressionConfig(eb=6.0, mode="abs", predictor="mop",
                                     backend=be)
        _, stats = core.compress(u, v, cfg)
        counts[be] = (stats["verify_rounds"], tuple(stats["verify_bad_counts"]))
    assert counts["xla"] == counts["numpy"] == counts["pallas"], counts


def test_incremental_face_check_matches_full():
    """The incremental subset predicate evaluation must agree with a
    full re-evaluation at the touched faces (gather/id bookkeeping)."""
    u, v = synthetic.double_gyre(T=4, H=20, W=24)
    T, H, W = u.shape
    from repro.core import ebound, fixedpoint

    scale, ufp, vfp = fixedpoint.to_fixed(u, v)
    fns = compressor._fused_fns((T, H, W), 16, 1, "mop", "xla")
    full_slice, full_slab = ebound.all_face_predicates(
        jnp.asarray(ufp), jnp.asarray(vfp))
    rng = np.random.default_rng(0)
    delta = rng.random((T, H, W)) < 0.01
    verts, (ts, fs), (tb, fb) = compressor._touched_faces(delta, T, H, W)
    assert len(verts)
    crossed = np.asarray(fns.face_subset(
        jnp.asarray(ufp.reshape(-1)), jnp.asarray(vfp.reshape(-1)),
        jnp.asarray(verts)))
    want = np.concatenate([np.asarray(full_slice)[ts, fs],
                           np.asarray(full_slab)[tb, fb]])
    assert (crossed == want).all()


def test_decode_parallel_matches_stepper_reference():
    """Prefix-sum (parallel-in-time) decode == a per-frame reference
    loop through the SAME stepper executable, on a mixed Lorenzo/SL
    blockmap.  This pins the segment re-basing algebra exactly without
    depending on cross-executable float rounding."""
    from repro.core import predictors

    rng = np.random.default_rng(5)
    T, H, W = 8, 32, 32
    block = 16
    res_u = jnp.asarray(rng.integers(-3, 4, (T, H, W)).astype(np.int64))
    res_v = jnp.asarray(rng.integers(-3, 4, (T, H, W)).astype(np.int64))
    bm = np.zeros((T, 2, 2), dtype=bool)
    bm[3] = True          # one SL frame mid-run
    bm[6, 0, 1] = True    # one mixed frame
    scale, xi_unit = 1024.0, 4
    g2f = (2.0 * xi_unit) / scale
    stepper = backend_mod.sl_stepper("xla", 0.5, 0.5, 2.0, 8)
    xu_p, xv_p = compressor._decode_fields_parallel(
        res_u, res_v, bm, scale, xi_unit, block, stepper)

    # reference: strictly sequential frame loop, same stepper
    mask = np.repeat(np.repeat(bm, block, 1), block, 2)[:, :H, :W]
    xu = [predictors.c2_block(res_u[0], block)]
    xv = [predictors.c2_block(res_v[0], block)]
    for t in range(1, T):
        pu, pv = stepper(xu[-1], xv[-1], g2f)
        m = jnp.asarray(mask[t])
        xu.append(jnp.where(m, res_u[t] + pu,
                            xu[-1] + predictors.c2_block(res_u[t], block)))
        xv.append(jnp.where(m, res_v[t] + pv,
                            xv[-1] + predictors.c2_block(res_v[t], block)))
    assert (np.asarray(xu_p) == np.asarray(jnp.stack(xu))).all()
    assert (np.asarray(xv_p) == np.asarray(jnp.stack(xv))).all()


def test_decode_parallel_pure_lorenzo_matches_scan():
    """With no SL frames both decoders are integer-exact, so the cumsum
    path must equal the sequential scan bit-for-bit."""
    rng = np.random.default_rng(6)
    T, H, W = 6, 32, 32
    res_u = jnp.asarray(rng.integers(-5, 6, (T, H, W)).astype(np.int64))
    res_v = jnp.asarray(rng.integers(-5, 6, (T, H, W)).astype(np.int64))
    bm = np.zeros((T, 2, 2), dtype=bool)
    stepper = backend_mod.sl_stepper("xla", 0.5, 0.5, 2.0, 8)
    xu_p, xv_p = compressor._decode_fields_parallel(
        res_u, res_v, bm, 1024.0, 4, 16, stepper)
    xu_s, xv_s = compressor._decode_fields(
        res_u, res_v, jnp.asarray(bm), 1024.0, 4, 16, 0.5, 0.5, 2.0, 8)
    assert (np.asarray(xu_p) == np.asarray(xu_s)).all()
    assert (np.asarray(xv_p) == np.asarray(xv_s)).all()


def test_no_python_loop_in_faces_to_vertex_mask():
    """Acceptance guard: _faces_to_vertex_mask is a vectorized scatter
    (no `for` over frames) and still marks exactly the right vertices."""
    import inspect

    src = inspect.getsource(compressor._faces_to_vertex_mask)
    assert "for t in range" not in src
    T, H, W = 3, 6, 7
    from repro.core import grid
    Fs = len(grid.slab_faces(H, W)["slice0"])
    from repro.core import ebound
    Fb = len(ebound.slab_face_table(H, W))
    bad_slice = np.zeros((T, Fs), bool)
    bad_slab = np.zeros((T - 1, Fb), bool)
    bad_slice[1, 5] = True
    bad_slab[0, Fb - 1] = True
    mask = compressor._faces_to_vertex_mask(bad_slice, bad_slab, T, H, W)
    want = np.zeros(T * H * W, bool)
    want[grid.slab_faces(H, W)["slice0"][5].astype(np.int64) + H * W] = True
    want[ebound.slab_face_table(H, W)[Fb - 1].astype(np.int64)] = True
    assert (mask.reshape(-1) == want).all()
