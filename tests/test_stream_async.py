"""Async streaming engine: byte identity, overlap bookkeeping, errors.

The engine's core guarantee (DESIGN.md #11): ``compress_stream(...,
async_engine=True)`` moves WHEN work happens across three threads but
never WHAT is computed, so the container bytes equal the serial stream
-- which equal ``compress_tiled`` -- unit for unit, offset for offset.
"""
import io

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    TileGrid,
    compress_stream,
    compress_tiled,
    decompress_tiled,
)
from repro.data import synthetic


GRID = TileGrid(tile_h=8, tile_w=12, window_t=3)


@pytest.fixture(scope="module")
def field():
    return synthetic.double_gyre(T=10, H=16, W=24)


@pytest.fixture(scope="module")
def cfg():
    return CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                             dt=0.1, dx=2.0 / 23, dy=1.0 / 15, fused=True)


def _frames(u, v):
    return ((u[t], v[t]) for t in range(u.shape[0]))


def _vrange(u, v):
    return (float(min(u.min(), v.min())), float(max(u.max(), v.max())))


@pytest.fixture(scope="module")
def tiled_blob(field, cfg):
    u, v = field
    return compress_tiled(u, v, cfg, GRID)


def test_async_bytes_equal_tiled(field, cfg, tiled_blob):
    """Acceptance: async_engine=True produces bytes identical to
    compress_tiled (and the serial stream)."""
    u, v = field
    blob_a, stats = compress_stream(_frames(u, v), cfg, GRID,
                                    value_range=_vrange(u, v),
                                    async_engine=True)
    assert stats["async_engine"] is True
    assert blob_a == tiled_blob[0]
    blob_s, stats_s = compress_stream(_frames(u, v), cfg, GRID,
                                      value_range=_vrange(u, v))
    assert stats_s["async_engine"] is False
    assert blob_s == blob_a


def test_async_without_value_range(field, cfg, tiled_blob):
    """No value_range: the stream is materialized for the exact global
    range, but async_engine=True still runs the engine (not a silent
    serial downgrade) and still matches compress_tiled bytes."""
    u, v = field
    blob, stats = compress_stream(_frames(u, v), cfg, GRID,
                                  async_engine=True)
    assert stats["async_engine"] is True
    assert blob == tiled_blob[0]


def test_async_writes_to_sink(field, cfg, tiled_blob):
    u, v = field
    sink = io.BytesIO()
    blob, _ = compress_stream(_frames(u, v), cfg, GRID,
                              value_range=_vrange(u, v), sink=sink,
                              async_engine=True)
    assert blob is None
    assert sink.getvalue() == tiled_blob[0]


def test_async_with_track_index(field):
    """The sidecar index rides through the writer thread unchanged."""
    u, v = field
    cfg_i = CompressionConfig(eb=1e-2, mode="rel", predictor="mop",
                              dt=0.1, dx=2.0 / 23, dy=1.0 / 15,
                              fused=True, track_index=True)
    blob_t, _ = compress_tiled(u, v, cfg_i, GRID)
    blob_a, _ = compress_stream(_frames(u, v), cfg_i, GRID,
                                value_range=_vrange(u, v),
                                async_engine=True)
    assert blob_a == blob_t


def test_async_organic_forcing_bitwise():
    """Verify-loop cascades (rounds >= 1) still produce identical bytes
    when the stages overlap -- the fixpoint stays on the compute
    thread, so seam agreement is untouched."""
    rng = np.random.default_rng(3)
    T = 6
    base = 1.0e8
    u = (base + rng.normal(0, 100.0, (T, 16, 16))).astype(np.float32)
    v = (base + rng.normal(0, 100.0, (T, 16, 16))).astype(np.float32)
    cfg_f = CompressionConfig(eb=6.0, mode="abs", predictor="mop",
                              backend="xla", fused=True)
    grid = TileGrid(tile_h=7, tile_w=9, window_t=2)
    blob_t, st = compress_tiled(u, v, cfg_f, grid)
    assert st["verify_rounds"] >= 1
    blob_a, _ = compress_stream(_frames(u, v), cfg_f, grid,
                                value_range=_vrange(u, v),
                                async_engine=True)
    assert blob_a == blob_t
    um, vm = decompress_tiled(blob_t)
    ua, va = decompress_tiled(blob_a)
    assert np.array_equal(um, ua) and np.array_equal(vm, va)


def test_async_source_error_propagates(cfg):
    """An exception in the frame iterable surfaces on the caller thread
    and shuts the stage threads down instead of hanging."""
    u, v = synthetic.double_gyre(T=6, H=16, W=24)

    def bad_frames():
        for t in range(4):
            yield u[t], v[t]
        raise OSError("simulated source failure")

    with pytest.raises(OSError, match="simulated source failure"):
        compress_stream(bad_frames(), cfg, GRID,
                        value_range=_vrange(u, v), async_engine=True)


def test_async_sink_error_propagates(field, cfg):
    """A failing sink (disk full, closed socket) surfaces instead of
    silently dropping units."""
    u, v = field

    class BadSink:
        def __init__(self):
            self.n = 0

        def write(self, data):
            self.n += len(data)
            if self.n > 4096:
                raise OSError("simulated sink failure")

    with pytest.raises(OSError, match="simulated sink failure"):
        compress_stream(_frames(u, v), cfg, GRID,
                        value_range=_vrange(u, v), sink=BadSink(),
                        async_engine=True)


def test_async_too_few_frames(cfg):
    u, v = synthetic.double_gyre(T=2, H=16, W=24)
    with pytest.raises(ValueError, match="at least 2 frames"):
        compress_stream(iter([(u[0], v[0])]), cfg, GRID,
                        value_range=(-1.0, 1.0), async_engine=True)
    with pytest.raises(ValueError, match="at least 2 frames"):
        compress_stream(iter([(u[0], v[0])]), cfg, GRID,
                        value_range=(-1.0, 1.0))


def test_async_single_frame_tail_window(cfg):
    """T that leaves a 1-frame tail window: scheduler parity holds."""
    u, v = synthetic.double_gyre(T=7, H=16, W=24)
    grid = TileGrid(tile_h=16, tile_w=24, window_t=3)
    blob_t, _ = compress_tiled(u, v, cfg, grid)
    blob_a, _ = compress_stream(_frames(u, v), cfg, grid,
                                value_range=_vrange(u, v),
                                async_engine=True)
    assert blob_a == blob_t
