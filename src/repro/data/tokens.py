"""Deterministic synthetic LM token pipeline.

Stateless and seekable: batch t is a pure function of (seed, step), so
checkpoint/restart needs only the step counter (no iterator state), and
every data-parallel host slices its own shard -- the standard design for
large-cluster input pipelines.

The stream is a mixture of Zipf-distributed unigrams and short Markov
motifs so losses decrease plausibly during the example runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    batch: int             # global batch
    seq_len: int
    seed: int = 0


def global_batch(cfg: TokenPipelineConfig, step: int):
    """(tokens (B, S), labels (B, S)) for the given step."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC0FFEE])
    )
    B, S, V = cfg.batch, cfg.seq_len, cfg.vocab
    # zipf-ish unigrams
    ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
    toks = np.minimum(ranks - 1, V - 1)
    # motif injection: repeat short spans to create learnable structure
    n_motifs = max(S // 64, 1)
    for b in range(B):
        starts = rng.integers(0, max(S - 16, 1), n_motifs)
        for s in starts:
            span = min(8, S - int(s) - 1)
            if span > 2:
                toks[b, s + 1 : s + 1 + span] = toks[b, s : s + span]
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return tokens, labels


def host_batch(cfg: TokenPipelineConfig, step: int, host_id: int,
               n_hosts: int):
    """This host's shard of the global batch (contiguous rows)."""
    tokens, labels = global_batch(cfg, step)
    assert cfg.batch % n_hosts == 0
    per = cfg.batch // n_hosts
    sl = slice(host_id * per, (host_id + 1) * per)
    return tokens[sl], labels[sl]
