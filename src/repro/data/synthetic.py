"""Synthetic time-varying 2D vector fields with moving critical points.

Stand-ins for the paper's four datasets (SCF / CFVKV / HCBA / FS), all
analytic or procedurally generated so benchmarks are reproducible without
external downloads:

  vortex_street   -- advecting alternating Oseen vortices behind a
                     cylinder + uniform base flow (von Karman analogue)
  double_gyre     -- the classic time-periodic double gyre (moving saddle)
  heated_plume    -- oscillating buoyant plume from a streamfunction
                     (Boussinesq analogue; divergence-free)
  turbulence      -- band-limited random streamfunction with phase
                     advection (decaying-turbulence ensemble analogue)

All return (u, v) float32 arrays of shape (T, H, W).
"""
from __future__ import annotations

import numpy as np


def _grid(H, W, Lx=2.0, Ly=1.0):
    y = np.linspace(0.0, Ly, H, dtype=np.float64)
    x = np.linspace(0.0, Lx, W, dtype=np.float64)
    X, Y = np.meshgrid(x, y)  # (H, W)
    return X, Y


def vortex_street(T=64, H=64, W=128, n_vortices=6, u0=0.35, seed=0):
    X, Y = _grid(H, W)
    u = np.zeros((T, H, W), dtype=np.float64)
    v = np.zeros((T, H, W), dtype=np.float64)
    rc = 0.08
    for t in range(T):
        tt = t * 0.05
        uu = np.full_like(X, u0)
        vv = np.zeros_like(Y)
        for k in range(n_vortices):
            sgn = 1.0 if k % 2 == 0 else -1.0
            cx = (0.3 + 0.35 * k + u0 * tt) % 2.2 - 0.1
            cy = 0.5 + sgn * 0.12
            dx = X - cx
            dy = Y - cy
            r2 = dx * dx + dy * dy + 1e-12
            gamma = sgn * 0.25 * (1.0 - np.exp(-r2 / rc**2)) / r2
            uu += -gamma * dy
            vv += gamma * dx
        u[t] = uu
        v[t] = vv
    return u.astype(np.float32), v.astype(np.float32)


def double_gyre(T=64, H=64, W=128, A=0.1, eps=0.25, omega=2.0 * np.pi / 10.0):
    X, Y = _grid(H, W, Lx=2.0, Ly=1.0)
    u = np.zeros((T, H, W), dtype=np.float64)
    v = np.zeros((T, H, W), dtype=np.float64)
    for t in range(T):
        tt = t * 0.1
        a = eps * np.sin(omega * tt)
        b = 1.0 - 2.0 * a
        f = a * X**2 + b * X
        dfdx = 2.0 * a * X + b
        u[t] = -np.pi * A * np.sin(np.pi * f) * np.cos(np.pi * Y)
        v[t] = np.pi * A * np.cos(np.pi * f) * np.sin(np.pi * Y) * dfdx
    return u.astype(np.float32), v.astype(np.float32)


def heated_plume(T=64, H=96, W=48, seed=1):
    X, Y = _grid(H, W, Lx=1.0, Ly=2.0)
    rng = np.random.default_rng(seed)
    phases = rng.uniform(0, 2 * np.pi, size=4)
    u = np.zeros((T, H, W), dtype=np.float64)
    v = np.zeros((T, H, W), dtype=np.float64)
    for t in range(T):
        tt = t * 0.08
        # oscillating plume streamfunction: rising core + side rolls
        psi = (
            0.15 * np.sin(np.pi * X) * np.sin(0.5 * np.pi * Y + 0.3 * tt)
            + 0.05
            * np.sin(2 * np.pi * X + 0.8 * np.sin(tt + phases[0]))
            * np.sin(np.pi * Y + phases[1])
            + 0.03 * np.cos(3 * np.pi * X + tt) * np.sin(1.5 * np.pi * Y)
        )
        u[t] = np.gradient(psi, axis=0)   # d(psi)/dy
        v[t] = -np.gradient(psi, axis=1)  # -d(psi)/dx
    return u.astype(np.float32), v.astype(np.float32)


def turbulence(T=64, H=64, W=64, n_modes=12, seed=2):
    rng = np.random.default_rng(seed)
    X, Y = _grid(H, W, Lx=1.0, Ly=1.0)
    kx = rng.integers(1, 5, n_modes)
    ky = rng.integers(1, 5, n_modes)
    amp = rng.normal(0, 1.0, n_modes) / np.sqrt(kx**2 + ky**2)
    ph = rng.uniform(0, 2 * np.pi, n_modes)
    drift = rng.normal(0, 0.4, (n_modes, 2))
    u = np.zeros((T, H, W), dtype=np.float64)
    v = np.zeros((T, H, W), dtype=np.float64)
    for t in range(T):
        tt = t * 0.06
        psi = np.zeros_like(X)
        for m in range(n_modes):
            psi += amp[m] * np.sin(
                2 * np.pi * (kx[m] * (X - drift[m, 0] * tt))
                + ph[m]
            ) * np.sin(2 * np.pi * ky[m] * (Y - drift[m, 1] * tt))
        u[t] = np.gradient(psi, axis=0)
        v[t] = -np.gradient(psi, axis=1)
    return u.astype(np.float32), v.astype(np.float32)


def advected_turbulence(T=64, H=64, W=64, u0=3.0, amp=1.5, seed=4,
                        n_modes=24):
    """Taylor-hypothesis flow: small-scale frozen turbulence advected by
    a uniform carrier at ``u0`` grid cells per frame -- the
    advection-dominated regime where the paper's semi-Lagrangian
    predictor wins (Sec. VI).  Velocities are in grid-units/frame, so
    CFL metadata is dt=dx=dy=1."""
    rng = np.random.default_rng(seed)
    # periodic rough streamfunction on an extended domain
    Wp = W + int(np.ceil(u0 * T)) + 2
    x = np.arange(Wp)[None, :]
    y = np.arange(H)[:, None]
    psi = np.zeros((H, Wp))
    for _ in range(n_modes):
        kx = rng.integers(2, 12)
        ky = rng.integers(2, 12)
        ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
        a = rng.normal(0, 1.0) / np.hypot(kx, ky)
        psi += a * np.sin(2 * np.pi * kx * x / W + ph1) * np.sin(
            2 * np.pi * ky * y / H + ph2)
    uu = np.gradient(psi, axis=0)
    vv = -np.gradient(psi, axis=1)
    # normalize fluctuations to amp * u0 peak so critical points exist
    # (u = u0 + u' crosses zero where |u'| > u0) and their trajectories
    # advect with the frame -- the paper's hurricane-track scenario
    peak = max(np.abs(uu).max(), np.abs(vv).max(), 1e-9)
    uu *= amp * u0 / peak
    vv *= amp * u0 / peak
    u = np.zeros((T, H, W))
    v = np.zeros((T, H, W))
    for t in range(T):
        # pattern frozen in the co-moving frame; the sampling window
        # slides backward so features advect in +j at u0 px/frame
        # (u[t][j] == u[t-1][j - u0], the SL-predictable direction)
        s = u0 * (T - 1 - t)
        i0 = int(np.floor(s))
        a = s - i0
        u[t] = u0 + (1 - a) * uu[:, i0 : i0 + W] + a * uu[:, i0 + 1 : i0 + 1 + W]
        v[t] = (1 - a) * vv[:, i0 : i0 + W] + a * vv[:, i0 + 1 : i0 + 1 + W]
    return u.astype(np.float32), v.astype(np.float32)


DATASETS = {
    "vortex_street": vortex_street,
    "double_gyre": double_gyre,
    "heated_plume": heated_plume,
    "turbulence": turbulence,
    "advected_turbulence": advected_turbulence,
}


def load(name: str, **kw):
    return DATASETS[name](**kw)
