"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    pos: str = "rope"            # rope | mrope | sinusoidal
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE replaces the MLP every k-th layer
    d_ff_expert: int = 0         # expert hidden dim (defaults to d_ff)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # hybrid / ssm
    attn_every: int = 0          # jamba: 1 attention layer per this many
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64

    # enc-dec (audio)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # vlm
    mrope_sections: Tuple[int, ...] = ()

    # frontend stub: inputs arrive as precomputed embeddings
    embedding_inputs: bool = False

    dtype: str = "bfloat16"      # activation dtype
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"

    # decode KV-cache head padding: pad Hkv up to this count so the
    # cache head axis divides the model mesh axis (0 = off).  Padded
    # heads carry zero K/V/q and are sliced away after attention.
    decode_head_pad: int = 0

    # sequence-chunked attention threshold / chunk size
    attn_chunk: int = 1024
    scan_chunk: int = 64         # ssm/rwkv time-chunk

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def layer_kinds(self):
        """Per-layer (mixer, ffn) plan.

        mixer in {attn, mamba, rwkv}; ffn in {mlp, moe}.
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "rwkv"
            elif self.attn_every > 0:
                mixer = "attn" if i % self.attn_every == 0 else "mamba"
            else:
                mixer = "attn"
            if self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1):
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mixer, ffn))
        return kinds

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff = self.d_model, self.d_ff
        hd = self.head_dim
        n = 2 * self.vocab * d if not self.tie_embeddings else self.vocab * d
        ffe = self.d_ff_expert or ff
        for mixer, ffn in self.layer_kinds:
            if mixer == "attn":
                n += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif mixer == "mamba":
                di = self.mamba_expand * d
                dt_rank = max(d // 16, 1)
                n += d * 2 * di + di * self.mamba_d_conv
                n += di * (dt_rank + 2 * self.mamba_d_state) + dt_rank * di
                n += di * d + di * self.mamba_d_state + di
            else:  # rwkv
                n += 5 * d * d + d * d  # r,k,v,g,o + decay lora (approx)
            if ffn == "moe":
                n += self.n_experts * 3 * d * ffe + d * self.n_experts
                n += self.n_shared_experts * 3 * d * ffe
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                n += mult * d * ff
        if self.is_encoder_decoder:
            # encoder blocks + decoder cross-attention
            n += self.n_enc_layers * (4 * d * d + (3 if self.mlp == "swiglu" else 2) * d * ff)
            n += self.n_layers * 4 * d * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        ffe = self.d_ff_expert or self.d_ff
        dense = self.param_count() - sum(
            self.n_experts * 3 * d * ffe
            for _, ffn in self.layer_kinds if ffn == "moe"
        )
        active_moe = sum(
            (self.top_k) * 3 * d * ffe
            for _, ffn in self.layer_kinds if ffn == "moe"
        )
        return dense + active_moe
