"""Mixture-of-Experts layer (GShard-style capacity dispatch).

Top-k routing with capacity-bounded one-hot dispatch einsums -- the
pjit-friendly formulation: expert weights are sharded over the 'model'
mesh axis (expert parallelism) and the dispatch/combine einsums lower to
all-to-alls under GSPMD.  Token overflow beyond capacity is dropped
(standard for capacity-factor routing); an auxiliary load-balancing loss
is returned for training.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, pdtype_of


def moe_params(cfg: ModelConfig, key):
    d = cfg.d_model
    ff = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    pd = pdtype_of(cfg)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, d, e, pd, scale=0.02),
        "w_gate": (
            jax.random.normal(kg, (e, d, ff), jnp.float32) / math.sqrt(d)
        ).astype(pd),
        "w_up": (
            jax.random.normal(ku, (e, d, ff), jnp.float32) / math.sqrt(d)
        ).astype(pd),
        "w_down": (
            jax.random.normal(kd, (e, ff, d), jnp.float32) / math.sqrt(ff)
        ).astype(pd),
    }
    if cfg.n_shared_experts:
        ks1, ks2, ks3 = jax.random.split(ks, 3)
        ffs = ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks1, d, ffs, pd),
            "w_up": dense_init(ks2, d, ffs, pd),
            "w_down": dense_init(ks3, ffs, d, pd),
        }
    return p


GROUP_SIZE = 1024  # routing-group size: dispatch memory is O(G * Sg * E * Cg)


def apply_moe(cfg: ModelConfig, p, x):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar f32).

    Tokens are routed in independent groups of GROUP_SIZE (the standard
    GShard/MaxText trick): the one-hot dispatch tensor is
    (G, Sg, E, Cg) with Cg = Sg * K * cf / E, i.e. linear -- not
    quadratic -- in the total token count.
    """
    B, S, D = x.shape
    E = cfg.n_experts
    K = cfg.top_k
    N = B * S
    sg = min(GROUP_SIZE, N)
    if N % sg != 0:
        sg = N  # degenerate smoke-test sizes: one group
    G = N // sg
    cap = max(int(cfg.capacity_factor * K * sg / E), K)
    dt = x.dtype

    from .. import perfflags

    xf = x.reshape(G, sg, D)
    # router logits accumulate in f32 without materializing an f32 copy
    # of the activations (perf iteration H5)
    if perfflags.BASELINE:
        logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    else:
        logits = jnp.einsum(
            "gsd,de->gse", xf, p["router"].astype(dt),
            preferred_element_type=jnp.float32,
        )
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, Sg, E)

    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (G, Sg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # position of each (token, k) within its expert queue (per group)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (G, Sg, K, E)
    flat = onehot.reshape(G, sg * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat          # (G, Sg*K, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(G, sg, K)
    keep = pos < cap

    # dispatch tensor (G, Sg, E, cap) one-hot; combine weights alike
    disp = (
        jax.nn.one_hot(expert_idx, E, dtype=dt)[..., None]
        * jax.nn.one_hot(
            jnp.where(keep, pos, cap), cap + 1, dtype=dt
        )[:, :, :, None, :-1]
    )  # (G, Sg, K, E, cap)
    dispatch = jnp.sum(disp, axis=2)                          # (G, Sg, E, cap)
    combine = jnp.sum(disp * gate_vals[..., None, None].astype(dt), axis=2)

    expert_in = jnp.einsum("gsd,gsec->egcd", xf, dispatch)    # (E, G, cap, D)
    gate = jax.nn.silu(
        jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(dt))
    )
    up = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"].astype(dt))
    expert_out = jnp.einsum(
        "egcf,efd->egcd", gate * up, p["w_down"].astype(dt)
    )
    out = jnp.einsum("egcd,gsec->gsd", expert_out, combine)

    xflat = xf.reshape(N, D)
    out = out.reshape(N, D)
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jax.nn.silu(xflat @ sp["w_gate"].astype(dt))
        out = out + (g * (xflat @ sp["w_up"].astype(dt))) @ sp["w_down"].astype(dt)

    # load-balancing auxiliary loss (Switch/GShard form)
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), (0, 1)
    )
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * E
    return out.reshape(B, S, D), aux.astype(jnp.float32)
