"""Model assembly for every assigned architecture family.

Four model classes behind one functional API:

  DecoderLM  -- uniform [attn + (mlp|moe)] blocks: dense, moe, vlm(M-RoPE)
  HybridLM   -- Jamba super-blocks: scan over groups of (1 attn + 7 mamba)
                sublayers with MoE on alternating sublayers
  RWKVLM     -- RWKV6 (time-mix + channel-mix) blocks
  EncDecLM   -- Whisper-style encoder-decoder (stubbed conv frontend:
                inputs are precomputed frame embeddings)

API (all functional, jit/scan friendly):
  init(rng) -> params
  train_loss(params, batch) -> (loss f32, metrics dict)
  prefill(params, batch) -> (last-position logits, cache)
  decode_step(params, batch, cache) -> (logits, cache)

Layers are stacked and scanned (`lax.scan`) with `jax.checkpoint` on the
block body, so HLO size is O(1) in depth and saved activations are one
(B, S, D) carry per layer.  The cross-entropy is sequence-chunked with
vocab-sharded logits so the full (B, S, V) tensor never materializes.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from . import layers as L
from . import mamba as M
from . import moe as E
from . import rwkv as R
from .config import ModelConfig


# ----------------------------------------------------------------- loss

def chunked_ce_loss(cfg: ModelConfig, embed_params, x, labels, chunk=1024):
    """Cross-entropy over vocab-sharded logits, scanned over seq chunks."""
    B, S, D = x.shape
    if S % chunk != 0 or S <= chunk:
        chunk = S
    n = S // chunk
    xc = x.reshape(B, n, chunk, D)
    lc = labels.reshape(B, n, chunk)

    @jax.checkpoint
    def body(carry, inp):
        xi, li = inp                                  # (B, chunk, D), (B, chunk)
        logits = L.unembed(cfg, embed_params, xi)     # (B, chunk, V) f32
        logits = shd.act(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, li[..., None].astype(jnp.int32), -1)
        nll = lse - lab[..., 0]
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)),
    )
    return total / (B * S)


def _pos_angles(cfg: ModelConfig, batch, S):
    if cfg.pos == "mrope":
        pos = batch["position_ids"]                   # (3, B, S)
        return L.mrope_angles(pos, cfg.head_dim, cfg.rope_theta,
                              cfg.mrope_sections)
    if cfg.pos == "rope":
        B = batch["tokens"].shape[0] if "tokens" in batch else batch["embeds"].shape[0]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    return None


def _inputs_embed(cfg: ModelConfig, params, batch):
    if cfg.embedding_inputs:
        x = batch["embeds"].astype(L.dtype_of(cfg))
    else:
        x = L.embed(cfg, params["embed"], batch["tokens"])
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
    return shd.act(x, "hidden")


# =================================================================== DecoderLM

class DecoderLM:
    """Uniform decoder-only transformer (dense / moe / vlm)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- params
    def _block_init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "ln1": L.norm_params(cfg, k1),
            "attn": L.qkv_params(cfg, k2),
            "ln2": L.norm_params(cfg, k3),
        }
        if cfg.n_experts:
            p["moe"] = E.moe_params(cfg, k4)
        else:
            p["mlp"] = L.mlp_params(cfg, k4)
        return p

    def init(self, rng):
        cfg = self.cfg
        ke, kb, kf = jax.random.split(rng, 3)
        blocks = jax.vmap(self._block_init)(jax.random.split(kb, cfg.n_layers))
        return {
            "embed": L.embed_params(cfg, ke),
            "blocks": blocks,
            "ln_f": L.norm_params(cfg, kf),
        }

    # ---------------- forward
    def _block(self, bp, x, angles):
        cfg = self.cfg
        h = L.apply_norm(cfg, bp["ln1"], x)
        q, k, v = L.project_qkv(cfg, bp["attn"], h, angles)
        att = L.causal_attention(cfg, q, k, v)
        x = x + L.attn_out(cfg, bp["attn"], att)
        x = shd.act(x, "hidden")
        h = L.apply_norm(cfg, bp["ln2"], x)
        if cfg.n_experts:
            ff, aux = E.apply_moe(cfg, bp["moe"], h)
        else:
            ff, aux = L.apply_mlp(cfg, bp["mlp"], h), jnp.zeros((), jnp.float32)
        x = shd.act(x + ff, "hidden")
        return x, aux

    def _backbone(self, params, x, angles):
        block = jax.checkpoint(lambda xx, bp: self._block(bp, xx, angles))

        def body(xx, bp):
            return block(xx, bp)

        x, auxs = jax.lax.scan(body, x, params["blocks"])
        return L.apply_norm(self.cfg, params["ln_f"], x), jnp.sum(auxs)

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        angles = _pos_angles(cfg, batch, x.shape[1])
        x, aux = self._backbone(params, x, angles)
        loss = chunked_ce_loss(cfg, params["embed"], x, batch["labels"])
        total = loss + 0.01 * aux
        return total, {"ce": loss, "aux": aux}

    # ---------------- serving
    def prefill(self, params, batch):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        angles = _pos_angles(cfg, batch, x.shape[1])

        @jax.checkpoint
        def body(xx, bp):
            h = L.apply_norm(cfg, bp["ln1"], xx)
            q, k, v = L.project_qkv(cfg, bp["attn"], h, angles)
            att = L.causal_attention(cfg, q, k, v)
            xx = xx + L.attn_out(cfg, bp["attn"], att)
            h = L.apply_norm(cfg, bp["ln2"], xx)
            if cfg.n_experts:
                ff, _ = E.apply_moe(cfg, bp["moe"], h)
            else:
                ff = L.apply_mlp(cfg, bp["mlp"], h)
            return shd.act(xx + ff, "hidden"), (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x[:, -1:])
        cache = {
            "k": shd.act(ks, self._cache_kind()),
            "v": shd.act(vs, self._cache_kind()),
            "length": jnp.full((), x.shape[1], jnp.int32),
        }
        return logits, cache

    def _cache_kind(self):
        return "cache"

    def init_cache(self, batch_size, max_len, seq_sharded=False,
                   dtype=None):
        cfg = self.cfg
        dt = dtype or L.dtype_of(cfg)
        hkv = max(cfg.decode_head_pad, cfg.n_kv_heads)
        shape = (cfg.n_layers, batch_size, max_len, hkv, cfg.head_dim)
        kind = "cache_seqshard" if seq_sharded else "cache"
        return {
            "k": shd.act(jnp.zeros(shape, dt), kind),
            "v": shd.act(jnp.zeros(shape, dt), kind),
            "length": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, batch, cache):
        """batch: tokens (B, 1) [or embeds], position scalar in cache."""
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        pos = cache["length"]
        B = x.shape[0]
        if cfg.pos == "mrope":
            pid = jnp.broadcast_to(pos[None, None, None], (3, B, 1)).astype(jnp.int32)
            angles = L.mrope_angles(pid, cfg.head_dim, cfg.rope_theta,
                                    cfg.mrope_sections)
        elif cfg.pos == "rope":
            pid = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
            angles = L.rope_angles(pid, cfg.head_dim, cfg.rope_theta)
        else:
            angles = None

        kind = self._cache_kind()

        hkv_pad = max(cfg.decode_head_pad, cfg.n_kv_heads) - cfg.n_kv_heads

        def body(xx, scan_in):
            bp, kc, vc = scan_in
            h = L.apply_norm(cfg, bp["ln1"], xx)
            q, k, v = L.project_qkv(cfg, bp["attn"], h, angles)
            if hkv_pad:
                padw = [(0, 0), (0, 0), (0, hkv_pad), (0, 0)]
                k = jnp.pad(k, padw)
                v = jnp.pad(v, padw)
                q = jnp.pad(q, [(0, 0), (0, 0), (0, hkv_pad), (0, 0), (0, 0)])
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, L.quantize_kv(k, kc.dtype), pos, axis=1
            )
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, L.quantize_kv(v, vc.dtype), pos, axis=1
            )
            att = L.decode_attention(q, kc, vc, pos + 1)
            if hkv_pad:
                att = att[:, :, : cfg.n_kv_heads]
            xx = xx + L.attn_out(cfg, bp["attn"], att.astype(xx.dtype))
            h = L.apply_norm(cfg, bp["ln2"], xx)
            if cfg.n_experts:
                ff, _ = E.apply_moe(cfg, bp["moe"], h)
            else:
                ff = L.apply_mlp(cfg, bp["mlp"], h)
            return shd.act(xx + ff, "hidden"), (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x)
        new_cache = {
            "k": shd.act(ks, kind),
            "v": shd.act(vs, kind),
            "length": pos + 1,
        }
        return logits, new_cache


# =================================================================== HybridLM

class HybridLM(DecoderLM):
    """Jamba: super-blocks of `attn_every` sublayers (1 attn + rest mamba),
    MoE replacing the MLP on alternating sublayers."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.group = cfg.attn_every
        self.n_groups = cfg.n_layers // cfg.attn_every

    def _sub_init(self, key, idx):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"ln1": L.norm_params(cfg, k1), "ln2": L.norm_params(cfg, k3)}
        if idx == 0:
            p["attn"] = L.qkv_params(cfg, k2)
        else:
            p["mamba"] = M.mamba_params(cfg, k2)
        if idx % cfg.moe_every == cfg.moe_every - 1:
            p["moe"] = E.moe_params(cfg, k4)
        else:
            p["mlp"] = L.mlp_params(cfg, k4)
        return p

    def init(self, rng):
        cfg = self.cfg
        ke, kb, kf = jax.random.split(rng, 3)

        def group_init(key):
            ks = jax.random.split(key, self.group)
            return [self._sub_init(ks[i], i) for i in range(self.group)]

        groups = jax.vmap(group_init)(jax.random.split(kb, self.n_groups))
        return {
            "embed": L.embed_params(cfg, ke),
            "superblocks": groups,
            "ln_f": L.norm_params(cfg, kf),
        }

    def _sub_forward(self, idx, sp, x, angles):
        cfg = self.cfg
        h = L.apply_norm(cfg, sp["ln1"], x)
        if idx == 0:
            q, k, v = L.project_qkv(cfg, sp["attn"], h, angles)
            att = L.causal_attention(cfg, q, k, v)
            x = x + L.attn_out(cfg, sp["attn"], att)
        else:
            x = x + M.mamba_forward(cfg, sp["mamba"], h)
        x = shd.act(x, "hidden")
        h = L.apply_norm(cfg, sp["ln2"], x)
        if idx % cfg.moe_every == cfg.moe_every - 1:
            ff, aux = E.apply_moe(cfg, sp["moe"], h)
        else:
            ff, aux = L.apply_mlp(cfg, sp["mlp"], h), jnp.zeros((), jnp.float32)
        return shd.act(x + ff, "hidden"), aux

    def _backbone(self, params, x, angles):
        cfg = self.cfg

        @jax.checkpoint
        def body(xx, gp):
            aux = jnp.zeros((), jnp.float32)
            for i in range(self.group):
                xx, a = self._sub_forward(i, gp[i], xx, angles)
                aux = aux + a
            return xx, aux

        x, auxs = jax.lax.scan(body, x, params["superblocks"])
        return L.apply_norm(cfg, params["ln_f"], x), jnp.sum(auxs)

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        angles = _pos_angles(cfg, batch, x.shape[1])
        x, aux = self._backbone(params, x, angles)
        loss = chunked_ce_loss(cfg, params["embed"], x, batch["labels"])
        return loss + 0.01 * aux, {"ce": loss, "aux": aux}

    # ---------------- serving (attn KV cache + mamba states)
    def init_cache(self, batch_size, max_len, seq_sharded=False, dtype=None):
        cfg = self.cfg
        dt = dtype or L.dtype_of(cfg)
        di = M.d_inner(cfg)
        kind = "cache_seqshard" if seq_sharded else "cache"
        kv_shape = (self.n_groups, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {
            "k": shd.act(jnp.zeros(kv_shape, dt), kind),
            "v": shd.act(jnp.zeros(kv_shape, dt), kind),
            "conv": shd.act(
                jnp.zeros((self.n_groups, self.group - 1, batch_size,
                           cfg.mamba_d_conv - 1, di), dt), "hidden"),
            "ssm": shd.act(
                jnp.zeros((self.n_groups, self.group - 1, batch_size, di,
                           cfg.mamba_d_state), jnp.float32), "hidden"),
            "length": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        angles = _pos_angles(cfg, batch, x.shape[1])

        def body(xx, gp):
            convs, ssms = [], []
            k_out = v_out = None
            for i in range(self.group):
                sp = gp[i]
                h = L.apply_norm(cfg, sp["ln1"], xx)
                if i == 0:
                    q, k, v = L.project_qkv(cfg, sp["attn"], h, angles)
                    att = L.causal_attention(cfg, q, k, v)
                    xx = xx + L.attn_out(cfg, sp["attn"], att)
                    k_out, v_out = k, v
                else:
                    out, st = M.mamba_forward(cfg, sp["mamba"], h,
                                              return_state=True)
                    xx = xx + out
                    convs.append(st["conv"])
                    ssms.append(st["ssm"])
                xx = shd.act(xx, "hidden")
                h = L.apply_norm(cfg, sp["ln2"], xx)
                if i % cfg.moe_every == cfg.moe_every - 1:
                    ff, _ = E.apply_moe(cfg, sp["moe"], h)
                else:
                    ff = L.apply_mlp(cfg, sp["mlp"], h)
                xx = shd.act(xx + ff, "hidden")
            return xx, (k_out, v_out, jnp.stack(convs), jnp.stack(ssms))

        x, (ks, vs, convs, ssms) = jax.lax.scan(body, x, params["superblocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x[:, -1:])
        cache = {
            "k": shd.act(ks, "cache"),
            "v": shd.act(vs, "cache"),
            "conv": convs,
            "ssm": ssms,
            "length": jnp.full((), x.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        pos = cache["length"]
        B = x.shape[0]
        pid = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        angles = L.rope_angles(pid, cfg.head_dim, cfg.rope_theta)

        def body(xx, scan_in):
            gp, kc, vc, conv_st, ssm_st = scan_in
            new_conv, new_ssm = [], []
            mi = 0
            for i in range(self.group):
                sp = gp[i]
                h = L.apply_norm(cfg, sp["ln1"], xx)
                if i == 0:
                    q, k, v = L.project_qkv(cfg, sp["attn"], h, angles)
                    kc = jax.lax.dynamic_update_slice_in_dim(
                        kc, L.quantize_kv(k, kc.dtype), pos, axis=1)
                    vc = jax.lax.dynamic_update_slice_in_dim(
                        vc, L.quantize_kv(v, vc.dtype), pos, axis=1)
                    att = L.decode_attention(q, kc, vc, pos + 1)
                    xx = xx + L.attn_out(cfg, sp["attn"], att.astype(xx.dtype))
                else:
                    st = {"conv": conv_st[mi], "ssm": ssm_st[mi]}
                    out, st2 = M.mamba_decode_step(cfg, sp["mamba"], h, st)
                    new_conv.append(st2["conv"])
                    new_ssm.append(st2["ssm"])
                    xx = xx + out
                    mi += 1
                h = L.apply_norm(cfg, sp["ln2"], xx)
                if i % cfg.moe_every == cfg.moe_every - 1:
                    ff, _ = E.apply_moe(cfg, sp["moe"], h)
                else:
                    ff = L.apply_mlp(cfg, sp["mlp"], h)
                xx = shd.act(xx + ff, "hidden")
            return xx, (kc, vc, jnp.stack(new_conv), jnp.stack(new_ssm))

        x, (ks, vs, convs, ssms) = jax.lax.scan(
            body, x,
            (params["superblocks"], cache["k"], cache["v"], cache["conv"],
             cache["ssm"]),
        )
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x)
        new_cache = {
            "k": ks, "v": vs, "conv": convs, "ssm": ssms,
            "length": pos + 1,
        }
        return logits, new_cache


# =================================================================== RWKVLM

class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _block_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_params(cfg, k1),
            "ln2": L.norm_params(cfg, k2),
            "rwkv": R.rwkv_params(cfg, k1),
        }

    def init(self, rng):
        cfg = self.cfg
        ke, kb, kf = jax.random.split(rng, 3)
        blocks = jax.vmap(self._block_init)(jax.random.split(kb, cfg.n_layers))
        return {
            "embed": L.embed_params(cfg, ke),
            "blocks": blocks,
            "ln_f": L.norm_params(cfg, kf),
        }

    def _backbone(self, params, x):
        cfg = self.cfg

        @jax.checkpoint
        def body(xx, bp):
            h = L.apply_norm(cfg, bp["ln1"], xx)
            tm, _, _ = R.time_mix(cfg, bp["rwkv"], h)
            xx = xx + tm
            h = L.apply_norm(cfg, bp["ln2"], xx)
            cm, _ = R.channel_mix(cfg, bp["rwkv"], h)
            return shd.act(xx + cm, "hidden"), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        return L.apply_norm(cfg, params["ln_f"], x)

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)
        x = self._backbone(params, x)
        loss = chunked_ce_loss(cfg, params["embed"], x, batch["labels"])
        return loss, {"ce": loss}

    def init_cache(self, batch_size, max_len=0, seq_sharded=False, dtype=None):
        cfg = self.cfg
        H = R.n_heads(cfg)
        hd = cfg.rwkv_head_dim
        dt = dtype or L.dtype_of(cfg)
        Lc = cfg.n_layers
        return {
            "wkv": shd.act(jnp.zeros((Lc, batch_size, H, hd, hd), jnp.float32),
                           "state"),
            "tm_x": jnp.zeros((Lc, batch_size, 1, cfg.d_model), dt),
            "cm_x": jnp.zeros((Lc, batch_size, 1, cfg.d_model), dt),
            "length": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch):
        """Forward over the prompt carrying states (chunked recurrence)."""
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)

        @jax.checkpoint
        def body(xx, bp):
            h = L.apply_norm(cfg, bp["ln1"], xx)
            tm, s_fin, lx = R.time_mix(cfg, bp["rwkv"], h)
            xx = xx + tm
            h2 = L.apply_norm(cfg, bp["ln2"], xx)
            cm, lcx = R.channel_mix(cfg, bp["rwkv"], h2)
            return shd.act(xx + cm, "hidden"), (s_fin, lx, lcx)

        x, (wkv, tm_x, cm_x) = jax.lax.scan(body, x, params["blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x[:, -1:])
        cache = {
            "wkv": wkv, "tm_x": tm_x, "cm_x": cm_x,
            "length": jnp.full((), x.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        cfg = self.cfg
        x = _inputs_embed(cfg, params, batch)

        def body(xx, scan_in):
            bp, wkv, tm_x, cm_x = scan_in
            h = L.apply_norm(cfg, bp["ln1"], xx)
            tm, wkv2, lx = R.time_mix_decode(cfg, bp["rwkv"], h, wkv, tm_x)
            xx = xx + tm
            h2 = L.apply_norm(cfg, bp["ln2"], xx)
            cm, lcx = R.channel_mix(cfg, bp["rwkv"], h2, cm_x)
            return xx + cm, (wkv2, lx, lcx)

        x, (wkv, tm_x, cm_x) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["tm_x"], cache["cm_x"])
        )
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x)
        return logits, {
            "wkv": wkv, "tm_x": tm_x, "cm_x": cm_x,
            "length": cache["length"] + 1,
        }


# =================================================================== EncDecLM

class EncDecLM:
    """Whisper-style enc-dec backbone.  Encoder inputs are precomputed
    frame embeddings (conv frontend stub), sinusoidal positions."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _enc_block_init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "ln1": L.norm_params(cfg, k1),
            "attn": L.qkv_params(cfg, k2),
            "ln2": L.norm_params(cfg, k3),
            "mlp": L.mlp_params(cfg, k4),
        }

    def _dec_block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        return {
            "ln1": L.norm_params(cfg, ks[0]),
            "self_attn": L.qkv_params(cfg, ks[1]),
            "ln_x": L.norm_params(cfg, ks[2]),
            "cross_attn": L.qkv_params(cfg, ks[3]),
            "ln2": L.norm_params(cfg, ks[4]),
            "mlp": L.mlp_params(cfg, ks[5]),
        }

    def init(self, rng):
        cfg = self.cfg
        ke, kb1, kb2, kf1, kf2 = jax.random.split(rng, 5)
        enc = jax.vmap(self._enc_block_init)(
            jax.random.split(kb1, cfg.n_enc_layers))
        dec = jax.vmap(self._dec_block_init)(
            jax.random.split(kb2, cfg.n_layers))
        return {
            "embed": L.embed_params(cfg, ke),
            "enc_blocks": enc,
            "dec_blocks": dec,
            "ln_enc": L.norm_params(cfg, kf1),
            "ln_f": L.norm_params(cfg, kf2),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(L.dtype_of(cfg))
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        x = shd.act(x, "hidden")

        @jax.checkpoint
        def body(xx, bp):
            h = L.apply_norm(cfg, bp["ln1"], xx)
            q, k, v = L.project_qkv(cfg, bp["attn"], h)
            att = L.causal_attention(cfg, q, k, v, causal=False)
            xx = xx + L.attn_out(cfg, bp["attn"], att)
            h = L.apply_norm(cfg, bp["ln2"], xx)
            return shd.act(xx + L.apply_mlp(cfg, bp["mlp"], h), "hidden"), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.apply_norm(cfg, params["ln_enc"], x)

    def _dec_block(self, bp, x, enc_out, self_causal=True):
        cfg = self.cfg
        h = L.apply_norm(cfg, bp["ln1"], x)
        q, k, v = L.project_qkv(cfg, bp["self_attn"], h)
        att = L.causal_attention(cfg, q, k, v, causal=self_causal)
        x = x + L.attn_out(cfg, bp["self_attn"], att)
        h = L.apply_norm(cfg, bp["ln_x"], x)
        q, _, _ = L.project_qkv(cfg, bp["cross_attn"], h)
        ek = enc_out @ bp["cross_attn"]["wk"].astype(enc_out.dtype)
        ev = enc_out @ bp["cross_attn"]["wv"].astype(enc_out.dtype)
        B, Se, _ = enc_out.shape
        ek = ek.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        ev = ev.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        att = L.causal_attention(cfg, q, ek, ev, causal=False)
        x = x + L.attn_out(cfg, bp["cross_attn"], att)
        h = L.apply_norm(cfg, bp["ln2"], x)
        return shd.act(x + L.apply_mlp(cfg, bp["mlp"], h), "hidden")

    def train_loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = L.embed(cfg, params["embed"], batch["tokens"])
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        @jax.checkpoint
        def body(xx, bp):
            return self._dec_block(bp, xx, enc_out), None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        loss = chunked_ce_loss(cfg, params["embed"], x, batch["labels"])
        return loss, {"ce": loss}

    def init_cache(self, batch_size, max_len, enc_len, dtype=None):
        cfg = self.cfg
        dt = dtype or L.dtype_of(cfg)
        Lc = cfg.n_layers
        mk = lambda s: shd.act(jnp.zeros(s, dt), "cache")
        return {
            "k": mk((Lc, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)),
            "v": mk((Lc, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)),
            "ek": mk((Lc, batch_size, enc_len, cfg.n_kv_heads, cfg.head_dim)),
            "ev": mk((Lc, batch_size, enc_len, cfg.n_kv_heads, cfg.head_dim)),
            "length": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch):
        """Encode frames, project cross-KV, run decoder prompt."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = L.embed(cfg, params["embed"], batch["tokens"])
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        def body(xx, bp):
            B, Se, _ = enc_out.shape
            ek = (enc_out @ bp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(
                B, Se, cfg.n_kv_heads, cfg.head_dim)
            ev = (enc_out @ bp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(
                B, Se, cfg.n_kv_heads, cfg.head_dim)
            h = L.apply_norm(cfg, bp["ln1"], xx)
            q, k, v = L.project_qkv(cfg, bp["self_attn"], h)
            att = L.causal_attention(cfg, q, k, v, causal=True)
            xx = xx + L.attn_out(cfg, bp["self_attn"], att)
            h = L.apply_norm(cfg, bp["ln_x"], xx)
            q, _, _ = L.project_qkv(cfg, bp["cross_attn"], h)
            att = L.causal_attention(cfg, q, ek, ev, causal=False)
            xx = xx + L.attn_out(cfg, bp["cross_attn"], att)
            h = L.apply_norm(cfg, bp["ln2"], xx)
            xx = shd.act(xx + L.apply_mlp(cfg, bp["mlp"], h), "hidden")
            return xx, (k, v, ek, ev)

        x, (ks, vs, eks, evs) = jax.lax.scan(body, x, params["dec_blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x[:, -1:])
        cache = {
            "k": ks, "v": vs, "ek": eks, "ev": evs,
            "length": jnp.full((), x.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, batch, cache):
        cfg = self.cfg
        x = L.embed(cfg, params["embed"], batch["tokens"])
        pos = cache["length"]
        pe_table = L.sinusoidal_positions(cache["k"].shape[2], cfg.d_model,
                                          x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pe_table, pos, 1, axis=0)[None]

        def body(xx, scan_in):
            bp, kc, vc, ek, ev = scan_in
            h = L.apply_norm(cfg, bp["ln1"], xx)
            q, k, v = L.project_qkv(cfg, bp["self_attn"], h)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, L.quantize_kv(k, kc.dtype), pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, L.quantize_kv(v, vc.dtype), pos, 1)
            att = L.decode_attention(q, kc, vc, pos + 1)
            xx = xx + L.attn_out(cfg, bp["self_attn"], att.astype(xx.dtype))
            h = L.apply_norm(cfg, bp["ln_x"], xx)
            q, _, _ = L.project_qkv(cfg, bp["cross_attn"], h)
            att = L.decode_attention(q, ek, ev, ek.shape[1])
            xx = xx + L.attn_out(cfg, bp["cross_attn"], att.astype(xx.dtype))
            h = L.apply_norm(cfg, bp["ln2"], xx)
            xx = xx + L.apply_mlp(cfg, bp["mlp"], h)
            return xx, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["ek"], cache["ev"])
        )
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(cfg, params["embed"], x)
        new_cache = dict(cache)
        new_cache.update({"k": ks, "v": vs, "length": pos + 1})
        return logits, new_cache


def build_model(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        return RWKVLM(cfg)
    if cfg.attn_every > 0:
        return HybridLM(cfg)
    return DecoderLM(cfg)
