"""Mamba (S6) selective-state-space mixer for the hybrid (Jamba) family.

Training path: time-chunked — ``lax.scan`` over chunks of ``scan_chunk``
tokens with an intra-chunk ``associative_scan`` (log-depth), so the HLO
stays small and the live state is (B, d_inner, d_state) per boundary.
Decode path: O(1) recurrent update carrying (conv_state, ssm_state).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, pdtype_of


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba_expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return max(cfg.d_model // 16, 1)


def mamba_params(cfg: ModelConfig, key):
    d = cfg.d_model
    di = d_inner(cfg)
    ds = cfg.mamba_d_state
    dr = dt_rank(cfg)
    dc = cfg.mamba_d_conv
    pd = pdtype_of(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(k1, d, 2 * di, pd),
        "conv_w": (jax.random.normal(k2, (dc, di), jnp.float32) * 0.1).astype(pd),
        "conv_b": jnp.zeros((di,), pd),
        "x_proj": dense_init(k3, di, dr + 2 * ds, pd),
        "dt_proj": dense_init(k4, dr, di, pd),
        "dt_bias": jnp.zeros((di,), pd),
        "a_log": jnp.log(a).astype(pd),       # A = -exp(a_log)
        "d_skip": jnp.ones((di,), pd),
        "out_proj": dense_init(k5, di, d, pd),
    }


def _ssm_inputs(cfg, p, xc):
    """xc (B, L, di) post-conv activations -> discretized (abar, bx, c)."""
    ds = cfg.mamba_d_state
    dr = dt_rank(cfg)
    dt_bc = xc @ p["x_proj"].astype(xc.dtype)            # (B, L, dr+2ds)
    dt = dt_bc[..., :dr] @ p["dt_proj"].astype(xc.dtype) + p["dt_bias"].astype(xc.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # (B, L, di)
    b = dt_bc[..., dr : dr + ds].astype(jnp.float32)      # (B, L, ds)
    c = dt_bc[..., dr + ds :].astype(jnp.float32)         # (B, L, ds)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (di, ds)
    abar = jnp.exp(dt[..., None] * a[None, None])         # (B, L, di, ds)
    bx = (dt * xc.astype(jnp.float32))[..., None] * b[..., None, :]
    return abar, bx, c


def _chunk_scan(abar, bx, h0):
    """Intra-chunk associative scan of h_t = abar_t h_{t-1} + bx_t.

    Perf note (EXPERIMENTS.md #Perf, H6): a sequential lax.scan variant
    ("fused-kernel formulation") was implemented and MEASURED SLOWER on
    the corrected byte accounting (355.7 s vs 330.3 s memory term for the
    398B train cell) -- the log-depth combine tree's intermediates are
    transient and cheaper than 64 per-step fusion round-trips + scan VJP
    residuals.  Hypothesis refuted; associative form retained.
    """

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_acc, b_acc = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    h = a_acc * h0[:, None] + b_acc                        # (B, L, di, ds)
    return h, h[:, -1]


def causal_conv(cfg, p, x, conv_state=None):
    """Depthwise causal conv along time.  x (B, L, di)."""
    dc = cfg.mamba_d_conv
    w = p["conv_w"].astype(x.dtype)                        # (dc, di)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, L+dc-1, di)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(dc)
    )
    new_state = xp[:, -(dc - 1) :] if dc > 1 else pad[:, :0]
    return out + p["conv_b"].astype(x.dtype), new_state


def mamba_forward(cfg: ModelConfig, p, x, chunk=None, return_state=False):
    """Training/prefill forward.  x (B, S, D) -> (B, S, D) [, final state]."""
    B, S, D = x.shape
    di = d_inner(cfg)
    ds = cfg.mamba_d_state
    chunk = chunk or cfg.scan_chunk
    dt = x.dtype

    xz = x @ p["in_proj"].astype(dt)                       # (B, S, 2di)
    xin, z = xz[..., :di], xz[..., di:]
    xc, _ = causal_conv(cfg, p, xin)
    xc = jax.nn.silu(xc)

    if S % chunk != 0:
        chunk = S  # degenerate sizes: single chunk
    n_chunks = S // chunk
    xc_c = xc.reshape(B, n_chunks, chunk, di)

    # remat the chunk body: the (B, chunk, di, ds) discretized tensors are
    # recomputed in the backward pass instead of being saved per chunk --
    # 3 x 67 MB transient instead of ~13 GB resident per mamba layer for
    # the 398B train cell (perf iteration H2); y is cast to the activation
    # dtype inside the body so only bf16 leaves the scan (H3).
    from .. import perfflags

    def body(h, xck):
        abar, bx, c = _ssm_inputs(cfg, p, xck)
        h_seq, h_last = _chunk_scan(abar, bx, h)
        y = jnp.einsum("blds,bls->bld", h_seq, c)          # (B, chunk, di)
        return h_last, (y if perfflags.BASELINE else y.astype(dt))

    body = perfflags.checkpoint_if_optimized(body)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_fin, ys = jax.lax.scan(body, h0, jnp.moveaxis(xc_c, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = (y + xc * p["d_skip"].astype(dt)).astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    if return_state:
        dc = cfg.mamba_d_conv
        conv_state = xin[:, -(dc - 1):] if dc > 1 else xin[:, :0]
        return out, {"conv": conv_state, "ssm": h_fin}
    return out


def mamba_init_state(cfg: ModelConfig, batch, dtype=jnp.float32):
    di = d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }


def mamba_decode_step(cfg: ModelConfig, p, x, state):
    """x (B, 1, D); state dict -> (out (B, 1, D), new state)."""
    B = x.shape[0]
    di = d_inner(cfg)
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    xin, z = xz[..., :di], xz[..., di:]
    xc, conv_state = causal_conv(cfg, p, xin, state["conv"])
    xc = jax.nn.silu(xc)
    abar, bx, c = _ssm_inputs(cfg, p, xc)                  # L = 1
    h = state["ssm"] * abar[:, 0] + bx[:, 0]               # (B, di, ds)
    y = jnp.einsum("bds,bs->bd", h, c[:, 0])[:, None]      # (B, 1, di)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, {"conv": conv_state.astype(state["conv"].dtype), "ssm": h}
