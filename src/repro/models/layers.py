"""Shared layer primitives: norms, rotary embeddings (RoPE / M-RoPE),
GQA attention (full, query-chunked, decode), MLPs, embeddings.

Conventions
-----------
* params are plain nested dicts of jnp arrays (a pytree), `param_dtype`
  (default f32) at rest, cast to `dtype` (default bf16) at use.
* activations: (B, S, D).  Attention works on (B, S, Hkv, G, Dh) grouped
  heads so GQA never materializes repeated KV.
* KV caches store un-repeated KV heads: (B, S, Hkv, Dh).
* every function is functional + jit/scan friendly; dtypes are explicit
  everywhere (the repo enables jax x64 globally for the compression
  library, so nothing here may rely on default dtypes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------- init

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- norms

def rmsnorm(x, scale, eps=1e-6):
    """RMS statistics accumulate in f32 via the dot's accumulator
    (preferred_element_type) -- no f32 copy of the activation is ever
    materialized (perf iteration H5; REPRO_PERF_BASELINE=1 restores the
    classic f32-materializing form)."""
    from .. import perfflags

    if perfflags.BASELINE:
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps)
        return (out * scale.astype(jnp.float32)).astype(x.dtype)
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_params(cfg: ModelConfig, key):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), pdtype_of(cfg))}
    return {
        "scale": jnp.ones((cfg.d_model,), pdtype_of(cfg)),
        "bias": jnp.zeros((cfg.d_model,), pdtype_of(cfg)),
    }


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ----------------------------------------------------------------- rotary

def rope_angles(positions, dim, theta):
    """positions (..., S) int32 -> (..., S, dim//2) f32 angles."""
    half = dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / float(half))
    )
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, angles):
    """x (B, S, ..., Dh); angles broadcastable to (B, S, 1, .., Dh//2)."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def mrope_angles(position_ids, dim, theta, sections):
    """M-RoPE (Qwen2-VL): position_ids (3, B, S); sections sum to dim//2.

    Each contiguous frequency section takes its angle from the matching
    positional stream (temporal / height / width).
    """
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / float(half))
    )
    # for each of the half frequencies pick stream sec_id[f] (static)
    import numpy as _np

    sec_id = jnp.asarray(
        _np.repeat(_np.arange(len(sections)), _np.asarray(sections))
    )
    # position_ids: (3, B, S) -> (B, S, half)
    p = jnp.moveaxis(position_ids.astype(jnp.float32), 0, -1)  # (B, S, 3)
    psel = jnp.take(p, sec_id, axis=-1)                         # (B, S, half)
    return psel * freqs


# ----------------------------------------------------------------- attention

def qkv_params(cfg: ModelConfig, key):
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, pd),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, pd),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, pd),
        "wo": dense_init(ko, cfg.n_heads * hd, d, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), pd)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
    return p


def project_qkv(cfg: ModelConfig, p, x, angles=None):
    """x (B, S, D) -> q (B, S, Hkv, G, Dh), k/v (B, S, Hkv, Dh).

    The *flat* (B, S, H*Dh) projections are constrained to shard their
    head-product dim over the model axis before the (Hkv, G, Dh) split:
    H*Dh is 16-divisible for every assigned arch even when Hkv alone is
    not, so GSPMD keeps attention logits head-sharded instead of
    replicating them (perf iteration H1, EXPERIMENTS.md #Perf)."""
    from ..parallel import sharding as shd

    B, S, _ = x.shape
    hd = cfg.head_dim
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    dt = x.dtype
    from .. import perfflags

    q = x @ p["wq"].astype(dt)
    if not perfflags.BASELINE:
        q = shd.act(q, "logits")
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, hkv, g, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    if angles is not None:
        q = apply_rope(q, angles[:, :, None, None, :])
        k = apply_rope(k, angles[:, :, None, :])
    return q, k, v


def _softmax_attend(q, k, v, mask, scale):
    """q (B,Sq,Hkv,G,Dh), k/v (B,Skv,Hkv,Dh), mask (Sq,Skv) or None."""
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out


def causal_attention(cfg: ModelConfig, q, k, v, causal=True, chunk=None):
    """Full or query-chunked causal attention.

    Query-chunking bounds the live attention matrix to
    (B, chunk, Hkv, G, Skv) -- the TPU-memory-sane formulation for the
    32k/500k cells (flash-attention is the Pallas analogue; XLA fuses the
    masked softmax here, and the chunk loop is a scan).
    """
    B, Sq, hkv, g, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    chunk = chunk or cfg.attn_chunk
    if Sq <= chunk or Sq % chunk != 0:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((Sq, Skv), dtype=bool), k=Skv - Sq)
        return _softmax_attend(q, k, v, mask, scale)

    n_chunks = Sq // chunk
    qc = q.reshape(B, n_chunks, chunk, hkv, g, hd)

    def body(carry, xs):
        qi, start = xs
        pos_q = start + jnp.arange(chunk)
        pos_k = jnp.arange(Skv)
        mask = pos_k[None, :] <= (pos_q[:, None] + (Skv - Sq))
        out = _softmax_attend(qi, k, v, mask if causal else None, scale)
        return carry, out

    starts = jnp.arange(n_chunks) * chunk
    _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qc, 1, 0), starts))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, hkv, g, hd)


KV_INT8_SCALE = 16.0  # fixed-point scale for int8 KV caches


def quantize_kv(x, cache_dtype):
    """bf16 KV -> cache dtype (int8 caches use a fixed 16x scale)."""
    if jnp.dtype(cache_dtype) == jnp.int8:
        return jnp.clip(
            jnp.round(x.astype(jnp.float32) * KV_INT8_SCALE), -127, 127
        ).astype(jnp.int8)
    return x.astype(cache_dtype)


def _dequant_kv(x):
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) * (1.0 / KV_INT8_SCALE)
    return x.astype(jnp.float32)


def decode_attention(q, k_cache, v_cache, length):
    """Single-step attention against a (possibly sequence-sharded) cache.

    q (B, 1, Hkv, G, Dh); caches (B, S, Hkv, Dh); length: valid prefix.
    Reductions over S lower to mesh collectives when S is sharded
    (long-context cells shard S over the 'data' axis).  int8 caches are
    dequantized at use (qwen32b decode_32k -- DESIGN.md #6).
    """
    B, _, hkv, g, hd = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32), _dequant_kv(k_cache)
    ) * scale
    valid = (jnp.arange(S) < length)[None, None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    vf = _dequant_kv(v_cache) if v_cache.dtype == jnp.int8 else v_cache
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(vf.dtype), vf)
    return out


def attn_out(cfg: ModelConfig, p, out):
    B, S = out.shape[0], out.shape[1]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(out.dtype)


# ----------------------------------------------------------------- mlp

def mlp_params(cfg: ModelConfig, key, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    pd = pdtype_of(cfg)
    if cfg.mlp == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d, ff, pd),
            "w_up": dense_init(k2, d, ff, pd),
            "w_down": dense_init(k3, ff, d, pd),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": dense_init(k1, d, ff, pd),
        "b_up": jnp.zeros((ff,), pd),
        "w_down": dense_init(k2, ff, d, pd),
        "b_down": jnp.zeros((d,), pd),
    }


def apply_mlp(cfg: ModelConfig, p, x):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"].astype(dt))
        up = x @ p["w_up"].astype(dt)
        return (gate * up) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ----------------------------------------------------------------- embeddings

def embed_params(cfg: ModelConfig, key):
    pd = pdtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, cfg.vocab, cfg.d_model, pd, scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, cfg.d_model, cfg.vocab, pd)
    return p


def embed(cfg: ModelConfig, p, tokens):
    return p["embedding"].astype(dtype_of(cfg))[tokens]


def unembed(cfg: ModelConfig, p, x):
    dt = x.dtype
    if cfg.tie_embeddings:
        return (x @ p["embedding"].astype(dt).T).astype(jnp.float32)
    return (x @ p["lm_head"].astype(dt)).astype(jnp.float32)


def sinusoidal_positions(S, d, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / float(d))
    pe = jnp.zeros((S, d), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)
