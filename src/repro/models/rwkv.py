"""RWKV6 ("Finch") attention-free mixer with data-dependent decay.

Time-mix recurrence per head (state S in R^{dk x dv}):

    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

with the *data-dependent* per-channel decay w_t = exp(-exp(w0 + LoRA(x)))
-- the signature RWKV6 feature.  Token-shift mixing uses static
per-channel interpolation (the dynamic-ddlerp refinement is noted as a
simplification in DESIGN.md); output uses per-head RMS normalization in
place of GroupNorm.

Training path is chunk-parallel (GLA-style): within a chunk all decay
exponents appear only as *differences* cum_{t-1} - cum_s <= 0, so every
exp() is <= 1 and fp32-safe; across chunks a ``lax.scan`` carries S.
Decode is the O(1) recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, pdtype_of

LORA_RANK = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_params(cfg: ModelConfig, key):
    d = cfg.d_model
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 10)
    p = {
        "mix_r": jnp.full((d,), 0.5, pd),
        "mix_k": jnp.full((d,), 0.5, pd),
        "mix_v": jnp.full((d,), 0.5, pd),
        "mix_g": jnp.full((d,), 0.5, pd),
        "mix_w": jnp.full((d,), 0.5, pd),
        "wr": dense_init(ks[0], d, d, pd),
        "wk": dense_init(ks[1], d, d, pd),
        "wv": dense_init(ks[2], d, d, pd),
        "wg": dense_init(ks[3], d, d, pd),
        "wo": dense_init(ks[4], d, d, pd),
        "w0": jnp.full((d,), -1.0, pd),             # base log-log decay
        "w_lora_a": dense_init(ks[5], d, LORA_RANK, pd),
        "w_lora_b": dense_init(ks[6], LORA_RANK, d, pd, scale=0.01),
        "u": (jax.random.normal(ks[7], (d,), jnp.float32) * 0.1).astype(pd),
        "ln_scale": jnp.ones((d,), pd),
        # channel mix
        "cmix_k": jnp.full((d,), 0.5, pd),
        "cmix_r": jnp.full((d,), 0.5, pd),
        "c_wk": dense_init(ks[8], d, cfg.d_ff, pd),
        "c_wv": dense_init(ks[9], cfg.d_ff, d, pd),
        "c_wr": dense_init(jax.random.fold_in(ks[9], 1), d, d, pd),
    }
    return p


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` for t = 0).  x (B, S, D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _decay(cfg, p, xw):
    """Data-dependent per-channel decay, log-space.  Returns log(w) <= 0."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
    lora = lora @ p["w_lora_b"].astype(jnp.float32)
    loglog = p["w0"].astype(jnp.float32) + lora
    return -jnp.exp(loglog)                          # log w in (-inf, 0)


def _head_split(x, H, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, H, hd)


def _headnorm(x, scale):
    """Per-head RMS normalization of (B, S, H, hd)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + 1e-6)
    B, S, H, hd = x.shape
    return (out.reshape(B, S, H * hd) * scale.astype(jnp.float32)).astype(x.dtype)


def time_mix(cfg: ModelConfig, p, x, chunk=None, state=None, last_x=None):
    """Chunk-parallel WKV.  x (B, S, D).  state (B, H, dk, dv) or None.

    Returns (out, final_state, final_x) so decode/prefill can chain.
    """
    B, S, D = x.shape
    H = n_heads(cfg)
    hd = cfg.rwkv_head_dim
    chunk = chunk or cfg.scan_chunk
    if S % chunk != 0:
        chunk = S
    dt = x.dtype

    from ..parallel import sharding as shd

    xs = _shift(x, last_x)
    # flat (B, S, D) projections are constrained to shard D over the
    # model axis before the head split (D is 16-divisible even when the
    # head count is not), so the (B, L, L, H, dk) pairwise-decay tensor
    # inherits a head/channel sharding instead of replicating
    # (perf iteration H8, EXPERIMENTS.md #Perf).
    from .. import perfflags

    _c = (lambda t: t) if perfflags.BASELINE else (lambda t: shd.act(t, "logits"))
    r = _head_split(_c(_mix(x, xs, p["mix_r"]) @ p["wr"].astype(dt)), H, hd)
    k = _head_split(_c(_mix(x, xs, p["mix_k"]) @ p["wk"].astype(dt)), H, hd)
    v = _head_split(_c(_mix(x, xs, p["mix_v"]) @ p["wv"].astype(dt)), H, hd)
    g = _mix(x, xs, p["mix_g"]) @ p["wg"].astype(dt)
    logw = _decay(cfg, p, _mix(x, xs, p["mix_w"]))   # (B, S, D) f32
    logw = logw.reshape(B, S, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    rf = r.astype(jnp.float32).reshape(B, S // chunk, chunk, H, hd)
    kf = k.astype(jnp.float32).reshape(B, S // chunk, chunk, H, hd)
    vf = v.astype(jnp.float32).reshape(B, S // chunk, chunk, H, hd)
    lw = logw.reshape(B, S // chunk, chunk, H, hd)

    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    # remat: the (B, L, L, H, dk) pairwise decay tensor is recomputed in
    # backward instead of saved per chunk (perf iteration H2).
    def body(S_in, xs_chunk):
        rc, kc, vc, lwc = xs_chunk                   # (B, L, H, hd)
        cum = jnp.cumsum(lwc, axis=1)                # (B, L, H, dk)
        cum_prev = cum - lwc                         # cum_{t-1}
        # cross-chunk: r_t decayed to chunk start @ S_in
        r_dec = rc * jnp.exp(cum_prev)
        out_cross = jnp.einsum("blhd,bhdv->blhv", r_dec, S_in)
        # intra-chunk pairwise with safe exponents (<= 0)
        ediff = cum_prev[:, :, None] - cum[:, None, :]      # (B, t, s, H, dk)
        L = rc.shape[1]
        tmask = jnp.tril(jnp.ones((L, L), bool), -1)[None, :, :, None, None]
        e = jnp.where(tmask, jnp.exp(jnp.minimum(ediff, 0.0)), 0.0)
        a = jnp.einsum("bthd,bshd,btshd->bths", rc, kc, e)
        out_intra = jnp.einsum("bths,bshv->bthv", a, vc)
        # current-token bonus
        diag = jnp.einsum("blhd,blhd->blh", rc, kc * u[None, None])
        out_diag = diag[..., None] * vc
        # state update (factors <= 1)
        dec_all = jnp.exp(cum[:, -1])                # (B, H, dk)
        k_dec = kc * jnp.exp(cum[:, -1:] - cum)      # factors <= 1
        S_out = S_in * dec_all[..., None] + jnp.einsum(
            "bshd,bshv->bhdv", k_dec, vc
        )
        return S_out, out_cross + out_intra + out_diag

    from ..perfflags import checkpoint_if_optimized

    body = checkpoint_if_optimized(body)
    seq = (
        jnp.moveaxis(rf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(lw, 1, 0),
    )
    S_fin, outs = jax.lax.scan(body, state, seq)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd).astype(dt)
    out = _headnorm(out, p["ln_scale"])
    out = out * jax.nn.silu(g)
    return out @ p["wo"].astype(dt), S_fin, x[:, -1:]


def time_mix_decode(cfg: ModelConfig, p, x, state, last_x):
    """Single-token recurrence.  x (B, 1, D)."""
    B, _, D = x.shape
    H = n_heads(cfg)
    hd = cfg.rwkv_head_dim
    dt = x.dtype
    xs = last_x
    r = _mix(x, xs, p["mix_r"]) @ p["wr"].astype(dt)
    k = _mix(x, xs, p["mix_k"]) @ p["wk"].astype(dt)
    v = _mix(x, xs, p["mix_v"]) @ p["wv"].astype(dt)
    g = _mix(x, xs, p["mix_g"]) @ p["wg"].astype(dt)
    logw = _decay(cfg, p, _mix(x, xs, p["mix_w"]))
    rf = r.astype(jnp.float32).reshape(B, H, hd)
    kf = k.astype(jnp.float32).reshape(B, H, hd)
    vf = v.astype(jnp.float32).reshape(B, H, hd)
    w = jnp.exp(logw).reshape(B, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    wkv = state + (kf * u[None])[..., None] * vf[:, :, None, :]
    out = jnp.einsum("bhd,bhdv->bhv", rf, wkv)       # (B, H, dv)
    new_state = state * w[..., None] + kf[..., None] * vf[:, :, None, :]
    out = out.reshape(B, 1, D).astype(dt)
    out = _headnorm(out.reshape(B, 1, H, hd), p["ln_scale"])
    out = out * jax.nn.silu(g)
    return out @ p["wo"].astype(dt), new_state, x


def channel_mix(cfg: ModelConfig, p, x, last_x=None):
    dt = x.dtype
    xs = _shift(x, last_x)
    xk = _mix(x, xs, p["cmix_k"])
    xr = _mix(x, xs, p["cmix_r"])
    k = jnp.square(jax.nn.relu(xk @ p["c_wk"].astype(dt)))
    r = jax.nn.sigmoid(xr @ p["c_wr"].astype(dt))
    return r * (k @ p["c_wv"].astype(dt)), x[:, -1:]
