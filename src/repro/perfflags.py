"""Perf-iteration A/B switch.

REPRO_PERF_BASELINE=1 reverts the beyond-baseline optimizations
(EXPERIMENTS.md #Perf iterations H1/H2/H3/H5) so baseline and optimized
cells can be lowered from the same tree under identical cost accounting:

  H1  flat-head sharding constraint on q/k/v projections
  H2  remat of mamba/rwkv chunk-scan bodies
  H3  bf16 chunk outputs (mamba y)
  H5  accumulator-typed norm/router statistics (vs f32 materialization)

(H4b, the padded decode KV cache, is toggled per-config via
``decode_head_pad``; H6, the sequential chunk scan, was refuted and
removed.)
"""
import os

BASELINE = os.environ.get("REPRO_PERF_BASELINE", "") == "1"


def backend_override():
    """REPRO_BACKEND=pallas|xla|numpy forces the kernel-dispatch backend
    for the compression hot path (core/backend.py); empty -> auto
    (pallas on TPU, xla elsewhere).  Read at call time so tests can
    monkeypatch the environment."""
    return os.environ.get("REPRO_BACKEND", "") or None


def fused_default():
    """REPRO_FUSED=0 reverts compressor.compress to the legacy
    (seed, per-round host-transfer) pipeline for A/B timing under
    identical accounting; default is the fused device-resident path."""
    return os.environ.get("REPRO_FUSED", "1") != "0"


def checkpoint_if_optimized(fn):
    if BASELINE:
        return fn
    import jax

    return jax.checkpoint(fn)
