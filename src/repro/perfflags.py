"""Perf-iteration A/B switch.

REPRO_PERF_BASELINE=1 reverts the beyond-baseline optimizations
(EXPERIMENTS.md #Perf iterations H1/H2/H3/H5) so baseline and optimized
cells can be lowered from the same tree under identical cost accounting:

  H1  flat-head sharding constraint on q/k/v projections
  H2  remat of mamba/rwkv chunk-scan bodies
  H3  bf16 chunk outputs (mamba y)
  H5  accumulator-typed norm/router statistics (vs f32 materialization)

(H4b, the padded decode KV cache, is toggled per-config via
``decode_head_pad``; H6, the sequential chunk scan, was refuted and
removed.)
"""
import os

BASELINE = os.environ.get("REPRO_PERF_BASELINE", "") == "1"


def backend_override():
    """REPRO_BACKEND=pallas|xla|numpy forces the kernel-dispatch backend
    for the compression hot path (core/backend.py); empty -> auto
    (pallas on TPU, xla elsewhere).  Read at call time so tests can
    monkeypatch the environment."""
    return os.environ.get("REPRO_BACKEND", "") or None


def fused_default():
    """REPRO_FUSED=0 reverts compressor.compress to the legacy
    (seed, per-round host-transfer) pipeline for A/B timing under
    identical accounting; default is the fused device-resident path."""
    return os.environ.get("REPRO_FUSED", "1") != "0"


def jit_cache_dir():
    """REPRO_JIT_CACHE=<dir> points JAX's persistent compilation cache
    at <dir>; REPRO_JIT_CACHE=1 uses ~/.cache/repro/jax-cache.  Unset
    (or 0) disables it.  Read at call time so tests can monkeypatch."""
    v = os.environ.get("REPRO_JIT_CACHE", "").strip()
    if not v or v == "0":
        return None
    if v == "1":
        return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "jax-cache")
    return v


_jit_cache_applied = None


def apply_jit_cache(path=None):
    """Idempotently enable JAX's persistent compilation cache at
    ``path`` (default: ``jit_cache_dir()``; no-op when that is unset).

    Repeated autotune/bench invocations re-jit the same stage
    executables from scratch in every process; the on-disk cache turns
    those cold compiles into loads.  Returns the applied path or None.
    Purely a compile-time cache: numerics and container bytes are
    unaffected.
    """
    global _jit_cache_applied
    path = path or jit_cache_dir()
    if not path:
        return None
    if _jit_cache_applied == path:
        return path
    import jax

    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        # cache every entry: the compression stages are many small
        # executables, each below the default min-compile-time bar
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        return None  # older jaxlibs without these flags
    _jit_cache_applied = path
    return path


def checkpoint_if_optimized(fn):
    if BASELINE:
        return fn
    import jax

    return jax.checkpoint(fn)
