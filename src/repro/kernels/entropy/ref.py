"""Pure-jnp reference for the batched symbol histogram (exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def symbol_histogram(sym):
    """sym (B, n) uint8 -> (B, 256) int32 per-row counts."""
    def one(row):
        return jnp.zeros((256,), jnp.int32).at[row.astype(jnp.int32)].add(1)

    return jax.vmap(one)(sym)
