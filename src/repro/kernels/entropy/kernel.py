"""Pallas TPU kernel: batched 256-bin symbol histogram.

The device entropy stage (core/entropy.py) needs one histogram per
symbol row of a (B, n) uint8 stack -- the only data the host ever sees
before bit-packing (the canonical code tables are built from it).  TPUs
have no scatter-add fast path, so the kernel takes the compare-and-sum
form instead: each grid step loads a (1, CHUNK) slice of one row,
compares it against a broadcasted 256-bin iota and reduces along the
chunk -- pure VPU integer work, exact by construction.  The n axis is
the inner grid dimension, so partial counts accumulate into the same
(1, 256) output block across sequential grid steps.

Symbols arrive as int32 (the ops wrapper widens uint8) to keep VMEM
tiling on the friendly (8, 128) int32 granularity rather than the
(32, 128) int8 one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NBINS = 256
CHUNK = 512          # n-axis slice per grid step (multiple of 128 lanes)


def _kernel(sym_ref, out_ref):
    j = pl.program_id(1)
    s = sym_ref[0]                                   # (CHUNK,) int32
    bins = jax.lax.broadcasted_iota(jnp.int32, (NBINS, s.shape[0]), 0)
    counts = jnp.sum((s[None, :] == bins).astype(jnp.int32), axis=1,
                     dtype=jnp.int32)

    @pl.when(j == 0)
    def _init():
        out_ref[0] = counts

    @pl.when(j != 0)
    def _acc():
        out_ref[0] = out_ref[0] + counts


@functools.partial(jax.jit, static_argnames=("interpret",))
def symbol_histogram_pallas(sym, interpret=True):
    """sym (B, n) int32 with values in [0, 255]; n a multiple of CHUNK
    (the ops wrapper zero-pads and corrects bin 0).  Returns (B, 256)
    int32 counts."""
    B, n = sym.shape
    grid = (B, n // CHUNK)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, CHUNK), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, NBINS), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NBINS), jnp.int32),
        interpret=interpret,
    )(sym)
