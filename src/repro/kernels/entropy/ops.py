"""Dispatch wrapper: TPU -> pallas kernel, CPU/other -> jnp ref."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def symbol_histogram(sym, force_ref=False, force_pallas=False):
    """Per-row 256-bin histogram of a (B, n) uint8 symbol stack.

    Integer counts are exact, so the pallas and ref paths are
    bit-identical; off-TPU the ref path is the default (the interpreted
    kernel exists for parity testing via ``force_pallas``).
    """
    on_tpu = jax.default_backend() == "tpu"
    if force_ref or (not force_pallas and not on_tpu):
        return ref.symbol_histogram(sym)
    n = sym.shape[1]
    pad = (-n) % kernel.CHUNK
    s32 = sym.astype(jnp.int32)
    if pad:
        s32 = jnp.pad(s32, ((0, 0), (0, pad)))
    hist = kernel.symbol_histogram_pallas(s32, interpret=not on_tpu)
    if pad:
        # zero-padding lands in bin 0; subtract it back out
        hist = hist.at[:, 0].add(-pad)
    return hist
