"""Pallas TPU kernels for the compression hot spots.

Each subpackage ships:
    kernel.py -- pl.pallas_call + BlockSpec VMEM tiling (TPU target)
    ops.py    -- jit'd dispatch wrapper (TPU -> kernel, else ref)
    ref.py    -- pure-jnp oracle

Kernels are validated in interpret mode on CPU (exact equality for the
integer kernels); the dry-run model path never requires them (the
framework is pure-JAX functional on any backend).
"""
