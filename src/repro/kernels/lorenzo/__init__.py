from .ops import dualquant_lorenzo_residual  # noqa: F401
