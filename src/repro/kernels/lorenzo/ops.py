"""Dispatch wrapper: TPU -> pallas kernel, CPU/other -> interpret/ref."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _pad_to(x, mh, mw, value=0):
    T, H, W = x.shape
    ph = (-H) % mh
    pw = (-W) % mw
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, ph), (0, pw)), constant_values=value)
    return x


def dualquant_lorenzo_residual(dfp, k, lossless, xi_unit, block=16,
                               force_ref=False, force_pallas=False):
    """Fused dual-quantization + block-local Lorenzo residual.

    dfp int32/int64 (T, H, W); k int32 (-1 where lossless); lossless
    bool.  Returns int32 residual (T, H, W).  ``force_pallas`` (used by
    the core backend dispatcher) skips the large-field CPU heuristic so
    the kernel always runs (interpret mode off-TPU).
    """
    T, H, W = dfp.shape
    on_tpu = jax.default_backend() == "tpu"
    if force_ref or (not force_pallas and not on_tpu and (H * W > 512 * 512)):
        # pure-jnp path (identical math, vectorized)
        x_prev = jnp.zeros((H, W), jnp.int32)
        outs = []
        for t in range(T):  # small T in ref mode; core pipeline is used
            r = ref.residual_frame_pair(
                dfp[t].astype(jnp.int32), dfp[max(t - 1, 0)].astype(jnp.int32),
                k[t], k[max(t - 1, 0)], lossless[t], lossless[max(t - 1, 0)],
                xi_unit, t == 0, block,
            )
            outs.append(r)
        return jnp.stack(outs)

    dfp32 = _pad_to(dfp.astype(jnp.int32), kernel.TILE_H, kernel.TILE_W)
    k32 = _pad_to(k.astype(jnp.int32), kernel.TILE_H, kernel.TILE_W)
    ll = _pad_to(lossless, kernel.TILE_H, kernel.TILE_W)
    out = kernel.dualquant_lorenzo_residual_pallas(
        dfp32, k32, ll, xi_unit, interpret=not on_tpu
    )
    return out[:, :H, :W]
