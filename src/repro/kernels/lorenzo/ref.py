"""Pure-jnp oracle: fused dual-quantization + block-local 3D Lorenzo
residual for one frame pair (matches core.quantize + core.predictors,
int32 domain)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import predictors


def round_div(d, g, k):
    """round-half-away(d / (g << k)), exact in integer arithmetic:
    ((|d| + q/2) >> k) // g with q = g << k (g even)."""
    q_half = (g << k) >> 1
    mag = ((jnp.abs(d) + q_half) >> k) // g
    return jnp.sign(d) * mag


def dual_quantize_frame(dfp, k, lossless, xi_unit):
    g = jnp.int32(2 * xi_unit)
    kk = jnp.maximum(k, 0)
    x = round_div(dfp, g, kk) << kk
    x0 = round_div(dfp, g, jnp.zeros_like(kk))
    return jnp.where(lossless, x0, x)


def residual_frame_pair(dfp_t, dfp_p, k_t, k_p, ll_t, ll_p, xi_unit,
                        is_first, block=16):
    """Residual of frame t given frame t-1 (all int32, (H, W))."""
    x_t = dual_quantize_frame(dfp_t, k_t, ll_t, xi_unit)
    x_p = dual_quantize_frame(dfp_p, k_p, ll_p, xi_unit)
    d2_t = predictors.d2_block(x_t, block)
    d2_p = predictors.d2_block(x_p, block)
    return jnp.where(is_first, d2_t, d2_t - d2_p)
