"""Pallas TPU kernel: fused dual-quantization + block-local 3D Lorenzo.

One pass over the fixed-point field produces the residual stream: load a
(1, TH, TW) tile of frames t and t-1 (+ eb-level and lossless maps),
quantize onto the base grid, apply the tile-local 2D difference and the
temporal difference -- 1 store per element, pure VPU integer work.

Because the Lorenzo context is *block-local* (16 x 16, DESIGN.md #3.2)
and the VMEM tile (default 128 x 128) is a multiple of it, the kernel
needs NO halo: every 16-tile is fully contained in one VMEM tile.  The
MXU is untouched; the kernel is bandwidth-bound by design (it exists to
fuse 5 HBM round-trips -- quantize, context, two diffs, temporal -- into
one).

Preconditions: |dfp| < 2^30 (fixedpoint.py guarantees), int32 domain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LBLOCK = 16          # Lorenzo tile (matches core.predictors.DEFAULT_BLOCK)
TILE_H = 128         # VMEM tile (8x sublane, 128-lane aligned)
TILE_W = 128


def _round_div(d, g, k):
    q_half = (g << k) >> 1
    mag = ((jnp.abs(d) + q_half) >> k) // g
    return jnp.sign(d) * mag


def _dual_quant(dfp, k, lossless, g):
    kk = jnp.maximum(k, 0)
    x = _round_div(dfp, g, kk) << kk
    x0 = _round_div(dfp, g, jnp.zeros_like(kk))
    return jnp.where(lossless, x0, x)


def _d2_block(x):
    """Tile-local 2D first-order difference (within-VMEM, no halo)."""
    H, W = x.shape
    ii = jax.lax.broadcasted_iota(jnp.int32, (H, W), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (H, W), 1)
    mi = ((ii % LBLOCK) != 0).astype(x.dtype)
    mj = ((jj % LBLOCK) != 0).astype(x.dtype)
    xi = jnp.pad(x, ((1, 0), (0, 0)))[:-1] * mi
    xj = jnp.pad(x, ((0, 0), (1, 0)))[:, :-1] * mj
    xij = jnp.pad(x, ((1, 0), (1, 0)))[:-1, :-1] * (mi * mj)
    return x - xi - xj + xij


def _kernel(dfp_t_ref, dfp_p_ref, k_t_ref, k_p_ref, ll_t_ref, ll_p_ref,
            meta_ref, out_ref):
    t = pl.program_id(0)
    g = meta_ref[0]
    x_t = _dual_quant(dfp_t_ref[0], k_t_ref[0], ll_t_ref[0] != 0, g)
    x_p = _dual_quant(dfp_p_ref[0], k_p_ref[0], ll_p_ref[0] != 0, g)
    d2_t = _d2_block(x_t)
    d2_p = _d2_block(x_p)
    out_ref[0] = jnp.where(t == 0, d2_t, d2_t - d2_p)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dualquant_lorenzo_residual_pallas(dfp, k, lossless, xi_unit,
                                      interpret=True):
    """dfp (T, H, W) int32; k (T, H, W) int32; lossless bool.

    Returns residual (T, H, W) int32.  H, W must be multiples of the
    VMEM tile (the ops wrapper pads).
    """
    T, H, W = dfp.shape
    grid = (T, H // TILE_H, W // TILE_W)

    def idx_t(t, i, j):
        return (t, i, j)

    def idx_p(t, i, j):
        return (jnp.maximum(t - 1, 0), i, j)

    tile = (1, TILE_H, TILE_W)
    in_specs = [
        pl.BlockSpec(tile, idx_t),                     # dfp_t
        pl.BlockSpec(tile, idx_p),                     # dfp_{t-1}
        pl.BlockSpec(tile, idx_t),                     # k_t
        pl.BlockSpec(tile, idx_p),                     # k_{t-1}
        pl.BlockSpec(tile, idx_t),                     # lossless_t
        pl.BlockSpec(tile, idx_p),                     # lossless_{t-1}
        pl.BlockSpec(memory_space=pl.ANY),             # meta (scalars)
    ]
    meta = (2 * jnp.asarray(xi_unit, dtype=jnp.int32)).reshape(1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(tile, idx_t),
        out_shape=jax.ShapeDtypeStruct((T, H, W), jnp.int32),
        interpret=interpret,
    )(dfp, dfp, k.astype(jnp.int32), k.astype(jnp.int32),
      lossless.astype(jnp.int32), lossless.astype(jnp.int32), meta)
