"""Oracle: float32 semi-Lagrangian prediction (core.predictors math in
the kernel's dtype)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import predictors


def sl_predict(u_prev, v_prev, cfl_x, cfl_y, d_max=2.0, n_max=8):
    u32 = u_prev.astype(jnp.float32)
    v32 = v_prev.astype(jnp.float32)
    i_s, j_s = predictors.sl_departure(u32, v32, cfl_x, cfl_y, d_max, n_max)
    return (
        predictors.bilinear(u32, i_s, j_s),
        predictors.bilinear(v32, i_s, j_s),
    )
