from .ops import sl_predict  # noqa: F401
