"""Pallas TPU kernel: semi-Lagrangian backtrace + bilinear sampling.

The previous frame's (u, v) planes are held whole in VMEM (two
f32[H, W] buffers -- up to ~2 x 4 MB for 1k x 1k frames, well within
the 16 MB/core budget); the grid tiles the *output* rows, so the
irregular reads of the backtrace stay on-chip and each output element is
written once.  RK2 midpoint for small displacements, clamped Euler
substeps otherwise (paper Eqs. 4-9), f32 arithmetic.

Gather note: per-element VMEM gathers lower on TPU only for recent
generations; the ops wrapper validates in interpret mode and keeps the
pure-jnp path (XLA gather) as the production fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_H = 8


def _bilinear(f, fi, fj, H, W):
    i0 = jnp.clip(jnp.floor(fi), 0, H - 1)
    j0 = jnp.clip(jnp.floor(fj), 0, W - 1)
    a = fi - i0
    b = fj - j0
    i0 = i0.astype(jnp.int32)
    j0 = j0.astype(jnp.int32)
    i1 = jnp.minimum(i0 + 1, H - 1)
    j1 = jnp.minimum(j0 + 1, W - 1)
    f00 = f[i0, j0]
    f01 = f[i0, j1]
    f10 = f[i1, j0]
    f11 = f[i1, j1]
    return ((1 - a) * (1 - b) * f00 + (1 - a) * b * f01
            + a * (1 - b) * f10 + a * b * f11)


def _sl_tile(u, v, r, H, W, cfl_x, cfl_y, d_max, n_max):
    """Backtrace + sample one (TILE_H, W) output row tile of one frame."""
    ii = (r * TILE_H
          + jax.lax.broadcasted_iota(jnp.int32, (TILE_H, W), 0)
          ).astype(jnp.float32)
    jj = jax.lax.broadcasted_iota(jnp.int32, (TILE_H, W), 1).astype(
        jnp.float32)
    zero = jnp.zeros((), jnp.int32)
    start = (r * TILE_H).astype(jnp.int32)
    u0 = jax.lax.dynamic_slice(u, (start, zero), (TILE_H, W))
    v0 = jax.lax.dynamic_slice(v, (start, zero), (TILE_H, W))
    d_inf = jnp.maximum(jnp.abs(u0) * cfl_x, jnp.abs(v0) * cfl_y)

    # RK2 midpoint
    i_h = jnp.clip(ii - 0.5 * v0 * cfl_y, 0.0, H - 1.0)
    j_h = jnp.clip(jj - 0.5 * u0 * cfl_x, 0.0, W - 1.0)
    u_h = _bilinear(u, i_h, j_h, H, W)
    v_h = _bilinear(v, i_h, j_h, H, W)
    i_rk = ii - v_h * cfl_y
    j_rk = jj - u_h * cfl_x

    # clamped Euler substeps
    n_sub = jnp.clip(jnp.ceil(d_inf / d_max), 1.0, float(n_max))
    pi, pj = ii, jj
    for s in range(n_max):
        us = _bilinear(u, pi, pj, H, W)
        vs = _bilinear(v, pi, pj, H, W)
        active = s < n_sub
        pi = jnp.where(active,
                       jnp.clip(pi - vs * cfl_y / n_sub, 0.0, H - 1.0), pi)
        pj = jnp.where(active,
                       jnp.clip(pj - us * cfl_x / n_sub, 0.0, W - 1.0), pj)

    use_rk = d_inf <= d_max
    i_s = jnp.clip(jnp.where(use_rk, i_rk, pi), 0.0, H - 1.0)
    j_s = jnp.clip(jnp.where(use_rk, j_rk, pj), 0.0, W - 1.0)
    return _bilinear(u, i_s, j_s, H, W), _bilinear(v, i_s, j_s, H, W)


def _make_kernel(H, W, cfl_x, cfl_y, d_max, n_max):
    def kernel(u_ref, v_ref, pu_ref, pv_ref):
        r = pl.program_id(0)
        pu, pv = _sl_tile(u_ref[...], v_ref[...], r, H, W,
                          cfl_x, cfl_y, d_max, n_max)
        pu_ref[...] = pu
        pv_ref[...] = pv

    return kernel


def _make_batched_kernel(H, W, cfl_x, cfl_y, d_max, n_max):
    def kernel(u_ref, v_ref, pu_ref, pv_ref):
        r = pl.program_id(1)
        pu, pv = _sl_tile(u_ref[0], v_ref[0], r, H, W,
                          cfl_x, cfl_y, d_max, n_max)
        pu_ref[0] = pu
        pv_ref[0] = pv

    return kernel


@functools.partial(
    jax.jit, static_argnames=("cfl_x", "cfl_y", "d_max", "n_max", "interpret")
)
def sl_predict_pallas(u_prev, v_prev, cfl_x, cfl_y, d_max=2.0, n_max=8,
                      interpret=True):
    """u_prev, v_prev: f32 (H, W), H % TILE_H == 0."""
    H, W = u_prev.shape
    kern = _make_kernel(H, W, float(cfl_x), float(cfl_y), float(d_max),
                        int(n_max))
    full = pl.BlockSpec((H, W), lambda r: (0, 0))
    tile = pl.BlockSpec((TILE_H, W), lambda r: (r, 0))
    pu, pv = pl.pallas_call(
        kern,
        grid=(H // TILE_H,),
        in_specs=[full, full],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((H, W), jnp.float32)] * 2,
        interpret=interpret,
    )(u_prev.astype(jnp.float32), v_prev.astype(jnp.float32))
    return pu, pv


@functools.partial(
    jax.jit, static_argnames=("cfl_x", "cfl_y", "d_max", "n_max", "interpret")
)
def sl_predict_batched_pallas(u_prev, v_prev, cfl_x, cfl_y, d_max=2.0,
                              n_max=8, interpret=True):
    """Frame-batched variant: u_prev, v_prev f32 (B, H, W) stacks of
    previous frames, H % TILE_H == 0.  One pallas_call over a (B, rows)
    grid; each program holds its frame's two planes whole in VMEM and
    writes one output row tile (same math as sl_predict_pallas).

    NOT in the production hot path yet: the pipeline replays SL through
    one per-frame stepper executable for encoder/decoder bit-consistency
    (core/backend.py sl_stepper, DESIGN.md #4).  This kernel is the
    TPU-compiled encoder upgrade once batched-vs-per-frame bitwise
    equality is validated on hardware; tests pin it against the
    per-frame kernel at f32 tolerance meanwhile."""
    B, H, W = u_prev.shape
    kern = _make_batched_kernel(H, W, float(cfl_x), float(cfl_y),
                                float(d_max), int(n_max))
    full = pl.BlockSpec((1, H, W), lambda b, r: (b, 0, 0))
    tile = pl.BlockSpec((1, TILE_H, W), lambda b, r: (b, r, 0))
    pu, pv = pl.pallas_call(
        kern,
        grid=(B, H // TILE_H),
        in_specs=[full, full],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((B, H, W), jnp.float32)] * 2,
        interpret=interpret,
    )(u_prev.astype(jnp.float32), v_prev.astype(jnp.float32))
    return pu, pv
