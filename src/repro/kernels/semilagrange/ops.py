"""Dispatch wrapper for the SL predictor kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def sl_predict(u_prev, v_prev, cfl_x, cfl_y, d_max=2.0, n_max=8,
               force_ref=False):
    """f32 semi-Lagrangian prediction of frame t from frame t-1."""
    H, W = u_prev.shape
    on_tpu = jax.default_backend() == "tpu"
    if force_ref or H % kernel.TILE_H != 0:
        return ref.sl_predict(u_prev, v_prev, cfl_x, cfl_y, d_max, n_max)
    return kernel.sl_predict_pallas(
        jnp.asarray(u_prev, jnp.float32), jnp.asarray(v_prev, jnp.float32),
        float(cfl_x), float(cfl_y), float(d_max), int(n_max),
        interpret=not on_tpu,
    )
