"""Oracle: int64 SoS face predicate (core.sos on jnp)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import sos


def face_crossed(u, v, idx):
    """u, v (N, 3) int64 values; idx (N, 3) int64.  Returns (N,) bool."""
    return sos.face_crossed_vals(jnp, u.astype(jnp.int64),
                                 v.astype(jnp.int64), idx.astype(jnp.int64))
