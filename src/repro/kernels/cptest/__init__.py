from .ops import face_crossed_batch  # noqa: F401
