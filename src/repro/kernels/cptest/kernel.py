"""Pallas TPU kernel: exact SoS face-crossing predicate in int32 limbs.

TPU has no int64 vector unit, but the SoS determinant test needs the
EXACT sign of au*bv - av*bu for |values| < 2^30 -- a 61-bit quantity.
We decompose each operand into three 10-bit limbs (a = a2*2^20 + a1*2^10
+ a0); every partial-product limb is then a sum of <= 3 terms of < 2^20,
so the 5-limb product difference stays below 2^23 in int32.  A single
carry-normalization pass canonicalizes limbs 0..3 into [0, 2^10) leaving
the sign in limb 4 + a nonneg remainder:

    sign = +1  if L4 > 0 or (L4 == 0 and rest > 0)
            0  if L4 == 0 and rest == 0
           -1  otherwise

The SoS tie-break cascade (core/sos.py) runs on top of the exact signs.
This is the TPU-native replacement for the paper's int64 CPU predicate
-- the hardware-adaptation note in DESIGN.md #3.4/#7.

Layout: faces are batched as (N, 128)-padded int32 planes; the grid
walks (8, 128) VMEM tiles; pure VPU integer MACs, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 8
TILE_C = 128
_B = 10                     # limb bits
_MASK = (1 << _B) - 1


def _limbs(x):
    """int32 -> three 10-bit limbs (floor semantics for negatives)."""
    a0 = x & _MASK
    x1 = x >> _B
    a1 = x1 & _MASK
    a2 = x1 >> _B
    return a2, a1, a0


def _sign_det_exact(au, av, bu, bv):
    """Exact sign of au*bv - av*bu via limb arithmetic (all int32)."""
    p2, p1, p0 = _limbs(au)
    q2, q1, q0 = _limbs(bv)
    r2, r1, r0 = _limbs(av)
    s2, s1, s0 = _limbs(bu)
    # product limbs of au*bv minus av*bu, positions 0..4 (base 2^10)
    l0 = p0 * q0 - r0 * s0
    l1 = p0 * q1 + p1 * q0 - r0 * s1 - r1 * s0
    l2 = p0 * q2 + p1 * q1 + p2 * q0 - r0 * s2 - r1 * s1 - r2 * s0
    l3 = p1 * q2 + p2 * q1 - r1 * s2 - r2 * s1
    l4 = p2 * q2 - r2 * s2
    # carry-normalize limbs 0..3 into [0, 2^10)
    c = l0 >> _B
    l0 = l0 & _MASK
    l1 = l1 + c
    c = l1 >> _B
    l1 = l1 & _MASK
    l2 = l2 + c
    c = l2 >> _B
    l2 = l2 & _MASK
    l3 = l3 + c
    c = l3 >> _B
    l3 = l3 & _MASK
    l4 = l4 + c
    rest = ((l3 << _B | l2) != 0) | ((l1 << _B | l0) != 0)
    pos = (l4 > 0) | ((l4 == 0) & rest)
    neg = l4 < 0
    return jnp.where(pos, 1, jnp.where(neg, -1, 0)).astype(jnp.int32)


def _sos_cascade(au, av, bu, bv):
    s = _sign_det_exact(au, av, bu, bv)
    s = jnp.where(s != 0, s, jnp.sign(bv))
    s = jnp.where(s != 0, s, jnp.sign(-bu))
    s = jnp.where(s != 0, s, jnp.sign(-av))
    s = jnp.where(s != 0, s, jnp.sign(au))
    return jnp.where(s != 0, s, -jnp.ones_like(s)).astype(jnp.int32)


def _sign_det_sos(au, av, ma, bu, bv, mb):
    fwd = _sos_cascade(au, av, bu, bv)
    rev = _sos_cascade(bu, bv, au, av)
    return jnp.where(ma < mb, fwd, -rev)


def _kernel(u0, v0, u1, v1, u2, v2, m0, m1, m2, out):
    a_u, a_v, i_a = u0[...], v0[...], m0[...]
    b_u, b_v, i_b = u1[...], v1[...], m1[...]
    c_u, c_v, i_c = u2[...], v2[...], m2[...]
    s1 = _sign_det_sos(a_u, a_v, i_a, b_u, b_v, i_b)
    s2 = _sign_det_sos(b_u, b_v, i_b, c_u, c_v, i_c)
    s3 = _sign_det_sos(c_u, c_v, i_c, a_u, a_v, i_a)
    out[...] = ((s1 == s2) & (s2 == s3)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def face_crossed_pallas(u, v, idx, interpret=True):
    """u, v, idx: (R, C, 3) int32 (R % 8 == 0, C % 128 == 0).

    Returns (R, C) int32 (1 = crossed).
    """
    R, C, _ = u.shape
    grid = (R // TILE_R, C // TILE_C)
    tile = (TILE_R, TILE_C)

    args = [u[..., 0], v[..., 0], u[..., 1], v[..., 1], u[..., 2], v[..., 2],
            idx[..., 0], idx[..., 1], idx[..., 2]]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(tile, lambda i, j: (i, j)) for _ in range(9)],
        out_specs=pl.BlockSpec(tile, lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.int32),
        interpret=interpret,
    )(*args)
