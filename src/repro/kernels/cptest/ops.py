"""Dispatch wrapper for the batched face predicate."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def face_crossed_batch(u, v, idx, force_ref=False):
    """u, v (N, 3) fixed-point values (|.| < 2^30); idx (N, 3) vertex ids
    (SoS order).  Returns (N,) bool."""
    N = u.shape[0]
    on_tpu = jax.default_backend() == "tpu"
    if force_ref:
        return ref.face_crossed(u, v, idx)

    C = kernel.TILE_C
    R = max((N + C - 1) // C, 1)
    R = -(-R // kernel.TILE_R) * kernel.TILE_R
    pad = R * C - N

    def prep(x):
        x = jnp.asarray(x, jnp.int32)
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1)
        return x.reshape(R, C, 3)

    # vertex ids fit int32 (precondition: < 2^31 space-time vertices);
    # padded faces get distinct dummy ids and are discarded below.
    idx32 = jnp.asarray(idx).astype(jnp.int32)
    idx_p = jnp.concatenate(
        [idx32, jnp.tile(jnp.asarray([[0, 1, 2]], jnp.int32), (pad, 1))]
    ).reshape(R, C, 3)

    out = kernel.face_crossed_pallas(
        prep(u), prep(v), idx_p, interpret=not on_tpu
    )
    return out.reshape(-1)[:N] != 0
