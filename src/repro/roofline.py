"""Roofline-term derivation from a compiled dry-run artifact.

Three terms per (arch, shape, mesh), in seconds (TPU v5e targets):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS        (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_BW            (819 GB/s)
    collective = collective_bytes_per_device / LINK_BW    (~50 GB/s/link)

FLOPs/bytes/collective-bytes come from the trip-count-aware HLO walker
(hlocost.py) over ``compiled.as_text()`` -- the stock
``compiled.cost_analysis()`` visits every scan body exactly once, which
undercounts a 64-layer scanned transformer by ~100x (verified; its raw
numbers are still recorded for reference).  Collective bytes sum the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, per assignment spec.

MODEL_FLOPS uses 6*N*D (train) or 2*N*D (inference) with N = active
params, D = global tokens; the ratio MODEL_FLOPS / (per-device HLO_FLOPs
x chips) flags remat/redundancy waste (remat pushes it below 1; a value
near 0.75 is the classic "4/3 remat overhead" signature).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

import numpy as np

from . import hlocost

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    memory_report: dict
    raw_cost_analysis: dict = dataclasses.field(default_factory=dict)
    loop_info: list = dataclasses.field(default_factory=list)
    # non-dot re-pricing (hlocost.NONDOT_FLOP_WEIGHTS): adjusted total
    # and per-opcode breakdown, recorded alongside the raw dot-dominated
    # count the same way raw_cost_analysis keeps the stock numbers
    flops_adjusted_per_device: float = 0.0
    nondot_flops: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self):
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_compute_adjusted(self):
        f = self.flops_adjusted_per_device or self.flops_per_device
        return f / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes_per_device / LINK_BW

    @property
    def bottleneck(self):
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self):
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self):
        """compute-term / achievable step time (sum-free bound: the
        bottleneck term is the floor on step time)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t else 0.0

    def row(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.flops_per_device * self.n_chips,
            "hlo_flops_raw": self.flops_per_device,
            "hlo_flops_adjusted": self.flops_adjusted_per_device
            or self.flops_per_device,
            "t_compute_adjusted_s": self.t_compute_adjusted,
            "nondot_flops": self.nondot_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
            "memory": self.memory_report,
            "raw_cost_analysis": self.raw_cost_analysis,
        }


def memory_report(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    args = out.get("argument_size_in_bytes", 0)
    alias = out.get("alias_size_in_bytes", 0)
    out["resident_bytes"] = (
        args + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0) - alias
    )
    return out


def model_flops(cfg, cell) -> float:
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        if cfg.is_encoder_decoder:
            tokens = cell.global_batch * (cell.seq_len + cell.dec_len)
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        if cfg.is_encoder_decoder:
            tokens = cell.global_batch * (cell.seq_len + cell.dec_len)
        return 2.0 * n_active * tokens
    # decode: one token per sequence (+ attention over the cache, which is
    # not in 2ND -- the useful-ratio for decode is expected << 1)
    return 2.0 * n_active * cell.global_batch


def analyze(compiled, arch, shape, mesh_name, n_chips, cfg, cell,
            hlo_text=None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jaxlibs wrap in a list
        ca = ca[0] if ca else {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = hlocost.analyze_text(text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_device=hc.flops, bytes_per_device=hc.bytes,
        coll_bytes_per_device=hc.collective_bytes,
        coll_breakdown={k: int(v) for k, v in hc.coll_breakdown.items()},
        model_flops=model_flops(cfg, cell),
        memory_report=memory_report(compiled),
        raw_cost_analysis={
            "flops": float(ca.get("flops", 0.0)),
            "bytes accessed": float(ca.get("bytes accessed", 0.0)),
        },
        loop_info=hc.loop_info[:32],
        flops_adjusted_per_device=hc.flops_adjusted,
        nondot_flops={k: float(v) for k, v in hc.nondot_flops.items()},
    )
