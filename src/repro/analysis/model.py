"""Data model for extracted critical-point trajectories.

A *crossing node* is a face of the space-time tet mesh crossed by the
zero set of the (u, v) field, located at the barycentric zero of the
linear interpolant over the face (paper Eq. 2) -- a point (t, y, x) in
space-time.  A *segment* joins the two crossed faces of one tet (Lemma
1), and the connected components of the segment graph are the
*tracks* (critical-point trajectories).

Because a face is shared by at most two tets, every node has degree at
most 2: tracks are simple polylines (open paths) or loops.  The node
order inside each polyline is canonicalized (see ``order_component``)
so two extractions of the same field produce bit-identical polylines --
the property the feature-query roundtrip tests assert.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# critical-point type codes (classify.py)
CP_TYPES = ("saddle", "source", "sink", "spiral_in", "spiral_out",
            "center", "degenerate")
CP_CODE = {name: i for i, name in enumerate(CP_TYPES)}


def order_component(node_keys, edges):
    """Canonical node order of one track component.

    node_keys: (N,) int64 sort keys (global face ids -- unique per
    node); edges: (E, 2) int indices into the component's node array.
    Returns an int64 index permutation tracing the polyline.

    Deterministic rule: open paths start at the endpoint with the
    smaller key and walk to the other end; loops start at the node with
    the smallest key and step first toward its smaller-keyed neighbor.
    Raises if any node has degree > 2 (impossible under Lemma 1).
    """
    n = len(node_keys)
    if n == 1:
        return np.zeros(1, dtype=np.int64)
    adj = [[] for _ in range(n)]
    for a, b in np.asarray(edges):
        adj[int(a)].append(int(b))
        adj[int(b)].append(int(a))
    deg = np.array([len(a) for a in adj])
    if (deg > 2).any():
        bad = int(np.argmax(deg > 2))
        raise ValueError(
            f"crossing node {int(node_keys[bad])} has degree {deg[bad]} "
            f"> 2; the segment graph is not a union of polylines")
    ends = np.nonzero(deg <= 1)[0]
    if len(ends):
        start = ends[np.argmin(node_keys[ends])]
        nxt = adj[start][0] if adj[start] else None
    else:  # loop
        start = int(np.argmin(node_keys))
        nbrs = adj[start]
        nxt = nbrs[int(np.argmin(node_keys[nbrs]))]
    order = [int(start)]
    prev = int(start)
    cur = None if nxt is None else int(nxt)
    while cur is not None and cur != start:
        order.append(cur)
        nbrs = adj[cur]
        step = [x for x in nbrs if x != prev]
        prev, cur = cur, (step[0] if step else None)
    if len(order) != n:
        # a real raise (not assert): the walk runs over segment edges
        # that may come from a container's track-index footer, so a
        # corrupted index must fail typed -- even under python -O --
        # instead of returning a silently truncated polyline
        raise ValueError(
            f"track component is not a single path/loop: walked "
            f"{len(order)} of {n} nodes (corrupt track index?)")
    return np.asarray(order, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class Track:
    """One critical-point trajectory (ordered polyline)."""

    track_id: int
    nodes: np.ndarray        # (N, 3) float64 (t, y, x), polyline order
    face_ids: np.ndarray     # (N,) int64 global face ids, same order
    types: np.ndarray        # (N,) int8 CP_TYPES codes, same order
    is_loop: bool

    @property
    def t_min(self) -> float:
        return float(self.nodes[:, 0].min())

    @property
    def t_max(self) -> float:
        return float(self.nodes[:, 0].max())

    @property
    def lifetime(self) -> float:
        return self.t_max - self.t_min

    def type_histogram(self) -> np.ndarray:
        return np.bincount(self.types, minlength=len(CP_TYPES))

    @property
    def dominant_type(self) -> str:
        return CP_TYPES[int(np.argmax(self.type_histogram()))]

    def events(self, T: int) -> dict:
        """Birth/death events at slab boundaries.

        A track whose first (last) node lies strictly inside the time
        domain is *born* (*dies*) there -- a genuine topology event; one
        touching t = 0 / t = T-1 merely enters/leaves the observation
        window.  Loops are born and die inside by construction.
        """
        eps = 1e-12
        return {
            "birth": "interior" if self.t_min > eps else "domain_start",
            "death": "interior" if self.t_max < T - 1 - eps
            else "domain_end",
        }


@dataclasses.dataclass(frozen=True)
class TrajectorySet:
    """All tracks of one field + flat per-node arrays.

    Flat arrays are in global node order (ascending face id); tracks
    hold the polyline-ordered views.  ``track_of`` maps flat node index
    -> dense track id.  Track ids are assigned by ascending minimum
    face id of the component, which makes them stable across host/device
    extraction and across tiled re-extraction of the same topology.
    """

    shape: tuple              # (T, H, W)
    nodes: np.ndarray         # (N, 3) float64 (t, y, x)
    face_ids: np.ndarray      # (N,) int64
    types: np.ndarray         # (N,) int8
    track_of: np.ndarray      # (N,) int32
    edges: np.ndarray         # (E, 2) int64 flat node indices
    tracks: tuple             # tuple[Track]

    @property
    def n_tracks(self) -> int:
        return len(self.tracks)

    @property
    def n_nodes(self) -> int:
        return len(self.face_ids)

    def track(self, track_id: int) -> Track:
        return self.tracks[track_id]

    def type_counts(self) -> dict:
        hist = np.bincount(self.types, minlength=len(CP_TYPES))
        return {name: int(hist[i]) for i, name in enumerate(CP_TYPES)}

    def summary(self) -> dict:
        return {
            "n_tracks": self.n_tracks,
            "n_crossing_nodes": self.n_nodes,
            "type_counts": self.type_counts(),
        }


def build_tracks(nodes, face_ids, types, track_of, edges):
    """Assemble polyline-ordered Track objects from flat arrays.

    Nodes and edges are grouped by track with one stable sort each
    (O(N log N) total; a per-track boolean scan would be O(K * N)).
    """
    n_tracks = int(track_of.max()) + 1 if len(track_of) else 0
    deg = np.bincount(edges.reshape(-1), minlength=len(face_ids)) \
        if len(edges) else np.zeros(len(face_ids), dtype=np.int64)
    node_order = np.argsort(track_of, kind="stable")
    node_ptr = np.searchsorted(track_of[node_order],
                               np.arange(n_tracks + 1))
    edge_track = track_of[edges[:, 0]] if len(edges) else \
        np.empty(0, dtype=np.int32)
    eorder = np.argsort(edge_track, kind="stable")
    edge_ptr = np.searchsorted(edge_track[eorder],
                               np.arange(n_tracks + 1))
    tracks = []
    for k in range(n_tracks):
        # stable argsort keeps the original (ascending) index order
        # within each group, so sel is sorted -- searchsorted-safe
        sel = node_order[node_ptr[k]:node_ptr[k + 1]]
        e = edges[eorder[edge_ptr[k]:edge_ptr[k + 1]]]
        local_edges = np.searchsorted(sel, e)
        order = order_component(face_ids[sel], local_edges)
        idx = sel[order]
        is_loop = bool(len(sel) > 1 and (deg[sel] == 2).all())
        tracks.append(Track(
            track_id=k,
            nodes=nodes[idx],
            face_ids=face_ids[idx],
            types=types[idx],
            is_loop=is_loop,
        ))
    return tuple(tracks)
