"""Feature-directed queries against a CPTT1 container's track index.

``query_tracks`` filters the sidecar track summaries (no unit decode at
all -- only the footer is parsed); ``track_read_plan`` turns one track
into the exact set of directory entries its reconstruction needs; and
``decode_for_track`` byte-slices and decodes ONLY those covering units,
re-deriving the track's polyline from the decoded values.

All entry points accept either raw container bytes or a filesystem
path.  Path sources are accessed with seek-based RANGE READS (footer +
covering unit frames only), so the "touches only the covering units"
property holds for the actual file I/O, not just the decode work.

Why the partial decode is exact: the sidecar stores the track's
*topology* (global face ids of its crossing nodes, segment edges, tet
anchor cells) but not its geometry.  Geometry is recomputed at query
time from the decoded field, gathering only grid points inside the
covering units (index.py's inflation argument guarantees every gather
-- barycentric node solve and classification Jacobian cell -- lands
there).  Units decode bit-identically whether decoded alone or as part
of the full field, so the polyline equals what full-decode extraction
would produce, node for node, bit for bit.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..core import encode, fixedpoint
from . import classify as classify_mod
from . import extraction, model
from .index import TrackIndex, parse_track_index


class _Source:
    """(offset, length) range reads over bytes or a file path."""

    def __init__(self, src):
        if isinstance(src, (bytes, bytearray, memoryview)):
            self._blob = bytes(src)
            self._path = None
            self.size = len(self._blob)
        else:
            self._blob = None
            self._path = os.fspath(src)
            self.size = os.path.getsize(self._path)

    def read(self, off: int, ln: int) -> bytes:
        if self._blob is not None:
            return self._blob[off : off + ln]
        with open(self._path, "rb") as f:
            f.seek(off)
            return f.read(ln)

    def header(self) -> dict:
        return encode.tiled_header_ranged(self.read, self.size)

    def unit(self, entry: dict):
        return encode.read_tiled_unit_ranged(self.read, entry)


def load_track_index(src):
    """(source, footer header, TrackIndex) of a tiled container.

    ``src`` is raw bytes or a path; only the footer is read here.
    """
    source = _Source(src)
    hdr = source.header()
    return source, hdr, parse_track_index(hdr)


def _summary(idx: TrackIndex, k: int) -> dict:
    hist = idx.track_type_hist[k]
    return {
        "track_id": int(k),
        "t_min": float(idx.track_t_min[k]),
        "t_max": float(idx.track_t_max[k]),
        "bbox": [float(x) for x in idx.track_bbox[k]],  # y0, y1, x0, x1
        "n_nodes": int(idx.track_n_nodes[k]),
        "n_segments": int(idx.track_seg_counts[k]),
        "type_hist": {name: int(hist[i])
                      for i, name in enumerate(model.CP_TYPES) if hist[i]},
        "dominant_type": model.CP_TYPES[int(np.argmax(hist))],
        "n_cover_units": int(idx.track_cover_ptr[k + 1]
                             - idx.track_cover_ptr[k]),
    }


def track_summaries(src) -> list:
    """All track summaries of a container (footer parse only)."""
    _, _, idx = load_track_index(src)
    return [_summary(idx, k) for k in range(idx.n_tracks)]


def query_tracks(src, bbox=None, trange=None, cp_type=None) -> list:
    """Tracks matching the given feature filters (footer parse only).

    bbox:   (y_min, y_max, x_min, x_max) grid coordinates; a track
            matches when its node bounding box overlaps.
    trange: (t_min, t_max); overlap test on the track lifetime.
    cp_type: a model.CP_TYPES name; matches tracks containing at least
            one node of that type.

    Summaries reflect the pre-compression field; the verify loop makes
    its crossed-face topology identical to the decoded field's, and
    node positions move by O(eb) only, so the filters are exact in
    topology and eb-accurate in geometry.
    """
    _, _, idx = load_track_index(src)
    sel = np.ones(idx.n_tracks, dtype=bool)
    if trange is not None:
        t0, t1 = float(trange[0]), float(trange[1])
        sel &= (idx.track_t_max >= t0) & (idx.track_t_min <= t1)
    if bbox is not None:
        y0, y1, x0, x1 = (float(b) for b in bbox)
        sel &= (idx.track_bbox[:, 1] >= y0) & (idx.track_bbox[:, 0] <= y1)
        sel &= (idx.track_bbox[:, 3] >= x0) & (idx.track_bbox[:, 2] <= x1)
    if cp_type is not None:
        if cp_type not in model.CP_CODE:
            raise ValueError(
                f"unknown cp_type {cp_type!r}; expected one of "
                f"{model.CP_TYPES}")
        sel &= idx.track_type_hist[:, model.CP_CODE[cp_type]] > 0
    return [_summary(idx, int(k)) for k in np.nonzero(sel)[0]]


def _cover_entries(hdr: dict, idx: TrackIndex, track_id: int) -> list:
    """Directory entries of the units covering one track."""
    wi, ti, tj = idx.decode_keys(idx.cover_units(track_id))
    keys = {(int(a), int(b), int(c)) for a, b, c in zip(wi, ti, tj)}
    return [e for e in hdr["units"] if tuple(e["key"]) in keys]


def track_read_plan(src, track_id: int) -> list:
    """Directory entries a ``decode_for_track`` would read -- and
    nothing else (byte offsets + lengths for remote range reads)."""
    _, hdr, idx = load_track_index(src)
    return _cover_entries(hdr, idx, track_id)


class _PatchField:
    """Fancy-indexing facade over a set of decoded unit boxes."""

    def __init__(self, shape, patches):
        self.shape = shape
        self.patches = patches            # [(box, int64 array)]

    def __getitem__(self, idx):
        t, i, j = (np.asarray(x) for x in idx)
        t, i, j = np.broadcast_arrays(t, i, j)
        out = np.zeros(t.shape, dtype=np.int64)
        found = np.zeros(t.shape, dtype=bool)
        for (t0, t1, i0, i1, j0, j1), arr in self.patches:
            m = ((t >= t0) & (t < t1) & (i >= i0) & (i < i1)
                 & (j >= j0) & (j < j1) & ~found)
            if m.any():
                out[m] = arr[t[m] - t0, i[m] - i0, j[m] - j0]
                found |= m
        assert found.all(), \
            "gather outside covering units -- index inflation bug"
        return out


@dataclasses.dataclass(frozen=True)
class TrackDecode:
    """decode_for_track result: the exact polyline + read accounting."""

    track: model.Track
    units_read: int
    units_total: int
    bytes_read: int
    entries: list


def decode_for_track(src, track_id: int, backend=None) -> TrackDecode:
    """Decode ONLY the units covering ``track_id`` and rebuild its
    polyline exactly (bit-identical to full-decode extraction).  Unit
    decode goes through the shared pipeline executor -- the same
    decode_payload implementation full decode and region decode use."""
    from ..core import pipeline as pipeline_mod

    source, hdr, idx = load_track_index(src)
    idx._check(track_id)
    T, H, W = hdr["shape"]
    entries = _cover_entries(hdr, idx, track_id)
    ex = pipeline_mod.executor_from_header(hdr, backend)
    patches_u, patches_v = [], []
    for entry in entries:
        uh, secs = source.unit(entry)
        u_rec, v_rec = ex.decode_unit(uh, secs)
        ufp, vfp = fixedpoint.refix(u_rec, v_rec, hdr["scale"])
        box = tuple(uh["box"])
        patches_u.append((box, ufp))
        patches_v.append((box, vfp))
    up = _PatchField((T, H, W), patches_u)
    vp = _PatchField((T, H, W), patches_v)

    seg_fid, _ = idx.track_segments(track_id)
    node_fid = np.unique(seg_fid)
    local_edges = np.searchsorted(node_fid, seg_fid).astype(np.int64)
    pos = extraction.node_positions(node_fid, up, vp, (T, H, W))
    types = classify_mod.classify_nodes(up, vp, pos,
                                        spiral_tol=idx.spiral_tol)
    # single-component assembly through the same code path as full
    # extraction, so ordering / loop detection can never diverge
    (track,) = model.build_tracks(
        pos, node_fid, types,
        np.zeros(len(node_fid), dtype=np.int32), local_edges)
    return TrackDecode(
        track=dataclasses.replace(track, track_id=track_id),
        units_read=len(entries),
        units_total=len(hdr["units"]),
        bytes_read=int(sum(e["len"] for e in entries)),
        entries=entries,
    )
