"""Feature-directed queries against a CPTT1 container's track index.

``query_tracks`` filters the sidecar track summaries (no unit decode at
all -- only the footer is parsed); ``track_read_plan`` turns one track
into the exact set of directory entries its reconstruction needs; and
``decode_for_track`` byte-slices and decodes ONLY those covering units,
re-deriving the track's polyline from the decoded values.

All entry points accept either raw container bytes or a filesystem
path.  Path sources are accessed with seek-based RANGE READS (footer +
covering unit frames only), so the "touches only the covering units"
property holds for the actual file I/O, not just the decode work.

Why the partial decode is exact: the sidecar stores the track's
*topology* (global face ids of its crossing nodes, segment edges, tet
anchor cells) but not its geometry.  Geometry is recomputed at query
time from the decoded field, gathering only grid points inside the
covering units (index.py's inflation argument guarantees every gather
-- barycentric node solve and classification Jacobian cell -- lands
there).  Units decode bit-identically whether decoded alone or as part
of the full field, so the polyline equals what full-decode extraction
would produce, node for node, bit for bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from collections import OrderedDict

from typing import Optional

import numpy as np

from .. import obs
from ..core import backend as backend_mod
from ..core import encode, fixedpoint
from ..core import faults as faults_mod
from . import classify as classify_mod
from . import extraction, model
from . import index as index_mod
from .index import TrackIndex, parse_track_index


class ContainerSource:
    """(offset, length) range reads over bytes or a file path.

    Path sources keep ONE file descriptor for the source's lifetime and
    read with ``os.pread`` -- positional, so concurrent range reads from
    the fetch pool never race on a shared seek offset (the previous
    implementation reopened the file on every call and silently
    truncated short reads).  Every read is length-checked: a truncated
    container raises ContainerError instead of decoding garbage.

    ``retries``/``backoff`` give TRANSIENT I/O errors (flaky NFS,
    interrupted reads -- raised as OSError) a bounded number of
    re-attempts with exponential backoff before the error escapes;
    ContainerError (corrupt bytes) is never retried -- re-reading
    cannot un-corrupt a frame.  ``faults`` accepts a core.faults
    FaultPlan probed at site ``"source.read"`` on every raw read.

    ``reads``/``bytes_fetched`` count the range reads actually issued --
    the observable the decoded-unit cache is benchmarked and tested
    against; ``retried`` counts recovered transient failures.  All
    three are views over per-source obs child counters, so one
    ``obs.snapshot()`` also sees the process-wide totals under
    ``query.range_reads`` / ``query.bytes_fetched`` / ``query.retried``.
    """

    def __init__(self, src, faults=None, retries: int = 0,
                 backoff: float = 0.01):
        if isinstance(src, (bytes, bytearray, memoryview)):
            self._blob = bytes(src)
            self._fd = None
            self._path = None
            self.size = len(self._blob)
        else:
            self._blob = None
            self._path = os.fspath(src)
            self._fd = os.open(self._path, os.O_RDONLY)
            self.size = os.fstat(self._fd).st_size
        self._c_reads = obs.child_counter("query.range_reads")
        self._c_bytes = obs.child_counter("query.bytes_fetched")
        self._c_retried = obs.child_counter("query.retried")
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.faults = faults_mod.FaultPoint(faults)
        self._lock = threading.Lock()
        self._hdr = None
        self._container_id = None

    @property
    def reads(self) -> int:
        return self._c_reads.value

    @property
    def bytes_fetched(self) -> int:
        return self._c_bytes.value

    @property
    def retried(self) -> int:
        return self._c_retried.value

    def _read_once(self, off: int, ln: int) -> bytes:
        self.faults.check("source.read")
        t0 = time.perf_counter_ns() if obs.enabled() else 0
        if self._blob is not None:
            data = self._blob[off : off + ln]
        else:
            if self._fd is None:
                raise ValueError("source is closed")
            # POSIX allows a single pread to return fewer bytes than
            # asked without being at EOF (signals, NFS, the ~2 GiB
            # per-call cap); only a 0-byte read means truncation
            parts = []
            got = 0
            while got < ln:
                chunk = os.pread(self._fd, ln - got, off + got)
                if not chunk:
                    break
                parts.append(chunk)
                got += len(chunk)
            data = b"".join(parts)
        if t0:
            obs.observe("query.pread_ns", time.perf_counter_ns() - t0)
        if len(data) != ln:
            raise encode.ContainerError(
                f"short read: [{off}, {off + ln}) of a {self.size}-byte "
                f"container returned {len(data)} bytes")
        self._c_reads.add(1)
        self._c_bytes.add(len(data))
        return data

    def read(self, off: int, ln: int) -> bytes:
        def _note(attempt, exc):
            self._c_retried.add(1)
        return faults_mod.retry_transient(
            lambda: self._read_once(off, ln), retries=self.retries,
            backoff=self.backoff, on_retry=_note, site="source.read")

    def read_many(self, entries: list, failures: list = None) -> list:
        """Concurrent range reads for a list of directory entries.
        Bytes sources read serially -- a memory slice has no I/O
        latency to hide, so pool handoff would be pure overhead.

        Worker exceptions ALWAYS surface: every future is awaited and
        the first failure re-raises on the caller's thread (typed --
        a truncated frame arrives as ContainerError, an I/O fault as
        OSError).  With ``failures`` given (degraded mode), per-entry
        errors are appended as ``(entry, exc)`` and the result list
        carries None at the failed positions instead of raising."""
        def one(e):
            try:
                return self.read(e["off"], e["len"])
            except (encode.ContainerError, OSError) as exc:
                if failures is None:
                    raise
                with self._lock:
                    failures.append((e, exc))
                return None
        if len(entries) <= 1 or self._blob is not None:
            return [one(e) for e in entries]
        from ..parallel.sharding import host_map, host_pool

        return host_map(host_pool("range-read"), one, entries)

    def close(self):
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):  # best-effort; explicit close preferred
        try:
            self.close()
        except Exception:
            pass

    def header(self) -> dict:
        """Directory footer (parsed once per source; three range reads).

        Also derives ``container_id`` -- a content fingerprint of the
        compressed footer bytes -- so the decoded-unit cache recognizes
        the same container across repeated queries regardless of
        whether it arrives as a path or as bytes."""
        if self._hdr is None:
            hdr, raw = encode.tiled_footer_ranged(self.read, self.size)
            self._hdr = hdr
            self._container_id = (self.size,
                                  hashlib.sha1(raw).hexdigest())
        return self._hdr

    @property
    def container_id(self):
        if self._container_id is None:
            self.header()
        return self._container_id

    def unit(self, entry: dict):
        return encode.read_tiled_unit_ranged(self.read, entry)


# backward-compatible alias (pre-engine name)
_Source = ContainerSource


# ----------------------------------------------------------------------
# bounded LRU cache of DECODED units
# ----------------------------------------------------------------------

class UnitCache:
    """Byte-bounded LRU of decoded unit patches.

    Keyed by ``(container_id, unit_off)``; values are the decoded
    float32 ``(box, u_rec, v_rec)`` patches, which every read path
    (region decode, track decode) derives its output from -- unit
    decode is deterministic and bit-identical across backends, so a
    cached patch is exactly what a fresh decode would produce.  Bounded
    by total payload bytes, not entry count, so one capacity knob works
    for any tile geometry.  Thread-safe: served reads may overlap.
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.cur_bytes = 0
        # hit/miss/eviction accounting lives in obs child counters (the
        # process totals appear in obs.snapshot() as cache.hits /
        # cache.misses / cache.evicted_bytes); the public fields below
        # are views over them
        self._c_hits = obs.child_counter("cache.hits")
        self._c_misses = obs.child_counter("cache.misses")
        self._c_evicted = obs.child_counter("cache.evicted_bytes")

    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    def get(self, key):
        with self._lock:
            val = self._d.get(key)
            if val is None:
                self._c_misses.add(1)
                return None
            self._d.move_to_end(key)
            self._c_hits.add(1)
            return val

    def put(self, key, value):
        box, u_rec, v_rec = value
        cost = int(u_rec.nbytes + v_rec.nbytes)
        with self._lock:
            if self.max_bytes <= 0 or cost > self.max_bytes:
                return
            old = self._d.pop(key, None)
            if old is not None:
                self.cur_bytes -= int(old[1].nbytes + old[2].nbytes)
            self._d[key] = value
            self.cur_bytes += cost
            while self.cur_bytes > self.max_bytes:
                _, (_, u_old, v_old) = self._d.popitem(last=False)
                dropped = int(u_old.nbytes + v_old.nbytes)
                self.cur_bytes -= dropped
                self._c_evicted.add(dropped)
        obs.gauge_set("cache.bytes", self.cur_bytes)

    def clear(self):
        with self._lock:
            self._d.clear()
            self.cur_bytes = 0
            self._c_hits.set_local(0)
            self._c_misses.set_local(0)
        obs.gauge_set("cache.bytes", 0)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._d), "bytes": self.cur_bytes,
                    "max_bytes": self.max_bytes, "hits": self.hits,
                    "misses": self.misses}


def _cache_mb_from_env() -> float:
    raw = os.environ.get("REPRO_UNIT_CACHE_MB", "")
    try:
        return float(raw) if raw else 256.0
    except ValueError:
        import warnings

        warnings.warn(f"ignoring malformed REPRO_UNIT_CACHE_MB={raw!r}; "
                      f"using the 256 MiB default")
        return 256.0


unit_cache = UnitCache(int(_cache_mb_from_env() * 2**20))


def configure_unit_cache(max_mb: float) -> UnitCache:
    """Resize (and clear) the process-wide decoded-unit cache.
    ``max_mb=0`` disables caching.  Initial size comes from the
    ``REPRO_UNIT_CACHE_MB`` environment variable (default 256)."""
    unit_cache.clear()
    unit_cache.max_bytes = int(max_mb * 2**20)
    return unit_cache


def fetch_decoded_units(source: ContainerSource, ex, entries: list,
                        failures: list = None):
    """Decoded ``(box, u_rec, v_rec)`` patches for directory entries,
    served from the unit cache; missing unit frames are range-read
    CONCURRENTLY, checksum-verified, decoded once through the shared
    executor, and cached.  Returns (patches in entry order, cache hit
    count).

    With ``failures`` given (degraded mode), units that fail the range
    read, the CRC check, or decode are appended as ``(entry, exc)`` and
    SKIPPED -- the patch list then holds only the surviving units, in
    entry order.  Without it, the first damaged unit raises."""
    cid = source.container_id
    out = {}
    missing = []
    for e in entries:
        got = unit_cache.get((cid, e["off"]))
        if got is None:
            missing.append(e)
        else:
            out[e["off"]] = got
    n_hits = len(entries) - len(missing)
    if missing:
        obs.count("query.units_decoded", len(missing))
        frames = source.read_many(missing, failures=failures)
        for e, frame in zip(missing, frames):
            if frame is None:       # read failed (already in failures)
                continue
            try:
                encode.check_unit_frame(frame, e)
                uh, secs = encode.unpack(frame)
                u_rec, v_rec = ex.decode_unit(uh, secs)
            except encode.ContainerError as exc:
                if failures is None:
                    raise
                failures.append((e, exc))
                continue
            val = (tuple(uh["box"]), u_rec, v_rec)
            unit_cache.put((cid, e["off"]), val)
            out[e["off"]] = val
    return [out[e["off"]] for e in entries if e["off"] in out], n_hits


def load_track_index(src):
    """(source, footer header, TrackIndex) of a tiled container.

    ``src`` is raw bytes or a path; only the footer is read here.
    """
    source = ContainerSource(src)
    hdr = source.header()
    return source, hdr, parse_track_index(hdr)


def _summary(idx: TrackIndex, k: int) -> dict:
    hist = idx.track_type_hist[k]
    return {
        "track_id": int(k),
        "t_min": float(idx.track_t_min[k]),
        "t_max": float(idx.track_t_max[k]),
        "bbox": [float(x) for x in idx.track_bbox[k]],  # y0, y1, x0, x1
        "n_nodes": int(idx.track_n_nodes[k]),
        "n_segments": int(idx.track_seg_counts[k]),
        "type_hist": {name: int(hist[i])
                      for i, name in enumerate(model.CP_TYPES) if hist[i]},
        "dominant_type": model.CP_TYPES[int(np.argmax(hist))],
        "n_cover_units": int(idx.track_cover_ptr[k + 1]
                             - idx.track_cover_ptr[k]),
    }


def track_summaries(src) -> list:
    """All track summaries of a container (footer parse only)."""
    source, _, idx = load_track_index(src)
    with source:
        return [_summary(idx, k) for k in range(idx.n_tracks)]


def query_tracks(src, bbox=None, trange=None, cp_type=None) -> list:
    """Tracks matching the given feature filters (footer parse only).

    bbox:   (y_min, y_max, x_min, x_max) grid coordinates; a track
            matches when its node bounding box overlaps.
    trange: (t_min, t_max); overlap test on the track lifetime.
    cp_type: a model.CP_TYPES name; matches tracks containing at least
            one node of that type.

    Summaries reflect the pre-compression field; the verify loop makes
    its crossed-face topology identical to the decoded field's, and
    node positions move by O(eb) only, so the filters are exact in
    topology and eb-accurate in geometry.
    """
    source, _, idx = load_track_index(src)
    source.close()
    sel = np.ones(idx.n_tracks, dtype=bool)
    if trange is not None:
        t0, t1 = float(trange[0]), float(trange[1])
        sel &= (idx.track_t_max >= t0) & (idx.track_t_min <= t1)
    if bbox is not None:
        y0, y1, x0, x1 = (float(b) for b in bbox)
        sel &= (idx.track_bbox[:, 1] >= y0) & (idx.track_bbox[:, 0] <= y1)
        sel &= (idx.track_bbox[:, 3] >= x0) & (idx.track_bbox[:, 2] <= x1)
    if cp_type is not None:
        if cp_type not in model.CP_CODE:
            raise ValueError(
                f"unknown cp_type {cp_type!r}; expected one of "
                f"{model.CP_TYPES}")
        sel &= idx.track_type_hist[:, model.CP_CODE[cp_type]] > 0
    return [_summary(idx, int(k)) for k in np.nonzero(sel)[0]]


def _cover_entries(hdr: dict, idx: TrackIndex, track_id: int) -> list:
    """Directory entries of the units covering one track."""
    wi, ti, tj = idx.decode_keys(idx.cover_units(track_id))
    keys = {(int(a), int(b), int(c)) for a, b, c in zip(wi, ti, tj)}
    return [e for e in hdr["units"] if tuple(e["key"]) in keys]


def track_read_plan(src, track_id: int) -> list:
    """Directory entries a ``decode_for_track`` would read -- and
    nothing else (byte offsets + lengths for remote range reads)."""
    source, hdr, idx = load_track_index(src)
    source.close()
    return _cover_entries(hdr, idx, track_id)


class _PatchField:
    """Fancy-indexing facade over a set of decoded unit boxes."""

    def __init__(self, shape, patches):
        self.shape = shape
        self.patches = patches            # [(box, int64 array)]

    def __getitem__(self, idx):
        t, i, j = (np.asarray(x) for x in idx)
        t, i, j = np.broadcast_arrays(t, i, j)
        out = np.zeros(t.shape, dtype=np.int64)
        found = np.zeros(t.shape, dtype=bool)
        for (t0, t1, i0, i1, j0, j1), arr in self.patches:
            m = ((t >= t0) & (t < t1) & (i >= i0) & (i < i1)
                 & (j >= j0) & (j < j1) & ~found)
            if m.any():
                out[m] = arr[t[m] - t0, i[m] - i0, j[m] - j0]
                found |= m
        if not found.all():
            raise encode.ContainerError(
                "track gather landed outside the covering units -- "
                "corrupt or incompatible track index")
        return out


@dataclasses.dataclass(frozen=True)
class TrackDecode:
    """decode_for_track result: the exact polyline + read accounting.

    ``bytes_read`` is the LOGICAL read volume of the plan (sum of
    covering-unit frame lengths -- what a cold decode costs);
    ``range_reads``/``bytes_fetched`` count the range reads actually
    issued this call, and shrink to the three footer reads when every
    covering unit is served from the decoded-unit cache.

    Degraded decodes (``degraded=True`` over a damaged container)
    additionally report what was lost: ``missing_units`` lists the
    covering units that failed to read or verify, ``segments_dropped``
    counts track segments whose reconstruction would have gathered
    into a missing unit, and ``pieces`` holds the surviving connected
    sub-polylines; ``track`` is then the largest piece (or None when
    nothing survives).
    """

    track: Optional[model.Track]
    units_read: int
    units_total: int
    bytes_read: int
    entries: list
    range_reads: int = 0
    bytes_fetched: int = 0
    cache_hits: int = 0
    missing_units: list = dataclasses.field(default_factory=list)
    segments_dropped: int = 0
    pieces: tuple = ()

    @property
    def complete(self) -> bool:
        return not self.missing_units


def _segment_survivors(seg_cell, missing_boxes, shape):
    """Keep mask over segments whose gather footprint avoids every
    missing unit's owned box.

    The footprint is the same +2-clamped point cover the track index
    uses to compute covering units (index._cover_points) -- so a kept
    segment's node position and Jacobian classification gather ONLY
    points owned by units that decoded, and are bit-identical to a
    full, undamaged decode of that segment.
    """
    pts = index_mod._cover_points(seg_cell, shape)        # (S, P, 3)
    bad = np.zeros(pts.shape[:2], dtype=bool)
    for t0, t1, i0, i1, j0, j1 in missing_boxes:
        bad |= ((pts[..., 0] >= t0) & (pts[..., 0] < t1)
                & (pts[..., 1] >= i0) & (pts[..., 1] < i1)
                & (pts[..., 2] >= j0) & (pts[..., 2] < j1))
    return ~bad.any(axis=1)


def decode_for_track(src, track_id: int, backend=None,
                     degraded: bool = False) -> TrackDecode:
    """Decode ONLY the units covering ``track_id`` and rebuild its
    polyline exactly (bit-identical to full-decode extraction).  Unit
    decode goes through the shared pipeline executor -- the same
    decode_payload implementation full decode and region decode use --
    and repeated or overlapping queries are served from the
    decoded-unit cache instead of re-reading and re-decoding.

    ``degraded=True``: units that fail to read or checksum-verify are
    reported in ``missing_units`` instead of raising, segments that
    would gather into them are dropped, and the surviving connected
    sub-polylines come back in ``pieces`` (assembled through the same
    build_tracks path, so each piece is exact on the points it keeps).
    Structural damage -- an unreadable footer -- still raises; run
    ``encode.salvage_container`` first for that.
    """
    from ..core import pipeline as pipeline_mod

    source, hdr, idx = load_track_index(src)
    with obs.span("query.decode_for_track",
                  track_id=int(track_id)) as sp, source:
        idx._check(track_id)
        T, H, W = hdr["shape"]
        entries = _cover_entries(hdr, idx, track_id)
        ex = pipeline_mod.executor_from_header(hdr, backend)
        failures = [] if degraded else None
        decoded, n_hits = fetch_decoded_units(source, ex, entries,
                                              failures=failures)
        patches_u, patches_v = [], []
        for box, u_rec, v_rec in decoded:
            ufp, vfp = fixedpoint.refix(u_rec, v_rec, hdr["scale"])
            patches_u.append((box, ufp))
            patches_v.append((box, vfp))
        up = _PatchField((T, H, W), patches_u)
        vp = _PatchField((T, H, W), patches_v)

        seg_fid, seg_cell = idx.track_segments(track_id)
        n_dropped = 0
        if failures:
            keep = _segment_survivors(
                seg_cell, [tuple(e["box"]) for e, _ in failures],
                (T, H, W))
            n_dropped = int(len(seg_fid) - keep.sum())
            seg_fid = seg_fid[keep]
        missing = [{"key": tuple(e["key"]), "box": tuple(e["box"]),
                    "error": str(err)} for e, err in (failures or ())]
        acct = dict(
            units_read=len(entries) - len(missing),
            units_total=len(hdr["units"]),
            bytes_read=int(sum(e["len"] for e in entries)),
            entries=entries,
            range_reads=source.reads,
            bytes_fetched=source.bytes_fetched,
            cache_hits=n_hits,
            missing_units=missing,
            segments_dropped=n_dropped,
        )
        sp.set(units=len(entries), cache_hits=n_hits,
               range_reads=source.reads,
               bytes_fetched=source.bytes_fetched)
        if len(seg_fid) == 0:
            return TrackDecode(track=None, **acct)
        node_fid = np.unique(seg_fid)
        local_edges = np.searchsorted(node_fid, seg_fid).astype(np.int64)
        pos = extraction.node_positions(node_fid, up, vp, (T, H, W))
        types = classify_mod.classify_nodes(up, vp, pos,
                                            spiral_tol=idx.spiral_tol)
        if n_dropped == 0:
            # single-component assembly through the same code path as
            # full extraction, so ordering / loop detection can never
            # diverge
            (track,) = model.build_tracks(
                pos, node_fid, types,
                np.zeros(len(node_fid), dtype=np.int32), local_edges)
            return TrackDecode(
                track=dataclasses.replace(track, track_id=track_id),
                **acct)
        # dropped segments can split the survivors into several
        # connected pieces; label them and assemble each one
        labels = np.asarray(backend_mod.connected_labels(
            len(node_fid), local_edges, backend="numpy"))
        track_of = extraction.dense_track_ids(node_fid, labels)
        pieces = model.build_tracks(pos, node_fid, types,
                                    track_of, local_edges)
        pieces = tuple(sorted(pieces, key=lambda p: -len(p.face_ids)))
        return TrackDecode(
            track=dataclasses.replace(pieces[0], track_id=track_id),
            pieces=pieces, **acct)
