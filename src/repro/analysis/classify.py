"""Critical-point classification from the interpolated Jacobian.

At a crossing node (t, y, x) the field is modeled exactly as the
compressor's mesh sees it between grid points: bilinear in space within
the containing cell, linear in time between the two bracketing frames.
The velocity-gradient tensor of that interpolant,

    J = [[du/dx, du/dy],
         [dv/dx, dv/dy]]   (grid units),

is evaluated at the node and the eigenvalue structure gives the
standard 2D critical-point taxonomy:

    det J < 0                      saddle
    det J > 0, tr^2 >= 4 det       source (tr > 0) / sink (tr < 0)
    det J > 0, tr^2 <  4 det       spiral_out / spiral_in / center

The center-vs-spiral split is tolerance-based on sampled data: a
mathematically divergence-free flow has tr J = 0 only up to
discretization error, so nodes with |tr| <= spiral_tol * sqrt(det) are
reported as centers.  det == 0 (structurally unstable) is tagged
``degenerate``; SoS guarantees the *predicates* are never degenerate
but the float Jacobian can still be.

All functions are numpy (analysis is host-side post-processing of
int64 fixed-point fields; dividing by ``scale`` is unnecessary because
every classification quantity is scale-invariant: u and v carry the
same fixed-point scale, so J scales uniformly and sign(det), sign(tr)
and tr^2/det are unchanged).
"""
from __future__ import annotations

import numpy as np

from .model import CP_CODE

DEFAULT_SPIRAL_TOL = 0.05


def cell_jacobian(ufp, vfp, t, y, x):
    """J entries of the space-bilinear/time-linear interpolant at nodes.

    ufp, vfp: (T, H, W) arrays (any real dtype; int64 fixed point is
    used as-is) OR any object exposing ``.shape`` and fancy indexing
    ``f[t_arr, i_arr, j_arr]`` (the query path gathers from a patchwork
    of decoded units).  t, y, x: (N,) float64 node coordinates in grid
    units.  Returns (du_dx, du_dy, dv_dx, dv_dy) float64 arrays.
    """
    T, H, W = ufp.shape
    t = np.asarray(t, np.float64)
    y = np.asarray(y, np.float64)
    x = np.asarray(x, np.float64)
    t0 = np.clip(np.floor(t), 0, T - 2).astype(np.int64)
    i0 = np.clip(np.floor(y), 0, H - 2).astype(np.int64)
    j0 = np.clip(np.floor(x), 0, W - 2).astype(np.int64)
    at = t - t0
    ay = y - i0
    ax = x - j0

    def grads(f):
        c = {}
        for dt in (0, 1):
            for di in (0, 1):
                for dj in (0, 1):
                    c[dt, di, dj] = np.asarray(
                        f[t0 + dt, i0 + di, j0 + dj], np.float64)
        # blend in time first
        g = {(di, dj): (1 - at) * c[0, di, dj] + at * c[1, di, dj]
             for di in (0, 1) for dj in (0, 1)}
        d_dx = (1 - ay) * (g[0, 1] - g[0, 0]) + ay * (g[1, 1] - g[1, 0])
        d_dy = (1 - ax) * (g[1, 0] - g[0, 0]) + ax * (g[1, 1] - g[0, 1])
        return d_dx, d_dy

    du_dx, du_dy = grads(ufp)
    dv_dx, dv_dy = grads(vfp)
    return du_dx, du_dy, dv_dx, dv_dy


def classify_nodes(ufp, vfp, nodes, spiral_tol: float = DEFAULT_SPIRAL_TOL):
    """CP type codes (model.CP_TYPES) for nodes (N, 3) = (t, y, x)."""
    nodes = np.asarray(nodes, np.float64)
    if len(nodes) == 0:
        return np.empty(0, dtype=np.int8)
    du_dx, du_dy, dv_dx, dv_dy = cell_jacobian(
        ufp, vfp, nodes[:, 0], nodes[:, 1], nodes[:, 2])
    tr = du_dx + dv_dy
    det = du_dx * dv_dy - du_dy * dv_dx
    disc = tr * tr - 4.0 * det

    out = np.full(len(nodes), CP_CODE["degenerate"], dtype=np.int8)
    saddle = det < 0
    node_like = (det > 0) & (disc >= 0)
    spiral_like = (det > 0) & (disc < 0)
    out[saddle] = CP_CODE["saddle"]
    out[node_like & (tr > 0)] = CP_CODE["source"]
    out[node_like & (tr <= 0)] = CP_CODE["sink"]
    centerish = spiral_like & (np.abs(tr) <= spiral_tol * np.sqrt(
        np.maximum(det, 0.0)))
    out[spiral_like & (tr > 0)] = CP_CODE["spiral_out"]
    out[spiral_like & (tr <= 0)] = CP_CODE["spiral_in"]
    out[centerish] = CP_CODE["center"]
    return out
