"""Track-aware adaptive error-bound policies (DESIGN.md #16).

Builds a ``core.ebpolicy.TilePolicy`` that TIGHTENS the base bound on
every policy unit a critical-point trajectory passes through (with a
one-cell/one-frame safety margin) and RELAXES it everywhere else --
the rate-allocation side of the paper's guarantee split: topology
exactness comes from the verify fixpoint regardless of the base bound,
so the policy spends bits near features without risking FC > 0.

The trajectory geometry comes from the same extraction the compressor
preserves (``analysis.extract`` over the original field's fixed-point
planes), so "near a track" is defined against exactly the features the
decoder will reproduce.
"""
from __future__ import annotations

import numpy as np

from ..core import ebpolicy, fixedpoint
from . import extraction


def track_units(u, v, window_t: int, tile_h: int, tile_w: int,
                margin: float = 1.0, backend=None,
                fixed_bits: int = fixedpoint.DEFAULT_BITS):
    """Policy-unit keys ``(wi, ti, tj)`` any trajectory touches.

    ``margin`` inflates each crossing node (in cells/frames) before
    mapping it onto the policy grid, so the one-cell/one-frame seam
    inflation of the policy resolution can never pull a relaxed bound
    onto a track vertex.
    """
    u = np.asarray(u, np.float32)
    v = np.asarray(v, np.float32)
    _, ufp, vfp = fixedpoint.to_fixed(u, v, fixed_bits)
    traj = extraction.extract(ufp, vfp, backend=backend, classify=False)
    nodes = np.asarray(traj.nodes, np.float64).reshape(-1, 3)
    T, H, W = u.shape
    sizes = (window_t, tile_h, tile_w)
    limits = (T - 1, H - 1, W - 1)
    keys = set()
    for t, y, x in nodes:
        ranges = []
        for c, size, hi in zip((t, y, x), sizes, limits):
            lo_cell = int(np.floor(max(c - margin, 0)))
            hi_cell = int(np.floor(min(c + margin, hi)))
            ranges.append(range(lo_cell // size, hi_cell // size + 1))
        for wi in ranges[0]:
            for ti in ranges[1]:
                for tj in ranges[2]:
                    keys.add((wi, ti, tj))
    return keys


def track_aware_policy(u, v, tight: float, relaxed: float,
                       window_t: int = 32, tile_h: int = 64,
                       tile_w: int = 64, margin: float = 1.0,
                       backend=None,
                       fixed_bits: int = fixedpoint.DEFAULT_BITS):
    """Tighten-near-trajectories policy for the original field.

    Units a track passes through get base bound ``tight``; all other
    units (and the past-the-end default) get ``relaxed``.  Bounds are
    in ``cfg.eb`` units, so ``cfg.mode`` scaling applies as usual.
    """
    if not (0 < tight <= relaxed):
        raise ValueError(f"need 0 < tight <= relaxed, got "
                         f"tight={tight}, relaxed={relaxed}")
    keys = track_units(u, v, window_t, tile_h, tile_w, margin=margin,
                       backend=backend, fixed_bits=fixed_bits)
    return ebpolicy.TilePolicy.make(
        window_t, tile_h, tile_w, default=float(relaxed),
        values={k: float(tight) for k in keys})
