"""Trajectory analytics & feature-query subsystem.

Turns the compressor's preserved critical-point trajectories into
queryable objects:

* ``extract``             -- full geometric extraction: space-time
                             polylines + CP types (TrajectorySet)
* ``classify_nodes``      -- Jacobian-eigenvalue CP classification
* ``query_tracks``        -- filter the CPTT1 sidecar track index
                             (bbox / time range / CP type); footer-only
* ``track_read_plan``     -- directory entries one track needs
* ``decode_for_track``    -- decode ONLY the covering units and rebuild
                             the exact polyline
* ``track_summaries``     -- all per-track index summaries
* ``track_aware_policy``  -- tighten-near-trajectories adaptive eb
                             policy (core.ebpolicy; DESIGN.md #16)

See DESIGN.md #9 for the sidecar index format and the seam-stitching
argument.
"""
from .adaptive import track_aware_policy, track_units  # noqa: F401
from .classify import classify_nodes  # noqa: F401
from .extraction import extract  # noqa: F401
from .index import (  # noqa: F401
    TRACK_INDEX_VERSION,
    TrackIndex,
    TrackIndexBuilder,
    parse_track_index,
)
from .model import CP_CODE, CP_TYPES, Track, TrajectorySet  # noqa: F401
from .query import (  # noqa: F401
    ContainerSource,
    TrackDecode,
    UnitCache,
    configure_unit_cache,
    decode_for_track,
    load_track_index,
    query_tracks,
    track_read_plan,
    track_summaries,
    unit_cache,
)
