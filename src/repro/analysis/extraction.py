"""Geometric track extraction: polylines, not just counts.

Upgrades ``core.trajectory.extract_tracks`` (which reduces the zero set
to ``n_tracks`` via a host union-find) to the full geometry:

1. every crossed face yields a crossing *node* at the barycentric zero
   of the face's linear interpolant (paper Eq. 2), an exact function of
   the three int64 vertex values -> (t, y, x) float64;
2. the 2 crossed faces of each tet (Lemma 1, enforced) join into a
   segment edge keyed on global face ids (grid.tet_face_map);
3. the segment graph is labeled with the device-resident batched
   connected-component labeling ``backend.connected_labels`` (iterated
   min-hook + pointer jumping; pallas/xla run on device, numpy is the
   host reference -- all three bit-identical);
4. nodes are typed from the eigenvalues of the interpolated Jacobian
   (classify.py) and assembled into a TrajectorySet of canonical
   polylines (model.py).

Everything downstream of the predicate tables is a sparse computation
proportional to the number of crossings, not the field size.
"""
from __future__ import annotations

import numpy as np

from ..core import backend as backend_mod
from ..core import grid, sos, trajectory
from . import classify as classify_mod
from . import model


def node_positions(fids, ufp, vfp, shape):
    """(N, 3) float64 (t, y, x) barycentric crossing points of faces.

    fids: global face ids; ufp/vfp: (T, H, W) int64 fixed point (or any
    object supporting ``f[t_arr, i_arr, j_arr]`` fancy indexing -- the
    query path gathers from a patchwork of decoded units).  The
    arithmetic is a fixed sequence of float64 ops on the int64 values,
    so two fields that agree on these faces yield bit-identical
    positions (the query-roundtrip guarantee).
    """
    T, H, W = shape
    HW = H * W
    verts = grid.face_vertices(fids, H, W)           # (N, 3) global ids
    tv = verts // HW
    iv = (verts % HW) // W
    jv = verts % W
    u3 = np.asarray(ufp[tv, iv, jv], np.int64)
    v3 = np.asarray(vfp[tv, iv, jv], np.int64)
    alpha, beta, gamma = sos.barycentric_crossing(u3, v3)
    w = np.stack([alpha, beta, gamma], axis=-1)
    tvf = tv.astype(np.float64)
    ivf = iv.astype(np.float64)
    jvf = jv.astype(np.float64)
    return np.stack([(w * tvf).sum(-1), (w * ivf).sum(-1), (w * jvf).sum(-1)],
                    axis=-1)


def dense_track_ids(face_ids, labels):
    """Dense track ids ordered by ascending component-minimum face id.

    labels: per-node component label == local index of the component's
    minimum node (backend.connected_labels contract).  face_ids is
    sorted ascending, so the label value order IS the min-fid order and
    the dense renumbering is a stable, tiling-independent id
    assignment.
    """
    roots = np.unique(labels)
    remap = np.full(len(face_ids), -1, dtype=np.int32)
    remap[roots] = np.arange(len(roots), dtype=np.int32)
    return remap[labels]


def extract(ufp, vfp, backend=None, tables=None, classify=True,
            spiral_tol=classify_mod.DEFAULT_SPIRAL_TOL):
    """Full geometric extraction -> model.TrajectorySet.

    ufp, vfp: (T, H, W) int64 fixed-point fields (fixedpoint.refix /
    to_fixed output).  ``tables`` optionally reuses precomputed
    face-predicate tables.  ``backend`` routes the connected-component
    labeling (None -> env/hardware auto, like the compressor).
    """
    ufp = np.asarray(ufp)
    vfp = np.asarray(vfp)
    T, H, W = ufp.shape
    shape = (T, H, W)
    be = backend_mod.resolve(backend)
    if tables is None:
        tables = trajectory.face_predicate_tables(ufp, vfp)

    family, _ = grid.tet_face_map(H, W)
    step = trajectory._frame_chunk(4 * family.shape[0])
    edge_parts = []
    for lo in range(0, T - 1, step):
        hi = min(lo + step, T - 1)
        crossed = trajectory.tet_crossings(tables, shape, lo, hi)
        edge_parts.append(trajectory.segment_edges(crossed, lo, shape))
    edges_fid = np.concatenate(edge_parts, axis=0) if edge_parts else \
        np.empty((0, 2), dtype=np.int64)

    # compact the sparse crossing nodes; face_ids ascending
    face_ids, edges = np.unique(edges_fid, return_inverse=True)
    edges = edges.reshape(-1, 2).astype(np.int64)
    labels = np.asarray(backend_mod.connected_labels(
        len(face_ids), edges, backend=be))
    track_of = dense_track_ids(face_ids, labels)

    nodes = node_positions(face_ids, ufp, vfp, shape)
    if classify and len(face_ids):
        types = classify_mod.classify_nodes(ufp, vfp, nodes,
                                            spiral_tol=spiral_tol)
    else:
        types = np.full(len(face_ids), model.CP_CODE["degenerate"],
                        dtype=np.int8)
    tracks = model.build_tracks(nodes, face_ids, types, track_of, edges)
    return model.TrajectorySet(
        shape=shape, nodes=nodes, face_ids=face_ids, types=types,
        track_of=track_of, edges=edges, tracks=tracks)
