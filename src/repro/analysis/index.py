"""CPTT1 sidecar track index: per-unit segments, global track ids.

Built during tiled/streaming compression (core/tiling.py) and stored
in the container's directory FOOTER under ``encode.TRACK_INDEX_KEY`` --
an optional msgpack key old readers skip without parsing, carrying its
own version so it can evolve independently of the container format.

What is stored (and why it reconstructs exact tracks):

* per (tile, window) unit: the zero-set *segments* of the tets the unit
  owns -- (fid_a, fid_b) global-face-id pairs plus the tet's anchor
  cell.  Tet ownership (the unit whose owned box contains the anchor)
  partitions all tets, so the union over units is exactly the global
  segment set, each segment once.
* global face ids are canonical (grid.py enumeration): the same
  geometric face gets the same id from both incident tets even when
  they live in different units, so concatenating the per-unit segment
  lists and labeling connected components stitches seam-crossing tracks
  EXACTLY -- no geometric matching, no tolerance.
* per track: lifetime, bbox, node count, CP-type histogram (summaries
  for query filtering; geometry is measured on the pre-compression
  field, whose crossed-face topology the verify loop guarantees equals
  the decoded field's), and the covering-unit list: every unit owning
  any grid point of the inflated cells of the track's segments.  The
  inflation (one extra point on the + side, see _cover_points) covers
  every gather ``decode_for_track`` performs -- barycentric node
  coordinates AND the classification Jacobian cell -- so decoding just
  the covering units reproduces full-decode extraction bit for bit.

Track ids are assigned by ascending minimum face id of the component --
the same rule extraction.extract uses -- so index ids, host-extraction
ids and query-time ids all agree.
"""
from __future__ import annotations

import numpy as np

from ..core import backend as backend_mod
from ..core import encode
from . import classify as classify_mod
from .extraction import dense_track_ids
from .model import CP_TYPES

TRACK_INDEX_VERSION = 1

_ARRAY_FIELDS = (
    "unit_keys", "unit_seg_ptr", "seg_fid", "seg_cell", "seg_track",
    "track_t_min", "track_t_max", "track_bbox", "track_n_nodes",
    "track_type_hist", "track_cover_ptr", "track_cover_unit",
)


def unit_key_of(t, i, j, tgrid):
    """(wi, ti, tj) unit key owning grid point(s) (t, i, j)."""
    return (np.asarray(t) // tgrid.window_t,
            np.asarray(i) // tgrid.tile_h,
            np.asarray(j) // tgrid.tile_w)


def encode_unit_key(wi, ti, tj, nti, ntj):
    return (np.asarray(wi) * nti + np.asarray(ti)) * ntj + np.asarray(tj)


def _cover_points(cells, shape):
    """Grid points decode_for_track may gather, per segment cell.

    A segment's node lies inside its tet's cell [t, t+1] x [i, i+1] x
    [j, j+1]; the classification cell is the floor of the node position
    clipped to the grid, which can reach one past the cell's + corner
    when a node sits exactly on a cell boundary.  So the cover is the
    points t..min(t+2, T-1) x i..min(i+2, H-1) x j..min(j+2, W-1).
    Returns (M, P, 3) int64 (P = 27 with out-of-range points clamped
    back inside -- clamping only repeats an already-covered point).
    """
    T, H, W = shape
    cells = np.asarray(cells, np.int64)
    d = np.stack(np.meshgrid(*([np.arange(3)] * 3), indexing="ij"),
                 axis=-1).reshape(-1, 3)                  # (27, 3)
    pts = cells[:, None, :] + d[None, :, :]
    return np.minimum(pts, np.asarray([T - 1, H - 1, W - 1]))


class TrackIndexBuilder:
    """Accumulates per-unit segment records; finalizes the footer dict.

    ``add_unit`` must be called once per emitted unit, in emission
    order, with the segments of the tets that unit owns (global face
    ids + anchor cells) and the unit's crossing-node records (face id,
    position, CP type) -- everything else is derived at finalize.
    """

    def __init__(self, tgrid, backend: str,
                 spiral_tol: float = classify_mod.DEFAULT_SPIRAL_TOL):
        self.tgrid = tgrid
        self.backend = backend
        self.spiral_tol = float(spiral_tol)
        self._keys = []
        self._seg_fid = []
        self._seg_cell = []
        self._node_fid = []
        self._node_pos = []
        self._node_type = []

    def add_unit(self, key, seg_fid, seg_cell, node_fid, node_pos,
                 node_type):
        self._keys.append([int(k) for k in key])
        self._seg_fid.append(np.asarray(seg_fid, np.int64).reshape(-1, 2))
        self._seg_cell.append(np.asarray(seg_cell, np.int32).reshape(-1, 3))
        self._node_fid.append(np.asarray(node_fid, np.int64))
        self._node_pos.append(
            np.asarray(node_pos, np.float64).reshape(-1, 3))
        self._node_type.append(np.asarray(node_type, np.int8))

    def finalize(self, shape) -> dict:
        """Global stitch + summaries -> msgpack-able footer section.

        ``shape`` is the final (T, H, W) -- only known at finish time
        for streams, which is fine because face ids are T-independent.
        """
        T, H, W = (int(s) for s in shape)
        g = self.tgrid
        nwi = -(-T // g.window_t)
        nti = -(-H // g.tile_h)
        ntj = -(-W // g.tile_w)
        U = len(self._keys)
        seg_fid = np.concatenate(self._seg_fid, 0) if U else \
            np.empty((0, 2), np.int64)
        seg_cell = np.concatenate(self._seg_cell, 0) if U else \
            np.empty((0, 3), np.int32)
        counts = np.array([len(s) for s in self._seg_fid], np.int64)
        unit_seg_ptr = np.zeros(U + 1, np.int64)
        unit_seg_ptr[1:] = np.cumsum(counts)

        # global stitch: same CCL + same id rule as extraction.extract
        face_ids, edges = np.unique(seg_fid, return_inverse=True)
        edges = edges.reshape(-1, 2).astype(np.int64)
        labels = np.asarray(backend_mod.connected_labels(
            len(face_ids), edges, backend=self.backend))
        track_of_face = dense_track_ids(face_ids, labels)
        seg_track = track_of_face[
            np.searchsorted(face_ids, seg_fid[:, 0])].astype(np.int32)
        K = int(track_of_face.max()) + 1 if len(face_ids) else 0

        # node summaries, deduped by face id (a seam face is recorded by
        # both incident units with identical values)
        if U and sum(len(n) for n in self._node_fid):
            nf = np.concatenate(self._node_fid)
            npos = np.concatenate(self._node_pos, 0)
            ntyp = np.concatenate(self._node_type)
            _, first = np.unique(nf, return_index=True)
            nf, npos, ntyp = nf[first], npos[first], ntyp[first]
        else:
            nf = np.empty(0, np.int64)
            npos = np.empty((0, 3), np.float64)
            ntyp = np.empty(0, np.int8)
        assert np.array_equal(nf, face_ids), \
            "node records do not match the stitched segment faces"
        tr = track_of_face

        track_t_min = np.full(K, np.inf)
        track_t_max = np.full(K, -np.inf)
        track_bbox = np.stack([np.full(K, np.inf), np.full(K, -np.inf),
                               np.full(K, np.inf), np.full(K, -np.inf)], 1)
        np.minimum.at(track_t_min, tr, npos[:, 0])
        np.maximum.at(track_t_max, tr, npos[:, 0])
        np.minimum.at(track_bbox[:, 0], tr, npos[:, 1])
        np.maximum.at(track_bbox[:, 1], tr, npos[:, 1])
        np.minimum.at(track_bbox[:, 2], tr, npos[:, 2])
        np.maximum.at(track_bbox[:, 3], tr, npos[:, 2])
        track_n_nodes = np.bincount(tr, minlength=K).astype(np.int32)
        track_type_hist = np.zeros((K, len(CP_TYPES)), np.int32)
        np.add.at(track_type_hist, (tr, ntyp.astype(np.int64)), 1)

        # covering units per track (sorted unique, CSR)
        pts = _cover_points(seg_cell, (T, H, W)).reshape(-1, 3)
        wi, ti, tj = unit_key_of(pts[:, 0], pts[:, 1], pts[:, 2], g)
        enc = encode_unit_key(wi, ti, tj, nti, ntj)
        pair = np.stack(
            [np.repeat(seg_track.astype(np.int64), 27), enc], 1)
        pair = np.unique(pair, axis=0)
        track_cover_ptr = np.zeros(K + 1, np.int64)
        track_cover_ptr[1:] = np.cumsum(np.bincount(pair[:, 0], minlength=K))
        track_cover_unit = pair[:, 1].astype(np.int32)

        arrays = {
            "unit_keys": np.asarray(self._keys, np.int32).reshape(U, 3),
            "unit_seg_ptr": unit_seg_ptr,
            "seg_fid": seg_fid,
            "seg_cell": seg_cell,
            "seg_track": seg_track,
            "track_t_min": track_t_min,
            "track_t_max": track_t_max,
            "track_bbox": track_bbox,
            "track_n_nodes": track_n_nodes,
            "track_type_hist": track_type_hist,
            "track_cover_ptr": track_cover_ptr,
            "track_cover_unit": track_cover_unit,
        }
        return {
            "version": TRACK_INDEX_VERSION,
            "n_tracks": K,
            "n_segments": int(len(seg_fid)),
            "spiral_tol": self.spiral_tol,
            "grid_units": [int(nwi), int(nti), int(ntj)],
            "arrays": {k: encode.pack_ndarray(v) for k, v in arrays.items()},
        }


class TrackIndex:
    """Parsed sidecar index (read side)."""

    def __init__(self, section: dict):
        v = section.get("version", 0)
        if v > TRACK_INDEX_VERSION:
            raise ValueError(
                f"track index version {v} is newer than this reader "
                f"(supports <= {TRACK_INDEX_VERSION})")
        self.version = v
        self.n_tracks = int(section["n_tracks"])
        self.n_segments = int(section["n_segments"])
        self.spiral_tol = float(section["spiral_tol"])
        self.grid_units = tuple(int(x) for x in section["grid_units"])
        for name in _ARRAY_FIELDS:
            setattr(self, name, encode.unpack_ndarray(
                section["arrays"][name]))
        # derived once at parse time; per-track summary building must
        # not rescan the segment array per track (O(K * S))
        self.track_seg_counts = np.bincount(
            self.seg_track, minlength=self.n_tracks)

    def cover_units(self, track_id: int):
        """Sorted encoded unit keys covering a track."""
        self._check(track_id)
        lo = int(self.track_cover_ptr[track_id])
        hi = int(self.track_cover_ptr[track_id + 1])
        return self.track_cover_unit[lo:hi]

    def track_segments(self, track_id: int):
        """(S, 2) fid pairs + (S, 3) cells of one track's segments."""
        self._check(track_id)
        sel = self.seg_track == track_id
        return self.seg_fid[sel], self.seg_cell[sel]

    def _check(self, track_id: int):
        if not 0 <= track_id < self.n_tracks:
            raise IndexError(
                f"track id {track_id} out of range [0, {self.n_tracks})")

    def decode_keys(self, enc):
        """Encoded unit key array -> (wi, ti, tj) int arrays."""
        _, nti, ntj = self.grid_units
        enc = np.asarray(enc, np.int64)
        return enc // (nti * ntj), (enc // ntj) % nti, enc % ntj


def parse_track_index(header: dict) -> TrackIndex:
    """TrackIndex from a tiled-container footer header dict."""
    section = header.get(encode.TRACK_INDEX_KEY)
    if section is None:
        raise ValueError(
            "container has no track index (compressed with "
            "track_index=False or by a pre-index writer); re-compress "
            "with CompressionConfig(track_index=True) to enable "
            "feature-directed queries")
    return TrackIndex(section)
