"""Span tracing with a Chrome-trace-event JSON exporter (DESIGN.md #14).

Spans are complete events (``"ph": "X"``) stamped with the recording
thread's id, so the three async-engine stages land on three tracks in
Perfetto and nest correctly per track by construction.  A thread-local
stack enforces LIFO discipline (enter/exit pairs can never interleave
across threads because the stack itself is per-thread); exiting a span
that is not the top of its own thread's stack is recorded as a
``stack_corrupt`` attribute instead of raising -- tracing must never
take down the pipeline.

Queue depths and other sampled series are counter events
(``"ph": "C"``); threads self-label with metadata events
(``"ph": "M"``/``thread_name``).  Timestamps are microseconds since an
import-time ``perf_counter_ns`` anchor, the unit Perfetto expects.

The buffer is bounded (``MAX_EVENTS``); overflow drops new events and
counts the drops, so a runaway trace degrades to missing tail data
rather than unbounded memory.
"""
from __future__ import annotations

import json
import os
import threading
import time

MAX_EVENTS = 500_000

_T0 = time.perf_counter_ns()
_LOCK = threading.Lock()
_EVENTS: list = []
_DROPPED = 0
_TLS = threading.local()

# span-exit observer installed by repro.obs: every finished span also
# lands its duration in a metrics Histogram ("span.<name>"), which is
# what autotune calibration fits its per-stage coefficients from.  A
# plain module global (not thread-local): the hook itself is expected
# to be thread-safe, and instrumentation must never raise.
_EXIT_HOOK = None


def set_exit_hook(fn):
    """``fn(name, dur_ns)`` called after every Span exit (or None)."""
    global _EXIT_HOOK
    _EXIT_HOOK = fn


def _stack():
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def _emit(ev):
    global _DROPPED
    with _LOCK:
        if len(_EVENTS) < MAX_EVENTS:
            _EVENTS.append(ev)
        else:
            _DROPPED += 1


class Span:
    """``with Span("tiling.encode", {"unit": k}): ...`` -- records one
    complete event on exit.  ``set(**kw)`` adds attributes mid-span;
    ``dur_s``/``dur_ns`` are readable after exit (benchmarks derive
    their section timings from these instead of hand-rolled
    ``perf_counter`` pairs)."""

    __slots__ = ("name", "args", "_t0", "dur_ns")

    def __init__(self, name: str, args: dict | None = None):
        self.name = name
        self.args = dict(args) if args else {}
        self._t0 = 0
        self.dur_ns = 0

    def set(self, **kw):
        self.args.update(kw)
        return self

    def __enter__(self):
        _stack().append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        self.dur_ns = t1 - self._t0
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        else:  # never raise from instrumentation; flag for the tests
            self.args["stack_corrupt"] = True
            if self in st:
                st.remove(self)
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        _emit({
            "name": self.name,
            "ph": "X",
            "ts": (self._t0 - _T0) / 1e3,
            "dur": self.dur_ns / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": self.args,
        })
        if _EXIT_HOOK is not None:
            try:
                _EXIT_HOOK(self.name, self.dur_ns)
            except Exception:
                pass  # instrumentation must never take down the pipeline
        return False

    @property
    def dur_s(self) -> float:
        return self.dur_ns / 1e9


class NoopSpan:
    """Shared disabled-path singleton: enter/exit/set are empty
    methods on an attribute-less instance -- the whole cost of a
    disabled ``with obs.span(...)`` is two no-op calls."""

    __slots__ = ()
    dur_ns = 0
    dur_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **kw):
        return self


NOOP = NoopSpan()


def current_span():
    st = _stack()
    return st[-1] if st else None


def counter_event(name: str, **values):
    """Sampled series (queue depth, cache bytes) as a Chrome counter
    event; each keyword becomes one series under the counter track."""
    _emit({
        "name": name,
        "ph": "C",
        "ts": (time.perf_counter_ns() - _T0) / 1e3,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": values,
    })


def instant_event(name: str, **values):
    """Point-in-time marker (watchdog fire, resume, retry)."""
    _emit({
        "name": name,
        "ph": "i",
        "s": "t",
        "ts": (time.perf_counter_ns() - _T0) / 1e3,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": values,
    })


def name_thread(label: str):
    _emit({
        "name": "thread_name",
        "ph": "M",
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": {"name": label},
    })


def events():
    with _LOCK:
        return list(_EVENTS)


def dropped() -> int:
    return _DROPPED


def export(path: str) -> int:
    """Write the buffered events as a Chrome trace JSON object
    (loadable in Perfetto / chrome://tracing).  Returns the number of
    events written."""
    with _LOCK:
        evs = list(_EVENTS)
    evs.sort(key=lambda e: e.get("ts", 0.0))
    payload = {"traceEvents": evs, "displayTimeUnit": "ms"}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)
    return len(evs)


def reset():
    global _DROPPED
    with _LOCK:
        _EVENTS.clear()
        _DROPPED = 0
