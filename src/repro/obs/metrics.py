"""Process-wide metrics registry (DESIGN.md #14).

Three metric kinds, all thread-safe with a record path that is one
lock acquire + one integer op:

* ``Counter`` -- monotonically increasing int.  A counter can be a
  *child* of a registered parent: the child keeps a private value (the
  backing store for public per-object fields like
  ``ContainerSource.reads``) while every ``add`` also flows into the
  registry-wide parent, so one ``snapshot()`` sees process totals and
  per-object views stay exact.
* ``Gauge`` -- last-write-wins scalar (queue depths, cache bytes).
* ``Histogram`` -- fixed log2 buckets over non-negative integer
  observations (nanoseconds, bytes).  Bucket 0 counts exact zeros;
  bucket ``i >= 1`` counts values in ``[2^(i-1), 2^i)``; the last
  bucket (index 63) absorbs everything ``>= 2^62``.  Fixed buckets
  mean ``observe`` never allocates and two process snapshots are
  always mergeable.

Metrics are ALWAYS live (they are the storage behind pre-existing
public counters, whose values existing tests pin regardless of
``REPRO_OBS``); only the ambient instrumentation helpers in
``repro.obs`` -- spans, trace counter events, ``obs.count`` et al. --
are env-gated.
"""
from __future__ import annotations

import threading

N_BUCKETS = 64


class Counter:
    __slots__ = ("name", "_lock", "_n", "_parent")

    def __init__(self, name: str, parent: "Counter | None" = None):
        self.name = name
        self._lock = threading.Lock()
        self._n = 0
        self._parent = parent

    def add(self, n: int = 1):
        with self._lock:
            self._n += n
        if self._parent is not None:
            self._parent.add(n)

    @property
    def value(self) -> int:
        return self._n

    def set_local(self, v: int):
        """Overwrite the private value WITHOUT touching the parent --
        for checkpoint/restore of objects whose public counter is a
        child view (the parent keeps counting this-process work)."""
        with self._lock:
            self._n = int(v)

    def snapshot(self):
        return {"type": "counter", "value": self._n}


class Gauge:
    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._v = 0

    def set(self, v):
        with self._lock:
            self._v = v

    def add(self, n=1):
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def snapshot(self):
        return {"type": "gauge", "value": self._v}


class Histogram:
    __slots__ = ("name", "_lock", "_buckets", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._buckets = [0] * N_BUCKETS
        self._count = 0
        self._sum = 0
        self._min = None
        self._max = None

    def observe(self, v):
        iv = int(v)
        if iv < 0:
            iv = 0
        idx = iv.bit_length()
        if idx >= N_BUCKETS:
            idx = N_BUCKETS - 1
        with self._lock:
            self._buckets[idx] += 1
            self._count += 1
            self._sum += iv
            if self._min is None or iv < self._min:
                self._min = iv
            if self._max is None or iv > self._max:
                self._max = iv

    @property
    def count(self):
        return self._count

    def snapshot(self):
        with self._lock:
            buckets = {i: c for i, c in enumerate(self._buckets) if c}
            return {
                "type": "histogram",
                "count": self._count,
                "sum": self._sum,
                "min": self._min,
                "max": self._max,
                "buckets": buckets,
            }


class Registry:
    """Name -> metric map.  Creation takes the registry lock once;
    recording touches only the metric's own lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics = {}

    def _get(self, name, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name) -> Histogram:
        return self._get(name, Histogram)

    def child_counter(self, name) -> Counter:
        """A private counter whose adds also roll up into the
        registered process-wide counter ``name``."""
        return Counter(name, parent=self.counter(name))

    def snapshot(self):
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(items)}

    def reset(self):
        with self._lock:
            self._metrics.clear()


REGISTRY = Registry()
