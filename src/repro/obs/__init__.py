"""repro.obs -- unified tracing, metrics & rate accounting (DESIGN.md #14).

Two layers with different gating:

* **Carrier metrics** (``obs.counter/gauge/histogram/child_counter``)
  are always live: they are the storage behind pre-existing public
  counters (``ContainerSource.reads``, ``Scheduler.n_emitted``,
  ``UnitCache`` stats, ``faults.retry_stats``), whose values existing
  tests pin with or without observability on.  One
  ``obs.snapshot()`` exports everything.
* **Ambient instrumentation** (``obs.span``, ``obs.count``,
  ``obs.observe``, ``obs.gauge_set``, trace counter/instant events,
  ``obs.device_sync``) is gated on ``REPRO_OBS`` (or
  ``obs.enable()``): disabled, ``span`` returns one shared no-op
  singleton and the record helpers fall through a single boolean test
  -- the hot paths stay within the bench-gated <= 2% envelope.

Tracing exports Chrome trace events (``obs.export_trace(path)``,
loadable in Perfetto); ``obs.run_report(container)`` breaks a finished
archive into bytes per section kind and achieved-vs-Shannon bits per
unit.  Instrumentation is strictly observational: container bytes are
identical with observability on and off (CI gates this).
"""
from __future__ import annotations

import os as _os

from . import metrics as _metrics
from . import trace as _trace
from .metrics import REGISTRY

__all__ = [
    "enabled", "enable", "disable",
    "counter", "gauge", "histogram", "child_counter",
    "count", "gauge_set", "observe",
    "span", "counter_event", "instant_event", "name_thread",
    "device_sync", "snapshot", "export_trace", "trace_events",
    "reset", "run_report", "stage_durations", "REGISTRY",
]

_enabled = _os.environ.get("REPRO_OBS", "0").strip() not in ("", "0")

# Every finished span also lands its duration in a "span.<name>"
# Histogram, so per-stage wall time is queryable from the metrics
# snapshot (not just the bounded trace buffer).  This is the data
# autotune calibration fits its cost-model coefficients from
# (repro.autotune.calibrate); spans only exist when tracing is
# enabled, so the disabled path cost is unchanged.
_trace.set_exit_hook(
    lambda name, dur_ns: REGISTRY.histogram("span." + name).observe(dur_ns))


def enabled() -> bool:
    return _enabled


def enable():
    global _enabled
    _enabled = True


def disable():
    global _enabled
    _enabled = False


# -- carrier metrics (always live) -------------------------------------

def counter(name: str) -> _metrics.Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> _metrics.Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> _metrics.Histogram:
    return REGISTRY.histogram(name)


def child_counter(name: str) -> _metrics.Counter:
    return REGISTRY.child_counter(name)


def snapshot() -> dict:
    return REGISTRY.snapshot()


# -- ambient instrumentation (REPRO_OBS-gated) -------------------------

def count(name: str, n: int = 1):
    if _enabled:
        REGISTRY.counter(name).add(n)


def gauge_set(name: str, v):
    if _enabled:
        REGISTRY.gauge(name).set(v)


def observe(name: str, v):
    if _enabled:
        REGISTRY.histogram(name).observe(v)


def span(name: str, **args):
    if not _enabled:
        return _trace.NOOP
    return _trace.Span(name, args)


def counter_event(name: str, **values):
    if _enabled:
        _trace.counter_event(name, **values)


def instant_event(name: str, **values):
    if _enabled:
        _trace.instant_event(name, **values)


def name_thread(label: str):
    if _enabled:
        _trace.name_thread(label)


def device_sync(x):
    """Block until device work backing ``x`` is done -- ONLY when
    tracing is on, so span boundaries measure the device time of their
    own stage instead of billing async dispatch to whoever syncs next.
    Value-neutral: returns ``x`` unchanged either way."""
    if _enabled and x is not None:
        import jax

        try:
            jax.block_until_ready(x)
        except Exception:
            pass  # host arrays / tracers: nothing to sync
    return x


def stage_durations(prefix: str = "") -> dict:
    """Per-span-name duration aggregates from the ``span.*`` Histograms.

    Returns ``{span_name: {"count", "sum_s", "min_s", "max_s"}}`` for
    every span whose name starts with ``prefix`` ("" = all).  This is
    the calibration export: a calibration run executes a workload with
    tracing enabled, then reads stage wall times from here instead of
    walking the (bounded, droppable) trace buffer.
    """
    out = {}
    for name, snap in REGISTRY.snapshot().items():
        if not name.startswith("span."):
            continue
        stage = name[len("span."):]
        if not stage.startswith(prefix):
            continue
        if snap.get("type") != "histogram" or not snap.get("count"):
            continue
        out[stage] = {
            "count": snap["count"],
            "sum_s": snap["sum"] / 1e9,
            "min_s": (snap["min"] or 0) / 1e9,
            "max_s": (snap["max"] or 0) / 1e9,
        }
    return out


def export_trace(path: str) -> int:
    return _trace.export(path)


def trace_events() -> list:
    return _trace.events()


def reset():
    """Clear metrics and the trace buffer (tests, bench arms)."""
    REGISTRY.reset()
    _trace.reset()


def run_report(container: bytes) -> dict:
    from .report import run_report as _rr

    return _rr(container)
