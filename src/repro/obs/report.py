"""Rate accounting: where do the bytes of a finished archive go?

``run_report(container)`` walks a container and decomposes it into
disjoint byte ranges by *kind*, summing exactly to the container size
(the ``rate_accounting`` bench gate asserts this), plus per-unit
achieved bits-per-symbol against the Shannon bound of each unit's own
symbol histogram -- the observable the adaptive-error-bound ROADMAP
item needs to decide where tightening a bound is cheap.

Kind attribution is exact where the layout permits:

* CPTH1 (device-entropy) unit frames are stored raw, so huffman
  bitstreams, 256-entry code-length tables (inside the msgpack section
  index), escape sections and side sections are separable byte ranges.
* CPTZ1/CPTL1 unit frames are one zstd/zlib frame; those bytes are
  reported whole under ``unit_frames_compressed`` and the
  *uncompressed* payload split rides along informationally under
  ``payload_bytes_by_kind`` (it cannot sum to container bytes and is
  not gated).

The Shannon bound is zero-order: ``H(histogram) * n`` bits over the
unit's decoded uint8 symbol streams.  Device-codec achieved bits
(packed canonical-Huffman bitstreams) can never beat it; the host
codec's LZ matching can, so the ``achieved >= shannon`` sanity check
applies to device units only.
"""
from __future__ import annotations

import struct

import msgpack
import numpy as np

from ..core import encode

_SYM_SECTIONS = ("sym_u", "sym_v")


def _entropy_bits(sym: np.ndarray) -> float:
    """Zero-order Shannon bound in bits for one uint8 symbol stream."""
    if sym.size == 0:
        return 0.0
    freq = np.bincount(sym.reshape(-1), minlength=256).astype(np.float64)
    p = freq[freq > 0] / float(sym.size)
    return float(-(p * np.log2(p)).sum() * sym.size)


def _device_frame(frame: bytes):
    """Exact kind split + symbol accounting of one raw CPTH1 frame."""
    m = len(encode.MAGIC_HUF)
    (hlen,) = struct.unpack("<I", frame[m: m + 4])
    header = msgpack.unpackb(frame[m + 4: m + 4 + hlen], raw=False)
    body = frame[m + 4 + hlen:]
    kinds = {"unit_headers": m + 4 + hlen, "huffman_bitstreams": 0,
             "tables": 0, "escapes": 0, "side_sections": 0}
    n_symbols = 0
    achieved_bits = 0
    shannon_bits = 0.0
    for name, meta in header["sections"].items():
        if meta.get("enc") == "huff":
            kinds["huffman_bitstreams"] += meta["len"]
            table = meta["lengths"]
            kinds["tables"] += len(table)
            kinds["unit_headers"] -= len(table)
            if name in _SYM_SECTIONS:
                from ..core import entropy

                n = int(np.prod(meta["shape"], dtype=np.int64))
                raw = body[meta["off"]: meta["off"] + meta["len"]]
                sym = entropy.decode_symbols(
                    np.frombuffer(table, np.uint8), raw, n)
                n_symbols += n
                achieved_bits += 8 * meta["len"]
                shannon_bits += _entropy_bits(sym)
        elif name.startswith("esc_"):
            kinds["escapes"] += meta["len"]
        else:
            kinds["side_sections"] += meta["len"]
    return header, kinds, n_symbols, achieved_bits, shannon_bits


def _host_frame(frame: bytes):
    """Whole-frame kind + payload-level split of one CPTZ1/CPTL1 frame."""
    header, sections = encode.unpack(frame)
    n_symbols = 0
    shannon_bits = 0.0
    payload_kinds = {"symbol_streams": 0, "escapes": 0, "side_sections": 0}
    for name, arr in sections.items():
        nbytes = int(np.asarray(arr).nbytes)
        if name in _SYM_SECTIONS:
            payload_kinds["symbol_streams"] += nbytes
            sym = np.asarray(arr, dtype=np.uint8)
            n_symbols += int(sym.size)
            shannon_bits += _entropy_bits(sym)
        elif name.startswith("esc_"):
            payload_kinds["escapes"] += nbytes
        else:
            payload_kinds["side_sections"] += nbytes
    kinds = {"unit_frames_compressed": len(frame)}
    achieved_bits = 8 * len(frame)
    return (header, kinds, n_symbols, achieved_bits, shannon_bits,
            payload_kinds)


def _merge(dst: dict, src: dict):
    for k, v in src.items():
        dst[k] = dst.get(k, 0) + v


def _unit_row(key, kinds, n_sym, achieved_bits, shannon_bits,
              eb_base=None):
    return {
        "key": list(key) if key is not None else None,
        "n_symbols": int(n_sym),
        "achieved_bits": int(achieved_bits),
        "shannon_bits": round(float(shannon_bits), 1),
        "achieved_bps": round(achieved_bits / max(n_sym, 1), 4),
        "shannon_bps": round(shannon_bits / max(n_sym, 1), 4),
        # per-unit absolute base error bound: the unit frame's own
        # self-describing "eb_base" (adaptive policy) or the container
        # scalar -- the rate/bound observable the adaptive allocation
        # search reads (autotune/rate.py)
        "eb_base": None if eb_base is None else float(eb_base),
    }


def _report_tiled(blob: bytes) -> dict:
    header, footer_raw = encode.tiled_footer_ranged(
        lambda off, ln: blob[off: off + ln], len(blob))
    frames, _, legacy = encode._scan_frames(blob)
    if legacy:
        raise encode.ContainerError(
            "rate accounting needs v4 frame preambles (pre-v4 archive)")
    m = len(encode.MAGIC_TILED)
    kinds = {
        "magic": m,
        "frame_preambles": encode.PREAMBLE_LEN * len(frames),
        "prologue": 0,
        # footer = zlib(msgpack header incl. directory + optional track
        # index) + u32 length word + trailing magic
        "directory_footer": len(footer_raw) + 4 + m,
    }
    payload_kinds = {}
    units = []
    codec = None
    for fr in frames:
        frame = blob[fr["off"]: fr["off"] + fr["len"]]
        if fr["mark"] == encode.PROLOGUE_MARK:
            kinds["prologue"] += fr["len"]
            continue
        key = fr["header"].get("key")
        if frame[: len(encode.MAGIC_HUF)] == encode.MAGIC_HUF:
            codec = codec or "device"
            fh, fk, n_sym, ach, sh = _device_frame(frame)
            _merge(kinds, fk)
        else:
            codec = codec or "host"
            fh, fk, n_sym, ach, sh, pk = _host_frame(frame)
            _merge(kinds, fk)
            _merge(payload_kinds, pk)
        units.append(_unit_row(
            key, fk, n_sym, ach, sh,
            eb_base=fh.get("eb_base", header.get("eb_abs"))))
    out = {
        "container": "CPTT1",
        "codec": codec or "host",
        "container_bytes": len(blob),
        "n_units": len(units),
        "bytes_by_kind": kinds,
        "units": units,
    }
    ti = header.get(encode.TRACK_INDEX_KEY)
    if ti is not None:
        out["track_index_bytes_uncompressed"] = len(
            msgpack.packb(ti, use_bin_type=True))
    if payload_kinds:
        out["payload_bytes_by_kind"] = payload_kinds
    return out


def _report_monolithic(blob: bytes) -> dict:
    if blob[: len(encode.MAGIC_HUF)] == encode.MAGIC_HUF:
        fh, fk, n_sym, ach, sh = _device_frame(blob)
        codec = "device"
        payload_kinds = None
    else:
        fh, fk, n_sym, ach, sh, payload_kinds = _host_frame(blob)
        codec = "host"
    out = {
        "container": blob[:5].decode("ascii", "replace"),
        "codec": codec,
        "container_bytes": len(blob),
        "n_units": 1,
        "bytes_by_kind": fk,
        "units": [_unit_row(None, fk, n_sym, ach, sh,
                            eb_base=fh.get("eb_abs"))],
    }
    if payload_kinds:
        out["payload_bytes_by_kind"] = payload_kinds
    return out


def run_report(container: bytes) -> dict:
    """Byte-kind decomposition + achieved-vs-Shannon rate per unit.

    ``bytes_by_kind`` values are disjoint container byte ranges and sum
    exactly to ``container_bytes`` for every supported layout.
    """
    blob = bytes(container)
    if encode.is_tiled(blob):
        rep = _report_tiled(blob)
    else:
        rep = _report_monolithic(blob)
    rep["kind_bytes_total"] = int(sum(rep["bytes_by_kind"].values()))
    return rep
