import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init).  512 placeholder host devices back the
production meshes:

    single-pod : (16, 16)      ("data", "model")   256 chips
    multi-pod  : (2, 16, 16)   ("pod", "data", "model")   512 chips

For each runnable cell this script builds the real step function
(train_step with AdamW + microbatching, prefill, or decode_step),
lowers it with ShapeDtypeStruct inputs carrying NamedShardings,
compiles, and records memory_analysis / cost_analysis / collective
bytes for EXPERIMENTS.md (the roofline reads these).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch yi_6b]
        [--shape train_4k] [--mesh single|multi|both] [--out out.json]
"""
import argparse       # noqa: E402
import json           # noqa: E402
import sys            # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.configs as C                      # noqa: E402
from repro import roofline                     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import build_model    # noqa: E402
from repro.parallel import sharding as shd          # noqa: E402
from repro.train import optimizer as opt            # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axes]


def _fit_spec(spec, shape, mesh):
    """Drop spec axes that do not divide the dimension (explicit input
    shardings require exact divisibility; replication is the correct
    fallback -- GSPMD pads internal tensors, but inputs must be exact)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, parts):
        if axes is not None and dim % _axis_size(mesh, axes) != 0:
            axes = None
        out.append(axes)
    return P(*out)


def _attach(sds_tree, shardings):
    def one(s, sh):
        spec = _fit_spec(sh.spec, s.shape, sh.mesh)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(sh.mesh, spec)
        )

    return jax.tree.map(one, sds_tree, shardings)


def _batch_shardings(batch_specs, mesh, rules):
    def spec(name, leaf):
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        if name == "position_ids":                  # (3, B, S)
            return P(None, rules.dp, None)
        return P(rules.dp, *([None] * (nd - 1)))

    return {
        k: NamedSharding(mesh, spec(k, v)) for k, v in batch_specs.items()
    }


def _cache_shardings(cache_sds, mesh, rules, seq_sharded):
    tp_size = mesh.shape[rules.tp] if rules.tp else 1

    def kv_spec(shape):
        # (L, B, S, Hkv, Dh).  Preferred: batch over dp, heads over tp.
        # When Hkv doesn't divide tp, shard the HEAD DIM (contracting-dim
        # TP); sharding S would put the decode cache update across shards
        # and trigger full rematerialization (perf iteration H4).  For
        # long-context (batch = 1) S is sharded over every available axis
        # (the update crosses shards once per step on a tiny slice).
        if seq_sharded:
            axes = tuple(a for a in (rules.fsdp, rules.tp) if a)
            return P(None, None, axes, None, None)
        if shape[3] % tp_size == 0:
            return P(None, rules.dp, None, rules.tp, None)
        if shape[4] % tp_size == 0:
            return P(None, rules.dp, None, None, rules.tp)
        return P(None, rules.dp, None, None, None)

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        nd = len(leaf.shape)
        if name in ("k", "v", "ek", "ev"):
            return NamedSharding(mesh, kv_spec(leaf.shape))
        if name == "length":
            return NamedSharding(mesh, P())
        if name == "wkv":                            # (L, B, H, dk, dv)
            return NamedSharding(mesh, P(None, rules.dp, rules.tp, None, None))
        if name in ("conv", "ssm"):                  # (G, g-1, B, ..)
            return NamedSharding(mesh, P(None, None, rules.dp))
        if name in ("tm_x", "cm_x"):                 # (L, B, 1, D)
            return NamedSharding(mesh, P(None, rules.dp, None, None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, cache_sds)


def lower_cell(arch_mod, shape_name, mesh, mesh_name):
    cfg = arch_mod.CONFIG
    cell = arch_mod.CELLS[shape_name]
    arch = cfg.name
    if cell.skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": cell.skip}

    model = build_model(cfg)
    rules = shd.rules_for_mesh(mesh)
    n_chips = int(np_prod(mesh.devices.shape))

    t0 = time.perf_counter()
    with mesh, shd.use_rules(rules):
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pshard = shd.param_shardings(params_sds, mesh)
        params_in = _attach(params_sds, pshard)

        batch_specs = C.input_specs(cfg, cell)
        bshard = _batch_shardings(batch_specs, mesh, rules)
        batch_in = _attach(batch_specs, bshard)

        if cell.kind == "train":
            ocfg = opt.AdamWConfig(state_dtype=cfg.opt_state_dtype)
            # never split the global batch below one example per
            # data-parallel shard (GSPMD would pad: half the chips would
            # compute padding -- perf iteration H9)
            dp_size = rules.dp_size
            mb = max(min(cell.microbatches, cell.global_batch // dp_size), 1)
            step = make_train_step(model, ocfg, mb)
            opt_sds = jax.eval_shape(
                lambda p: {"adam": opt.init_state(p, ocfg)}, params_sds
            )
            oshard = {
                "adam": {
                    "m": pshard, "v": pshard,
                    "step": NamedSharding(mesh, P()),
                }
            }
            opt_in = _attach(opt_sds, oshard)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_in, opt_in, batch_in
            )
        elif cell.kind == "prefill":
            lowered = jax.jit(model.prefill).lower(params_in, batch_in)
        else:  # decode
            kv_dt = jnp.dtype(cell.kv_dtype)
            with shd.use_rules(None):
                if cfg.is_encoder_decoder:
                    cache_sds = jax.eval_shape(
                        lambda: model.init_cache(
                            cell.global_batch, cell.cache_len,
                            enc_len=cell.enc_len, dtype=kv_dt)
                    )
                elif cfg.family == "ssm":
                    cache_sds = jax.eval_shape(
                        lambda: model.init_cache(cell.global_batch)
                    )
                else:
                    cache_sds = jax.eval_shape(
                        lambda: model.init_cache(
                            cell.global_batch, cell.cache_len, dtype=kv_dt)
                    )
            cshard = _cache_shardings(cache_sds, mesh, rules,
                                      cell.seq_sharded_cache)
            cache_in = _attach(cache_sds, cshard)
            lowered = jax.jit(model.decode_step, donate_argnums=(2,)).lower(
                params_in, batch_in, cache_in
            )

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        rl = roofline.analyze(
            compiled, arch, shape_name, mesh_name, n_chips, cfg, cell
        )
        row = rl.row()
        row.update({
            "status": "ok",
            "kind": cell.kind,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
        })
        mem = row["memory"].get("resident_bytes")
        print(
            f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} OK  "
            f"compile={t_compile:6.1f}s  flops/dev={rl.flops_per_device:.3e}  "
            f"resident={mem / 2**30 if mem else -1:.2f}GiB  "
            f"bottleneck={rl.bottleneck}",
            flush=True,
        )
        return row


def np_prod(t):
    out = 1
    for x in t:
        out *= int(x)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch module name")
    ap.add_argument("--shape", default=None, choices=list(C.SHAPE_TABLE))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, (
        "dry-run needs 512 placeholder devices; do not import jax before "
        "this module sets XLA_FLAGS"
    )

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else C.ARCHS
    shapes = [args.shape] if args.shape else list(C.SHAPE_TABLE)

    rows = []
    failures = 0
    for arch_name in archs:
        mod = C.get(arch_name)
        for mesh_name, mesh in meshes:
            for shape_name in shapes:
                try:
                    rows.append(lower_cell(mod, shape_name, mesh, mesh_name))
                except Exception:
                    failures += 1
                    print(f"[dryrun] {arch_name} {shape_name} {mesh_name} "
                          f"FAILED", flush=True)
                    traceback.print_exc()
                    rows.append({
                        "arch": arch_name, "shape": shape_name,
                        "mesh": mesh_name, "status": "fail",
                        "error": traceback.format_exc()[-2000:],
                    })
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"[dryrun] wrote {args.out}")
    ok = sum(1 for r in rows if r.get("status") == "ok")
    skip = sum(1 for r in rows if r.get("status") == "skip")
    print(f"[dryrun] {ok} ok, {skip} skip, {failures} fail")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
