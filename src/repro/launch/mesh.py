"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required for the dry-run's placeholder-device
bootstrap ordering).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many devices the test process has."""
    return jax.make_mesh(shape, axes)
