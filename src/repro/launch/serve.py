"""Batched serving driver: prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16

A fixed pool of ``--batch`` slots decodes in lockstep; finished requests
free their slot and the next queued request is prefilled into it
(continuous batching).  Reports per-phase latency and decode
tokens/sec.  Works for every decoder arch (dense/moe/ssm/hybrid/vlm);
enc-dec (whisper) serves one utterance batch per prefill.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models.transformer import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mod = C.get(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    decode = jax.jit(model.decode_step)
    prefill = jax.jit(model.prefill)

    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.normal(
            0, 1, (args.batch, args.prompt_len, cfg.d_model)
        ).astype(np.float32))
        toks = jnp.asarray(rng.integers(
            0, cfg.vocab, (args.batch, 8)).astype(np.int32))
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"frames": frames, "tokens": toks})
        # pad self cache to max_len
        full = model.init_cache(args.batch, args.max_len,
                                enc_len=args.prompt_len)
        full["k"] = full["k"].at[:, :, :8].set(cache["k"])
        full["v"] = full["v"].at[:, :, :8].set(cache["v"])
        full["ek"], full["ev"] = cache["ek"], cache["ev"]
        full["length"] = cache["length"]
        cache = full
        t1 = time.perf_counter()
        n_gen = 0
        for _ in range(args.gen_len):
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            logits, cache = decode(params, {"tokens": nxt}, cache)
            n_gen += args.batch
        t2 = time.perf_counter()
        print(f"[serve] enc-dec prefill {t1 - t0:.3f}s, "
              f"decode {n_gen / (t2 - t1):.1f} tok/s")
        return 0

    def new_request(rid):
        if cfg.embedding_inputs:
            emb = rng.normal(0, 1, (1, args.prompt_len, cfg.d_model))
            return {
                "embeds": jnp.asarray(emb.astype(np.float32)).astype(jnp.bfloat16),
                "position_ids": jnp.broadcast_to(
                    jnp.arange(args.prompt_len, dtype=jnp.int32)[None, None],
                    (3, 1, args.prompt_len)),
            }
        toks = rng.integers(0, cfg.vocab, (1, args.prompt_len))
        return {"tokens": jnp.asarray(toks.astype(np.int32))}

    # continuous batching with per-slot caches (batch=1 per slot keeps the
    # demo simple; production would use a paged batched cache)
    queue = list(range(args.requests))
    slots = [None] * args.batch   # (rid, cache, logits, generated)
    done = 0
    t0 = time.perf_counter()
    decoded_tokens = 0
    prefills = 0
    while done < args.requests:
        for s in range(args.batch):
            if slots[s] is None and queue:
                rid = queue.pop(0)
                logits, cache = prefill(params, new_request(rid))
                if not (cfg.family == "ssm"):
                    full = model.init_cache(1, args.max_len)
                    pl_len = int(cache["length"])
                    full["k"] = full["k"].at[:, :, :pl_len].set(cache["k"])
                    full["v"] = full["v"].at[:, :, :pl_len].set(cache["v"])
                    full["length"] = cache["length"]
                    cache = full
                slots[s] = [rid, cache, logits, 0]
                prefills += 1
        for s in range(args.batch):
            if slots[s] is None:
                continue
            rid, cache, logits, n = slots[s]
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            if cfg.embedding_inputs:
                step_in = {"embeds": jnp.zeros(
                    (1, 1, cfg.d_model), jnp.bfloat16)}
            else:
                step_in = {"tokens": nxt}
            logits, cache = decode(params, step_in, cache)
            decoded_tokens += 1
            n += 1
            if n >= args.gen_len:
                slots[s] = None
                done += 1
            else:
                slots[s] = [rid, cache, logits, n]
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests, {prefills} prefills, "
          f"{decoded_tokens} tokens in {dt:.2f}s "
          f"({decoded_tokens / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
