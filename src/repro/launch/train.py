"""End-to-end training driver with checkpoint/restart and straggler
mitigation.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_1_6b \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt [--resume] \
        [--grad-compress] [--mesh 2x2]

Fault-tolerance contract (DESIGN.md #6):
  * checkpoints are atomic (tmp + rename + LATEST pointer) and saved
    every ``--ckpt-every`` steps; ``--resume`` restarts from LATEST,
    including the data-pipeline position (stateless batches keyed on
    step) -- kill the process anywhere and restart loses at most
    ckpt-every steps.
  * restore is mesh-shape agnostic: a checkpoint from any mesh loads
    onto the current one (elastic scaling path).
  * straggler mitigation: per-step deadline = ``--deadline-factor`` x
    rolling median step time; a breach logs a straggler event and, on a
    real cluster, would trigger the preemption hook (here: counted and
    reported, since a single-host CPU run has no peers to preempt).
"""
from __future__ import annotations

import argparse
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data.tokens import TokenPipelineConfig, global_batch
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import build_model
from repro.parallel import sharding as shd
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.grad_compress import GradCompressConfig
from repro.train.train_step import init_train_state, make_train_step


def parse_mesh(s):
    if not s:
        return None
    dims = tuple(int(x) for x in s.split("x"))
    names = ("data", "model")[: len(dims)] if len(dims) <= 2 else (
        "pod", "data", "model")
    return make_test_mesh(dims, names)


def make_batch(cfg, tp_cfg, step, batch, seq):
    tokens, labels = global_batch(tp_cfg, step)
    out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.embedding_inputs:
        rng = np.random.default_rng(step)
        out = {
            "embeds": jnp.asarray(
                rng.normal(0, 1, (batch, seq, cfg.d_model)).astype(np.float32)
            ).astype(jnp.bfloat16),
            "position_ids": jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32)[None, None], (3, batch, seq)
            ),
            "labels": out["labels"],
        }
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng(step)
        out = {
            "frames": jnp.asarray(
                rng.normal(0, 1, (batch, seq, cfg.d_model)).astype(np.float32)
            ),
            "tokens": out["tokens"][:, :64],
            "labels": out["labels"][:, :64],
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--deadline-factor", type=float, default=3.0)
    ap.add_argument("--mesh", default="", help="e.g. 2x2 (test mesh)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    mod = C.get(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    model = build_model(cfg)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=20,
                           state_dtype=cfg.opt_state_dtype)
    gc_cfg = GradCompressConfig(enabled=args.grad_compress)
    step_fn = make_train_step(model, ocfg, args.microbatches, gc_cfg)

    mesh = parse_mesh(args.mesh)
    rules = shd.rules_for_mesh(mesh) if mesh else None

    tp_cfg = TokenPipelineConfig(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq, seed=args.seed)

    params, state = init_train_state(
        model, jax.random.PRNGKey(args.seed), ocfg, gc_cfg)
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        shardings = None
        if mesh:
            pshard = shd.param_shardings(params, mesh)
            shardings = {"params": pshard}
        restored, manifest = ckpt.restore(
            args.ckpt_dir, {"params": params, "opt": state},
            shardings=shardings)
        params, state = restored["params"], restored["opt"]
        start_step = manifest["step"]
        print(f"[train] resumed from step {start_step}", flush=True)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    times = []
    stragglers = 0
    losses = []
    ctx = mesh if mesh else _nullctx()
    with ctx, shd.use_rules(rules):
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = make_batch(cfg, tp_cfg, step, args.batch, args.seq)
            params, state, metrics = jit_step(params, state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            if len(times) >= 5:
                deadline = args.deadline_factor * statistics.median(times)
                if dt > deadline:
                    stragglers += 1
                    print(f"[train] straggler: step {step} took {dt:.3f}s "
                          f"(deadline {deadline:.3f}s) -- preemption hook "
                          f"would fire here", flush=True)
            times.append(dt)
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step + 1,
                          {"params": params, "opt": state},
                          meta={"arch": cfg.name, "loss": loss})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps,
                  {"params": params, "opt": state},
                  meta={"arch": cfg.name, "loss": losses[-1]})
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"{stragglers} straggler events", flush=True)
    return 0


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    sys.exit(main())
