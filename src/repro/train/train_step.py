"""Composable train step: microbatched grad accumulation + AdamW +
optional error-bounded gradient compression.

``make_train_step(model, opt_cfg, microbatches, gc_cfg)`` returns a pure
function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit(..., donate_argnums=(0, 1))``.  Microbatching
splits the *leading batch axis* and accumulates grads with a ``lax.scan``
so peak activation memory is that of a single microbatch (this is what
fits the 32B/398B train cells in 16 GB/chip -- see DESIGN.md #6).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import grad_compress as gc
from . import optimizer as opt


def _split_batch(batch: Dict[str, Any], n: int):
    """Reshape every leaf (B, ...) -> (n, B//n, ...)."""

    def sp(x):
        # position_ids are (3, B, S): split axis 1
        if x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] % n == 0:
            return x.reshape(x.shape[0], n, x.shape[1] // n, *x.shape[2:]).swapaxes(0, 1)
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    model,
    opt_cfg: opt.AdamWConfig,
    microbatches: int = 1,
    gc_cfg: Optional[gc.GradCompressConfig] = None,
):
    gc_cfg = gc_cfg or gc.GradCompressConfig()

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_batch(batch, microbatches)

            def body(acc, mb):
                # _split_batch already yields (3, b, S) position_ids slices
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}

        residuals = opt_state.get("gc_residuals")
        if gc_cfg.enabled:
            grads, residuals, gcm = gc.compress_grads(grads, residuals, gc_cfg)
        else:
            gcm = {}

        params, new_inner, om = opt.apply_updates(
            params, grads, opt_state["adam"], opt_cfg
        )
        new_state = {"adam": new_inner}
        if gc_cfg.enabled:
            new_state["gc_residuals"] = residuals
        metrics = dict(metrics)
        metrics.update(om)
        metrics.update(gcm)
        metrics["loss"] = loss
        return params, new_state, metrics

    return train_step


def init_train_state(model, rng, opt_cfg: opt.AdamWConfig,
                     gc_cfg: Optional[gc.GradCompressConfig] = None):
    params = model.init(rng)
    state = {"adam": opt.init_state(params, opt_cfg)}
    if gc_cfg and gc_cfg.enabled:
        state["gc_residuals"] = gc.init_residuals(params)
    return params, state
