"""Fault-tolerant checkpointing: atomic writes, manifest, reshard-restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       step, arch, mesh shape, leaf index, data hash
        arrays.npz          flat leaf -> array (gathered host values)
        [arrays.cptz]       optional lossy-compressed params (paper codec's
                            eb-quantizer + zstd; opt-in, exact by default)
    <dir>/LATEST            atomic pointer (tmp + rename)

Restore is *mesh-shape agnostic*: arrays are saved as full (unsharded)
host values and re-placed with ``jax.device_put`` under the target mesh's
shardings -- so a checkpoint written on (16, 16) restores onto
(2, 16, 16) or a CPU test mesh unchanged (elastic scaling / failure
recovery path).  Writes go to a tmp dir + atomic rename; a crashed write
can never corrupt LATEST.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint is absent, incomplete, or inconsistent with the
    restore template.  A real error class (not ``assert``): restore
    validation must survive ``python -O``, and callers recovering from
    a crashed trainer need a typed failure to catch."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == object or arr.dtype.kind not in "biufc":
            raise TypeError(f"non-numeric checkpoint leaf {key}: {arr.dtype}")
        out[key] = arr
    return out


def _lossy_encode(arr: np.ndarray, rel_eb: float):
    """Paper-style eb quantization of a float leaf: uniform quantum
    2*eb_abs + zstd-compressed int32 codes.  Returns (codes, scale) or
    None when the leaf is not worth quantizing."""
    if arr.dtype.kind != "f" or arr.size < 1024:
        return None
    rng = float(np.abs(arr).max())
    if rng == 0.0:
        return None
    q = 2.0 * rel_eb * rng
    codes = np.round(arr.astype(np.float64) / q).astype(np.int32)
    return codes, np.float64(q)


def save(directory: str, step: int, trees: Dict[str, Any],
         meta: Optional[dict] = None, keep: int = 3,
         lossy_rel_eb: Optional[float] = None) -> str:
    """Atomically persist `trees` (e.g. {'params': ..., 'opt': ...}).

    ``lossy_rel_eb`` opts large float leaves into the paper's
    error-bounded quantizer (|err| <= rel_eb * max|leaf|); codes are
    stored as int32 and zstd squeezes them in the npz container.  Exact
    (default) and lossy leaves can mix freely; restore is transparent.
    """
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    tmp = tempfile.mkdtemp(prefix=f".{name}.tmp", dir=directory)
    try:
        arrays = {}
        index = {}
        for tree_name, tree in trees.items():
            flat = _flatten(tree)
            for k, v in flat.items():
                key = f"{tree_name}:{k}"
                entry = {"shape": list(v.shape), "dtype": str(v.dtype)}
                if lossy_rel_eb:
                    enc = _lossy_encode(v, lossy_rel_eb)
                    if enc is not None:
                        codes, q = enc
                        arrays[key] = codes
                        entry["lossy_q"] = float(q)
                        index[key] = entry
                        continue
                arrays[key] = v
                index[key] = entry
        np.savez_compressed(os.path.join(tmp, "arrays.npz"), **arrays)
        digest = hashlib.sha256()
        for k in sorted(arrays):
            digest.update(k.encode())
            digest.update(arrays[k].tobytes()[:4096])
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": index,
            "hash": digest.hexdigest(),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.isdir(path):
        return None
    return int(name.split("_")[1])


def restore(directory: str, template_trees: Dict[str, Any],
            shardings: Optional[Dict[str, Any]] = None,
            step: Optional[int] = None):
    """Restore into the *structure* of `template_trees` (shapes/dtypes or
    ShapeDtypeStructs), placing leaves with `shardings` if given (pytrees
    of NamedSharding matching each template) -- this is the
    mesh-reshape/elastic path.  Returns (trees, manifest)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    out = {}
    for tree_name, template in template_trees.items():
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        shd_tree = shardings.get(tree_name) if shardings else None
        shd_leaves = jax.tree_util.tree_leaves(shd_tree) if shd_tree is not None else None
        new_leaves = []
        for i, (lpath, leaf) in enumerate(leaves):
            key = tree_name + ":" + "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in lpath
            )
            arr = data[key]
            meta_leaf = manifest["leaves"].get(key, {})
            if "lossy_q" in meta_leaf:
                arr = (arr.astype(np.float64) * meta_leaf["lossy_q"]).astype(
                    np.dtype(meta_leaf["dtype"]))
            if tuple(arr.shape) != tuple(leaf.shape):
                raise CheckpointError(
                    f"checkpoint leaf {key} has shape "
                    f"{tuple(arr.shape)}, template expects "
                    f"{tuple(leaf.shape)}")
            if shd_leaves is not None:
                arr = jax.device_put(arr, shd_leaves[i])
            new_leaves.append(arr)
        out[tree_name] = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return out, manifest
