"""AdamW with fully-sharded state (same sharding as params).

Hand-rolled (no optax dependency): init/update are pure pytree maps, so
optimizer state inherits parameter shardings leaf-for-leaf and the whole
update fuses into the train step.  `opt_state_dtype` ('float32' or
'bfloat16') trades moment-memory for precision -- the bf16 setting is
what lets the 398B hybrid fit 512 chips (DESIGN.md #6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    state_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
