"""Error-bounded gradient compression for cross-pod reduction.

The paper's eb-quantization (quantize.py), stripped of the CP constraint,
applied to distributed training: before gradients cross the *inter-pod*
links (the slowest roofline term in the multi-pod mesh), each leaf is
quantized to int8 with a per-block scale; pods all-reduce the int8 codes
(4x fewer bytes than f32, 2x fewer than bf16) and dequantize locally.

Error feedback (residual carry) keeps the scheme convergent: the
quantization error of step t is added to the gradient of step t+1 --
standard in gradient-compression literature and a direct reuse of the
paper's "residual goes to the next predictor input" philosophy.

Under pjit we cannot address the 'pod' axis explicitly without
shard_map; instead the compression is applied to the *global* gradient
(quantize -> dequantize with a straight-through estimator of the
collective).  The roofline win is realized by XLA reducing the int8
tensor; the dry-run HLO shows the all-reduce operand dtype shrink.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    enabled: bool = False
    bits: int = 8
    error_feedback: bool = True


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _quant_dequant(g, bits):
    """Per-block symmetric int quantization of a flat leaf."""
    orig_shape = g.shape
    gf = g.astype(jnp.float32).reshape(-1)
    n = gf.shape[0]
    pad = (-n) % BLOCK
    gf = jnp.pad(gf, (0, pad)).reshape(-1, BLOCK)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.max(jnp.abs(gf), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.reshape(-1)[:n].reshape(orig_shape), None


def compress_grads(grads, residuals, cfg: GradCompressConfig):
    """Returns (decompressed grads, new residuals, metrics)."""
    if not cfg.enabled:
        return grads, residuals, {"gc_error": jnp.zeros((), jnp.float32)}

    def one(g, r):
        gin = g.astype(jnp.float32)
        if cfg.error_feedback:
            gin = gin + r.astype(jnp.float32)
        deq, _ = _quant_dequant(gin, cfg.bits)
        new_r = (gin - deq).astype(jnp.bfloat16) if cfg.error_feedback else r
        return deq.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    err = sum(
        jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree.leaves(new_g), flat_g)
    )
    return new_g, new_r, {"gc_error": err}
