"""Cost-model-driven plan auto-tuning (DESIGN.md #15).

Pick the fastest pipeline plan for an input, calibrated from measured
obs spans:

    blob, stats = repro.compress(u, v, cfg, autotune=True)
    print(repro.autotune.explain())

``tune_config`` enumerates the discrete plan space (search.py), ranks
it with the analytic cost model (costmodel.py) seeded from roofline
terms and calibrated against obs span measurements (calibrate.py), then
measure-verifies the top-k candidates on the actual field before
committing.  The chosen plan is returned as an ordinary
CompressionConfig -- from there on the pipeline is exactly the one a
user could have configured by hand, so autotuning can change speed but
never the bytes a given chosen plan produces.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from .calibrate import (CalibrationTable, CalibrationTableError,
                        calibrate, default_table_path, load_or_calibrate,
                        load_table, save_table)
from .costmodel import CostModel, Workload, device_kind
from .rate import compress_with_target
from .search import PlanCandidate, apply, available_backends, \
    enumerate_candidates, search

__all__ = [
    "CalibrationTable", "CalibrationTableError", "CostModel",
    "PlanCandidate", "Workload", "apply", "available_backends",
    "calibrate", "compress_with_target", "default_table_path",
    "device_kind", "enumerate_candidates", "explain", "last_report",
    "load_or_calibrate", "load_table", "save_table", "search",
    "tune_config", "tune_stream",
]

# measure-verify the top-k model picks on the real field when it is
# small enough to rerun cheaply; above the cap trust the model ranking
_MEASURE_ELEMS_CAP = 2_000_000
_TOP_K = 3

_LAST_REPORT: Optional[dict] = None


def _measure_fn(u, v, cfg):
    """measure(cand) -> seconds: one untimed warmup (compile) + one
    timed run of the candidate on the actual field."""
    from ..core import compressor, tiling

    def measure(cand):
        c = apply(cfg, cand)
        def run():
            if c.tiling is None:
                return compressor.compress(u, v, c)
            return tiling.compress_tiled(u, v, c, c.tiling)
        run()  # warmup: jit compile off the clock (shared helper rule)
        t0 = time.perf_counter()
        run()
        return time.perf_counter() - t0

    return measure


def _sample(u, v):
    """A temporally-subsampled stand-in field for measure-verify when
    the input is too large to rerun per candidate."""
    T = u.shape[0]
    step = max(T * u.shape[1] * u.shape[2] * 2 // _MEASURE_ELEMS_CAP, 1)
    tt = max(T // step, 4)
    return u[:tt], v[:tt]


def _policy_spec_of(cfg) -> tuple:
    """Canonical spec of cfg's eb policy, () for uniform -- stamped on
    every candidate so the tune's identity includes the byte-changing
    knob it ran under (search.py module doc)."""
    from ..core import ebpolicy

    return tuple(ebpolicy.policy_spec(
        ebpolicy.normalize(getattr(cfg, "eb_policy", None))) or ())


def _build_report(shape, stream, ranked, chosen, table, elapsed_s,
                  eb_policy=()):
    return {
        "shape": tuple(int(s) for s in shape),
        "stream": stream,
        # byte-changing plan knob the tune ran under (carried, never
        # searched); "uniform" when no policy was set
        "eb_policy": "adaptive" if eb_policy else "uniform",
        "device_kind": table.device_kind if table else device_kind(),
        "calibrated": bool(table and table.coeffs),
        "tune_time_s": elapsed_s,
        "chosen": chosen.cand.describe(),
        "plans": [
            {
                "plan": r.cand.describe(),
                "chosen": r.cand == chosen.cand,
                "predicted_s": r.predicted["total"],
                "predicted_stages": dict(r.predicted["stages"]),
                "measured_s": r.measured_s,
            }
            for r in ranked
        ],
    }


def tune_config(u, v, cfg, table: Optional[CalibrationTable] = None,
                measure: Optional[bool] = None, top_k: int = _TOP_K):
    """Return a new CompressionConfig running the predicted-fastest plan
    for field (u, v).  ``measure=None`` auto-decides: top-k candidates
    are timed on the real field (or a temporal subsample when huge);
    ``measure=False`` trusts the model ranking outright."""
    global _LAST_REPORT
    from ..core import compressor  # noqa: F401  (config type lives there)

    t0 = time.perf_counter()
    u = np.asarray(u)
    v = np.asarray(v)
    shape = u.shape
    if table is None:
        table = load_or_calibrate()
    model = CostModel(coeffs=table.coeffs, kind=table.device_kind)
    if measure is None or measure:
        mu, mv = (u, v) if u.size * 2 <= _MEASURE_ELEMS_CAP \
            else _sample(u, v)
        measure_cb = _measure_fn(mu, mv, cfg)
    else:
        measure_cb, top_k = None, 0
    pol_spec = _policy_spec_of(cfg)
    ranked = search(shape, model=model, top_k=top_k, measure=measure_cb,
                    eb_policy=pol_spec)
    chosen = ranked[0]
    _LAST_REPORT = _build_report(shape, False, ranked, chosen, table,
                                 time.perf_counter() - t0,
                                 eb_policy=pol_spec)
    return apply(cfg, chosen.cand)


def tune_stream(shape, cfg, table: Optional[CalibrationTable] = None,
                ingest_s_per_frame: float = 0.0):
    """Model-only tuning for the streaming path (the stream cannot be
    rerun per candidate, so no measure-verify).  ``shape`` is the
    (T, H, W) the stream will deliver -- T may be an estimate.
    ``ingest_s_per_frame`` is the producer's per-frame latency (a paced
    solver); it is what makes the async engine worth its coordination
    cost in the model.  Returns (new cfg, chosen PlanCandidate); the
    cfg's grid is always set (streams are tiled by construction)."""
    global _LAST_REPORT
    t0 = time.perf_counter()
    if table is None:
        table = load_or_calibrate()
    model = CostModel(coeffs=table.coeffs, kind=table.device_kind)
    pol_spec = _policy_spec_of(cfg)
    ranked = search(tuple(shape), model=model, stream=True,
                    ingest_s=ingest_s_per_frame * shape[0],
                    eb_policy=pol_spec)
    chosen = ranked[0]
    _LAST_REPORT = _build_report(tuple(shape), True, ranked, chosen,
                                 table, time.perf_counter() - t0,
                                 eb_policy=pol_spec)
    return apply(cfg, chosen.cand), chosen.cand


def last_report() -> Optional[dict]:
    """The raw report dict from the most recent tune (or None)."""
    return _LAST_REPORT


def explain(report: Optional[dict] = None, limit: int = 8) -> str:
    """Human-readable predicted-vs-measured account of the last tune:
    the chosen plan first, then the best rejected candidates."""
    rep = report or _LAST_REPORT
    if rep is None:
        return "autotune: no tuning run recorded in this process"
    lines = [
        "autotune report: shape=%s %s device=%s (%s) tuned in %.3fs"
        % ("x".join(str(s) for s in rep["shape"]),
           "stream" if rep["stream"] else "in-memory",
           rep["device_kind"],
           "calibrated" if rep["calibrated"] else "seed coefficients",
           rep["tune_time_s"]),
        "eb policy: %s (byte-changing plan knob -- carried through the "
        "search, never enumerated)" % rep.get("eb_policy", "uniform"),
        "%-28s %10s %10s  %s" % ("plan", "pred(s)", "meas(s)", ""),
    ]
    for p in rep["plans"][:limit]:
        meas = "%.4f" % p["measured_s"] if p["measured_s"] is not None \
            else "-"
        mark = "<= chosen" if p["chosen"] else ""
        lines.append("%-28s %10.4f %10s  %s"
                     % (p["plan"], p["predicted_s"], meas, mark))
        if p["chosen"]:
            for stage, s in sorted(p["predicted_stages"].items(),
                                   key=lambda kv: -kv[1]):
                lines.append("    %-24s %10.4f" % (stage, s))
    extra = len(rep["plans"]) - limit
    if extra > 0:
        lines.append("  ... %d more candidates pruned by the model"
                     % extra)
    return "\n".join(lines)
