"""Plan search: enumerate + cost-prune candidate pipeline plans.

The discrete space is the cross product of

    tile geometry (divisor/halving heuristics over H, W) x
    window length x batch chunk (batch_cap) x backend x codec x
    async on/off x queue bounds,

plus the monolithic (untiled) candidate when the input is in memory.
Every candidate is ranked by the analytic cost model (costmodel.py,
optionally calibrated from obs spans); ``search`` can then
measure-verify the top-k on the actual field so a mispriced model
never silently picks a slow plan.  Ordering is deterministic: ties on
predicted/measured cost break on the candidate's knob tuple, so a
fixed calibration table always yields the same chosen plan.

None of the searched knobs can change container bytes for a *chosen*
plan: backend/codec/tiling select the plan itself (different plans =
different containers, by design), while batch_cap / queue bounds /
async are pure scheduling (see DESIGN.md #15 for the argument).

The eb policy (core/ebpolicy.py) is the opposite kind of knob: it is
BYTE-CHANGING, so the search never enumerates it -- every candidate
carries the caller's policy through unchanged (``eb_policy`` below is
the policy's canonical spec, informational: it rides in the candidate
key and report so two tunes under different policies are never
conflated, but ``apply`` leaves ``cfg.eb_policy`` untouched).  Picking
per-unit bounds for a target ratio is a separate, rate-distortion
search: autotune/rate.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from . import costmodel


@dataclasses.dataclass(frozen=True, order=True)
class PlanCandidate:
    """One point of the search space.  ``grid`` is (tile_h, tile_w,
    window_t) or None for the monolithic pipeline."""

    grid: Optional[tuple] = None
    backend: str = "xla"
    codec: str = "host"
    batch_units: bool = True
    batch_cap: int = 8
    async_engine: bool = False
    q_in_frames: Optional[int] = None
    q_out_units: Optional[int] = None
    # byte-changing plan knob carried through, never searched (module
    # doc): the canonical ebpolicy spec, () for uniform
    eb_policy: tuple = ()

    @property
    def key(self):
        """Deterministic tie-break / identity tuple."""
        return (self.grid or (0, 0, 0), self.backend, self.codec,
                self.batch_units, self.batch_cap, self.async_engine,
                self.q_in_frames or 0, self.q_out_units or 0,
                self.eb_policy)

    def describe(self) -> str:
        g = "mono" if self.grid is None else \
            f"{self.grid[0]}x{self.grid[1]}x{self.grid[2]}"
        bits = [g, self.backend, self.codec,
                f"cap{self.batch_cap}" if self.grid else "",
                "async" if self.async_engine else "",
                "eb-adaptive" if self.eb_policy else ""]
        return "/".join(b for b in bits if b)


def available_backends() -> tuple:
    """Backends worth searching on this host.  pallas only exists on
    TPU (backend.resolve would demote it per-unit anyway, making it a
    duplicate of xla on CPU)."""
    if costmodel.device_kind() == "tpu":
        return ("pallas", "xla", "numpy")
    return ("xla", "numpy")


def _axis_tiles(n: int) -> tuple:
    """Candidate tile sizes along one spatial axis: the full extent
    plus halvings down to 8, preferring exact divisors (no ragged last
    tile -> fewer signature groups)."""
    out = [n]
    t = n
    while t > 8:
        t = max(t // 2, 8)
        out.append(t)
    # snap each halving to the nearest divisor within 25% if one exists
    divs = [d for d in range(8, n + 1) if n % d == 0]
    snapped = []
    for t in out:
        best = min(divs, key=lambda d: abs(d - t), default=t)
        snapped.append(best if abs(best - t) <= max(t // 4, 1) else t)
    # dedupe, keep order
    seen, res = set(), []
    for t in snapped:
        if t not in seen:
            seen.add(t)
            res.append(t)
    return tuple(res[:3])


def _window_lengths(T: int) -> tuple:
    out, w = [T], T
    while w > 4:
        w = max(w // 2, 4)
        out.append(w)
    seen, res = set(), []
    for w in out:
        if w not in seen:
            seen.add(w)
            res.append(w)
    return tuple(res[:3])


def enumerate_candidates(shape, stream: bool = False,
                         backends: Optional[Sequence[str]] = None,
                         codecs: Sequence[str] = ("host", "device"),
                         batch_caps: Sequence[int] = (4, 8, 16),
                         eb_policy: tuple = ()) -> list:
    """The full (pre-pruning) candidate list for one field shape.

    ``stream=True`` drops the monolithic candidate (a stream cannot be
    monolithic) and adds async-engine / queue-bound variants.
    ``eb_policy`` (a canonical spec, () for uniform) is stamped on
    every candidate unchanged -- carried, never enumerated.
    """
    T, H, W = shape
    backends = tuple(backends or available_backends())
    eb_policy = tuple(eb_policy or ())
    cands = []
    if not stream:
        for be in backends:
            cands.append(PlanCandidate(grid=None, backend=be,
                                       eb_policy=eb_policy))
    grids = [(th, tw, wt)
             for th in _axis_tiles(H)
             for tw in _axis_tiles(W)
             for wt in _window_lengths(T)]
    # a 1x1-tile "grid" covering everything in one window duplicates the
    # monolithic plan's work at tiled overhead; keep it only for streams
    if not stream:
        grids = [g for g in grids
                 if not (g[0] >= H and g[1] >= W and g[2] >= T)]
    for g in grids:
        nti = -(-H // g[0])
        ntj = -(-W // g[1])
        for be in backends:
            for codec in codecs:
                for cap in batch_caps:
                    if cap > nti * ntj and cap != batch_caps[0]:
                        continue  # caps beyond the unit count duplicate
                    base = PlanCandidate(grid=g, backend=be, codec=codec,
                                         batch_cap=cap,
                                         eb_policy=eb_policy)
                    cands.append(base)
                    if stream:
                        tpw = nti * ntj
                        cands.append(dataclasses.replace(
                            base, async_engine=True,
                            q_in_frames=max(g[2], 2),
                            q_out_units=max(2 * tpw, 2)))
                        cands.append(dataclasses.replace(
                            base, async_engine=True,
                            q_in_frames=2,
                            q_out_units=max(tpw // 2, 2)))
    # dedupe (divisor snapping can collide) with deterministic order
    seen, out = set(), []
    for c in cands:
        if c.key not in seen:
            seen.add(c.key)
            out.append(c)
    return out


@dataclasses.dataclass
class Ranked:
    cand: PlanCandidate
    predicted: dict                  # costmodel.predict output
    measured_s: Optional[float] = None


def search(shape, model: Optional[costmodel.CostModel] = None,
           stream: bool = False, verify_rounds: float = 2.0,
           backends: Optional[Sequence[str]] = None,
           top_k: int = 0,
           measure: Optional[Callable[[PlanCandidate], float]] = None,
           candidates: Optional[Sequence[PlanCandidate]] = None,
           ingest_s: float = 0.0, eb_policy: tuple = ()) -> list:
    """Rank the candidate space by predicted cost; optionally measure
    the ``top_k`` cheapest with ``measure(cand) -> seconds`` and re-rank
    those by measured time.  Returns [Ranked] sorted best-first --
    measured candidates (if any) always sort ahead of unmeasured ones.
    """
    model = model or costmodel.CostModel()
    T, H, W = shape
    wl = costmodel.Workload(T=T, H=H, W=W, verify_rounds=verify_rounds,
                            stream=stream, ingest_s=ingest_s)
    cands = list(candidates) if candidates is not None else \
        enumerate_candidates(shape, stream=stream, backends=backends,
                             eb_policy=eb_policy)
    ranked = [Ranked(c, model.predict(c, wl)) for c in cands]
    ranked.sort(key=lambda r: (r.predicted["total"], r.cand.key))
    if top_k and measure is not None:
        head = ranked[:top_k]
        for r in head:
            r.measured_s = measure(r.cand)
        head.sort(key=lambda r: (r.measured_s, r.cand.key))
        ranked = head + ranked[top_k:]
    return ranked


def apply(cfg, cand: PlanCandidate):
    """A new CompressionConfig realizing ``cand`` (cfg untouched).

    ``cfg.eb_policy`` passes through unmodified: the candidate's
    ``eb_policy`` field is a record of the policy the tune ran under,
    not a knob the search is allowed to move (byte-changing)."""
    from ..core import tiling

    grid = None
    if cand.grid is not None:
        grid = tiling.TileGrid(tile_h=cand.grid[0], tile_w=cand.grid[1],
                               window_t=cand.grid[2])
    return dataclasses.replace(
        cfg, backend=cand.backend, codec=cand.codec,
        batch_units=cand.batch_units, batch_cap=cand.batch_cap,
        q_in_frames=cand.q_in_frames, q_out_units=cand.q_out_units,
        tiling=grid)
