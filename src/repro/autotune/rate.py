"""Rate allocation: pick per-unit base bounds to hit a target ratio.

``compress(..., target_ratio=...)`` lands here.  The search builds an
adaptive eb policy (core/ebpolicy.py) instead of scaling one global
bound:

1. a uniform baseline run at ``cfg.eb`` measures the starting ratio
   (if it already meets the target, it IS the result -- zero-cost
   opt-in);
2. a tiled probe over the policy grid feeds ``obs.run_report``: the
   per-unit achieved-vs-Shannon bits say how many bits each unit is
   actually spending, which (a) identifies how far from the entropy
   floor the stream is and (b) seeds the relax ladder -- the bit
   deficit to the target divided by the relaxable symbol count is the
   per-symbol saving needed, and coarsening the quantization grid by
   ``f`` saves ~log2(f) bits/symbol, so ``f0 = 2**ceil(deficit_bps)``;
3. units covering an extracted critical-point trajectory are
   PROTECTED: they keep ``cfg.eb`` no matter the target, so the
   features the compressor exists to preserve never pay for the ratio
   (and FC = 0 stays enforced by the verify fixpoint regardless);
4. a geometric ladder over the relax factor ``f`` re-compresses
   two-valued policies (protected at ``eb``, everything else at
   ``eb * f``) and keeps the SMALLEST f meeting the target.

Why two-valued and not per-unit-graded bounds: measured on the
entropy-coded symbol streams, bound-value diversity is poison -- every
distinct bound adds distinct cap planes and level mixes, and the
entropy cost of that heterogeneity exceeds what graded relaxation
saves (a graded ``eb * f**w_u`` sweep landed BELOW the uniform
baseline).  The ladder is also not bisectable: ratio(f) is
non-monotonic because looser bounds widen the level ladder
(``levels_for``), so the search walks rungs and remembers the best.

The result is an ordinary adaptive container -- everything recorded
self-describingly (policy spec in the header, per-unit ``eb_base``),
so decode needs nothing from this module.
"""
from __future__ import annotations

import dataclasses
import math


def _policy_grid(cfg, shape):
    """Policy-grid dims: the configured tiling when present, else a
    fine default.  Fine matters: every protected (track-covering) unit
    drags its one-cell/one-frame inflated neighborhood down to the
    tight bound, so coarse policy tiles let a handful of trajectories
    pin most of the field and the relaxation buys nothing."""
    T, H, W = shape
    g = getattr(cfg, "tiling", None)
    if g is not None:
        return int(g.window_t), int(g.tile_h), int(g.tile_w)
    return (min(max(T // 2, 1), 4),
            min(H, max(8, H // 8)),
            min(W, max(8, W // 8)))


def _compress(u, v, cfg):
    from ..core import compressor, tiling

    if cfg.tiling is not None:
        return tiling.compress_tiled(u, v, cfg, cfg.tiling)
    return compressor.compress(u, v, cfg)


def compress_with_target(u, v, cfg, target_ratio: float,
                         max_relax: float = 256.0, max_iters: int = 6,
                         margin: float = 1.0):
    """Compress (u, v) to at least ``target_ratio`` via adaptive
    per-unit bounds; track-covering units stay at ``cfg.eb``.

    Returns (blob, stats); stats gains a ``rate_target`` record
    (target, achieved, met flag, relax factor, protected-unit count).
    When even the best policy in the family cannot reach the target,
    the best-ratio container found is returned with ``met=False`` -- a
    typed failure would throw away a perfectly valid archive.
    """
    import numpy as np

    from .. import analysis, obs
    from ..core import ebpolicy, tiling

    if target_ratio <= 0:
        raise ValueError(f"target_ratio must be > 0, got {target_ratio}")
    if ebpolicy.normalize(getattr(cfg, "eb_policy", None)) is not None:
        raise ValueError("compress_with_target builds the eb policy "
                         "itself; pass a config without one")
    u = np.asarray(u, np.float32)
    v = np.asarray(v, np.float32)
    raw_bytes = u.nbytes + v.nbytes

    blob0, stats0 = _compress(u, v, cfg)
    if stats0["ratio"] >= target_ratio:
        stats0["rate_target"] = {
            "target_ratio": float(target_ratio),
            "achieved_ratio": float(stats0["ratio"]),
            "met": True, "relax": 1.0, "n_protected": None,
            "uniform_ratio": float(stats0["ratio"]),
            "uniform_sufficient": True,
        }
        return blob0, stats0

    wt, th, tw = _policy_grid(cfg, u.shape)
    # per-unit achieved/Shannon bits from a tiled probe over the policy
    # grid (the baseline may be monolithic = one unit, which tells the
    # allocator nothing)
    probe_cfg = dataclasses.replace(
        cfg, tiling=tiling.TileGrid(tile_h=th, tile_w=tw, window_t=wt),
        track_index=False)
    probe, _ = tiling.compress_tiled(u, v, probe_cfg, probe_cfg.tiling)
    rows = [r for r in obs.run_report(probe)["units"]
            if r["key"] is not None]
    protected = analysis.track_units(u, v, wt, th, tw, margin=margin,
                                     backend=cfg.backend,
                                     fixed_bits=cfg.fixed_bits)
    free = [r for r in rows if tuple(r["key"]) not in protected]
    free_syms = sum(r["n_symbols"] for r in free)
    base = float(cfg.eb)

    # seed rung: bits we must shed to hit the target, spread over the
    # relaxable symbols; coarsening the grid by f saves ~log2(f) bps
    deficit_bits = 8.0 * (len(blob0) - raw_bytes / target_ratio)
    need_bps = deficit_bits / max(free_syms, 1)
    f0 = 2.0 ** max(2, math.ceil(need_bps))
    f0 = min(max(f0, 2.0), float(max_relax))

    def build(f):
        pol = ebpolicy.TilePolicy.make(
            wt, th, tw, default=base * f,
            values={k: base for k in protected})
        run_cfg = dataclasses.replace(
            cfg, eb_policy=pol,
            n_levels=ebpolicy.levels_for(pol, cfg.n_levels))
        blob, stats = _compress(u, v, run_cfg)
        return float(f), blob, stats

    tried = {}
    best = None           # best ratio seen (fallback when target unmet)
    winner = None         # smallest f meeting the target

    def visit(f):
        nonlocal best, winner
        if f in tried:
            return tried[f]
        r = build(f)
        tried[f] = r
        if best is None or r[2]["ratio"] > best[2]["ratio"]:
            best = r
        if r[2]["ratio"] >= target_ratio and \
                (winner is None or r[0] < winner[0]):
            winner = r
        return r

    f = f0
    r = visit(f)
    if r[2]["ratio"] >= target_ratio:
        # walk down for the least-distortion rung still meeting it
        while len(tried) < max_iters and f > 2.0:
            f = f / 2.0
            if visit(f)[2]["ratio"] < target_ratio:
                break
    else:
        # walk up until the target is met or the family tops out
        while len(tried) < max_iters and f < float(max_relax):
            f = min(f * 2.0, float(max_relax))
            if visit(f)[2]["ratio"] >= target_ratio:
                break

    f, blob, stats = winner if winner is not None else best
    stats["rate_target"] = {
        "target_ratio": float(target_ratio),
        "achieved_ratio": float(stats["ratio"]),
        "met": bool(stats["ratio"] >= target_ratio),
        "relax": float(f),
        "seed_relax": float(f0),
        "rungs_tried": sorted(tried),
        "n_protected": len(protected),
        "n_units": len(rows),
        "uniform_ratio": float(stats0["ratio"]),
        "uniform_sufficient": False,
    }
    return blob, stats
