"""Cost-model calibration from measured obs spans (DESIGN.md #15).

Protocol: run the real pipelines (monolithic fused + tiled, host and
device codecs) on a few small synthetic fields with tracing enabled,
read per-stage wall time from the ``span.*`` duration Histograms
(``obs.stage_durations``), and fit the two-term model

    t_stage = c0 * n_dispatches + c1 * n_elements

per (backend, stage) by least squares over the collected (dispatches,
elements, seconds) samples -- at least two field sizes, so c0 and c1
are separable.  Coefficients are persisted to a versioned JSON table
keyed by (device_kind, backend, stage); a table from another format
version or another device kind is refused with a typed
``CalibrationTableError`` (reason "stale" / "foreign"), never silently
used -- a TPU-fitted table would invert every CPU trade-off.

Calibration runs enable JAX's persistent compilation cache
(``perfflags.apply_jit_cache``) so repeated invocations stop paying
cold jit; REPRO_JIT_CACHE overrides the location.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import numpy as np

from .. import obs, perfflags
from . import costmodel

TABLE_FORMAT = "repro-autotune-calib"
TABLE_VERSION = 1

# span name -> model stage (costmodel.STAGES)
SPAN_STAGES = {
    "pipeline.derive_eb": "derive_eb",
    "pipeline.quantize_predict": "quantize_predict",
    "pipeline.verify_round": "verify_round",
    "pipeline.symbolize": "symbolize",
    "pipeline.pack": "pack",
    "tiling.derive_window": "tiled_derive",
    "tiling.verify_round": "tiled_verify",
    "tiling.unit_payloads": "tiled_encode",
    "tiling.write_units": "tiled_write",
    "tiling.entropy_fragments": "tiled_entropy",
}

# default calibration workload: two sizes so c0/c1 separate
CALIB_SHAPES = ((4, 24, 24), (8, 40, 40))


class CalibrationTableError(ValueError):
    """A calibration table that must not be used: wrong format/version
    (``reason="stale"``), wrong hardware (``reason="foreign"``), or
    unparseable (``reason="corrupt"``)."""

    def __init__(self, msg: str, reason: str):
        super().__init__(msg)
        self.reason = reason


@dataclasses.dataclass
class CalibrationTable:
    """Fitted {(backend, stage): (c0, c1)} for one device kind."""

    device_kind: str
    coeffs: dict
    version: int = TABLE_VERSION
    meta: dict = dataclasses.field(default_factory=dict)


def default_table_path() -> str:
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune_calib.json")


def save_table(table: CalibrationTable, path: Optional[str] = None) -> str:
    path = path or default_table_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "format": TABLE_FORMAT,
        "version": table.version,
        "device_kind": table.device_kind,
        "meta": table.meta,
        "entries": [
            {"backend": be, "stage": stage, "c0": c0, "c1": c1}
            for (be, stage), (c0, c1) in sorted(table.coeffs.items())
        ],
    }
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path


def load_table(path: Optional[str] = None,
               expect_kind: Optional[str] = None) -> CalibrationTable:
    """Load and VALIDATE a persisted table.  Raises CalibrationTableError
    (typed, with ``.reason``) instead of ever silently returning a table
    this process must not use."""
    path = path or default_table_path()
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        raise CalibrationTableError(
            f"calibration table {path} is unreadable: {e}",
            reason="corrupt") from e
    if not isinstance(payload, dict) \
            or payload.get("format") != TABLE_FORMAT:
        raise CalibrationTableError(
            f"{path} is not a {TABLE_FORMAT} file", reason="corrupt")
    if payload.get("version") != TABLE_VERSION:
        raise CalibrationTableError(
            f"calibration table {path} has format version "
            f"{payload.get('version')}; this build expects "
            f"{TABLE_VERSION} -- recalibrate instead of reusing stale "
            "coefficients", reason="stale")
    kind = expect_kind or costmodel.device_kind()
    if payload.get("device_kind") != kind:
        raise CalibrationTableError(
            f"calibration table {path} was fitted on "
            f"{payload.get('device_kind')!r} hardware, this process runs "
            f"on {kind!r} -- foreign coefficients would invert the "
            "trade-offs; recalibrate", reason="foreign")
    coeffs = {}
    try:
        for e in payload["entries"]:
            coeffs[(e["backend"], e["stage"])] = (
                float(e["c0"]), float(e["c1"]))
    except (KeyError, TypeError, ValueError) as e:
        raise CalibrationTableError(
            f"calibration table {path} has malformed entries: {e}",
            reason="corrupt") from e
    return CalibrationTable(device_kind=payload["device_kind"],
                            coeffs=coeffs, version=payload["version"],
                            meta=payload.get("meta", {}))


def _fit(samples) -> tuple:
    """Least-squares (c0, c1) >= 0 from rows of (n_disp, n_elems, t)."""
    a = np.array([[r[0], r[1]] for r in samples], dtype=np.float64)
    t = np.array([r[2] for r in samples], dtype=np.float64)
    c0 = c1 = 0.0
    if len(samples) >= 2 and np.linalg.matrix_rank(a) == 2:
        sol, *_ = np.linalg.lstsq(a, t, rcond=None)
        c0, c1 = float(sol[0]), float(sol[1])
    if c0 < 0.0 or c1 < 0.0 or (c0 == 0.0 and c1 == 0.0):
        # degenerate fit: fall back to a pure per-element rate (and a
        # per-dispatch floor from the smallest observed dispatch)
        tot_e = sum(r[1] for r in samples)
        tot_d = sum(r[0] for r in samples)
        tot_t = sum(r[2] for r in samples)
        c1 = tot_t / tot_e if tot_e else 0.0
        c0 = 0.1 * tot_t / tot_d if tot_d else 0.0
    return c0, c1


def _workload_runs(shape, backend, eb):
    """The calibration runs for one (shape, backend): monolithic fused
    (host codec) + tiled host + tiled device.  Returns
    [(kind, codec, grid)] descriptors executed by calibrate()."""
    T, H, W = shape
    grid = (max(H // 2, 8), max(W // 2, 8), max(T // 2, 2))
    return [("mono", "host", None), ("tiled", "host", grid),
            ("tiled", "device", grid)]


def _stage_elems(kind, stage, shape, grid):
    """Total elements the model charges a stage with for one run (must
    mirror costmodel.CostModel.predict's accounting)."""
    T, H, W = shape
    wl = costmodel.Workload(T=T, H=H, W=W)
    if kind == "mono":
        return wl.elems
    g = costmodel.geometry(wl, grid)
    if stage in ("tiled_write", "tiled_entropy"):
        return g.n_units * g.unit_owned_elems
    return g.n_units * g.unit_ext_elems


def calibrate(shapes=CALIB_SHAPES, backends=None, eb: float = 1e-2,
              path: Optional[str] = None, save: bool = True,
              jit_cache: bool = True) -> CalibrationTable:
    """Run the calibration workload and fit a CalibrationTable.

    ``backends`` defaults to every backend worth searching on this host
    (search.available_backends).  With ``save`` the table is persisted
    to ``path`` (default ~/.cache/repro/autotune_calib.json) for later
    runs to load.
    """
    from ..core import compressor, tiling
    from . import search as search_mod

    if jit_cache:
        perfflags.apply_jit_cache(
            perfflags.jit_cache_dir()
            or os.path.join(os.path.dirname(default_table_path()),
                            "jax-cache"))
    backends = tuple(backends or search_mod.available_backends())
    kind = costmodel.device_kind()
    samples = {}
    was_enabled = obs.enabled()
    try:
        obs.enable()
        for backend in backends:
            for shape in shapes:
                T, H, W = shape
                rng = np.random.default_rng(7)
                base = np.cumsum(
                    rng.normal(size=(T, H, W)).astype(np.float32), axis=0)
                u, v = base, base[::-1].copy()
                for kind_run, codec, grid in _workload_runs(
                        shape, backend, eb):
                    cfg = compressor.CompressionConfig(
                        eb=eb, mode="rel", predictor="mop",
                        backend=backend, fused=True, codec=codec,
                        track_index=False)
                    # warm once so compile time never lands in the fit
                    # (the persistent jit cache makes this cheap on
                    # repeat invocations), then measure a clean run
                    if grid is None:
                        compressor.compress(u, v, cfg)
                    else:
                        tg = tiling.TileGrid(tile_h=grid[0],
                                             tile_w=grid[1],
                                             window_t=grid[2])
                        tiling.compress_tiled(u, v, cfg, tg)
                    before = obs.stage_durations()
                    if grid is None:
                        compressor.compress(u, v, cfg)
                    else:
                        tiling.compress_tiled(u, v, cfg, tg)
                    after = obs.stage_durations()
                    for span, stage in SPAN_STAGES.items():
                        b = before.get(span, {"count": 0, "sum_s": 0.0})
                        a = after.get(span, {"count": 0, "sum_s": 0.0})
                        n = a["count"] - b["count"]
                        dt = a["sum_s"] - b["sum_s"]
                        if n <= 0 or dt <= 0:
                            continue
                        elems = _stage_elems(kind_run, stage, shape, grid)
                        samples.setdefault((backend, stage), []).append(
                            (n, float(elems), dt))
    finally:
        obs.enable() if was_enabled else obs.disable()

    coeffs = {key: _fit(rows) for key, rows in samples.items()}
    table = CalibrationTable(
        device_kind=kind, coeffs=coeffs,
        meta={"shapes": [list(s) for s in shapes],
              "backends": list(backends), "eb": eb})
    if save:
        save_table(table, path)
    return table


def load_or_calibrate(path: Optional[str] = None) -> CalibrationTable:
    """The autotune entry point's table source: load the persisted
    table; on missing/stale/foreign/corrupt, run a fresh calibration
    (and persist it).  A refused table is counted, never used."""
    try:
        return load_table(path)
    except FileNotFoundError:
        obs.counter("autotune.table_miss").add(1)
    except CalibrationTableError as e:
        obs.counter(f"autotune.table_refused.{e.reason}").add(1)
    return calibrate(path=path)
