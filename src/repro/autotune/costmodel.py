"""Analytic per-stage cost model over pipeline plans (DESIGN.md #15).

Every stage cost is the two-term affine model

    t_stage = c0 * n_dispatches + c1 * n_elements

where ``c0`` prices per-dispatch overhead (jit call/dispatch latency,
host loop iteration) and ``c1`` prices per-element streaming work.
Uncalibrated, the coefficients are *seeded* from roofline terms: each
stage has a (flops/element, bytes/element) intensity estimate -- the
non-dot op weights come from ``hlocost.NONDOT_FLOP_WEIGHTS`` (gather/
scatter for symbol routing, reduce/histogram for table builds,
prefix-sum for the bit-pack), since the entropy stages are exactly the
ops a dot-dominated FLOP count misprices -- and ``c1`` is the roofline
max of compute and memory time at the device-kind's peak rates.
Calibration (calibrate.py) replaces the seeds with coefficients fitted
to measured ``obs`` span durations on the actual machine; seeds only
have to rank candidates sensibly until a calibration table exists.

The model never touches container bytes: it only orders candidate
configurations by predicted wall time.  Byte content is fully
determined by the chosen plan (pipeline.PipelinePlan), not by how fast
we guessed it would run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .. import hlocost

# model stages <-> the obs spans they are calibrated from
# (monolithic pipeline spans and tiled-path spans are separate stages:
# they run different code with different dispatch granularity)
STAGES = (
    "derive_eb",        # pipeline.derive_eb (monolithic)
    "quantize_predict",  # pipeline.quantize_predict (monolithic)
    "verify_round",     # pipeline.verify_round (monolithic)
    "symbolize",        # pipeline.symbolize (host codec)
    "pack",             # pipeline.pack (host codec)
    "tiled_derive",     # tiling.derive_window
    "tiled_verify",     # tiling.verify_round
    "tiled_encode",     # tiling.unit_payloads (final-mask encode)
    "tiled_write",      # tiling.write_units (symbolize+pack+container)
    "tiled_entropy",    # tiling.entropy_fragments (device codec)
)

# stage intensity seeds: (flops/element, bytes/element).  The entropy
# stages draw on the non-dot op weights (hlocost.NONDOT_FLOP_WEIGHTS):
# symbolize is gather-shaped (escape routing), table build is
# reduce/histogram-shaped, bit-pack is a prefix-sum pass.
_W = hlocost.NONDOT_FLOP_WEIGHTS
STAGE_INTENSITY = {
    "derive_eb": (48.0, 40.0),
    "quantize_predict": (64.0, 56.0),
    "verify_round": (96.0, 72.0),
    "symbolize": (_W["gather"] + _W["reduce"], 12.0),
    "pack": (_W["reduce-window"] + _W["reduce"], 10.0),
    "tiled_derive": (48.0, 40.0),
    "tiled_verify": (96.0, 72.0),
    "tiled_encode": (64.0, 56.0),
    "tiled_write": (_W["gather"] + _W["reduce-window"], 12.0),
    "tiled_entropy": (_W["gather"] + _W["reduce"] + _W["reduce-window"],
                      8.0),
}

# device-kind peak rates: (flops/s, bytes/s, dispatch overhead s).
# TPU numbers mirror roofline.PEAK_FLOPS/HBM_BW; the cpu row is a
# deliberately modest single-socket estimate -- seeds only need to
# produce a sane *ordering*, calibration supplies real magnitudes.
DEVICE_RATES = {
    "tpu": (197e12, 819e9, 50e-6),
    "gpu": (60e12, 1.5e12, 30e-6),
    "cpu": (5e10, 2e10, 120e-6),
}
# the numpy backend skips jit dispatch entirely: cheaper per call,
# slower per element than fused XLA CPU code
_NUMPY_RATE_SCALE = (0.5, 1.0, 0.15)


def device_kind() -> str:
    """Coarse device kind ('tpu' | 'gpu' | 'cpu') of the default JAX
    backend; the calibration-table key that makes a table foreign on
    different hardware."""
    try:
        import jax

        return {"tpu": "tpu", "gpu": "gpu", "cuda": "gpu",
                "rocm": "gpu"}.get(jax.default_backend(), "cpu")
    except Exception:
        return "cpu"


def seed_coeffs(kind: str, backend: str) -> dict:
    """Roofline-seeded {stage: (c0, c1)} for one (device kind, backend)."""
    peak_flops, mem_bw, disp = DEVICE_RATES.get(kind, DEVICE_RATES["cpu"])
    if backend == "numpy":
        sf, sb, sd = _NUMPY_RATE_SCALE
        peak_flops, mem_bw, disp = peak_flops * sf, mem_bw * sb, disp * sd
    out = {}
    for stage in STAGES:
        f, b = STAGE_INTENSITY[stage]
        # roofline: the slower of the compute and memory terms bounds
        # the per-element time
        out[stage] = (disp, max(f / peak_flops, b / mem_bw))
    return out


@dataclasses.dataclass(frozen=True)
class Workload:
    """What the cost model prices a candidate against."""

    T: int
    H: int
    W: int
    verify_rounds: float = 2.0      # expected fixpoint rounds
    stream: bool = False
    # total producer latency over the stream (seconds): frames arriving
    # from a paced source (a running solver) serialize with compute on
    # the serial engine but overlap with it on the async engine -- the
    # term that makes async worth its coordination cost
    ingest_s: float = 0.0

    @property
    def elems(self) -> int:
        # both components
        return 2 * self.T * self.H * self.W


def _tile_counts(n: int, tile: int):
    """(tiles, distinct extents) along one axis for tile size ``tile``."""
    nt = -(-n // tile)
    # interior tiles share one extent; a ragged last tile adds another
    distinct = 1 if n % tile == 0 or nt == 1 else 2
    return nt, distinct


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Derived unit geometry for one candidate on one workload."""

    n_windows: int
    n_units: int
    n_sig_groups: int        # signature-group fan-out per window
    unit_ext_elems: int      # halo-extended elements per unit (u+v)
    unit_owned_elems: int    # owned elements per unit (u+v)
    tiles_per_window: int


def geometry(wl: Workload, grid) -> Optional[Geometry]:
    """Geometry for a (tile_h, tile_w, window_t) triple; None for the
    monolithic (untiled) candidate."""
    if grid is None:
        return None
    th, tw, wt = grid
    nw = -(-wl.T // wt)
    nti, dh = _tile_counts(wl.H, th)
    ntj, dw = _tile_counts(wl.W, tw)
    # window-length variety: a ragged last window adds a group set
    dt = 1 if wl.T % wt == 0 or nw == 1 else 2
    ext = (min(wt, wl.T) + 2) * (min(th, wl.H) + 2) * (min(tw, wl.W) + 2)
    owned = min(wt, wl.T) * min(th, wl.H) * min(tw, wl.W)
    return Geometry(
        n_windows=nw,
        n_units=nw * nti * ntj,
        n_sig_groups=max(dh * dw * dt, 1),
        unit_ext_elems=2 * ext,
        unit_owned_elems=2 * owned,
        tiles_per_window=nti * ntj,
    )


class CostModel:
    """Predict per-stage and total encode cost for a candidate.

    ``coeffs`` maps (backend, stage) -> (c0, c1); missing entries fall
    back to the roofline seeds for the model's device kind.
    """

    def __init__(self, coeffs: Optional[dict] = None,
                 kind: Optional[str] = None):
        self.kind = kind or device_kind()
        self.coeffs = dict(coeffs or {})
        self._seeds = {}

    def coeff(self, backend: str, stage: str):
        c = self.coeffs.get((backend, stage))
        if c is not None:
            return c
        seeds = self._seeds.get(backend)
        if seeds is None:
            seeds = self._seeds[backend] = seed_coeffs(self.kind, backend)
        return seeds[stage]

    def _term(self, backend: str, stage: str, n_disp: float,
              n_elems: float) -> float:
        c0, c1 = self.coeff(backend, stage)
        return c0 * n_disp + c1 * n_elems

    def predict(self, cand, wl: Workload) -> dict:
        """{"stages": {stage: seconds}, "total": seconds} for one
        candidate (search.PlanCandidate) on one workload."""
        be = cand.backend
        rounds = max(wl.verify_rounds, 1.0)
        stages = {}
        if cand.grid is None:
            # monolithic fused pipeline: one dispatch per stage, the
            # verify loop re-dispatches per round
            e = wl.elems
            stages["derive_eb"] = self._term(be, "derive_eb", 1, e)
            stages["quantize_predict"] = self._term(
                be, "quantize_predict", 1, e)
            stages["verify_round"] = self._term(
                be, "verify_round", rounds, rounds * e)
            stages["symbolize"] = self._term(be, "symbolize", 2, e)
            stages["pack"] = self._term(be, "pack", 2, e)
            total = sum(stages.values())
        else:
            g = geometry(wl, cand.grid)
            ext_total = g.n_units * g.unit_ext_elems
            owned_total = g.n_units * g.unit_owned_elems
            # batched execution chunks each signature group by batch_cap
            if cand.batch_units:
                per_w = sum(
                    -(-max(g.tiles_per_window // g.n_sig_groups, 1)
                      // cand.batch_cap)
                    for _ in range(g.n_sig_groups))
                n_batches = g.n_windows * per_w
            else:
                n_batches = g.n_units
            stages["tiled_derive"] = self._term(
                be, "tiled_derive", g.n_windows, ext_total)
            stages["tiled_verify"] = self._term(
                be, "tiled_verify", rounds * n_batches, rounds * ext_total)
            stages["tiled_encode"] = self._term(
                be, "tiled_encode", n_batches, ext_total)
            if cand.codec == "device":
                stages["tiled_entropy"] = self._term(
                    be, "tiled_entropy", g.n_windows * g.n_sig_groups,
                    owned_total)
                # container write still runs, minus the host Huffman
                stages["tiled_write"] = 0.25 * self._term(
                    be, "tiled_write", g.n_units, owned_total)
            else:
                stages["tiled_write"] = self._term(
                    be, "tiled_write", g.n_units, owned_total)
            total = sum(stages.values())
            if wl.stream:
                if cand.async_engine:
                    # three-stage overlap: ingest, compute and emit run
                    # concurrently, so the pipeline time approaches the
                    # slowest group plus a small coordination cost;
                    # undersized handoff queues reintroduce stalls
                    compute = (stages["tiled_derive"]
                               + stages["tiled_verify"]
                               + stages["tiled_encode"])
                    emit = total - compute
                    overlapped = max(wl.ingest_s, compute, emit) \
                        + 0.05 * total
                    q_out = cand.q_out_units or 2 * g.tiles_per_window
                    if q_out < g.tiles_per_window:
                        overlapped += 0.10 * total
                    q_in = cand.q_in_frames or max(cand.grid[2], 2)
                    if q_in < 2:
                        overlapped += 0.05 * total
                    total = overlapped
                else:
                    # serial engine: producer latency serializes with
                    # every downstream stage
                    total += wl.ingest_s
        return {"stages": stages, "total": total}
