"""Lossy baselines (paper Tables II-V comparison rows).

zfp-like   -- fixed-accuracy 4x4 orthonormal block transform (DCT-II)
              per frame, coefficient quantization, zstd backend.  A
              faithful-in-spirit stand-in for ZFP's decorrelating
              transform (labelled "-like" everywhere).
sz3-like   -- our dual-quantized block-local 3D-Lorenzo pipeline with a
              *uniform* error bound and NO critical-point constraints:
              exactly what a generic SZ-style compressor does.
cpsz-like  -- per-time-slice CP preservation only (slice faces constrain
              the error bound; cross-time slab faces are ignored), the
              paper's characterization of cpSZ(SoS): FC_t = 0 but
              trajectories may still break inside slabs.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from ..core import ebound, encode, fixedpoint, predictors, quantize
from ..core.compressor import (
    CompressionConfig, _decode_fields_jit, _reconstruct, _faces_to_vertex_mask,
)
import jax

_DCT4 = None


def _dct4():
    global _DCT4
    if _DCT4 is None:
        k = np.arange(4)[:, None]
        n = np.arange(4)[None, :]
        m = np.cos(np.pi * (2 * n + 1) * k / 8.0) * np.sqrt(2.0 / 4.0)
        m[0] /= np.sqrt(2.0)
        _DCT4 = m
    return _DCT4


def zfp_like(u, v, eb=1e-2, mode="rel", level=12, **kw):
    t0 = time.perf_counter()
    u = np.asarray(u, np.float32)
    v = np.asarray(v, np.float32)
    rng = float(max(u.max(), v.max()) - min(u.min(), v.min()))
    eb_abs = eb * rng if mode == "rel" else eb
    T, H, W = u.shape
    Hp, Wp = -(-H // 4) * 4, -(-W // 4) * 4
    m = _dct4()

    def fwd(x):
        xp = np.zeros((T, Hp, Wp), np.float32)
        xp[:, :H, :W] = x
        xp[:, H:, :W] = xp[:, H - 1 : H, :W]
        xp[:, :, W:] = xp[:, :, W - 1 : W]
        b = xp.reshape(T, Hp // 4, 4, Wp // 4, 4).transpose(0, 1, 3, 2, 4)
        c = np.einsum("ij,tbkjl,ml->tbkim", m, b.astype(np.float64), m)
        q = np.round(c / eb_abs).astype(np.int32)
        return q

    def inv(q):
        c = q.astype(np.float64) * eb_abs
        b = np.einsum("ji,tbkjl,lm->tbkim", m, c, m)
        xp = b.transpose(0, 1, 3, 2, 4).reshape(T, Hp, Wp)
        return xp[:, :H, :W].astype(np.float32)

    qu, qv = fwd(u), fwd(v)
    payload = qu.astype(np.int16).tobytes() + qv.astype(np.int16).tobytes()
    over = np.concatenate([qu[np.abs(qu) > 32000], qv[np.abs(qv) > 32000]])
    blob = encode.codec_compress(payload, level)
    tc = time.perf_counter() - t0
    t0 = time.perf_counter()
    ur, vr = inv(np.clip(qu, -32000, 32000)), inv(np.clip(qv, -32000, 32000))
    td = time.perf_counter() - t0
    n = u.nbytes + v.nbytes
    return {
        "name": "zfp-like", "lossless": False, "eb_abs": eb_abs,
        "orig_bytes": n, "comp_bytes": len(blob) + over.nbytes,
        "ratio": n / (len(blob) + over.nbytes),
        "t_compress": tc, "t_decompress": td,
        "u_rec": ur, "v_rec": vr,
    }


def _pack_like_ours(res_u, res_v, lossless, u_ll, v_ll, bm_shape, level):
    sym_u, esc_u = encode.to_symbols(np.asarray(res_u))
    sym_v, esc_v = encode.to_symbols(np.asarray(res_v))
    sections = {
        "sym_u": sym_u, "sym_v": sym_v, "esc_u": esc_u, "esc_v": esc_v,
        "lossless": np.packbits(lossless),
        "u_ll": u_ll, "v_ll": v_ll,
        "blockmap": np.packbits(np.zeros(bm_shape, bool)),
        "bm_shape": np.asarray(bm_shape, np.int32),
    }
    return encode.pack({"v": 1}, sections, level)


def sz3_like(u, v, eb=1e-2, mode="rel", level=12, block=16, **kw):
    """Uniform-eb Lorenzo pipeline, no CP constraints, no verify."""
    t0 = time.perf_counter()
    u = np.asarray(u, np.float32)
    v = np.asarray(v, np.float32)
    T, H, W = u.shape
    rng = float(max(u.max(), v.max()) - min(u.min(), v.min()))
    eb_abs = eb * rng if mode == "rel" else eb
    scale, ufp, vfp = fixedpoint.to_fixed(u, v)
    tau = max(int(np.floor(eb_abs * scale)), 1)
    xi_unit = max(tau, 1)  # SZ semantics: quantum 2*eb, max err <= eb
    k = jnp.zeros((T, H, W), jnp.int32)
    ll = jnp.zeros((T, H, W), bool)
    xu = quantize.dual_quantize(jnp.asarray(ufp), k, ll, xi_unit)
    xv = quantize.dual_quantize(jnp.asarray(vfp), k, ll, xi_unit)
    res_u = predictors.lorenzo_encode(xu, block)
    res_v = predictors.lorenzo_encode(xv, block)
    bm_shape = (T, -(-H // block), -(-W // block))
    blob = _pack_like_ours(res_u, res_v, np.zeros((T, H, W), bool),
                           np.zeros(0, np.float32), np.zeros(0, np.float32),
                           bm_shape, level)
    tc = time.perf_counter() - t0

    t0 = time.perf_counter()
    xu_d, xv_d = _decode_fields_jit(
        res_u, res_v, jnp.zeros(bm_shape, bool), scale, xi_unit, block,
        1.0, 1.0, 2.0, 32)
    ur, vr = _reconstruct(xu_d, xv_d, scale, xi_unit, ll,
                          jnp.asarray(u), jnp.asarray(v))
    td = time.perf_counter() - t0
    n = u.nbytes + v.nbytes
    return {
        "name": "sz3-like", "lossless": False, "eb_abs": eb_abs,
        "orig_bytes": n, "comp_bytes": len(blob), "ratio": n / len(blob),
        "t_compress": tc, "t_decompress": td,
        "u_rec": np.asarray(ur), "v_rec": np.asarray(vr),
    }


def cpsz_like(u, v, eb=1e-2, mode="rel", level=12, block=16, **kw):
    """Per-slice CP preservation only (no slab faces, no slab verify)."""
    t0 = time.perf_counter()
    u = np.asarray(u, np.float32)
    v = np.asarray(v, np.float32)
    T, H, W = u.shape
    rng = float(max(u.max(), v.max()) - min(u.min(), v.min()))
    eb_abs = eb * rng if mode == "rel" else eb
    scale, ufp, vfp = fixedpoint.to_fixed(u, v)
    tau = max(int(np.floor(eb_abs * scale)), 1)
    xi_unit, n_levels = quantize.ladder(tau)

    ufp_j, vfp_j = jnp.asarray(ufp), jnp.asarray(vfp)
    # slice faces only: run the full derivation, then lift the slab
    # constraints by re-deriving with slab contributions ignored.
    eb_slice = _slice_only_eb(ufp_j, vfp_j, tau)

    lossless_extra = jnp.zeros((T, H, W), bool)
    for _ in range(8):
        k, lossless = quantize.quantize_eb(eb_slice, xi_unit, n_levels)
        lossless = jnp.logical_or(lossless, lossless_extra)
        xu = quantize.dual_quantize(ufp_j, k, lossless, xi_unit)
        xv = quantize.dual_quantize(vfp_j, k, lossless, xi_unit)
        res_u = predictors.lorenzo_encode(xu, block)
        res_v = predictors.lorenzo_encode(xv, block)
        bm_shape = (T, -(-H // block), -(-W // block))
        xu_d, xv_d = _decode_fields_jit(
            res_u, res_v, jnp.zeros(bm_shape, bool), scale, xi_unit, block,
            1.0, 1.0, 2.0, 32)
        ur, vr = _reconstruct(xu_d, xv_d, scale, xi_unit, lossless,
                              jnp.asarray(u), jnp.asarray(v))
        # verify SLICE predicates only (the cpSZ guarantee)
        ur_fp, vr_fp = fixedpoint.refix(np.asarray(ur), np.asarray(vr), scale)
        s0, _ = ebound.all_face_predicates(ufp_j, vfp_j)
        s1, _ = ebound.all_face_predicates(jnp.asarray(ur_fp), jnp.asarray(vr_fp))
        bad = np.asarray(s0 ^ s1)
        err = np.maximum(np.abs(np.asarray(ur, np.float64) - u),
                         np.abs(np.asarray(vr, np.float64) - v))
        bad_pt = err > eb_abs
        if bad.sum() == 0 and bad_pt.sum() == 0:
            break
        extra = np.asarray(lossless_extra) | bad_pt
        extra |= _faces_to_vertex_mask(
            bad, np.zeros((T - 1, 1), bool), T, H, W)
        lossless_extra = jnp.asarray(extra)

    lossless_np = np.asarray(lossless)
    blob = _pack_like_ours(res_u, res_v, lossless_np,
                           u[lossless_np], v[lossless_np], bm_shape, level)
    tc = time.perf_counter() - t0
    n = u.nbytes + v.nbytes
    return {
        "name": "cpsz-like", "lossless": False, "eb_abs": eb_abs,
        "orig_bytes": n, "comp_bytes": len(blob), "ratio": n / len(blob),
        "t_compress": tc, "t_decompress": 0.0,
        "u_rec": np.asarray(ur), "v_rec": np.asarray(vr),
    }


def _slice_only_eb(ufp, vfp, tau):
    """Per-vertex bound from time-slice faces only (cpSZ semantics)."""
    from ..core import grid, sos
    from ..core.ebound import _faces_eb_update, _incidence_table

    T, H, W = ufp.shape
    HW = H * W
    slice_tab = jnp.asarray(grid.slab_faces(H, W)["slice0"])
    slice_inc = jnp.asarray(_incidence_table(H, W, "slice"))
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)

    def body(carry, x):
        t, u_t, v_t = x
        eb, _ = _faces_eb_update(u_t, v_t, t * HW, slice_tab, tau, HW,
                                 slice_inc)
        return carry, eb

    _, ebs = jax.lax.scan(
        body, 0, (jnp.arange(T, dtype=jnp.int64), u2, v2))
    return ebs.reshape(T, H, W)
