from .lossless import gzip_compress, zstd_compress, fpzip_like  # noqa: F401
from .lossy import zfp_like, sz3_like, cpsz_like  # noqa: F401

REGISTRY = {
    "gzip": gzip_compress,
    "zstd": zstd_compress,
    "fpzip-like": fpzip_like,
    "zfp-like": zfp_like,
    "sz3-like": sz3_like,
    "cpsz-like": cpsz_like,
}
