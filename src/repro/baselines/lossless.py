"""Lossless baselines (paper Tables II-V upper-bound rows).

gzip / zstd are the real codecs; "fpzip-like" approximates FPZIP's
float-stream decorrelation with byte-plane splitting + per-plane delta +
zstd (the actual FPZIP predictive coder is patented/external; byte-plane
splitting captures most of its advantage on smooth fields and is
labelled accordingly everywhere it is reported).
"""
from __future__ import annotations

import time
import zlib

import numpy as np

from ..core import encode as _enc


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def gzip_compress(u, v, **kw):
    raw = np.ascontiguousarray(u).tobytes() + np.ascontiguousarray(v).tobytes()
    blob, tc = _timed(lambda: zlib.compress(raw, 6))
    dec, td = _timed(lambda: zlib.decompress(blob))
    assert dec == raw
    n = len(raw)
    return {
        "name": "gzip", "lossless": True,
        "orig_bytes": n, "comp_bytes": len(blob),
        "ratio": n / len(blob), "t_compress": tc, "t_decompress": td,
        "u_rec": u, "v_rec": v,
    }


def zstd_compress(u, v, level=12, **kw):
    raw = np.ascontiguousarray(u).tobytes() + np.ascontiguousarray(v).tobytes()
    blob, tc = _timed(lambda: _enc.codec_compress(raw, level))
    codec = _enc.backend_codec()
    dec, td = _timed(lambda: _enc.codec_decompress(blob, codec))
    assert dec == raw
    n = len(raw)
    return {
        "name": codec, "lossless": True,
        "orig_bytes": n, "comp_bytes": len(blob),
        "ratio": n / len(blob), "t_compress": tc, "t_decompress": td,
        "u_rec": u, "v_rec": v,
    }


def _byteplane(arr: np.ndarray) -> bytes:
    """Byte-plane split + per-plane delta (fpzip-flavoured decorrelation)."""
    b = np.ascontiguousarray(arr).view(np.uint8).reshape(-1, arr.dtype.itemsize)
    planes = [np.diff(b[:, i].astype(np.int16), prepend=np.int16(0)).astype(np.int8)
              for i in range(arr.dtype.itemsize)]
    return np.concatenate(planes).tobytes()


def _unbyteplane(raw: bytes, shape, dtype) -> np.ndarray:
    n = int(np.prod(shape))
    item = np.dtype(dtype).itemsize
    planes = np.frombuffer(raw, np.int8).reshape(item, n)
    b = np.empty((n, item), np.uint8)
    for i in range(item):
        b[:, i] = np.cumsum(planes[i].astype(np.int16)).astype(np.uint8)
    return b.reshape(-1).view(dtype)[:n].reshape(shape)


def fpzip_like(u, v, level=12, **kw):
    raw_u = _byteplane(u)
    raw_v = _byteplane(v)
    blob, tc = _timed(lambda: (_enc.codec_compress(raw_u, level),
                               _enc.codec_compress(raw_v, level)))
    codec = _enc.backend_codec()

    def dec():
        ur = _unbyteplane(_enc.codec_decompress(blob[0], codec), u.shape, u.dtype)
        vr = _unbyteplane(_enc.codec_decompress(blob[1], codec), v.shape, v.dtype)
        return ur, vr

    (ur, vr), td = _timed(dec)
    assert (ur == u).all() and (vr == v).all()
    n = u.nbytes + v.nbytes
    total = len(blob[0]) + len(blob[1])
    return {
        "name": "fpzip-like", "lossless": True,
        "orig_bytes": n, "comp_bytes": total,
        "ratio": n / total, "t_compress": tc, "t_decompress": td,
        "u_rec": u, "v_rec": v,
    }
