"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` visits every computation once: a
``lax.scan`` over 64 layers is costed as ONE layer (verified
empirically; XLA's HloCostAnalysis does not multiply while bodies by
their trip count).  For a scanned-layer transformer that undercounts
FLOPs by orders of magnitude, which would poison the roofline.

XLA's optimized HLO, however, annotates every bounded loop with
``backend_config={"known_trip_count":{"n":"64"}}``.  This module parses
the per-device optimized module text and aggregates, weighting every
computation by the product of trip counts on its call path:

  * FLOPs    -- dot ops: 2 * |result| * contracted-dim product (batch and
                free dims are in |result|); elementwise flops approximated
                as 1/element of fusion outputs (transformers are
                dot-dominated; softmax/norm contribute O(1%)).
  * HBM bytes -- sum of operand+result sizes of *top-level* ops in each
                computation (fusion internals live in registers/VMEM,
                matching HloCostAnalysis's fusion treatment).
  * collective bytes -- operand sizes of all-reduce / all-gather /
                reduce-scatter / all-to-all / collective-permute ops,
                trip-weighted.

Everything is computed on the PER-DEVICE partitioned module, so results
are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "f8e4m3fn": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*[^{]+\{\s*$"
)
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_op_line(line):
    """Procedural parse of '%name = TYPE opcode(...)rest' -- regexes fail
    on tuple types containing '/*index=5*/' comments."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":          # tuple type: scan to match
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i : j + 1]
        i = j + 1
    else:                                  # array type token
        t = re.match(r"[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?", line[i:])
        if not t:
            return None
        type_str = t.group(0)
        i += t.end()
    o = re.match(r"\s*([\w\-]+)\(", line[i:])
    if not o:
        return None
    opcode = o.group(1)
    rest = line[i + o.end():]
    return name, type_str, opcode, rest
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_SINGLE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)")
_CALLED_LIST = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_names(rest: str):
    names = list(_CALLED_SINGLE.findall(rest))
    for grp in _CALLED_LIST.findall(rest):
        names += [n.strip().lstrip("%") for n in grp.split(",") if n.strip()]
    return names

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "bitcast-convert", "iota",
}

# Pure elementwise ops fuse into their consumers on TPU; the CPU backend
# leaves many of them unfused, which would wildly inflate the HBM-bytes
# estimate.  We simulate TPU fusion by not charging bytes for top-level
# elementwise ops (their large inputs are dot/fusion results, which are
# charged where produced).  They still contribute 1 flop/element.
# Non-dot structured-op flop weights (per element *touched*, see
# _nondot_charge for which operand that is).  The compression pipeline's
# entropy stage is built from exactly these shapes -- symbol gather/
# scatter routing, histogram-style reduces for the code-table build,
# prefix-sum (reduce-window / cumulative) passes for the bit-pack -- and
# the dot-dominated approximation above prices them all at 1 flop/elem,
# which misprices the stage by an order of magnitude.  The raw
# (dot-dominated) total stays in ``HloCost.flops``; the reweighted total
# is recorded separately as ``flops_adjusted`` with a per-opcode
# breakdown, mirroring how the stock cost_analysis numbers are kept as
# reference alongside the trip-count-aware walk.
NONDOT_FLOP_WEIGHTS = {
    "gather": 4.0,              # address compute + clamp per gathered elem
    "scatter": 6.0,             # address + combine per update elem
    "dynamic-slice": 2.0,
    "dynamic-update-slice": 2.0,
    "reduce": 2.0,              # histogram/sum trees: combine + route
    "reduce-window": 8.0,       # prefix-sum style windowed passes
    "select-and-scatter": 8.0,
    "sort": 16.0,               # ~log2(n) compare-exchange passes
}

_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "power", "exponential", "log", "tanh", "rsqrt", "sqrt", "negate",
    "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "convert", "compare", "select", "and", "or",
    "xor", "not", "clamp", "broadcast", "reshape", "exponential-minus-one",
    "log-plus-one", "logistic", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "atan2", "remainder",
}


def _elem_count(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            total += _elem_count(dims) * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(type_str):
        if m.group(1) in _DTYPE_BYTES:
            total += _elem_count(m.group(2))
    return total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # text after the opening paren (operands + attrs)
    operands: List[str]


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    is_entry: bool


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h and line.rstrip().endswith("{"):
            cur = _Computation(h.group(2), [], bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed and cur is not None:
            name, type_str, opcode, rest = parsed
            # operand names: %refs inside the top-level parens
            depth = 1
            arg_text = []
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                arg_text.append(ch)
            args = "".join(arg_text)
            operands = re.findall(r"%([\w.\-]+)", args)
            cur.ops.append(_Op(name, type_str, opcode, rest, operands))
    return comps


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    result_elems = shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m or not op.operands:
        return 2.0 * result_elems  # degenerate
    lhs_type = shapes.get(op.operands[0], "")
    tok = _SHAPE_TOKEN.search(lhs_type)
    if not tok:
        return 2.0 * result_elems
    lhs_dims = [int(d) for d in tok.group(2).split(",") if d]
    contracted = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            contracted *= lhs_dims[idx]
    return 2.0 * result_elems * contracted


_PARAM_IDX = re.compile(r"^\s*(\d+)\s*\)")


def _fusion_windowed_discount(op, comps, shapes):
    """Bytes to subtract from a fusion's operand charge: operands that
    the fused computation only reads through a dynamic-slice window
    (classic scan-xs access) are charged the window, not the buffer."""
    discount = 0
    for callee in _called_names(op.rest):
        comp = comps.get(callee)
        if comp is None:
            continue
        # parameter name -> fusion operand index
        param_idx = {}
        for o in comp.ops:
            if o.opcode == "parameter":
                m = _PARAM_IDX.search(o.rest)
                if m:
                    param_idx[o.name] = int(m.group(1))
        sliced_params = set()
        window = {}
        for o in comp.ops:
            if o.opcode == "dynamic-slice" and o.operands:
                src = o.operands[0]
                if src in param_idx:
                    sliced_params.add(src)
                    window[src] = shape_bytes(o.type_str)
        # a parameter read ONLY via dynamic-slice gets the discount
        for o in comp.ops:
            if o.opcode in ("dynamic-slice", "parameter"):
                continue
            for src in list(sliced_params):
                if src in o.operands:
                    sliced_params.discard(src)
        for src in sliced_params:
            idx = param_idx[src]
            if idx < len(op.operands):
                full = shape_bytes(shapes.get(op.operands[idx], ""))
                discount += max(full - 2 * window.get(src, 0), 0)
    return discount


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0           # raw: dot/conv + 1-flop/elem elementwise
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)
    loop_info: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    # non-dot structured-op charges (NONDOT_FLOP_WEIGHTS), trip-weighted:
    # full per-opcode charge, and the raw total with those ops re-priced
    nondot_flops: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_adjusted: float = 0.0


def _nondot_charge(op: _Op, shapes: Dict[str, str]) -> float:
    """Elements a structured non-dot op actually touches: reductions and
    windowed passes are priced on their *input* (a histogram over 1M
    elements producing 256 bins does 1M combines, not 256), scatter on
    its update operand, gather/slice on the gathered window."""
    oc = op.opcode
    if oc in ("reduce", "reduce-window", "select-and-scatter", "sort"):
        n = shape_elems(shapes.get(op.operands[0], "")) if op.operands else 0
        return float(n or shape_elems(op.type_str))
    if oc == "scatter" and len(op.operands) > 1:
        n = shape_elems(shapes.get(op.operands[1], ""))
        return float(n or shape_elems(op.type_str))
    return float(shape_elems(op.type_str))


def analyze_text(text: str) -> HloCost:
    comps = _split_computations(text)
    # global symbol table (names are unique module-wide in HLO dumps)
    shapes: Dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            shapes[op.name] = op.type_str

    cost = HloCost()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return cost
    adjust = [0.0]      # extra flops from re-priced non-dot ops

    def visit(comp: _Computation, mult: float, in_fusion: bool):
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                cost.flops += mult * _dot_flops(op, shapes)
            elif oc == "convolution":
                # spatial conv: 2 * |out| * (in_ch * kernel_elems)
                cost.flops += mult * 2.0 * shape_elems(op.type_str) * 64
            elif oc not in _SKIP_BYTES_OPS and not in_fusion:
                # elementwise estimate: 1 flop per output element
                cost.flops += mult * shape_elems(op.type_str)

            if oc in NONDOT_FLOP_WEIGHTS:
                # re-priced charge recorded alongside the raw estimate
                # (which billed 1 flop/output-elem at top level, 0 in
                # fusions); the raw ``flops`` total is left untouched
                full = NONDOT_FLOP_WEIGHTS[oc] * _nondot_charge(op, shapes)
                naive = 0.0 if in_fusion or oc in _SKIP_BYTES_OPS \
                    else float(shape_elems(op.type_str))
                cost.nondot_flops[oc] = (
                    cost.nondot_flops.get(oc, 0.0) + mult * full)
                adjust[0] += mult * max(full - naive, 0.0)

            base = oc.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute",
                        "ragged-all-to-all") and not oc.endswith("-done"):
                b = sum(shape_bytes(shapes.get(o, "")) for o in op.operands)
                if b == 0:
                    b = shape_bytes(op.type_str)
                cost.collective_bytes += mult * b
                cost.coll_breakdown[base] = (
                    cost.coll_breakdown.get(base, 0.0) + mult * b
                )

            if (not in_fusion and oc not in _SKIP_BYTES_OPS
                    and oc not in _ELEMENTWISE_OPS):
                if oc in ("dynamic-slice", "gather"):
                    # reads only the sliced window, not the full operand
                    # (charging the operand would bill scans for the whole
                    # stacked xs buffer on every iteration)
                    b = 2 * shape_bytes(op.type_str)
                elif oc in ("dynamic-update-slice", "scatter"):
                    # writes only the update window (operand 1)
                    upd = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
                    b = 2 * shape_bytes(upd) if upd else shape_bytes(op.type_str)
                else:
                    b = shape_bytes(op.type_str) + sum(
                        shape_bytes(shapes.get(o, "")) for o in op.operands
                    )
                    # fusions rooted in (dynamic-)update-slice write/read
                    # only the window; the full aliased buffer appears as
                    # both an operand and the result -- back both out.
                    if oc == "fusion" and "dynamic-update-slice" in op.name:
                        b = max(b - 2 * shape_bytes(op.type_str), 0)
                    elif oc == "fusion":
                        # operands consumed inside the fused computation
                        # through a dynamic-slice are windowed reads
                        # (scan xs): charge the window, not the buffer.
                        b -= _fusion_windowed_discount(op, comps, shapes)
                        b = max(b, 0)
                cost.bytes += mult * b

            # recurse into called computations
            if oc == "while":
                t = _TRIP.search(op.rest)
                trip = int(t.group(1)) if t else 1
                cost.loop_info.append((op.name, trip))
                for n in _called_names(op.rest):
                    if n in comps:
                        visit(comps[n], mult * trip, in_fusion)
            elif oc == "fusion":
                for n in _called_names(op.rest):
                    if n in comps:
                        visit(comps[n], mult, True)
            elif oc in ("call", "conditional", "custom-call"):
                for n in _called_names(op.rest):
                    if n in comps:
                        visit(comps[n], mult, in_fusion)
            # reduce/sort/map comparators: skipped (negligible)

    visit(entry, 1.0, False)
    cost.flops_adjusted = cost.flops + adjust[0]
    return cost
