"""rwkv6-3b [ssm] 32L d=2560 (attn-free) ff=8960 v=65536 -- Finch,
data-dependent decay.

[arXiv:2404.05892; hf]
long_500k runs natively: decode is an O(1) recurrence on a
(L, B, H, 64, 64) state; no KV cache exists.
"""
from repro.configs import standard_cells
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=8960, vocab=65536, rwkv_head_dim=64,
    scan_chunk=32,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=128, vocab=512, rwkv_head_dim=32,
    scan_chunk=8,
)

CELLS = standard_cells(train_mb=4, long_ok=True)
