"""qwen1.5-0.5b [dense] 24L d=1024 16H (kv=16) ff=2816 v=151936, QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen0.5-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=96, vocab=512, qkv_bias=True,
    tie_embeddings=True, attn_chunk=16,
)

CELLS = standard_cells(train_mb=1)
