"""whisper-small [audio] 12+12L d=768 12H (kv=12) ff=3072 v=51865 --
enc-dec, conv frontend stubbed (input_specs feeds frame embeddings).

[arXiv:2212.04356; unverified]
Cell semantics: seq_len applies to the *encoder* (audio frames); the
decoder prompt is 448 tokens (Whisper's max).  decode_32k = one decoder
step against 32k cross-attention memory.  long_500k skipped (full
attention).
"""
from repro.configs import CellSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
    is_encoder_decoder=True, n_enc_layers=12, mlp="gelu",
    norm="layernorm", pos="sinusoidal",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    is_encoder_decoder=True, n_enc_layers=2, mlp="gelu",
    norm="layernorm", pos="sinusoidal", attn_chunk=16,
)

CELLS = {
    "train_4k": CellSpec("train", 4096, 256, microbatches=2, dec_len=448),
    "prefill_32k": CellSpec("prefill", 32768, 32, dec_len=448),
    "decode_32k": CellSpec("decode", 32768, 128, cache_len=448,
                           enc_len=32768),
    "long_500k": CellSpec(
        "decode", 524288, 1, cache_len=448, enc_len=524288,
        skip="full quadratic attention arch: 500k decode excluded per "
             "assignment (sub-quadratic archs only)",
    ),
}
