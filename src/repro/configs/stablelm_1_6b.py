"""stablelm-1.6b [dense] 24L d=2048 32H (kv=32) ff=5632 v=100352.

[hf:stabilityai/stablelm-2-1_6b; unverified]
Simplifications vs HF: full-dim RoPE (upstream uses 25% partial rotary)
and RMSNorm (upstream LayerNorm) -- noted in DESIGN.md.
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=160, vocab=512, attn_chunk=16,
)

CELLS = standard_cells(train_mb=2)
