"""llama4-scout-17b-a16e [moe] 48L d=5120 40H (kv=8) ff=8192 v=202048,
MoE 16e top-1 + 1 shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Early-fusion vision frontend is irrelevant to the text cells (stub);
iRoPE interleaving simplified to uniform RoPE (DESIGN.md).
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, n_shared_experts=1, d_ff_expert=8192,
    rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, top_k=1, n_shared_experts=1, d_ff_expert=128,
    attn_chunk=16,
)

CELLS = standard_cells(train_mb=16)
