"""qwen1.5-32b [dense] 64L d=5120 40H (kv=40) ff=27392 v=152064, QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]
decode_32k uses the int8 KV cache (5.5 TB of bf16 KV does not fit 256
chips; int8 + per-use dequant does -- DESIGN.md #6).  40 heads do not
divide the 16-way model axis; GSPMD pads (40 -> 48) -- accounted in the
roofline notes.
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064, qkv_bias=True,
    rope_theta=1e6, decode_head_pad=48,
)

SMOKE = ModelConfig(
    name="qwen32-smoke", family="dense", n_layers=2, d_model=80,
    n_heads=5, n_kv_heads=5, d_ff=224, vocab=512, qkv_bias=True,
    attn_chunk=16,
)

CELLS = standard_cells(train_mb=16, decode_kv_dtype="int8")
