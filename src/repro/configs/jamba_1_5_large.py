"""jamba-1.5-large-398b [hybrid] 72L d=8192 64H (kv=8) ff=24576 v=65536,
MoE 16e top-2, Mamba:attn 7:1 interleave.

[arXiv:2403.19887; hf]
Memory plan: bf16 params + bf16 Adam moments (6 B/param -> 9.3 GB/chip on
256 chips); microbatch 16 keeps layer-boundary activations < 5 GB.
long_500k runs with the sequence-sharded KV cache for the 9 attention
layers + O(1) Mamba states.
"""
from repro.configs import CellSpec, standard_cells
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_every=2, attn_every=8,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, top_k=2, moe_every=2, attn_every=4,
    mamba_d_state=4, mamba_d_conv=2, mamba_expand=2,
    scan_chunk=8, attn_chunk=16,
)

CELLS = standard_cells(train_mb=16, long_ok=True)
