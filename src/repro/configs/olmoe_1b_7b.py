"""olmoe-1b-7b [moe] 16L d=2048 16H (kv=16) ff=1024 v=50304,
MoE 64e top-8.

[arXiv:2409.02060; hf]
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, d_ff_expert=1024, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=64, vocab=512,
    n_experts=8, top_k=2, d_ff_expert=64, attn_chunk=16,
)

CELLS = standard_cells(train_mb=2)
