"""yi-6b [dense] 32L d=4096 32H (GQA kv=4) ff=11008 v=64000.

[arXiv:2403.04652; hf] llama-arch GQA.
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab=64000, rope_theta=5e6,
)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=8, n_kv_heads=2, d_ff=160, vocab=512, attn_chunk=16,
)

CELLS = standard_cells(train_mb=4)
