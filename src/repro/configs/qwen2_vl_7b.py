"""qwen2-vl-7b [vlm] 28L d=3584 28H (GQA kv=4) ff=18944 v=152064 --
M-RoPE, dynamic resolution (patch frontend stubbed: input_specs provides
precomputed patch/text embeddings + 3-stream position ids).

[arXiv:2409.12191; hf]
"""
from repro.models.config import ModelConfig
from repro.configs import standard_cells

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True,
    pos="mrope", mrope_sections=(16, 24, 24), embedding_inputs=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, qkv_bias=True,
    pos="mrope", mrope_sections=(4, 2, 2), embedding_inputs=True,
    attn_chunk=16,
)

CELLS = standard_cells(train_mb=8)
