"""Assigned-architecture registry and (arch x shape) cell definitions.

Every architecture module exposes:
  CONFIG  -- the exact published configuration
  SMOKE   -- a reduced same-family config for CPU tests
  CELLS   -- shape-name -> CellSpec (or a skip reason)

``input_specs(cfg, cell)`` builds ShapeDtypeStruct stand-ins for every
model input of a cell -- weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

SHAPE_TABLE = {
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128),
    "long_500k": (524288, 1),
}

ARCHS = [
    "stablelm_1_6b",
    "qwen1_5_0_5b",
    "yi_6b",
    "qwen1_5_32b",
    "jamba_1_5_large",
    "llama4_scout_17b_16e",
    "olmoe_1b_7b",
    "rwkv6_3b",
    "whisper_small",
    "qwen2_vl_7b",
]


@dataclasses.dataclass(frozen=True)
class CellSpec:
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1
    cache_len: int = 0             # decode: prefilled KV length
    kv_dtype: str = "bfloat16"     # decode KV cache dtype (int8 for 32B)
    seq_sharded_cache: bool = False
    enc_len: int = 0               # enc-dec: encoder length
    dec_len: int = 448             # enc-dec: decoder token length
    skip: str = ""                 # non-empty -> cell skipped, with reason


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: CellSpec) -> dict:
    """ShapeDtypeStruct batch for a cell (cache built separately)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cfg.is_encoder_decoder:
        if cell.kind == "train":
            return {
                "frames": _sds((B, S, cfg.d_model), jnp.float32),
                "tokens": _sds((B, cell.dec_len), i32),
                "labels": _sds((B, cell.dec_len), i32),
            }
        if cell.kind == "prefill":
            return {
                "frames": _sds((B, S, cfg.d_model), jnp.float32),
                "tokens": _sds((B, cell.dec_len), i32),
            }
        return {"tokens": _sds((B, 1), i32)}
    if cfg.embedding_inputs:
        if cell.kind == "train":
            return {
                "embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "position_ids": _sds((3, B, S), i32),
                "labels": _sds((B, S), i32),
            }
        if cell.kind == "prefill":
            return {
                "embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "position_ids": _sds((3, B, S), i32),
            }
        return {"embeds": _sds((B, 1, cfg.d_model), jnp.bfloat16)}
    if cell.kind == "train":
        return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
    if cell.kind == "prefill":
        return {"tokens": _sds((B, S), i32)}
    return {"tokens": _sds((B, 1), i32)}


def standard_cells(
    train_mb: int,
    *,
    long_ok: bool = False,
    decode_kv_dtype: str = "bfloat16",
    prefill_skip: str = "",
) -> Dict[str, CellSpec]:
    """The default 4-cell table for decoder LMs."""
    s = SHAPE_TABLE
    cells = {
        "train_4k": CellSpec("train", *s["train_4k"], microbatches=train_mb),
        "prefill_32k": CellSpec("prefill", *s["prefill_32k"], skip=prefill_skip),
        "decode_32k": CellSpec(
            "decode", 32768, s["decode_32k"][1], cache_len=32768,
            kv_dtype=decode_kv_dtype,
        ),
    }
    if long_ok:
        cells["long_500k"] = CellSpec(
            "decode", 524288, 1, cache_len=524288, seq_sharded_cache=True
        )
    else:
        cells["long_500k"] = CellSpec(
            "decode", 524288, 1, cache_len=524288,
            skip="full quadratic attention arch: 500k decode excluded per "
                 "assignment (sub-quadratic archs only)",
        )
    return cells


_loaded: Dict[str, object] = {}


def get(name: str):
    key = name.replace("-", "_").replace(".", "_")
    if key not in _loaded:
        _loaded[key] = importlib.import_module(f"repro.configs.{key}")
    return _loaded[key]


def all_archs():
    return [get(a) for a in ARCHS]
