"""Per-unit adaptive base error bounds (EbPolicy; DESIGN.md #16).

The base error bound used to be one global scalar (``cfg.eb``).  An
*EbPolicy* generalizes it to a per-(window, tile) field over the
policy's OWN grid -- deliberately independent of the execution tiling
-- resolved into per-vertex base-bound planes before the derive stage:

* the per-vertex base bound is the MIN over policy units whose
  one-cell / one-frame inflated owned box covers the vertex -- the same
  min-reduction rule the tiled eb derivation applies on halo seams
  (PR 2), so every engine (monolithic, tiled, streaming serial/async,
  resumed) resolves the identical field from the policy alone;
* the global plan parameters (tau, xi_unit, scale) derive from the
  policy's MAXIMUM bound: adaptivity only ever clamps per-vertex bounds
  DOWN, which keeps the quantization grid global and the decode path
  byte-for-byte unchanged (a bound below xi_unit simply forces the
  vertex lossless);
* correctness (FC = 0) is policy-independent: the verify fixpoint
  forces any violating vertex to lossless regardless of the base bound,
  so a policy changes rate, never topology (DESIGN.md #16).

The temporal neighbor rule counts window ``(t + 1) // window_t`` even
when frame ``t + 1`` does not exist, so streaming resolves frame ``t``
without knowing the final T and still matches the in-memory engines
bit-for-bit.

The default (policy ``None`` / :class:`UniformPolicy`) routes through
the exact pre-policy scalar code paths and produces byte-identical
containers -- the refactor is provably behavior-preserving where not
opted in.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np


class DegenerateRangeError(ValueError):
    """``mode="rel"`` on a (near-)constant field: the value range is
    (numerically) zero, so a relative bound would collapse to
    ``cfg.eb * 1e-30`` and explode the quantizer level count."""


# a range this many orders below the value magnitude carries no signal
# a *relative* bound could meaningfully scale to
_REL_RANGE_FLOOR = 1e-12


def check_relative_range(rng: float, max_abs: float) -> float:
    """Validate the value range a ``mode="rel"`` bound scales with.

    Raises :class:`DegenerateRangeError` (a typed ValueError, never an
    assert -- must hold under ``python -O``) when the range is zero or
    vanishes against the value magnitude.  Returns the range.
    """
    if rng <= max_abs * _REL_RANGE_FLOOR:
        raise DegenerateRangeError(
            f"mode='rel' on a (near-)constant field: value range {rng!r} "
            f"vs magnitude {max_abs!r}; a relative error bound is "
            "meaningless here -- use mode='abs' with an explicit bound")
    return rng


@dataclasses.dataclass(frozen=True)
class UniformPolicy:
    """The default policy: one global base bound (``cfg.eb``)
    everywhere.  Compresses through the exact scalar code paths --
    containers are byte-identical to a config with no policy at all."""

    @property
    def is_uniform(self) -> bool:
        return True

    def spec(self):
        return None


@dataclasses.dataclass(frozen=True)
class TilePolicy:
    """Explicit per-(window, tile) base bounds over the policy's own
    grid.

    ``values`` maps policy-unit keys ``(wi, ti, tj)`` to base bounds in
    ``cfg.eb`` units (``cfg.mode`` applies: relative bounds scale with
    the field's value range exactly like the scalar path); units absent
    from ``values`` use ``default``.  The grid here is the POLICY grid
    -- resolution never reads the execution tiling, so the resolved
    per-vertex field (and therefore the container bytes) cannot depend
    on which engine or tile geometry runs the compression.
    """

    window_t: int
    tile_h: int
    tile_w: int
    default: float
    values: tuple = ()          # sorted (((wi, ti, tj), eb), ...)

    @classmethod
    def make(cls, window_t: int, tile_h: int, tile_w: int,
             default: float, values=None) -> "TilePolicy":
        """Normalized construction from a ``{key: eb}`` mapping."""
        items = tuple(sorted(
            (tuple(int(x) for x in k), float(ebv))
            for k, ebv in dict(values or {}).items()))
        pol = cls(window_t=int(window_t), tile_h=int(tile_h),
                  tile_w=int(tile_w), default=float(default),
                  values=items)
        pol.validate()
        return pol

    def validate(self):
        # real raises, not asserts: policy validation must survive -O
        if min(self.window_t, self.tile_h, self.tile_w) < 1:
            raise ValueError(f"policy grid sizes must be >= 1: {self}")
        if not (self.default > 0.0):
            raise ValueError(f"policy default bound must be > 0, got "
                             f"{self.default}")
        for key, ebv in self.values:
            if len(key) != 3 or min(key) < 0:
                raise ValueError(f"policy unit key must be a "
                                 f"(wi, ti, tj) of non-negatives: {key}")
            if not (ebv > 0.0):
                raise ValueError(f"policy bound for {key} must be > 0, "
                                 f"got {ebv}")

    @property
    def is_uniform(self) -> bool:
        # an all-equal TilePolicy is still treated as adaptive: it was
        # explicitly opted into, so it writes the self-describing
        # (versioned) container rather than silently aliasing uniform
        return False

    def spec(self):
        """Canonical msgpack-able identity (plan knob / fingerprint /
        container header form)."""
        return ("tile", int(self.window_t), int(self.tile_h),
                int(self.tile_w), float(self.default),
                tuple((tuple(int(x) for x in k), float(v))
                      for k, v in self.values))


def normalize(policy):
    """Config-level policy -> resolved form: ``None`` for the uniform
    scalar path, a validated :class:`TilePolicy` otherwise."""
    if policy is None or policy == "uniform":
        return None
    if isinstance(policy, UniformPolicy):
        return None
    if isinstance(policy, TilePolicy):
        policy.validate()
        return policy
    if isinstance(policy, (tuple, list)):
        return policy_from_spec(policy)
    raise TypeError(f"eb_policy must be None, 'uniform', UniformPolicy, "
                    f"TilePolicy or a policy spec, got {type(policy)}")


def policy_spec(policy):
    """Canonical spec of a normalized policy (None for uniform)."""
    return None if policy is None else policy.spec()


def policy_from_spec(spec) -> TilePolicy:
    """Inverse of :meth:`TilePolicy.spec` (accepts the msgpack list
    form a container header round-trips through)."""
    if not spec or spec[0] != "tile" or len(spec) != 6:
        raise ValueError(f"unknown eb policy spec: {spec!r}")
    _, wt, th, tw, default, values = spec
    return TilePolicy.make(wt, th, tw, default,
                           {tuple(k): v for k, v in values})


def min_bound(policy: TilePolicy) -> float:
    """The policy's tightest bound (``cfg.eb`` units)."""
    return float(min([policy.default] + [v for _, v in policy.values]))


def levels_for(policy: TilePolicy, n_levels: int = 1) -> int:
    """Quantizer levels covering the policy's dynamic range.

    The ladder's finest grid is ``xi_unit = tau >> (n_levels - 1)``
    with tau derived from the policy's loosest bound; a vertex whose
    bound falls below xi_unit is forced lossless.  For tight units to
    QUANTIZE (at their own finer grid) rather than store raw values,
    the ladder must reach down to the tightest bound:
    ``n_levels >= log2(loosest / tightest) + 1``.  Returns that floor,
    never below the caller's ``n_levels``.
    """
    import math

    span = max_bound(policy) / min_bound(policy)
    return max(int(n_levels), int(math.ceil(math.log2(span))) + 1)


def max_bound(policy: TilePolicy) -> float:
    """The policy's loosest bound (``cfg.eb`` units) -- what the global
    plan (tau, xi_unit) derives from.  The default participates: every
    frame's resolution can reach it through uncovered or
    past-the-stream-end neighbor windows."""
    return float(max([policy.default] + [v for _, v in policy.values]))


@functools.lru_cache(maxsize=32)
def _window_plane(policy: TilePolicy, wi: int, H: int, W: int):
    """(H, W) float64 plane of window ``wi``'s bounds (policy units):
    per-tile values min-reduced over ONE-CELL inflated owned boxes, so
    a vertex on (or next to) a tile seam takes the tighter side --
    exactly the halo min-reduction rule of the tiled eb derivation."""
    vals = dict(policy.values)
    th, tw = policy.tile_h, policy.tile_w
    plane = np.full((H, W), np.inf, np.float64)
    for ti in range(-(-H // th)):
        i0, i1 = ti * th, min(ti * th + th, H)
        for tj in range(-(-W // tw)):
            j0, j1 = tj * tw, min(tj * tw + tw, W)
            v = vals.get((wi, ti, tj), policy.default)
            sl = plane[max(i0 - 1, 0):min(i1 + 1, H),
                       max(j0 - 1, 0):min(j1 + 1, W)]
            np.minimum(sl, v, out=sl)
    plane.setflags(write=False)
    return plane


def frame_bounds(policy: TilePolicy, t: int, H: int, W: int,
                 factor: float) -> np.ndarray:
    """(H, W) float64 ABSOLUTE per-vertex base bounds for frame ``t``.

    Min over the windows owning frames t-1, t, t+1 (one-frame
    inflation; ``(t + 1) // window_t`` counts even past the stream end
    so streaming needs no final-T knowledge), times the mode factor
    (1.0 for abs, the f32-reduced value range for rel).  Scaling by a
    positive scalar commutes with min, so the factor applies once.
    """
    wis = sorted({tt // policy.window_t for tt in (t - 1, t, t + 1)
                  if tt >= 0})
    plane = _window_plane(policy, wis[0], H, W)
    for wi in wis[1:]:
        plane = np.minimum(plane, _window_plane(policy, wi, H, W))
    return plane * float(factor)


def frame_caps(policy: TilePolicy, t: int, H: int, W: int,
               factor: float, scale: float) -> np.ndarray:
    """(H, W) int64 fixed-point caps for frame ``t`` -- the per-vertex
    analogue of the plan's ``tau = floor(eb_abs * scale)``."""
    return np.floor(frame_bounds(policy, t, H, W, factor)
                    * float(scale)).astype(np.int64)


def field_bounds(policy: TilePolicy, shape, factor: float) -> np.ndarray:
    """(T, H, W) float64 absolute base bounds (monolithic resolution)."""
    T, H, W = shape
    return np.stack([frame_bounds(policy, t, H, W, factor)
                     for t in range(T)])


def field_caps(policy: TilePolicy, shape, factor: float,
               scale: float) -> np.ndarray:
    """(T, H, W) int64 caps (monolithic resolution)."""
    T, H, W = shape
    return np.stack([frame_caps(policy, t, H, W, factor, scale)
                     for t in range(T)])
