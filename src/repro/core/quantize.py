"""Error-bound quantization + dual-quantization onto a base integer grid.

TPU adaptation of the paper's (eb-quantize, predict, quantize) stages --
see DESIGN.md #3.1.  The per-vertex bound xi_v (ebound.py) is rounded
*down* onto a power-of-two ladder

    xi_k = xi_unit * 2^k,   k in [0, n_levels),  xi_unit = max(1, tau >> (K-1))

and each fixed-point value is rounded half-away-from-zero to the nearest
multiple of q_k = 2 * xi_k, expressed on the base grid g = 2 * xi_unit:

    X_v = round(d_v / q_k) << k          (integer, multiple of 2^k)
    recon_v = X_v * g,   |recon_v - d_v| <= xi_k <= xi_v

Crucially the decoder never needs k_v: X is self-contained.  The paper's
per-vertex eb code stream Q_xi disappears from the format entirely (a
strict rate improvement), and reconstruction is a single parallel
multiply.  Vertices with xi_v < xi_unit are stored losslessly (mask +
raw values); their X entry carries the k=0 rounding of the original so
that predictors see a well-defined context on both sides.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Ladder depth. The paper uses a multi-level eb quantization (Q_xi); with
# the dual-quantized PARALLEL coder the multi-level ladder expresses
# residuals on the finest grid, inflating symbols at coarse-eb vertices
# (they escape entropy coding entirely). A single level + lossless
# fallback measured strictly better at every tested (dataset, eb):
# e.g. advected turbulence 6.97x -> 41.78x, SCF 7.6x -> 12.8x
# (EXPERIMENTS.md #Perf, iteration C1). The ladder stays available via
# CompressionConfig(n_levels=...).
DEFAULT_LEVELS = 1


def ladder(tau: int, n_levels: int = DEFAULT_LEVELS):
    """Returns (xi_unit, n_usable_levels).  xi_unit >= 1."""
    tau = int(tau)
    if tau < 1:
        return 1, 0
    xi_unit = max(1, tau >> (n_levels - 1))
    # largest k with xi_unit * 2^k <= tau
    kmax = int(np.floor(np.log2(tau / xi_unit))) if tau >= xi_unit else -1
    return xi_unit, kmax + 1


def quantize_eb(eb, xi_unit, n_levels: int):
    """Map per-vertex integer bounds onto the ladder.

    Returns (k (int32, -1 where lossless), lossless mask).  xi_unit may
    be a python int or a traced scalar (the fused pipeline passes it as
    a jit argument so eb sweeps reuse one compiled round).
    """
    eb = jnp.asarray(eb)
    xi = jnp.asarray(xi_unit, jnp.int64)
    lossless = eb < xi
    ratio = (jnp.maximum(eb, xi).astype(jnp.float64)
             / xi.astype(jnp.float64))
    k = jnp.floor(jnp.log2(ratio)).astype(jnp.int32)
    k = jnp.clip(k, 0, max(n_levels - 1, 0))
    k = jnp.where(lossless, -1, k)
    return k, lossless


def round_half_away_div(d, q):
    """sign(d) * ((|d| + q//2) // q) for int64 d, even int64 q."""
    mag = (jnp.abs(d) + (q >> 1)) // q
    return jnp.sign(d) * mag


def dual_quantize(dfp, k, lossless, xi_unit):
    """Round fixed-point values to the base grid with per-vertex granularity.

    dfp: int64; k: int32 (>=0 where coded); lossless: bool.
    Returns X int64 with recon = X * g, g = 2 * xi_unit.
    """
    g = 2 * jnp.asarray(xi_unit, jnp.int64)
    kk = jnp.maximum(k, 0).astype(jnp.int64)
    q = g << kk
    x = round_half_away_div(dfp, q) << kk
    x0 = round_half_away_div(dfp, g)  # k = 0 rounding for lossless context
    return jnp.where(lossless, x0, x)


def recon_fixed(x, xi_unit):
    return x * (2 * jnp.asarray(xi_unit, jnp.int64))
