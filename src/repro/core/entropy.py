"""Device-resident batched entropy stage (the ``device`` codec).

The host codec (encode.py) symbolizes and Huffman-packs residual
streams one unit at a time on the CPU, which leaves every upstream
device win stranded behind a host loop.  This module keeps the entropy
stage on the accelerator for a whole batch of same-shape units at once:

  1. **symbolize** (device): zigzag-fold the int64 residual rows of a
     (B, n) stack, clamp to the ESC escape symbol, count a per-row
     256-bin histogram (``backend.symbol_histogram`` -- pallas kernel
     on TPU), and compact the escaped residuals with an exclusive
     cumulative-sum scatter so each row's escapes are contiguous.
  2. **code build** (host, tiny): per-row canonical code tables from
     the device histograms, length-limited to ``L_MAX`` bits and built
     for the whole batch in one vectorized pass
     (``build_tables_batch``) -- 256 counts per row is the only data
     that crosses to the host before packing.
  3. **bitpack** (device): gather per-symbol (code, length), compute
     every symbol's bit offset with a parallel prefix sum, and
     scatter-add the MSB-first code windows into a byte buffer in 3
     collision-free lane passes -- the same packing arithmetic as
     ``encode.huffman_encode``, vmapped over rows.

Per-row tables make each unit's bitstream independent of the batch it
rode in, so batched and sequential encodes stay byte-identical -- the
repo-wide invariant.  Decode needs no device: ``pack`` stores the
length table in the section index (encode.HuffSection) and
``decode_symbols`` replays the stream through the existing host
``huffman_decode``; ``L_MAX`` = 16 <= the decoder's vectorized-peek
limit, and the worst-case pack buffer is a static 2 bytes/symbol.

The numpy rows of ``EntropyFns`` mirror the jax math operation for
operation (integer-exact), so the numpy backend produces bit-identical
containers -- tests/test_entropy_device.py pins all of this.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import backend as backend_mod
from . import encode as encode_mod
from .encode import ESC, ContainerError, HuffSection

L_MAX = 16           # length limit for device tables (static buffer bound)


# ----------------------------------------------------------------------
# host side: table build + decode
# ----------------------------------------------------------------------

def build_tables(hist) -> tuple[np.ndarray, np.ndarray]:
    """256-bin counts -> (lengths int32[256], codes uint32[256]).

    Per-row *optimal* (heap-built, length-limited) Huffman tables --
    the reference construction, kept for single-stream callers and the
    parity tests.  The batched stage uses ``build_tables_batch``."""
    lengths = encode_mod.length_limited_lengths(
        np.asarray(hist, np.int64), L_MAX)
    codes, _ = encode_mod.canonical_codes(lengths)
    return lengths.astype(np.int32), codes.astype(np.uint32)


def build_tables_batch(hist) -> tuple[np.ndarray, np.ndarray]:
    """(R, 256) counts -> (lengths int32 (R, 256), codes uint32 (R, 256)).

    Canonical code construction for a whole batch of rows at once.  A
    per-row heap-built Huffman tree is a Python loop per unit -- the
    exact host-loop shape the batched stage exists to remove -- so batch
    rows use Shannon-style lengths, ``ceil(log2(n/count))`` clamped to
    ``[1, L_MAX]``: Kraft-valid by construction (each 2^-len <= p, so
    the row sums to <= 1), within one bit per symbol of optimal, and
    decoded by the exact same canonical machinery (the code words are
    ``canonical_codes(lengths)``, vectorized over rows).  A row whose
    clamp breaks Kraft (> 2^L_MAX-fold skew) falls back to flat 8-bit
    codes.  Each row's table depends only on that row's counts, which
    keeps batched == sequential bytes."""
    hist = np.asarray(hist, np.int64)
    R = hist.shape[0]
    present = hist > 0
    n = hist.sum(axis=1, keepdims=True)
    with np.errstate(divide="ignore"):
        ln = np.ceil(np.log2(np.maximum(n, 1)
                             / np.maximum(hist, 1))).astype(np.int32)
    ln = np.where(present, np.clip(ln, 1, L_MAX), 0)
    kraft = np.where(present, np.int64(1) << (L_MAX - ln), 0).sum(axis=1)
    bad = kraft > (np.int64(1) << L_MAX)
    if bad.any():
        ln[bad] = np.where(present[bad], 8, 0)
    # canonical assignment (same convention as encode.canonical_codes):
    # first code of length l = (first of l-1 + count of l-1) << 1, and
    # same-length symbols take codes in symbol order
    onehot = ln[:, :, None] == np.arange(1, L_MAX + 1, dtype=np.int32)
    # one narrow cumsum serves both the per-length counts (last slice)
    # and the within-length ranks; int16 holds <= 256 and halves the
    # pass cost vs the default int64 promotion
    csum = np.cumsum(onehot, axis=1, dtype=np.int16)     # (R, 256, L_MAX)
    cnt = csum[:, -1, :].astype(np.int64)                # (R, L_MAX)
    first = np.zeros((R, L_MAX + 1), np.int64)           # first[l] for len l
    for l in range(2, L_MAX + 1):
        first[:, l] = (first[:, l - 1] + cnt[:, l - 2]) << 1
    rank_s = np.take_along_axis(
        csum - 1, np.maximum(ln - 1, 0)[:, :, None], axis=2)[:, :, 0]
    codes = np.take_along_axis(first, ln.astype(np.int64), axis=1) + rank_s
    codes = np.where(present, codes, 0)
    return ln, codes.astype(np.uint32)


def decode_symbols(lengths, data, n) -> np.ndarray:
    """Inverse of the device bitpack: lengths uint8[256] (from the
    section index) + packed bits -> uint8 symbols.  Host-only; used by
    ``encode._decode_section`` for ``enc: "huff"`` sections."""
    if n == 0:
        return np.empty(0, dtype=np.uint8)
    ln = np.asarray(lengths, np.uint8).astype(np.int32)
    ml = int(ln.max())
    if ml == 0 or ml > L_MAX:
        raise ContainerError(
            f"invalid huffman table: max code length {ml} "
            f"(expected 1..{L_MAX})")
    # Kraft inequality: a corrupt table would overflow the peek tables
    kraft = int((np.int64(1) << (ml - ln[ln > 0])).sum())
    if kraft > (1 << ml):
        raise ContainerError("invalid huffman table: Kraft sum exceeds 1")
    return encode_mod.huffman_decode(ln, data, n)


# ----------------------------------------------------------------------
# device side: symbolize + bitpack (jax) and their numpy mirrors
# ----------------------------------------------------------------------

def _pack_cap(n: int) -> int:
    # worst-case packed bytes per row, plus the 8-byte scatter skirt
    return (n * L_MAX) // 8 + 8


def _symbolize_core(res, backend):
    """(B, n) int64 residuals -> (sym uint8 (B, n), hist int32 (B, 256),
    escbuf int64 (B, n) escape-compacted rows, n_esc int32 (B,))."""
    n = res.shape[1]
    z = jnp.where(res >= 0, 2 * res, -2 * res - 1)
    esc = z >= ESC
    sym = jnp.where(esc, ESC, z).astype(jnp.uint8)
    hist = backend_mod.symbol_histogram(sym, backend)
    # exclusive-cumsum compaction: escape i of a row lands at slot
    # (number of escapes before it); non-escapes are parked on a dump
    # slot past the row end and sliced away
    idx = jnp.cumsum(esc.astype(jnp.int32), axis=1) - 1
    scat = jnp.where(esc, idx, n)

    def compact(s, r):
        return jnp.zeros((n + 1,), jnp.int64).at[s].set(r)

    escbuf = jax.vmap(compact)(scat, res)
    return sym, hist, escbuf[:, :n], esc.sum(axis=1).astype(jnp.int32)


def _bitpack_core(sym, lengths, codes):
    """(B, n) uint8 symbols + per-row tables -> (buf uint8 (B, cap),
    nbits int64 (B,)).  Same arithmetic as encode.huffman_encode: each
    symbol's canonical code is placed in a 64-bit MSB-first window at
    its prefix-summed bit offset and scattered byte-wise per lane.
    With L_MAX + 7 <= 23 the code occupies bits 41..63 of the window,
    so only the top 3 big-endian byte lanes can be nonzero -- 3 scatter
    passes instead of encode.huffman_encode's 8 (whose codes run to 56
    bits)."""
    n = sym.shape[1]
    cap = _pack_cap(n)
    s = sym.astype(jnp.int32)
    ln = jnp.take_along_axis(lengths, s, axis=1)
    cd = jnp.take_along_axis(codes, s, axis=1).astype(jnp.uint64)
    ends = jnp.cumsum(ln, axis=1)
    starts = ends - ln
    byte_off = starts // 8
    # clip only guards padding rows whose borrowed table may assign
    # length 0; live rows always have 41 <= shift <= 63
    shift = jnp.clip(64 - (starts % 8) - ln, 0, 63).astype(jnp.uint64)
    val = cd << shift

    def pack_row(bo, v):
        buf = jnp.zeros((cap,), jnp.uint8)
        # lanes 3..7 are zero for any live row (shift >= 41); padding
        # rows may put garbage in low bits, but their buffers are
        # sliced away after the fetch, so skipping the lanes is exact
        for b in range(3):
            lane = ((v >> jnp.uint64(56 - 8 * b))
                    & jnp.uint64(0xFF)).astype(jnp.uint8)
            buf = buf.at[bo + b].add(lane)
        return buf

    return jax.vmap(pack_row)(byte_off, val), ends[:, -1].astype(jnp.int64)


def _symbolize_np(res):
    res = np.asarray(res, np.int64)
    z = np.where(res >= 0, 2 * res, -2 * res - 1)
    esc = z >= ESC
    sym = np.where(esc, ESC, z).astype(np.uint8)
    B, n = sym.shape
    hist = backend_mod.symbol_histogram(sym, "numpy")
    escbuf = np.zeros((B, n), np.int64)
    n_esc = esc.sum(axis=1).astype(np.int32)
    for i in range(B):
        escbuf[i, : n_esc[i]] = res[i][esc[i]]
    return sym, hist, escbuf, n_esc


def _bitpack_np(sym, lengths, codes):
    """Host mirror of ``_bitpack_core``, vectorized flat across rows.

    Uses a 32-bit MSB-first window instead of the core's 64-bit one:
    with L_MAX + 7 <= 23 the code sits in bits 9..31, so the top 3
    big-endian byte lanes carry exactly the bytes the 64-bit window
    puts in its own top 3 lanes -- identical placement, half the
    intermediate bytes.  One ``np.add.at`` per lane over all rows at
    once (rows offset into one flat buffer) instead of a per-row loop.
    """
    B, n = sym.shape
    cap = _pack_cap(n)
    rows = np.arange(B, dtype=np.int64)[:, None]
    # codes are < 2^L_MAX and lengths <= L_MAX = 16, so one uint32 LUT
    # (length in the high half) turns two table gathers into one
    lut = ((lengths.astype(np.uint32) << 16)
           | codes.astype(np.uint32)).reshape(-1)
    g = lut[sym.astype(np.int32) + (rows * 256).astype(np.int32)]
    ln = (g >> 16).astype(np.int64)
    cd = g & np.uint32(0xFFFF)
    ends = np.cumsum(ln, axis=1, dtype=np.int64)
    starts = ends - ln
    shift = (32 - (starts & 7) - ln).astype(np.uint32)
    vals = (cd << shift).astype(">u4")
    view = vals.reshape(-1).view(np.uint8).reshape(B * n, 4)
    flat_off = ((starts >> 3) + rows * cap).reshape(-1)
    out = np.zeros(B * cap, np.uint8)
    for b in range(3):     # lane 3 (bits 0..7) is zero: shift >= 9
        np.add.at(out, flat_off + b, view[:, b])
    return out.reshape(B, cap), ends[:, -1].astype(np.int64)


# ----------------------------------------------------------------------
# per-backend executable registry
# ----------------------------------------------------------------------

class EntropyFns:
    """Persistent symbolize/bitpack executables for one backend.

    jax backends get jitted, shape-polymorphic (retrace-per-shape)
    wrappers; the numpy backend runs the host mirrors directly.  One
    instance per backend lives in the registry so executables survive
    across calls (no per-call recompiles).

    The ``xla`` binding additionally gates on the actual jax platform:
    both hot loops here are scatter-shaped (histogram, escape
    compaction, byte-lane bit packing), and XLA's CPU scatter lowers to
    a serial update loop (~25 M updates/s measured) while the
    vectorized host mirrors run ``np.add.at``/``np.bincount`` at
    ~500 M/s -- a ~20x gap that would invert the whole point of the
    batched stage.  Off-accelerator, ``xla`` therefore routes to the
    mirrors, which are bit-identical by construction (the parity tests
    assert it); on TPU/GPU the jitted path keeps the streams resident.
    ``pallas`` always jits: off-TPU it exists for interpret-mode kernel
    parity, not throughput."""

    def __init__(self, backend: str):
        self.backend = backend
        on_accel = jax.default_backend() != "cpu"
        self.jitted = backend != "numpy" and (on_accel
                                              or backend == "pallas")
        if self.jitted:
            self.symbolize = jax.jit(
                lambda res: _symbolize_core(res, backend))
            self.bitpack = jax.jit(_bitpack_core)
        else:
            self.symbolize = _symbolize_np
            self.bitpack = _bitpack_np


_ENTROPY_FNS: dict[str, EntropyFns] = {}
_REGISTRY_LOCK = threading.Lock()


def entropy_fns(backend: str) -> EntropyFns:
    with _REGISTRY_LOCK:
        ef = _ENTROPY_FNS.get(backend)
        if ef is None:
            obs.counter("pipeline.registry_miss.entropy").add(1)
            ef = _ENTROPY_FNS[backend] = EntropyFns(backend)
        return ef


def clear_registry() -> None:
    with _REGISTRY_LOCK:
        _ENTROPY_FNS.clear()


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


# ----------------------------------------------------------------------
# drivers
# ----------------------------------------------------------------------

def encode_streams(res_u, res_v, backend: str = "xla") -> list[dict]:
    """Batched device entropy encode of (B, ...) residual stacks.

    Stacks the u and v streams as 2B rows through one symbolize and one
    bitpack executable; returns one section fragment per unit:
    ``{"sym_u": HuffSection, "esc_u": int64[...], "sym_v": ...,
    "esc_v": ...}`` -- drop-in for the same keys of
    ``encode.field_sections``.  Tables are per-row, so the fragments
    are independent of B (batched == sequential bytes)."""
    with obs.span("entropy.encode_streams", units=int(res_u.shape[0]),
                  backend=backend):
        # the host fetches below (np.asarray) are the device-sync
        # points: the span closes only after the bitstreams landed
        return _encode_streams(res_u, res_v, backend)


def _encode_streams(res_u, res_v, backend: str = "xla") -> list[dict]:
    B = int(res_u.shape[0])
    n = int(np.prod(res_u.shape[1:], dtype=np.int64))
    live = 2 * B
    ef = entropy_fns(backend)
    if not ef.jitted:
        # host mirrors: no executable cache to protect, so no padding
        rows = np.concatenate([
            np.asarray(res_u, np.int64).reshape(B, n),
            np.asarray(res_v, np.int64).reshape(B, n)])
    else:
        rows = jnp.concatenate([
            jnp.asarray(res_u).reshape(B, n),
            jnp.asarray(res_v).reshape(B, n)]).astype(jnp.int64)
        pad = _next_pow2(live) - live
        if pad:
            # pad the row axis to a power of 2 (bounds the executable
            # count per n); pad rows are discarded after the fetch
            rows = jnp.concatenate([rows, jnp.repeat(rows[-1:], pad, 0)])
    sym, hist, escbuf, n_esc = ef.symbolize(rows)

    # padding rows repeat the last live row, so building their tables
    # is the same arithmetic as repeating the live tables
    lengths, codes = build_tables_batch(np.asarray(hist))
    buf, nbits = ef.bitpack(sym, lengths, codes)

    buf_np = np.asarray(buf[:live])
    nbits_np = np.asarray(nbits[:live])
    n_esc_np = np.asarray(n_esc[:live])
    lengths_u8 = lengths[:live].astype(np.uint8)

    def esc_row(i):
        k = int(n_esc_np[i])
        if k == 0:
            return np.empty(0, dtype=np.int64)
        # device-side slice first: only the escapes cross to the host
        return np.asarray(escbuf[i, :k], dtype=np.int64)

    out = []
    for i in range(B):
        iu, iv = i, B + i
        out.append({
            "sym_u": HuffSection(
                buf_np[iu, : (int(nbits_np[iu]) + 7) // 8].tobytes(),
                lengths_u8[iu], n),
            "sym_v": HuffSection(
                buf_np[iv, : (int(nbits_np[iv]) + 7) // 8].tobytes(),
                lengths_u8[iv], n),
            "esc_u": esc_row(iu),
            "esc_v": esc_row(iv),
        })
    return out


def merge_sections(frag: dict, lossless_np, u_ll, v_ll, bm) -> dict:
    """One unit's entropy fragment + host-side metadata -> the full
    section dict, in ``encode.field_sections`` key order (the order
    fixes the frame's byte layout)."""
    bm = np.asarray(bm)
    return {
        "sym_u": frag["sym_u"],
        "sym_v": frag["sym_v"],
        "esc_u": frag["esc_u"],
        "esc_v": frag["esc_v"],
        "lossless": np.packbits(lossless_np),
        "u_ll": np.asarray(u_ll),
        "v_ll": np.asarray(v_ll),
        "blockmap": np.packbits(bm),
        "bm_shape": np.asarray(bm.shape, dtype=np.int32),
    }


def field_sections_device(res_u, res_v, lossless_np, u_ll, v_ll, bm,
                          backend: str = "xla") -> dict:
    """Device-codec twin of ``encode.field_sections`` (one unit)."""
    stack = (np.asarray if backend == "numpy" else jnp.asarray)
    frag = encode_streams(stack(res_u)[None], stack(res_v)[None],
                          backend)[0]
    return merge_sections(frag, lossless_np, u_ll, v_ll, bm)
