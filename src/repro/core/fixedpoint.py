"""Fixed-point conversion for exact critical-point predicates.

The paper (Alg. 3, lines 1-2) converts the float vector field to a scaled
int64 representation before any critical-point test, so that the SoS
determinant cascade is exact integer arithmetic.  We keep |value| < 2^bits
(default 30) so a 2x2 determinant term |u_i * v_j| < 2^60 and three-term
sums stay well inside int64.
"""
from __future__ import annotations

import numpy as np

DEFAULT_BITS = 30


def compute_scale(max_abs: float, bits: int = DEFAULT_BITS) -> float:
    """Power-of-two scale S with |round(x * S)| < 2**bits for |x| <= max_abs."""
    if max_abs <= 0.0 or not np.isfinite(max_abs):
        return 1.0
    # floor(log2(2^bits / max_abs)) guarantees max_abs * S <= 2^bits
    exp = int(np.floor(bits - np.log2(max_abs))) - 1
    return float(2.0 ** exp)


def to_fixed(u: np.ndarray, v: np.ndarray, bits: int = DEFAULT_BITS):
    """Convert float fields to int64 fixed point.  Returns (scale, U, V)."""
    max_abs = float(max(np.max(np.abs(u)), np.max(np.abs(v)), 1e-300))
    scale = compute_scale(max_abs, bits)
    ufp = np.round(np.asarray(u, dtype=np.float64) * scale).astype(np.int64)
    vfp = np.round(np.asarray(v, dtype=np.float64) * scale).astype(np.int64)
    return scale, ufp, vfp


def refix(u: np.ndarray, v: np.ndarray, scale: float):
    """Re-apply a known scale (used on decompressed data for verification)."""
    ufp = np.round(np.asarray(u, dtype=np.float64) * scale).astype(np.int64)
    vfp = np.round(np.asarray(v, dtype=np.float64) * scale).astype(np.int64)
    return ufp, vfp


def from_fixed(ufp: np.ndarray, vfp: np.ndarray, scale: float, dtype=np.float32):
    inv = 1.0 / scale
    return (ufp * inv).astype(dtype), (vfp * inv).astype(dtype)
