"""Per-vertex error-bound derivation (paper Alg. 2 + Alg. 4).

For every triangular face of the space-time mesh we evaluate Alg. 2 once
per vertex rotation (the algorithm is asymmetric: it bounds the
perturbation of the vertex in slot 2 with the other two fixed), zero the
bound on faces already crossed by the zero set (so their vertices are
stored losslessly and the crossing geometry is exact), and scatter-min
into the per-vertex bound array.  Faces are processed slab-by-slab with
``lax.scan``; the face tables (grid.py) are static constants.

Alg. 2's sufficiency is for a single moving vertex; the compressor's
verify-and-correct loop (compressor.py) upgrades this to an unconditional
guarantee under simultaneous perturbation -- see DESIGN.md #3.5.

Tile locality: everything here depends on vertex VALUES plus the
relative ORDER of vertex ids (the SoS tie-break compares ids, it never
uses their magnitude).  A halo-extended sub-box of the grid preserves
the global id order under its own row-major local ids
(grid.box_vertex_ids), so ``derive_vertex_eb`` evaluated on a tile is
bit-identical to the global evaluation restricted to that tile; min-
reducing per-tile bounds across every tile that sees a vertex
reconstructs the global per-vertex bound exactly (core/tiling.py,
DESIGN.md #6).

All bounds are integers in fixed-point units.  Divisions run in float64
with a conservative down-rounding (relative margin 2^-40, then -1), which
keeps every returned bound strictly below the exact real-valued bound.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from . import grid, sos

_MARGIN = 1.0 - 2.0 ** -40


def _alg2_eb(xp, u0, u1, u2, v0, v1, v2):
    """Alg. 2: max perturbation of (u2, v2) that cannot flip the face
    predicate, with (u0,v0), (u1,v1) held fixed.  int64 in, int64 out.

    Reference formulation; the production path is face_rotation_ebs /
    _rotation_ebs_from_dets, which shares the pairwise determinants
    across the three rotations (bit-equal, see
    tests/test_grid_ebound.py::test_rotation_ebs_match_per_rotation_reference).
    """
    m0 = u2 * v0 - u0 * v2
    m1 = u1 * v2 - u2 * v1
    m2 = u0 * v1 - u1 * v0
    m = m0 + m1 + m2

    f = jnp.float64 if xp is jnp else np.float64
    absm = xp.abs(m).astype(f)
    den0 = (xp.abs(u1 - u0) + xp.abs(v0 - v1)).astype(f)
    den1 = (xp.abs(u1) + xp.abs(v1)).astype(f)
    den2 = (xp.abs(u0) + xp.abs(v0)).astype(f)

    big = xp.asarray(2.0**62, dtype=f)
    eb = xp.where(den0 > 0, absm / xp.maximum(den0, 1.0), big)
    eb = xp.minimum(eb, xp.abs(m1).astype(f) / xp.maximum(den1, 1.0))
    eb = xp.minimum(eb, xp.abs(m0).astype(f) / xp.maximum(den2, 1.0))

    # same-sign relaxation: if all u (resp. v) share a strict sign the
    # face can never be crossed while each vertex keeps its own sign, so
    # |u2| - 1 is a safe integer bound for this vertex.
    su0, su1, su2 = xp.sign(u0), xp.sign(u1), xp.sign(u2)
    sv0, sv1, sv2 = xp.sign(v0), xp.sign(v1), xp.sign(v2)
    same_u = (su0 == su1) & (su1 == su2) & (su2 != 0)
    same_v = (sv0 == sv1) & (sv1 == sv2) & (sv2 != 0)
    eb = xp.where(same_u, xp.maximum(eb, (xp.abs(u2) - 1).astype(f)), eb)
    eb = xp.where(same_v, xp.maximum(eb, (xp.abs(v2) - 1).astype(f)), eb)

    eb_int = xp.floor(eb * _MARGIN).astype(xp.int64) - 1
    # paper early-outs: degenerate face (M == 0) or a fixed vertex exactly
    # at the origin -> lossless.
    zero = (m == 0) | (den1 == 0) | (den2 == 0)
    eb_int = xp.where(zero, xp.zeros_like(eb_int), eb_int)
    return xp.maximum(eb_int, 0)


def face_rotation_ebs(xp, fu, fv, crossed):
    """Alg. 2 for the three rotations of each face.

    fu, fv: (..., 3) int64 values;  crossed: (...,) bool.
    Returns (..., 3) int64 bounds aligned with the face's vertex slots.
    Every rotation permutes the SAME three pairwise determinants, so
    they are computed once and shared (bit-identical to the per-rotation
    evaluation: integer dets, identical float division operands).
    """
    a_u, b_u, c_u = fu[..., 0], fu[..., 1], fu[..., 2]
    a_v, b_v, c_v = fv[..., 0], fv[..., 1], fv[..., 2]
    d_ab = a_u * b_v - a_v * b_u
    d_bc = b_u * c_v - b_v * c_u
    d_ca = c_u * a_v - c_v * a_u
    return _rotation_ebs_from_dets(
        xp, fu, fv, crossed, d_ab, d_bc, d_ca)


def _rotation_ebs_from_dets(xp, fu, fv, crossed, d_ab, d_bc, d_ca):
    a_u, b_u, c_u = fu[..., 0], fu[..., 1], fu[..., 2]
    a_v, b_v, c_v = fv[..., 0], fv[..., 1], fv[..., 2]
    f = jnp.float64 if xp is jnp else np.float64
    m = d_ca + d_bc + d_ab
    absm = xp.abs(m).astype(f)
    big = xp.asarray(2.0**62, dtype=f)

    # same-sign relaxation is a property of the whole face
    su0, su1, su2 = xp.sign(a_u), xp.sign(b_u), xp.sign(c_u)
    sv0, sv1, sv2 = xp.sign(a_v), xp.sign(b_v), xp.sign(c_v)
    same_u = (su0 == su1) & (su1 == su2) & (su2 != 0)
    same_v = (sv0 == sv1) & (sv1 == sv2) & (sv2 != 0)

    def rot_eb(m0, m1, pu, pv, qu, qv, su, sv):
        """Perturb vertex s with (p, q) fixed; m0 = det(s,p), m1 = det(q,s)."""
        den0 = (xp.abs(qu - pu) + xp.abs(pv - qv)).astype(f)
        den1 = (xp.abs(qu) + xp.abs(qv)).astype(f)
        den2 = (xp.abs(pu) + xp.abs(pv)).astype(f)
        eb = xp.where(den0 > 0, absm / xp.maximum(den0, 1.0), big)
        eb = xp.minimum(eb, xp.abs(m1).astype(f) / xp.maximum(den1, 1.0))
        eb = xp.minimum(eb, xp.abs(m0).astype(f) / xp.maximum(den2, 1.0))
        eb = xp.where(same_u, xp.maximum(eb, (xp.abs(su) - 1).astype(f)), eb)
        eb = xp.where(same_v, xp.maximum(eb, (xp.abs(sv) - 1).astype(f)), eb)
        eb_int = xp.floor(eb * _MARGIN).astype(xp.int64) - 1
        zero = (m == 0) | (den1 == 0) | (den2 == 0)
        eb_int = xp.where(zero, xp.zeros_like(eb_int), eb_int)
        return xp.maximum(eb_int, 0)

    eb_c = rot_eb(d_ca, d_bc, a_u, a_v, b_u, b_v, c_u, c_v)
    eb_a = rot_eb(d_ab, d_ca, b_u, b_v, c_u, c_v, a_u, a_v)
    eb_b = rot_eb(d_bc, d_ab, c_u, c_v, a_u, a_v, b_u, b_v)
    ebs = xp.stack([eb_a, eb_b, eb_c], axis=-1)
    return xp.where(crossed[..., None], xp.zeros_like(ebs), ebs)


@lru_cache(maxsize=32)
def _incidence_table(H: int, W: int, kind: str) -> np.ndarray:
    """Static vertex -> incident (face, slot) flat-index table.

    Entry [v, k] indexes into ``ebs.reshape(-1)`` (layout f*3 + slot);
    rows are padded with the out-of-range sentinel F*3.  Lets the eb
    reduction run as a vectorized gather-min instead of a scatter-min
    (XLA scatters serialize on CPU and dominate derivation time).
    """
    if kind == "slice":
        tab = grid.slab_faces(H, W)["slice0"]
        n_verts = H * W
    else:
        tab = slab_face_table(H, W)
        n_verts = 2 * H * W
    F = len(tab)
    vert = tab.reshape(-1).astype(np.int64)
    order = np.argsort(vert, kind="stable")
    sv = vert[order]
    si = order.astype(np.int64)          # flat index f*3 + slot
    counts = np.bincount(sv, minlength=n_verts)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(len(sv)) - starts[sv]
    out = np.full((n_verts, int(counts.max())), F * 3, dtype=np.int64)
    out[sv, pos] = si
    return out


def _faces_eb_update(u_flat, v_flat, idx_base, faces, tau, n_verts, inc):
    """Per-face ebs gather-min'd into a fresh (n_verts,) array.

    u_flat/v_flat: (n_verts,) int64 values of the vertex planes involved;
    idx_base: scalar global id of local vertex 0 (for SoS indices);
    faces: (F, 3) int32 static table; inc: (n_verts, K) incidence table
    (_incidence_table).  The three pairwise determinants are shared
    between the crossed test and all Alg. 2 rotations.
    """
    fu = u_flat[faces]
    fv = v_flat[faces]
    fidx = faces.astype(jnp.int64) + idx_base
    a_u, b_u, c_u = fu[..., 0], fu[..., 1], fu[..., 2]
    a_v, b_v, c_v = fv[..., 0], fv[..., 1], fv[..., 2]
    d_ab = a_u * b_v - a_v * b_u
    d_bc = b_u * c_v - b_v * c_u
    d_ca = c_u * a_v - c_v * a_u
    crossed = sos.face_crossed(
        jnp,
        fu[..., 0], fv[..., 0], fidx[..., 0],
        fu[..., 1], fv[..., 1], fidx[..., 1],
        fu[..., 2], fv[..., 2], fidx[..., 2],
        d_ab=d_ab, d_bc=d_bc, d_ca=d_ca,
    )
    ebs = _rotation_ebs_from_dets(jnp, fu, fv, crossed, d_ab, d_bc, d_ca)
    big = jnp.asarray([2**62], dtype=jnp.int64)
    ebs_flat = jnp.concatenate([ebs.reshape(-1), big])
    out = jnp.minimum(jnp.min(ebs_flat[inc], axis=1),
                      jnp.asarray(tau, jnp.int64))
    return out, crossed


def derive_vertex_eb(ufp, vfp, tau: int):
    """Per-vertex error bounds over the full space-time mesh.

    ufp, vfp: (T, H, W) int64.  Returns (eb (T, H, W) int64,
    slice_crossed (T, Fs) bool, slab_crossed (T-1, Fb) bool).
    """
    T, H, W = ufp.shape
    HW = H * W
    slice_tab = jnp.asarray(grid.slab_faces(H, W)["slice0"])
    sf = grid.slab_faces(H, W)
    slab_tab = jnp.asarray(np.concatenate([sf["side"], sf["internal"]], axis=0))
    slice_inc = jnp.asarray(_incidence_table(H, W, "slice"))
    slab_inc = jnp.asarray(_incidence_table(H, W, "slab"))

    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)

    def slice_body(t, uv):
        u_t, v_t = uv
        eb, crossed = _faces_eb_update(
            u_t, v_t, t * HW, slice_tab, tau, HW, slice_inc)
        return eb, crossed

    def slice_scan(carry, x):
        t, u_t, v_t = x
        eb, crossed = slice_body(t, (u_t, v_t))
        return carry, (eb, crossed)

    _, (eb_slice, slice_crossed) = jax.lax.scan(
        slice_scan, 0, (jnp.arange(T, dtype=jnp.int64), u2, v2)
    )

    def slab_scan(carry, x):
        t, u_pair, v_pair = x
        eb, crossed = _faces_eb_update(
            u_pair.reshape(-1), v_pair.reshape(-1), t * HW, slab_tab, tau,
            2 * HW, slab_inc
        )
        return carry, (eb.reshape(2, HW), crossed)

    pairs_u = jnp.stack([u2[:-1], u2[1:]], axis=1)  # (T-1, 2, HW)
    pairs_v = jnp.stack([v2[:-1], v2[1:]], axis=1)
    _, (eb_slab2, slab_crossed) = jax.lax.scan(
        slab_scan, 0, (jnp.arange(T - 1, dtype=jnp.int64), pairs_u, pairs_v)
    )

    eb = eb_slice
    # slab [t, t+1] contributes its plane-0 bounds to time t ...
    eb = eb.at[:-1].min(eb_slab2[:, 0])
    # ... and its plane-1 bounds to time t+1.
    eb = eb.at[1:].min(eb_slab2[:, 1])
    return eb.reshape(T, H, W), slice_crossed, slab_crossed


# jitted entry point shared by the monolithic compressor and the tiled
# pipeline (one compiled executable per (shape, tau) class)
derive_vertex_eb_jit = jax.jit(derive_vertex_eb, static_argnums=2)


def all_face_predicates(ufp, vfp, be: str = "xla"):
    """SoS predicates for every face, via the dispatched predicate op
    (core/backend.py).  Returns (slice (T, Fs), slab (T-1, Fb))."""
    from . import backend as _backend

    T, H, W = ufp.shape
    HW = H * W
    n_verts = T * HW
    sf = grid.slab_faces(H, W)
    slab_tab_np = np.concatenate([sf["side"], sf["internal"]], axis=0)

    if be == "numpy":
        u2 = np.asarray(ufp).reshape(T, HW)
        v2 = np.asarray(vfp).reshape(T, HW)
        st = sf["slice0"].astype(np.int64)
        idx = st[None] + (np.arange(T, dtype=np.int64) * HW)[:, None, None]
        slice_pred = _backend.face_crossed(
            u2[:, st], v2[:, st], idx, backend=be, n_verts=n_verts)
        bt = slab_tab_np.astype(np.int64)
        pair_u = np.concatenate([u2[:-1], u2[1:]], axis=1)
        pair_v = np.concatenate([v2[:-1], v2[1:]], axis=1)
        idx = bt[None] + (np.arange(T - 1, dtype=np.int64) * HW)[:, None, None]
        slab_pred = _backend.face_crossed(
            pair_u[:, bt], pair_v[:, bt], idx, backend=be, n_verts=n_verts)
        return slice_pred, slab_pred

    slice_tab = jnp.asarray(sf["slice0"])
    slab_tab = jnp.asarray(slab_tab_np)
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)

    def slice_scan(carry, x):
        t, u_t, v_t = x
        fu, fv = u_t[slice_tab], v_t[slice_tab]
        fidx = slice_tab.astype(jnp.int64) + t * HW
        return carry, _backend.face_crossed(fu, fv, fidx, backend=be,
                                            n_verts=n_verts)

    _, slice_pred = jax.lax.scan(
        slice_scan, 0, (jnp.arange(T, dtype=jnp.int64), u2, v2)
    )

    def slab_scan(carry, x):
        t, u_pair, v_pair = x
        uf = u_pair.reshape(-1)[slab_tab]
        vf = v_pair.reshape(-1)[slab_tab]
        fidx = slab_tab.astype(jnp.int64) + t * HW
        return carry, _backend.face_crossed(uf, vf, fidx, backend=be,
                                            n_verts=n_verts)

    pairs_u = jnp.stack([u2[:-1], u2[1:]], axis=1)
    pairs_v = jnp.stack([v2[:-1], v2[1:]], axis=1)
    _, slab_pred = jax.lax.scan(
        slab_scan, 0, (jnp.arange(T - 1, dtype=jnp.int64), pairs_u, pairs_v)
    )
    return slice_pred, slab_pred


@lru_cache(maxsize=32)
def slab_face_table(H, W):
    """(Fb, 3) int32 side+internal face table (local 2-plane ids).

    Cached: the concatenation is rebuilt for every verify round and every
    tile geometry otherwise (the table is static per (H, W))."""
    sf = grid.slab_faces(H, W)
    return np.concatenate([sf["side"], sf["internal"]], axis=0)
