"""Per-vertex error-bound derivation (paper Alg. 2 + Alg. 4).

For every triangular face of the space-time mesh we evaluate Alg. 2 once
per vertex rotation (the algorithm is asymmetric: it bounds the
perturbation of the vertex in slot 2 with the other two fixed), zero the
bound on faces already crossed by the zero set (so their vertices are
stored losslessly and the crossing geometry is exact), and scatter-min
into the per-vertex bound array.  Faces are processed slab-by-slab with
``lax.scan``; the face tables (grid.py) are static constants.

Alg. 2's sufficiency is for a single moving vertex; the compressor's
verify-and-correct loop (compressor.py) upgrades this to an unconditional
guarantee under simultaneous perturbation -- see DESIGN.md #3.5.

All bounds are integers in fixed-point units.  Divisions run in float64
with a conservative down-rounding (relative margin 2^-40, then -1), which
keeps every returned bound strictly below the exact real-valued bound.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import grid, sos

_MARGIN = 1.0 - 2.0 ** -40


def _alg2_eb(xp, u0, u1, u2, v0, v1, v2):
    """Alg. 2: max perturbation of (u2, v2) that cannot flip the face
    predicate, with (u0,v0), (u1,v1) held fixed.  int64 in, int64 out."""
    m0 = u2 * v0 - u0 * v2
    m1 = u1 * v2 - u2 * v1
    m2 = u0 * v1 - u1 * v0
    m = m0 + m1 + m2

    f = jnp.float64 if xp is jnp else np.float64
    absm = xp.abs(m).astype(f)
    den0 = (xp.abs(u1 - u0) + xp.abs(v0 - v1)).astype(f)
    den1 = (xp.abs(u1) + xp.abs(v1)).astype(f)
    den2 = (xp.abs(u0) + xp.abs(v0)).astype(f)

    big = xp.asarray(2.0**62, dtype=f)
    eb = xp.where(den0 > 0, absm / xp.maximum(den0, 1.0), big)
    eb = xp.minimum(eb, xp.abs(m1).astype(f) / xp.maximum(den1, 1.0))
    eb = xp.minimum(eb, xp.abs(m0).astype(f) / xp.maximum(den2, 1.0))

    # same-sign relaxation: if all u (resp. v) share a strict sign the
    # face can never be crossed while each vertex keeps its own sign, so
    # |u2| - 1 is a safe integer bound for this vertex.
    su0, su1, su2 = xp.sign(u0), xp.sign(u1), xp.sign(u2)
    sv0, sv1, sv2 = xp.sign(v0), xp.sign(v1), xp.sign(v2)
    same_u = (su0 == su1) & (su1 == su2) & (su2 != 0)
    same_v = (sv0 == sv1) & (sv1 == sv2) & (sv2 != 0)
    eb = xp.where(same_u, xp.maximum(eb, (xp.abs(u2) - 1).astype(f)), eb)
    eb = xp.where(same_v, xp.maximum(eb, (xp.abs(v2) - 1).astype(f)), eb)

    eb_int = xp.floor(eb * _MARGIN).astype(xp.int64) - 1
    # paper early-outs: degenerate face (M == 0) or a fixed vertex exactly
    # at the origin -> lossless.
    zero = (m == 0) | (den1 == 0) | (den2 == 0)
    eb_int = xp.where(zero, xp.zeros_like(eb_int), eb_int)
    return xp.maximum(eb_int, 0)


def face_rotation_ebs(xp, fu, fv, crossed):
    """Alg. 2 for the three rotations of each face.

    fu, fv: (..., 3) int64 values;  crossed: (...,) bool.
    Returns (..., 3) int64 bounds aligned with the face's vertex slots.
    """
    a_u, b_u, c_u = fu[..., 0], fu[..., 1], fu[..., 2]
    a_v, b_v, c_v = fv[..., 0], fv[..., 1], fv[..., 2]
    eb_c = _alg2_eb(xp, a_u, b_u, c_u, a_v, b_v, c_v)
    eb_a = _alg2_eb(xp, b_u, c_u, a_u, b_v, c_v, a_v)
    eb_b = _alg2_eb(xp, c_u, a_u, b_u, c_v, a_v, b_v)
    ebs = xp.stack([eb_a, eb_b, eb_c], axis=-1)
    return xp.where(crossed[..., None], xp.zeros_like(ebs), ebs)


def _faces_eb_update(u_flat, v_flat, idx_base, faces, tau, n_verts):
    """Per-face ebs scatter-min'd into a fresh (n_verts,) array.

    u_flat/v_flat: (n_verts,) int64 values of the vertex planes involved;
    idx_base: scalar global id of local vertex 0 (for SoS indices);
    faces: (F, 3) int32 static table.
    """
    fu = u_flat[faces]
    fv = v_flat[faces]
    fidx = faces.astype(jnp.int64) + idx_base
    crossed = sos.face_crossed_vals(jnp, fu, fv, fidx)
    ebs = face_rotation_ebs(jnp, fu, fv, crossed)
    out = jnp.full((n_verts,), tau, dtype=jnp.int64)
    out = out.at[faces.reshape(-1)].min(ebs.reshape(-1))
    return out, crossed


def derive_vertex_eb(ufp, vfp, tau: int):
    """Per-vertex error bounds over the full space-time mesh.

    ufp, vfp: (T, H, W) int64.  Returns (eb (T, H, W) int64,
    slice_crossed (T, Fs) bool, slab_crossed (T-1, Fb) bool).
    """
    T, H, W = ufp.shape
    HW = H * W
    slice_tab = jnp.asarray(grid.slab_faces(H, W)["slice0"])
    sf = grid.slab_faces(H, W)
    slab_tab = jnp.asarray(np.concatenate([sf["side"], sf["internal"]], axis=0))

    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)

    def slice_body(t, uv):
        u_t, v_t = uv
        eb, crossed = _faces_eb_update(u_t, v_t, t * HW, slice_tab, tau, HW)
        return eb, crossed

    def slice_scan(carry, x):
        t, u_t, v_t = x
        eb, crossed = slice_body(t, (u_t, v_t))
        return carry, (eb, crossed)

    _, (eb_slice, slice_crossed) = jax.lax.scan(
        slice_scan, 0, (jnp.arange(T, dtype=jnp.int64), u2, v2)
    )

    def slab_scan(carry, x):
        t, u_pair, v_pair = x
        eb, crossed = _faces_eb_update(
            u_pair.reshape(-1), v_pair.reshape(-1), t * HW, slab_tab, tau, 2 * HW
        )
        return carry, (eb.reshape(2, HW), crossed)

    pairs_u = jnp.stack([u2[:-1], u2[1:]], axis=1)  # (T-1, 2, HW)
    pairs_v = jnp.stack([v2[:-1], v2[1:]], axis=1)
    _, (eb_slab2, slab_crossed) = jax.lax.scan(
        slab_scan, 0, (jnp.arange(T - 1, dtype=jnp.int64), pairs_u, pairs_v)
    )

    eb = eb_slice
    # slab [t, t+1] contributes its plane-0 bounds to time t ...
    eb = eb.at[:-1].min(eb_slab2[:, 0])
    # ... and its plane-1 bounds to time t+1.
    eb = eb.at[1:].min(eb_slab2[:, 1])
    return eb.reshape(T, H, W), slice_crossed, slab_crossed


def all_face_predicates(ufp, vfp):
    """SoS predicates for every face.  Returns (slice (T, Fs), slab (T-1, Fb))."""
    T, H, W = ufp.shape
    HW = H * W
    slice_tab = jnp.asarray(grid.slab_faces(H, W)["slice0"])
    sf = grid.slab_faces(H, W)
    slab_tab = jnp.asarray(np.concatenate([sf["side"], sf["internal"]], axis=0))
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)

    def slice_scan(carry, x):
        t, u_t, v_t = x
        fu, fv = u_t[slice_tab], v_t[slice_tab]
        fidx = slice_tab.astype(jnp.int64) + t * HW
        return carry, sos.face_crossed_vals(jnp, fu, fv, fidx)

    _, slice_pred = jax.lax.scan(
        slice_scan, 0, (jnp.arange(T, dtype=jnp.int64), u2, v2)
    )

    def slab_scan(carry, x):
        t, u_pair, v_pair = x
        uf = u_pair.reshape(-1)[slab_tab]
        vf = v_pair.reshape(-1)[slab_tab]
        fidx = slab_tab.astype(jnp.int64) + t * HW
        return carry, sos.face_crossed_vals(jnp, uf, vf, fidx)

    pairs_u = jnp.stack([u2[:-1], u2[1:]], axis=1)
    pairs_v = jnp.stack([v2[:-1], v2[1:]], axis=1)
    _, slab_pred = jax.lax.scan(
        slab_scan, 0, (jnp.arange(T - 1, dtype=jnp.int64), pairs_u, pairs_v)
    )
    return slice_pred, slab_pred


def slab_face_table(H, W):
    """(Fb, 3) int32 side+internal face table (local 2-plane ids)."""
    sf = grid.slab_faces(H, W)
    return np.concatenate([sf["side"], sf["internal"]], axis=0)
