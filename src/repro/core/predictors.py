"""Predictors on the dual-quantized integer field X (quantize.py).

Block-local first-order 3D Lorenzo (3DL)
----------------------------------------
The classic Lorenzo predictor reads *reconstructed* causal neighbors,
which serializes SZ-style encoders.  On the dual-quantized integers the
predictor feedback disappears, and we additionally re-block the spatial
context into ``block x block`` tiles (default 16): cells on a tile's
leading edges use the temporal term only.  Residual

    res_t = D2(X_t) - D2(X_{t-1})   (t > 0),      res_0 = D2(X_0)

with D2 the *tile-local* 2D first-order difference.  Decode is

    X_t = X_{t-1} + C2(res_t),      X_0 = C2(res_0)

with C2 the tile-local 2D inclusive cumsum -- exact integer inverses,
embarrassingly parallel across (t, tiles).  See DESIGN.md #3.2.

Semi-Lagrangian (SL) predictor (paper Sec. VI-A)
------------------------------------------------
Backtrace from each grid point along the previous *reconstructed*
velocity field: RK2 midpoint when the local CFL displacement d_inf is
within ``d_max`` pixels, else up to ``n_max`` clamped Euler substeps;
bilinear-sample frame t-1 at the departure point.  Depends only on frame
t-1, so the encoder evaluates all frames in parallel; the decoder runs it
inside the frame scan.  Both sides call the *same* function on the same
integers, so predictions match bit-for-bit.

Determinism note (DESIGN.md #4): float arithmetic is NOT bit-stable
across different XLA compilation contexts (fusion decisions change
roundings), so encoder/verify/decoder consistency is achieved
structurally -- all three call the SAME per-frame jitted executable
(core/backend.py sl_stepper) -- rather than by re-deriving the
prediction in differently-compiled graphs.  The substep loop
early-exits at the field-wide maximum substep count (a pure win:
iterations beyond a pixel's own n_sub are masked identities, so
results are unchanged bit-for-bit).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 16


# ----------------------------------------------------------------------
# block-local Lorenzo
# ----------------------------------------------------------------------

def _shift1(x, axis):
    """x[..., i-1, ...] with zero at i == 0."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, -1)
    return jnp.pad(x, pad)[tuple(sl)]


def _edge_mask(n, block, dtype):
    idx = jnp.arange(n)
    return ((idx % block) != 0).astype(dtype)


def d2_block(x, block=DEFAULT_BLOCK):
    """Tile-local 2D first-order difference over the last two axes."""
    mi = _edge_mask(x.shape[-2], block, x.dtype)[:, None]
    mj = _edge_mask(x.shape[-1], block, x.dtype)[None, :]
    xi = _shift1(x, -2) * mi
    xj = _shift1(x, -1) * mj
    xij = _shift1(_shift1(x, -2), -1) * (mi * mj)
    return x - xi - xj + xij


def c2_block(r, block=DEFAULT_BLOCK):
    """Tile-local 2D inclusive cumsum (inverse of d2_block)."""

    def cs(a, axis):
        n = a.shape[axis]
        nb = -(-n // block)
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, nb * block - n)
        ap = jnp.pad(a, pad)
        shape = list(ap.shape)
        shape[axis : axis + 1] = [nb, block]
        ap = ap.reshape(shape)
        ap = jnp.cumsum(ap, axis=axis + 1)
        shape2 = list(a.shape)
        shape2[axis] = nb * block
        ap = ap.reshape(shape2)
        sl = [slice(None)] * a.ndim
        sl[axis] = slice(0, n)
        return ap[tuple(sl)]

    return cs(cs(r, r.ndim - 2), r.ndim - 1)


def lorenzo_encode(x, block=DEFAULT_BLOCK):
    """res (T, H, W) int64 from X (T, H, W) int64."""
    d2 = d2_block(x, block)
    return d2 - _shift1(d2, 0)


def lorenzo_decode_frame(prev_x, res_t, block=DEFAULT_BLOCK):
    return prev_x + c2_block(res_t, block)


# ----------------------------------------------------------------------
# semi-Lagrangian
# ----------------------------------------------------------------------

def bilinear(f, fi, fj):
    """Paper Eq. 6: bilinear sample of f (H, W) at float positions."""
    H, W = f.shape[-2], f.shape[-1]
    i0 = jnp.clip(jnp.floor(fi), 0, H - 1)
    j0 = jnp.clip(jnp.floor(fj), 0, W - 1)
    a = fi - i0
    b = fj - j0
    i0 = i0.astype(jnp.int32)
    j0 = j0.astype(jnp.int32)
    i1 = jnp.minimum(i0 + 1, H - 1)
    j1 = jnp.minimum(j0 + 1, W - 1)
    f00 = f[..., i0, j0]
    f01 = f[..., i0, j1]
    f10 = f[..., i1, j0]
    f11 = f[..., i1, j1]
    return (
        (1 - a) * (1 - b) * f00
        + (1 - a) * b * f01
        + a * (1 - b) * f10
        + a * b * f11
    )


def sl_departure(u_prev, v_prev, cfl_x, cfl_y, d_max=2.0, n_max=32,
                 early_exit=False):
    """Departure points (i*, j*) for every grid node (paper Eqs. 4, 7-9).

    ``early_exit=True`` stops the substep loop at the field-wide maximum
    substep count instead of always running n_max iterations; iterations
    past a pixel's own n_sub are masked identities, so the result is
    bit-identical either way (the flag exists so the legacy A/B pipeline
    keeps the seed's cost profile -- perfflags / DESIGN.md #5).
    """
    H, W = u_prev.shape
    dt = u_prev.dtype
    cfl_x = jnp.asarray(cfl_x, dt)
    cfl_y = jnp.asarray(cfl_y, dt)
    ii, jj = jnp.meshgrid(
        jnp.arange(H, dtype=dt),
        jnp.arange(W, dtype=dt),
        indexing="ij",
    )
    u0 = u_prev
    v0 = v_prev
    d_inf = jnp.maximum(jnp.abs(u0) * cfl_x, jnp.abs(v0) * cfl_y)

    # RK2 midpoint
    i_h = jnp.clip(ii - 0.5 * v0 * cfl_y, 0.0, H - 1.0)
    j_h = jnp.clip(jj - 0.5 * u0 * cfl_x, 0.0, W - 1.0)
    u_h = bilinear(u_prev, i_h, j_h)
    v_h = bilinear(v_prev, i_h, j_h)
    i_rk = ii - v_h * cfl_y
    j_rk = jj - u_h * cfl_x

    # adaptive substepping
    n_sub = jnp.clip(jnp.ceil(d_inf / d_max), 1.0, float(n_max))

    def step(s, pi, pj):
        us = bilinear(u_prev, pi, pj)
        vs = bilinear(v_prev, pi, pj)
        active = s < n_sub
        pi = jnp.where(active, jnp.clip(pi - vs * cfl_y / n_sub, 0.0, H - 1.0), pi)
        pj = jnp.where(active, jnp.clip(pj - us * cfl_x / n_sub, 0.0, W - 1.0), pj)
        return pi, pj

    if early_exit:
        n_hi = jnp.max(n_sub)

        def cond(carry):
            s, _, _ = carry
            return s < n_hi

        def body(carry):
            s, pi, pj = carry
            pi, pj = step(s, pi, pj)
            return (s + 1, pi, pj)

        _, pi, pj = jax.lax.while_loop(cond, body, (jnp.int32(0), ii, jj))
    else:
        pi, pj = jax.lax.fori_loop(
            0, n_max, lambda s, pos: step(s, *pos), (ii, jj)
        )

    use_rk = d_inf <= d_max
    i_star = jnp.clip(jnp.where(use_rk, i_rk, pi), 0.0, H - 1.0)
    j_star = jnp.clip(jnp.where(use_rk, j_rk, pj), 0.0, W - 1.0)
    return i_star, j_star


def sl_predict_frame(xu_prev, xv_prev, grid_to_float, cfl_x, cfl_y,
                     d_max=2.0, n_max=32, early_exit=False):
    """Predict frame t's integer grid values from frame t-1's X fields.

    xu_prev, xv_prev: int64 (H, W) base-grid integers of frame t-1.
    grid_to_float: g / S -- converts base-grid ints to data units.
    Returns (pu, pv) int64 predictions on the base grid.
    """
    g2f = jnp.asarray(grid_to_float, jnp.float64)
    u_prev = xu_prev.astype(jnp.float64) * g2f
    v_prev = xv_prev.astype(jnp.float64) * g2f
    i_s, j_s = sl_departure(u_prev, v_prev, cfl_x, cfl_y, d_max, n_max,
                            early_exit)
    pu = bilinear(u_prev, i_s, j_s) / g2f
    pv = bilinear(v_prev, i_s, j_s) / g2f
    return jnp.rint(pu).astype(jnp.int64), jnp.rint(pv).astype(jnp.int64)


def sl_encode(xu, xv, grid_to_float, cfl_x, cfl_y, d_max=2.0, n_max=32):
    """SL residuals for all frames (frame 0 copies the 3DL convention of
    spatial-only coding and is never selected by MoP)."""
    predict = partial(
        sl_predict_frame,
        grid_to_float=grid_to_float,
        cfl_x=cfl_x,
        cfl_y=cfl_y,
        d_max=d_max,
        n_max=n_max,
    )
    pu, pv = jax.vmap(predict)(xu[:-1], xv[:-1])
    res_u = xu[1:] - pu
    res_v = xv[1:] - pv
    zero = jnp.zeros_like(xu[:1])
    return (
        jnp.concatenate([zero, res_u], axis=0),
        jnp.concatenate([zero, res_v], axis=0),
    )
