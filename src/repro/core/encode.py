"""Entropy/backend coding (paper Sec. V "lossless_comp" stage).

Residual symbols are zigzag-folded, escape-coded into a uint8 stream
(values >= 255 escape to an int64 side list), and the packed container is
compressed with zstd (FSE entropy + LZ77 matching ~= the paper's
Huffman + Zstd stack, but with a vectorizable decoder -- DESIGN.md #3.6).
A canonical Huffman coder is also provided; it is bit-exact round-trip
tested and used by the encoding-efficiency benchmark to report the same
quantities as the paper's Fig. 6/7 analysis.

Container layout: msgpack header + raw sections, the whole thing inside
one zstd frame.
"""
from __future__ import annotations

import heapq
import io
import struct

import msgpack
import numpy as np
import zstandard

MAGIC = b"CPTZ1"
ESC = 255


# ----------------------------------------------------------------------
# symbol stream
# ----------------------------------------------------------------------

def fold_np(res):
    res = np.asarray(res, dtype=np.int64)
    return np.where(res >= 0, 2 * res, -2 * res - 1)


def unfold_np(z):
    z = np.asarray(z, dtype=np.int64)
    return np.where(z % 2 == 0, z // 2, -(z + 1) // 2)


def to_symbols(res):
    """int64 residuals -> (uint8 stream, int64 escapes)."""
    z = fold_np(res).reshape(-1)
    esc_mask = z >= ESC
    sym = np.where(esc_mask, ESC, z).astype(np.uint8)
    escapes = res.reshape(-1)[esc_mask].astype(np.int64)
    return sym, escapes


def from_symbols(sym, escapes, shape):
    z = sym.astype(np.int64)
    res = unfold_np(z)
    esc_mask = sym == ESC
    res[esc_mask] = escapes
    return res.reshape(shape)


# ----------------------------------------------------------------------
# canonical Huffman (reference entropy coder)
# ----------------------------------------------------------------------

def huffman_code_lengths(freq):
    """Code length per symbol via the standard heap construction."""
    items = [(int(f), i) for i, f in enumerate(freq) if f > 0]
    if not items:
        return np.zeros(len(freq), dtype=np.int32)
    if len(items) == 1:
        ln = np.zeros(len(freq), dtype=np.int32)
        ln[items[0][1]] = 1
        return ln
    heap = [(f, n, (s,)) for n, (f, s) in enumerate(items)]
    heapq.heapify(heap)
    counter = len(heap)
    depth = {}
    while len(heap) > 1:
        f1, _, s1 = heapq.heappop(heap)
        f2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            depth[s] = depth.get(s, 0) + 1
        counter += 1
        heapq.heappush(heap, (f1 + f2, counter, s1 + s2))
    ln = np.zeros(len(freq), dtype=np.int32)
    for s, d in depth.items():
        ln[s] = d
    return ln


def canonical_codes(lengths):
    """(codes uint32, lengths) canonical assignment."""
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint32)
    code = 0
    prev_len = 0
    for s in order:
        ln = int(lengths[s])
        if ln == 0:
            continue
        if prev_len == 0:
            prev_len = ln
        code <<= ln - prev_len
        codes[s] = code
        code += 1
        prev_len = ln
    return codes, lengths


def huffman_encode(sym):
    """uint8 symbols -> (lengths table, packed bits, n_symbols)."""
    freq = np.bincount(sym, minlength=256)
    lengths = huffman_code_lengths(freq)
    # keep ln + intra-byte offset <= 64 for the vectorized packer
    while lengths.max() > 56:
        freq = np.where(freq > 0, (freq + 1) // 2, 0)
        lengths = huffman_code_lengths(freq)
    codes, _ = canonical_codes(lengths)
    ln = lengths[sym].astype(np.int64)
    cd = codes[sym].astype(np.uint64)
    total = int(ln.sum())
    # vectorized MSB-first bit packing
    ends = np.cumsum(ln)
    starts = ends - ln
    nbytes = (total + 7) // 8
    buf = np.zeros(nbytes + 8, dtype=np.uint8)
    # write each symbol's code into a 64-bit window at its byte offset
    byte_off = (starts // 8).astype(np.int64)
    bit_off = (starts % 8).astype(np.int64)
    shift = (64 - bit_off - ln).astype(np.uint64)
    vals = (cd << shift).astype(">u8")
    # scatter with per-byte accumulation: process in 8 passes so windows
    # touching the same bytes never collide (codes <= 56 bits + 7 offset).
    view = vals.view(np.uint8).reshape(-1, 8)
    for b in range(8):
        np.add.at(buf, byte_off + b, view[:, b])
    return lengths, buf[:nbytes].tobytes(), len(sym)


def huffman_decode(lengths, data, n):
    """Table-driven canonical Huffman decode (peek-table, python loop in
    chunks -- reference implementation, used on test/bench sized inputs)."""
    codes, _ = canonical_codes(lengths)
    maxlen = int(lengths.max()) if lengths.max() > 0 else 1
    peek = np.zeros(1 << maxlen, dtype=np.uint16)
    plen = np.zeros(1 << maxlen, dtype=np.uint8)
    for s in range(256):
        ln = int(lengths[s])
        if ln == 0:
            continue
        prefix = int(codes[s]) << (maxlen - ln)
        span = 1 << (maxlen - ln)
        peek[prefix : prefix + span] = s
        plen[prefix : prefix + span] = ln
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))
    out = np.empty(n, dtype=np.uint8)
    pos = 0
    # pad so window reads never run off the end
    bits = np.concatenate([bits, np.zeros(maxlen, dtype=np.uint8)])
    pw = (1 << np.arange(maxlen - 1, -1, -1)).astype(np.uint32)
    for i in range(n):
        window = int(bits[pos : pos + maxlen] @ pw)
        s = peek[window]
        out[i] = s
        pos += int(plen[window])
    return out


def huffman_stream_size_bits(sym):
    freq = np.bincount(sym, minlength=256)
    lengths = huffman_code_lengths(freq)
    return int((lengths[sym]).sum())


# ----------------------------------------------------------------------
# container
# ----------------------------------------------------------------------

def pack(header: dict, sections: dict, level: int = 12) -> bytes:
    body = io.BytesIO()
    sec_index = {}
    for name, arr in sections.items():
        raw = np.ascontiguousarray(arr).tobytes()
        sec_index[name] = {
            "off": body.tell(),
            "len": len(raw),
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
        body.write(raw)
    header = dict(header)
    header["sections"] = sec_index
    hdr = msgpack.packb(header, use_bin_type=True)
    payload = struct.pack("<I", len(hdr)) + hdr + body.getvalue()
    comp = zstandard.ZstdCompressor(level=level).compress(payload)
    return MAGIC + comp


def unpack(blob: bytes):
    assert blob[: len(MAGIC)] == MAGIC, "not a CPTZ container"
    payload = zstandard.ZstdDecompressor().decompress(blob[len(MAGIC):])
    (hlen,) = struct.unpack("<I", payload[:4])
    header = msgpack.unpackb(payload[4 : 4 + hlen], raw=False)
    base = 4 + hlen
    sections = {}
    for name, meta in header.pop("sections").items():
        raw = payload[base + meta["off"] : base + meta["off"] + meta["len"]]
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"]))
        sections[name] = arr.reshape(meta["shape"])
    return header, sections
