"""Entropy/backend coding (paper Sec. V "lossless_comp" stage).

Residual symbols are zigzag-folded, escape-coded into a uint8 stream
(values >= 255 escape to an int64 side list), and the packed container is
compressed with zstd (FSE entropy + LZ77 matching ~= the paper's
Huffman + Zstd stack, but with a vectorizable decoder -- DESIGN.md #3.6).
A canonical Huffman coder is also provided; it is bit-exact round-trip
tested and used by the encoding-efficiency benchmark to report the same
quantities as the paper's Fig. 6/7 analysis.

Container layout: msgpack header + raw sections, the whole thing inside
one zstd frame.  When the optional ``zstandard`` module is absent the
container degrades to a zlib frame (magic ``CPTL1``, ``codec`` flagged in
the header) so importing and using the core never hard-fails.
"""
from __future__ import annotations

import heapq
import io
import os
import struct
import time
import zlib

import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover - exercised by the CI minimal-env job
    zstandard = None

MAGIC = b"CPTZ1"          # zstd-backed container
MAGIC_ZLIB = b"CPTL1"     # zlib fallback container (same layout inside)
MAGIC_TILED = b"CPTT1"    # tiled container (unit frames + directory footer)
MAGIC_HUF = b"CPTH1"      # device-entropy container: symbol sections are
                          # pre-packed canonical-Huffman bitstreams, the
                          # payload is NOT wrapped in an outer codec frame
ESC = 255


class ContainerError(ValueError):
    """Malformed, truncated, or corrupted container bytes.

    Every integrity check on the read path raises this (never a bare
    ``assert``, which vanishes under ``python -O`` and would turn a
    truncated or forged container into silent wrong output).  It
    subclasses ValueError so pre-existing ``except ValueError`` callers
    keep working.
    """


class ChecksumError(ContainerError):
    """A unit frame's stored checksum does not match its bytes.

    Distinct from generic ContainerError so degraded-mode readers can
    skip exactly the bit-rotted units while still refusing structural
    corruption (a forged directory is not salvageable; a flipped bit
    in one unit is)."""


# Per-unit checksum.  The design calls for CRC32C; no C-speed CRC32C
# implementation ships with CPython and this project adds no
# dependencies, so the container stores IEEE CRC32 (zlib.crc32, also
# C speed) and self-describes the algorithm in the footer under
# ``checksum`` -- a future reader/writer can switch algorithms without
# a layout change.
CHECKSUM_ALGO = "crc32"


def frame_crc(frame: bytes) -> int:
    """Checksum of one container frame (footer ``checksum`` algo)."""
    return zlib.crc32(frame) & 0xFFFFFFFF


def have_zstd() -> bool:
    return zstandard is not None


def backend_codec() -> str:
    """Name of the container codec pack() will use."""
    return "zstd" if zstandard is not None else "zlib"


def codec_compress(raw: bytes, level: int = 12) -> bytes:
    """Compress raw bytes with the available container codec.

    The zlib fallback caps at level 6: level 9 is ~11x slower for <1%
    size on residual symbol streams, which would dominate encode time.
    """
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return zlib.compress(raw, min(int(level), 6))


def codec_decompress(blob: bytes, codec: str) -> bytes:
    """Decompress one container frame; unknown codec names are refused.

    A corrupted/forged header used to fall through to zlib and decode
    to garbage; now anything but the two known codecs raises, and a
    frame that fails to decompress raises ContainerError.
    """
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "blob was packed with zstd but the 'zstandard' module is "
                "not installed; pip install zstandard to decode it"
            )
        try:
            return zstandard.ZstdDecompressor().decompress(blob)
        except zstandard.ZstdError as e:
            raise ContainerError(f"corrupt zstd frame: {e}") from e
    if codec == "zlib":
        try:
            return zlib.decompress(blob)
        except zlib.error as e:
            raise ContainerError(f"corrupt zlib frame: {e}") from e
    raise ValueError(
        f"unknown container codec {codec!r}; expected 'zstd' or 'zlib'")


# ----------------------------------------------------------------------
# symbol stream
# ----------------------------------------------------------------------

def fold_np(res):
    res = np.asarray(res, dtype=np.int64)
    return np.where(res >= 0, 2 * res, -2 * res - 1)


def unfold_np(z):
    z = np.asarray(z, dtype=np.int64)
    return np.where(z % 2 == 0, z // 2, -(z + 1) // 2)


def to_symbols(res):
    """int64 residuals -> (uint8 stream, int64 escapes)."""
    z = fold_np(res).reshape(-1)
    esc_mask = z >= ESC
    sym = np.where(esc_mask, ESC, z).astype(np.uint8)
    escapes = res.reshape(-1)[esc_mask].astype(np.int64)
    return sym, escapes


def from_symbols(sym, escapes, shape):
    z = sym.astype(np.int64)
    res = unfold_np(z)
    esc_mask = sym == ESC
    res[esc_mask] = escapes
    return res.reshape(shape)


# ----------------------------------------------------------------------
# field payload sections (shared by monolithic blobs and tiled units)
# ----------------------------------------------------------------------

def field_sections(res_u, res_v, lossless_np, u_ll, v_ll, bm) -> dict:
    """Symbolize one field payload (a full field or one tiled unit) into
    the canonical section dict -- the single place the section schema is
    assembled (core/pipeline.py routes every path through it)."""
    sym_u, esc_u = to_symbols(np.asarray(res_u))
    sym_v, esc_v = to_symbols(np.asarray(res_v))
    bm = np.asarray(bm)
    return {
        "sym_u": sym_u,
        "sym_v": sym_v,
        "esc_u": esc_u,
        "esc_v": esc_v,
        "lossless": np.packbits(lossless_np),
        "u_ll": np.asarray(u_ll),
        "v_ll": np.asarray(v_ll),
        "blockmap": np.packbits(bm),
        "bm_shape": np.asarray(bm.shape, dtype=np.int32),
    }


def parse_field_sections(sections: dict, shape):
    """Inverse of field_sections (minus the lossless raw values, which
    the caller scatters): -> (res_u, res_v, blockmap, lossless)."""
    T, H, W = shape
    res_u = from_symbols(sections["sym_u"], sections["esc_u"], shape)
    res_v = from_symbols(sections["sym_v"], sections["esc_v"], shape)
    bm_shape = tuple(int(x) for x in sections["bm_shape"])
    n_bm = int(np.prod(bm_shape))
    blockmap = np.unpackbits(sections["blockmap"], count=n_bm).astype(bool)
    blockmap = blockmap.reshape(bm_shape)
    lossless = np.unpackbits(sections["lossless"],
                             count=T * H * W).astype(bool)
    lossless = lossless.reshape(shape)
    return res_u, res_v, blockmap, lossless


# ----------------------------------------------------------------------
# canonical Huffman (reference entropy coder)
# ----------------------------------------------------------------------

def huffman_code_lengths(freq):
    """Code length per symbol via the standard heap construction."""
    items = [(int(f), i) for i, f in enumerate(freq) if f > 0]
    if not items:
        return np.zeros(len(freq), dtype=np.int32)
    if len(items) == 1:
        ln = np.zeros(len(freq), dtype=np.int32)
        ln[items[0][1]] = 1
        return ln
    heap = [(f, n, (s,)) for n, (f, s) in enumerate(items)]
    heapq.heapify(heap)
    counter = len(heap)
    depth = {}
    while len(heap) > 1:
        f1, _, s1 = heapq.heappop(heap)
        f2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            depth[s] = depth.get(s, 0) + 1
        counter += 1
        heapq.heappush(heap, (f1 + f2, counter, s1 + s2))
    ln = np.zeros(len(freq), dtype=np.int32)
    for s, d in depth.items():
        ln[s] = d
    return ln


def canonical_codes(lengths):
    """(codes uint32, lengths) canonical assignment."""
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint32)
    code = 0
    prev_len = 0
    for s in order:
        ln = int(lengths[s])
        if ln == 0:
            continue
        if prev_len == 0:
            prev_len = ln
        code <<= ln - prev_len
        codes[s] = code
        code += 1
        prev_len = ln
    return codes, lengths


def length_limited_lengths(freq, limit: int) -> np.ndarray:
    """Huffman code lengths clamped to ``limit`` bits.

    The clamp halves frequencies until the deepest leaf fits -- each
    iteration is still a valid Huffman tree (Kraft holds), and repeated
    halving drives the distribution toward uniform, whose depth for a
    256-symbol alphabet is 8, so the loop terminates for any limit >= 8.
    Used by the device entropy stage (core/entropy.py), whose bit-packer
    sizes its worst-case output buffer as n_symbols * limit bits.
    """
    freq = np.asarray(freq, dtype=np.int64)
    lengths = huffman_code_lengths(freq)
    while lengths.max() > limit:
        freq = np.where(freq > 0, (freq + 1) // 2, 0)
        lengths = huffman_code_lengths(freq)
    return lengths


def huffman_encode(sym):
    """uint8 symbols -> (lengths table, packed bits, n_symbols)."""
    freq = np.bincount(sym, minlength=256)
    lengths = huffman_code_lengths(freq)
    # keep ln + intra-byte offset <= 64 for the vectorized packer
    while lengths.max() > 56:
        freq = np.where(freq > 0, (freq + 1) // 2, 0)
        lengths = huffman_code_lengths(freq)
    codes, _ = canonical_codes(lengths)
    ln = lengths[sym].astype(np.int64)
    cd = codes[sym].astype(np.uint64)
    total = int(ln.sum())
    # vectorized MSB-first bit packing
    ends = np.cumsum(ln)
    starts = ends - ln
    nbytes = (total + 7) // 8
    buf = np.zeros(nbytes + 8, dtype=np.uint8)
    # write each symbol's code into a 64-bit window at its byte offset
    byte_off = (starts // 8).astype(np.int64)
    bit_off = (starts % 8).astype(np.int64)
    shift = (64 - bit_off - ln).astype(np.uint64)
    vals = (cd << shift).astype(">u8")
    # scatter with per-byte accumulation: process in 8 passes so windows
    # touching the same bytes never collide (codes <= 56 bits + 7 offset).
    view = vals.view(np.uint8).reshape(-1, 8)
    for b in range(8):
        np.add.at(buf, byte_off + b, view[:, b])
    return lengths, buf[:nbytes].tobytes(), len(sym)


def _peek_tables(lengths, codes, maxlen):
    peek = np.zeros(1 << maxlen, dtype=np.uint16)
    plen = np.zeros(1 << maxlen, dtype=np.uint8)
    for s in np.nonzero(np.asarray(lengths) > 0)[0]:
        ln = int(lengths[s])
        prefix = int(codes[s]) << (maxlen - ln)
        span = 1 << (maxlen - ln)
        peek[prefix : prefix + span] = s
        plen[prefix : prefix + span] = ln
    return peek, plen


def _huffman_decode_scalar(peek, plen, maxlen, data, n):
    """Reference per-symbol loop; only used for pathological maxlen."""
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))
    out = np.empty(n, dtype=np.uint8)
    pos = 0
    bits = np.concatenate([bits, np.zeros(maxlen, dtype=np.uint8)])
    pw = (1 << np.arange(maxlen - 1, -1, -1)).astype(np.uint64)
    for i in range(n):
        window = int(bits[pos : pos + maxlen] @ pw)
        out[i] = peek[window]
        pos += int(plen[window])
    return out


# primary peek table is capped at 2^24 entries (48 MB of tables); deeper
# trees (possible up to the encoder's 56-bit clamp, but requiring
# astronomically skewed inputs) take the scalar path.
_VEC_MAXLEN = 24
_STRIDE_LOG2 = 6


def huffman_decode(lengths, data, n, _chunk=1 << 22):
    """Table-driven canonical Huffman decode, vectorized.

    Chunked peek-table decode (DESIGN.md #3.6): stage 1 speculatively
    decodes (symbol, code length) at EVERY bit offset of the stream with
    the canonical peek table -- pure vectorized gathers, processed in
    ``_chunk``-sized position blocks to bound transient memory.  Stage 2
    resolves the true symbol-boundary chain pos_{i+1} = pos_i +
    len(pos_i) with jump tables: 2^k-symbol jumps for k <= 6 (six
    vectorized passes), a Python walk over every 64th boundary only
    (n/64 steps), then vectorized interleave-expansion back to all n
    positions -- no per-symbol Python loop.
    """
    if n == 0:
        return np.empty(0, dtype=np.uint8)
    codes, _ = canonical_codes(lengths)
    maxlen = int(lengths.max()) if lengths.max() > 0 else 1
    peek, plen = _peek_tables(lengths, codes, maxlen)
    if maxlen > _VEC_MAXLEN or n < 2048:
        return _huffman_decode_scalar(peek, plen, maxlen, data, n)

    raw = np.frombuffer(data, dtype=np.uint8)
    nbits = 8 * len(raw)
    # 64-bit big-endian rolling windows, one per byte offset (8 ORs)
    raw = np.concatenate([raw, np.zeros(16, dtype=np.uint8)])
    nwin = len(raw) - 8
    w64 = np.zeros(nwin, dtype=np.uint64)
    for k in range(8):
        w64 |= raw[k : k + nwin].astype(np.uint64) << np.uint64(56 - 8 * k)

    # stage 1: next-position + symbol for every bit offset
    dom = nbits + maxlen + 1          # padded position domain
    pos_dtype = np.int32 if dom < 2**31 else np.int64
    nxt = np.empty(dom, dtype=pos_dtype)
    sym_at = np.empty(dom, dtype=np.uint8)
    top = np.uint64(64 - maxlen)
    for lo in range(0, dom, _chunk):
        hi = min(lo + _chunk, dom)
        p = np.arange(lo, hi, dtype=np.int64)
        win = (w64[p >> 3] << (p & 7).astype(np.uint64)) >> top
        sym_at[lo:hi] = peek[win]
        nxt[lo:hi] = np.minimum(p + plen[win], dom - 1).astype(pos_dtype)

    # stage 2: jump tables J[k] (2^k symbols per jump)
    L = _STRIDE_LOG2
    J = [nxt]
    for _ in range(L):
        J.append(J[-1][J[-1]])
    # walk only every 2^L-th boundary sequentially
    n_anchor = -(-n // (1 << L))
    anchors = np.empty(n_anchor, dtype=np.int64)
    jl = J[L]
    pos = 0
    for i in range(n_anchor):
        anchors[i] = pos
        pos = int(jl[pos])
    # expand anchors back to every boundary (interleave per level)
    P = anchors
    for k in range(L - 1, -1, -1):
        Q = np.empty(2 * len(P), dtype=np.int64)
        Q[0::2] = P
        Q[1::2] = J[k][P]
        P = Q
    return sym_at[P[:n]]


def huffman_stream_size_bits(sym):
    freq = np.bincount(sym, minlength=256)
    lengths = huffman_code_lengths(freq)
    return int((lengths[sym]).sum())


# ----------------------------------------------------------------------
# container
# ----------------------------------------------------------------------

class HuffSection:
    """A section whose bytes are already entropy-coded (device stage).

    ``data`` is a canonical-Huffman bitstream over ``n`` uint8 symbols,
    packed MSB-first; ``lengths`` is the 256-entry code-length table
    (uint8, max ``entropy.L_MAX`` bits).  ``pack`` stores the table in
    the section index so ``unpack`` can rebuild the exact uint8 symbol
    array -- downstream parsing (``parse_field_sections``) never sees
    the difference between the host and device codecs.
    """

    __slots__ = ("data", "lengths", "n")

    def __init__(self, data: bytes, lengths, n: int):
        self.data = bytes(data)
        self.lengths = np.ascontiguousarray(lengths, dtype=np.uint8)
        self.n = int(n)


# small non-symbol sections inside a CPTH1 frame (escapes, lossless
# bitmaps, raw float values) get an individual zlib pass; below this
# size the 11-byte zlib framing is pure overhead
_HUF_ZLIB_MIN = 64


def pack(header: dict, sections: dict, level: int = 12) -> bytes:
    """Assemble one container frame.

    Two framings share the section-index layout: the host codecs wrap
    the whole payload in one zstd/zlib frame (magic CPTZ1/CPTL1), while
    a sections dict containing ``HuffSection`` values produces a CPTH1
    frame -- symbol sections stay as their packed Huffman bitstreams
    (re-compressing them would buy nothing), other sections are
    zlib-compressed individually, and the payload is stored raw.  Every
    frame self-describes its codec (magic + header ``codec`` tag), so
    readers never guess.
    """
    if any(isinstance(a, HuffSection) for a in sections.values()):
        return _pack_huf(header, sections)
    body = io.BytesIO()
    sec_index = {}
    for name, arr in sections.items():
        raw = np.ascontiguousarray(arr).tobytes()
        sec_index[name] = {
            "off": body.tell(),
            "len": len(raw),
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
        body.write(raw)
    header = dict(header)
    header["sections"] = sec_index
    header["codec"] = backend_codec()
    hdr = msgpack.packb(header, use_bin_type=True)
    payload = struct.pack("<I", len(hdr)) + hdr + body.getvalue()
    magic = MAGIC if zstandard is not None else MAGIC_ZLIB
    return magic + codec_compress(payload, level)


def _pack_huf(header: dict, sections: dict) -> bytes:
    body = io.BytesIO()
    sec_index = {}
    for name, arr in sections.items():
        if isinstance(arr, HuffSection):
            sec_index[name] = {
                "off": body.tell(),
                "len": len(arr.data),
                "dtype": "uint8",
                "shape": [arr.n],
                "enc": "huff",
                "lengths": arr.lengths.tobytes(),
            }
            body.write(arr.data)
            continue
        raw = np.ascontiguousarray(arr).tobytes()
        meta = {
            "off": body.tell(),
            "len": len(raw),
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
        if len(raw) >= _HUF_ZLIB_MIN:
            comp = zlib.compress(raw, 6)
            if len(comp) < len(raw):
                meta["len"] = len(comp)
                meta["enc"] = "zlib"
                raw = comp
        sec_index[name] = meta
        body.write(raw)
    header = dict(header)
    header["sections"] = sec_index
    header["codec"] = "huffman"
    hdr = msgpack.packb(header, use_bin_type=True)
    return (MAGIC_HUF + struct.pack("<I", len(hdr)) + hdr
            + body.getvalue())


def _decode_section(name: str, meta: dict, raw: bytes) -> np.ndarray:
    """One section's bytes -> array, honoring its per-section ``enc``."""
    enc = meta.get("enc")
    try:
        dtype, shape = meta["dtype"], meta["shape"]
        if enc == "huff":
            lengths = np.frombuffer(meta["lengths"], np.uint8)
            if lengths.size != 256:
                raise ContainerError(
                    f"section {name!r}: huffman table has {lengths.size} "
                    f"entries, expected 256")
            n = int(np.prod(shape, dtype=np.int64))
            from . import entropy
            arr = entropy.decode_symbols(lengths, raw, n)
        elif enc == "zlib":
            arr = np.frombuffer(zlib.decompress(raw), dtype=np.dtype(dtype))
        elif enc is None:
            arr = np.frombuffer(raw, dtype=np.dtype(dtype))
        else:
            raise ContainerError(
                f"section {name!r}: unknown encoding {enc!r}")
        return arr.reshape(shape)
    except ContainerError:
        raise
    except (TypeError, ValueError, zlib.error) as e:
        raise ContainerError(f"corrupt section {name!r}: {e}") from e


def unpack(blob: bytes):
    magic = blob[: len(MAGIC)]
    if magic == MAGIC_HUF:
        return _unpack_huf(blob)
    if magic not in (MAGIC, MAGIC_ZLIB):
        raise ContainerError("not a CPTZ/CPTL container (bad magic)")
    codec = "zstd" if magic == MAGIC else "zlib"
    payload = codec_decompress(blob[len(MAGIC):], codec)
    return _parse_payload(payload)


def _unpack_huf(blob: bytes):
    return _parse_payload(bytes(blob[len(MAGIC_HUF):]))


def _parse_payload(payload: bytes):
    if len(payload) < 4:
        raise ContainerError("truncated container: missing header length")
    (hlen,) = struct.unpack("<I", payload[:4])
    if 4 + hlen > len(payload):
        raise ContainerError(
            f"truncated container: header length {hlen} exceeds "
            f"{len(payload)}-byte payload")
    try:
        header = msgpack.unpackb(payload[4 : 4 + hlen], raw=False)
    except Exception as e:
        raise ContainerError(f"corrupt container header: {e}") from e
    if not isinstance(header, dict) or "sections" not in header:
        raise ContainerError("container header has no sections index")
    base = 4 + hlen
    sections = {}
    sec_index = header.pop("sections")
    if not isinstance(sec_index, dict):
        raise ContainerError("container sections index is not a map")
    for name, meta in sec_index.items():
        try:
            off, ln = meta["off"], meta["len"]
        except (TypeError, KeyError) as e:
            raise ContainerError(
                f"malformed section entry {name!r}: {e}") from e
        if not (isinstance(off, int) and isinstance(ln, int)):
            raise ContainerError(
                f"malformed section entry {name!r}: non-integer "
                f"off/len {off!r}/{ln!r}")
        lo = base + off
        hi = lo + ln
        if off < 0 or hi > len(payload):
            raise ContainerError(
                f"section {name!r} byte range [{lo}, {hi}) outside "
                f"{len(payload)}-byte payload")
        if "dtype" not in meta or "shape" not in meta:
            raise ContainerError(
                f"malformed section entry {name!r}: missing dtype/shape")
        sections[name] = _decode_section(name, meta, payload[lo:hi])
    return header, sections


# ----------------------------------------------------------------------
# tiled container: random-access unit frames + directory footer
# ----------------------------------------------------------------------
#
# Layout, version 4 (streaming-writable: units are emitted before the
# directory is known, so the directory lives in a FOOTER, not a
# preamble):
#
#     MAGIC_TILED
#     | "CPPR" u32 len u32 crc | prologue frame          (version >= 4)
#     | "CPUN" u32 len u32 crc | unit frame              (repeated)
#     | zlib(msgpack header) | u32 header_len | MAGIC_TILED
#
# Each unit frame is a fully self-describing pack() container (magic +
# codec payload), so random access to one (tile, window) unit is a byte
# slice at the directory's (off, len) followed by one unpack() -- no
# other unit is touched.  The footer header carries the global stream
# parameters plus a ``units`` directory: one entry per unit with its
# grid key, owned space-time box, byte offset, length and (v4) CRC.
#
# The 12-byte frame preambles added in v4 make the body WALKABLE
# without the footer: ``salvage_container`` rebuilds the directory of
# a truncated/footerless archive by scanning preambles, checking each
# frame's CRC, and resynchronizing on the "CPUN" mark across damaged
# spans.  The prologue frame repeats the global decode parameters that
# normally live only in the footer, so a salvaged archive is fully
# decodable.  Directory offsets keep pointing at the FRAME (past the
# preamble), so every pre-existing (off, len) reader works unchanged;
# version-3 archives (no preambles, no CRCs) stay readable because
# nothing on the directory-driven read path looks between frames and
# checksum verification keys off the entry's ``crc`` field being
# present.
#
# Forward compatibility: the footer header is a msgpack map and readers
# only look up the keys they know, so OPTIONAL sections ride along as
# extra keys that old readers skip without parsing.  The trajectory
# sidecar index (repro/analysis/index.py) is stored this way under
# TRACK_INDEX_KEY, with its own internal version number -- adding or
# evolving it never bumps the container version and never disturbs unit
# byte offsets (tests/test_container_golden.py pins both properties).

TRACK_INDEX_KEY = "track_index"

UNIT_MARK = b"CPUN"       # v4 per-unit frame preamble mark
PROLOGUE_MARK = b"CPPR"   # v4 prologue frame preamble mark
_PREAMBLE = struct.Struct("<II")          # (frame_len, frame_crc)
PREAMBLE_LEN = len(UNIT_MARK) + _PREAMBLE.size


def _preamble(mark: bytes, frame: bytes) -> bytes:
    return mark + _PREAMBLE.pack(len(frame), frame_crc(frame))


def pack_ndarray(arr) -> dict:
    """msgpack-able {dtype, shape, data} triple for a numpy array."""
    arr = np.ascontiguousarray(arr)
    return {
        "dtype": str(arr.dtype),
        "shape": [int(s) for s in arr.shape],
        "data": arr.tobytes(),
    }


def unpack_ndarray(d: dict) -> np.ndarray:
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return arr.reshape(d["shape"])


def is_tiled(blob: bytes) -> bool:
    return blob[: len(MAGIC_TILED)] == MAGIC_TILED


class TiledWriter:
    """Append-only tiled-container writer.

    Works against any binary ``sink`` with ``write`` (a file, a socket
    wrapper); when ``sink`` is None an in-memory buffer is used and
    ``finish`` returns the full blob bytes.  Unit payloads are written
    as they arrive -- nothing is buffered -- which is what makes
    compress_stream's memory footprint independent of the field length.
    """

    def __init__(self, sink=None, level: int = 12, prologue: dict = None):
        self._own = sink is None
        self._sink = io.BytesIO() if sink is None else sink
        self._level = level
        self._sink.write(MAGIC_TILED)
        self._pos = len(MAGIC_TILED)
        self.units = []
        if prologue is not None:
            frame = pack(dict(prologue), {}, self._level)
            self._sink.write(_preamble(PROLOGUE_MARK, frame))
            self._sink.write(frame)
            self._pos += PREAMBLE_LEN + len(frame)

    @classmethod
    def resumed(cls, sink, pos: int, units: list,
                level: int = 12) -> "TiledWriter":
        """Reattach to a partially written container for crash resume.

        ``sink`` must already be positioned at byte ``pos`` (the caller
        truncates the file to the journal's durable frontier first);
        ``units`` is the directory recovered from the journal.  Nothing
        is written here -- the next ``add_unit`` appends exactly where
        the interrupted run would have.
        """
        w = cls.__new__(cls)
        w._own = False
        w._sink = sink
        w._level = level
        w._pos = int(pos)
        w.units = [dict(u) for u in units]
        return w

    def add_unit(self, key, box, header: dict, sections: dict) -> None:
        """Append one (window, tile) unit; records its directory entry.

        key: (wi, ti, tj) grid coordinates; box: (t0, t1, i0, i1, j0, j1)
        half-open owned ranges (duplicated into the directory so read
        planning never needs to decode a unit).  The key is also stamped
        into the unit's own header and the frame is preceded by a
        "CPUN" length+CRC preamble, which together make the body
        walkable by ``salvage_container`` with no footer at all.
        """
        header = dict(header)
        header["key"] = [int(k) for k in key]
        frame = pack(header, sections, self._level)
        self._sink.write(_preamble(UNIT_MARK, frame))
        self._pos += PREAMBLE_LEN
        self.units.append({
            "key": [int(k) for k in key],
            "box": [int(b) for b in box],
            "off": self._pos,
            "len": len(frame),
            "crc": frame_crc(frame),
        })
        self._sink.write(frame)
        self._pos += len(frame)

    def finish(self, header: dict):
        """Write the directory footer.  Returns the blob when buffering."""
        header = dict(header)
        header["units"] = self.units
        header.setdefault("checksum", CHECKSUM_ALGO)
        hdr = zlib.compress(msgpack.packb(header, use_bin_type=True), 6)
        self._sink.write(hdr)
        self._sink.write(struct.pack("<I", len(hdr)))
        self._sink.write(MAGIC_TILED)
        self._pos += len(hdr) + 4 + len(MAGIC_TILED)
        if self._own:
            return self._sink.getvalue()
        return None

    @property
    def bytes_written(self) -> int:
        return self._pos


def tiled_footer_ranged(read, size: int):
    """(header dict, compressed footer bytes) via a range reader.

    ``read(off, ln) -> bytes`` over a container of ``size`` bytes --
    the primitive for file/remote sources where loading the whole blob
    would defeat read planning (three small reads: magic, length word,
    footer).  The raw footer bytes double as a content fingerprint for
    the decoded-unit cache (analysis/query.py)."""
    m = len(MAGIC_TILED)
    if size < 2 * m + 4:
        raise ContainerError(
            f"truncated tiled container: {size} bytes is smaller than "
            f"the minimal frame")
    if read(0, m) != MAGIC_TILED:
        raise ContainerError("not a CPTT tiled container (bad magic)")
    tail = read(size - m - 4, m + 4)
    if tail[-m:] != MAGIC_TILED:
        raise ContainerError("truncated tiled container (no footer)")
    (hlen,) = struct.unpack("<I", tail[:4])
    if hlen + 2 * m + 4 > size:
        raise ContainerError(
            f"corrupt tiled footer: header length {hlen} exceeds "
            f"{size}-byte container")
    raw = read(size - m - 4 - hlen, hlen)
    try:
        header = msgpack.unpackb(zlib.decompress(raw), raw=False)
    except Exception as e:
        raise ContainerError(f"corrupt tiled footer: {e}") from e
    if not isinstance(header, dict) or "units" not in header:
        raise ContainerError("tiled footer has no unit directory")
    units = header["units"]
    if not isinstance(units, list) or any(
            not isinstance(e, dict)
            or not {"key", "box", "off", "len"} <= e.keys()
            for e in units):
        raise ContainerError("tiled footer unit directory is malformed")
    for e in units:
        off, ln = e["off"], e["len"]
        if not (isinstance(off, int) and isinstance(ln, int)
                and m <= off and 0 <= ln and off + ln <= size):
            raise ContainerError(
                f"unit directory entry {e['key']} byte range "
                f"[{off}, {off + ln}) outside [{m}, {size})")
    return header, raw


def tiled_header_ranged(read, size: int) -> dict:
    """Directory footer via an (offset, length) range reader."""
    return tiled_footer_ranged(read, size)[0]


def tiled_header(blob: bytes) -> dict:
    """Directory footer of a tiled container (header dict incl. units)."""
    return tiled_header_ranged(lambda off, ln: blob[off : off + ln],
                               len(blob))


def check_unit_frame(frame: bytes, entry: dict) -> None:
    """Verify one unit frame against its directory entry's CRC.

    No-op for pre-v4 entries (no ``crc`` key): old containers carry no
    per-unit checksum and stay readable.  Raises :class:`ChecksumError`
    on mismatch so degraded readers can skip exactly this unit.
    """
    want = entry.get("crc")
    if want is None:
        return
    got = frame_crc(frame)
    if got != int(want):
        raise ChecksumError(
            f"unit {entry.get('key')} checksum mismatch: stored "
            f"{int(want):#010x}, frame bytes hash to {got:#010x} "
            f"(bit rot or torn write)")


def read_tiled_unit_ranged(read, entry: dict):
    """Decode ONE unit frame via an (offset, length) range reader."""
    frame = read(entry["off"], entry["len"])
    if len(frame) != entry["len"]:
        raise ContainerError(
            f"short read: unit frame at [{entry['off']}, "
            f"{entry['off'] + entry['len']}) returned {len(frame)} bytes "
            f"(truncated container?)")
    check_unit_frame(frame, entry)
    return unpack(frame)


def read_tiled_unit(blob: bytes, entry: dict):
    """Decode ONE unit frame by directory entry -- touches only its bytes."""
    return read_tiled_unit_ranged(lambda off, ln: blob[off : off + ln],
                                  entry)


# ----------------------------------------------------------------------
# salvage: rebuild the directory of a truncated / footerless archive
# ----------------------------------------------------------------------

def _scan_frames(data: bytes):
    """Walk v4 frame preambles.  Yields dicts per recovered frame:
    {"mark", "off" (frame start), "len", "crc", "header"} -- only frames
    whose CRC matches and whose header msgpack-decodes are yielded;
    damaged spans are skipped by resynchronizing on the "CPUN" mark.
    Returns (frames, n_dropped, legacy) where legacy=True means no v4
    preambles were found at all (pre-v4 archive)."""
    m = len(MAGIC_TILED)
    frames, n_dropped = [], 0
    pos = m
    if data[pos: pos + len(PROLOGUE_MARK)] not in (PROLOGUE_MARK, UNIT_MARK):
        return frames, n_dropped, True
    while True:
        mark = data[pos: pos + 4]
        if mark not in (PROLOGUE_MARK, UNIT_MARK):
            nxt = data.find(UNIT_MARK, pos + 1)
            if nxt < 0:
                break
            n_dropped += 1
            pos = nxt
            continue
        body = pos + PREAMBLE_LEN
        if body > len(data):
            break                      # torn preamble at EOF
        ln, crc = _PREAMBLE.unpack(data[pos + 4: body])
        frame = data[body: body + ln]
        ok = len(frame) == ln and frame_crc(frame) == crc
        header = None
        if ok:
            try:
                header, _ = unpack(frame)
            except ContainerError:
                ok = False             # false mark hit inside a payload
        if not ok:
            nxt = data.find(UNIT_MARK, pos + 1)
            if nxt < 0:
                break
            n_dropped += 1
            pos = nxt
            continue
        frames.append({"mark": bytes(mark), "off": body, "len": ln,
                       "crc": crc, "header": header})
        pos = body + ln
    return frames, n_dropped, False


def salvage_container(data, out=None, fallback_header: dict = None):
    """Rebuild a readable tiled container from a damaged archive.

    ``data`` is the raw bytes (or a path) of a tiled container whose
    footer is missing/corrupt or whose body has damaged spans.  The v4
    body is walked via the per-frame preambles; every unit whose CRC
    verifies is copied into a fresh container and a new directory
    footer is synthesized from the prologue frame's global parameters
    (or ``fallback_header`` when the prologue itself was destroyed).

    Returns ``(blob, report)``; when ``out`` is a path the blob is
    written there and ``blob`` is None.  ``report`` counts recovered /
    dropped units and scanned bytes.  Pre-v4 archives have no frame
    preambles to walk and are refused with ContainerError.
    """
    if isinstance(data, (str, bytes)) and not isinstance(data, bytes):
        with open(data, "rb") as f:
            data = f.read()
    if data[: len(MAGIC_TILED)] != MAGIC_TILED:
        raise ContainerError("not a CPTT tiled container (bad magic)")
    frames, n_dropped, legacy = _scan_frames(data)
    if legacy:
        raise ContainerError(
            "archive has no v4 frame preambles (pre-v4 container); "
            "nothing to walk -- salvage needs the footer, which is "
            "the only directory a version<=3 archive has")
    prologue = None
    prologue_found = False
    units = []
    for fr in frames:
        if fr["mark"] == PROLOGUE_MARK:
            if prologue is None:
                prologue = fr["header"]
                prologue_found = True
            continue
        hdr = fr["header"]
        if "key" not in hdr or "box" not in hdr:
            n_dropped += 1
            continue
        units.append(fr)
    if prologue is None:
        if fallback_header is None:
            raise ContainerError(
                "prologue frame unrecoverable and no fallback_header "
                "given; cannot synthesize decode parameters")
        prologue = dict(fallback_header)
    header = {k: v for k, v in prologue.items() if k != "prologue"}
    shape = list(header.get("shape", [0, 0, 0]))
    if units:
        shape[0] = max(int(fr["header"]["box"][1]) for fr in units)
    header["shape"] = shape
    header["salvaged"] = True
    header.setdefault("checksum", CHECKSUM_ALGO)

    buf = io.BytesIO()
    buf.write(MAGIC_TILED)
    pframe = pack(dict(prologue), {})
    buf.write(_preamble(PROLOGUE_MARK, pframe))
    buf.write(pframe)
    directory = []
    for fr in sorted(units, key=lambda f: tuple(f["header"]["key"])):
        frame = data[fr["off"]: fr["off"] + fr["len"]]
        buf.write(_preamble(UNIT_MARK, frame))
        directory.append({
            "key": [int(k) for k in fr["header"]["key"]],
            "box": [int(b) for b in fr["header"]["box"]],
            "off": buf.tell(),
            "len": fr["len"],
            "crc": fr["crc"],
        })
        buf.write(frame)
    header["units"] = directory
    raw = zlib.compress(msgpack.packb(header, use_bin_type=True), 6)
    buf.write(raw)
    buf.write(struct.pack("<I", len(raw)))
    buf.write(MAGIC_TILED)
    blob = buf.getvalue()
    report = {
        "units_recovered": len(directory),
        "units_dropped": n_dropped,
        "bytes_scanned": len(data),
        "bytes_recovered": len(blob),
        "prologue_recovered": prologue_found,
    }
    if out is not None:
        with open(out, "wb") as f:
            f.write(blob)
        return None, report
    return blob, report


# ----------------------------------------------------------------------
# write-ahead journal (sidecar of a streaming compression run)
# ----------------------------------------------------------------------
#
# The journal is an append-only sidecar file next to the container
# being streamed (``<container>.journal``).  Records are length- and
# CRC-framed msgpack maps:
#
#     "CPTJ1" | u32 len | u32 crc | msgpack(record) | ...
#
# Record types (record["t"]):
#   "begin"  run fingerprint (grid/config/shape) + data_start offset
#   "unit"   one emitted unit: directory entry + sidecar-index rows
#   "ckpt"   a durable frontier: everything needed to resume --
#            container byte position, scheduler counters, and the
#            zlib-packed eb/forced planes of every still-resident frame
#
# A crash can tear at most the final record; the reader stops at the
# first length/CRC mismatch and resumes from the last intact "ckpt".
# fsync ordering: the DATA file is flushed+fsynced before the "ckpt"
# record is appended and fsynced, so a checkpoint never claims bytes
# the container does not durably have.

JOURNAL_MAGIC = b"CPTJ1"


def fsync_timed(fileno: int) -> None:
    """``os.fsync`` with obs accounting -- every durability point in
    the journal/stream path routes through here so fsync count and
    latency (``journal.fsync`` / ``journal.fsync_ns``) are one
    snapshot away when diagnosing a slow archive run."""
    from .. import obs

    obs.counter("journal.fsync").add(1)
    if obs.enabled():
        t0 = time.perf_counter_ns()
        os.fsync(fileno)
        obs.histogram("journal.fsync_ns").observe(
            time.perf_counter_ns() - t0)
    else:
        os.fsync(fileno)


class JournalWriter:
    """Append-only, CRC-framed journal for crash-recoverable streaming."""

    def __init__(self, path: str, fresh: bool = True):
        self.path = path
        self._f = open(path, "wb" if fresh else "ab")
        if fresh:
            self._f.write(JOURNAL_MAGIC)
            self._f.flush()

    def append(self, record: dict, sync: bool = False) -> None:
        raw = msgpack.packb(record, use_bin_type=True)
        self._f.write(struct.pack("<II", len(raw), frame_crc(raw)))
        self._f.write(raw)
        if sync:
            self._f.flush()
            fsync_timed(self._f.fileno())

    def close(self) -> None:
        self._f.flush()
        self._f.close()


def read_journal(path: str):
    """All intact records of a journal; a torn tail is tolerated.

    Returns [] for an empty/absent journal.  Raises ContainerError only
    when the file exists but is not a journal at all (bad magic) --
    a half-written final record is the EXPECTED crash artifact and
    simply ends the scan.
    """
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return []
    if not data:
        return []
    if data[: len(JOURNAL_MAGIC)] != JOURNAL_MAGIC:
        raise ContainerError(f"{path}: not a CPTJ1 journal")
    records = []
    pos = len(JOURNAL_MAGIC)
    while pos + 8 <= len(data):
        ln, crc = struct.unpack("<II", data[pos: pos + 8])
        raw = data[pos + 8: pos + 8 + ln]
        if len(raw) != ln or frame_crc(raw) != crc:
            break                      # torn tail: stop at last intact
        try:
            records.append(msgpack.unpackb(raw, raw=False))
        except Exception:
            break
        pos += 8 + ln
    return records


