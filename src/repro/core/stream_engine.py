"""Out-of-core concurrent streaming engine (DESIGN.md #11).

``compress_stream`` used to process windows strictly serially: host
frame ingestion, device encode/verify, and CPU symbolize/pack took
turns on one thread, so the device idled during zstd packing and the
packer idled during verify rounds.  This module runs the SAME window
state machine with the three stages overlapped:

    ingest thread   -- pulls (u_t, v_t) frames from the source iterable
                       (window N+1), converts to float32 and precomputes
                       the fixed-point planes, hands frames over a
                       bounded queue;
    compute thread  -- (the caller's thread) owns ALL device work and
                       the sliding plane storage: window derivation, the
                       seam-agreed verify fixpoint, and the final-mask
                       encode of window N via the shared PlanExecutor
                       batched stages (core/pipeline.py);
    writer thread   -- consumes per-unit payloads (core/tiling.py
                       ``_UnitPayload``) in emission order: symbolize,
                       pack (zstd/zlib) and TiledWriter emission of
                       window N-1, plus track-index bookkeeping.

Why the bytes cannot change: the scheduler below is the one state
machine both modes run (``Scheduler``), so derive/fixpoint/emit
decisions are identical; payloads are produced in the serial emission
order and the writer queue is FIFO, so units hit the TiledWriter in the
same order at the same offsets; and symbolize/pack are deterministic
pure functions of the payload.  Only WHEN work happens moves across
threads -- never WHAT is computed.  Asserted end-to-end in
tests/test_stream_async.py and the ``async_vs_serial`` benchmark
section.

Why memory stays bounded (~2 windows, preserved from the serial
engine): the plane store still drops frames behind the pending
frontier, the ingest queue holds at most one window of frames ahead,
and the writer queue holds at most ~2 windows of unit payloads
(residual streams, ~1/4 the footprint of raw frames); a slow sink
back-pressures the compute thread instead of growing the queue.
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np

from . import tiling


class Scheduler:
    """The window state machine shared by the serial and async engines.

    Transplanted verbatim from the pre-engine ``compress_stream`` loop
    (PR 2): derive every window whose halo extension is fully buffered,
    run the seam-agreed fixpoint over derived-but-unemitted windows,
    emit each window once the NEXT window's fixpoint has run (its
    verify outcome is then sealed), and drop frame planes behind the
    pending frontier.  ``emit`` receives ``_UnitPayload`` objects in
    the canonical emission order; the engines differ only in where
    that callable runs the CPU pack.
    """

    def __init__(self, st, cfg, grid, emit):
        self.st = st
        self.cfg = cfg
        self.grid = grid
        self.emit = emit
        self.windows = []       # every derived window, in order
        self.pending = []       # derived, not yet emitted (ordered)
        self.frontier = 0       # frames below this are sealed
        self.next_w = 0         # next window index to derive
        self.T = 0
        self.eof = False

    def add_frame(self, u_t, v_t, ufp_t=None, vfp_t=None):
        tiling._add_frame(self.st, self.T, u_t, v_t, ufp_t, vfp_t)
        self.T += 1
        if self._derive_ready():
            self._advance()

    def finish(self):
        self.eof = True
        self._derive_ready()
        self._advance()
        if self.pending:
            raise RuntimeError("scheduler left unemitted windows")

    def _derive_ready(self):
        """Derive every window whose extension is fully buffered."""
        st, grid = self.st, self.grid
        out = []
        while True:
            t0 = self.next_w * grid.window_t
            if t0 >= self.T:
                break
            t1 = min(t0 + grid.window_t, self.T)
            full = t1 == t0 + grid.window_t and self.T >= t1 + grid.thalo
            if not (full or self.eof):
                break
            et1 = min(t1 + grid.thalo, self.T)
            w = tiling._Window(
                self.next_w, t0, t1,
                tiling.window_specs(self.next_w, t0, t1, st.H, st.W,
                                    et1, grid))
            tiling._derive_window(st, w)
            self.windows.append(w)
            self.pending.append(w)
            self.next_w += 1
            out.append(w)
        return out

    def _advance(self):
        """Fixpoint + emit everything the derive frontier allows."""
        st, grid = self.st, self.grid
        if not self.pending:
            return
        eb_final_hi = self.T if self.eof else self.windows[-1].t1
        fix = [w for w in self.pending if w.et1 <= eb_final_hi]
        if not fix:
            return
        if self.cfg.verify:
            tiling._fixpoint(st, fix, frontier=self.frontier)
        emit_hi = len(fix) if self.eof else len(fix) - 1
        for w in fix[:emit_hi]:
            for p in tiling._unit_payloads(st, w):
                self.emit(p)
            self.pending.remove(w)
            self.frontier = w.t1
        if self.pending:
            keep = self.pending[0].t0 - grid.thalo
            for planes in (st.u, st.v, st.ufp, st.vfp, st.eb, st.forced):
                planes.drop_below(keep)


def run(pairs, cfg, grid, value_range, sink=None, async_engine=False):
    """Streaming-compress ``pairs`` with the serial or async engine.
    Entry point for ``tiling.compress_stream`` (which owns the
    config/grid defaulting and the no-value-range fallback)."""
    t_start = time.perf_counter()
    if async_engine:
        blob, stats = _AsyncEngine(cfg, grid, value_range, sink).run(
            pairs, t_start)
    else:
        blob, stats = _run_serial(pairs, cfg, grid, value_range, sink,
                                  t_start)
    stats["async_engine"] = bool(async_engine)
    return blob, stats


def _run_serial(pairs, cfg, grid, value_range, sink, t_start):
    st = None
    sched = None
    for uf, vf in pairs:
        uf = np.asarray(uf, np.float32)
        if sched is None:
            H, W = uf.shape
            st = tiling._init_state(cfg, grid, H, W, value_range, sink)
            sched = Scheduler(st, cfg, grid,
                              emit=lambda p: tiling._write_unit(st, p))
        sched.add_frame(uf, vf)
    if sched is None or sched.T < 2:
        raise ValueError("need at least 2 frames")
    sched.finish()
    blob = st.writer.finish(tiling._finish_header(st, sched.T))
    return blob, tiling._stats(st, sched.T, blob, t_start)


_EOF = object()


class _AsyncEngine:
    """Three-stage overlapped engine; see the module docstring."""

    def __init__(self, cfg, grid, value_range, sink):
        self.cfg = cfg
        self.grid = grid
        self.value_range = value_range
        self.sink = sink
        # at most ~one window of frames buffered ahead of the planes
        self.q_in = queue.Queue(maxsize=max(grid.window_t, 2))
        self.q_out = None           # sized once the tile count is known
        self.stop = threading.Event()
        self.scale = None           # set after state init; read by ingest
        self._ingest_exc = None
        self._writer_exc = None
        self.st = None

    # ---- ingest stage ---------------------------------------------------

    def _ingest(self, pairs):
        try:
            for uf, vf in pairs:
                uf = np.asarray(uf, np.float32)
                vf = np.asarray(vf, np.float32)
                scale = self.scale
                ufp = vfp = None
                if scale is not None:
                    # deterministic: bit-equal wherever it is computed
                    ufp = np.round(uf.astype(np.float64) * scale)
                    vfp = np.round(vf.astype(np.float64) * scale)
                if not self._put(self.q_in, (uf, vf, ufp, vfp)):
                    return
        except BaseException as e:  # propagate to the compute thread
            self._ingest_exc = e
        finally:
            self._put(self.q_in, _EOF, force=True)

    # ---- writer stage ---------------------------------------------------

    def _writer(self):
        try:
            while True:
                p = self.q_out.get()
                if p is _EOF:
                    return
                tiling._write_unit(self.st, p)
        except BaseException as e:
            self._writer_exc = e
            # drain so a blocked compute-thread put can never deadlock
            while True:
                p = self.q_out.get()
                if p is _EOF:
                    return

    def _put(self, q, item, force=False):
        """Queue put that stays responsive to shutdown/stage failure."""
        while True:
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                if not force and self.stop.is_set():
                    return False

    def _emit(self, p):
        if self._writer_exc is not None:
            raise self._writer_exc
        self._put(self.q_out, p, force=True)

    # ---- compute stage (caller thread) ----------------------------------

    def run(self, pairs, t_start):
        ingest = threading.Thread(target=self._ingest, args=(pairs,),
                                  name="repro-stream-ingest", daemon=True)
        writer = threading.Thread(target=self._writer,
                                  name="repro-stream-writer", daemon=True)
        ingest.start()
        sched = None
        try:
            while True:
                item = self.q_in.get()
                if item is _EOF:
                    break
                uf, vf, ufp, vfp = item
                if sched is None:
                    H, W = uf.shape
                    self.st = tiling._init_state(
                        self.cfg, self.grid, H, W, self.value_range,
                        self.sink)
                    self.scale = self.st.scale
                    nti = -(-H // self.grid.tile_h)
                    ntj = -(-W // self.grid.tile_w)
                    # ~2 windows of unit payloads in flight, max
                    self.q_out = queue.Queue(
                        maxsize=max(2 * nti * ntj, 2))
                    writer.start()
                    sched = Scheduler(self.st, self.cfg, self.grid,
                                      emit=self._emit)
                sched.add_frame(uf, vf, ufp, vfp)
            if self._ingest_exc is not None:
                raise self._ingest_exc
            if sched is None or sched.T < 2:
                raise ValueError("need at least 2 frames")
            sched.finish()
            self._put(self.q_out, _EOF, force=True)
            writer.join()
            if self._writer_exc is not None:
                raise self._writer_exc
            blob = self.st.writer.finish(
                tiling._finish_header(self.st, sched.T))
            return blob, tiling._stats(self.st, sched.T, blob, t_start)
        finally:
            self.stop.set()
            if writer.is_alive():
                self._put(self.q_out, _EOF, force=True)
                writer.join(timeout=10.0)
            # unblock a full-queue ingest put, then give it a bounded
            # window to exit -- it may be blocked INSIDE the user's
            # frame iterable (a stalled solver/socket), which no amount
            # of draining can interrupt; it is a daemon thread, so
            # leaking it beats hanging the caller on shutdown
            deadline = time.monotonic() + 5.0
            while ingest.is_alive() and time.monotonic() < deadline:
                try:
                    self.q_in.get_nowait()
                except queue.Empty:
                    pass
                ingest.join(timeout=0.1)
