"""Out-of-core concurrent streaming engine (DESIGN.md #11, #12).

``compress_stream`` used to process windows strictly serially: host
frame ingestion, device encode/verify, and CPU symbolize/pack took
turns on one thread, so the device idled during zstd packing and the
packer idled during verify rounds.  This module runs the SAME window
state machine with the three stages overlapped:

    ingest thread   -- pulls (u_t, v_t) frames from the source iterable
                       (window N+1), converts to float32 and precomputes
                       the fixed-point planes, hands frames over a
                       bounded queue;
    compute thread  -- (the caller's thread) owns ALL device work and
                       the sliding plane storage: window derivation, the
                       seam-agreed verify fixpoint, and the final-mask
                       encode of window N via the shared PlanExecutor
                       batched stages (core/pipeline.py);
    writer thread   -- consumes per-unit payloads (core/tiling.py
                       ``_UnitPayload``) in emission order: symbolize,
                       pack (zstd/zlib) and TiledWriter emission of
                       window N-1, plus track-index bookkeeping.

Why the bytes cannot change: the scheduler below is the one state
machine both modes run (``Scheduler``), so derive/fixpoint/emit
decisions are identical; payloads are produced in the serial emission
order and the writer queue is FIFO, so units hit the TiledWriter in the
same order at the same offsets; and symbolize/pack are deterministic
pure functions of the payload.  Only WHEN work happens moves across
threads -- never WHAT is computed.  Asserted end-to-end in
tests/test_stream_async.py and the ``async_vs_serial`` benchmark
section.

Why memory stays bounded (~2 windows, preserved from the serial
engine): the plane store still drops frames behind the pending
frontier, the ingest queue holds at most one window of frames ahead,
and the writer queue holds at most ~2 windows of unit payloads
(residual streams, ~1/4 the footprint of raw frames); a slow sink
back-pressures the compute thread instead of growing the queue.

Crash recovery (DESIGN.md #12): when the sink is a filesystem path,
``_Session`` keeps a write-ahead journal next to the container --
a ``begin`` fingerprint record, one record per emitted unit (its
directory entry + sidecar-index rows), and a fsync'd ``ckpt`` record
at each emission boundary snapshotting the scheduler frontier and the
still-resident eb/forced planes.  ``resume=True`` truncates the data
file to the last durable checkpoint, restores the writer/scheduler/
plane state, and re-feeds frames from ``resume_from``; the finished
container is byte-identical to an uninterrupted run because everything
behind the frontier was already final (the PR-5 emission-order
argument) and everything ahead is recomputed from bit-identical
inputs against idempotently restored eb/forced state.

Failure containment: the engine propagates the FIRST failing stage's
exception to the caller, poisons both bounded queues without ever
blocking (a dead consumer cannot deadlock shutdown), and -- when a
``stage_timeout`` is set (or REPRO_STAGE_TIMEOUT) -- converts a
silently stalled stage into ``EngineStallError``.  Deterministic fault
injection for all of this lives in core/faults.py.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
import zlib

import msgpack
import numpy as np

from . import ebpolicy, encode, pipeline, tiling
from . import faults as faults_mod
from .. import obs


class EngineStallError(RuntimeError):
    """A pipeline stage made no progress within the watchdog timeout."""


class ResumeError(ValueError):
    """The journal's run fingerprint does not match this invocation
    (different config/grid/value_range/shape): resuming would splice
    incompatible bytes into the container."""


def _stage_timeout(value):
    if value is not None:
        return float(value)
    env = os.environ.get("REPRO_STAGE_TIMEOUT")
    return float(env) if env else None


class Scheduler:
    """The window state machine shared by the serial and async engines.

    Transplanted verbatim from the pre-engine ``compress_stream`` loop
    (PR 2): derive every window whose halo extension is fully buffered,
    run the seam-agreed fixpoint over derived-but-unemitted windows,
    emit each window once the NEXT window's fixpoint has run (its
    verify outcome is then sealed), and drop frame planes behind the
    pending frontier.  ``emit`` receives ``_UnitPayload`` objects in
    the canonical emission order; the engines differ only in where
    that callable runs the CPU pack.

    ``checkpoint`` (optional) is called after each emission burst with
    a msgpack-able snapshot of everything a crash resume needs: the
    frontier, the first-unemitted window index, and the eb/forced
    planes of every still-resident frame.  Restoring that snapshot and
    re-feeding frames from ``resume_from`` reproduces the exact
    remaining emissions: re-derivation min-reduces the SAME eb values
    into the restored planes (idempotent), and the restored forced
    planes are already at the last fixpoint, so re-run verify rounds
    add nothing (DESIGN.md #12 argument).
    """

    def __init__(self, st, cfg, grid, emit, checkpoint=None):
        self.st = st
        self.cfg = cfg
        self.grid = grid
        self.emit = emit
        self.checkpoint = checkpoint
        self.windows = []       # every derived window, in order
        self.pending = []       # derived, not yet emitted (ordered)
        self.frontier = 0       # frames below this are sealed
        self.next_w = 0         # next window index to derive
        self.T = 0
        self.eof = False
        # units handed to emit, ever -- a per-scheduler view over the
        # process-wide "engine.units_emitted" obs counter (kept as a
        # public field because checkpoints snapshot it)
        self._c_emitted = obs.child_counter("engine.units_emitted")
        self._c_windows = obs.child_counter("engine.windows_emitted")

    @property
    def n_emitted(self) -> int:
        return self._c_emitted.value

    def add_frame(self, u_t, v_t, ufp_t=None, vfp_t=None):
        tiling._add_frame(self.st, self.T, u_t, v_t, ufp_t, vfp_t)
        self.T += 1
        if self._derive_ready():
            self._advance()

    def finish(self):
        self.eof = True
        self._derive_ready()
        self._advance()
        if self.pending:
            raise RuntimeError("scheduler left unemitted windows")

    def restore(self, ckpt: dict):
        """Adopt a journal checkpoint: resume scheduling exactly where
        the interrupted run's last durable emission left off."""
        self.frontier = int(ckpt["frontier"])
        self.next_w = int(ckpt["next_w"])
        self.T = int(ckpt["resume_from"])
        # restored units were emitted by the CRASHED run: reset only
        # this scheduler's view so n_emitted matches the checkpoint
        # without double-counting them in the process-wide counter
        self._c_emitted.set_local(int(ckpt["n_units"]))

    def _derive_ready(self):
        """Derive every window whose extension is fully buffered."""
        st, grid = self.st, self.grid
        out = []
        while True:
            t0 = self.next_w * grid.window_t
            if t0 >= self.T:
                break
            t1 = min(t0 + grid.window_t, self.T)
            full = t1 == t0 + grid.window_t and self.T >= t1 + grid.thalo
            if not (full or self.eof):
                break
            et1 = min(t1 + grid.thalo, self.T)
            w = tiling._Window(
                self.next_w, t0, t1,
                tiling.window_specs(self.next_w, t0, t1, st.H, st.W,
                                    et1, grid))
            tiling._derive_window(st, w)
            self.windows.append(w)
            self.pending.append(w)
            self.next_w += 1
            out.append(w)
        return out

    def _advance(self):
        """Fixpoint + emit everything the derive frontier allows."""
        st, grid = self.st, self.grid
        if not self.pending:
            return
        eb_final_hi = self.T if self.eof else self.windows[-1].t1
        fix = [w for w in self.pending if w.et1 <= eb_final_hi]
        if not fix:
            return
        if self.cfg.verify:
            tiling._fixpoint(st, fix, frontier=self.frontier)
        emit_hi = len(fix) if self.eof else len(fix) - 1
        emitted = False
        for w in fix[:emit_hi]:
            for p in tiling._unit_payloads(st, w):
                self.emit(p)
                self._c_emitted.add(1)
            self._c_windows.add(1)
            self.pending.remove(w)
            self.frontier = w.t1
            emitted = True
        if self.pending:
            keep = self.pending[0].t0 - grid.thalo
            drop = [st.u, st.v, st.ufp, st.vfp, st.eb, st.forced]
            if st.ebf is not None:
                drop.append(st.ebf)
            for planes in drop:
                planes.drop_below(keep)
            if emitted and self.checkpoint is not None:
                self.checkpoint(self._snapshot(keep))

    def _snapshot(self, keep: int) -> dict:
        """Everything a resume needs, as one msgpack-able record.

        Only eb/forced planes are snapshotted: u/v/ufp/vfp are re-fed
        (bit-identical) from the source, and preds/seen re-derive.  eb
        planes compress ~50x under zlib-1 (they are mostly the huge
        sentinel); forced planes packbits to H*W/8 bytes."""
        st = self.st
        return {
            "t": "ckpt",
            "frontier": int(self.frontier),
            "resume_from": int(keep),
            "next_w": int(self.pending[0].wi),
            "T": int(self.T),
            "n_units": int(self.n_emitted),
            "eb": [[int(t), zlib.compress(
                np.ascontiguousarray(st.eb.p[t]).tobytes(), 1)]
                for t in sorted(st.eb.p) if t >= keep],
            "forced": [[int(t), np.packbits(st.forced.p[t]).tobytes()]
                       for t in sorted(st.forced.p) if t >= keep],
        }


# ----------------------------------------------------------------------
# journaled session: data file + write-ahead journal + restore
# ----------------------------------------------------------------------

def _fingerprint(cfg, grid, value_range, H, W) -> dict:
    """Everything that must match for resumed bytes to splice cleanly."""
    fp = {k: v for k, v in dataclasses.asdict(cfg).items()
          if isinstance(v, (int, float, str, bool, type(None)))}
    # the scalar filter above silently drops the policy (asdict turns a
    # TilePolicy into a nested dict); it is byte-changing, so a resumed
    # run MUST re-present the identical policy -- record its canonical
    # spec explicitly (_fp_equal's msgpack round trip normalizes tuples)
    fp["eb_policy"] = ebpolicy.policy_spec(
        ebpolicy.normalize(getattr(cfg, "eb_policy", None)))
    fp["grid"] = dataclasses.asdict(grid)
    fp["value_range"] = [float(value_range[0]), float(value_range[1])]
    fp["H"], fp["W"] = int(H), int(W)
    return fp


def _fp_equal(a: dict, b: dict) -> bool:
    # normalize through one msgpack round trip (tuples -> lists, ...)
    rt = lambda d: msgpack.unpackb(  # noqa: E731
        msgpack.packb(d, use_bin_type=True, default=str), raw=False)
    return rt(a) == rt(b)


class _Session:
    """One journaled streaming run against a filesystem-path sink.

    Owns the container data file and the ``<path>.journal`` sidecar,
    wraps unit emission with journal records, performs the
    fsync-ordered checkpoint (data file first, THEN the journal record
    that claims it), and rebuilds writer/plane/index state on resume.
    """

    def __init__(self, path, cfg, grid, value_range):
        self.path = os.fspath(path)
        self.journal_path = self.path + ".journal"
        self.cfg = cfg
        self.grid = grid
        self.value_range = value_range
        self.file = None
        self.journal = None
        self.st = None
        self.resume_from = 0
        self.resumed = False
        self._begin = None
        self._ckpt = None
        self._unit_recs = []
        # per-unit journal records buffered in memory between
        # checkpoints: a record is only durable (or even written) once
        # the checkpoint that claims its bytes lands, so writing them
        # earlier buys no recovery -- units past the last checkpoint
        # are replayed from the source either way.  One batched write +
        # one fsync per window checkpoint instead of a write per unit.
        self._pending_recs = []

    # -- resume inspection -------------------------------------------------
    def finished_stats(self):
        """(None, stats) if the container already has a valid footer
        (the previous run completed); else None."""
        try:
            size = os.path.getsize(self.path)
            with open(self.path, "rb") as f:
                def rd(off, ln):
                    f.seek(off)
                    return f.read(ln)
                hdr, _ = encode.tiled_footer_ranged(rd, size)
        except (OSError, encode.ContainerError):
            return None
        if os.path.exists(self.journal_path):
            os.remove(self.journal_path)
        return None, {
            "already_complete": True,
            "comp_bytes": size,
            "n_units": len(hdr.get("units", ())),
            "pipeline": "tiled",
        }

    def load_journal(self) -> bool:
        """Parse the journal; True if a durable checkpoint exists."""
        try:
            recs = encode.read_journal(self.journal_path)
        except encode.ContainerError:
            return False
        if not recs or recs[0].get("t") != "begin":
            return False
        ckpts = [r for r in recs if r.get("t") == "ckpt"]
        if not ckpts:
            return False
        self._begin = recs[0]
        self._ckpt = ckpts[-1]
        units = [r for r in recs if r.get("t") == "unit"]
        n = int(self._ckpt["n_units"])
        if len(units) < n:
            return False               # journal torn before its ckpt
        self._unit_recs = units[:n]
        self.resume_from = int(self._ckpt["resume_from"])
        return True

    # -- fresh / resumed opening --------------------------------------------
    def open_fresh(self):
        self.file = open(self.path, "wb")
        return self.file

    def begin(self, st, H, W):
        """First-frame hook: the state (and thus the container prologue)
        exists now; start the journal with the run fingerprint."""
        self.st = st
        self.file.flush()
        encode.fsync_timed(self.file.fileno())
        self.journal = encode.JournalWriter(self.journal_path)
        self.journal.append({
            "t": "begin",
            "fp": _fingerprint(self.cfg, self.grid, self.value_range, H, W),
            "H": int(H), "W": int(W),
            "data_start": int(st.writer.bytes_written),
        }, sync=True)

    def restore_state(self):
        """Rebuild compression state from the journal.  Returns the
        restored ``_State`` (caller builds the Scheduler around it)."""
        bg, ck = self._begin, self._ckpt
        fp = _fingerprint(self.cfg, self.grid, self.value_range,
                          bg["H"], bg["W"])
        if not _fp_equal(fp, bg["fp"]):
            raise ResumeError(
                f"journal {self.journal_path} was written by a run with "
                f"different parameters; refusing to splice (delete the "
                f"journal and {self.path} to start over)")
        H, W = int(bg["H"]), int(bg["W"])
        f = open(self.path, "r+b")
        f.truncate(int(ck["bytes"]))
        f.seek(int(ck["bytes"]))
        self.file = f
        # throwaway in-memory writer: only the state scaffolding is
        # wanted; the real writer reattaches to the truncated file
        st = tiling._init_state(self.cfg, self.grid, H, W,
                                self.value_range, None)
        st.writer = encode.TiledWriter.resumed(
            f, int(ck["bytes"]), [r["entry"] for r in self._unit_recs],
            self.cfg.zstd_level)
        for r in self._unit_recs:
            c = r["counts"]
            st.n_units += 1
            st.n_ll += int(c["ll"])
            st.n_verts += int(c["verts"])
            st.n_sl_blocks += int(c["sl"])
            st.n_blocks += int(c["blocks"])
            if st.tindex is not None and r.get("seg") is not None:
                st.tindex.add_unit(
                    tuple(r["entry"]["key"]),
                    *(encode.unpack_ndarray(d) for d in r["seg"]))
        for t, raw in ck["eb"]:
            st.eb.p[int(t)] = np.frombuffer(
                zlib.decompress(raw), np.int64).reshape(H, W).copy()
        for t, raw in ck["forced"]:
            st.forced.p[int(t)] = np.unpackbits(
                np.frombuffer(raw, np.uint8),
                count=H * W).astype(bool).reshape(H, W)
        self.st = st
        self.resumed = True
        obs.counter("journal.resumes").add(1)
        obs.instant_event("journal.resume",
                          resume_from=int(ck["resume_from"]),
                          n_units=int(ck["n_units"]),
                          bytes=int(ck["bytes"]))
        # rewrite the journal without the (now truncated-away) tail so
        # a crash DURING this resumed run restores consistently; the
        # tmp+rename keeps the swap atomic
        tmp = self.journal_path + ".tmp"
        jw = encode.JournalWriter(tmp)
        jw.append(bg)
        for r in self._unit_recs:
            jw.append(r)
        jw.append(ck, sync=True)
        jw.close()
        os.replace(tmp, self.journal_path)
        self.journal = encode.JournalWriter(self.journal_path, fresh=False)
        return st

    # -- per-unit / per-checkpoint hooks -------------------------------------
    def write_unit(self, p) -> None:
        """Emit one unit AND journal it (directory entry + index rows +
        counters) so a resume can rebuild the writer and sidecar index
        without re-reading container bytes."""
        st = self.st
        tiling._write_unit(st, p)
        bm = np.asarray(p.bm)
        self._pending_recs.append({
            "t": "unit",
            "entry": st.writer.units[-1],
            "counts": {"ll": int(p.ll.sum()), "verts": int(p.ll.size),
                       "sl": int(bm.sum()), "blocks": int(bm.size)},
            "seg": None if p.seg is None else
                   [encode.pack_ndarray(a) for a in p.seg],
        })

    def checkpoint(self, snap: dict) -> None:
        """Durable frontier: the data file is flushed+fsynced BEFORE
        the journal record that claims its byte count, so a checkpoint
        never promises bytes the container does not have.  The buffered
        unit records drain here, ahead of the claiming ckpt record (a
        reader requires every claimed unit record to precede its ckpt),
        and the sync=True on the ckpt append flushes + fsyncs the whole
        batch once."""
        t0 = time.perf_counter_ns()
        with obs.span("journal.checkpoint", units=len(self._pending_recs),
                      frontier=int(snap.get("frontier", -1))):
            snap["bytes"] = int(self.st.writer.bytes_written)
            self.file.flush()
            encode.fsync_timed(self.file.fileno())
            for rec in self._pending_recs:
                self.journal.append(rec)
            self._pending_recs.clear()
            self.journal.append(snap, sync=True)
        obs.counter("journal.checkpoints").add(1)
        if obs.enabled():
            obs.histogram("journal.checkpoint_ns").observe(
                time.perf_counter_ns() - t0)

    # -- teardown -------------------------------------------------------------
    def complete(self):
        """Successful finish: make the container durable, drop the
        journal (it would otherwise shadow the finished footer)."""
        self.file.flush()
        encode.fsync_timed(self.file.fileno())
        self.file.close()
        self.file = None
        if self.journal is not None:
            self.journal.close()
            self.journal = None
        if os.path.exists(self.journal_path):
            os.remove(self.journal_path)

    def abandon(self):
        """Failure path: close handles, KEEP the files -- they are the
        crash artifacts resume works from."""
        for h in (self.file, self.journal):
            try:
                if h is not None:
                    h.close()
            except OSError:
                pass
        self.file = None
        self.journal = None


# ----------------------------------------------------------------------
# entry point
# ----------------------------------------------------------------------

def run(pairs, cfg, grid, value_range, sink=None, async_engine=False,
        resume=False, faults=None, stage_timeout=None):
    """Streaming-compress ``pairs`` with the serial or async engine.
    Entry point for ``tiling.compress_stream`` (which owns the
    config/grid defaulting and the no-value-range fallback).

    ``pairs`` may be an iterable of (u_t, v_t) or a callable
    ``pairs(t_start) -> iterable`` (lets resume seek the source
    instead of replaying it).  ``sink`` as a filesystem path enables
    the write-ahead journal; ``resume=True`` additionally restores a
    crashed run from it.
    """
    t_start = time.perf_counter()
    journaled = isinstance(sink, (str, os.PathLike))
    if resume and not journaled:
        raise ValueError("resume=True requires a filesystem-path sink "
                         "(the journal lives next to the container)")
    session = None
    if journaled:
        session = _Session(sink, cfg, grid, value_range)
        if resume:
            done = session.finished_stats()
            if done is not None:
                done[1]["async_engine"] = bool(async_engine)
                return done
            session.load_journal()

    resume_from = session.resume_from if session else 0
    if callable(pairs):
        src = pairs(resume_from)
    elif resume_from:
        it = iter(pairs)
        for _ in range(resume_from):
            next(it)
        src = it
    else:
        src = pairs

    fpt = faults_mod.FaultPoint(faults)
    timeout = _stage_timeout(stage_timeout)
    try:
        if async_engine:
            blob, stats = _AsyncEngine(
                cfg, grid, value_range, sink, session=session, faults=fpt,
                stage_timeout=timeout).run(src, t_start)
        else:
            blob, stats = _run_serial(src, cfg, grid, value_range, sink,
                                      t_start, session=session, faults=fpt)
    except BaseException:
        if session is not None:
            session.abandon()
        raise
    stats["async_engine"] = bool(async_engine)
    stats["resumed_from"] = resume_from
    return blob, stats


def _session_state(session, sched_args):
    """(st, sched) for a journaled run that is resuming, else None."""
    if session is None or session._ckpt is None:
        return None
    st = session.restore_state()
    sched = Scheduler(st, *sched_args, emit=session.write_unit,
                      checkpoint=session.checkpoint)
    sched.restore(session._ckpt)
    return st, sched


def _run_serial(pairs, cfg, grid, value_range, sink, t_start,
                session=None, faults=None):
    fpt = faults or faults_mod.FaultPoint(None)
    st = None
    sched = None
    restored = _session_state(session, (cfg, grid))
    if restored is not None:
        st, sched = restored
    for uf, vf in pairs:
        fpt.check("stream.compute")
        uf = np.asarray(uf, np.float32)
        if sched is None:
            H, W = uf.shape
            if session is not None:
                sink = session.open_fresh()
            st = tiling._init_state(cfg, grid, H, W, value_range, sink)
            if session is not None:
                session.begin(st, H, W)
                emit, ckpt = session.write_unit, session.checkpoint
            else:
                emit = lambda p: tiling._write_unit(st, p)  # noqa: E731
                ckpt = None
            sched = Scheduler(st, cfg, grid, emit=emit, checkpoint=ckpt)
        sched.add_frame(uf, vf)
    if sched is None or sched.T < 2:
        raise ValueError("need at least 2 frames")
    sched.finish()
    blob = st.writer.finish(tiling._finish_header(st, sched.T))
    if session is not None:
        session.complete()
    return blob, tiling._stats(st, sched.T, blob, t_start)


_EOF = object()


class _AsyncEngine:
    """Three-stage overlapped engine; see the module docstring.

    Failure containment contract:

    * the FIRST stage failure wins: it is recorded once, both queues
      are poisoned, and the caller's thread re-raises it;
    * no shutdown path ever blocks on a bounded queue: poisoning makes
      room by discarding queued work (the run is already dead);
    * with ``stage_timeout`` set, a stage that stops making progress
      (stuck sink, wedged source) raises EngineStallError instead of
      hanging the caller forever.
    """

    def __init__(self, cfg, grid, value_range, sink, session=None,
                 faults=None, stage_timeout=None):
        self.cfg = cfg
        self.grid = grid
        self.value_range = value_range
        self.sink = sink
        self.session = session
        self.faults = faults or faults_mod.FaultPoint(None)
        self.stage_timeout = stage_timeout
        # queue bounds are searched scheduling knobs (pipeline.PLAN_KNOBS
        # q_in_frames / q_out_units); the defaults keep the original
        # sizing: ~one window of frames ahead of the planes, ~two
        # windows of unit payloads ahead of the writer.  Bounds change
        # stall behavior only -- emission order (hence bytes) is fixed
        # by the scheduler.
        knobs = pipeline.resolve_knobs(cfg)
        q_in = knobs["q_in_frames"] or max(grid.window_t, 2)
        self._q_out_units = knobs["q_out_units"]
        self.q_in = queue.Queue(maxsize=max(int(q_in), 2))
        self.q_out = None           # sized once the tile count is known
        self.stop = threading.Event()
        self.scale = None           # set after state init; read by ingest
        self._exc = None            # first failing stage's exception
        self._exc_lock = threading.Lock()
        self.st = None

    def _fail(self, e: BaseException) -> None:
        """Record the first failure and wake every stage."""
        with self._exc_lock:
            if self._exc is None:
                self._exc = e
        self.stop.set()

    def _check_failed(self):
        if self._exc is not None:
            raise self._exc

    # ---- ingest stage ---------------------------------------------------

    def _ingest(self, pairs):
        obs.name_thread("engine.ingest")
        try:
            for t, (uf, vf) in enumerate(pairs):
                with obs.span("engine.ingest", t=t):
                    self.faults.check("stream.ingest")
                    uf = np.asarray(uf, np.float32)
                    vf = np.asarray(vf, np.float32)
                    scale = self.scale
                    ufp = vfp = None
                    if scale is not None:
                        # deterministic: bit-equal wherever it is
                        # computed
                        ufp = np.round(uf.astype(np.float64) * scale)
                        vfp = np.round(vf.astype(np.float64) * scale)
                ok = self._put(self.q_in, (uf, vf, ufp, vfp))
                obs.count("engine.frames_ingested", 1)
                if not ok:
                    return
        except BaseException as e:  # propagate to the compute thread
            self._fail(e)
            self._poison(self.q_in)
            return
        # Normal end of input: deliver _EOF in FIFO order behind every
        # queued frame.  _poison would make room by DISCARDING queued
        # frames -- correct when the run is already failing, but on the
        # happy path it would silently drop the tail of the stream.
        try:
            if not self._put(self.q_in, _EOF):
                self._poison(self.q_in)
        except BaseException as e:
            self._fail(e)
            self._poison(self.q_in)

    # ---- writer stage ---------------------------------------------------

    def _writer(self):
        obs.name_thread("engine.writer")
        try:
            while True:
                p = self.q_out.get()
                if obs.enabled():
                    obs.counter_event("engine.q_out",
                                      depth=self.q_out.qsize())
                if p is _EOF:
                    return
                if isinstance(p, tuple) and p[0] == "ckpt":
                    # checkpoint marker: every unit queued before it
                    # has been written, so the byte count is durable
                    self.session.checkpoint(p[1])
                    continue
                with obs.span("engine.write", key=list(p.key)):
                    self.faults.check("stream.write")
                    if self.session is not None:
                        self.session.write_unit(p)
                    else:
                        tiling._write_unit(self.st, p)
                obs.count("engine.units_written", 1)
        except BaseException as e:
            self._fail(e)
            # keep draining so a blocked compute-thread put always
            # completes; poisoned _EOF ends the drain
            while True:
                try:
                    p = self.q_out.get(timeout=0.1)
                except queue.Empty:
                    if self.stop.is_set():
                        return
                    continue
                if p is _EOF:
                    return

    # ---- queue plumbing ---------------------------------------------------

    def _put(self, q, item, force=False):
        """Queue put that stays responsive to shutdown/stage failure.

        Returns False if shutdown/failure interrupted the put (the
        item is dropped -- the run is already failing).  With a
        stage_timeout, a consumer that stops consuming converts the
        wait into EngineStallError instead of an unbounded block."""
        qname = "q_in" if q is self.q_in else "q_out"
        waited = 0.0
        while True:
            try:
                q.put(item, timeout=0.1)
                if waited:
                    # back-pressure stall: this stage sat on a full
                    # queue before the consumer made room
                    obs.count(f"engine.{qname}.stall_ms",
                              int(waited * 1000))
                if obs.enabled():
                    obs.counter_event(f"engine.{qname}", depth=q.qsize())
                return True
            except queue.Full:
                waited += 0.1
                if self._exc is not None:
                    return False
                if not force and self.stop.is_set():
                    return False
                if (self.stage_timeout is not None
                        and waited >= self.stage_timeout):
                    obs.count("engine.watchdog.fired", 1)
                    obs.instant_event("engine.watchdog", queue=qname,
                                      waited_s=round(waited, 1))
                    raise EngineStallError(
                        f"stage consuming {q is self.q_in and 'frames' or 'units'} "
                        f"made no progress for {waited:.1f}s "
                        f"(queue stuck at capacity)")

    @staticmethod
    def _poison(q):
        """Deliver _EOF to a bounded queue WITHOUT ever blocking: if the
        queue is full (consumer dead or slow), discard queued work to
        make room -- by the time a queue is poisoned the run's outcome
        is already decided, so the dropped items are never missed."""
        if q is None:
            return
        while True:
            try:
                q.put_nowait(_EOF)
                return
            except queue.Full:
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass

    def _emit(self, p):
        self._check_failed()
        if not self._put(self.q_out, p):
            self._check_failed()

    def _checkpoint(self, snap):
        # ride the FIFO queue so the writer applies it strictly after
        # the units it covers
        self._check_failed()
        self._put(self.q_out, ("ckpt", snap))

    # ---- compute stage (caller thread) ----------------------------------

    def _get_frame(self):
        """q_in.get with failure fast-path + optional stall watchdog."""
        waited = 0.0
        while True:
            try:
                item = self.q_in.get(timeout=0.1)
                if waited:
                    obs.count("engine.compute.stall_ms",
                              int(waited * 1000))
                return item
            except queue.Empty:
                waited += 0.1
                self._check_failed()
                if (self.stage_timeout is not None
                        and waited >= self.stage_timeout):
                    obs.count("engine.watchdog.fired", 1)
                    obs.instant_event("engine.watchdog", queue="q_in",
                                      waited_s=round(waited, 1))
                    raise EngineStallError(
                        f"ingest produced no frame for {waited:.1f}s "
                        f"(stalled source?)")

    def run(self, pairs, t_start):
        obs.name_thread("engine.compute")
        if self.stage_timeout is not None:
            obs.count("engine.watchdog.armed", 1)
        ingest = threading.Thread(target=self._ingest, args=(pairs,),
                                  name="repro-stream-ingest", daemon=True)
        writer = threading.Thread(target=self._writer,
                                  name="repro-stream-writer", daemon=True)
        session = self.session
        sched = None
        restored = _session_state(session, (self.cfg, self.grid))
        if restored is not None:
            self.st, sched = restored
            # session.write_unit/checkpoint must run on the WRITER
            # thread; rebind the scheduler callbacks to the queue
            sched.emit = self._emit
            sched.checkpoint = self._checkpoint
            self.scale = self.st.scale
            self._size_q_out(self.st.H, self.st.W)
            writer.start()
        ingest.start()
        try:
            while True:
                item = self._get_frame()
                if item is _EOF:
                    break
                uf, vf, ufp, vfp = item
                _csp = obs.span("engine.compute",
                                t=sched.T if sched is not None else 0)
                self.faults.check("stream.compute")
                if sched is None:
                    H, W = uf.shape
                    sink = self.sink
                    if session is not None:
                        sink = session.open_fresh()
                    self.st = tiling._init_state(
                        self.cfg, self.grid, H, W, self.value_range, sink)
                    if session is not None:
                        session.begin(self.st, H, W)
                    self.scale = self.st.scale
                    self._size_q_out(H, W)
                    writer.start()
                    sched = Scheduler(
                        self.st, self.cfg, self.grid, emit=self._emit,
                        checkpoint=None if session is None
                        else self._checkpoint)
                with _csp:
                    sched.add_frame(uf, vf, ufp, vfp)
                obs.count("engine.frames_computed", 1)
            self._check_failed()
            if sched is None or sched.T < 2:
                raise ValueError("need at least 2 frames")
            sched.finish()
            self._put(self.q_out, _EOF, force=True)
            writer.join(timeout=self.stage_timeout)
            if writer.is_alive():
                raise EngineStallError(
                    f"writer did not drain within {self.stage_timeout}s")
            self._check_failed()
            blob = self.st.writer.finish(
                tiling._finish_header(self.st, sched.T))
            if session is not None:
                session.complete()
            return blob, tiling._stats(self.st, sched.T, blob, t_start)
        except BaseException as e:
            self._fail(e)
            raise
        finally:
            self.stop.set()
            if writer.is_alive():
                self._poison(self.q_out)
                writer.join(timeout=10.0)
            # unblock a full-queue ingest put, then give it a bounded
            # window to exit -- it may be blocked INSIDE the user's
            # frame iterable (a stalled solver/socket), which no amount
            # of draining can interrupt; it is a daemon thread, so
            # leaking it beats hanging the caller on shutdown
            deadline = time.monotonic() + 5.0
            while ingest.is_alive() and time.monotonic() < deadline:
                try:
                    self.q_in.get_nowait()
                except queue.Empty:
                    pass
                ingest.join(timeout=0.1)

    def _size_q_out(self, H, W):
        if self._q_out_units:
            self.q_out = queue.Queue(maxsize=max(int(self._q_out_units), 2))
            return
        nti = -(-H // self.grid.tile_h)
        ntj = -(-W // self.grid.tile_w)
        # ~2 windows of unit payloads in flight, max
        self.q_out = queue.Queue(maxsize=max(2 * nti * ntj, 2))


def resume_info(path) -> dict:
    """What a ``resume=True`` run of ``path`` would do: the journal's
    durable frontier, or completion.  For operators and the recovery
    bench; read-only."""
    path = os.fspath(path)
    out = {"path": path, "complete": False, "resumable": False,
           "resume_from": 0, "n_units": 0, "bytes": 0,
           # per-site transient-retry accounting (faults.retry_stats):
           # a run that survived on retries is distinguishable here
           # from one that never saw an I/O hiccup
           "retries": faults_mod.retry_stats()}
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            def rd(off, ln):
                f.seek(off)
                return f.read(ln)
            hdr, _ = encode.tiled_footer_ranged(rd, size)
        out["complete"] = True
        out["n_units"] = len(hdr.get("units", ()))
        out["bytes"] = size
        return out
    except (OSError, encode.ContainerError):
        pass
    try:
        recs = encode.read_journal(path + ".journal")
    except encode.ContainerError:
        return out
    ckpts = [r for r in recs if r.get("t") == "ckpt"]
    if ckpts:
        out["resumable"] = True
        out["resume_from"] = int(ckpts[-1]["resume_from"])
        out["n_units"] = int(ckpts[-1]["n_units"])
        out["bytes"] = int(ckpts[-1]["bytes"])
    elif recs and recs[0].get("t") == "begin":
        # crashed before the first durable checkpoint: resume restarts
        # the stream from frame 0 (still a valid resume target)
        out["resumable"] = True
    return out
