"""Core library: critical-point-trajectory-preserving compression.

Importing this package enables jax x64 (the SoS predicates require exact
int64 arithmetic).  The LM/model stack is dtype-explicit and unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from .compressor import CompressionConfig, compress, decompress  # noqa: E402,F401
from .ebpolicy import (  # noqa: E402,F401
    DegenerateRangeError,
    TilePolicy,
    UniformPolicy,
)
from .tiling import (  # noqa: E402,F401
    TileGrid,
    compress_stream,
    compress_tiled,
    decompress_region,
    decompress_tiled,
)
