"""Block-wise adaptive Mixture of Predictors (paper Sec. VI).

For each (frame, spatial tile) we score both candidate residual fields by
estimated rate

    R_p = H0(hist_p) + lambda * escape_frac_p + R_meta,
    R_meta = 1 / (Bx * By * 2) bits/sample/component,  lambda = 16

and pick SL only when its relative improvement over 3DL exceeds the gate
(0.03%, paper's anti-thrashing threshold).  Unlike the paper's strided
micro-encoding we score on the *full* tile histograms -- exact and fully
vectorized (DESIGN.md #3.3).  Frame 0 has no previous frame and is forced
to 3DL.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CLIP = 255           # folded residual clip; >= CLIP is an escape symbol
LAMBDA = 16.0        # bits charged per escaped (raw-stored) sample
GATE = 3e-4          # relative-improvement gate for selecting SL


def fold(res):
    """Zigzag fold signed residuals to non-negative ints."""
    return jnp.where(res >= 0, 2 * res, -2 * res - 1)


def unfold(z):
    return jnp.where(z % 2 == 0, z // 2, -(z + 1) // 2)


def _tile_ids(T, H, W, block):
    nbi = -(-H // block)
    nbj = -(-W // block)
    ti = jnp.arange(H) // block
    tj = jnp.arange(W) // block
    tid2 = ti[:, None] * nbj + tj[None, :]
    tid = (
        jnp.arange(T, dtype=jnp.int32)[:, None, None] * (nbi * nbj)
        + tid2[None].astype(jnp.int32)
    )
    return tid, nbi, nbj


def _tile_hist(sym, tid, n_tiles):
    """(n_tiles, CLIP+1) histogram of symbols (already clipped)."""
    flat = (tid.reshape(-1).astype(jnp.int64) * (CLIP + 1)) + sym.reshape(-1)
    h = jnp.zeros((n_tiles * (CLIP + 1),), dtype=jnp.int32)
    h = h.at[flat].add(1)
    return h.reshape(n_tiles, CLIP + 1)


def _rate(hist, block):
    """Estimated bits/sample from per-tile histograms."""
    n = jnp.sum(hist, axis=-1).astype(jnp.float64)
    n = jnp.maximum(n, 1.0)
    p = hist.astype(jnp.float64) / n[..., None]
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-300)), 0.0), -1)
    esc = hist[..., CLIP].astype(jnp.float64) / n
    return ent + LAMBDA * esc + 1.0 / (block * block * 2)


def select(res3_u, res3_v, ressl_u, ressl_v, block):
    """Per-(frame, tile) predictor choice.

    Returns blockmap (T, nbi, nbj) bool -- True selects SL.
    """
    T, H, W = res3_u.shape
    tid, nbi, nbj = _tile_ids(T, H, W, block)
    n_tiles = T * nbi * nbj

    def hist_pair(ru, rv):
        su = jnp.minimum(fold(ru), CLIP).astype(jnp.int64)
        sv = jnp.minimum(fold(rv), CLIP).astype(jnp.int64)
        return _tile_hist(su, tid, n_tiles) + _tile_hist(sv, tid, n_tiles)

    r3 = _rate(hist_pair(res3_u, res3_v), block)
    rsl = _rate(hist_pair(ressl_u, ressl_v), block)
    improve = (r3 - rsl) / jnp.maximum(r3, 1e-12)
    use_sl = improve > GATE
    use_sl = use_sl.reshape(T, nbi, nbj)
    return use_sl.at[0].set(False)  # no previous frame at t = 0


def assemble(res3, ressl, blockmap, block):
    """Merge residual fields according to the blockmap."""
    T, H, W = res3.shape
    mask = jnp.repeat(jnp.repeat(blockmap, block, axis=1), block, axis=2)
    mask = mask[:, :H, :W]
    return jnp.where(mask, ressl, res3)
