"""Simulation-of-Simplicity robust critical-point predicates.

A face of the space-time tetrahedral mesh carries three vector values
a, b, c in R^2 (int64 fixed point) with distinct global vertex indices.
The face is *crossed* by the zero set iff the origin lies inside
conv{a, b, c}, decided by the signs of the three pairwise determinants
det(a,b), det(b,c), det(c,a) (paper Eq. 1).

Degeneracies (det == 0, zero on a vertex/edge) are resolved with a
symbolic perturbation of the *values*:  vertex with global index m is
perturbed by (eps^(4^m), 2 * eps^(4^m) ... ) -- concretely we use
exponents alpha_m = 4^m for the u component and beta_m = 2 * 4^m for the
v component.  Every sum of <= 2 exponents has a unique base-4 digit
pattern, so the expansion order of det(A + dA, B + dB) is unambiguous.
For indices mA < mB the terms of

    det(A + dA, B + dB) = (Au Bv - Av Bu)
                        + Bv eps^{aA} - Bu eps^{bA}
                        - Av eps^{aB} + Au eps^{bB}
                        - eps^{bA + aB} + eps^{aA + bB}

ordered by decreasing magnitude (increasing exponent) give the sign
cascade below.  The cascade ends in a nonzero constant, so the SoS sign
is never zero, and it depends only on (values, indices) -- hence it is
consistent across all faces sharing a vertex, which is what Lemma 1 /
Theorems 1-2 of the paper require.

Every function is written against a generic array namespace ``xp`` so the
same code runs vectorized under numpy (host analysis) and jax.numpy
(jit'd compression pipeline).  All inputs are int64; products stay below
2^62 provided |values| < 2^30 (see fixedpoint.py).
"""
from __future__ import annotations

import numpy as np


def _sign(xp, x):
    return xp.sign(x)


def _tiebreak(xp, au, av, bu, bv):
    """SoS tie-break for det(A, B) == 0, index(A) < index(B).

    Cascade: +Bv, -Bu, -Av, +Au, then constant -1 (no determinant --
    the caller already knows it vanished).
    """
    s = _sign(xp, bv)
    s = xp.where(s != 0, s, _sign(xp, -bu))
    s = xp.where(s != 0, s, _sign(xp, -av))
    s = xp.where(s != 0, s, _sign(xp, au))
    s = xp.where(s != 0, s, -xp.ones_like(s))
    return s


def _cascade(xp, au, av, bu, bv):
    """SoS sign of det(A, B) assuming index(A) < index(B)."""
    d = au * bv - av * bu
    s = _sign(xp, d)
    return xp.where(s != 0, s, _tiebreak(xp, au, av, bu, bv))


def sign_det_sos(xp, au, av, ma, bu, bv, mb):
    """SoS-robust sign of det(A, B) = Au*Bv - Av*Bu for arrays of pairs.

    The determinant is computed ONCE: when it is nonzero both index
    orders agree on sign(d) (rev = sign(-d), negated back), so the
    double tie-break cascade only decides the d == 0 case.
    """
    d = au * bv - av * bu
    s = _sign(xp, d)
    tie = xp.where(ma < mb,
                   _tiebreak(xp, au, av, bu, bv),
                   -_tiebreak(xp, bu, bv, au, av))
    return xp.where(s != 0, s, tie)


def _sign_det_sos_d(xp, d, au, av, ma, bu, bv, mb):
    """sign_det_sos with the determinant d = det(A, B) precomputed."""
    s = _sign(xp, d)
    tie = xp.where(ma < mb,
                   _tiebreak(xp, au, av, bu, bv),
                   -_tiebreak(xp, bu, bv, au, av))
    return xp.where(s != 0, s, tie)


def face_crossed(xp, au, av, ma, bu, bv, mb, cu, cv, mc,
                 d_ab=None, d_bc=None, d_ca=None):
    """True where origin in conv{a,b,c} under SoS (paper Eq. 1 + Alg. 1).

    The pairwise determinants may be passed in when the caller already
    computed them (ebound shares them with the Alg. 2 rotations).
    """
    if d_ab is None:
        d_ab = au * bv - av * bu
        d_bc = bu * cv - bv * cu
        d_ca = cu * av - cv * au
    s1 = _sign_det_sos_d(xp, d_ab, au, av, ma, bu, bv, mb)
    s2 = _sign_det_sos_d(xp, d_bc, bu, bv, mb, cu, cv, mc)
    s3 = _sign_det_sos_d(xp, d_ca, cu, cv, mc, au, av, ma)
    return (s1 == s2) & (s2 == s3)


def face_crossed_vals(xp, uvals, vvals, idx):
    """Convenience: uvals/vvals/idx of shape (..., 3)."""
    return face_crossed(
        xp,
        uvals[..., 0], vvals[..., 0], idx[..., 0],
        uvals[..., 1], vvals[..., 1], idx[..., 1],
        uvals[..., 2], vvals[..., 2], idx[..., 2],
    )


def _sign_det_sos_lt(xp, au, av, bu, bv, lt):
    """sign_det_sos with the id comparison index(A) < index(B) given as
    a precomputed bool instead of two index operands."""
    d = au * bv - av * bu
    s = _sign(xp, d)
    tie = xp.where(lt,
                   _tiebreak(xp, au, av, bu, bv),
                   -_tiebreak(xp, bu, bv, au, av))
    return xp.where(s != 0, s, tie)


def face_crossed_ordered(xp, au, av, bu, bv, cu, cv, lt_ab, lt_bc, lt_ca):
    """face_crossed with the SoS id-order comparisons precomputed.

    lt_ab = index(a) < index(b) etc.  Bit-identical to face_crossed --
    the ids enter the predicate ONLY through these three comparisons.
    Used by jitted batch paths that would otherwise close over large
    int64 id constants: XLA constant-folds slices/compares of embedded
    constants at compile time, which took >30 s per tile geometry on
    production-size tiles; host-precomputed bools leave nothing to fold.
    """
    s1 = _sign_det_sos_lt(xp, au, av, bu, bv, lt_ab)
    s2 = _sign_det_sos_lt(xp, bu, bv, cu, cv, lt_bc)
    s3 = _sign_det_sos_lt(xp, cu, cv, au, av, lt_ca)
    return (s1 == s2) & (s2 == s3)


def barycentric_crossing(uvals, vvals):
    """Barycentric coordinates of the origin in conv{a,b,c} (paper Eq. 2).

    numpy float64; only meaningful on crossed faces (D_f != 0 generically).
    uvals, vvals: (..., 3) int64.
    """
    a_u, b_u, c_u = (uvals[..., i].astype(np.float64) for i in range(3))
    a_v, b_v, c_v = (vvals[..., i].astype(np.float64) for i in range(3))
    d_ab = a_u * b_v - a_v * b_u
    d_bc = b_u * c_v - b_v * c_u
    d_ca = c_u * a_v - c_v * a_u
    df = d_ab + d_bc + d_ca
    df = np.where(df == 0.0, 1.0, df)  # guarded; degenerate faces unused
    alpha = d_bc / df
    beta = d_ca / df
    gamma = d_ab / df
    return alpha, beta, gamma
