"""Space-time simplicial mesh over a regular (T, H, W) grid.

Spatial triangulation (paper Alg. 4, cases): every cell
(i, j)-(i+1, j+1) is split along the main diagonal into

    tri1 = {(i, j), (i+1, j), (i+1, j+1)}
    tri2 = {(i, j), (i, j+1), (i+1, j+1)}

Spatial ids sid(i, j) = i * W + j are strictly increasing within each
triangle tuple above, so the Kuhn/Freudenthal prism split keyed on global
vertex order is simply, for a sorted triangle (a, b, c) over slab
[t, t+1]:

    tau1 = (a0, b0, c0, c1)
    tau2 = (a0, b0, b1, c1)
    tau3 = (a0, a1, b1, c1)

(x0 = vertex at time t, x1 = at time t+1).  Quad sides split along the
(p0, q1) diagonal for p < q -- consistent between the two prisms sharing
an edge, giving a conforming tetrahedralization (paper Sec. III-B).

Face families per slab (local vertex id = plane * H*W + sid, plane in
{0, 1}):

    slice0    bottom time-slice triangles            2 (H-1)(W-1)
    slice1    top time-slice triangles (same + HW)   2 (H-1)(W-1)
    side      2 per spatial edge (h, v, d edges)     2 (H(W-1) + (H-1)W + (H-1)(W-1))
    internal  2 per spatial triangle                 4 (H-1)(W-1)

Per-vertex incident faces across the two adjacent slabs total <= 36,
matching the paper's "3x3x3 neighborhood, 6 case families" analysis.

Tables are numpy int32, built once per (H, W) and treated as static
constants by the jax pipeline.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np


def _sid(i, j, W):
    return i * W + j


@lru_cache(maxsize=32)
def spatial_triangles(H: int, W: int) -> np.ndarray:
    """(2*(H-1)*(W-1), 3) int32 sorted spatial-id triangles."""
    ii, jj = np.meshgrid(np.arange(H - 1), np.arange(W - 1), indexing="ij")
    v00 = _sid(ii, jj, W).ravel()
    v10 = _sid(ii, jj + 1, W).ravel()
    v01 = _sid(ii + 1, jj, W).ravel()
    v11 = _sid(ii + 1, jj + 1, W).ravel()
    tri1 = np.stack([v00, v01, v11], axis=1)
    tri2 = np.stack([v00, v10, v11], axis=1)
    return np.concatenate([tri1, tri2], axis=0).astype(np.int32)


@lru_cache(maxsize=32)
def spatial_edges(H: int, W: int) -> np.ndarray:
    """(E, 2) int32 sorted spatial edges: horizontal, vertical, diagonal."""
    edges = []
    ii, jj = np.meshgrid(np.arange(H), np.arange(W - 1), indexing="ij")
    edges.append(np.stack([_sid(ii, jj, W).ravel(), _sid(ii, jj + 1, W).ravel()], 1))
    ii, jj = np.meshgrid(np.arange(H - 1), np.arange(W), indexing="ij")
    edges.append(np.stack([_sid(ii, jj, W).ravel(), _sid(ii + 1, jj, W).ravel()], 1))
    ii, jj = np.meshgrid(np.arange(H - 1), np.arange(W - 1), indexing="ij")
    edges.append(np.stack([_sid(ii, jj, W).ravel(), _sid(ii + 1, jj + 1, W).ravel()], 1))
    return np.concatenate(edges, axis=0).astype(np.int32)


@lru_cache(maxsize=32)
def slab_faces(H: int, W: int):
    """Face tables for one slab, dict name -> (F, 3) int32 local ids.

    Local vertex id = plane * (H*W) + spatial id, plane in {0, 1}.
    Vertex ids within a face are strictly increasing, so the face key is
    canonical and the SoS index order is the id order.
    """
    HW = H * W
    tris = spatial_triangles(H, W).astype(np.int64)
    edges = spatial_edges(H, W).astype(np.int64)
    a, b, c = tris[:, 0], tris[:, 1], tris[:, 2]
    p, q = edges[:, 0], edges[:, 1]

    slice0 = tris.copy()
    slice1 = tris + HW
    side = np.concatenate(
        [
            np.stack([p, q, q + HW], 1),       # (p0, q0, q1)
            np.stack([p, p + HW, q + HW], 1),  # (p0, p1, q1)
        ],
        axis=0,
    )
    internal = np.concatenate(
        [
            np.stack([a, b, c + HW], 1),        # (a0, b0, c1)
            np.stack([a, b + HW, c + HW], 1),   # (a0, b1, c1)
        ],
        axis=0,
    )
    return {
        "slice0": slice0.astype(np.int32),
        "slice1": slice1.astype(np.int32),
        "side": side.astype(np.int32),
        "internal": internal.astype(np.int32),
    }


@lru_cache(maxsize=32)
def slab_faces_concat(H: int, W: int, include_top: bool):
    """Concatenated face table for a slab: slice0 + side + internal
    (+ slice1 when include_top, used for the final slab only).
    Returns (faces (F, 3) int32, slice0_count, slab_face_count)."""
    f = slab_faces(H, W)
    parts = [f["slice0"], f["side"], f["internal"]]
    if include_top:
        parts.append(f["slice1"])
    faces = np.concatenate(parts, axis=0)
    return faces, len(f["slice0"]), len(f["side"]) + len(f["internal"])


@lru_cache(maxsize=32)
def slab_tets(H: int, W: int) -> np.ndarray:
    """(3 * n_tris, 4) int32 tetrahedra of one slab in local 2-plane ids."""
    HW = H * W
    tris = spatial_triangles(H, W).astype(np.int64)
    a, b, c = tris[:, 0], tris[:, 1], tris[:, 2]
    tau1 = np.stack([a, b, c, c + HW], 1)
    tau2 = np.stack([a, b, b + HW, c + HW], 1)
    tau3 = np.stack([a, a + HW, b + HW, c + HW], 1)
    return np.concatenate([tau1, tau2, tau3], axis=0).astype(np.int32)


# The 4 triangular faces of a tetrahedron (vertex ids sorted ascending
# within each face because tet vertex tuples are sorted).
TET_FACES = np.array([[0, 1, 2], [0, 1, 3], [0, 2, 3], [1, 2, 3]], dtype=np.int32)


# ----------------------------------------------------------------------
# global face enumeration + tet-face index (trajectory analytics)
# ----------------------------------------------------------------------
#
# Every face of the (T, H, W) space-time mesh gets one dense int64 id,
# interleaved per time step so the id NEVER depends on T (streaming
# writers assign ids before the stream length is known):
#
#     slice faces   t * (Fs + Fb) + f          t in [0, T)
#     slab  faces   t * (Fs + Fb) + Fs + f     t in [0, T-1)
#
# with Fs = len(slice0) (f indexing slab_faces(H, W)["slice0"]) and
# Fb = len(side) + len(internal) (f indexing concat(side, internal),
# the ebound.slab_face_table order).  The id is what the analytics
# subsystem (repro/analysis) keys crossing nodes on: it is globally
# canonical (one id per geometric face, shared by both incident tets
# and both adjacent tiles), so segment lists recorded per (tile,
# window) unit glue into exact global tracks by id equality.  Ids are
# also monotone in time, which makes min-fid track ordering a
# birth-time ordering.


def face_family_sizes(H: int, W: int):
    """(Fs, Fb): per-slab slice-face and slab-face counts."""
    f = slab_faces(H, W)
    return len(f["slice0"]), len(f["side"]) + len(f["internal"])


def n_faces(shape) -> int:
    """Total number of distinct faces of the (T, H, W) mesh."""
    T, H, W = shape
    Fs, Fb = face_family_sizes(H, W)
    return T * Fs + (T - 1) * Fb


def _row_lookup(table: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Index of each query row in ``table`` (every query must be a row)."""
    uniq, inv = np.unique(
        np.concatenate([table, queries], axis=0), axis=0, return_inverse=True
    )
    pos = np.full(len(uniq), -1, dtype=np.int64)
    pos[inv[: len(table)]] = np.arange(len(table))
    out = pos[inv[len(table):]]
    if not (out >= 0).all():
        # real raise (not assert): queries can originate from a
        # container's track index, so bad ids must fail under -O too
        raise ValueError("query face not present in face table "
                         "(corrupt face ids?)")
    return out


@lru_cache(maxsize=32)
def tet_face_map(H: int, W: int):
    """Per tet-face (family, index) into the per-slab face enumeration.

    Returns (family (Ntet, 4) int8, index (Ntet, 4) int32) where family
    0 = bottom slice (slab time t), 1 = top slice (t + 1, indexed in the
    slice0 table), 2 = slab face (indexed in concat(side, internal) --
    the ebound.slab_face_table order).  With these tables the crossing
    state of every tet face is a pure gather from the face-predicate
    tables: no SoS re-evaluation per tet.
    """
    HW = H * W
    tets = slab_tets(H, W).astype(np.int64)
    tf = tets[:, TET_FACES]                    # (Ntet, 4, 3) local ids
    sf = slab_faces(H, W)
    slice_tab = sf["slice0"].astype(np.int64)
    slab_tab = np.concatenate([sf["side"], sf["internal"]], 0).astype(np.int64)

    plane1 = tf >= HW
    all0 = ~plane1.any(axis=2)
    all1 = plane1.all(axis=2)
    family = np.full(tf.shape[:2], 2, dtype=np.int8)
    family[all0] = 0
    family[all1] = 1

    index = np.empty(tf.shape[:2], dtype=np.int32)
    flat = tf.reshape(-1, 3)
    fam_flat = family.reshape(-1)
    for fam, tab, off in ((0, slice_tab, 0), (1, slice_tab, HW),
                          (2, slab_tab, 0)):
        sel = fam_flat == fam
        if sel.any():
            index.reshape(-1)[sel] = _row_lookup(tab, flat[sel] - off)
    return family, index


def tet_face_fids(family, index, t_slab, H, W):
    """Global face ids for tet faces of slab(s) ``t_slab``.

    family/index as returned by tet_face_map (any matching shapes),
    t_slab broadcastable int array of slab times.  Returns int64 ids
    (independent of T -- see the enumeration comment above).
    """
    Fs, Fb = face_family_sizes(H, W)
    F = Fs + Fb
    family = np.asarray(family)
    index = np.asarray(index, dtype=np.int64)
    t = np.asarray(t_slab, dtype=np.int64)
    slice_t = t + (family == 1)
    return np.where(
        family == 2,
        t * F + Fs + index,
        slice_t * F + index,
    )


def face_vertices(fids, H, W) -> np.ndarray:
    """Global space-time vertex ids (N, 3) of faces given by global id."""
    HW = H * W
    Fs, Fb = face_family_sizes(H, W)
    F = Fs + Fb
    sf = slab_faces(H, W)
    slice_tab = sf["slice0"].astype(np.int64)
    slab_tab = np.concatenate([sf["side"], sf["internal"]], 0).astype(np.int64)
    fids = np.asarray(fids, dtype=np.int64)
    t = fids // F
    r = fids % F
    is_slab = r >= Fs
    out = np.empty((len(fids), 3), dtype=np.int64)
    if (~is_slab).any():
        out[~is_slab] = slice_tab[r[~is_slab]] + t[~is_slab, None] * HW
    if is_slab.any():
        out[is_slab] = slab_tab[r[is_slab] - Fs] + t[is_slab, None] * HW
    return out


def box_vertex_ids(shape, box) -> np.ndarray:
    """Global flat vertex ids of a space-time sub-box.

    shape: (T, H, W) of the full grid; box: (t0, t1, i0, i1, j0, j1)
    half-open ranges.  Returns int64 of shape (t1-t0, i1-i0, j1-j0).

    The returned ids are strictly increasing in the box's own row-major
    (t, i, j) order -- i.e. the sub-box's LOCAL flat ids are
    order-isomorphic to the global ids.  This is the invariant the tiled
    pipeline (core/tiling.py) rests on: the SoS tie-break (sos.py) reads
    vertex ids only through ``<`` comparisons, so evaluating predicates
    and Alg.-2 bounds with tile-local ids is bit-identical to the global
    evaluation restricted to the tile.
    """
    T, H, W = shape
    t0, t1, i0, i1, j0, j1 = box
    tt = np.arange(t0, t1, dtype=np.int64)[:, None, None]
    ii = np.arange(i0, i1, dtype=np.int64)[None, :, None]
    jj = np.arange(j0, j1, dtype=np.int64)[None, None, :]
    return tt * (H * W) + ii * W + jj


def face_counts(H: int, W: int, T: int) -> dict:
    """Total face counts for reporting."""
    f = slab_faces(H, W)
    n_slice = len(f["slice0"])
    n_side = len(f["side"])
    n_internal = len(f["internal"])
    return {
        "slice_faces": n_slice * T,
        "slab_faces": (n_side + n_internal) * (T - 1),
        "tets": 6 * (H - 1) * (W - 1) * (T - 1),
    }
