"""Critical-point-trajectory-preserving compressor (paper Alg. 3).

Public API:

    blob, stats = compress(u, v, CompressionConfig(eb=...))
    u_rec, v_rec = decompress(blob)

Pipeline (encode):
  1. fixed-point conversion (fixedpoint.py)
  2. face predicates + per-vertex error bounds (ebound.py, Alg. 2/4)
  3. eb log-quantization + dual-quantization -> integer field X
  4. predictors: block-local 3D Lorenzo and/or semi-Lagrangian + MoP,
     routed through the kernel-dispatch backend (backend.py: pallas /
     xla / numpy implementations of the three hot ops)
  5. verify-and-correct: simulate the *exact* decode (including the
     float32 output rounding), re-evaluate SoS face predicates on the
     reconstruction, force the vertices of any violated face (or any
     vertex breaking the pointwise bound) to lossless, and repeat.  The
     loop is monotone (the lossless set only grows) and terminates; on
     exit FC_t = FC_s = 0 *by construction* -- an end-to-end guarantee
     rather than a derivation-time one (DESIGN.md #3.5).
  6. escape-coded symbol streams + lossless side channels -> zstd (or
     zlib-fallback) container (encode.py)

Since the pipeline-plan refactor (DESIGN.md #10) this module is a thin
driver: the stage graph lives in core/pipeline.py as a ``PipelinePlan``
executed by a ``PlanExecutor``, and the SAME stage implementations serve
the monolithic fused path, the legacy seed path (``cfg.fused=False`` /
``REPRO_FUSED=0`` -- just the alternate stage binding, kept so
benchmarks/timing.py can measure the fused speedup under identical
accounting) and the tiled/streaming paths (core/tiling.py).  Names like
``_decode_fields_parallel`` are re-exported here for backward
compatibility (tests, baselines, benchmarks).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from .. import perfflags
from . import backend as backend_mod
from . import ebound, ebpolicy, encode, fixedpoint, pipeline, predictors, \
    quantize
from .ebpolicy import DegenerateRangeError, TilePolicy, UniformPolicy

jax.config.update("jax_enable_x64", True)
# opt-in persistent compilation cache (REPRO_JIT_CACHE; README)
perfflags.apply_jit_cache()

FORMAT_VERSION = pipeline.FORMAT_VERSION
FORMAT_VERSION_ADAPTIVE = pipeline.FORMAT_VERSION_ADAPTIVE


@dataclasses.dataclass
class CompressionConfig:
    eb: float = 1e-2                  # error bound
    mode: str = "rel"                 # 'abs' or 'rel' (relative to value range)
    predictor: str = "mop"            # 'mop' | 'lorenzo' | 'sl'
    block: int = predictors.DEFAULT_BLOCK
    n_levels: int = quantize.DEFAULT_LEVELS
    fixed_bits: int = fixedpoint.DEFAULT_BITS
    dt: float = 1.0
    dx: float = 1.0
    dy: float = 1.0
    d_max: float = 2.0
    n_max: int = 32
    zstd_level: int = 12
    verify: bool = True
    max_rounds: int = 12
    backend: Optional[str] = None     # 'pallas' | 'xla' | 'numpy' | None=auto
    fused: Optional[bool] = None      # None -> perfflags.fused_default()
    tiling: Optional[object] = None   # tiling.TileGrid -> tiled pipeline
    track_index: bool = True          # tiled: write the CPTT1 sidecar
                                      # track index (repro.analysis)
    batch_units: bool = True          # tiled: stack same-signature units
                                      # through the vmapped batched stages
                                      # (pipeline.py; False = per-unit loop)
    codec: str = "host"               # entropy stage: 'host' (per-unit
                                      # CPU Huffman + zstd/zlib) |
                                      # 'device' (batched accelerator
                                      # entropy stage, core/entropy.py)
    # execution-scheduling knobs (pipeline.PLAN_KNOBS): these change how
    # fast a fixed plan runs, NEVER the container bytes it produces --
    # repro.autotune searches over them alongside the plan knobs above
    batch_cap: int = 8                # tiled: max units per stacked batch
    q_in_frames: Optional[int] = None   # async engine ingest queue bound
                                        # (None -> max(window_t, 2))
    q_out_units: Optional[int] = None   # async engine handoff queue bound
                                        # (None -> 2 * tiles per window)
    # byte-changing plan knob (NOT a scheduling knob): per-(window,
    # tile) base-bound policy (core/ebpolicy.py).  None / "uniform" /
    # UniformPolicy() -> the scalar cfg.eb path, byte-identical to a
    # config predating the knob; a TilePolicy resolves into a
    # per-vertex base-bound field before the derive stage and bumps
    # the container version (DESIGN.md #16)
    eb_policy: Optional[object] = None


def _as_fields(u, v):
    u = np.asarray(u)
    v = np.asarray(v)
    # real raises (not asserts): input validation must hold under -O
    if u.shape != v.shape or u.ndim != 3:
        raise ValueError(
            f"expect (T, H, W) u and v, got {u.shape} and {v.shape}")
    if min(u.shape) < 2:
        raise ValueError(
            f"need at least a 2x2x2 space-time grid, got {u.shape}")
    return u.astype(np.float32), v.astype(np.float32)


def _eb_factor(u, v, cfg):
    """The mode factor turning a bound in ``cfg.eb`` units absolute:
    1.0 for ``abs``, the value range for ``rel``.  Raises
    :class:`DegenerateRangeError` on (near-)constant relative-mode
    fields, where the range carries no signal to scale with."""
    if cfg.mode == "abs":
        return 1.0
    lo = min(u.min(), v.min())
    hi = max(u.max(), v.max())
    # the subtraction stays in the fields' float32 (bit-compatibility
    # with the pre-policy scalar path)
    rng = float(hi - lo)
    ebpolicy.check_relative_range(rng, max(abs(float(lo)),
                                           abs(float(hi))))
    return max(rng, 1e-30)


def _abs_eb(u, v, cfg):
    return float(cfg.eb) * _eb_factor(u, v, cfg)


# ----------------------------------------------------------------------
# backward-compatible re-exports (implementations live in pipeline.py)
# ----------------------------------------------------------------------

_derive_eb_jit = ebound.derive_vertex_eb_jit
_predicates = pipeline._predicates_jit
_decode_fields = pipeline._decode_fields
_decode_fields_jit = pipeline._decode_fields_jit
_decode_fields_parallel = pipeline._decode_fields_parallel
_reconstruct = pipeline._reconstruct
_faces_to_vertex_mask = pipeline._faces_to_vertex_mask
_face_verts = pipeline._face_verts
_touched_faces = pipeline._touched_faces
_FusedFns = pipeline.UnitFns
_fused_fns = pipeline.unit_fns


def _encode_stage(ufp, vfp, eb, xi_unit, n_levels, lossless_extra,
                  cfg: CompressionConfig):
    """eb -> X fields (legacy quantize binding; eb is precomputed)."""
    return pipeline.legacy_quantize(ufp, vfp, eb, xi_unit, n_levels,
                                    lossless_extra)


def _residuals(xu, xv, scale, xi_unit, cfg: CompressionConfig):
    """Legacy predict binding (full residual stacks)."""
    return pipeline.legacy_residuals(
        xu, xv, scale, xi_unit, cfg.predictor, cfg.block,
        cfg.dt / cfg.dx, cfg.dt / cfg.dy, cfg.d_max, cfg.n_max)


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------

def compress(u, v, cfg: Optional[CompressionConfig] = None,
             autotune: bool = False, target_ratio: Optional[float] = None):
    # default is constructed per call: a module-level default instance
    # would be shared (and mutable) across every caller
    if cfg is None:
        cfg = CompressionConfig()
    if target_ratio is not None:
        # rate-distortion mode: search per-unit base bounds (an eb
        # policy) until the container hits the target ratio, keeping
        # track-covering units at cfg.eb (repro.autotune.rate)
        from ..autotune import rate as rate_mod

        return rate_mod.compress_with_target(u, v, cfg,
                                             float(target_ratio))
    if autotune:
        # pick the fastest searched config for this input (calibrated
        # cost model + top-k measurement, repro.autotune); the chosen
        # config may set cfg.tiling, switch backend/codec etc. -- but
        # for the plan it picks, the bytes are identical to a
        # hand-configured run with that same plan
        from .. import autotune as autotune_mod

        cfg = autotune_mod.tune_config(u, v, cfg)
    if cfg.tiling is not None:
        from . import tiling
        return tiling.compress_tiled(u, v, cfg, cfg.tiling)
    fused = perfflags.fused_default() if cfg.fused is None else cfg.fused
    name = "fused" if fused else "legacy"
    be = backend_mod.resolve(cfg.backend) if fused else "xla"

    t0 = time.perf_counter()
    u, v = _as_fields(u, v)
    pol = ebpolicy.normalize(cfg.eb_policy)
    factor = _eb_factor(u, v, cfg)
    # the plan's global (tau, xi_unit) derive from the policy's LOOSEST
    # bound; per-vertex caps only ever clamp down from there, so the
    # quantization grid stays global and decode is unchanged
    eb_abs = float(cfg.eb if pol is None else
                   ebpolicy.max_bound(pol)) * factor
    scale, ufp, vfp = fixedpoint.to_fixed(u, v, cfg.fixed_bits)
    plan = pipeline.plan_from_cfg(cfg, be, scale, eb_abs, name)
    ex = pipeline.PlanExecutor(plan)
    if pol is None:
        enc = pipeline.compress_field(ex, u, v, ufp, vfp)
    else:
        enc = pipeline.compress_field(
            ex, u, v, ufp, vfp,
            eb_cap=ebpolicy.field_caps(pol, u.shape, factor, scale),
            eb_bound=ebpolicy.field_bounds(pol, u.shape, factor))
    return pipeline.pack_field(ex, u, v, enc, t0)


def decompress(blob: bytes, backend: Optional[str] = None):
    if encode.is_tiled(blob):
        from . import tiling
        return tiling.decompress_tiled(blob, backend=backend)
    header, sections = encode.unpack(blob)
    version = header.get("version", 1)
    if version > FORMAT_VERSION_ADAPTIVE:
        raise ValueError(
            f"container format version {version} is newer than this "
            f"decoder (supports <= {FORMAT_VERSION_ADAPTIVE})")
    ex = pipeline.executor_from_header(header, backend)
    return pipeline.decode_field_blob(ex, header, sections)
