"""Critical-point-trajectory-preserving compressor (paper Alg. 3).

Public API:

    blob, stats = compress(u, v, CompressionConfig(eb=...))
    u_rec, v_rec = decompress(blob)

Pipeline (encode):
  1. fixed-point conversion (fixedpoint.py)
  2. face predicates + per-vertex error bounds (ebound.py, Alg. 2/4)
  3. eb log-quantization + dual-quantization -> integer field X
  4. predictors: block-local 3D Lorenzo and/or semi-Lagrangian + MoP
  5. verify-and-correct: simulate the *exact* decode (including the
     float32 output rounding), re-evaluate every SoS face predicate on
     the reconstruction, force the vertices of any violated face (or any
     vertex breaking the pointwise bound) to lossless, and repeat.  The
     loop is monotone (the lossless set only grows) and terminates; on
     exit FC_t = FC_s = 0 *by construction* -- an end-to-end guarantee
     rather than a derivation-time one (DESIGN.md #3.5).
  6. escape-coded symbol streams + lossless side channels -> zstd
     container (encode.py)

Decode is a scan over frames: X_t from residuals (+ tile-local cumsum or
SL prediction per the blockmap), reconstruction X * g / S, lossless
overrides.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ebound, encode, fixedpoint, mop, predictors, quantize

jax.config.update("jax_enable_x64", True)


@dataclasses.dataclass
class CompressionConfig:
    eb: float = 1e-2                  # error bound
    mode: str = "rel"                 # 'abs' or 'rel' (relative to value range)
    predictor: str = "mop"            # 'mop' | 'lorenzo' | 'sl'
    block: int = predictors.DEFAULT_BLOCK
    n_levels: int = quantize.DEFAULT_LEVELS
    fixed_bits: int = fixedpoint.DEFAULT_BITS
    dt: float = 1.0
    dx: float = 1.0
    dy: float = 1.0
    d_max: float = 2.0
    n_max: int = 32
    zstd_level: int = 12
    verify: bool = True
    max_rounds: int = 12


def _as_fields(u, v):
    u = np.asarray(u)
    v = np.asarray(v)
    assert u.shape == v.shape and u.ndim == 3, "expect (T, H, W) u and v"
    assert u.shape[0] >= 2 and u.shape[1] >= 2 and u.shape[2] >= 2, (
        "need at least a 2x2x2 space-time grid"
    )
    return u.astype(np.float32), v.astype(np.float32)


def _abs_eb(u, v, cfg):
    if cfg.mode == "abs":
        return float(cfg.eb)
    rng = float(
        max(u.max(), v.max()) - min(u.min(), v.min())
    )
    return float(cfg.eb) * max(rng, 1e-30)


# ----------------------------------------------------------------------
# jitted stages
# ----------------------------------------------------------------------

@jax.jit
def _predicates(ufp, vfp):
    return ebound.all_face_predicates(ufp, vfp)


_derive_eb_jit = jax.jit(ebound.derive_vertex_eb, static_argnums=2)


def _encode_stage(ufp, vfp, eb, xi_unit, n_levels, lossless_extra,
                  cfg: CompressionConfig):
    """eb -> X fields.  eb is the precomputed per-vertex bound."""
    k, lossless = quantize.quantize_eb(eb, xi_unit, n_levels)
    lossless = jnp.logical_or(lossless, lossless_extra)
    k = jnp.where(lossless_extra, -1, k)
    xu = quantize.dual_quantize(ufp, k, lossless, xi_unit)
    xv = quantize.dual_quantize(vfp, k, lossless, xi_unit)
    return xu, xv, lossless


def _residuals(xu, xv, scale, xi_unit, cfg: CompressionConfig):
    g2f = (2.0 * xi_unit) / scale
    cfl_x = cfg.dt / cfg.dx
    cfl_y = cfg.dt / cfg.dy
    res3_u = predictors.lorenzo_encode(xu, cfg.block)
    res3_v = predictors.lorenzo_encode(xv, cfg.block)
    if cfg.predictor == "lorenzo":
        T = xu.shape[0]
        nbi = -(-xu.shape[1] // cfg.block)
        nbj = -(-xu.shape[2] // cfg.block)
        bm = jnp.zeros((T, nbi, nbj), dtype=bool)
        return res3_u, res3_v, bm
    ressl_u, ressl_v = predictors.sl_encode(
        xu, xv, g2f, cfl_x, cfl_y, cfg.d_max, cfg.n_max
    )
    if cfg.predictor == "sl":
        T = xu.shape[0]
        nbi = -(-xu.shape[1] // cfg.block)
        nbj = -(-xu.shape[2] // cfg.block)
        bm = jnp.ones((T, nbi, nbj), dtype=bool).at[0].set(False)
    else:
        bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, cfg.block)
    res_u = mop.assemble(res3_u, ressl_u, bm, cfg.block)
    res_v = mop.assemble(res3_v, ressl_v, bm, cfg.block)
    return res_u, res_v, bm


def _decode_fields(res_u, res_v, blockmap, scale, xi_unit, block,
                   cfl_x, cfl_y, d_max, n_max):
    """Scan over frames: residuals -> X fields (int64)."""
    g2f = (2.0 * xi_unit) / scale
    T, H, W = res_u.shape

    def frame0(res_u0, res_v0):
        xu = predictors.c2_block(res_u0, block)
        xv = predictors.c2_block(res_v0, block)
        return xu, xv

    def step(carry, inp):
        xu_p, xv_p = carry
        ru, rv, bm = inp
        xu3 = predictors.lorenzo_decode_frame(xu_p, ru, block)
        xv3 = predictors.lorenzo_decode_frame(xv_p, rv, block)
        pu, pv = predictors.sl_predict_frame(
            xu_p, xv_p, g2f, cfl_x, cfl_y, d_max, n_max
        )
        xus = ru + pu
        xvs = rv + pv
        mask = jnp.repeat(jnp.repeat(bm, block, axis=0), block, axis=1)[:H, :W]
        xu = jnp.where(mask, xus, xu3)
        xv = jnp.where(mask, xvs, xv3)
        return (xu, xv), (xu, xv)

    xu0, xv0 = frame0(res_u[0], res_v[0])
    (_, _), (xu_rest, xv_rest) = jax.lax.scan(
        step, (xu0, xv0), (res_u[1:], res_v[1:], blockmap[1:])
    )
    xu = jnp.concatenate([xu0[None], xu_rest], axis=0)
    xv = jnp.concatenate([xv0[None], xv_rest], axis=0)
    return xu, xv


_decode_fields_jit = jax.jit(
    _decode_fields, static_argnums=(5, 8, 9), static_argnames=()
)


def _reconstruct(xu, xv, scale, xi_unit, lossless, u_raw, v_raw):
    g = 2.0 * xi_unit
    u_rec = (xu.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    v_rec = (xv.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    u_rec = jnp.where(lossless, u_raw, u_rec)
    v_rec = jnp.where(lossless, v_raw, v_rec)
    return u_rec, v_rec


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------

def compress(u, v, cfg: CompressionConfig = CompressionConfig()):
    t0 = time.perf_counter()
    u, v = _as_fields(u, v)
    T, H, W = u.shape
    eb_abs = _abs_eb(u, v, cfg)
    scale, ufp, vfp = fixedpoint.to_fixed(u, v, cfg.fixed_bits)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    xi_unit, n_usable = quantize.ladder(tau, cfg.n_levels)

    ufp_j = jnp.asarray(ufp)
    vfp_j = jnp.asarray(vfp)
    slice_pred0, slab_pred0 = _predicates(ufp_j, vfp_j)

    lossless_extra = jnp.zeros((T, H, W), dtype=bool)
    if tau < 1 or n_usable < 1:
        lossless_extra = jnp.ones((T, H, W), dtype=bool)

    cfl_x = cfg.dt / cfg.dx
    cfl_y = cfg.dt / cfg.dy

    eb_vertex, _, _ = _derive_eb_jit(ufp_j, vfp_j, int(max(tau, 1)))

    rounds = 0
    stats_rounds = []
    while True:
        xu, xv, lossless = _encode_stage(
            ufp_j, vfp_j, eb_vertex, xi_unit, cfg.n_levels, lossless_extra, cfg
        )
        res_u, res_v, blockmap = _residuals(xu, xv, scale, xi_unit, cfg)

        if not cfg.verify:
            break
        # simulate the exact decode
        xu_d, xv_d = _decode_fields_jit(
            res_u, res_v, blockmap, scale, xi_unit, cfg.block,
            cfl_x, cfl_y, cfg.d_max, cfg.n_max,
        )
        u_rec, v_rec = _reconstruct(
            xu_d, xv_d, scale, xi_unit, lossless, jnp.asarray(u), jnp.asarray(v)
        )
        # end-to-end predicate check on the refixed reconstruction
        ur_fp, vr_fp = fixedpoint.refix(np.asarray(u_rec), np.asarray(v_rec), scale)
        slice_pred1, slab_pred1 = _predicates(jnp.asarray(ur_fp), jnp.asarray(vr_fp))
        bad_slice = np.asarray(slice_pred0 ^ slice_pred1)
        bad_slab = np.asarray(slab_pred0 ^ slab_pred1)
        # pointwise bound check (float32 output, strict)
        err = np.maximum(
            np.abs(np.asarray(u_rec, dtype=np.float64) - u.astype(np.float64)),
            np.abs(np.asarray(v_rec, dtype=np.float64) - v.astype(np.float64)),
        )
        bad_pt = err > eb_abs

        n_bad = int(bad_slice.sum()) + int(bad_slab.sum()) + int(bad_pt.sum())
        stats_rounds.append(n_bad)
        if n_bad == 0 or rounds >= cfg.max_rounds:
            break
        extra = np.asarray(lossless_extra).copy()
        extra |= bad_pt
        extra |= _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W)
        lossless_extra = jnp.asarray(extra)
        rounds += 1

    sym_u, esc_u = encode.to_symbols(np.asarray(res_u))
    sym_v, esc_v = encode.to_symbols(np.asarray(res_v))
    lossless_np = np.asarray(lossless)
    u_ll = u[lossless_np]
    v_ll = v[lossless_np]

    header = {
        "version": 1,
        "shape": [int(T), int(H), int(W)],
        "scale": float(scale),
        "xi_unit": int(xi_unit),
        "block": int(cfg.block),
        "cfl_x": float(cfl_x),
        "cfl_y": float(cfl_y),
        "d_max": float(cfg.d_max),
        "n_max": int(cfg.n_max),
        "eb_abs": float(eb_abs),
    }
    sections = {
        "sym_u": sym_u,
        "sym_v": sym_v,
        "esc_u": esc_u,
        "esc_v": esc_v,
        "lossless": np.packbits(lossless_np),
        "u_ll": u_ll,
        "v_ll": v_ll,
        "blockmap": np.packbits(np.asarray(blockmap)),
        "bm_shape": np.asarray(blockmap.shape, dtype=np.int32),
    }
    blob = encode.pack(header, sections, cfg.zstd_level)
    t1 = time.perf_counter()
    orig_bytes = u.nbytes + v.nbytes
    stats = {
        "orig_bytes": orig_bytes,
        "comp_bytes": len(blob),
        "ratio": orig_bytes / max(len(blob), 1),
        "lossless_frac": float(lossless_np.mean()),
        "sl_block_frac": float(np.asarray(blockmap).mean()),
        "verify_rounds": rounds,
        "verify_bad_counts": stats_rounds,
        "eb_abs": eb_abs,
        "scale": scale,
        "tau": tau,
        "xi_unit": xi_unit,
        "seconds": t1 - t0,
    }
    return blob, stats


def _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W):
    """Mark all vertices of violated faces."""
    from . import grid

    HW = H * W
    mask = np.zeros(T * HW, dtype=bool)
    slice_tab = grid.slab_faces(H, W)["slice0"]
    slab_tab = ebound.slab_face_table(H, W)
    for t in range(bad_slice.shape[0]):
        f = np.nonzero(bad_slice[t])[0]
        if len(f):
            mask[(slice_tab[f].astype(np.int64) + t * HW).reshape(-1)] = True
    for t in range(bad_slab.shape[0]):
        f = np.nonzero(bad_slab[t])[0]
        if len(f):
            mask[(slab_tab[f].astype(np.int64) + t * HW).reshape(-1)] = True
    return mask.reshape(T, H, W)


def decompress(blob: bytes):
    header, sections = encode.unpack(blob)
    T, H, W = header["shape"]
    res_u = encode.from_symbols(sections["sym_u"], sections["esc_u"], (T, H, W))
    res_v = encode.from_symbols(sections["sym_v"], sections["esc_v"], (T, H, W))
    bm_shape = tuple(int(x) for x in sections["bm_shape"])
    n_bm = int(np.prod(bm_shape))
    blockmap = np.unpackbits(sections["blockmap"], count=n_bm).astype(bool)
    blockmap = blockmap.reshape(bm_shape)
    lossless = np.unpackbits(sections["lossless"], count=T * H * W).astype(bool)
    lossless = lossless.reshape(T, H, W)

    xu, xv = _decode_fields_jit(
        jnp.asarray(res_u),
        jnp.asarray(res_v),
        jnp.asarray(blockmap),
        header["scale"],
        header["xi_unit"],
        header["block"],
        header["cfl_x"],
        header["cfl_y"],
        header["d_max"],
        header["n_max"],
    )
    u_raw = np.zeros((T, H, W), dtype=np.float32)
    v_raw = np.zeros((T, H, W), dtype=np.float32)
    u_raw[lossless] = sections["u_ll"]
    v_raw[lossless] = sections["v_ll"]
    u_rec, v_rec = _reconstruct(
        xu, xv, header["scale"], header["xi_unit"],
        jnp.asarray(lossless), jnp.asarray(u_raw), jnp.asarray(v_raw),
    )
    return np.asarray(u_rec), np.asarray(v_rec)
