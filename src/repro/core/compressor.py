"""Critical-point-trajectory-preserving compressor (paper Alg. 3).

Public API:

    blob, stats = compress(u, v, CompressionConfig(eb=...))
    u_rec, v_rec = decompress(blob)

Pipeline (encode):
  1. fixed-point conversion (fixedpoint.py)
  2. face predicates + per-vertex error bounds (ebound.py, Alg. 2/4)
  3. eb log-quantization + dual-quantization -> integer field X
  4. predictors: block-local 3D Lorenzo and/or semi-Lagrangian + MoP,
     routed through the kernel-dispatch backend (backend.py: pallas /
     xla / numpy implementations of the three hot ops)
  5. verify-and-correct: simulate the *exact* decode (including the
     float32 output rounding), re-evaluate SoS face predicates on the
     reconstruction, force the vertices of any violated face (or any
     vertex breaking the pointwise bound) to lossless, and repeat.  The
     loop is monotone (the lossless set only grows) and terminates; on
     exit FC_t = FC_s = 0 *by construction* -- an end-to-end guarantee
     rather than a derivation-time one (DESIGN.md #3.5).
  6. escape-coded symbol streams + lossless side channels -> zstd (or
     zlib-fallback) container (encode.py)

Two pipeline implementations coexist (DESIGN.md #5):

* FUSED (default): every verify round is device-resident -- quantize,
  residuals, decode simulation, reconstruction, refix and predicate
  diff all run as jitted stages with only scalars and small index sets
  crossing to the host (no field-sized np.asarray round-trips
  mid-loop).  After round 0 re-verification is INCREMENTAL: forcing a
  vertex lossless changes the reconstruction only at that vertex (X is
  pointwise, integer decode is exact, and the SL predictor is replayed
  through the same stepper executable), so only faces incident to
  newly-forced vertices are re-checked, and the pointwise bound can
  only newly fail at vertices that are now stored exactly.  Decode --
  both the verify simulation and decompress, which share one
  implementation -- exploits that block-Lorenzo time-stepping
  X_t = X_{t-1} + C2(res_t) is a prefix sum: maximal Lorenzo-only
  frame runs are decoded with one cumsum over time (parallel-in-time),
  falling back to per-frame stepping only across SL frames.

* LEGACY (cfg.fused=False / REPRO_FUSED=0): the seed pipeline --
  full predicate re-evaluation and host transfers every round,
  sequential lax.scan decode -- kept callable so benchmarks/timing.py
  can measure the fused speedup under identical accounting.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import perfflags
from . import backend as backend_mod
from . import ebound, encode, fixedpoint, grid, mop, predictors, quantize

jax.config.update("jax_enable_x64", True)

FORMAT_VERSION = 2


@dataclasses.dataclass
class CompressionConfig:
    eb: float = 1e-2                  # error bound
    mode: str = "rel"                 # 'abs' or 'rel' (relative to value range)
    predictor: str = "mop"            # 'mop' | 'lorenzo' | 'sl'
    block: int = predictors.DEFAULT_BLOCK
    n_levels: int = quantize.DEFAULT_LEVELS
    fixed_bits: int = fixedpoint.DEFAULT_BITS
    dt: float = 1.0
    dx: float = 1.0
    dy: float = 1.0
    d_max: float = 2.0
    n_max: int = 32
    zstd_level: int = 12
    verify: bool = True
    max_rounds: int = 12
    backend: Optional[str] = None     # 'pallas' | 'xla' | 'numpy' | None=auto
    fused: Optional[bool] = None      # None -> perfflags.fused_default()
    tiling: Optional[object] = None   # tiling.TileGrid -> tiled pipeline
    track_index: bool = True          # tiled: write the CPTT1 sidecar
                                      # track index (repro.analysis)


def _as_fields(u, v):
    u = np.asarray(u)
    v = np.asarray(v)
    assert u.shape == v.shape and u.ndim == 3, "expect (T, H, W) u and v"
    assert u.shape[0] >= 2 and u.shape[1] >= 2 and u.shape[2] >= 2, (
        "need at least a 2x2x2 space-time grid"
    )
    return u.astype(np.float32), v.astype(np.float32)


def _abs_eb(u, v, cfg):
    if cfg.mode == "abs":
        return float(cfg.eb)
    rng = float(
        max(u.max(), v.max()) - min(u.min(), v.min())
    )
    return float(cfg.eb) * max(rng, 1e-30)


# ----------------------------------------------------------------------
# shared jitted stages
# ----------------------------------------------------------------------

@jax.jit
def _predicates(ufp, vfp):
    return ebound.all_face_predicates(ufp, vfp)


_derive_eb_jit = ebound.derive_vertex_eb_jit  # one executable per (shape, tau)


def _encode_stage(ufp, vfp, eb, xi_unit, n_levels, lossless_extra,
                  cfg: CompressionConfig):
    """eb -> X fields.  eb is the precomputed per-vertex bound."""
    k, lossless = quantize.quantize_eb(eb, xi_unit, n_levels)
    lossless = jnp.logical_or(lossless, lossless_extra)
    k = jnp.where(lossless_extra, -1, k)
    xu = quantize.dual_quantize(ufp, k, lossless, xi_unit)
    xv = quantize.dual_quantize(vfp, k, lossless, xi_unit)
    return xu, xv, lossless


def _residuals(xu, xv, scale, xi_unit, cfg: CompressionConfig):
    g2f = (2.0 * xi_unit) / scale
    cfl_x = cfg.dt / cfg.dx
    cfl_y = cfg.dt / cfg.dy
    T = xu.shape[0]
    nbi = -(-xu.shape[1] // cfg.block)
    nbj = -(-xu.shape[2] // cfg.block)
    if cfg.predictor == "lorenzo":
        res3_u = predictors.lorenzo_encode(xu, cfg.block)
        res3_v = predictors.lorenzo_encode(xv, cfg.block)
        bm = jnp.zeros((T, nbi, nbj), dtype=bool)
        return res3_u, res3_v, bm
    ressl_u, ressl_v = predictors.sl_encode(
        xu, xv, g2f, cfl_x, cfl_y, cfg.d_max, cfg.n_max
    )
    if cfg.predictor == "sl":
        # only frame 0 consumes a Lorenzo (spatial-only) residual; skip
        # the full 3DL stack the seed computed here
        res_u = ressl_u.at[0].set(predictors.d2_block(xu[0], cfg.block))
        res_v = ressl_v.at[0].set(predictors.d2_block(xv[0], cfg.block))
        bm = jnp.ones((T, nbi, nbj), dtype=bool).at[0].set(False)
        return res_u, res_v, bm
    res3_u = predictors.lorenzo_encode(xu, cfg.block)
    res3_v = predictors.lorenzo_encode(xv, cfg.block)
    bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, cfg.block)
    res_u = mop.assemble(res3_u, ressl_u, bm, cfg.block)
    res_v = mop.assemble(res3_v, ressl_v, bm, cfg.block)
    return res_u, res_v, bm


def _decode_fields(res_u, res_v, blockmap, scale, xi_unit, block,
                   cfl_x, cfl_y, d_max, n_max):
    """Legacy decode: sequential scan over frames (seed pipeline)."""
    g2f = (2.0 * xi_unit) / scale
    T, H, W = res_u.shape

    def frame0(res_u0, res_v0):
        xu = predictors.c2_block(res_u0, block)
        xv = predictors.c2_block(res_v0, block)
        return xu, xv

    def step(carry, inp):
        xu_p, xv_p = carry
        ru, rv, bm = inp
        xu3 = predictors.lorenzo_decode_frame(xu_p, ru, block)
        xv3 = predictors.lorenzo_decode_frame(xv_p, rv, block)
        pu, pv = predictors.sl_predict_frame(
            xu_p, xv_p, g2f, cfl_x, cfl_y, d_max, n_max
        )
        xus = ru + pu
        xvs = rv + pv
        mask = jnp.repeat(jnp.repeat(bm, block, axis=0), block, axis=1)[:H, :W]
        xu = jnp.where(mask, xus, xu3)
        xv = jnp.where(mask, xvs, xv3)
        return (xu, xv), (xu, xv)

    xu0, xv0 = frame0(res_u[0], res_v[0])
    (_, _), (xu_rest, xv_rest) = jax.lax.scan(
        step, (xu0, xv0), (res_u[1:], res_v[1:], blockmap[1:])
    )
    xu = jnp.concatenate([xu0[None], xu_rest], axis=0)
    xv = jnp.concatenate([xv0[None], xv_rest], axis=0)
    return xu, xv


_decode_fields_jit = jax.jit(
    _decode_fields, static_argnums=(5, 8, 9), static_argnames=()
)


def _reconstruct(xu, xv, scale, xi_unit, lossless, u_raw, v_raw):
    g = 2.0 * xi_unit
    u_rec = (xu.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    v_rec = (xv.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    u_rec = jnp.where(lossless, u_raw, u_rec)
    v_rec = jnp.where(lossless, v_raw, v_rec)
    return u_rec, v_rec


def _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W):
    """Mark all vertices of violated faces (vectorized scatter)."""
    HW = H * W
    mask = np.zeros(T * HW, dtype=bool)
    slice_tab = grid.slab_faces(H, W)["slice0"]
    slab_tab = ebound.slab_face_table(H, W)
    t_ids, f_ids = np.nonzero(np.asarray(bad_slice))
    if len(t_ids):
        ids = slice_tab[f_ids].astype(np.int64) + t_ids[:, None] * HW
        mask[ids.reshape(-1)] = True
    t_ids, f_ids = np.nonzero(np.asarray(bad_slab))
    if len(t_ids):
        ids = slab_tab[f_ids].astype(np.int64) + t_ids[:, None] * HW
        mask[ids.reshape(-1)] = True
    return mask.reshape(T, H, W)


# ----------------------------------------------------------------------
# fused pipeline: device-resident verify rounds + parallel-in-time decode
# ----------------------------------------------------------------------

def _decode_fields_parallel(res_u, res_v, blockmap, scale, xi_unit, block,
                            stepper):
    """Parallel-in-time decode shared by the verify simulation and
    decompress (one implementation => bitwise-consistent guarantees).

    ``blockmap`` is a HOST bool array (T, nbi, nbj): maximal runs of
    frames with no SL tile satisfy X_t = X_{t-1} + C2(res_t), a prefix
    sum decoded with one cumsum over time; only frames containing SL
    tiles step through the shared SL ``stepper`` executable.
    """
    res_u = jnp.asarray(res_u)
    res_v = jnp.asarray(res_v)
    bm = np.asarray(blockmap)
    T, H, W = res_u.shape
    g2f = (2.0 * xi_unit) / scale
    c2u = predictors.c2_block(res_u, block)   # every frame, in parallel
    c2v = predictors.c2_block(res_v, block)
    any_sl = bm.reshape(T, -1).any(axis=1)
    any_sl[0] = False                          # frame 0 is spatial-only
    if not any_sl.any():
        return jnp.cumsum(c2u, axis=0), jnp.cumsum(c2v, axis=0)
    Su = jnp.cumsum(c2u, axis=0)
    Sv = jnp.cumsum(c2v, axis=0)
    mask_rep = np.repeat(np.repeat(bm, block, axis=1), block, axis=2)[:, :H, :W]

    us, vs = [], []
    prev_u = prev_v = None
    cur = 0
    for t in np.flatnonzero(any_sl):
        t = int(t)
        if t > cur:
            if cur == 0:
                seg_u, seg_v = Su[:t], Sv[:t]
            else:
                seg_u = (prev_u - Su[cur - 1])[None] + Su[cur:t]
                seg_v = (prev_v - Sv[cur - 1])[None] + Sv[cur:t]
            us.append(seg_u)
            vs.append(seg_v)
            prev_u, prev_v = seg_u[-1], seg_v[-1]
        pu, pv = stepper(prev_u, prev_v, g2f)
        m = jnp.asarray(mask_rep[t])
        xu_t = jnp.where(m, res_u[t] + pu, prev_u + c2u[t])
        xv_t = jnp.where(m, res_v[t] + pv, prev_v + c2v[t])
        us.append(xu_t[None])
        vs.append(xv_t[None])
        prev_u, prev_v = xu_t, xv_t
        cur = t + 1
    if cur < T:
        us.append((prev_u - Su[cur - 1])[None] + Su[cur:])
        vs.append((prev_v - Sv[cur - 1])[None] + Sv[cur:])
    return jnp.concatenate(us, axis=0), jnp.concatenate(vs, axis=0)


class _FusedFns:
    """Jitted stages of the fused pipeline for one static configuration
    (shape x block x n_levels x predictor x backend); cached below.

    ``be_lorenzo`` routes only the Lorenzo-residual op: the pallas
    kernel computes in int32 (|residual| <= 2^32 / xi_unit worst case),
    so callers demote it to xla when xi_unit < 4 keeps no headroom.
    """

    def __init__(self, shape, block, n_levels, predictor, be,
                 be_lorenzo=None):
        self.shape = shape
        self.block = block
        self.n_levels = n_levels
        self.predictor = predictor
        self.be = be
        self.be_lorenzo = be if be_lorenzo is None else be_lorenzo
        T, H, W = shape
        self.nb = (-(-H // block), -(-W // block))
        sf = grid.slab_faces(H, W)
        self._slice_tab = jnp.asarray(sf["slice0"])
        self._slab_tab = jnp.asarray(ebound.slab_face_table(H, W))
        jit = (lambda f, **kw: f) if be == "numpy" else jax.jit

        self.lorenzo_stage = jit(self._lorenzo_stage)
        self.quant_stage = jit(self._quant_stage)
        self.sl_stage = jit(self._sl_stage)
        self.mop_stage = jit(self._mop_stage)
        self.screen_unsafe = jit(self._screen_unsafe)
        self.check_pt = jit(self._check_pt)
        self.face_subset = jit(self._face_subset)

    # ---- encode stages

    def _quant_stage(self, ufp, vfp, eb_vertex, lossless_extra, xi_unit):
        k, lossless = quantize.quantize_eb(eb_vertex, xi_unit, self.n_levels)
        lossless = jnp.logical_or(lossless, lossless_extra)
        k = jnp.where(lossless_extra, -1, k)
        xu = quantize.dual_quantize(ufp, k, lossless, xi_unit)
        xv = quantize.dual_quantize(vfp, k, lossless, xi_unit)
        return xu, xv, k, lossless

    def _lorenzo_stage(self, ufp, vfp, eb_vertex, lossless_extra, xi_unit):
        """Pure-Lorenzo encode: the fused dualquant+residual op, no X
        materialization."""
        k, lossless = quantize.quantize_eb(eb_vertex, xi_unit, self.n_levels)
        lossless = jnp.logical_or(lossless, lossless_extra)
        k = jnp.where(lossless_extra, -1, k)
        res_u = backend_mod.lorenzo_residual(
            ufp, k, lossless, xi_unit, self.block, self.be_lorenzo)
        res_v = backend_mod.lorenzo_residual(
            vfp, k, lossless, xi_unit, self.block, self.be_lorenzo)
        return res_u, res_v, lossless

    def _sl_stage(self, xu, xv, pu, pv):
        res_u = jnp.concatenate(
            [predictors.d2_block(xu[:1], self.block), xu[1:] - pu], axis=0)
        res_v = jnp.concatenate(
            [predictors.d2_block(xv[:1], self.block), xv[1:] - pv], axis=0)
        return res_u, res_v

    def _mop_stage(self, ufp, vfp, k, lossless, xu, xv, pu, pv, xi_unit):
        res3_u = backend_mod.lorenzo_residual(
            ufp, k, lossless, xi_unit, self.block, self.be_lorenzo, x=xu)
        res3_v = backend_mod.lorenzo_residual(
            vfp, k, lossless, xi_unit, self.block, self.be_lorenzo, x=xv)
        zero = jnp.zeros_like(xu[:1])
        ressl_u = jnp.concatenate([zero, xu[1:] - pu], axis=0)
        ressl_v = jnp.concatenate([zero, xv[1:] - pv], axis=0)
        res3_u = jnp.asarray(res3_u)
        res3_v = jnp.asarray(res3_v)
        bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, self.block)
        res_u = mop.assemble(res3_u, ressl_u, bm, self.block)
        res_v = mop.assemble(res3_v, ressl_v, bm, self.block)
        return res_u, res_v, bm

    # ---- verify stages

    def _recon_refix(self, xu_d, xv_d, lossless, u_raw, v_raw, scale,
                     xi_unit, eb_abs):
        u_rec, v_rec = _reconstruct(xu_d, xv_d, scale, xi_unit, lossless,
                                    u_raw, v_raw)
        ur_fp = jnp.round(u_rec.astype(jnp.float64) * scale).astype(jnp.int64)
        vr_fp = jnp.round(v_rec.astype(jnp.float64) * scale).astype(jnp.int64)
        err = jnp.maximum(
            jnp.abs(u_rec.astype(jnp.float64) - u_raw.astype(jnp.float64)),
            jnp.abs(v_rec.astype(jnp.float64) - v_raw.astype(jnp.float64)),
        )
        bad_pt = err > eb_abs
        return ur_fp, vr_fp, bad_pt

    def _screen_unsafe(self, ufp, vfp, ur_fp, vr_fp):
        """Faces whose predicate COULD have flipped (sound screen).

        A face all of whose u-components (or all of whose v-components)
        keep one strict sign in BOTH the original and the reconstruction
        cannot be crossed in either (the convex hull stays off the
        origin, SoS included), so its predicate is provably unchanged.
        Only the remaining faces -- a thin band around the zero set --
        need the exact SoS evaluation.  Pure boolean gathers: no int64
        products.
        """
        T, H, W = self.shape
        HW = H * W
        masks = []
        for o, r in ((ufp, ur_fp), (vfp, vr_fp)):
            masks.append(((o > 0) & (r > 0)).reshape(T, HW))
            masks.append(((o < 0) & (r < 0)).reshape(T, HW))

        def face_all(m, tab):
            return m[:, tab[:, 0]] & m[:, tab[:, 1]] & m[:, tab[:, 2]]

        def unsafe(window):
            pu, nu, pv, nv = (face_all(m, tab) for m, tab in window)
            return ~(pu | nu | pv | nv)

        st = self._slice_tab
        unsafe_slice = unsafe([(m, st) for m in masks])
        bt = self._slab_tab
        pair = [jnp.concatenate([m[:-1], m[1:]], axis=1) for m in masks]
        unsafe_slab = unsafe([(m, bt) for m in pair])
        return unsafe_slice, unsafe_slab

    def _check_pt(self, xu_d, xv_d, lossless, lossless_extra, u_raw, v_raw,
                  scale, xi_unit, eb_abs):
        ur_fp, vr_fp, bad_pt = self._recon_refix(
            xu_d, xv_d, lossless, u_raw, v_raw, scale, xi_unit, eb_abs)
        forced = lossless_extra | bad_pt
        return forced, jnp.asarray(bad_pt).sum(), ur_fp, vr_fp

    def _face_subset(self, ur_flat, vr_flat, verts):
        """Predicates for an explicit face subset (incremental rounds)."""
        T, H, W = self.shape
        fu = ur_flat[verts]
        fv = vr_flat[verts]
        return backend_mod.face_crossed(
            fu, fv, verts.astype(jnp.int64), backend=self.be,
            n_verts=T * H * W)


# 64 entries: the tiled pipeline (core/tiling.py) requests one per
# distinct tile extension AND owned shape (edge/corner/interior tiles x
# first/middle/tail windows) on top of the monolithic shapes; a smaller
# cache would evict live entries and silently recompile every round
@functools.lru_cache(maxsize=64)
def _fused_fns(shape, block, n_levels, predictor, be, be_lorenzo=None):
    return _FusedFns(shape, block, n_levels, predictor, be, be_lorenzo)


def _face_verts(ts, fs, tb, fb, H, W):
    """Global vertex-id triples for explicit (slice, slab) face indices."""
    HW = H * W
    slice_tab = grid.slab_faces(H, W)["slice0"]
    slab_tab = ebound.slab_face_table(H, W)
    return np.concatenate([
        slice_tab[fs].astype(np.int64) + ts[:, None] * HW,
        slab_tab[fb].astype(np.int64) + tb[:, None] * HW,
    ], axis=0)


def _touched_faces(delta_np, T, H, W):
    """Faces incident to newly-forced vertices -> (verts (N,3) global
    ids, slice_sel, slab_sel index arrays)."""
    HW = H * W
    slice_tab = grid.slab_faces(H, W)["slice0"]
    slab_tab = ebound.slab_face_table(H, W)
    d2 = delta_np.reshape(T, HW)
    t_slice = (d2[:, slice_tab[:, 0]] | d2[:, slice_tab[:, 1]]
               | d2[:, slice_tab[:, 2]])
    pair = np.concatenate([d2[:-1], d2[1:]], axis=1)
    t_slab = (pair[:, slab_tab[:, 0]] | pair[:, slab_tab[:, 1]]
              | pair[:, slab_tab[:, 2]])
    ts, fs = np.nonzero(t_slice)
    tb, fb = np.nonzero(t_slab)
    return _face_verts(ts, fs, tb, fb, H, W), (ts, fs), (tb, fb)


def _compress_fused(u, v, cfg: CompressionConfig, be: str):
    t0 = time.perf_counter()
    u, v = _as_fields(u, v)
    T, H, W = u.shape
    eb_abs = _abs_eb(u, v, cfg)
    scale, ufp, vfp = fixedpoint.to_fixed(u, v, cfg.fixed_bits)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    xi_unit, n_usable = quantize.ladder(tau, cfg.n_levels)
    cfl_x = cfg.dt / cfg.dx
    cfl_y = cfg.dt / cfg.dy
    g2f = (2.0 * xi_unit) / scale

    # the pallas Lorenzo kernel is int32; at xi_unit < 4 a worst-case
    # residual (8 * 2^29 / xi_unit) could wrap, so demote that op to xla
    be_lorenzo = "xla" if (be == "pallas" and xi_unit < 4) else be
    fns = _fused_fns((T, H, W), cfg.block, cfg.n_levels, cfg.predictor, be,
                     be_lorenzo)
    stepper = backend_mod.sl_stepper(be, cfl_x, cfl_y, cfg.d_max, cfg.n_max)
    nbi, nbj = fns.nb

    ufp_j = jnp.asarray(ufp)
    vfp_j = jnp.asarray(vfp)
    u_j = jnp.asarray(u)
    v_j = jnp.asarray(v)
    # eb derivation evaluates every face's SoS predicate along the way
    # (the crossed-face zeroing); reuse those instead of a second full
    # predicate pass over the original field (the seed paid it twice)
    eb_vertex, slice_pred0, slab_pred0 = _derive_eb_jit(
        ufp_j, vfp_j, int(max(tau, 1)))

    lossless_extra = jnp.zeros((T, H, W), dtype=bool)
    if tau < 1 or n_usable < 1:
        lossless_extra = jnp.ones((T, H, W), dtype=bool)

    slice0_np = slab0_np = None   # host copies, fetched once if needed
    rounds = 0
    stats_rounds = []
    prev_extra = None
    while True:
        # ---- encode (jitted stages; device-resident)
        if cfg.predictor == "lorenzo":
            res_u, res_v, lossless = fns.lorenzo_stage(
                ufp_j, vfp_j, eb_vertex, lossless_extra, xi_unit)
            bm = np.zeros((T, nbi, nbj), dtype=bool)
        else:
            xu, xv, k, lossless = fns.quant_stage(
                ufp_j, vfp_j, eb_vertex, lossless_extra, xi_unit)
            pu, pv = backend_mod.sl_predictions(xu, xv, g2f, stepper)
            if cfg.predictor == "sl":
                res_u, res_v = fns.sl_stage(xu, xv, pu, pv)
                bm = np.ones((T, nbi, nbj), dtype=bool)
                bm[0] = False
            else:
                res_u, res_v, bm_dev = fns.mop_stage(
                    ufp_j, vfp_j, k, lossless, xu, xv, pu, pv, xi_unit)
                bm = np.asarray(bm_dev)

        if not cfg.verify:
            break

        # ---- simulate the exact decode (same code as decompress)
        xu_d, xv_d = _decode_fields_parallel(
            res_u, res_v, bm, scale, xi_unit, cfg.block, stepper)

        # pointwise bound + reconstruction refix, device-resident
        forced, n_pt, ur_fp, vr_fp = fns.check_pt(
            xu_d, xv_d, lossless, lossless_extra, u_j, v_j,
            scale, xi_unit, eb_abs)
        n_bad = int(n_pt)

        # face predicates are re-evaluated only where they could have
        # changed: round 0 uses the sign-stability screen (a thin band
        # around the zero set); later rounds only faces incident to
        # newly-forced vertices, since the reconstruction changed only
        # there (#3.5).
        if prev_extra is None:
            unsafe_sl, unsafe_sb = fns.screen_unsafe(
                ufp_j, vfp_j, ur_fp, vr_fp)
            ts, fs = np.nonzero(np.asarray(unsafe_sl))
            tb, fb = np.nonzero(np.asarray(unsafe_sb))
            verts = _face_verts(ts, fs, tb, fb, H, W)
        else:
            delta_np = np.asarray(lossless_extra ^ prev_extra)
            verts, (ts, fs), (tb, fb) = _touched_faces(delta_np, T, H, W)
        if len(verts):
            if slice0_np is None:
                slice0_np = np.asarray(slice_pred0)
                slab0_np = np.asarray(slab_pred0)
            orig = np.concatenate([slice0_np[ts, fs], slab0_np[tb, fb]])
            B = max(8, 1 << (len(verts) - 1).bit_length())
            verts_p = np.concatenate([
                verts,
                np.tile(np.array([[0, 1, 2]], np.int64),
                        (B - len(verts), 1)),
            ], axis=0)
            crossed = np.asarray(fns.face_subset(
                ur_fp.reshape(-1), vr_fp.reshape(-1),
                jnp.asarray(verts_p)))[: len(verts)]
            bad = crossed != orig
            n_bad += int(bad.sum())
            if bad.any():
                add = np.zeros(T * H * W, dtype=bool)
                add[verts[bad].reshape(-1)] = True
                forced = forced | jnp.asarray(add.reshape(T, H, W))

        stats_rounds.append(n_bad)
        if n_bad == 0 or rounds >= cfg.max_rounds:
            break
        prev_extra = lossless_extra
        lossless_extra = forced
        rounds += 1

    sym_u, esc_u = encode.to_symbols(np.asarray(res_u))
    sym_v, esc_v = encode.to_symbols(np.asarray(res_v))
    lossless_np = np.asarray(lossless)
    u_ll = u[lossless_np]
    v_ll = v[lossless_np]

    header = {
        "version": FORMAT_VERSION,
        "pipeline": "fused",
        "sl_backend": be,
        "shape": [int(T), int(H), int(W)],
        "scale": float(scale),
        "xi_unit": int(xi_unit),
        "block": int(cfg.block),
        "cfl_x": float(cfl_x),
        "cfl_y": float(cfl_y),
        "d_max": float(cfg.d_max),
        "n_max": int(cfg.n_max),
        "eb_abs": float(eb_abs),
    }
    sections = {
        "sym_u": sym_u,
        "sym_v": sym_v,
        "esc_u": esc_u,
        "esc_v": esc_v,
        "lossless": np.packbits(lossless_np),
        "u_ll": u_ll,
        "v_ll": v_ll,
        "blockmap": np.packbits(np.asarray(bm)),
        "bm_shape": np.asarray(bm.shape, dtype=np.int32),
    }
    blob = encode.pack(header, sections, cfg.zstd_level)
    t1 = time.perf_counter()
    orig_bytes = u.nbytes + v.nbytes
    stats = {
        "orig_bytes": orig_bytes,
        "comp_bytes": len(blob),
        "ratio": orig_bytes / max(len(blob), 1),
        "lossless_frac": float(lossless_np.mean()),
        "sl_block_frac": float(np.asarray(bm).mean()),
        "verify_rounds": rounds,
        "verify_bad_counts": stats_rounds,
        "eb_abs": eb_abs,
        "scale": scale,
        "tau": tau,
        "xi_unit": xi_unit,
        "seconds": t1 - t0,
        "backend": be,
        "pipeline": "fused",
    }
    return blob, stats


# ----------------------------------------------------------------------
# legacy (seed) pipeline -- kept for A/B benchmarking
# ----------------------------------------------------------------------

def _compress_legacy(u, v, cfg: CompressionConfig):
    t0 = time.perf_counter()
    u, v = _as_fields(u, v)
    T, H, W = u.shape
    eb_abs = _abs_eb(u, v, cfg)
    scale, ufp, vfp = fixedpoint.to_fixed(u, v, cfg.fixed_bits)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    xi_unit, n_usable = quantize.ladder(tau, cfg.n_levels)

    ufp_j = jnp.asarray(ufp)
    vfp_j = jnp.asarray(vfp)
    slice_pred0, slab_pred0 = _predicates(ufp_j, vfp_j)

    lossless_extra = jnp.zeros((T, H, W), dtype=bool)
    if tau < 1 or n_usable < 1:
        lossless_extra = jnp.ones((T, H, W), dtype=bool)

    cfl_x = cfg.dt / cfg.dx
    cfl_y = cfg.dt / cfg.dy

    eb_vertex, _, _ = _derive_eb_jit(ufp_j, vfp_j, int(max(tau, 1)))

    rounds = 0
    stats_rounds = []
    while True:
        xu, xv, lossless = _encode_stage(
            ufp_j, vfp_j, eb_vertex, xi_unit, cfg.n_levels, lossless_extra, cfg
        )
        res_u, res_v, blockmap = _residuals(xu, xv, scale, xi_unit, cfg)

        if not cfg.verify:
            break
        # simulate the exact decode
        xu_d, xv_d = _decode_fields_jit(
            res_u, res_v, blockmap, scale, xi_unit, cfg.block,
            cfl_x, cfl_y, cfg.d_max, cfg.n_max,
        )
        u_rec, v_rec = _reconstruct(
            xu_d, xv_d, scale, xi_unit, lossless, jnp.asarray(u), jnp.asarray(v)
        )
        # end-to-end predicate check on the refixed reconstruction
        ur_fp, vr_fp = fixedpoint.refix(np.asarray(u_rec), np.asarray(v_rec), scale)
        slice_pred1, slab_pred1 = _predicates(jnp.asarray(ur_fp), jnp.asarray(vr_fp))
        bad_slice = np.asarray(slice_pred0 ^ slice_pred1)
        bad_slab = np.asarray(slab_pred0 ^ slab_pred1)
        # pointwise bound check (float32 output, strict)
        err = np.maximum(
            np.abs(np.asarray(u_rec, dtype=np.float64) - u.astype(np.float64)),
            np.abs(np.asarray(v_rec, dtype=np.float64) - v.astype(np.float64)),
        )
        bad_pt = err > eb_abs

        n_bad = int(bad_slice.sum()) + int(bad_slab.sum()) + int(bad_pt.sum())
        stats_rounds.append(n_bad)
        if n_bad == 0 or rounds >= cfg.max_rounds:
            break
        extra = np.asarray(lossless_extra).copy()
        extra |= bad_pt
        extra |= _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W)
        lossless_extra = jnp.asarray(extra)
        rounds += 1

    sym_u, esc_u = encode.to_symbols(np.asarray(res_u))
    sym_v, esc_v = encode.to_symbols(np.asarray(res_v))
    lossless_np = np.asarray(lossless)
    u_ll = u[lossless_np]
    v_ll = v[lossless_np]

    header = {
        "version": FORMAT_VERSION,
        "pipeline": "legacy",
        "shape": [int(T), int(H), int(W)],
        "scale": float(scale),
        "xi_unit": int(xi_unit),
        "block": int(cfg.block),
        "cfl_x": float(cfl_x),
        "cfl_y": float(cfl_y),
        "d_max": float(cfg.d_max),
        "n_max": int(cfg.n_max),
        "eb_abs": float(eb_abs),
    }
    sections = {
        "sym_u": sym_u,
        "sym_v": sym_v,
        "esc_u": esc_u,
        "esc_v": esc_v,
        "lossless": np.packbits(lossless_np),
        "u_ll": u_ll,
        "v_ll": v_ll,
        "blockmap": np.packbits(np.asarray(blockmap)),
        "bm_shape": np.asarray(blockmap.shape, dtype=np.int32),
    }
    blob = encode.pack(header, sections, cfg.zstd_level)
    t1 = time.perf_counter()
    orig_bytes = u.nbytes + v.nbytes
    stats = {
        "orig_bytes": orig_bytes,
        "comp_bytes": len(blob),
        "ratio": orig_bytes / max(len(blob), 1),
        "lossless_frac": float(lossless_np.mean()),
        "sl_block_frac": float(np.asarray(blockmap).mean()),
        "verify_rounds": rounds,
        "verify_bad_counts": stats_rounds,
        "eb_abs": eb_abs,
        "scale": scale,
        "tau": tau,
        "xi_unit": xi_unit,
        "seconds": t1 - t0,
        "backend": "xla",
        "pipeline": "legacy",
    }
    return blob, stats


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------

def compress(u, v, cfg: CompressionConfig = CompressionConfig()):
    if cfg.tiling is not None:
        from . import tiling
        return tiling.compress_tiled(u, v, cfg, cfg.tiling)
    fused = perfflags.fused_default() if cfg.fused is None else cfg.fused
    if not fused:
        return _compress_legacy(u, v, cfg)
    be = backend_mod.resolve(cfg.backend)
    return _compress_fused(u, v, cfg, be)


def decompress(blob: bytes, backend: Optional[str] = None):
    if encode.is_tiled(blob):
        from . import tiling
        return tiling.decompress_tiled(blob, backend=backend)
    header, sections = encode.unpack(blob)
    version = header.get("version", 1)
    if version > FORMAT_VERSION:
        raise ValueError(
            f"container format version {version} is newer than this "
            f"decoder (supports <= {FORMAT_VERSION})")
    T, H, W = header["shape"]
    res_u = encode.from_symbols(sections["sym_u"], sections["esc_u"], (T, H, W))
    res_v = encode.from_symbols(sections["sym_v"], sections["esc_v"], (T, H, W))
    bm_shape = tuple(int(x) for x in sections["bm_shape"])
    n_bm = int(np.prod(bm_shape))
    blockmap = np.unpackbits(sections["blockmap"], count=n_bm).astype(bool)
    blockmap = blockmap.reshape(bm_shape)
    lossless = np.unpackbits(sections["lossless"], count=T * H * W).astype(bool)
    lossless = lossless.reshape(T, H, W)

    if header.get("pipeline", "legacy") == "fused":
        # replay the SL predictions through the stepper executable the
        # encoder verified with (backend recorded in the header)
        be = backend_mod.resolve(backend or header.get("sl_backend"))
        stepper = backend_mod.sl_stepper(
            be, header["cfl_x"], header["cfl_y"],
            header["d_max"], header["n_max"])
        xu, xv = _decode_fields_parallel(
            jnp.asarray(res_u), jnp.asarray(res_v), blockmap,
            header["scale"], header["xi_unit"], header["block"], stepper)
    else:
        xu, xv = _decode_fields_jit(
            jnp.asarray(res_u),
            jnp.asarray(res_v),
            jnp.asarray(blockmap),
            header["scale"],
            header["xi_unit"],
            header["block"],
            header["cfl_x"],
            header["cfl_y"],
            header["d_max"],
            header["n_max"],
        )
    u_raw = np.zeros((T, H, W), dtype=np.float32)
    v_raw = np.zeros((T, H, W), dtype=np.float32)
    u_raw[lossless] = sections["u_ll"]
    v_raw[lossless] = sections["v_ll"]
    u_rec, v_rec = _reconstruct(
        xu, xv, header["scale"], header["xi_unit"],
        jnp.asarray(lossless), jnp.asarray(u_raw), jnp.asarray(v_raw),
    )
    return np.asarray(u_rec), np.asarray(v_rec)
