"""Evaluation metrics (paper Sec. VII-C)."""
from __future__ import annotations

import numpy as np

from . import trajectory


def compression_ratio(orig_bytes: int, comp_bytes: int) -> float:
    return orig_bytes / max(comp_bytes, 1)


def psnr(u, v, u_rec, v_rec) -> float:
    """PSNR = 20 log10(range) - 10 log10(MSE), over both components."""
    d = np.concatenate(
        [
            (np.asarray(u, np.float64) - np.asarray(u_rec, np.float64)).ravel(),
            (np.asarray(v, np.float64) - np.asarray(v_rec, np.float64)).ravel(),
        ]
    )
    mse = float(np.mean(d * d))
    vals = np.concatenate([np.asarray(u).ravel(), np.asarray(v).ravel()])
    rng = float(vals.max() - vals.min())
    if mse == 0.0:
        return float("inf")
    return 20.0 * np.log10(max(rng, 1e-300)) - 10.0 * np.log10(mse)


def evaluate(u, v, u_rec, v_rec, scale, orig_bytes, comp_bytes,
             with_tracks: bool = True) -> dict:
    """Full metric suite: CR, PSNR, FC_t, FC_s, #Traj (orig vs rec).

    The fields are refixed ONCE and the face-predicate tables are built
    ONCE per field, then threaded through both the false-case diff and
    the track extraction (the seed rebuilt both twice).
    """
    from . import fixedpoint

    out = {
        "CR": compression_ratio(orig_bytes, comp_bytes),
        "PSNR": psnr(u, v, u_rec, v_rec),
        "max_err": float(
            max(
                np.abs(np.asarray(u, np.float64) - np.asarray(u_rec, np.float64)).max(),
                np.abs(np.asarray(v, np.float64) - np.asarray(v_rec, np.float64)).max(),
            )
        ),
    }
    uo, vo = fixedpoint.refix(u, v, scale)
    ur, vr = fixedpoint.refix(u_rec, v_rec, scale)
    p0 = trajectory.face_predicate_tables(uo, vo)
    p1 = trajectory.face_predicate_tables(ur, vr)
    out.update(trajectory.false_cases_from_tables(p0, p1))
    if with_tracks:
        out["n_traj_orig"] = trajectory.extract_tracks(
            uo, vo, tables=p0)["n_tracks"]
        out["n_traj_rec"] = trajectory.extract_tracks(
            ur, vr, tables=p1)["n_tracks"]
    return out
