"""Tiled streaming compression with halo-exact trajectory preservation.

The monolithic pipeline (compressor.py) holds the full (T, H, W) field
device-resident.  This module splits the field into spatial tiles x
temporal windows, compresses every (tile, window) as an independent unit
through the same fused stages, and packs the units into a random-access
container (encode.TiledWriter) -- while keeping the decoded output
BIT-IDENTICAL to the monolithic fused pipeline.  Why that is possible:

1.  *Order isomorphism.*  The SoS predicate (sos.py) reads vertex ids
    only through ``<`` comparisons, and a sub-box's row-major local ids
    preserve the global id order (grid.box_vertex_ids).  So predicates
    and Alg.-2 bounds evaluated on a halo-extended tile are bit-equal to
    the global evaluation restricted to that tile.

2.  *Halo-exact eb reduction.*  Each tile derives per-vertex error
    bounds over its one-cell/one-frame halo extension; the global bound
    is the MIN across every tile that sees a vertex.  Every face lies
    inside at least one extension, and a tile missing some of a vertex's
    incident faces only ever reports a LARGER bound, so the reduction
    reconstructs the global per-vertex eb exactly -- seam vertices get
    the same bound on both sides.

3.  *Pointwise X.*  Dual-quantization is pointwise in (value, eb,
    forced-mask), and integer residual decode is an exact inverse of
    residual encode, so the reconstructed integer field X -- and hence
    the float32 output -- is fully determined by (eb, forced mask,
    xi_unit) regardless of how residuals are blocked into units.  Units
    may therefore reset the temporal predictor at window starts and run
    the semi-Lagrangian predictor tile-locally (full random access)
    without changing a single output bit.

4.  *Seam-agreed verify.*  The verify-and-correct loop runs per tile on
    the halo extension; every face is checked by every tile that sees
    it, with identical values and order-isomorphic ids, so all tiles
    reach the same forced/not decision and the per-round union of
    forced vertices equals the monolithic round's forced set.  By
    induction the fixpoint -- and the output -- is bit-identical.

Entry points:

    blob, stats = compress_tiled(u, v, cfg, TileGrid(...))
    blob, stats = compress_stream(frame_pairs, cfg, grid,
                                  value_range=(lo, hi))   # bounded memory
    u, v = decompress_tiled(blob)                         # full field
    u, v = decompress_region(blob, (t0, t1, i0, i1, j0, j1))
    plan = read_plan(blob, region)    # directory entries a decode touches

``compress_stream`` consumes an iterable of per-frame ``(u_t, v_t)``
planes and holds only ~2 windows of frames in memory; units are written
to the sink as soon as their window's verify fixpoint can no longer be
affected by future frames.  A verify cascade that would force a vertex
in an already-emitted window raises StreamingCascadeError (enlarge
``window_t`` or use compress_tiled); forcing cascades that long have not
been observed on any test field.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import backend as backend_mod
from . import compressor, ebound, ebpolicy, encode, fixedpoint, pipeline, sos
from . import grid as mesh
from .. import obs

# v4: prologue frame + per-frame "CPUN"/"CPPR" preambles (walkable body,
# salvageable without a footer) + per-unit CRC in the directory.
# Version-3 and older archives stay readable: the directory-driven read
# path never looks between frames and checksum verification keys off
# the entry's ``crc`` field (tests/test_container_golden.py pins this
# against a checked-in v3 blob).
TILED_FORMAT_VERSION = 4
# v5: unit frames may be CPTH1 (device entropy stage, core/entropy.py)
# instead of CPTZ1/CPTL1.  Host-codec archives keep writing v4 -- the
# bump applies only where an old reader would actually fail.
TILED_FORMAT_VERSION_DEVICE = 5
# v6: adaptive eb policy (core/ebpolicy.py): the container header
# records the policy spec and every unit frame records its own base
# bound ("eb_base", self-describing msgpack extras a v<=5 reader skips).
# Uniform-policy archives keep writing v4/v5, so the goldens and old
# readers are unaffected (DESIGN.md #16).
TILED_FORMAT_VERSION_ADAPTIVE = 6
_EB_BIG = np.int64(2**62)
# batched unit execution: cap the stacked batch (with pow2 padding this
# bounds both peak memory and the number of compiled batch sizes).
# The per-run value is a searched scheduling knob
# (pipeline.PLAN_KNOBS["batch_cap"], carried on _State); chunking by
# signature group keeps the bytes identical for every cap value.
_BATCH_CAP = pipeline.PLAN_DEFAULTS["batch_cap"]


class StreamingCascadeError(RuntimeError):
    """A verify-and-correct cascade crossed the emitted-window frontier."""


# ----------------------------------------------------------------------
# tile planning
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Tiling geometry: spatial tiles x temporal windows + halo widths."""

    tile_h: int = 128
    tile_w: int = 128
    window_t: int = 32
    halo: int = 1       # spatial halo (cells); >= 1 for halo-exact eb
    thalo: int = 1      # temporal halo (frames); >= 1

    def validate(self):
        # real raises, not asserts: geometry validation must hold under
        # python -O (a halo=0 grid silently breaks eb exactness)
        if self.tile_h < 1 or self.tile_w < 1 or self.window_t < 1:
            raise ValueError(f"tile/window sizes must be >= 1: {self}")
        if self.halo < 1:
            raise ValueError("spatial halo must cover incident faces "
                             "(halo >= 1)")
        if self.thalo < 1:
            raise ValueError("temporal halo must cover incident slabs "
                             "(thalo >= 1)")


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """One (window, tile) unit: owned + halo-extended half-open boxes."""

    wi: int
    ti: int
    tj: int
    t0: int; t1: int; i0: int; i1: int; j0: int; j1: int
    et0: int; et1: int; ei0: int; ei1: int; ej0: int; ej1: int

    @property
    def key(self):
        return (self.wi, self.ti, self.tj)

    @property
    def owned_box(self):
        return (self.t0, self.t1, self.i0, self.i1, self.j0, self.j1)

    @property
    def ext_box(self):
        return (self.et0, self.et1, self.ei0, self.ei1, self.ej0, self.ej1)

    @property
    def owned_shape(self):
        return (self.t1 - self.t0, self.i1 - self.i0, self.j1 - self.j0)

    @property
    def ext_shape(self):
        return (self.et1 - self.et0, self.ei1 - self.ei0,
                self.ej1 - self.ej0)

    @property
    def owned_in_ext(self):
        return (slice(self.t0 - self.et0, self.t1 - self.et0),
                slice(self.i0 - self.ei0, self.i1 - self.ei0),
                slice(self.j0 - self.ej0, self.j1 - self.ej0))


def window_specs(wi: int, t0: int, t1: int, H: int, W: int, et1: int,
                 grid: TileGrid):
    """Tile specs of one temporal window (et1 = clamped extended end)."""
    et0 = max(t0 - grid.thalo, 0)
    nti = -(-H // grid.tile_h)
    ntj = -(-W // grid.tile_w)
    specs = []
    for ti in range(nti):
        i0 = ti * grid.tile_h
        i1 = min(i0 + grid.tile_h, H)
        ei0 = max(i0 - grid.halo, 0)
        ei1 = min(i1 + grid.halo, H)
        for tj in range(ntj):
            j0 = tj * grid.tile_w
            j1 = min(j0 + grid.tile_w, W)
            ej0 = max(j0 - grid.halo, 0)
            ej1 = min(j1 + grid.halo, W)
            specs.append(TileSpec(wi, ti, tj, t0, t1, i0, i1, j0, j1,
                                  et0, et1, ei0, ei1, ej0, ej1))
    return specs


def plan(shape, grid: TileGrid):
    """All TileSpecs for a full (T, H, W) field."""
    grid.validate()
    T, H, W = shape
    specs = []
    for wi in range(-(-T // grid.window_t)):
        t0 = wi * grid.window_t
        t1 = min(t0 + grid.window_t, T)
        et1 = min(t1 + grid.thalo, T)
        specs.extend(window_specs(wi, t0, t1, H, W, et1, grid))
    return specs


# ----------------------------------------------------------------------
# sliding per-frame plane storage (bounded memory for streaming)
# ----------------------------------------------------------------------

class _Planes:
    """Dict-of-frames (H, W) numpy storage with box accessors."""

    def __init__(self, H, W, dtype, fill):
        self.H, self.W = H, W
        self.dtype = dtype
        self.fill = fill
        self.p = {}

    def ensure(self, t):
        if t not in self.p:
            self.p[t] = np.full((self.H, self.W), self.fill, self.dtype)
        return self.p[t]

    def put(self, t, arr):
        self.p[t] = np.asarray(arr, self.dtype)

    def box(self, b):
        t0, t1, i0, i1, j0, j1 = b
        return np.stack([self.ensure(t)[i0:i1, j0:j1]
                         for t in range(t0, t1)])

    def min_box(self, b, vals):
        t0, t1, i0, i1, j0, j1 = b
        for k, t in enumerate(range(t0, t1)):
            sl = self.ensure(t)[i0:i1, j0:j1]
            np.minimum(sl, vals[k], out=sl)

    def or_box(self, b, vals):
        t0, t1, i0, i1, j0, j1 = b
        for k, t in enumerate(range(t0, t1)):
            self.ensure(t)[i0:i1, j0:j1] |= vals[k]

    def drop_below(self, t):
        for k in [k for k in self.p if k < t]:
            del self.p[k]


# ----------------------------------------------------------------------
# shared state + jitted batch deriver
# ----------------------------------------------------------------------

@dataclasses.dataclass
class _State:
    cfg: object
    grid: TileGrid
    ex: object                      # pipeline.PlanExecutor (stage impls)
    be: str
    H: int
    W: int
    scale: float
    eb_abs: float
    tau: int
    xi_unit: int
    n_usable: int
    g2f: float
    stepper: object
    u: _Planes
    v: _Planes
    ufp: _Planes
    vfp: _Planes
    eb: _Planes
    forced: _Planes
    preds: dict = dataclasses.field(default_factory=dict)
    seen: dict = dataclasses.field(default_factory=dict)
    writer: object = None
    prologue: dict = None           # global decode params (v4 prologue)
    tindex: object = None           # analysis.index.TrackIndexBuilder | None
    n_frames: int = 0
    bad_counts: list = dataclasses.field(default_factory=list)
    rounds: int = 0
    n_ll: int = 0
    n_sl_blocks: int = 0
    n_blocks: int = 0
    n_verts: int = 0
    n_units: int = 0
    batch_cap: int = _BATCH_CAP     # searched scheduling knob (never
                                    # changes bytes; pipeline.PLAN_KNOBS)
    policy: object = None           # normalized ebpolicy.TilePolicy |
                                    # None (uniform scalar path)
    ebf: object = None              # adaptive only: float64 _Planes of
                                    # resolved per-vertex ABSOLUTE base
                                    # bounds (verify + eb_base headers)
    eb_factor: float = 1.0          # cfg.eb-units -> absolute (1.0 for
                                    # abs mode, the f32 range for rel)


def _init_state(cfg, grid: TileGrid, H, W, vrange, sink):
    """Global stream parameters from the (exact) global value range.

    Mirrors the monolithic derivation bit-for-bit: same eb_abs, fixed-
    point scale, tau and xi_unit, so every downstream integer matches.
    """
    grid.validate()
    be = backend_mod.resolve(cfg.backend)
    lo, hi = float(vrange[0]), float(vrange[1])
    pol = ebpolicy.normalize(getattr(cfg, "eb_policy", None))
    if cfg.mode == "abs":
        eb_factor = 1.0
    else:
        # the value range is reduced in float32 exactly like the
        # monolithic _abs_eb (fields are float32, so lo/hi are exactly
        # representable and only the SUBTRACTION rounding matters --
        # a f64 subtract here once cost a off-by-one tau at 64x256x256)
        rng = float(np.float32(hi) - np.float32(lo))
        ebpolicy.check_relative_range(rng, max(abs(lo), abs(hi)))
        eb_factor = max(rng, 1e-30)
    # the global plan derives from the policy's LOOSEST bound; adaptive
    # per-vertex caps only clamp down from it (core/ebpolicy.py)
    eb_abs = float(cfg.eb if pol is None
                   else ebpolicy.max_bound(pol)) * eb_factor
    max_abs = max(abs(lo), abs(hi), 1e-300)
    scale = fixedpoint.compute_scale(max_abs, cfg.fixed_bits)
    plan = pipeline.plan_from_cfg(cfg, be, scale, eb_abs, name="tiled")
    ex = pipeline.PlanExecutor(plan)
    all_ll = plan.tau < 1 or plan.n_usable < 1
    tindex = None
    if getattr(cfg, "track_index", True):
        from ..analysis.index import TrackIndexBuilder

        tindex = TrackIndexBuilder(grid, be)
    st = _State(
        tindex=tindex,
        cfg=cfg, grid=grid, ex=ex, be=be, H=H, W=W,
        scale=plan.scale, eb_abs=plan.eb_abs, tau=plan.tau,
        xi_unit=plan.xi_unit, n_usable=plan.n_usable, g2f=plan.g2f,
        batch_cap=max(int(pipeline.resolve_knobs(cfg)["batch_cap"]), 1),
        stepper=ex.stepper,
        u=_Planes(H, W, np.float32, 0.0),
        v=_Planes(H, W, np.float32, 0.0),
        ufp=_Planes(H, W, np.int64, 0),
        vfp=_Planes(H, W, np.int64, 0),
        eb=_Planes(H, W, np.int64, _EB_BIG),
        forced=_Planes(H, W, bool, all_ll),
        policy=pol,
        ebf=(None if pol is None
             else _Planes(H, W, np.float64, np.inf)),
        eb_factor=eb_factor,
    )
    # v4 prologue: the global decode parameters, written up front so a
    # footerless (crashed/truncated) archive remains self-describing
    # for encode.salvage_container.  shape[0] is 0 here -- the true T
    # is only known at finish time; salvage recovers it from unit boxes.
    prologue = _container_header(st, 0)
    prologue["prologue"] = True
    st.prologue = prologue
    st.writer = encode.TiledWriter(sink, cfg.zstd_level, prologue=prologue)
    return st


def _add_frame(st: _State, t, u_t, v_t, ufp_t=None, vfp_t=None):
    """Insert one frame; ``ufp_t``/``vfp_t`` accept the fixed-point
    planes precomputed off-thread (the async engine's ingest stage --
    np.round(x64 * scale) is deterministic, so who computes it cannot
    change a bit)."""
    u_t = np.asarray(u_t, np.float32)
    v_t = np.asarray(v_t, np.float32)
    if u_t.shape != (st.H, st.W) or v_t.shape != (st.H, st.W):
        raise ValueError(
            f"frame {t} shape {u_t.shape}/{v_t.shape} != ({st.H}, {st.W})")
    st.n_frames = max(st.n_frames, t + 1)
    st.u.put(t, u_t)
    st.v.put(t, v_t)
    if ufp_t is None:
        ufp_t = np.round(u_t.astype(np.float64) * st.scale)
    if vfp_t is None:
        vfp_t = np.round(v_t.astype(np.float64) * st.scale)
    st.ufp.put(t, ufp_t)
    st.vfp.put(t, vfp_t)


def _pick_fns(st: _State, shape):
    # one keyed registry for every path (pipeline.unit_fns); the pallas
    # int32-headroom demotion rule lives in the plan
    return st.ex.fns(shape)


def _sig(spec: TileSpec):
    """Batching signature: units sharing it stack through one vmapped
    executable set (pipeline.BatchFns)."""
    return pipeline.unit_signature(
        spec.ext_shape, spec.owned_shape,
        (spec.t0 - spec.et0, spec.i0 - spec.ei0, spec.j0 - spec.ej0))


@functools.lru_cache(maxsize=8)
def _batch_deriver(tau: int):
    """Jitted, device-parallel per-vertex eb derivation over a stacked
    batch of same-shape tile extensions (parallel/sharding.py mesh)."""
    from ..parallel import sharding

    def one(uu, vv):
        return ebound.derive_vertex_eb(uu, vv, tau)

    return jax.jit(lambda us, vs: sharding.map_tiles(one, us, vs))


def _derive_window(st: _State, w):
    """Phase 1 for one window: per-tile eb + original face predicates,
    min-reduced into the global per-vertex bound planes."""
    run = _batch_deriver(int(max(st.tau, 1)))
    groups = {}
    for spec in w.specs:
        groups.setdefault(spec.ext_shape, []).append(spec)
    with obs.span("tiling.derive_window", window=int(w.wi),
                  units=len(w.specs)):
        for specs in groups.values():
            us = np.stack([st.ufp.box(s.ext_box) for s in specs])
            vs = np.stack([st.vfp.box(s.ext_box) for s in specs])
            ebs, slice_c, slab_c = run(us, vs)
            # np.asarray of the device results is the host fetch -- the
            # stage's device-sync point
            ebs = np.asarray(ebs)
            slice_c = np.asarray(slice_c)
            slab_c = np.asarray(slab_c)
            for k, spec in enumerate(specs):
                st.eb.min_box(spec.ext_box, ebs[k])
                st.preds[spec.key] = (slice_c[k], slab_c[k])
    if st.policy is not None:
        # adaptive policy: min the resolved per-vertex caps into the
        # derived bound planes (idempotent, so thalo overlap between
        # windows and journaled re-derivation after resume are safe);
        # the float64 bound planes feed verify and the eb_base headers
        et0 = min(s.et0 for s in w.specs)
        for t in range(et0, w.et1):
            boundf = ebpolicy.frame_bounds(st.policy, t, st.H, st.W,
                                           st.eb_factor)
            cap = np.floor(boundf * st.scale).astype(np.int64)
            np.minimum(st.eb.ensure(t), cap, out=st.eb.ensure(t))
            np.minimum(st.ebf.ensure(t), boundf, out=st.ebf.ensure(t))
    w.derived = True


# ----------------------------------------------------------------------
# per-tile encode + verify round
# ----------------------------------------------------------------------

def _quant_and_streams(st: _State, spec: TileSpec):
    """Quantize the halo extension + build the unit's residual streams
    (sequential per-unit emission path; the batched path is
    _encode_group).  Returns only what emission reads."""
    _, _, ll_e, res_u, res_v, bm = st.ex.encode_unit(
        st.ufp.box(spec.ext_box), st.vfp.box(spec.ext_box),
        st.eb.box(spec.ext_box), st.forced.box(spec.ext_box),
        spec.owned_in_ext)
    return ll_e, res_u, res_v, bm


def _tile_round(st: _State, spec: TileSpec, delta):
    """One verify round on one tile's halo extension.

    ``delta`` is None for the initial (sign-stability-screened) full
    check, else the ext-shaped bool mask of vertices forced since this
    tile last checked (only incident faces are re-evaluated).  Returns
    (forced_ext bool, n_bad) with decisions bit-equal to the monolithic
    round restricted to this extension.
    """
    # bind the extension boxes on device once; encode_unit and the
    # checks below reuse them (jnp.asarray of a device array is free)
    ufp_e = jnp.asarray(st.ufp.box(spec.ext_box))
    vfp_e = jnp.asarray(st.vfp.box(spec.ext_box))
    extra_e = jnp.asarray(st.forced.box(spec.ext_box))
    xu_e, xv_e, ll_e, res_u, res_v, bm = st.ex.encode_unit(
        ufp_e, vfp_e, st.eb.box(spec.ext_box), extra_e, spec.owned_in_ext)
    fns_e = _pick_fns(st, spec.ext_shape)
    o = spec.owned_in_ext
    # simulate the unit's exact decode, paste into the extension
    xu_d, xv_d = st.ex.decode_fields(res_u, res_v, bm)
    xu_sim = jnp.asarray(xu_e).at[o].set(xu_d)
    xv_sim = jnp.asarray(xv_e).at[o].set(xv_d)
    u_e = jnp.asarray(st.u.box(spec.ext_box))
    v_e = jnp.asarray(st.v.box(spec.ext_box))
    forced, n_pt, ur_fp, vr_fp = fns_e.check_pt(
        xu_sim, xv_sim, ll_e, extra_e, u_e, v_e,
        st.scale, st.xi_unit,
        # uniform passes the exact scalar (pre-policy trace); adaptive
        # passes the resolved per-vertex absolute bounds, which the
        # pointwise check broadcasts elementwise
        st.eb_abs if st.policy is None
        else jnp.asarray(st.ebf.box(spec.ext_box)))
    n_bad = int(n_pt)
    forced_np = np.asarray(forced)
    add, nf = pipeline.check_faces(
        fns_e, spec.ext_shape, ufp_e, vfp_e, ur_fp, vr_fp,
        st.preds[spec.key], delta)
    n_bad += nf
    if add is not None:
        forced_np = forced_np | add
    return forced_np, n_bad


# ----------------------------------------------------------------------
# batched same-signature unit execution (pipeline.BatchFns)
# ----------------------------------------------------------------------

def _stack_boxes(st: _State, specs, planes):
    return np.stack([planes.box(s.ext_box) for s in specs])


def _encode_group(st: _State, specs):
    """Batched encode of one same-signature spec group.  Returns
    per-spec (xu_e, xv_e, ll_e, res_u, res_v, bm) tuples, byte-equal to
    the sequential _quant_and_streams outputs (pipeline module doc)."""
    sig = _sig(specs[0])
    xu_e, xv_e, ll_e, res_u, res_v, bms = st.ex.encode_units(
        sig, _stack_boxes(st, specs, st.ufp),
        _stack_boxes(st, specs, st.vfp),
        _stack_boxes(st, specs, st.eb),
        _stack_boxes(st, specs, st.forced))
    return [(xu_e[b], xv_e[b], ll_e[b], res_u[b], res_v[b], bms[b])
            for b in range(len(specs))]


def _round_group(st: _State, specs, deltas):
    """Batched verify round over one same-signature spec group; the
    face re-checks (variable-size selections) stay per-unit.  Returns
    per-spec (forced_ext np bool, n_bad) -- decisions bit-equal to the
    sequential _tile_round (pipeline module doc).

    Each extension box is stacked and uploaded exactly ONCE per round;
    encode, decode-sim, pointwise check and screen all reuse the bound
    device stacks (the sequential path's no-re-upload rule, batched).
    """
    ex = st.ex
    sig = _sig(specs[0])
    bf = ex.batch_fns(sig)
    ufp_es = jnp.asarray(_stack_boxes(st, specs, st.ufp))
    vfp_es = jnp.asarray(_stack_boxes(st, specs, st.vfp))
    extra_es = jnp.asarray(_stack_boxes(st, specs, st.forced))
    xu_e, xv_e, ll_e, res_u, res_v, bms = ex.encode_units(
        sig, ufp_es, vfp_es, _stack_boxes(st, specs, st.eb), extra_es)
    xu_d, xv_d = ex.decode_units(bf, res_u, res_v, bms)
    xu_sim, xv_sim = bf.paste(xu_e, xv_e, xu_d, xv_d)
    u_es = jnp.asarray(_stack_boxes(st, specs, st.u))
    v_es = jnp.asarray(_stack_boxes(st, specs, st.v))
    (xu_p, xv_p, ll_p, ex_p, u_p, v_p), _ = pipeline._pad_pow2(
        [xu_sim, xv_sim, ll_e, extra_es, u_es, v_es])
    pb = xu_p.shape[0]
    scales = jnp.full((pb,), st.scale, jnp.float64)
    xis = jnp.full((pb,), st.xi_unit, jnp.int64)
    if st.policy is None:
        ebs = jnp.full((pb,), st.eb_abs, jnp.float64)
    else:
        # per-vertex bound stacks ride the same vmapped check: the
        # mapped axis stays 0, the inner broadcast turns elementwise
        (ebs,), _ = pipeline._pad_pow2(
            [jnp.asarray(_stack_boxes(st, specs, st.ebf))])
    forced_b, n_pt_b, ur_b, vr_b = bf.check_pt(
        xu_p, xv_p, ll_p, ex_p, u_p, v_p, scales, xis, ebs)

    screened = all(d is None for d in deltas)
    if screened:
        (ufp_p, vfp_p), _ = pipeline._pad_pow2([ufp_es, vfp_es])
        unsafe_sl_b, unsafe_sb_b = bf.screen(ufp_p, vfp_p, ur_b, vr_b)

    Te, he, we = specs[0].ext_shape
    fns_e = _pick_fns(st, specs[0].ext_shape)
    out = []
    for b, (spec, delta) in enumerate(zip(specs, deltas)):
        n_bad = int(n_pt_b[b])
        forced_np = np.asarray(forced_b[b])
        if delta is None:
            selection = pipeline.screen_selection_from(
                unsafe_sl_b[b], unsafe_sb_b[b], he, we)
        else:
            selection = pipeline._touched_faces(delta, Te, he, we)
        add, nf = pipeline.face_recheck(
            fns_e, spec.ext_shape, ur_b[b], vr_b[b], st.preds[spec.key],
            selection)
        n_bad += nf
        if add is not None:
            forced_np = forced_np | add
        out.append((forced_np, n_bad))
    return out


def _round_work(st: _State, work):
    """Run one verify round over ``work`` = [(spec, delta)]: batched by
    signature when the plan allows, per-unit otherwise.  Returns
    [(spec, forced_ext, n_bad)]."""
    if not st.ex.plan.batch_units:
        return [(spec, *_tile_round(st, spec, delta))
                for spec, delta in work]
    groups = {}
    for spec, delta in work:
        groups.setdefault((_sig(spec), delta is None), []).append(
            (spec, delta))
    out = []
    for items in groups.values():
        for lo in range(0, len(items), st.batch_cap):
            chunk = items[lo:lo + st.batch_cap]
            obs.observe("pipeline.batch_group_size", len(chunk))
            if len(chunk) == 1:
                # a 1-unit batch would just compile a second executable
                # set for the same work; the per-unit path is bit-equal
                spec, delta = chunk[0]
                out.append((spec, *_tile_round(st, spec, delta)))
                continue
            specs = [s for s, _ in chunk]
            deltas = [d for _, d in chunk]
            for spec, (forced_np, nb) in zip(
                    specs, _round_group(st, specs, deltas)):
                out.append((spec, forced_np, nb))
    return out


# ----------------------------------------------------------------------
# verify-and-correct fixpoint over a set of windows
# ----------------------------------------------------------------------

def _fixpoint(st: _State, windows, frontier: int = 0):
    """Run the seam-agreed verify loop over ``windows``' tiles.

    Per round every participating tile evaluates its extension exactly
    as the monolithic round would (screen on first contact, incident
    faces of newly-forced vertices afterwards); the per-round union of
    forced vertices is applied globally so both sides of every seam
    agree before the next round.  Raises StreamingCascadeError if an
    addition lands below ``frontier`` (an already-emitted frame).
    """
    cfg = st.cfg
    specs = [s for w in windows for s in w.specs]
    work = []
    for spec in specs:
        if spec.key not in st.seen:
            work.append((spec, None))
        else:
            delta = st.forced.box(spec.ext_box) & ~st.seen[spec.key]
            if delta.any():
                work.append((spec, delta))
    rounds = 0
    while work:
        additions = {}
        n_bad = 0
        with obs.span("tiling.verify_round", round=rounds,
                      units=len(work)):
            round_out = _round_work(st, work)
        for spec, forced_ext, nb in round_out:
            n_bad += nb
            new = forced_ext & ~st.forced.box(spec.ext_box)
            if new.any():
                t0 = spec.et0
                for k in range(new.shape[0]):
                    if new[k].any():
                        acc = additions.setdefault(
                            t0 + k, np.zeros((st.H, st.W), bool))
                        acc[spec.ei0:spec.ei1, spec.ej0:spec.ej1] |= new[k]
        st.bad_counts.append(n_bad)
        if not additions or rounds >= cfg.max_rounds:
            break
        if min(additions) < frontier:
            raise StreamingCascadeError(
                f"verify cascade reached emitted frame {min(additions)} "
                f"(< frontier {frontier}); increase window_t or use "
                f"compress_tiled")
        for t, mask in additions.items():
            st.forced.ensure(t)
            st.forced.p[t] |= mask
        rounds += 1
        st.rounds = max(st.rounds, rounds)
        work = []
        for spec in specs:
            t0, t1, i0, i1, j0, j1 = spec.ext_box
            delta = np.stack([
                additions[t][i0:i1, j0:j1] if t in additions
                else np.zeros((i1 - i0, j1 - j0), bool)
                for t in range(t0, t1)
            ])
            if delta.any():
                work.append((spec, delta))
    obs.count("tiling.verify_rounds", rounds)
    for spec in specs:
        st.seen[spec.key] = st.forced.box(spec.ext_box)
    for w in windows:
        w.screened = True


# ----------------------------------------------------------------------
# per-unit trajectory-segment extraction (sidecar track index)
# ----------------------------------------------------------------------
#
# Every unit owns the tets anchored in its owned box (slabs
# [t0, min(t1, T-1)), cells [i0, min(i1, H-1)) x [j0, min(j1, W-1)) --
# a partition of all tets).  The crossed-state of those tets' faces is
# evaluated on the halo extension with tile-local vertex ids
# (order-isomorphic to global ids => bit-identical SoS predicates),
# batched per extension-geometry group and shard_mapped over the
# ("tiles",) mesh like the eb derivation.  The sparse host pass then
# converts crossings to GLOBAL face ids / anchor cells and records the
# unit's segments + crossing nodes into the TrackIndexBuilder; global
# stitching happens once at finish time (analysis/index.py).


class _PlanesView:
    """(T, H, W) fancy-indexing facade over _Planes frame storage.

    Lets analysis.node_positions / classify gather from the sliding
    per-frame planes without materializing the full field (streaming
    holds only ~2 windows of frames).
    """

    def __init__(self, planes: _Planes, T: int):
        self.planes = planes
        self.shape = (T, planes.H, planes.W)

    def __getitem__(self, idx):
        t, i, j = (np.asarray(x) for x in idx)
        t, i, j = np.broadcast_arrays(t, i, j)
        out = np.empty(t.shape, dtype=self.planes.dtype)
        for tt in np.unique(t):
            m = t == tt
            assert int(tt) in self.planes.p, \
                f"frame {int(tt)} not resident (dropped or not yet seen)"
            out[m] = self.planes.p[int(tt)][i[m], j[m]]
        return out


@functools.lru_cache(maxsize=64)
def _local_tet_faces(key):
    """Static (n_slabs * Ntl, 4, 3) tet-face vertex ids, local to the
    extension box, for the tets a unit owns.  Mirrors the grid.py
    enumeration order (tau1|tau2|tau3 over tri1|tri2 over row-major
    cells) so local tet index -> global tet index is pure arithmetic.
    """
    Te, he, we, dt0, di0, dj0, nsl, nci, ncj = key
    if nsl <= 0 or nci <= 0 or ncj <= 0:
        return None
    P = he * we
    ii, jj = np.meshgrid(np.arange(nci), np.arange(ncj), indexing="ij")

    def sid(i, j):
        return ((di0 + i) * we + (dj0 + j)).ravel().astype(np.int64)

    v00 = sid(ii, jj)
    v10 = sid(ii, jj + 1)
    v01 = sid(ii + 1, jj)
    v11 = sid(ii + 1, jj + 1)
    tri1 = np.stack([v00, v01, v11], 1)
    tri2 = np.stack([v00, v10, v11], 1)
    tris = np.concatenate([tri1, tri2], 0)
    a, b, c = tris[:, 0], tris[:, 1], tris[:, 2]
    tau1 = np.stack([a, b, c, c + P], 1)
    tau2 = np.stack([a, b, b + P, c + P], 1)
    tau3 = np.stack([a, a + P, b + P, c + P], 1)
    tets = np.concatenate([tau1, tau2, tau3], 0)
    faces = tets[:, mesh.TET_FACES]               # (Ntl, 4, 3)
    out = faces[None] + ((dt0 + np.arange(nsl, dtype=np.int64)) * P
                         )[:, None, None, None]
    return np.ascontiguousarray(out.reshape(-1, 4, 3))


@functools.lru_cache(maxsize=64)
def _batch_seg_fn(key, be: str):
    """Batched crossed-face evaluator for one extension geometry.

    Local ids are order-isomorphic to global ids, so the SoS predicate
    is bit-identical to the global evaluation (the integer op contract:
    all backends agree, so jnp is used on-device and numpy on host).
    The per-vertex gather indices and SoS id-order bools are pre-split
    on the host (sos.face_crossed_ordered): embedding the combined
    (N, 4, 3) int64 id table as a jit constant made XLA constant-fold
    its slices and compares for >30 s per geometry at 128x128 tiles.
    """
    fidx_np = _local_tet_faces(key)
    if fidx_np is None:
        return None
    if be == "numpy":
        def run_np(us, vs):
            return np.stack([
                sos.face_crossed_vals(
                    np, np.asarray(u).reshape(-1)[fidx_np],
                    np.asarray(v).reshape(-1)[fidx_np], fidx_np)
                for u, v in zip(us, vs)])
        return run_np

    from ..parallel import sharding

    f0 = jnp.asarray(fidx_np[..., 0])
    f1 = jnp.asarray(fidx_np[..., 1])
    f2 = jnp.asarray(fidx_np[..., 2])
    lt_ab = jnp.asarray(fidx_np[..., 0] < fidx_np[..., 1])
    lt_bc = jnp.asarray(fidx_np[..., 1] < fidx_np[..., 2])
    lt_ca = jnp.asarray(fidx_np[..., 2] < fidx_np[..., 0])

    def one(uu, vv):
        uf = uu.reshape(-1)
        vf = vv.reshape(-1)
        return sos.face_crossed_ordered(
            jnp, uf[f0], vf[f0], uf[f1], vf[f1], uf[f2], vf[f2],
            lt_ab, lt_bc, lt_ca)

    return jax.jit(lambda us, vs: sharding.map_tiles_padded(one, us, vs))


def _unit_segment_records(st: _State, spec: TileSpec, crossed, key):
    """Host conversion: local crossings -> global segments + nodes."""
    from ..analysis import classify as classify_mod
    from ..analysis import extraction

    (_, _, _, _, _, _, nsl, nci, ncj) = key
    H, W = st.H, st.W
    ncc = nci * ncj
    Ntl = 6 * ncc
    crossed = np.asarray(crossed).reshape(nsl * Ntl, 4)
    from . import trajectory
    trajectory.check_lemma1(crossed.reshape(nsl, Ntl, 4), t_lo=spec.t0)

    j = np.nonzero(crossed.sum(axis=1) == 2)[0]
    if len(j) == 0:
        e = np.empty
        return (e((0, 2), np.int64), e((0, 3), np.int32), e(0, np.int64),
                e((0, 3), np.float64), e(0, np.int8))
    rows = crossed[j]
    _, slots = np.nonzero(rows)
    slots = slots.reshape(-1, 2)
    rt = j // Ntl
    r = j % Ntl
    k = r // (2 * ncc)
    rq = r % (2 * ncc)
    q = rq // ncc
    cc = rq % ncc
    gi = spec.i0 + cc // ncj
    gj = spec.j0 + cc % ncj
    ts = spec.t0 + rt
    Nc = (H - 1) * (W - 1)
    gtet = (k * 2 + q) * Nc + gi * (W - 1) + gj
    family, index = mesh.tet_face_map(H, W)
    seg_fid = mesh.tet_face_fids(
        family[gtet[:, None], slots], index[gtet[:, None], slots],
        ts[:, None], H, W)
    seg_cell = np.stack([ts, gi, gj], axis=1).astype(np.int32)

    node_fid = np.unique(seg_fid)
    uview = _PlanesView(st.ufp, st.n_frames)
    vview = _PlanesView(st.vfp, st.n_frames)
    node_pos = extraction.node_positions(
        node_fid, uview, vview, uview.shape)
    node_type = classify_mod.classify_nodes(
        uview, vview, node_pos, spiral_tol=st.tindex.spiral_tol)
    return seg_fid, seg_cell, node_fid, node_pos, node_type


def _window_segment_records(st: _State, w) -> dict:
    """Batched per-tile segment extraction for one window's units."""
    T = st.n_frames
    groups = {}
    for spec in w.specs:
        key = (spec.ext_shape + (
            spec.t0 - spec.et0, spec.i0 - spec.ei0, spec.j0 - spec.ej0,
            min(spec.t1, T - 1) - spec.t0,
            min(spec.i1, st.H - 1) - spec.i0,
            min(spec.j1, st.W - 1) - spec.j0))
        groups.setdefault(key, []).append(spec)
    records = {}
    for key, specs in groups.items():
        run = _batch_seg_fn(key, st.be)
        if run is None:
            e = np.empty
            for spec in specs:
                records[spec.key] = (
                    e((0, 2), np.int64), e((0, 3), np.int32),
                    e(0, np.int64), e((0, 3), np.float64), e(0, np.int8))
            continue
        us = np.stack([st.ufp.box(s.ext_box) for s in specs])
        vs = np.stack([st.vfp.box(s.ext_box) for s in specs])
        crossed = np.asarray(run(jnp.asarray(us), jnp.asarray(vs))
                             if st.be != "numpy" else run(us, vs))
        for b, spec in enumerate(specs):
            records[spec.key] = _unit_segment_records(
                st, spec, crossed[b], key)
    return records


# ----------------------------------------------------------------------
# unit emission
# ----------------------------------------------------------------------

@dataclasses.dataclass
class _UnitPayload:
    """Everything the CPU-side write stage needs for ONE unit -- no
    reference back into the sliding plane storage, so the scheduler may
    drop frames the moment payloads exist (the async engine hands these
    across a thread boundary)."""

    key: tuple
    box: tuple
    ll: object          # owned lossless mask (np bool)
    u_ll: object        # raw values at lossless vertices (np f32)
    v_ll: object
    res_u: object       # residual streams (device or host arrays)
    res_v: object
    bm: object          # blockmap (np bool)
    seg: object         # segment records tuple | None
    frag: object = None  # device-codec entropy fragment (HuffSections +
                        # escapes, core/entropy.py); res_u/res_v are
                        # released once it exists
    eb_base: object = None  # adaptive only: the unit's loosest resolved
                        # absolute base bound (self-describing per-unit
                        # header extra); computed here because the
                        # async writer thread has no plane access


def _unit_payloads(st: _State, w):
    """Span-wrapped entry for :func:`_unit_payloads_impl` (the device
    half of window emission; the async engine times this stage per
    window through the same span)."""
    with obs.span("tiling.unit_payloads", window=int(w.wi),
                  units=len(w.specs)):
        return _unit_payloads_impl(st, w)


def _unit_payloads_impl(st: _State, w):
    """Device/plane-reading half of window emission.

    Runs the final-mask encode (batched by signature when the plan
    allows) and snapshots per-unit payloads in the window's spec order
    -- the order the serial writer emits, which the async engine
    preserves through its handoff queue, keeping the container bytes
    identical.  Re-quantizes at the final mask rather than caching the
    last verify round's streams: a cache would hold every pending
    tile's residual field (2x the raw f32 footprint) alive until
    emission, defeating the bounded-memory point of tiling for one
    redundant encode pass.
    """
    seg_records = _window_segment_records(st, w) \
        if st.tindex is not None else None
    streams = {}
    if st.ex.plan.batch_units:
        groups = {}
        for spec in w.specs:
            groups.setdefault(_sig(spec), []).append(spec)
        for specs in groups.values():
            for lo in range(0, len(specs), st.batch_cap):
                chunk = specs[lo:lo + st.batch_cap]
                if len(chunk) == 1:
                    continue          # per-unit path below is bit-equal
                for spec, enc in zip(chunk, _encode_group(st, chunk)):
                    # keep only what emission reads -- pinning the
                    # extension X fields of a whole window would break
                    # the streaming path's bounded-memory contract
                    streams[spec.key] = enc[2:]
    payloads = []
    for spec in w.specs:
        if spec.key in streams:
            ll_e, res_u, res_v, bm = streams.pop(spec.key)
        else:
            ll_e, res_u, res_v, bm = _quant_and_streams(st, spec)
        o = spec.owned_in_ext
        ll_o = np.asarray(ll_e[o])
        u_o = st.u.box(spec.owned_box)
        v_o = st.v.box(spec.owned_box)
        payloads.append(_UnitPayload(
            key=spec.key, box=spec.owned_box, ll=ll_o,
            u_ll=u_o[ll_o], v_ll=v_o[ll_o],
            res_u=res_u, res_v=res_v, bm=bm,
            seg=None if seg_records is None else seg_records[spec.key],
            eb_base=(None if st.policy is None else
                     float(st.ebf.box(spec.owned_box).max()))))
        # original-predicate tables and seam snapshots are dead now
        st.preds.pop(spec.key, None)
        st.seen.pop(spec.key, None)
    if st.ex.codec == "device":
        _attach_entropy_fragments(st, payloads)
    w.emitted = True
    return payloads


def _attach_entropy_fragments(st: _State, payloads):
    """Device entropy stage over one window's payloads: stack the
    residual streams by owned shape and entropy-encode each stack in
    one batched device pass (per-unit tables keep the bytes independent
    of the grouping -- pipeline module doc).  The raw residual arrays
    are dropped once their fragment exists, so the async writer thread
    hands off pre-packed bitstreams instead of full streams."""
    stack = np.stack if st.ex.plan.backend == "numpy" else jnp.stack
    groups = {}
    for i, p in enumerate(payloads):
        groups.setdefault(tuple(p.res_u.shape), []).append(i)
    with obs.span("tiling.entropy_fragments", units=len(payloads),
                  groups=len(groups)):
        for idxs in groups.values():
            obs.observe("pipeline.batch_group_size", len(idxs))
            frags = st.ex.entropy_fragments(
                stack([payloads[i].res_u for i in idxs]),
                stack([payloads[i].res_v for i in idxs]))
            for i, frag in zip(idxs, frags):
                payloads[i].frag = frag
                payloads[i].res_u = payloads[i].res_v = None


def _write_unit(st: _State, p: _UnitPayload):
    """CPU half of unit emission: symbolize + pack + directory/index
    bookkeeping.  Pure host work on payload data only -- the async
    engine runs this on its writer thread while the device encodes the
    next window."""
    header = {"box": [int(x) for x in p.box]}
    if p.eb_base is not None:
        # self-describing per-unit base bound (adaptive policy); v<=5
        # readers skip unknown msgpack keys, so only obs/report tooling
        # needs to know it exists
        header["eb_base"] = float(p.eb_base)
    if p.frag is not None:
        from . import entropy
        sections = entropy.merge_sections(
            p.frag, p.ll, p.u_ll, p.v_ll, p.bm)
    else:
        sections = encode.field_sections(
            p.res_u, p.res_v, p.ll, p.u_ll, p.v_ll, p.bm)
    st.writer.add_unit(p.key, p.box, header, sections)
    if p.seg is not None:
        st.tindex.add_unit(p.key, *p.seg)
    bm = np.asarray(p.bm)
    obs.count("tiling.units_written", 1)
    st.n_units += 1
    st.n_ll += int(p.ll.sum())
    st.n_verts += p.ll.size
    st.n_sl_blocks += int(bm.sum())
    st.n_blocks += bm.size


def _emit_window(st: _State, w):
    payloads = _unit_payloads(st, w)
    with obs.span("tiling.write_units", window=int(w.wi),
                  units=len(payloads)):
        for p in payloads:
            _write_unit(st, p)


def _finish_header(st: _State, T: int):
    """Container header + the optional track-index footer section.

    The index rides as an EXTRA msgpack key (encode.TRACK_INDEX_KEY):
    readers that do not know it skip it without parsing, so the
    container version stays unchanged.
    """
    header = _container_header(st, T)
    if st.tindex is not None:
        header[encode.TRACK_INDEX_KEY] = st.tindex.finalize(
            (T, st.H, st.W))
    return header


def _container_header(st: _State, T: int):
    cfg = st.cfg
    # device-codec containers hold CPTH1 unit frames an older reader
    # cannot parse, so only THEY bump to v5; host-codec containers stay
    # at v4 (old readers keep working, and the v4 golden pin in
    # tests/test_container_golden.py stays exact).  An adaptive eb
    # policy bumps to v6 regardless of codec -- its bytes depend on the
    # policy, so it can never alias a uniform container.
    if st.policy is not None:
        version = TILED_FORMAT_VERSION_ADAPTIVE
    elif st.ex.codec == "device":
        version = TILED_FORMAT_VERSION_DEVICE
    else:
        version = TILED_FORMAT_VERSION
    header = {
        "version": version,
        "pipeline": "tiled",
        "predictor": cfg.predictor,
        "sl_backend": st.be,
        "shape": [int(T), int(st.H), int(st.W)],
        "scale": float(st.scale),
        "xi_unit": int(st.xi_unit),
        "block": int(cfg.block),
        "cfl_x": float(cfg.dt / cfg.dx),
        "cfl_y": float(cfg.dt / cfg.dy),
        "d_max": float(cfg.d_max),
        "n_max": int(cfg.n_max),
        "eb_abs": float(st.eb_abs),
        "tiling": dataclasses.asdict(st.grid),
    }
    if st.policy is not None:
        header["eb_policy"] = ebpolicy.policy_spec(st.policy)
    return header


def _stats(st: _State, T, blob, t0):
    """Stream stats (monolithic keys + tiled extras).  Note
    verify_bad_counts sums PER-TILE counts: a bad seam face or halo
    vertex is counted once per tile that sees it, so the numbers are
    inflated relative to the monolithic pipeline's same-named stat
    (the forced-vertex SETS are identical; only the counting differs)."""
    orig_bytes = T * st.H * st.W * 4 * 2
    comp_bytes = len(blob) if blob is not None else st.writer.bytes_written
    return {
        "orig_bytes": orig_bytes,
        "comp_bytes": comp_bytes,
        "ratio": orig_bytes / max(comp_bytes, 1),
        "lossless_frac": st.n_ll / max(st.n_verts, 1),
        "sl_block_frac": st.n_sl_blocks / max(st.n_blocks, 1),
        "verify_rounds": st.rounds,
        "verify_bad_counts": st.bad_counts,
        "eb_abs": st.eb_abs,
        "scale": st.scale,
        "tau": st.tau,
        "xi_unit": st.xi_unit,
        "seconds": time.perf_counter() - t0,
        "backend": st.be,
        "pipeline": "tiled",
        "n_units": st.n_units,
        "tiling": dataclasses.asdict(st.grid),
        "batch_units": st.ex.plan.batch_units,
    }


class _Window:
    def __init__(self, wi, t0, t1, specs):
        self.wi, self.t0, self.t1 = wi, t0, t1
        self.specs = specs
        self.et1 = max(s.et1 for s in specs)
        self.derived = False
        self.screened = False
        self.emitted = False


# ----------------------------------------------------------------------
# public entry points: in-memory tiled + streaming
# ----------------------------------------------------------------------

def _prepare(u, v, cfg, grid: TileGrid, sink=None):
    """Load an in-memory field into stream state + derive every window
    (phase 1).  Split out so tests can drive the fixpoint directly."""
    u, v = compressor._as_fields(u, v)
    T, H, W = u.shape
    vrange = (float(min(u.min(), v.min())), float(max(u.max(), v.max())))
    st = _init_state(cfg, grid, H, W, vrange, sink)
    for t in range(T):
        _add_frame(st, t, u[t], v[t])
    windows = []
    for wi in range(-(-T // grid.window_t)):
        t0 = wi * grid.window_t
        t1 = min(t0 + grid.window_t, T)
        et1 = min(t1 + grid.thalo, T)
        windows.append(_Window(wi, t0, t1,
                               window_specs(wi, t0, t1, H, W, et1, grid)))
    for w in windows:
        _derive_window(st, w)
    return st, windows, T


def compress_tiled(u, v, cfg=None, grid: Optional[TileGrid] = None,
                   sink=None):
    """Tiled compression of an in-memory field; bit-identical output to
    the monolithic fused pipeline (global verify fixpoint across all
    units).  Returns (blob, stats) -- blob is None when ``sink`` given.
    """
    cfg = cfg or compressor.CompressionConfig()
    grid = grid or getattr(cfg, "tiling", None) or TileGrid()
    grid.validate()
    t_start = time.perf_counter()
    with obs.span("tiling.compress_tiled", codec=None) as _sp:
        st, windows, T = _prepare(u, v, cfg, grid, sink)
        _sp.set(codec=st.ex.codec, n_windows=len(windows),
                shape=[int(T), int(st.H), int(st.W)])
        if cfg.verify:
            with obs.span("tiling.fixpoint", n_windows=len(windows)):
                _fixpoint(st, windows, frontier=0)
        for w in windows:
            _emit_window(st, w)
        blob = st.writer.finish(_finish_header(st, T))
    return blob, _stats(st, T, blob, t_start)


def compress_stream(pairs, cfg=None, grid: Optional[TileGrid] = None,
                    value_range=None, sink=None, async_engine=False,
                    resume=False, faults=None, stage_timeout=None,
                    autotune=False, n_frames_hint=None):
    """Streaming tiled compression of an iterable of (u_t, v_t) frames.

    ``value_range=(lo, hi)`` must be the exact global min/max over both
    components (it fixes the fixed-point scale and the relative error
    bound before the stream starts); without it the stream is
    materialized and delegated to compress_tiled.  Holds ~2 windows of
    frames; emits each unit as soon as later frames can no longer
    change its verify outcome.  Returns (blob, stats); blob is None
    when writing to ``sink``.

    ``async_engine=True`` runs the out-of-core concurrent engine
    (core/stream_engine.py): frame ingestion, device encode/verify and
    CPU symbolize/pack overlap on three stages, producing bytes
    IDENTICAL to the serial path (and to compress_tiled) -- only the
    scheduling changes, never the emission order or the packed streams.

    Crash recovery: when ``sink`` is a filesystem path the run keeps a
    write-ahead journal at ``<sink>.journal`` (fsync'd at window
    boundaries).  After a crash, rerunning with ``resume=True``
    restarts from the last durable checkpoint: already-final container
    bytes are kept, the scheduler state is restored, and only frames
    from the journal's ``resume_from`` onward are consumed from
    ``pairs`` -- the finished container is byte-identical to an
    uninterrupted run (DESIGN.md #12).  ``pairs`` may be a callable
    ``pairs(t_start) -> iterable`` so a source can seek instead of
    replaying (a plain iterable is skipped forward).

    ``faults`` (core/faults.py FaultPlan) and ``stage_timeout``
    (seconds; also REPRO_STAGE_TIMEOUT) are the fault-injection /
    watchdog hooks of the async engine -- test and benchmark plumbing,
    inert in production use.

    ``autotune=True`` picks grid/backend/codec/scheduling via the cost
    model (repro.autotune, model-only: a stream cannot be rerun per
    candidate) before any frame is compressed; ``n_frames_hint`` bounds
    the workload estimate when ``pairs`` has no ``len``.  Incompatible
    with ``resume`` -- a resumed run must replay the original plan
    bit-for-bit, not search for a new one.
    """
    cfg = cfg or compressor.CompressionConfig()
    if autotune:
        if resume:
            raise ValueError(
                "autotune=True cannot be combined with resume=True: a "
                "resumed run must replay the journaled plan exactly; "
                "rerun with the original grid/config")
        from .. import autotune as autotune_mod

        src = pairs(0) if callable(pairs) else pairs
        n_frames = None
        try:
            n_frames = len(src)
        except TypeError:
            pass
        it = iter(src)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("autotune=True needs at least one frame")
        H, W = np.asarray(first[0]).shape
        cfg, cand = autotune_mod.tune_stream(
            (n_frames or n_frames_hint or 64, H, W), cfg)
        grid = cfg.tiling
        async_engine = cand.async_engine
        pairs = itertools.chain([first], it)
    grid = grid or getattr(cfg, "tiling", None) or TileGrid()
    grid.validate()
    from . import stream_engine

    if value_range is None:
        if resume:
            raise ValueError(
                "resume=True needs an explicit value_range: the range "
                "fixes the fixed-point scale, and a resumed run must "
                "derive bit-identical parameters without re-reading "
                "already-compressed frames")
        # the stream must be materialized to learn the global range;
        # with the async engine requested, derive the exact range and
        # still run the engine (same bytes either way) rather than
        # silently downgrading to the serial in-memory path
        src = pairs(0) if callable(pairs) else pairs
        frames = [(np.asarray(uf, np.float32), np.asarray(vf, np.float32))
                  for uf, vf in src]
        if not async_engine:
            u = np.stack([f[0] for f in frames])
            v = np.stack([f[1] for f in frames])
            return compress_tiled(u, v, cfg, grid, sink=sink)
        lo = min(min(float(uf.min()), float(vf.min())) for uf, vf in frames)
        hi = max(max(float(uf.max()), float(vf.max())) for uf, vf in frames)
        pairs = frames
        value_range = (lo, hi)

    return stream_engine.run(pairs, cfg, grid, value_range, sink,
                             async_engine=async_engine, resume=resume,
                             faults=faults, stage_timeout=stage_timeout)


# ----------------------------------------------------------------------
# decode: full, region, read planning
# ----------------------------------------------------------------------

def _overlaps(box, region):
    t0, t1, i0, i1, j0, j1 = box
    rt0, rt1, ri0, ri1, rj0, rj1 = region
    return t0 < rt1 and rt0 < t1 and i0 < ri1 and ri0 < i1 \
        and j0 < rj1 and rj0 < j1


def _source_of(src):
    """ContainerSource over bytes or a path (persistent handle + typed
    short-read errors + decoded-unit cache id; analysis/query.py)."""
    from ..analysis import query as query_mod

    return query_mod.ContainerSource(src)


def _plan_entries(hdr: dict, region=None):
    """Directory entries overlapping ``region`` -- the ONE place the
    coverage rule lives (read planning and region decode must never
    diverge on which units a region touches)."""
    if region is None:
        return list(hdr["units"])
    return [e for e in hdr["units"] if _overlaps(e["box"], region)]


def read_plan(src, region=None):
    """Directory entries a region decode touches -- and nothing else.
    ``src`` is container bytes or a filesystem path."""
    with _source_of(src) as source:
        hdr = source.header()
    return _plan_entries(hdr, region)


@dataclasses.dataclass
class DecodeReport:
    """What a degraded-mode decode could and could not recover.

    ``missing_units`` lists one dict per unit that failed its checksum
    or could not be read ({"key", "box", "error"}); the corresponding
    output voxels are holes (left at 0).  A report with no missing
    units is a complete decode.

    ``retries`` is the per-site :func:`faults.retry_stats` snapshot
    taken when the decode finished -- a decode that only succeeded
    because the source retried transient read errors is visible here
    instead of looking identical to a clean one."""

    n_units: int = 0                 # units the region plan touched
    n_decoded: int = 0
    missing_units: list = dataclasses.field(default_factory=list)
    retries: dict = dataclasses.field(default_factory=dict)

    @property
    def complete(self) -> bool:
        return not self.missing_units

    def hole_mask(self, region):
        """(T, H, W)-of-region bool mask of voxels lost to missing
        units (True = hole)."""
        rt0, rt1, ri0, ri1, rj0, rj1 = region
        mask = np.zeros((rt1 - rt0, ri1 - ri0, rj1 - rj0), dtype=bool)
        for m in self.missing_units:
            t0, t1, i0, i1, j0, j1 = m["box"]
            mask[max(t0, rt0) - rt0: max(min(t1, rt1) - rt0, 0),
                 max(i0, ri0) - ri0: max(min(i1, ri1) - ri0, 0),
                 max(j0, rj0) - rj0: max(min(j1, rj1) - rj0, 0)] = True
        return mask


def decompress_tiled(src, region=None, backend=None, degraded=False):
    """Decode a tiled container (whole field, or just ``region``).

    ``src`` is container bytes or a filesystem path (range reads only).
    Only the units whose owned boxes overlap the region are read
    (byte slices at directory offsets) and decoded -- and repeated or
    overlapping decodes are served from the process-wide decoded-unit
    cache (analysis/query.py) instead of re-reading and re-decoding
    covering units.

    ``degraded=True`` turns per-unit damage (checksum mismatch, short
    read) from a raise into a report: the return becomes
    ``(u, v, DecodeReport)``, damaged units' voxels are holes (0) and
    ``report.missing_units`` says exactly which and where.  Structural
    damage (corrupt footer/directory) still raises -- there is nothing
    to partially decode without a directory; run
    ``encode.salvage_container`` first.
    """
    from ..analysis import query as query_mod

    report = DecodeReport()
    with _source_of(src) as source:
        hdr = source.header()
        version = hdr.get("version", 1)
        if version > TILED_FORMAT_VERSION_ADAPTIVE:
            raise ValueError(
                f"container format version {version} is newer than this "
                f"decoder (supports <= {TILED_FORMAT_VERSION_ADAPTIVE})")
        T, H, W = hdr["shape"]
        if region is None:
            region = (0, T, 0, H, 0, W)
        rt0, rt1, ri0, ri1, rj0, rj1 = region
        if not (0 <= rt0 < rt1 <= T and 0 <= ri0 < ri1 <= H
                and 0 <= rj0 < rj1 <= W):
            raise ValueError(f"region {region} outside field "
                             f"({T}, {H}, {W})")
        ex = pipeline.executor_from_header(hdr, backend)
        u_out = np.zeros((rt1 - rt0, ri1 - ri0, rj1 - rj0),
                         dtype=np.float32)
        v_out = np.zeros_like(u_out)
        entries = _plan_entries(hdr, region)
        report.n_units = len(entries)
        failures = [] if degraded else None
        full = (rt0, rt1, ri0, ri1, rj0, rj1) == (0, T, 0, H, 0, W)
        if full:
            # full-field decode: stream unit-at-a-time (one compressed
            # frame resident at a time) and leave the unit cache alone
            # -- pinning a whole field of patches would evict every
            # entry with real reuse probability for zero future hits
            def decoded_iter():
                for entry in entries:
                    try:
                        uh, secs = source.unit(entry)
                        u_rec, v_rec = ex.decode_unit(uh, secs)
                    except encode.ContainerError as e:
                        if failures is None:
                            raise
                        failures.append((entry, e))
                        continue
                    yield tuple(uh["box"]), u_rec, v_rec
            decoded = decoded_iter()
        else:
            decoded, _ = query_mod.fetch_decoded_units(
                source, ex, entries, failures=failures)
        for box, u_rec, v_rec in decoded:
            t0, t1, i0, i1, j0, j1 = box
            ct0, ct1 = max(t0, rt0), min(t1, rt1)
            ci0, ci1 = max(i0, ri0), min(i1, ri1)
            cj0, cj1 = max(j0, rj0), min(j1, rj1)
            u_src = (slice(ct0 - t0, ct1 - t0), slice(ci0 - i0, ci1 - i0),
                     slice(cj0 - j0, cj1 - j0))
            dst = (slice(ct0 - rt0, ct1 - rt0),
                   slice(ci0 - ri0, ci1 - ri0),
                   slice(cj0 - rj0, cj1 - rj0))
            u_out[dst] = u_rec[u_src]
            v_out[dst] = v_rec[u_src]
            report.n_decoded += 1
        if failures:
            report.missing_units = [
                {"key": tuple(e["key"]), "box": tuple(e["box"]),
                 "error": str(err)} for e, err in failures]
    if degraded:
        from . import faults as faults_mod

        report.retries = faults_mod.retry_stats()
        return u_out, v_out, report
    return u_out, v_out


def decompress_region(src, region, backend=None, degraded=False):
    """Random-access decode of (t0, t1, i0, i1, j0, j1) -- reads only
    the units covering the region (cached across repeated queries).
    ``degraded=True`` reports damaged units instead of raising (see
    decompress_tiled)."""
    return decompress_tiled(src, region=region, backend=backend,
                            degraded=degraded)
