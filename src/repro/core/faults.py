"""Deterministic fault injection for the streaming/query stack.

Robustness code is only as good as the failures it has actually seen.
This module gives tests (and the recovery benchmark) a seeded, replayable
way to make the I/O and threading layers misbehave at exact, chosen
points:

* ``FaultPlan`` -- an ordered set of fault rules keyed by ``(site, op)``.
  A *site* is a short string naming an instrumented location
  (``"source.read"``, ``"engine.compute"``, ``"writer.write"``, ...);
  the plan decides, per call, whether that call fails and how.
* ``FaultPoint`` -- the hook object handed to instrumented code.  Code
  under test calls ``faults.check("site")`` (a no-op when no plan is
  armed) and the plan raises the scheduled exception on the scheduled
  call number.

Fault kinds
-----------
``io_error``      raise ``InjectedFault`` (an ``OSError``) on the Nth
                  call at a site.  ``transient=k`` makes the first *k*
                  raises transient: retry layers that re-invoke the
                  same site eventually succeed, which is how the
                  bounded-retry path in ``ContainerSource`` is tested.
``thread_death``  raise ``InjectedThreadDeath`` (a ``BaseException``
                  subclass) -- deliberately *not* an ``Exception`` so
                  that naive ``except Exception`` recovery code does
                  not swallow it; only the engine's shutdown path may
                  handle it.
``stall``         sleep for ``seconds`` on the Nth call, to trip
                  watchdog timeouts.

Everything is deterministic: the plan is driven by explicit call
counters, and the optional ``seed`` only feeds ``spread()`` helpers
that *derive* call numbers (e.g. "some call in the first 40") so a
matrix test can vary placement across cases while each case stays
exactly reproducible.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class InjectedFault(OSError):
    """A scheduled I/O failure from a :class:`FaultPlan`."""


class InjectedThreadDeath(BaseException):
    """A scheduled hard thread death (not an ``Exception`` on purpose:
    generic recovery code must not be able to swallow it)."""


@dataclass
class _Rule:
    kind: str                   # "io_error" | "thread_death" | "stall"
    nth: int                    # 1-based call number at the site
    transient: int = 0          # io_error: first k raises are transient
    seconds: float = 0.0        # stall duration
    message: str = ""
    fired: int = 0              # how many times this rule has raised


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Instances are thread-safe: the streaming engine probes the same
    plan from three threads.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._rules: Dict[str, List[_Rule]] = {}
        self._calls: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.log: List[Tuple[str, str, int]] = []   # (site, kind, call#)

    # -- plan construction -------------------------------------------------
    def io_error(self, site: str, nth: int = 1, *, transient: int = 0,
                 message: str = "") -> "FaultPlan":
        """Raise :class:`InjectedFault` on the ``nth`` call at ``site``.

        ``transient=k``: the rule re-arms for the next *k* calls too
        (calls nth..nth+k raise), after which the site succeeds -- a
        retry loop that re-executes the site k+1 times gets through.
        """
        self._add(site, _Rule("io_error", nth, transient=transient,
                              message=message or f"injected io error @ {site}"))
        return self

    def thread_death(self, site: str, nth: int = 1) -> "FaultPlan":
        self._add(site, _Rule("thread_death", nth,
                              message=f"injected thread death @ {site}"))
        return self

    def stall(self, site: str, seconds: float, nth: int = 1) -> "FaultPlan":
        self._add(site, _Rule("stall", nth, seconds=float(seconds)))
        return self

    def spread(self, lo: int, hi: int) -> int:
        """A seed-derived call number in ``[lo, hi]`` (inclusive) --
        lets matrix tests place a fault "somewhere early" while staying
        replayable from the plan's seed."""
        return self._rng.randint(int(lo), int(hi))

    def _add(self, site: str, rule: _Rule) -> None:
        if rule.nth < 1:
            raise ValueError(f"fault nth must be >= 1, got {rule.nth}")
        with self._lock:
            self._rules.setdefault(site, []).append(rule)

    # -- probing -----------------------------------------------------------
    def check(self, site: str) -> None:
        """Account one call at ``site``; raise/stall if a rule matches."""
        with self._lock:
            n = self._calls.get(site, 0) + 1
            self._calls[site] = n
            todo = None
            for rule in self._rules.get(site, ()):
                if rule.kind == "io_error":
                    if rule.nth <= n <= rule.nth + rule.transient:
                        rule.fired += 1
                        todo = rule
                        break
                elif rule.nth == n:
                    rule.fired += 1
                    todo = rule
                    break
            if todo is not None:
                self.log.append((site, todo.kind, n))
        if todo is None:
            return
        if todo.kind == "stall":
            time.sleep(todo.seconds)
            return
        if todo.kind == "thread_death":
            raise InjectedThreadDeath(todo.message)
        raise InjectedFault(todo.message)

    def calls(self, site: str) -> int:
        with self._lock:
            return self._calls.get(site, 0)

    def fired(self, site: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for s, _, _ in self.log
                       if site is None or s == site)


class FaultPoint:
    """Nullable handle instrumented code keeps: ``FaultPoint(None)`` is
    a zero-cost no-op, ``FaultPoint(plan)`` defers to the plan."""

    __slots__ = ("plan",)

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan

    def check(self, site: str) -> None:
        if self.plan is not None:
            self.plan.check(site)

    def __bool__(self) -> bool:
        return self.plan is not None


# ----------------------------------------------------------------------
# bounded retry with per-site visibility
# ----------------------------------------------------------------------
#
# A retry that succeeds used to be invisible: only the final failure
# surfaced, so a flaky disk retrying on every read looked identical to
# a healthy one.  Every retry_transient site now records its attempt
# accounting here (and into the obs registry), and debugging surfaces
# (stream_engine.resume_info, tiling.DecodeReport) report it.

_RETRY_LOCK = threading.Lock()
_RETRY_STATS: Dict[str, Dict[str, object]] = {}


def _record_retry(site: str, attempts: int, retried: int, ok: bool,
                  error: Optional[BaseException]) -> None:
    from .. import obs

    with _RETRY_LOCK:
        st = _RETRY_STATS.setdefault(site, {
            "calls": 0, "attempts": 0, "retries": 0,
            "failures": 0, "last_outcome": None, "last_error": None,
        })
        st["calls"] += 1
        st["attempts"] += attempts
        st["retries"] += retried
        if ok:
            st["last_outcome"] = "ok"
        else:
            st["failures"] += 1
            st["last_outcome"] = "failed"
            st["last_error"] = repr(error)
    obs.counter(f"faults.retry.{site}.attempts").add(attempts)
    if retried:
        obs.counter(f"faults.retry.{site}.retries").add(retried)
        obs.instant_event("faults.retry", site=site, retried=retried,
                          outcome="ok" if ok else "failed")
    if not ok:
        obs.counter(f"faults.retry.{site}.failures").add(1)


def retry_stats(site: Optional[str] = None):
    """Per-site retry accounting since process start (or last reset):
    ``{site: {calls, attempts, retries, failures, last_outcome,
    last_error}}`` -- or one site's dict (empty if never seen)."""
    with _RETRY_LOCK:
        if site is not None:
            return dict(_RETRY_STATS.get(site, {}))
        return {s: dict(st) for s, st in _RETRY_STATS.items()}


def reset_retry_stats() -> None:
    with _RETRY_LOCK:
        _RETRY_STATS.clear()


def retry_transient(fn: Callable[[], object], *, retries: int = 3,
                    backoff: float = 0.01,
                    retry_on: tuple = (OSError,),
                    on_retry: Optional[Callable[[int, BaseException], None]]
                    = None, site: Optional[str] = None):
    """Run ``fn`` with bounded retry + exponential backoff on transient
    errors.  ``InjectedThreadDeath`` (BaseException) always escapes.

    ``retries`` is the number of *re*-attempts: the function runs at
    most ``retries + 1`` times.  The final failure is re-raised as-is
    so callers keep the typed error.  ``site`` names the call site for
    ``retry_stats`` / obs accounting, so retries that eventually
    SUCCEED are still visible afterwards.
    """
    attempt = 0
    while True:
        try:
            out = fn()
        except retry_on as e:
            attempt += 1
            if attempt > retries:
                if site is not None:
                    _record_retry(site, attempt, attempt - 1, False, e)
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff > 0:
                time.sleep(backoff * (2.0 ** (attempt - 1)))
        else:
            if site is not None:
                _record_retry(site, attempt + 1, attempt, True, None)
            return out
