"""Kernel-dispatch backend for the compression hot path (DESIGN.md #4).

The three hot ops of the pipeline -- fused dual-quantize + block-Lorenzo
residual, semi-Lagrangian prediction, and the SoS face predicate -- are
routed through one of three interchangeable backends:

  ``pallas``  the Pallas TPU kernels under ``repro.kernels`` (compiled
              on TPU, ``interpret=True`` elsewhere) -- the production
              device path;
  ``xla``     the pure-jnp implementations in core (default off-TPU);
  ``numpy``   host reference implementations.

Determinism contract (DESIGN.md #4):

* The two INTEGER ops (Lorenzo residual, SoS predicate) are exact and
  bit-identical across all three backends; tests/test_backend_parity.py
  enforces this on residual streams, lossless masks and blockmaps.
* The SL predictor is float and float arithmetic is not bit-stable
  across different XLA compilation contexts, so encoder, verify loop
  and decoder all call the SAME per-frame executable returned by
  ``sl_stepper`` -- consistency is structural, not numerical.  The
  blob header records which backend produced the SL predictions
  (``sl_backend``) and decompress replays that stepper.  xla/numpy
  steppers share f64 math; the pallas stepper is the f32 TPU kernel.

Backend selection: explicit argument > ``REPRO_BACKEND`` env var
(perfflags) > auto (``pallas`` on TPU, ``xla`` elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .. import perfflags
from ..kernels.cptest import ops as _cp_ops
from ..kernels.entropy import ops as _ent_ops
from ..kernels.lorenzo import ops as _lz_ops
from ..kernels.semilagrange import kernel as _sl_kernel
from . import predictors, quantize, sos

BACKENDS = ("pallas", "xla", "numpy")


def resolve(name: str | None = None) -> str:
    """Resolve a backend name (None -> env override -> hardware auto)."""
    name = name or perfflags.backend_override()
    if name is None:
        name = "pallas" if jax.default_backend() == "tpu" else "xla"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")
    return name


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# op 1: fused dual-quantization + block-local 3D Lorenzo residual
# ----------------------------------------------------------------------

def _lorenzo_residual_np(dfp, k, lossless, xi_unit, block):
    dfp = np.asarray(dfp, np.int64)
    k = np.asarray(k)
    ll = np.asarray(lossless)
    g = np.int64(2 * int(xi_unit))
    kk = np.maximum(k, 0).astype(np.int64)
    q = g << kk
    x = (np.sign(dfp) * ((np.abs(dfp) + (q >> 1)) // q)) << kk
    x0 = np.sign(dfp) * ((np.abs(dfp) + (g >> 1)) // g)
    x = np.where(ll, x0, x)
    T, H, W = x.shape
    mi = ((np.arange(H) % block) != 0).astype(np.int64)[:, None]
    mj = ((np.arange(W) % block) != 0).astype(np.int64)[None, :]
    xi = np.zeros_like(x)
    xi[:, 1:, :] = x[:, :-1, :]
    xj = np.zeros_like(x)
    xj[:, :, 1:] = x[:, :, :-1]
    xij = np.zeros_like(x)
    xij[:, 1:, 1:] = x[:, :-1, :-1]
    d2 = x - xi * mi - xj * mj + xij * (mi * mj)
    res = d2.copy()
    res[1:] -= d2[:-1]
    return res


def lorenzo_residual(dfp, k, lossless, xi_unit,
                     block=predictors.DEFAULT_BLOCK, backend="xla", x=None):
    """Fused eb-quantize + dual-quantize + 3D-Lorenzo residual.

    dfp (T, H, W) int64 fixed-point; k int32 eb levels (-1 lossless);
    lossless bool.  Returns int64 residuals, identical across backends.
    ``x`` optionally passes the already-materialized dual-quantized
    field (the mop path computes it anyway for SL): the xla backend
    then skips the in-op re-quantization -- XLA cannot CSE across jit
    boundaries -- while the pallas kernel re-fuses it from dfp by
    design (one HBM pass) and the numpy reference stays self-contained.
    """
    if backend == "pallas" and block == _lz_ops.kernel.LBLOCK:
        out = _lz_ops.dualquant_lorenzo_residual(
            dfp, k, lossless, xi_unit, block, force_pallas=True
        )
        return out.astype(jnp.int64)
    if backend == "numpy":
        return _lorenzo_residual_np(dfp, k, lossless, xi_unit, block)
    if x is None:
        x = quantize.dual_quantize(dfp, k, lossless, xi_unit)
    return predictors.lorenzo_encode(x, block)


# ----------------------------------------------------------------------
# op 2: semi-Lagrangian prediction (canonical f32, predictors.py)
# ----------------------------------------------------------------------

def _bilinear_np(f, fi, fj):
    H, W = f.shape[-2], f.shape[-1]
    i0 = np.clip(np.floor(fi), 0, H - 1)
    j0 = np.clip(np.floor(fj), 0, W - 1)
    a = fi - i0
    b = fj - j0
    i0 = i0.astype(np.int32)
    j0 = j0.astype(np.int32)
    i1 = np.minimum(i0 + 1, H - 1)
    j1 = np.minimum(j0 + 1, W - 1)
    f00 = f[..., i0, j0]
    f01 = f[..., i0, j1]
    f10 = f[..., i1, j0]
    f11 = f[..., i1, j1]
    return (
        (1 - a) * (1 - b) * f00
        + (1 - a) * b * f01
        + a * (1 - b) * f10
        + a * b * f11
    )


def _sl_predict_frame_np(xu_prev, xv_prev, g2f, cfl_x, cfl_y, d_max, n_max):
    """numpy transcription of predictors.sl_predict_frame (f64 math)."""
    f64 = np.float64
    g2 = f64(g2f)
    u = np.asarray(xu_prev).astype(f64) * g2
    v = np.asarray(xv_prev).astype(f64) * g2
    H, W = u.shape
    cx = f64(cfl_x)
    cy = f64(cfl_y)
    ii, jj = np.meshgrid(np.arange(H, dtype=f64), np.arange(W, dtype=f64),
                         indexing="ij")
    d_inf = np.maximum(np.abs(u) * cx, np.abs(v) * cy)

    i_h = np.clip(ii - 0.5 * v * cy, 0.0, H - 1.0)
    j_h = np.clip(jj - 0.5 * u * cx, 0.0, W - 1.0)
    u_h = _bilinear_np(u, i_h, j_h)
    v_h = _bilinear_np(v, i_h, j_h)
    i_rk = ii - v_h * cy
    j_rk = jj - u_h * cx

    n_sub = np.clip(np.ceil(d_inf / d_max), 1.0, float(n_max))
    n_hi = float(n_sub.max())
    pi, pj = ii.copy(), jj.copy()
    s = 0
    while s < n_hi:
        us = _bilinear_np(u, pi, pj)
        vs = _bilinear_np(v, pi, pj)
        active = s < n_sub
        pi = np.where(active, np.clip(pi - vs * cy / n_sub, 0.0, H - 1.0), pi)
        pj = np.where(active, np.clip(pj - us * cx / n_sub, 0.0, W - 1.0), pj)
        s += 1

    use_rk = d_inf <= d_max
    i_s = np.clip(np.where(use_rk, i_rk, pi), 0.0, H - 1.0)
    j_s = np.clip(np.where(use_rk, j_rk, pj), 0.0, W - 1.0)
    pu = _bilinear_np(u, i_s, j_s) / g2
    pv = _bilinear_np(v, i_s, j_s) / g2
    return (np.rint(pu).astype(np.int64), np.rint(pv).astype(np.int64))


@functools.lru_cache(maxsize=64)
def sl_stepper(backend, cfl_x, cfl_y, d_max, n_max):
    """The per-frame SL prediction executable F(xu_prev, xv_prev, g2f).

    F maps frame t-1's base-grid integer planes to frame t's integer
    predictions.  The SAME returned callable (one jitted executable per
    (backend, CFL, d_max, n_max)) is used by the encoder's residual
    pass, the verify loop's decode simulation, and decompress -- which
    is what makes the float prediction consistent end-to-end (module
    doc).  g2f stays a traced argument so eb sweeps don't recompile.
    """
    if backend == "numpy":
        def step_np(xu_prev, xv_prev, g2f):
            return _sl_predict_frame_np(
                np.asarray(xu_prev), np.asarray(xv_prev), float(g2f),
                cfl_x, cfl_y, d_max, n_max)
        return step_np

    if backend == "pallas":
        @jax.jit
        def step_pallas(xu_prev, xv_prev, g2f):
            H, W = xu_prev.shape
            if H % _sl_kernel.TILE_H:  # kernel needs row-tile alignment
                return predictors.sl_predict_frame(
                    xu_prev, xv_prev, g2f, cfl_x, cfl_y, d_max, n_max,
                    early_exit=True)
            g2 = jnp.asarray(g2f, jnp.float32)
            u = xu_prev.astype(jnp.float32) * g2
            v = xv_prev.astype(jnp.float32) * g2
            pu, pv = _sl_kernel.sl_predict_pallas(
                u, v, float(cfl_x), float(cfl_y), float(d_max), int(n_max),
                interpret=_interpret(),
            )
            return (jnp.rint(pu / g2).astype(jnp.int64),
                    jnp.rint(pv / g2).astype(jnp.int64))
        return step_pallas

    @jax.jit
    def step_xla(xu_prev, xv_prev, g2f):
        return predictors.sl_predict_frame(
            xu_prev, xv_prev, g2f, cfl_x, cfl_y, d_max, n_max,
            early_exit=True)
    return step_xla


def sl_predictions(xu, xv, g2f, stepper):
    """Encoder-side predictions for frames 1..T-1 via T-1 calls of the
    shared stepper (dispatches pipeline asynchronously on device; the
    loop is over frames of ONE executable, not a fresh trace)."""
    pus, pvs = [], []
    for t in range(1, xu.shape[0]):
        pu, pv = stepper(xu[t - 1], xv[t - 1], g2f)
        pus.append(pu)
        pvs.append(pv)
    return jnp.stack(pus), jnp.stack(pvs)


def sl_predictions_batched(xus, xvs, g2f, stepper):
    """Predictions for a (B, T, H, W) batch of units, T >= 2.

    Deliberately NOT a vmap: float arithmetic is not bit-stable across
    compilation contexts (module doc), so every (unit, frame) steps
    through the SAME per-frame executable the sequential encode path and
    the decoder use -- batched output is bit-identical to per-unit
    output by construction.  All B * (T-1) dispatches are asynchronous.
    """
    pus, pvs = [], []
    for b in range(int(xus.shape[0])):
        pu, pv = sl_predictions(xus[b], xvs[b], g2f, stepper)
        pus.append(pu)
        pvs.append(pv)
    return jnp.stack(pus), jnp.stack(pvs)


# ----------------------------------------------------------------------
# op: batched connected-component labeling (trajectory stitching)
# ----------------------------------------------------------------------

_CCL_MAX_ROUNDS = 64


# module-level jits: defining these inside connected_labels would give
# every call fresh function objects and re-compile both executables
@jax.jit
def _ccl_hook_jnp(p, a, b):
    pa, pb = p[a], p[b]
    lo = jnp.minimum(pa, pb)
    hi = jnp.maximum(pa, pb)
    return p.at[hi].min(lo)


@jax.jit
def _ccl_jump_jnp(p):
    return p[p]


def _ccl_rounds(parent, ea, eb, hook, compress, all_equal):
    """Shared hook + pointer-jump driver (generic over array backend).

    Each round min-hooks every edge's endpoint labels and then pointer-
    jumps ``parent`` to its own fixpoint (full path compression), so
    label information spreads at a doubling rate along tracks.  The loop
    stops when a hook round changes nothing.  Labels only ever decrease
    and only toward ids inside the same component, so the fixpoint is
    exactly label[i] = min(component(i)) -- deterministic, identical
    across backends, and independent of edge order.
    """
    for _ in range(_CCL_MAX_ROUNDS):
        nxt = hook(parent, ea, eb)
        while True:
            jumped = compress(nxt)
            if all_equal(jumped, nxt):
                break
            nxt = jumped
        if all_equal(nxt, parent):
            return parent
        parent = nxt
    raise RuntimeError("connected_labels did not converge "
                       f"in {_CCL_MAX_ROUNDS} rounds")


def connected_labels(n: int, edges, backend="xla"):
    """Connected components of an undirected graph on nodes [0, n).

    edges: (E, 2) integer array.  Returns int64 labels with
    label[i] = min node id of i's component -- the device-resident
    replacement for the host union-find over trajectory crossing nodes
    (iterated min-hook + pointer jumping).  The integer op is exact, so
    all three backends return identical labels; ``pallas`` routes to the
    xla implementation (the op is pure gather/scatter, which XLA already
    emits as memory-bound kernels -- there is no compute to fuse).
    """
    edges = np.asarray(edges) if backend == "numpy" else jnp.asarray(edges)
    if n == 0:
        return np.empty(0, np.int64) if backend == "numpy" \
            else jnp.empty(0, jnp.int64)
    if edges.size == 0:
        return np.arange(n, dtype=np.int64) if backend == "numpy" \
            else jnp.arange(n, dtype=jnp.int64)

    if backend == "numpy":
        ea = np.asarray(edges[:, 0], np.int64)
        eb = np.asarray(edges[:, 1], np.int64)

        def hook(p, a, b):
            p = p.copy()
            pa, pb = p[a], p[b]
            lo = np.minimum(pa, pb)
            hi = np.maximum(pa, pb)
            np.minimum.at(p, hi, lo)
            return p

        return _ccl_rounds(np.arange(n, dtype=np.int64), ea, eb, hook,
                           lambda p: p[p], np.array_equal)

    ea = jnp.asarray(edges[:, 0], jnp.int64)
    eb = jnp.asarray(edges[:, 1], jnp.int64)
    return _ccl_rounds(
        jnp.arange(n, dtype=jnp.int64), ea, eb, _ccl_hook_jnp,
        _ccl_jump_jnp, lambda a, b: bool(jnp.array_equal(a, b)))


# ----------------------------------------------------------------------
# op 3: SoS face-crossing predicate
# ----------------------------------------------------------------------

def face_crossed(fu, fv, fidx, backend="xla", n_verts=None):
    """Exact SoS predicate on batched faces; fu/fv/fidx (..., 3).

    ``n_verts`` (static total space-time vertex count) guards the pallas
    int32-limb kernel's id-width precondition.
    """
    if backend == "pallas" and (n_verts is None or n_verts < 2**31):
        shape = fu.shape[:-1]
        n = int(np.prod(shape)) if shape else 1
        out = _cp_ops.face_crossed_batch(
            jnp.reshape(fu, (n, 3)), jnp.reshape(fv, (n, 3)),
            jnp.reshape(fidx, (n, 3)),
        )
        return jnp.reshape(out, shape)
    if backend == "numpy":
        return sos.face_crossed_vals(np, np.asarray(fu), np.asarray(fv),
                                     np.asarray(fidx))
    return sos.face_crossed_vals(jnp, fu, fv, fidx)


# ----------------------------------------------------------------------
# op 4: batched symbol histogram (device entropy stage, core/entropy.py)
# ----------------------------------------------------------------------

def _symbol_histogram_np(sym):
    # one flat bincount over row-offset keys (row i -> bins [256i, 256i+256))
    # instead of a per-row loop: one C pass regardless of B
    sym = np.asarray(sym)
    B, n = sym.shape
    keys = sym.astype(np.int32) + (np.arange(B, dtype=np.int32)[:, None] << 8)
    counts = np.bincount(keys.reshape(-1), minlength=B * 256)
    return counts.reshape(B, 256).astype(np.int32)


def symbol_histogram(sym, backend="xla"):
    """Per-row 256-bin histogram of a (B, n) uint8 symbol stack.

    Integer counts: exact and bit-identical across all three backends.
    The pallas path routes through kernels/entropy (compare-and-sum
    kernel on TPU, interpret mode elsewhere); xla uses the vmapped
    scatter-add reference; numpy is the host bincount loop.
    """
    if backend == "numpy":
        return _symbol_histogram_np(sym)
    if backend == "pallas":
        return _ent_ops.symbol_histogram(sym, force_pallas=True)
    return _ent_ops.symbol_histogram(sym, force_ref=True)
