"""Declarative pipeline plan + shared unit executor (DESIGN.md #10).

Every compression path in this repo -- monolithic fused, legacy (seed),
tiled and streaming -- runs the same stage graph

    fixedpoint -> eb-derive -> quantize -> predict -> verify-fixpoint
               -> symbolize -> pack

over *units* (a unit is a (field view, forced mask, eb, predicate
snapshot) tuple; the monolithic pipelines are the single-unit special
case).  This module owns:

* ``PipelinePlan``: the frozen description of one pipeline configuration
  -- global stream parameters (scale, tau, xi_unit, CFL, ...) plus the
  per-stage *bindings* that select a stage implementation.  The legacy
  seed pipeline is just the alternate binding set (``LEGACY_BINDINGS``:
  full predicate re-evaluation + sequential scan decode); the fused and
  tiled paths share ``FUSED_BINDINGS``.

* ``PlanExecutor``: binds a plan to executables -- the per-shape
  ``UnitFns`` stage registry, the shared SL stepper, and the batched
  ``BatchFns`` registry -- and exposes the stage entry points the
  drivers (core/compressor.py, core/tiling.py) orchestrate.

* Batched unit execution: same-signature units (one (ext_shape,
  owned_shape, owned offset) triple -- all interior tiles of a window
  share it) are stacked on a leading axis and run through vmapped
  encode/verify stages, shard_mapped over the ``("tiles",)`` mesh
  (parallel/sharding.py).  Why batched == sequential BITWISE:

    - quantize, Lorenzo residuals, MoP assembly, the decode cumsum, and
      every predicate/screen op are exact integer/boolean arithmetic --
      identical under any batching or backend (the DESIGN.md #4
      contract).
    - the reconstruction/pointwise checks are elementwise IEEE f64 ops
      (no reductions), bit-stable under vmap.
    - the two float-sensitive stages go through ONE executable in both
      modes by construction: SL prediction steps each unit through the
      same per-frame ``sl_stepper`` executable the sequential path (and
      the decoder) uses, and the MoP rate model runs the per-owned-shape
      ``UnitFns.mop_select`` executable per unit.

  So the residual streams, blockmaps and lossless masks -- hence the
  container bytes -- are byte-equal between ``batch_units=True`` and
  ``False`` (asserted in tests/test_pipeline_executor.py and
  benchmarks/timing.py's ``batched_vs_sequential`` section).

Compiled-stage registries (``unit_fns`` / ``batch_fns``) are explicit
keyed dicts, NOT an LRU: unit-shape churn (tile geometry sweeps, many
fields in one process) can never silently evict a live entry and
recompile every verify round.  Entries are keyed by the full static
signature and live for the process; ``clear_registries()`` resets them.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import backend as backend_mod
from . import ebound, ebpolicy, encode, fixedpoint, grid, mop, predictors, \
    quantize

jax.config.update("jax_enable_x64", True)

FORMAT_VERSION = 2
# written only by adaptive (non-uniform eb policy) monolithic encodes:
# the header additionally records the policy spec.  Uniform containers
# stay at FORMAT_VERSION, so pre-policy readers (and the goldens) are
# unaffected (DESIGN.md #16).
FORMAT_VERSION_ADAPTIVE = 3

STAGES = ("fixedpoint", "eb_derive", "quantize", "predict",
          "verify_fixpoint", "symbolize", "pack")

# stage bindings: (stage, variant) pairs; the variant names select the
# implementations below.  Stages not listed are shared by every plan.
FUSED_BINDINGS = (("encode", "fused"), ("decode", "parallel"),
                  ("verify", "screened"))
LEGACY_BINDINGS = (("encode", "legacy"), ("decode", "scan"),
                   ("verify", "full"))
# alternate symbolize/pack binding: the device-resident batched entropy
# stage (core/entropy.py) -- per-unit canonical Huffman bitstreams
# packed on the accelerator, emitted as self-describing CPTH1 frames.
# The default host binding keeps the zstd/zlib whole-payload codecs.
DEVICE_ENTROPY_BINDINGS = (("symbolize", "device"), ("pack", "device"))
HOST_ENTROPY_BINDINGS = (("symbolize", "host"), ("pack", "host"))
CODECS = ("host", "device")


def _codec_bindings(base: tuple, codec: str) -> tuple:
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r}; expected one of {CODECS}")
    return base + (DEVICE_ENTROPY_BINDINGS if codec == "device"
                   else HOST_ENTROPY_BINDINGS)


# Tunable plan/execution knobs: the single declarative source for every
# configurable default that plan construction and the tiled/streaming
# executors read.  ``plan_from_cfg`` and the execution paths resolve
# each knob through ``resolve_knobs`` (no scattered hand-set getattr
# defaults), and ``repro.autotune`` derives its search space from the
# same rows -- adding a knob here is the one step that exposes it to
# both.  Rows are (name, default); scheduling knobs (batch_cap, queue
# bounds) never reach the PipelinePlan and can never change container
# bytes -- only how fast a fixed plan executes.
PLAN_KNOBS = (
    ("predictor", "mop"),
    ("block", predictors.DEFAULT_BLOCK),
    ("n_levels", quantize.DEFAULT_LEVELS),
    ("zstd_level", 12),
    ("verify", True),
    ("max_rounds", 12),
    ("batch_units", True),       # stack same-signature units (vmapped)
    ("codec", "host"),           # entropy stage: host | device
    ("batch_cap", 8),            # tiled: max units per stacked batch
    ("q_in_frames", None),       # async engine: ingest queue bound
                                 # (None -> max(window_t, 2))
    ("q_out_units", None),       # async engine: handoff queue bound
                                 # (None -> max(2 * tiles_per_window, 2))
    ("eb_policy", None),         # BYTE-CHANGING plan knob: per-unit
                                 # base-bound policy (core/ebpolicy.py);
                                 # None/uniform -> the scalar path
)
PLAN_DEFAULTS = dict(PLAN_KNOBS)


def resolve_knobs(cfg) -> dict:
    """Every PLAN_KNOBS value for ``cfg``, falling back to the declared
    defaults for knobs the config object does not carry."""
    return {name: getattr(cfg, name, default)
            for name, default in PLAN_KNOBS}


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """One pipeline configuration: global stream parameters + bindings.

    ``name`` is the container's ``pipeline`` tag ("fused" | "legacy" |
    "tiled"); "tiled" shares the fused bindings.
    """

    name: str
    predictor: str
    backend: str
    backend_lorenzo: str
    block: int
    n_levels: int
    scale: float
    eb_abs: float
    tau: int
    xi_unit: int
    n_usable: int
    cfl_x: float
    cfl_y: float
    d_max: float
    n_max: int
    zstd_level: int = 12
    verify: bool = True
    max_rounds: int = 12
    batch_units: bool = True
    codec: str = "host"
    # canonical spec tuple of the eb policy (ebpolicy.policy_spec);
    # None for uniform.  A PLAN knob, not a scheduling knob: it changes
    # container bytes, so it lives on the plan and in the header.
    eb_policy: object = None
    bindings: tuple = FUSED_BINDINGS + HOST_ENTROPY_BINDINGS

    @property
    def g2f(self) -> float:
        return (2.0 * self.xi_unit) / self.scale


def lorenzo_backend(be: str, xi_unit: int) -> str:
    """The pallas Lorenzo kernel is int32; at xi_unit < 4 a worst-case
    residual (8 * 2^29 / xi_unit) could wrap, so demote that op to xla."""
    return "xla" if (be == "pallas" and xi_unit < 4) else be


def plan_from_cfg(cfg, be: str, scale: float, eb_abs: float,
                  name: str = "fused") -> PipelinePlan:
    """Plan from a CompressionConfig + the field-derived stream params.

    Every configurable default routes through PLAN_KNOBS/resolve_knobs
    -- plan construction is fully data-driven, so autotune's searched
    configs and hand-written ones resolve through the same table.
    """
    knobs = resolve_knobs(cfg)
    tau = max(int(np.floor(eb_abs * scale)), 0)
    xi_unit, n_usable = quantize.ladder(tau, knobs["n_levels"])
    return PipelinePlan(
        name=name,
        predictor=knobs["predictor"],
        backend=be,
        backend_lorenzo=lorenzo_backend(be, xi_unit),
        block=knobs["block"],
        n_levels=knobs["n_levels"],
        scale=scale,
        eb_abs=eb_abs,
        tau=tau,
        xi_unit=xi_unit,
        n_usable=n_usable,
        cfl_x=cfg.dt / cfg.dx,
        cfl_y=cfg.dt / cfg.dy,
        d_max=cfg.d_max,
        n_max=cfg.n_max,
        zstd_level=knobs["zstd_level"],
        verify=knobs["verify"],
        max_rounds=knobs["max_rounds"],
        batch_units=knobs["batch_units"],
        codec=knobs["codec"],
        eb_policy=ebpolicy.policy_spec(
            ebpolicy.normalize(knobs["eb_policy"])),
        bindings=_codec_bindings(
            LEGACY_BINDINGS if name == "legacy" else FUSED_BINDINGS,
            knobs["codec"]),
    )


def plan_from_header(header: dict, backend: Optional[str] = None
                     ) -> PipelinePlan:
    """Decode-side plan.  The fused/tiled decoder replays the SL stepper
    backend recorded in the header (``sl_backend``); the legacy decoder
    uses the pure-XLA scan."""
    name = header.get("pipeline", "legacy")
    if name == "legacy":
        be = "xla"
    else:
        be = backend_mod.resolve(backend or header.get("sl_backend"))
    xi_unit = int(header["xi_unit"])
    return PipelinePlan(
        name=name,
        predictor=header.get("predictor", "mop"),
        backend=be,
        backend_lorenzo=lorenzo_backend(be, xi_unit),
        block=int(header["block"]),
        n_levels=1,
        scale=float(header["scale"]),
        eb_abs=float(header.get("eb_abs", 0.0)),
        tau=0,
        xi_unit=xi_unit,
        n_usable=1,
        cfl_x=float(header["cfl_x"]),
        cfl_y=float(header["cfl_y"]),
        d_max=float(header["d_max"]),
        n_max=int(header["n_max"]),
        # decode is host-side either way (the section ``enc`` tags carry
        # the per-section codec); record which entropy stage encoded it
        codec="device" if header.get("codec") == "huffman" else "host",
        bindings=_codec_bindings(
            LEGACY_BINDINGS if name == "legacy" else FUSED_BINDINGS,
            "device" if header.get("codec") == "huffman" else "host"),
    )


# ----------------------------------------------------------------------
# shared static face tables (cached -- rebuilt per verify round before)
# ----------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _face_tables(H: int, W: int):
    """Host (slice_tab, slab_tab) pair used by every verify round."""
    return grid.slab_faces(H, W)["slice0"], ebound.slab_face_table(H, W)


def _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W):
    """Mark all vertices of violated faces (vectorized scatter)."""
    HW = H * W
    mask = np.zeros(T * HW, dtype=bool)
    slice_tab, slab_tab = _face_tables(H, W)
    t_ids, f_ids = np.nonzero(np.asarray(bad_slice))
    if len(t_ids):
        ids = slice_tab[f_ids].astype(np.int64) + t_ids[:, None] * HW
        mask[ids.reshape(-1)] = True
    t_ids, f_ids = np.nonzero(np.asarray(bad_slab))
    if len(t_ids):
        ids = slab_tab[f_ids].astype(np.int64) + t_ids[:, None] * HW
        mask[ids.reshape(-1)] = True
    return mask.reshape(T, H, W)


def _face_verts(ts, fs, tb, fb, H, W):
    """Global vertex-id triples for explicit (slice, slab) face indices."""
    HW = H * W
    slice_tab, slab_tab = _face_tables(H, W)
    return np.concatenate([
        slice_tab[fs].astype(np.int64) + ts[:, None] * HW,
        slab_tab[fb].astype(np.int64) + tb[:, None] * HW,
    ], axis=0)


def _touched_faces(delta_np, T, H, W):
    """Faces incident to newly-forced vertices -> (verts (N,3) global
    ids, slice_sel, slab_sel index arrays)."""
    HW = H * W
    slice_tab, slab_tab = _face_tables(H, W)
    d2 = delta_np.reshape(T, HW)
    t_slice = (d2[:, slice_tab[:, 0]] | d2[:, slice_tab[:, 1]]
               | d2[:, slice_tab[:, 2]])
    pair = np.concatenate([d2[:-1], d2[1:]], axis=1)
    t_slab = (pair[:, slab_tab[:, 0]] | pair[:, slab_tab[:, 1]]
              | pair[:, slab_tab[:, 2]])
    ts, fs = np.nonzero(t_slice)
    tb, fb = np.nonzero(t_slab)
    return _face_verts(ts, fs, tb, fb, H, W), (ts, fs), (tb, fb)


# ----------------------------------------------------------------------
# shared jitted stage pieces
# ----------------------------------------------------------------------

def _reconstruct(xu, xv, scale, xi_unit, lossless, u_raw, v_raw):
    g = 2.0 * xi_unit
    u_rec = (xu.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    v_rec = (xv.astype(jnp.float64) * (g / scale)).astype(jnp.float32)
    u_rec = jnp.where(lossless, u_raw, u_rec)
    v_rec = jnp.where(lossless, v_raw, v_rec)
    return u_rec, v_rec


def _recon_refix(xu_d, xv_d, lossless, u_raw, v_raw, scale, xi_unit,
                 eb_abs):
    """Reconstruct, re-fix and flag pointwise-bound violations."""
    u_rec, v_rec = _reconstruct(xu_d, xv_d, scale, xi_unit, lossless,
                                u_raw, v_raw)
    ur_fp = jnp.round(u_rec.astype(jnp.float64) * scale).astype(jnp.int64)
    vr_fp = jnp.round(v_rec.astype(jnp.float64) * scale).astype(jnp.int64)
    err = jnp.maximum(
        jnp.abs(u_rec.astype(jnp.float64) - u_raw.astype(jnp.float64)),
        jnp.abs(v_rec.astype(jnp.float64) - v_raw.astype(jnp.float64)),
    )
    bad_pt = err > eb_abs
    return ur_fp, vr_fp, bad_pt


def _quantize_core(ufp, vfp, eb_vertex, lossless_extra, xi_unit, n_levels):
    """eb -> (X_u, X_v, k, lossless); the ONE quantize-stage body every
    binding (sequential, batched, legacy) runs -- divergence here would
    break the batched == sequential byte-equality guarantee."""
    k, lossless = quantize.quantize_eb(eb_vertex, xi_unit, n_levels)
    lossless = jnp.logical_or(lossless, lossless_extra)
    k = jnp.where(lossless_extra, -1, k)
    xu = quantize.dual_quantize(ufp, k, lossless, xi_unit)
    xv = quantize.dual_quantize(vfp, k, lossless, xi_unit)
    return xu, xv, k, lossless


def _check_pt_core(xu_d, xv_d, lossless, lossless_extra, u_raw, v_raw,
                   scale, xi_unit, eb_abs):
    ur_fp, vr_fp, bad_pt = _recon_refix(
        xu_d, xv_d, lossless, u_raw, v_raw, scale, xi_unit, eb_abs)
    forced = lossless_extra | bad_pt
    return forced, jnp.asarray(bad_pt).sum(), ur_fp, vr_fp


def _screen_unsafe_core(shape, slice_tab, slab_tab, ufp, vfp, ur_fp, vr_fp):
    """Faces whose predicate COULD have flipped (sound screen).

    A face all of whose u-components (or all of whose v-components)
    keep one strict sign in BOTH the original and the reconstruction
    cannot be crossed in either (the convex hull stays off the
    origin, SoS included), so its predicate is provably unchanged.
    Only the remaining faces -- a thin band around the zero set --
    need the exact SoS evaluation.  Pure boolean gathers: no int64
    products.
    """
    T, H, W = shape
    HW = H * W
    masks = []
    for o, r in ((ufp, ur_fp), (vfp, vr_fp)):
        masks.append(((o > 0) & (r > 0)).reshape(T, HW))
        masks.append(((o < 0) & (r < 0)).reshape(T, HW))

    def face_all(m, tab):
        return m[:, tab[:, 0]] & m[:, tab[:, 1]] & m[:, tab[:, 2]]

    def unsafe(window):
        pu, nu, pv, nv = (face_all(m, tab) for m, tab in window)
        return ~(pu | nu | pv | nv)

    unsafe_slice = unsafe([(m, slice_tab) for m in masks])
    pair = [jnp.concatenate([m[:-1], m[1:]], axis=1) for m in masks]
    unsafe_slab = unsafe([(m, slab_tab) for m in pair])
    return unsafe_slice, unsafe_slab


# ----------------------------------------------------------------------
# per-shape unit stage functions (the keyed registry, DESIGN.md #10)
# ----------------------------------------------------------------------

class UnitFns:
    """Jitted stages of the fused pipeline for one static configuration
    (shape x block x n_levels x predictor x backend); registered once in
    the keyed ``unit_fns`` registry and shared by every path.

    ``be_lorenzo`` routes only the Lorenzo-residual op: the pallas
    kernel computes in int32 (|residual| <= 2^32 / xi_unit worst case),
    so callers demote it to xla when xi_unit < 4 keeps no headroom.
    """

    def __init__(self, shape, block, n_levels, predictor, be,
                 be_lorenzo=None):
        self.shape = shape
        self.block = block
        self.n_levels = n_levels
        self.predictor = predictor
        self.be = be
        self.be_lorenzo = be if be_lorenzo is None else be_lorenzo
        T, H, W = shape
        self.nb = (-(-H // block), -(-W // block))
        slice_tab, slab_tab = _face_tables(H, W)
        self._slice_tab = jnp.asarray(slice_tab)
        self._slab_tab = jnp.asarray(slab_tab)
        jit = (lambda f, **kw: f) if be == "numpy" else jax.jit

        self.lorenzo_stage = jit(self._lorenzo_stage)
        self.quant_stage = jit(self._quant_stage)
        self.sl_stage = jit(self._sl_stage)
        self.mop_stage = jit(self._mop_stage)
        self.screen_unsafe = jit(self._screen_unsafe)
        self.check_pt = jit(self._check_pt)
        self.face_subset = jit(self._face_subset)
        # mop_select is ALWAYS jitted -- even on the numpy backend -- so
        # the float rate model runs through one executable per owned
        # shape in every mode (sequential, batched, any backend):
        # executable identity is what makes the blockmap -- hence the
        # container bytes -- mode-independent (module doc).
        self.mop_select = jax.jit(self._mop_select)
        self.mop_assemble = jax.jit(self._mop_assemble)

    # ---- encode stages

    def _quant_stage(self, ufp, vfp, eb_vertex, lossless_extra, xi_unit):
        return _quantize_core(ufp, vfp, eb_vertex, lossless_extra,
                              xi_unit, self.n_levels)

    def _lorenzo_stage(self, ufp, vfp, eb_vertex, lossless_extra, xi_unit):
        """Pure-Lorenzo encode: the fused dualquant+residual op, no X
        materialization."""
        k, lossless = quantize.quantize_eb(eb_vertex, xi_unit, self.n_levels)
        lossless = jnp.logical_or(lossless, lossless_extra)
        k = jnp.where(lossless_extra, -1, k)
        res_u = backend_mod.lorenzo_residual(
            ufp, k, lossless, xi_unit, self.block, self.be_lorenzo)
        res_v = backend_mod.lorenzo_residual(
            vfp, k, lossless, xi_unit, self.block, self.be_lorenzo)
        return res_u, res_v, lossless

    def _sl_stage(self, xu, xv, pu, pv):
        res_u = jnp.concatenate(
            [predictors.d2_block(xu[:1], self.block), xu[1:] - pu], axis=0)
        res_v = jnp.concatenate(
            [predictors.d2_block(xv[:1], self.block), xv[1:] - pv], axis=0)
        return res_u, res_v

    def _mop_stage(self, ufp, vfp, k, lossless, xu, xv, pu, pv, xi_unit):
        res3_u, res3_v, ressl_u, ressl_v = self._mop_residuals(
            ufp, vfp, k, lossless, xu, xv, pu, pv, xi_unit)
        bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, self.block)
        res_u = mop.assemble(res3_u, ressl_u, bm, self.block)
        res_v = mop.assemble(res3_v, ressl_v, bm, self.block)
        return res_u, res_v, bm

    def _mop_residuals(self, ufp, vfp, k, lossless, xu, xv, pu, pv,
                       xi_unit):
        """MoP candidate residuals only; selection runs separately
        through the shared ``mop_select`` executable (unit paths)."""
        res3_u = backend_mod.lorenzo_residual(
            ufp, k, lossless, xi_unit, self.block, self.be_lorenzo, x=xu)
        res3_v = backend_mod.lorenzo_residual(
            vfp, k, lossless, xi_unit, self.block, self.be_lorenzo, x=xv)
        zero = jnp.zeros_like(xu[:1])
        ressl_u = jnp.concatenate([zero, xu[1:] - pu], axis=0)
        ressl_v = jnp.concatenate([zero, xv[1:] - pv], axis=0)
        return (jnp.asarray(res3_u), jnp.asarray(res3_v),
                ressl_u, ressl_v)

    def _mop_select(self, res3_u, res3_v, ressl_u, ressl_v):
        return mop.select(res3_u, res3_v, ressl_u, ressl_v, self.block)

    def _mop_assemble(self, res3_u, res3_v, ressl_u, ressl_v, bm):
        return (mop.assemble(res3_u, ressl_u, bm, self.block),
                mop.assemble(res3_v, ressl_v, bm, self.block))

    # ---- verify stages

    def _screen_unsafe(self, ufp, vfp, ur_fp, vr_fp):
        return _screen_unsafe_core(self.shape, self._slice_tab,
                                   self._slab_tab, ufp, vfp, ur_fp, vr_fp)

    def _check_pt(self, xu_d, xv_d, lossless, lossless_extra, u_raw, v_raw,
                  scale, xi_unit, eb_abs):
        return _check_pt_core(xu_d, xv_d, lossless, lossless_extra,
                              u_raw, v_raw, scale, xi_unit, eb_abs)

    def _face_subset(self, ur_flat, vr_flat, verts):
        """Predicates for an explicit face subset (incremental rounds)."""
        T, H, W = self.shape
        fu = ur_flat[verts]
        fv = vr_flat[verts]
        return backend_mod.face_crossed(
            fu, fv, verts.astype(jnp.int64), backend=self.be,
            n_verts=T * H * W)


# explicit keyed registries (no LRU: shape churn can never evict a live
# entry and silently recompile every verify round).  Guarded by a lock:
# the async stream engine and the served-read layer (analysis/query.py)
# may build executors from worker threads, and an unguarded get-or-create
# could construct the same UnitFns twice concurrently.
_UNIT_FNS: dict = {}
_BATCH_FNS: dict = {}
_BATCH_STAGES: dict = {}
_REGISTRY_LOCK = threading.Lock()


def unit_fns(shape, block, n_levels, predictor, be, be_lorenzo=None
             ) -> UnitFns:
    key = (tuple(shape), block, n_levels, predictor, be, be_lorenzo)
    with _REGISTRY_LOCK:
        fns = _UNIT_FNS.get(key)
        if fns is None:
            # registry miss = a fresh jit trace per stage; the retrace
            # counter is how shape churn shows up in obs.snapshot()
            obs.counter("pipeline.registry_miss.unit_fns").add(1)
            fns = _UNIT_FNS[key] = UnitFns(shape, block, n_levels,
                                           predictor, be, be_lorenzo)
    return fns


def clear_registries():
    from . import entropy
    with _REGISTRY_LOCK:
        _UNIT_FNS.clear()
        _BATCH_FNS.clear()
        _BATCH_STAGES.clear()
    entropy.clear_registry()


# ----------------------------------------------------------------------
# batched unit stage functions (one signature = one stacked batch)
# ----------------------------------------------------------------------

def unit_signature(ext_shape, owned_shape, owned_offset):
    """Batching signature: units sharing it can be stacked and run
    through one vmapped executable set."""
    return (tuple(ext_shape), tuple(owned_shape), tuple(owned_offset))


class _BatchStages:
    """The signature-offset-INDEPENDENT stage executables of BatchFns.

    Every stage here depends only on (ext_shape, block, n_levels) --
    NOT on the owned box -- so units whose signatures differ only in
    owned shape/offset (e.g. the four corner tiles of a window, or
    interior vs edge tiles) share ONE compiled executable set instead
    of recompiling identical programs per signature.  Only ``paste``
    (BatchFns) closes over the owned slice.
    """

    def __init__(self, ext_shape, block, n_levels):
        from ..parallel import sharding

        Te, he, we = ext_shape
        slice_tab, slab_tab = _face_tables(he, we)
        slice_tab = jnp.asarray(slice_tab)
        slab_tab = jnp.asarray(slab_tab)
        blk = block

        def _quant1(u, v, eb, extra, xi):
            return _quantize_core(u, v, eb, extra, xi, n_levels)

        def _res_lorenzo1(xu, xv):
            return (predictors.lorenzo_encode(xu, blk),
                    predictors.lorenzo_encode(xv, blk))

        def _res_sl1(xu, xv, pu, pv):
            ru = jnp.concatenate(
                [predictors.d2_block(xu[:1], blk), xu[1:] - pu], axis=0)
            rv = jnp.concatenate(
                [predictors.d2_block(xv[:1], blk), xv[1:] - pv], axis=0)
            return ru, rv

        def _res_mop1(xu, xv, pu, pv):
            r3u = predictors.lorenzo_encode(xu, blk)
            r3v = predictors.lorenzo_encode(xv, blk)
            zero = jnp.zeros_like(xu[:1])
            rsu = jnp.concatenate([zero, xu[1:] - pu], axis=0)
            rsv = jnp.concatenate([zero, xv[1:] - pv], axis=0)
            return r3u, r3v, rsu, rsv

        def _assemble1(r3u, r3v, rsu, rsv, bm):
            return (mop.assemble(r3u, rsu, bm, blk),
                    mop.assemble(r3v, rsv, bm, blk))

        def _decode_cumsum1(ru, rv):
            return (jnp.cumsum(predictors.c2_block(ru, blk), axis=0),
                    jnp.cumsum(predictors.c2_block(rv, blk), axis=0))

        def _check_pt1(xu_d, xv_d, ll, extra, u, v, scale, xi, eb_abs):
            return _check_pt_core(xu_d, xv_d, ll, extra, u, v,
                                  scale, xi, eb_abs)

        def _screen1(ufp, vfp, ur, vr):
            return _screen_unsafe_core((Te, he, we), slice_tab, slab_tab,
                                       ufp, vfp, ur, vr)

        def mt(fn):
            return jax.jit(lambda *b: sharding.map_tiles_padded(fn, *b))

        self.quant = mt(_quant1)
        self.res_lorenzo = mt(_res_lorenzo1)
        self.res_sl = mt(_res_sl1)
        self.res_mop = mt(_res_mop1)
        self.assemble = mt(_assemble1)
        self.decode_cumsum = mt(_decode_cumsum1)
        self.check_pt = mt(_check_pt1)
        self.screen = mt(_screen1)


class BatchFns:
    """Vmapped + tiles-mesh-sharded stages for one unit signature.

    Per-unit scalars (xi_unit, scale, eb_abs) travel as (B,) arrays so
    one compiled executable serves every plan with this geometry.  Only
    exact integer/boolean and elementwise-f64 work lives here; the SL
    predictor and the MoP rate model are routed through the same
    executables as the sequential path (module doc).  All stages except
    ``paste`` are borrowed from the shared per-ext-shape _BatchStages
    entry (same registry lifetime), so same-geometry signatures never
    compile twice.
    """

    def __init__(self, sig, block, n_levels, stages: _BatchStages):
        (Te, he, we), (To, ho, wo), (dt0, di0, dj0) = sig
        self.sig = sig
        self.block = block
        self.n_levels = n_levels
        self.ext_shape = (Te, he, we)
        self.owned_shape = (To, ho, wo)
        self.owned = (slice(dt0, dt0 + To), slice(di0, di0 + ho),
                      slice(dj0, dj0 + wo))
        self.quant = stages.quant
        self.res_lorenzo = stages.res_lorenzo
        self.res_sl = stages.res_sl
        self.res_mop = stages.res_mop
        self.assemble = stages.assemble
        self.decode_cumsum = stages.decode_cumsum
        self.check_pt = stages.check_pt
        self.screen = stages.screen
        o = (slice(None),) + self.owned
        self.paste = jax.jit(
            lambda xe, ve, xd, vd: (xe.at[o].set(xd), ve.at[o].set(vd)))


def batch_fns(sig, block, n_levels) -> BatchFns:
    key = (sig, block, n_levels)
    with _REGISTRY_LOCK:
        fns = _BATCH_FNS.get(key)
        if fns is None:
            obs.counter("pipeline.registry_miss.batch_fns").add(1)
            skey = (sig[0], block, n_levels)
            stages = _BATCH_STAGES.get(skey)
            if stages is None:
                obs.counter("pipeline.registry_miss.batch_stages").add(1)
                stages = _BATCH_STAGES[skey] = _BatchStages(
                    sig[0], block, n_levels)
            fns = _BATCH_FNS[key] = BatchFns(sig, block, n_levels, stages)
    return fns


def _pad_pow2(arrays):
    """Pad each array's leading axis to the next power of two (repeating
    the last row) so jitted batched stages compile for O(log) distinct
    batch sizes instead of one per group size.  Returns (padded, n)."""
    n = int(arrays[0].shape[0])
    m = 1 << max(n - 1, 0).bit_length()
    if m == n:
        return [jnp.asarray(a) for a in arrays], n
    out = []
    for a in arrays:
        a = jnp.asarray(a)
        out.append(jnp.concatenate(
            [a, jnp.repeat(a[-1:], m - n, axis=0)], axis=0))
    return out, n


# ----------------------------------------------------------------------
# legacy (seed) stage implementations -- the alternate binding
# ----------------------------------------------------------------------

_predicates_jit = jax.jit(lambda ufp, vfp: ebound.all_face_predicates(
    ufp, vfp))


def legacy_quantize(ufp, vfp, eb, xi_unit, n_levels, lossless_extra):
    """Seed quantize stage: the shared core, k discarded."""
    xu, xv, _, lossless = _quantize_core(ufp, vfp, eb, lossless_extra,
                                         xi_unit, n_levels)
    return xu, xv, lossless


def legacy_residuals(xu, xv, scale, xi_unit, predictor, block,
                     cfl_x, cfl_y, d_max, n_max):
    """Seed predict stage: full residual stacks, no fused ops."""
    g2f = (2.0 * xi_unit) / scale
    T = xu.shape[0]
    nbi = -(-xu.shape[1] // block)
    nbj = -(-xu.shape[2] // block)
    if predictor == "lorenzo":
        res3_u = predictors.lorenzo_encode(xu, block)
        res3_v = predictors.lorenzo_encode(xv, block)
        bm = jnp.zeros((T, nbi, nbj), dtype=bool)
        return res3_u, res3_v, bm
    ressl_u, ressl_v = predictors.sl_encode(
        xu, xv, g2f, cfl_x, cfl_y, d_max, n_max)
    if predictor == "sl":
        # only frame 0 consumes a Lorenzo (spatial-only) residual; skip
        # the full 3DL stack the seed computed here
        res_u = ressl_u.at[0].set(predictors.d2_block(xu[0], block))
        res_v = ressl_v.at[0].set(predictors.d2_block(xv[0], block))
        bm = jnp.ones((T, nbi, nbj), dtype=bool).at[0].set(False)
        return res_u, res_v, bm
    res3_u = predictors.lorenzo_encode(xu, block)
    res3_v = predictors.lorenzo_encode(xv, block)
    bm = mop.select(res3_u, res3_v, ressl_u, ressl_v, block)
    res_u = mop.assemble(res3_u, ressl_u, bm, block)
    res_v = mop.assemble(res3_v, ressl_v, bm, block)
    return res_u, res_v, bm


def _decode_fields(res_u, res_v, blockmap, scale, xi_unit, block,
                   cfl_x, cfl_y, d_max, n_max):
    """Legacy decode: sequential scan over frames (seed pipeline)."""
    g2f = (2.0 * xi_unit) / scale
    T, H, W = res_u.shape

    def frame0(res_u0, res_v0):
        xu = predictors.c2_block(res_u0, block)
        xv = predictors.c2_block(res_v0, block)
        return xu, xv

    def step(carry, inp):
        xu_p, xv_p = carry
        ru, rv, bm = inp
        xu3 = predictors.lorenzo_decode_frame(xu_p, ru, block)
        xv3 = predictors.lorenzo_decode_frame(xv_p, rv, block)
        pu, pv = predictors.sl_predict_frame(
            xu_p, xv_p, g2f, cfl_x, cfl_y, d_max, n_max
        )
        xus = ru + pu
        xvs = rv + pv
        mask = jnp.repeat(jnp.repeat(bm, block, axis=0), block, axis=1)[:H, :W]
        xu = jnp.where(mask, xus, xu3)
        xv = jnp.where(mask, xvs, xv3)
        return (xu, xv), (xu, xv)

    xu0, xv0 = frame0(res_u[0], res_v[0])
    (_, _), (xu_rest, xv_rest) = jax.lax.scan(
        step, (xu0, xv0), (res_u[1:], res_v[1:], blockmap[1:])
    )
    xu = jnp.concatenate([xu0[None], xu_rest], axis=0)
    xv = jnp.concatenate([xv0[None], xv_rest], axis=0)
    return xu, xv


_decode_fields_jit = jax.jit(
    _decode_fields, static_argnums=(5, 8, 9), static_argnames=()
)


# ----------------------------------------------------------------------
# fused decode: parallel-in-time, shared by verify-sim and decompress
# ----------------------------------------------------------------------

def _decode_fields_parallel(res_u, res_v, blockmap, scale, xi_unit, block,
                            stepper):
    """Parallel-in-time decode shared by the verify simulation and
    decompress (one implementation => bitwise-consistent guarantees).

    ``blockmap`` is a HOST bool array (T, nbi, nbj): maximal runs of
    frames with no SL tile satisfy X_t = X_{t-1} + C2(res_t), a prefix
    sum decoded with one cumsum over time; only frames containing SL
    tiles step through the shared SL ``stepper`` executable.
    """
    res_u = jnp.asarray(res_u)
    res_v = jnp.asarray(res_v)
    bm = np.asarray(blockmap)
    T, H, W = res_u.shape
    g2f = (2.0 * xi_unit) / scale
    c2u = predictors.c2_block(res_u, block)   # every frame, in parallel
    c2v = predictors.c2_block(res_v, block)
    any_sl = bm.reshape(T, -1).any(axis=1)
    any_sl[0] = False                          # frame 0 is spatial-only
    if not any_sl.any():
        return jnp.cumsum(c2u, axis=0), jnp.cumsum(c2v, axis=0)
    Su = jnp.cumsum(c2u, axis=0)
    Sv = jnp.cumsum(c2v, axis=0)
    mask_rep = np.repeat(np.repeat(bm, block, axis=1), block, axis=2)[:, :H, :W]

    us, vs = [], []
    prev_u = prev_v = None
    cur = 0
    for t in np.flatnonzero(any_sl):
        t = int(t)
        if t > cur:
            if cur == 0:
                seg_u, seg_v = Su[:t], Sv[:t]
            else:
                seg_u = (prev_u - Su[cur - 1])[None] + Su[cur:t]
                seg_v = (prev_v - Sv[cur - 1])[None] + Sv[cur:t]
            us.append(seg_u)
            vs.append(seg_v)
            prev_u, prev_v = seg_u[-1], seg_v[-1]
        pu, pv = stepper(prev_u, prev_v, g2f)
        m = jnp.asarray(mask_rep[t])
        xu_t = jnp.where(m, res_u[t] + pu, prev_u + c2u[t])
        xv_t = jnp.where(m, res_v[t] + pv, prev_v + c2v[t])
        us.append(xu_t[None])
        vs.append(xv_t[None])
        prev_u, prev_v = xu_t, xv_t
        cur = t + 1
    if cur < T:
        us.append((prev_u - Su[cur - 1])[None] + Su[cur:])
        vs.append((prev_v - Sv[cur - 1])[None] + Sv[cur:])
    return jnp.concatenate(us, axis=0), jnp.concatenate(vs, axis=0)


# ----------------------------------------------------------------------
# face re-verification shared by monolithic and tiled rounds
# ----------------------------------------------------------------------

def screen_selection_from(unsafe_sl, unsafe_sb, H, W):
    """Host face selection from (already computed) screen masks."""
    ts, fs = np.nonzero(np.asarray(unsafe_sl))
    tb, fb = np.nonzero(np.asarray(unsafe_sb))
    return _face_verts(ts, fs, tb, fb, H, W), (ts, fs), (tb, fb)


def face_recheck(fns: UnitFns, shape, ur_fp, vr_fp, preds, selection):
    """Exact SoS re-evaluation of an explicit face selection against the
    original-predicate snapshot ``preds = (slice0, slab0)``.

    Returns (forced-additions bool array of ``shape`` or None, n_bad).
    """
    verts, (ts, fs), (tb, fb) = selection
    if not len(verts):
        return None, 0
    slice0, slab0 = preds
    orig = np.concatenate([slice0[ts, fs], slab0[tb, fb]])
    B = max(8, 1 << (len(verts) - 1).bit_length())
    verts_p = np.concatenate([
        verts,
        np.tile(np.array([[0, 1, 2]], np.int64), (B - len(verts), 1)),
    ], axis=0)
    crossed = np.asarray(fns.face_subset(
        ur_fp.reshape(-1), vr_fp.reshape(-1),
        jnp.asarray(verts_p)))[: len(verts)]
    bad = crossed != orig
    if not bad.any():
        return None, 0
    T, H, W = shape
    add = np.zeros(T * H * W, dtype=bool)
    add[verts[bad].reshape(-1)] = True
    return add.reshape(shape), int(bad.sum())


def check_faces(fns: UnitFns, shape, ufp_j, vfp_j, ur_fp, vr_fp, preds,
                delta):
    """Face re-verification where predicates could have changed:
    ``delta is None`` -> the sign-stability screen (first contact);
    else only faces incident to newly-forced ``delta`` vertices."""
    T, H, W = shape
    if delta is None:
        unsafe_sl, unsafe_sb = fns.screen_unsafe(ufp_j, vfp_j, ur_fp, vr_fp)
        selection = screen_selection_from(unsafe_sl, unsafe_sb, H, W)
    else:
        selection = _touched_faces(delta, T, H, W)
    return face_recheck(fns, shape, ur_fp, vr_fp, preds, selection)


# ----------------------------------------------------------------------
# executor
# ----------------------------------------------------------------------

class PlanExecutor:
    """Binds a PipelinePlan to its executables and exposes the stage
    entry points (full-field and per-unit) that every driver routes
    through."""

    def __init__(self, plan: PipelinePlan):
        self.plan = plan
        self._impl = dict(plan.bindings)
        self.stepper = backend_mod.sl_stepper(
            plan.backend, plan.cfl_x, plan.cfl_y, plan.d_max, plan.n_max)

    @property
    def g2f(self):
        return self.plan.g2f

    def fns(self, shape) -> UnitFns:
        p = self.plan
        return unit_fns(shape, p.block, p.n_levels, p.predictor,
                        p.backend, p.backend_lorenzo)

    def batch_fns(self, sig) -> BatchFns:
        return batch_fns(sig, self.plan.block, self.plan.n_levels)

    # ---- eb-derive stage ------------------------------------------------

    def derive_eb(self, ufp_j, vfp_j):
        """Per-vertex bounds + original predicates (one pass: the
        crossed-face zeroing evaluates every SoS predicate anyway)."""
        return ebound.derive_vertex_eb_jit(
            ufp_j, vfp_j, int(max(self.plan.tau, 1)))

    # ---- decode stage ---------------------------------------------------

    def decode_fields(self, res_u, res_v, bm):
        p = self.plan
        if self._impl["decode"] == "scan":
            return _decode_fields_jit(
                jnp.asarray(res_u), jnp.asarray(res_v), jnp.asarray(bm),
                p.scale, p.xi_unit, p.block, p.cfl_x, p.cfl_y,
                p.d_max, p.n_max)
        return _decode_fields_parallel(
            res_u, res_v, np.asarray(bm), p.scale, p.xi_unit, p.block,
            self.stepper)

    def decode_payload(self, shape, sections):
        """sections -> reconstructed (u, v) float32 numpy arrays.  One
        implementation for monolithic blobs and tiled container units."""
        p = self.plan
        res_u, res_v, bm, ll = encode.parse_field_sections(sections, shape)
        xu, xv = self.decode_fields(res_u, res_v, bm)
        u_raw = np.zeros(shape, dtype=np.float32)
        v_raw = np.zeros(shape, dtype=np.float32)
        u_raw[ll] = sections["u_ll"]
        v_raw[ll] = sections["v_ll"]
        u_rec, v_rec = _reconstruct(
            xu, xv, p.scale, p.xi_unit,
            jnp.asarray(ll), jnp.asarray(u_raw), jnp.asarray(v_raw))
        return np.asarray(u_rec), np.asarray(v_rec)

    def decode_unit(self, unit_header, sections):
        t0, t1, i0, i1, j0, j1 = unit_header["box"]
        return self.decode_payload((t1 - t0, i1 - i0, j1 - j0), sections)

    # ---- symbolize/pack stage (host codec vs device entropy stage) ------

    @property
    def codec(self) -> str:
        return self._impl.get("symbolize", "host")

    def encode_sections(self, res_u, res_v, ll, u_ll, v_ll, bm) -> dict:
        """One unit's streams -> container section dict, routed through
        the plan's symbolize/pack binding: the host codec symbolizes on
        CPU (encode.field_sections), the device codec entropy-encodes
        the residual streams on the accelerator (core/entropy.py)."""
        if self.codec == "device":
            from . import entropy
            return entropy.field_sections_device(
                res_u, res_v, np.asarray(ll), u_ll, v_ll, np.asarray(bm),
                self.plan.backend)
        return encode.field_sections(res_u, res_v, np.asarray(ll),
                                     u_ll, v_ll, np.asarray(bm))

    def entropy_fragments(self, res_u_stack, res_v_stack) -> list:
        """Batched device entropy encode of stacked same-shape residual
        streams; returns one section fragment per unit (device codec
        only -- callers gate on ``codec``)."""
        from . import entropy
        return entropy.encode_streams(res_u_stack, res_v_stack,
                                      self.plan.backend)

    # ---- per-unit encode (tiled paths; ext-quantize + owned streams) ----

    def encode_unit(self, ufp_e, vfp_e, eb_e, extra_e, owned):
        """Sequential unit encode: quantize the halo extension, build
        the owned box's residual streams.  Returns (xu_e, xv_e, ll_e,
        res_u, res_v, bm(np))."""
        p = self.plan
        ext_shape = tuple(int(s) for s in ufp_e.shape)
        fns_e = self.fns(ext_shape)
        # bind the device copies once: every later use (quant, owned
        # slicing) reuses them instead of re-uploading the boxes
        ufp_j = jnp.asarray(ufp_e)
        vfp_j = jnp.asarray(vfp_e)
        xu_e, xv_e, k_e, ll_e = fns_e.quant_stage(
            ufp_j, vfp_j, jnp.asarray(eb_e), jnp.asarray(extra_e),
            p.xi_unit)
        o = owned
        owned_shape = tuple(
            int(s.stop - s.start) for s in o)
        fns_o = self.fns(owned_shape)
        res_u, res_v, bm = self._unit_streams(
            fns_o, ufp_j[o], vfp_j[o],
            k_e[o], ll_e[o], xu_e[o], xv_e[o])
        return xu_e, xv_e, ll_e, res_u, res_v, bm

    def _unit_streams(self, fns_o, ufp_o, vfp_o, k_o, ll_o, xu_o, xv_o):
        """Residual streams of one unit (the bytes that get stored).

        The temporal predictor restarts at the unit's first frame and
        the SL backtrace runs on the unit's own planes (tile-local), so
        decode of a unit touches nothing outside it.  Residual blocking
        cannot change the decoded X (exact integer inverses), so this
        stays bit-compatible with the monolithic output.
        """
        p = self.plan
        To, ho, wo = xu_o.shape
        nbi, nbj = fns_o.nb
        if p.predictor == "lorenzo":
            res_u = backend_mod.lorenzo_residual(
                ufp_o, k_o, ll_o, p.xi_unit, p.block, fns_o.be_lorenzo,
                x=xu_o)
            res_v = backend_mod.lorenzo_residual(
                vfp_o, k_o, ll_o, p.xi_unit, p.block, fns_o.be_lorenzo,
                x=xv_o)
            return res_u, res_v, np.zeros((To, nbi, nbj), dtype=bool)
        if To > 1:
            pu, pv = backend_mod.sl_predictions(xu_o, xv_o, self.g2f,
                                                self.stepper)
        else:
            pu = pv = jnp.zeros((0, ho, wo), jnp.int64)
        if p.predictor == "sl":
            res_u, res_v = fns_o.sl_stage(xu_o, xv_o, pu, pv)
            bm = np.ones((To, nbi, nbj), dtype=bool)
            bm[0] = False
            return res_u, res_v, bm
        r3u, r3v, rsu, rsv = fns_o._mop_residuals(
            ufp_o, vfp_o, k_o, ll_o, xu_o, xv_o, pu, pv, p.xi_unit)
        bm = fns_o.mop_select(r3u, r3v, rsu, rsv)
        res_u, res_v = fns_o.mop_assemble(r3u, r3v, rsu, rsv, bm)
        return res_u, res_v, np.asarray(bm)

    # ---- batched unit encode -------------------------------------------

    def encode_units(self, sig, ufp_es, vfp_es, eb_es, extra_es):
        """Batched encode of same-signature units stacked on axis 0.

        Integer stages run vmapped over the ("tiles",) mesh; SL goes
        per-unit through the shared stepper; MoP selection per-unit
        through the shared ``mop_select`` executable -- so the result is
        byte-equal to ``encode_unit`` per unit (module doc).
        Returns (xu_e, xv_e, ll_e, res_u, res_v, bms(np (B, ...))).
        """
        p = self.plan
        bf = self.batch_fns(sig)
        B = int(ufp_es.shape[0])
        (padded, _) = _pad_pow2([ufp_es, vfp_es, eb_es, extra_es])
        xis = jnp.full((padded[0].shape[0],), p.xi_unit, jnp.int64)
        xu_e, xv_e, k_e, ll_e = bf.quant(*padded, xis)
        ob = (slice(None),) + bf.owned
        xu_o, xv_o = xu_e[ob], xv_e[ob]
        To, ho, wo = bf.owned_shape
        nbi = -(-ho // p.block)
        nbj = -(-wo // p.block)
        if p.predictor == "lorenzo":
            res_u, res_v = bf.res_lorenzo(xu_o, xv_o)
            bms = np.zeros((B, To, nbi, nbj), dtype=bool)
            return xu_e[:B], xv_e[:B], ll_e[:B], res_u[:B], res_v[:B], bms
        if To > 1:
            # SL steps only the live rows (the padding rows repeat the
            # last unit; their predictions are re-padded to match)
            pu, pv = backend_mod.sl_predictions_batched(
                xu_o[:B], xv_o[:B], self.g2f, self.stepper)
            (pu, pv), _ = _pad_pow2([pu, pv])
        else:
            pu = pv = jnp.zeros((xu_o.shape[0], 0, ho, wo), jnp.int64)
        if p.predictor == "sl":
            res_u, res_v = bf.res_sl(xu_o, xv_o, pu, pv)
            bms = np.ones((B, To, nbi, nbj), dtype=bool)
            bms[:, 0] = False
            return xu_e[:B], xv_e[:B], ll_e[:B], res_u[:B], res_v[:B], bms
        r3u, r3v, rsu, rsv = bf.res_mop(xu_o, xv_o, pu, pv)
        fns_o = self.fns(bf.owned_shape)
        bms_dev = [fns_o.mop_select(r3u[b], r3v[b], rsu[b], rsv[b])
                   for b in range(B)]
        bms_j = jnp.stack(bms_dev)
        bms = np.asarray(bms_j)
        (bm_p,), _ = _pad_pow2([bms_j])
        res_u, res_v = bf.assemble(r3u, r3v, rsu, rsv, bm_p)
        return xu_e[:B], xv_e[:B], ll_e[:B], res_u[:B], res_v[:B], bms

    def decode_units(self, bf: BatchFns, res_u, res_v, bms):
        """Decode-sim of a unit batch: one batched cumsum when no unit
        contains an SL frame (exact integers), else the shared per-unit
        parallel decode."""
        if not bms[:, 1:].any():
            (ru_p, rv_p), n = _pad_pow2([res_u, res_v])
            xu, xv = bf.decode_cumsum(ru_p, rv_p)
            return xu[:n], xv[:n]
        p = self.plan
        xus, xvs = [], []
        for b in range(len(bms)):
            xu, xv = _decode_fields_parallel(
                res_u[b], res_v[b], bms[b], p.scale, p.xi_unit, p.block,
                self.stepper)
            xus.append(xu)
            xvs.append(xv)
        return jnp.stack(xus), jnp.stack(xvs)


def executor_from_header(header: dict, backend: Optional[str] = None
                         ) -> PlanExecutor:
    return PlanExecutor(plan_from_header(header, backend))


# ----------------------------------------------------------------------
# full-field drivers (quantize -> predict -> verify-fixpoint)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class FieldEncode:
    """compress_field result: streams + masks + verify accounting."""

    res_u: object
    res_v: object
    bm: object
    lossless: object
    rounds: int
    bad_counts: list


class _ScreenedCtx:
    """Fused verify-loop state: original predicates (host copies fetched
    lazily) + the previous round's forced set (incremental rechecks)."""

    def __init__(self, slice0, slab0):
        self._dev = (slice0, slab0)
        self._np = None
        self.prev_extra = None

    def preds_np(self):
        if self._np is None:
            self._np = (np.asarray(self._dev[0]), np.asarray(self._dev[1]))
        return self._np


def _encode_field(ex: PlanExecutor, variant, ufp_j, vfp_j, eb_vertex,
                  lossless_extra, shape):
    """Quantize + predict stages on the full field -> (res_u, res_v,
    bm, lossless)."""
    p = ex.plan
    T, H, W = shape
    if variant == "legacy":
        xu, xv, lossless = legacy_quantize(
            ufp_j, vfp_j, eb_vertex, p.xi_unit, p.n_levels, lossless_extra)
        res_u, res_v, bm = legacy_residuals(
            xu, xv, p.scale, p.xi_unit, p.predictor, p.block,
            p.cfl_x, p.cfl_y, p.d_max, p.n_max)
        return res_u, res_v, bm, lossless
    fns = ex.fns(shape)
    nbi, nbj = fns.nb
    if p.predictor == "lorenzo":
        res_u, res_v, lossless = fns.lorenzo_stage(
            ufp_j, vfp_j, eb_vertex, lossless_extra, p.xi_unit)
        bm = np.zeros((T, nbi, nbj), dtype=bool)
        return res_u, res_v, bm, lossless
    xu, xv, k, lossless = fns.quant_stage(
        ufp_j, vfp_j, eb_vertex, lossless_extra, p.xi_unit)
    pu, pv = backend_mod.sl_predictions(xu, xv, ex.g2f, ex.stepper)
    if p.predictor == "sl":
        res_u, res_v = fns.sl_stage(xu, xv, pu, pv)
        bm = np.ones((T, nbi, nbj), dtype=bool)
        bm[0] = False
        return res_u, res_v, bm, lossless
    res_u, res_v, bm_dev = fns.mop_stage(
        ufp_j, vfp_j, k, lossless, xu, xv, pu, pv, p.xi_unit)
    return res_u, res_v, np.asarray(bm_dev), lossless


def _verify_screened(ex, ctx: _ScreenedCtx, shape, ufp_j, vfp_j, u_j, v_j,
                     xu_d, xv_d, lossless, lossless_extra,
                     eb_bound=None):
    """Fused verify round: device-resident pointwise check + screened /
    incremental face re-verification (DESIGN.md #3.5).

    ``eb_bound``: per-vertex absolute base bounds (adaptive policy);
    None keeps the plan's scalar -- the exact pre-policy trace."""
    p = ex.plan
    fns = ex.fns(shape)
    forced, n_pt, ur_fp, vr_fp = fns.check_pt(
        xu_d, xv_d, lossless, lossless_extra, u_j, v_j,
        p.scale, p.xi_unit,
        p.eb_abs if eb_bound is None else jnp.asarray(eb_bound))
    n_bad = int(n_pt)
    delta = None if ctx.prev_extra is None else np.asarray(
        lossless_extra ^ ctx.prev_extra)
    add, nf = check_faces(fns, shape, ufp_j, vfp_j, ur_fp, vr_fp,
                          ctx.preds_np(), delta)
    n_bad += nf
    if add is not None:
        forced = forced | jnp.asarray(add)
    return forced, n_bad


def _verify_full(ex, ctx: _ScreenedCtx, shape, u, v, xu_d, xv_d, lossless,
                 lossless_extra, eb_bound=None):
    """Legacy verify round: full predicate re-evaluation + host
    transfers (seed pipeline, kept for A/B benchmarking)."""
    p = ex.plan
    T, H, W = shape
    slice_pred0, slab_pred0 = ctx._dev
    u_rec, v_rec = _reconstruct(
        xu_d, xv_d, p.scale, p.xi_unit, lossless,
        jnp.asarray(u), jnp.asarray(v))
    ur_fp, vr_fp = fixedpoint.refix(np.asarray(u_rec), np.asarray(v_rec),
                                    p.scale)
    slice_pred1, slab_pred1 = _predicates_jit(
        jnp.asarray(ur_fp), jnp.asarray(vr_fp))
    bad_slice = np.asarray(slice_pred0 ^ slice_pred1)
    bad_slab = np.asarray(slab_pred0 ^ slab_pred1)
    err = np.maximum(
        np.abs(np.asarray(u_rec, dtype=np.float64) - u.astype(np.float64)),
        np.abs(np.asarray(v_rec, dtype=np.float64) - v.astype(np.float64)),
    )
    bad_pt = err > (p.eb_abs if eb_bound is None
                    else np.asarray(eb_bound))
    n_bad = int(bad_slice.sum()) + int(bad_slab.sum()) + int(bad_pt.sum())
    extra = np.asarray(lossless_extra).copy()
    extra |= bad_pt
    extra |= _faces_to_vertex_mask(bad_slice, bad_slab, T, H, W)
    return jnp.asarray(extra), n_bad


def compress_field(ex: PlanExecutor, u, v, ufp, vfp,
                   eb_cap=None, eb_bound=None) -> FieldEncode:
    """Full-field quantize -> predict -> verify-fixpoint driver; the
    monolithic pipelines are this single-unit loop (the tiled fixpoint
    in core/tiling.py runs the same stages per unit).

    ``eb_cap`` / ``eb_bound``: per-vertex int64 caps and float64
    absolute bounds of an adaptive eb policy; both None on the uniform
    path, which then runs the exact pre-policy traces."""
    p = ex.plan
    T, H, W = u.shape
    shape = (T, H, W)
    ufp_j = jnp.asarray(ufp)
    vfp_j = jnp.asarray(vfp)
    u_j = jnp.asarray(u)
    v_j = jnp.asarray(v)
    # eb derivation evaluates every face's SoS predicate along the way
    # (the crossed-face zeroing); reuse those instead of a second full
    # predicate pass over the original field (the seed paid it twice)
    with obs.span("pipeline.derive_eb", shape=list(shape)):
        eb_vertex, slice_pred0, slab_pred0 = ex.derive_eb(ufp_j, vfp_j)
        if eb_cap is not None:
            # adaptive policy: clamp the derived bounds DOWN to the
            # per-vertex caps -- min composes with the derivation's own
            # tau clamp, so ordering cannot matter
            eb_vertex = jnp.minimum(eb_vertex, jnp.asarray(eb_cap))
        obs.device_sync(eb_vertex)
    lossless_extra = jnp.zeros(shape, dtype=bool)
    if p.tau < 1 or p.n_usable < 1:
        lossless_extra = jnp.ones(shape, dtype=bool)

    enc_variant = ex._impl["encode"]
    verify_variant = ex._impl["verify"]
    ctx = _ScreenedCtx(slice_pred0, slab_pred0)
    rounds = 0
    bad_counts = []
    while True:
        with obs.span("pipeline.quantize_predict", round=rounds):
            res_u, res_v, bm, lossless = _encode_field(
                ex, enc_variant, ufp_j, vfp_j, eb_vertex, lossless_extra,
                shape)
            obs.device_sync(res_u)
        if not p.verify:
            break
        # simulate the exact decode (same code as decompress)
        with obs.span("pipeline.verify_round", round=rounds) as _vs:
            xu_d, xv_d = ex.decode_fields(res_u, res_v, bm)
            if verify_variant == "full":
                new_extra, n_bad = _verify_full(
                    ex, ctx, shape, u, v, xu_d, xv_d, lossless,
                    lossless_extra, eb_bound=eb_bound)
            else:
                new_extra, n_bad = _verify_screened(
                    ex, ctx, shape, ufp_j, vfp_j, u_j, v_j, xu_d, xv_d,
                    lossless, lossless_extra, eb_bound=eb_bound)
            _vs.set(n_bad=n_bad)
        bad_counts.append(n_bad)
        if n_bad == 0 or rounds >= p.max_rounds:
            break
        ctx.prev_extra = lossless_extra
        lossless_extra = new_extra
        rounds += 1
    obs.count("pipeline.verify_rounds", rounds)
    return FieldEncode(res_u, res_v, bm, lossless, rounds, bad_counts)


# ----------------------------------------------------------------------
# symbolize + pack + stats (shared assembly, all paths)
# ----------------------------------------------------------------------

def field_header(plan: PipelinePlan, shape) -> dict:
    T, H, W = shape
    header = {
        # the version only moves when the policy does: uniform
        # containers are byte-identical to pre-policy output
        "version": (FORMAT_VERSION_ADAPTIVE if plan.eb_policy
                    else FORMAT_VERSION),
        "pipeline": plan.name,
        "predictor": plan.predictor,
    }
    if plan.eb_policy:
        header["eb_policy"] = plan.eb_policy
    if plan.name != "legacy":
        header["sl_backend"] = plan.backend
    header.update({
        "shape": [int(T), int(H), int(W)],
        "scale": float(plan.scale),
        "xi_unit": int(plan.xi_unit),
        "block": int(plan.block),
        "cfl_x": float(plan.cfl_x),
        "cfl_y": float(plan.cfl_y),
        "d_max": float(plan.d_max),
        "n_max": int(plan.n_max),
        "eb_abs": float(plan.eb_abs),
    })
    return header


def pack_field(ex: PlanExecutor, u, v, enc: FieldEncode, t0: float):
    """Symbolize + pack + stats for a full-field encode."""
    p = ex.plan
    lossless_np = np.asarray(enc.lossless)
    bm_np = np.asarray(enc.bm)
    with obs.span("pipeline.symbolize", codec=ex.codec):
        sections = ex.encode_sections(
            enc.res_u, enc.res_v, lossless_np, u[lossless_np],
            v[lossless_np], bm_np)
    with obs.span("pipeline.pack") as _ps:
        blob = encode.pack(field_header(p, u.shape), sections,
                           p.zstd_level)
        _ps.set(bytes=len(blob))
    t1 = time.perf_counter()
    orig_bytes = u.nbytes + v.nbytes
    stats = {
        "orig_bytes": orig_bytes,
        "comp_bytes": len(blob),
        "ratio": orig_bytes / max(len(blob), 1),
        "lossless_frac": float(lossless_np.mean()),
        "sl_block_frac": float(bm_np.mean()),
        "verify_rounds": enc.rounds,
        "verify_bad_counts": enc.bad_counts,
        "eb_abs": p.eb_abs,
        "scale": p.scale,
        "tau": p.tau,
        "xi_unit": p.xi_unit,
        "seconds": t1 - t0,
        "backend": p.backend,
        "pipeline": p.name,
    }
    return blob, stats


def decode_field_blob(ex: PlanExecutor, header: dict, sections: dict):
    T, H, W = header["shape"]
    return ex.decode_payload((T, H, W), sections)
