"""Critical-point trajectory extraction and false-case counting.

Mirrors the FTK-style procedure the paper uses for evaluation
(Sec. VII-G): every crossed face of the space-time tet mesh yields a
crossing node; within each tetrahedron the (0 or 2, under SoS) crossed
faces are joined by a zero-set segment; segments glue across tets sharing
a crossed face.  Union-find over crossing nodes gives the track set.
Runs host-side (numpy + python union-find over the sparse crossings).
"""
from __future__ import annotations

import numpy as np

from . import fixedpoint, grid, sos


def _frame_chunk(n_faces: int, budget: int = 1 << 22) -> int:
    """Frames per batch so transient gathers stay ~tens of MB."""
    return max(1, budget // max(n_faces, 1))


def face_predicate_tables(ufp, vfp):
    """All face predicates, numpy, organized per slab.

    Returns dict with 'slice' (T, Fs) and 'slab' (T-1, Fb) bool arrays.
    (Same face enumeration as ebound.all_face_predicates, but computed
    with numpy so host tooling does not need jax.)  Faces are gathered
    for a batch of frames at once -- the per-frame Python loop the seed
    used dominated e2e test time -- chunked so the transient (C, F, 3)
    gathers stay bounded on large fields.
    """
    T, H, W = ufp.shape
    HW = H * W
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)
    slice_tab = grid.slab_faces(H, W)["slice0"].astype(np.int64)
    sf = grid.slab_faces(H, W)
    slab_tab = np.concatenate([sf["side"], sf["internal"]], 0).astype(np.int64)
    toff = np.arange(T, dtype=np.int64)[:, None, None] * HW

    slice_pred = np.zeros((T, len(slice_tab)), dtype=bool)
    step = _frame_chunk(len(slice_tab))
    for lo in range(0, T, step):
        hi = min(lo + step, T)
        fu = u2[lo:hi, :][:, slice_tab]              # (C, Fs, 3)
        fv = v2[lo:hi, :][:, slice_tab]
        idx = slice_tab[None] + toff[lo:hi]
        slice_pred[lo:hi] = sos.face_crossed_vals(np, fu, fv, idx)

    slab_pred = np.zeros((T - 1, len(slab_tab)), dtype=bool)
    step = _frame_chunk(len(slab_tab))
    for lo in range(0, T - 1, step):
        hi = min(lo + step, T - 1)
        pair_u = np.concatenate([u2[lo:hi], u2[lo + 1 : hi + 1]], axis=1)
        pair_v = np.concatenate([v2[lo:hi], v2[lo + 1 : hi + 1]], axis=1)
        fu = pair_u[:, slab_tab]                     # (C, Fb, 3)
        fv = pair_v[:, slab_tab]
        idx = slab_tab[None] + toff[lo:hi]
        slab_pred[lo:hi] = sos.face_crossed_vals(np, fu, fv, idx)
    return {"slice": slice_pred, "slab": slab_pred}


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        p = self.parent.setdefault(x, x)
        while p != self.parent[p]:
            self.parent[p] = self.parent[self.parent[p]]
            p = self.parent[p]
        self.parent[x] = p
        return p

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb


def _face_key(verts):
    """Canonical global face key (verts already sorted ascending)."""
    return (int(verts[0]), int(verts[1]), int(verts[2]))


def extract_tracks(ufp, vfp):
    """Track statistics of the zero set.

    Returns dict: n_tracks, n_crossings, crossings per kind.
    """
    T, H, W = ufp.shape
    HW = H * W
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)
    tets = grid.slab_tets(H, W).astype(np.int64)  # (Ntet, 4) local 2-plane ids
    tet_faces = tets[:, grid.TET_FACES]           # (Ntet, 4, 3)

    uf = _UnionFind()
    crossed_total = 0

    # predicates for a batch of slabs at once (vectorized); the python
    # union-find below only walks the sparse active tets
    step = _frame_chunk(4 * len(tet_faces))
    for lo in range(0, T - 1, step):
        hi = min(lo + step, T - 1)
        pair_u = np.concatenate([u2[lo:hi], u2[lo + 1 : hi + 1]], axis=1)
        pair_v = np.concatenate([v2[lo:hi], v2[lo + 1 : hi + 1]], axis=1)
        fu = pair_u[:, tet_faces]                 # (C, Ntet, 4, 3)
        fv = pair_v[:, tet_faces]
        idx = tet_faces[None] \
            + (np.arange(lo, hi, dtype=np.int64) * HW)[:, None, None, None]
        crossed = sos.face_crossed_vals(np, fu, fv, idx)  # (C, Ntet, 4)
        crossed_total += int(crossed.sum())
        n_crossed = crossed.sum(axis=2)
        # Under SoS each tet has 0 or 2 crossed faces (Lemma 1).
        for ci, ti in zip(*np.nonzero(n_crossed == 2)):
            fa, fb = np.nonzero(crossed[ci, ti])[0]
            ka = _face_key(idx[ci, ti, fa])
            kb = _face_key(idx[ci, ti, fb])
            uf.union(ka, kb)

    roots = {uf.find(k) for k in uf.parent}
    return {
        "n_tracks": len(roots),
        "n_crossing_nodes": len(uf.parent),
        "n_crossed_incidences": crossed_total,
    }


def false_cases(u_orig, v_orig, u_rec, v_rec, scale):
    """FC_t / FC_s / per-time CP counts, per the paper's metrics."""
    uo, vo = fixedpoint.refix(u_orig, v_orig, scale)
    ur, vr = fixedpoint.refix(u_rec, v_rec, scale)
    p0 = face_predicate_tables(uo, vo)
    p1 = face_predicate_tables(ur, vr)
    fc_t = int((p0["slice"] ^ p1["slice"]).sum())
    fc_s = int((p0["slab"] ^ p1["slab"]).sum())
    return {
        "FC_t": fc_t,
        "FC_s": fc_s,
        "CP_t_orig": int(p0["slice"].sum()),
        "CP_t_rec": int(p1["slice"].sum()),
        "CP_slab_orig": int(p0["slab"].sum()),
        "CP_slab_rec": int(p1["slab"].sum()),
    }
