"""Critical-point trajectory extraction and false-case counting.

Mirrors the FTK-style procedure the paper uses for evaluation
(Sec. VII-G): every crossed face of the space-time tet mesh yields a
crossing node; within each tetrahedron the (0 or 2, under SoS) crossed
faces are joined by a zero-set segment; segments glue across tets sharing
a crossed face.  Union-find over crossing nodes gives the track set.
Runs host-side (numpy + python union-find over the sparse crossings).
"""
from __future__ import annotations

import numpy as np

from . import fixedpoint, grid, sos


def _frame_chunk(n_faces: int, budget: int = 1 << 22) -> int:
    """Frames per batch so transient gathers stay ~tens of MB."""
    return max(1, budget // max(n_faces, 1))


def face_predicate_tables(ufp, vfp):
    """All face predicates, numpy, organized per slab.

    Returns dict with 'slice' (T, Fs) and 'slab' (T-1, Fb) bool arrays.
    (Same face enumeration as ebound.all_face_predicates, but computed
    with numpy so host tooling does not need jax.)  Faces are gathered
    for a batch of frames at once -- the per-frame Python loop the seed
    used dominated e2e test time -- chunked so the transient (C, F, 3)
    gathers stay bounded on large fields.
    """
    T, H, W = ufp.shape
    HW = H * W
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)
    slice_tab = grid.slab_faces(H, W)["slice0"].astype(np.int64)
    sf = grid.slab_faces(H, W)
    slab_tab = np.concatenate([sf["side"], sf["internal"]], 0).astype(np.int64)
    toff = np.arange(T, dtype=np.int64)[:, None, None] * HW

    slice_pred = np.zeros((T, len(slice_tab)), dtype=bool)
    step = _frame_chunk(len(slice_tab))
    for lo in range(0, T, step):
        hi = min(lo + step, T)
        fu = u2[lo:hi, :][:, slice_tab]              # (C, Fs, 3)
        fv = v2[lo:hi, :][:, slice_tab]
        idx = slice_tab[None] + toff[lo:hi]
        slice_pred[lo:hi] = sos.face_crossed_vals(np, fu, fv, idx)

    slab_pred = np.zeros((T - 1, len(slab_tab)), dtype=bool)
    step = _frame_chunk(len(slab_tab))
    for lo in range(0, T - 1, step):
        hi = min(lo + step, T - 1)
        pair_u = np.concatenate([u2[lo:hi], u2[lo + 1 : hi + 1]], axis=1)
        pair_v = np.concatenate([v2[lo:hi], v2[lo + 1 : hi + 1]], axis=1)
        fu = pair_u[:, slab_tab]                     # (C, Fb, 3)
        fv = pair_v[:, slab_tab]
        idx = slab_tab[None] + toff[lo:hi]
        slab_pred[lo:hi] = sos.face_crossed_vals(np, fu, fv, idx)
    return {"slice": slice_pred, "slab": slab_pred}


class Lemma1ViolationError(RuntimeError):
    """A tet with a crossed-face count outside {0, 2}.

    Under SoS this is impossible (paper Lemma 1): the zero set enters
    and leaves every tetrahedron through exactly two faces or misses it
    entirely.  Hitting this means a predicate-consistency bug upstream
    (e.g. faces of one tet evaluated with inconsistent vertex ids), so
    extraction raises instead of silently dropping the crossing.
    """


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        p = self.parent.setdefault(x, x)
        while p != self.parent[p]:
            self.parent[p] = self.parent[self.parent[p]]
            p = self.parent[p]
        self.parent[x] = p
        return p

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb


def check_lemma1(crossed, t_lo: int = 0):
    """Raise Lemma1ViolationError unless every tet has 0 or 2 crossings.

    crossed: (C, Ntet, 4) bool for slabs [t_lo, t_lo + C).
    """
    n_crossed = crossed.sum(axis=2)
    bad = (n_crossed != 0) & (n_crossed != 2)
    if bad.any():
        ci, ti = np.nonzero(bad)
        raise Lemma1ViolationError(
            f"{bad.sum()} tets with crossed-face count not in {{0, 2}} "
            f"(first: slab {t_lo + int(ci[0])}, tet {int(ti[0])}, "
            f"count {int(n_crossed[ci[0], ti[0]])}); SoS predicates are "
            f"inconsistent upstream")


def tet_crossings(tables, shape, t_lo: int, t_hi: int):
    """Crossed-state of every tet face of slabs [t_lo, t_hi), as pure
    gathers from precomputed face-predicate tables (no SoS work).

    Returns crossed (C, Ntet, 4) bool (grid.tet_face_fids gives the
    global ids).  Raises Lemma1ViolationError on degenerate tets.
    """
    T, H, W = shape
    family, index = grid.tet_face_map(H, W)
    sl = tables["slice"]
    sb = tables["slab"]
    idx_slice = np.where(family == 2, 0, index)        # keep gathers in-range
    idx_slab = np.where(family == 2, index, 0)
    c_bot = sl[t_lo:t_hi][:, idx_slice]                # (C, Ntet, 4)
    c_top = sl[t_lo + 1 : t_hi + 1][:, idx_slice]
    c_slab = sb[t_lo:t_hi][:, idx_slab]
    crossed = np.where(family == 0, c_bot,
                       np.where(family == 1, c_top, c_slab))
    check_lemma1(crossed, t_lo)
    return crossed


def segment_edges(crossed, t_lo, shape):
    """Global-face-id segment edges of slabs [t_lo, t_lo + C).

    Each tet with two crossed faces contributes one zero-set segment
    joining them; the edge is the (fid_a, fid_b) pair.  Returns (E, 2)
    int64 (unsorted pairs in tet order).
    """
    T, H, W = shape
    family, index = grid.tet_face_map(H, W)
    ci, ti = np.nonzero(crossed.sum(axis=2) == 2)
    if len(ci) == 0:
        return np.empty((0, 2), dtype=np.int64)
    rows = crossed[ci, ti]                     # (M, 4), exactly 2 True
    _, slots = np.nonzero(rows)
    slots = slots.reshape(-1, 2)
    fids = grid.tet_face_fids(
        family[ti[:, None], slots], index[ti[:, None], slots],
        (t_lo + ci)[:, None], H, W)
    return fids


def extract_tracks(ufp, vfp, tables=None):
    """Track statistics of the zero set (host union-find reference).

    Returns dict: n_tracks, n_crossing_nodes, n_crossed_incidences.
    ``tables`` optionally passes precomputed face_predicate_tables so
    callers evaluating several metrics share one predicate pass.  The
    union-find here is the host reference implementation; the
    device-resident geometric extraction lives in repro.analysis.
    """
    T, H, W = ufp.shape
    shape = (T, H, W)
    if tables is None:
        tables = face_predicate_tables(ufp, vfp)

    uf = _UnionFind()
    crossed_total = 0
    seen = set()
    family, _ = grid.tet_face_map(H, W)
    step = _frame_chunk(4 * family.shape[0])
    for lo in range(0, T - 1, step):
        hi = min(lo + step, T - 1)
        crossed = tet_crossings(tables, shape, lo, hi)
        crossed_total += int(crossed.sum())
        edges = segment_edges(crossed, lo, shape)
        for a, b in edges:
            uf.union(int(a), int(b))
        seen.update(edges.reshape(-1).tolist())
    n_nodes = len(seen)
    roots = {uf.find(k) for k in uf.parent}
    return {
        "n_tracks": len(roots),
        "n_crossing_nodes": n_nodes,
        "n_crossed_incidences": crossed_total,
    }


def false_cases_from_tables(p0, p1):
    """FC_t / FC_s / CP counts from precomputed predicate tables."""
    fc_t = int((p0["slice"] ^ p1["slice"]).sum())
    fc_s = int((p0["slab"] ^ p1["slab"]).sum())
    return {
        "FC_t": fc_t,
        "FC_s": fc_s,
        "CP_t_orig": int(p0["slice"].sum()),
        "CP_t_rec": int(p1["slice"].sum()),
        "CP_slab_orig": int(p0["slab"].sum()),
        "CP_slab_rec": int(p1["slab"].sum()),
    }


def false_cases(u_orig, v_orig, u_rec, v_rec, scale):
    """FC_t / FC_s / per-time CP counts, per the paper's metrics."""
    uo, vo = fixedpoint.refix(u_orig, v_orig, scale)
    ur, vr = fixedpoint.refix(u_rec, v_rec, scale)
    p0 = face_predicate_tables(uo, vo)
    p1 = face_predicate_tables(ur, vr)
    return false_cases_from_tables(p0, p1)
