"""Critical-point trajectory extraction and false-case counting.

Mirrors the FTK-style procedure the paper uses for evaluation
(Sec. VII-G): every crossed face of the space-time tet mesh yields a
crossing node; within each tetrahedron the (0 or 2, under SoS) crossed
faces are joined by a zero-set segment; segments glue across tets sharing
a crossed face.  Union-find over crossing nodes gives the track set.
Runs host-side (numpy + python union-find over the sparse crossings).
"""
from __future__ import annotations

import numpy as np

from . import fixedpoint, grid, sos


def face_predicate_tables(ufp, vfp):
    """All face predicates, numpy, organized per slab.

    Returns dict with 'slice' (T, Fs) and 'slab' (T-1, Fb) bool arrays.
    (Same face enumeration as ebound.all_face_predicates, but computed
    with numpy so host tooling does not need jax.)
    """
    T, H, W = ufp.shape
    HW = H * W
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)
    slice_tab = grid.slab_faces(H, W)["slice0"].astype(np.int64)
    sf = grid.slab_faces(H, W)
    slab_tab = np.concatenate([sf["side"], sf["internal"]], 0).astype(np.int64)

    slice_pred = np.zeros((T, len(slice_tab)), dtype=bool)
    for t in range(T):
        fu = u2[t][slice_tab]
        fv = v2[t][slice_tab]
        idx = slice_tab + t * HW
        slice_pred[t] = sos.face_crossed_vals(np, fu, fv, idx)

    slab_pred = np.zeros((T - 1, len(slab_tab)), dtype=bool)
    for t in range(T - 1):
        vals_u = np.concatenate([u2[t], u2[t + 1]])
        vals_v = np.concatenate([v2[t], v2[t + 1]])
        fu = vals_u[slab_tab]
        fv = vals_v[slab_tab]
        idx = slab_tab + t * HW
        slab_pred[t] = sos.face_crossed_vals(np, fu, fv, idx)
    return {"slice": slice_pred, "slab": slab_pred}


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        p = self.parent.setdefault(x, x)
        while p != self.parent[p]:
            self.parent[p] = self.parent[self.parent[p]]
            p = self.parent[p]
        self.parent[x] = p
        return p

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb


def _face_key(verts):
    """Canonical global face key (verts already sorted ascending)."""
    return (int(verts[0]), int(verts[1]), int(verts[2]))


def extract_tracks(ufp, vfp):
    """Track statistics of the zero set.

    Returns dict: n_tracks, n_crossings, crossings per kind.
    """
    T, H, W = ufp.shape
    HW = H * W
    u2 = ufp.reshape(T, HW)
    v2 = vfp.reshape(T, HW)
    tets = grid.slab_tets(H, W).astype(np.int64)  # (Ntet, 4) local 2-plane ids
    tet_faces = tets[:, grid.TET_FACES]           # (Ntet, 4, 3)

    uf = _UnionFind()
    crossed_total = 0

    for t in range(T - 1):
        vals_u = np.concatenate([u2[t], u2[t + 1]])
        vals_v = np.concatenate([v2[t], v2[t + 1]])
        fu = vals_u[tet_faces]                    # (Ntet, 4, 3)
        fv = vals_v[tet_faces]
        idx = tet_faces + t * HW
        crossed = sos.face_crossed_vals(np, fu, fv, idx)  # (Ntet, 4)
        n_crossed = crossed.sum(axis=1)
        # Under SoS each tet has 0 or 2 crossed faces (Lemma 1).
        active = np.nonzero(n_crossed == 2)[0]
        crossed_total += int(crossed.sum())
        for ti in active:
            fa, fb = np.nonzero(crossed[ti])[0]
            ka = _face_key(idx[ti, fa])
            kb = _face_key(idx[ti, fb])
            uf.union(ka, kb)

    roots = {uf.find(k) for k in uf.parent}
    return {
        "n_tracks": len(roots),
        "n_crossing_nodes": len(uf.parent),
        "n_crossed_incidences": crossed_total,
    }


def false_cases(u_orig, v_orig, u_rec, v_rec, scale):
    """FC_t / FC_s / per-time CP counts, per the paper's metrics."""
    uo, vo = fixedpoint.refix(u_orig, v_orig, scale)
    ur, vr = fixedpoint.refix(u_rec, v_rec, scale)
    p0 = face_predicate_tables(uo, vo)
    p1 = face_predicate_tables(ur, vr)
    fc_t = int((p0["slice"] ^ p1["slice"]).sum())
    fc_s = int((p0["slab"] ^ p1["slab"]).sum())
    return {
        "FC_t": fc_t,
        "FC_s": fc_s,
        "CP_t_orig": int(p0["slice"].sum()),
        "CP_t_rec": int(p1["slice"].sum()),
        "CP_slab_orig": int(p0["slab"].sum()),
        "CP_slab_rec": int(p1["slab"].sum()),
    }
