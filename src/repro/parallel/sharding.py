"""Logical-axis sharding rules for params, optimizer state, activations.

Mesh axes:
  * ``model`` (tp): tensor parallel -- attention heads / ffn hidden /
    vocab / experts (EP).
  * ``data``  (dp + fsdp): batch sharding *and* the FSDP dimension of
    every weight matrix.
  * ``pod``   (multi-pod only): pure data parallelism across pods;
    gradients cross pods once per step (optionally compressed --
    train/grad_compress.py).  FSDP stays *within* a pod so parameter
    all-gathers never cross the inter-pod links.

Model code never names mesh axes: it calls ``act(x, kind)`` which applies
``with_sharding_constraint`` when rules are active (dry-run/production)
and is a no-op otherwise (CPU unit tests).

Param specs are assigned by leaf-path pattern matching; stacked-layer
leading dims are unsharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    dp: Tuple[str, ...] = ("data",)    # batch axes (includes 'pod' if present)
    fsdp: Optional[str] = "data"       # weight-shard axis (within-pod)
    tp: Optional[str] = "model"
    tp_size: int = 1
    dp_size: int = 1


_RULES: Optional[ShardingRules] = None


def rules_for_mesh(mesh: Mesh) -> ShardingRules:
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp = dp or (names[0],)
    tp = "model" if "model" in names else None
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    return ShardingRules(
        dp=dp,
        fsdp="data" if "data" in names else None,
        tp=tp,
        tp_size=mesh.shape[tp] if tp else 1,
        dp_size=dp_size,
    )


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    global _RULES
    prev = _RULES
    _RULES = rules
    try:
        yield
    finally:
        _RULES = prev


def current_rules() -> Optional[ShardingRules]:
    return _RULES


# ------------------------------------------------------------- activations

def act(x, kind: str):
    """Sharding constraint on an activation; no-op without active rules."""
    r = _RULES
    if r is None:
        return x
    spec = _ACT_SPECS[kind](r, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def _cache_spec(r, shape):
    # (L, B, S, Hkv, Dh): heads over tp when divisible; otherwise shard
    # the HEAD DIM (contracting-dim TP -- partial logits + all-reduce).
    # Sharding S instead would make the decode dynamic-update-slice cross
    # shards and force a full cache rematerialization (perf iteration H4).
    if r.tp and shape[3] % r.tp_size == 0:
        return P(None, r.dp, None, r.tp, None)
    if r.tp and shape[4] % r.tp_size == 0:
        return P(None, r.dp, None, None, r.tp)
    return P(None, r.dp, None, None, None)


def _cache_seqshard_spec(r, shape):
    axes = tuple(a for a in (r.fsdp, r.tp) if a)
    return P(None, None, axes, None, None)


def _state_spec(r, shape):
    # recurrent state (L, B, H/feat, ...): feature over tp when divisible
    tp = r.tp if (r.tp and shape[2] % r.tp_size == 0) else None
    return P(None, r.dp, tp, *([None] * (len(shape) - 3)))


_ACT_SPECS = {
    # (B, S, D) replicated D between blocks
    "hidden": lambda r, s: P(r.dp, *([None] * (len(s) - 1))),
    # (B, S, V) vocab-sharded logits
    "logits": lambda r, s: P(r.dp, *([None] * (len(s) - 2)), r.tp),
    # (B, S, H*, ...) head-sharded tensor
    "heads": lambda r, s: P(r.dp, None, r.tp, *([None] * (len(s) - 3))),
    # (B, S) tokens
    "tokens": lambda r, s: P(r.dp, *([None] * (len(s) - 1))),
    "cache": _cache_spec,
    "cache_seqshard": _cache_seqshard_spec,
    "state": _state_spec,
}


# ------------------------------------------------------------- params

# (pattern, spec builder) -- first match wins; `l` = stacked-layer prefix
def _pp(*names):
    return re.compile("|".join(names))


_PARAM_RULES = [
    # embeddings
    (_pp(r"embedding$"), lambda r: P(r.tp, r.fsdp)),
    (_pp(r"lm_head$"), lambda r: P(r.fsdp, r.tp)),
    # attention
    (_pp(r"\bwq$", r"\bwk$", r"\bwv$"), lambda r: P(r.fsdp, r.tp)),
    (_pp(r"\bwo$"), lambda r: P(r.tp, r.fsdp)),
    (_pp(r"\bbq$", r"\bbk$", r"\bbv$"), lambda r: P(r.tp)),
    # mlp
    (_pp(r"w_gate$", r"w_up$", r"c_wk$", r"c_wr$", r"\bwr$", r"\bwg$"),
     lambda r: P(r.fsdp, r.tp)),
    (_pp(r"w_down$", r"c_wv$"), lambda r: P(r.tp, r.fsdp)),
    (_pp(r"b_up$"), lambda r: P(r.tp)),
    # moe (expert-parallel leading dim)
    (_pp(r"router$"), lambda r: P(r.fsdp, None)),
    (_pp(r"experts?/w_gate$",), lambda r: P(r.tp, r.fsdp, None)),
    # mamba
    (_pp(r"in_proj$", r"dt_proj$"), lambda r: P(r.fsdp, r.tp)),
    (_pp(r"out_proj$"), lambda r: P(r.tp, r.fsdp)),
    (_pp(r"x_proj$", r"a_log$"), lambda r: P(r.tp, None)),
    (_pp(r"conv_w$"), lambda r: P(None, r.tp)),
    (_pp(r"conv_b$", r"dt_bias$", r"d_skip$"), lambda r: P(r.tp)),
    # rwkv decay lora
    (_pp(r"w_lora_a$"), lambda r: P(r.fsdp, None)),
    (_pp(r"w_lora_b$"), lambda r: P(None, r.tp)),
]

_MOE_EXPERT = re.compile(r"(^|/)(w_gate|w_up|w_down)$")


def _leaf_spec(path: str, ndim: int, n_stack: int, r: ShardingRules) -> P:
    # expert tensors are 3D (E, ., .): match before generic mlp rules
    if ndim - n_stack == 3 and _MOE_EXPERT.search(path):
        if path.endswith("w_down"):
            base = (r.tp, None, r.fsdp)
        else:
            base = (r.tp, r.fsdp, None)
        return P(*([None] * n_stack), *base)
    for pat, builder in _PARAM_RULES:
        if pat.search(path):
            base = builder(r)
            base_t = tuple(base)
            # pad/trim to actual rank after the stacked prefix
            rank = ndim - n_stack
            if len(base_t) > rank:
                base_t = base_t[:rank]
            base_t = base_t + (None,) * (rank - len(base_t))
            return P(*([None] * n_stack), *base_t)
    return P()  # replicate (norm scales, small vectors)


def _path_str(path) -> str:
    parts = []
    for pk in path:
        if hasattr(pk, "key"):
            parts.append(str(pk.key))
        elif hasattr(pk, "idx"):
            parts.append(str(pk.idx))
        else:
            parts.append(str(pk))
    return "/".join(parts)


def param_specs(params_shape, rules: ShardingRules, stacked_prefixes=("blocks",
                "enc_blocks", "dec_blocks", "superblocks")):
    """Pytree of PartitionSpec matching `params_shape` (shapes/arrays)."""

    def spec(path, leaf):
        ps = _path_str(path)
        n_stack = 1 if any(f"{sp}/" in ps or ps.startswith(f"{sp}/")
                           for sp in stacked_prefixes) else 0
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        s = _leaf_spec(ps, nd, n_stack, rules)
        # drop specs on dims that do not divide the mesh cleanly enough to
        # matter is left to GSPMD (it pads); nothing to do here.
        return s

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(params_shape, mesh: Mesh):
    rules = rules_for_mesh(mesh)
    specs = param_specs(params_shape, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# --------------------------------------------------------- tile units
# Data parallelism for the tiled compression pipeline (core/tiling.py +
# core/pipeline.py BatchFns): (tile, window) units of one batching
# signature are stacked on a leading axis and mapped with vmap,
# shard_mapped over a 1-axis "tiles" mesh so the batch splits across
# every local device.  Tiles are independent by construction (halo-exact
# eb + seam-agreed verify), so the mapping needs no collectives --
# in_specs == out_specs == P("tiles").  Every batched pipeline stage
# (eb derivation, quantize, residuals, decode cumsum, pointwise check,
# sign screen, segment extraction) routes through map_tiles*.


def _shard_map_fn():
    try:  # moved between jax versions
        from jax.experimental.shard_map import shard_map
        return shard_map
    except ImportError:
        return getattr(jax, "shard_map", None)


@functools.lru_cache(maxsize=1)
def tiles_mesh() -> Mesh:
    """1-axis mesh over every local device for tile-unit parallelism.

    Cached: the batched pipeline stages re-enter map_tiles at every jit
    trace, and mesh construction is not free."""
    return jax.make_mesh((jax.device_count(),), ("tiles",))


def map_tiles(fn, *batched):
    """Apply ``fn`` (one tile unit -> pytree) over a leading tile axis.

    Uses shard_map(vmap(fn)) over the "tiles" mesh when the batch size
    divides the local device count (it always does on one device, so CI
    exercises the sharded path); plain vmap otherwise (the ragged
    remainder still runs, just not device-parallel).
    """
    import jax.numpy as jnp

    batched = [jnp.asarray(b) for b in batched]
    n = int(batched[0].shape[0])
    vfn = jax.vmap(fn)
    shard_map = _shard_map_fn()
    if n and shard_map is not None and n % jax.device_count() == 0:
        spec = P("tiles")
        return shard_map(vfn, mesh=tiles_mesh(),
                         in_specs=spec, out_specs=spec)(*batched)
    return vfn(*batched)


# --------------------------------------------------------- host workers
# Shared host-side thread pools for the out-of-core paths: the async
# stream engine's stage threads hand work off through queues, but the
# served-read layer (analysis/query.py) fans CONCURRENT RANGE READS of
# unit frames over a pool -- reads are I/O-bound (page cache misses,
# network filesystems), so a handful of threads hides most of the
# latency without oversubscribing the host.

DEFAULT_HOST_WORKERS = 8


@functools.lru_cache(maxsize=8)
def host_pool(name: str, workers: int = DEFAULT_HOST_WORKERS):
    """Named, process-lifetime ThreadPoolExecutor for host-side I/O
    concurrency.  Cached by (name, workers): callers on a hot path
    (every track query) must not pay pool construction, and idle
    threads cost nothing."""
    from concurrent.futures import ThreadPoolExecutor

    return ThreadPoolExecutor(max_workers=workers,
                              thread_name_prefix=f"repro-{name}")


def host_map(pool, fn, items):
    """``pool.map`` with STRICT failure surfacing.

    ``Executor.map`` evaluates lazily and tears down mid-iteration on
    the first worker exception, silently abandoning later results.
    Here every item is submitted up front, every future is awaited, and
    the first exception (in submission order) re-raises on the caller's
    thread with its original type -- a worker can never fail without
    the caller seeing it.  Returns results in item order.
    """
    futures = [pool.submit(fn, it) for it in items]
    results, first_exc = [], None
    for f in futures:
        try:
            results.append(f.result())
        except BaseException as e:     # noqa: BLE001 -- re-raised below
            if first_exc is None:
                first_exc = e
            results.append(None)
    if first_exc is not None:
        raise first_exc
    return results


def map_tiles_padded(fn, *batched):
    """map_tiles that PADS a ragged batch up to a device-count multiple
    (repeating the last tile) so the shard_mapped path is always taken,
    then drops the padded rows from every output leaf.

    Used by the per-tile trajectory-segment extraction (core/tiling.py),
    whose group sizes (edge/corner tile counts) rarely divide the device
    count; ``fn`` must be row-independent (tile units are, by
    construction).  On one device this degenerates to map_tiles.
    """
    import jax.numpy as jnp

    batched = [jnp.asarray(b) for b in batched]
    n = int(batched[0].shape[0])
    d = jax.device_count()
    if n == 0 or n % d == 0:
        return map_tiles(fn, *batched)
    pad = d - n % d
    padded = [jnp.concatenate([b, jnp.repeat(b[-1:], pad, axis=0)], axis=0)
              for b in batched]
    out = map_tiles(fn, *padded)
    return jax.tree.map(lambda leaf: leaf[:n], out)
