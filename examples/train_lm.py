"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing + error-bounded gradient compression.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The model is a qwen-family decoder (12L x 512d, 50k vocab ~= 101M
params).  Gradients pass through the paper-derived eb-quantizer (int8 +
error feedback) before the optimizer -- the cross-pod compression path
of the production mesh, exercised here on CPU.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data.tokens import TokenPipelineConfig, global_batch
from repro.models.config import ModelConfig
from repro.models.transformer import build_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.grad_compress import GradCompressConfig
from repro.train.train_step import init_train_state, make_train_step

CFG = ModelConfig(
    name="demo-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=50304,
    qkv_bias=True, attn_chunk=256,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    model = build_model(CFG)
    print(f"params: {CFG.param_count() / 1e6:.0f}M")
    ocfg = opt.AdamWConfig(lr=6e-4, warmup_steps=50)
    gc_cfg = GradCompressConfig(enabled=True)
    params, state = init_train_state(model, jax.random.PRNGKey(0), ocfg, gc_cfg)
    step_fn = jax.jit(make_train_step(model, ocfg, 1, gc_cfg),
                      donate_argnums=(0, 1))
    tp = TokenPipelineConfig(vocab=CFG.vocab, batch=args.batch,
                             seq_len=args.seq)
    for step in range(args.steps):
        tokens, labels = global_batch(tp, step)
        params, state, m = step_fn(
            params, state,
            {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)})
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": state})
            print(f"  checkpoint @ {step + 1}")
    print(f"final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
