"""Scenario: archival + remote analysis of a simulation campaign.

A "simulation" produces frames; the archiver compresses the stream in
windows with trajectory preservation; an "analyst" later decompresses
and extracts critical-point tracks, which must match the originals
exactly -- the paper's motivating workflow end to end.

    PYTHONPATH=src python examples/flow_archive.py
"""
import numpy as np

from repro.core import CompressionConfig, compress, decompress, fixedpoint
from repro.core import trajectory
from repro.data import synthetic


def main():
    # the full campaign (e.g. streamed from a solver)
    u, v = synthetic.double_gyre(T=48, H=48, W=96)
    meta = dict(dt=0.1, dx=2.0 / 95, dy=1.0 / 47)

    # --- archiver: window the stream, compress each window
    window = 16
    blobs = []
    for t0 in range(0, u.shape[0], window):
        cfg = CompressionConfig(eb=5e-3, mode="rel", predictor="mop", **meta)
        blob, stats = compress(u[t0 : t0 + window], v[t0 : t0 + window], cfg)
        blobs.append(blob)
        print(f"window {t0:3d}: ratio {stats['ratio']:6.2f}x  "
              f"{stats['verify_rounds']} corrections")
    raw = u.nbytes + v.nbytes
    comp = sum(len(b) for b in blobs)
    print(f"archive: {raw / 2**20:.1f} MiB -> {comp / 2**20:.2f} MiB "
          f"({raw / comp:.1f}x)")

    # --- analyst: restore and extract trajectories per window
    for i, blob in enumerate(blobs):
        t0 = i * window
        ur, vr = decompress(blob)
        scale, uo, vo = fixedpoint.to_fixed(u[t0 : t0 + window],
                                            v[t0 : t0 + window])
        ud, vd = fixedpoint.refix(ur, vr, scale)
        tr0 = trajectory.extract_tracks(uo, vo)
        tr1 = trajectory.extract_tracks(ud, vd)
        assert tr0 == tr1, (tr0, tr1)
        print(f"window {t0:3d}: {tr0['n_tracks']} tracks, "
              f"{tr0['n_crossing_nodes']} crossings -- identical after "
              f"decompression")
    print("campaign archived and analyzed with zero topology distortion.")


if __name__ == "__main__":
    main()
