"""Quickstart: compress a time-varying vector field with exact
critical-point-trajectory preservation.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CompressionConfig, compress, decompress, metrics
from repro.data import synthetic


def main():
    # a von Karman-style vortex street: moving critical points
    u, v = synthetic.vortex_street(T=32, H=64, W=128)
    print(f"field: {u.shape}, {2 * u.nbytes / 2**20:.1f} MiB")

    cfg = CompressionConfig(
        eb=1e-2,            # 1% of value range
        mode="rel",
        predictor="mop",    # block-adaptive Lorenzo / semi-Lagrangian
        dt=0.05, dx=2.0 / 127, dy=1.0 / 63,   # generation metadata (CFL)
    )
    blob, stats = compress(u, v, cfg)
    print(f"compressed: {len(blob) / 2**20:.2f} MiB "
          f"(ratio {stats['ratio']:.1f}x, "
          f"{stats['lossless_frac'] * 100:.2f}% lossless vertices, "
          f"{stats['verify_rounds']} correction rounds)")

    u_rec, v_rec = decompress(blob)
    m = metrics.evaluate(u, v, u_rec, v_rec, stats["scale"],
                         stats["orig_bytes"], stats["comp_bytes"])
    print(f"PSNR {m['PSNR']:.1f} dB, max_err {m['max_err']:.2e} "
          f"(bound {stats['eb_abs']:.2e})")
    print(f"false cases: FC_t={m['FC_t']} FC_s={m['FC_s']}  "
          f"trajectories: {m['n_traj_orig']} -> {m['n_traj_rec']}")
    assert m["FC_t"] == 0 and m["FC_s"] == 0
    assert m["n_traj_orig"] == m["n_traj_rec"]
    print("every critical-point trajectory preserved exactly.")


if __name__ == "__main__":
    main()
