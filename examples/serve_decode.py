"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_decode.py

Thin wrapper over the production launcher (repro.launch.serve) using the
reduced yi-6b-family config on CPU.
"""
from repro.launch import serve


def main():
    serve.main([
        "--arch", "yi_6b", "--smoke",
        "--requests", "12", "--batch", "4",
        "--prompt-len", "32", "--gen-len", "12",
    ])


if __name__ == "__main__":
    main()
