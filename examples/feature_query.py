"""Scenario: feature-directed retrieval from a tiled archive.

An archiver writes a vortex-street simulation into one tiled CPTT1
container (sidecar track index included); an analyst later asks
"which vortex cores exist, and what exactly did core #k do?" --
touching only the footer for the query and only the covering units for
the reconstruction, never the full field.

    PYTHONPATH=src python examples/feature_query.py
"""
import numpy as np

from repro import analysis
from repro.core import CompressionConfig, TileGrid, compress_tiled
from repro.data import synthetic


def main():
    u, v = synthetic.vortex_street(T=24, H=48, W=96)
    cfg = CompressionConfig(eb=5e-3, mode="rel", predictor="mop",
                            dt=0.05, dx=2.0 / 95, dy=1.0 / 47)
    grid = TileGrid(tile_h=24, tile_w=32, window_t=8)
    blob, stats = compress_tiled(u, v, cfg, grid)
    print(f"archive: {stats['orig_bytes'] / 2**20:.1f} MiB -> "
          f"{stats['comp_bytes'] / 2**20:.2f} MiB in "
          f"{stats['n_units']} units")

    # query: rotating cores alive in the first half, footer parse only
    cores = [s for t in ("center", "spiral_in", "spiral_out")
             for s in analysis.query_tracks(
                 blob, cp_type=t, trange=(0, u.shape[0] // 2))]
    cores = {s["track_id"]: s for s in cores}.values()
    print(f"{len(cores)} rotating-core tracks "
          f"(of {len(analysis.track_summaries(blob))} total)")

    # reconstruct the longest-lived core from its covering units only
    best = max(cores, key=lambda s: s["t_max"] - s["t_min"])
    res = analysis.decode_for_track(blob, best["track_id"])
    t = res.track
    print(f"track {t.track_id} ({t.dominant_type}): "
          f"{len(t.nodes)} nodes, t [{t.t_min:.1f}, {t.t_max:.1f}], "
          f"drifts x {t.nodes[0, 2]:.1f} -> {t.nodes[-1, 2]:.1f}")
    print(f"read {res.units_read}/{res.units_total} units "
          f"({res.bytes_read}/{len(blob)} bytes)")
    assert res.units_read < res.units_total


if __name__ == "__main__":
    main()
