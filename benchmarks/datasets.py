"""Benchmark dataset registry: synthetic analogues of the paper's four
(Table I), sized for CPU-runnable benchmarks, with generation metadata
(dt, dx, dy) for the SL predictor's CFL factors."""
from __future__ import annotations

from repro.data import synthetic


def load_all(small=True):
    if small:
        dims = dict(
            SCF=dict(T=40, H=64, W=96),
            DG=dict(T=40, H=48, W=96),
            HCBA=dict(T=40, H=96, W=48),
            FS=dict(T=40, H=64, W=64),
        )
    else:
        dims = dict(
            SCF=dict(T=120, H=100, W=225),
            DG=dict(T=120, H=64, W=128),
            HCBA=dict(T=120, H=150, W=90),
            FS=dict(T=120, H=128, W=128),
        )
    out = {}
    u, v = synthetic.vortex_street(**dims["SCF"])
    out["SCF"] = (u, v, dict(dt=0.05, dx=2.0 / (dims["SCF"]["W"] - 1),
                             dy=1.0 / (dims["SCF"]["H"] - 1)))
    u, v = synthetic.double_gyre(**dims["DG"])
    out["DG"] = (u, v, dict(dt=0.1, dx=2.0 / (dims["DG"]["W"] - 1),
                            dy=1.0 / (dims["DG"]["H"] - 1)))
    u, v = synthetic.heated_plume(**dims["HCBA"])
    out["HCBA"] = (u, v, dict(dt=1.0, dx=1.0, dy=1.0))
    u, v = synthetic.turbulence(**dims["FS"])
    out["FS"] = (u, v, dict(dt=1.0, dx=1.0, dy=1.0))
    adv_dims = dict(T=40, H=64, W=64) if small else dict(T=120, H=128, W=128)
    u, v = synthetic.advected_turbulence(**adv_dims)
    out["ADV"] = (u, v, dict(dt=1.0, dx=1.0, dy=1.0))
    return out
