"""Fig. 5 analogue: rate-distortion curves (bitrate vs PSNR) per dataset
for 3DL / SL / MoP, plus the fraction of MoP blocks selecting SL."""
from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig, compress, decompress, metrics

from . import datasets

EBS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2)


def main(small=True, ebs=EBS, log=print):
    rows = []
    for name, (u, v, meta) in datasets.load_all(small).items():
        for pred in ("lorenzo", "sl", "mop"):
            for eb in ebs:
                cfg = CompressionConfig(eb=eb, mode="rel", predictor=pred,
                                        **meta)
                blob, stats = compress(u, v, cfg)
                ur, vr = decompress(blob)
                psnr = metrics.psnr(u, v, ur, vr)
                bitrate = 32.0 / stats["ratio"]
                rows.append({
                    "dataset": name, "predictor": pred, "eb": eb,
                    "bitrate": round(bitrate, 4),
                    "PSNR": round(psnr, 2),
                    "CR": round(stats["ratio"], 2),
                    "sl_frac": round(stats["sl_block_frac"], 4),
                    "lossless_frac": round(stats["lossless_frac"], 4),
                })
                log(f"[rd] {name} {pred:8s} eb={eb:.0e} "
                    f"bpp={bitrate:6.3f} PSNR={psnr:6.2f} "
                    f"slfrac={stats['sl_block_frac']:.3f}")
    return rows


if __name__ == "__main__":
    import json

    rows = main()
    with open("experiments/rate_distortion.json", "w") as f:
        json.dump(rows, f, indent=1)
